"""End-to-end training of a ~100M-param transformer for a few hundred steps
on CPU — the assignment's (b) end-to-end driver, using the same launcher a
pod run would use (checkpointing, prefetching, straggler log).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

~100M params: 8 layers, d_model=512, d_ff=2048, vocab 32000.
"""
import argparse
import sys

sys.argv = [sys.argv[0]]  # re-parse inside the launcher
ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
args, _ = ap.parse_known_args()

import dataclasses

from repro.configs import get_config
from repro.configs.base import ModelConfig

# a ~100M llama-style config
cfg = ModelConfig(
    name="lm-100m", family="dense", n_layers=8, d_model=512, n_heads=8,
    n_kv_heads=8, d_ff=2048, vocab_size=32_000, rope_theta=1e4,
)
print(f"model: {cfg.name}, {cfg.param_count()/1e6:.1f}M params")

# register it so the launcher can find it, then delegate
import repro.configs as C

C.ARCHS[cfg.name] = cfg
from repro.launch.train import main

sys.exit(main([
    "--arch", cfg.name, "--steps", str(args.steps),
    "--batch", "8", "--seq", "128", "--shape", "custom",
    "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
    "--lr", "1e-3", "--log-every", "25",
]))
