"""Multi-tenant deployment walkthrough: co-schedule CNNs on one FPGA.

Part 1 shows the three co-execution options for serving ResNet-50 and
MobileNetV2 from a single zc706 and what partition-aware joint DSE buys
over the obvious baselines:

1. equal split          — half the DSPs/BRAM/bandwidth each, designs
                          searched for that fixed split;
2. time multiplexing    — full board per model, round-robin (weights
                          re-stream on every context switch);
3. joint search         — budget split AND per-model CE arrangements
                          searched together.

Part 2 adds a third model and tight per-model SLOs, and lets the
SLO-driven search (``objective="slo"``) pick over the full hybrid space:
each model either owns a dedicated slice or joins the time-multiplexed
shared slice, and the front is driven by graded deadline attainment.

    PYTHONPATH=src python examples/multinet_deploy.py [--n 2048]
"""
import argparse

import numpy as np

from repro.api import Session
from repro.cnn.registry import get_cnn
from repro.core.dse import decode_design
from repro.core.dse.pareto import knee_point
from repro.core.multinet import MultinetSearchConfig
from repro.core.notation import format_spec
from repro.fpga.boards import get_board

ap = argparse.ArgumentParser()
ap.add_argument("--n", type=int, default=2048,
                help="deployment-evaluation budget for EACH arm")
args = ap.parse_args()

names = ("resnet50", "mobilenetv2")
nets = [get_cnn(n) for n in names]
dev = get_board("zc706")
ses = Session(dev)     # one session: every arm reuses the same megabatch
                       # tables and the one compiled joint program
cfg = MultinetSearchConfig(pop_size=min(256, args.n), seed=0)

arms = {}
for arm in ("equal_split", "temporal", "search"):
    res = ses.deploy(nets, args.n, strategy=arm, config=cfg)
    arms[arm] = res
    pts = res.front_points()
    best = pts[np.argmin(pts[:, 0])]
    print(f"{arm:>12}: {res.n_evals} deployments in {res.seconds:.1f}s "
          f"({res.per_eval_us:.0f} µs/deployment) — best worst-model "
          f"latency {best[0] * 1e3:.1f} ms at min-throughput "
          f"{-best[1]:.1f}/s")

# ---- unpack the searched deployment at the knee of the front -------------
res = arms["search"]
pts = res.front_points()
knee = res.front[int(np.argmin(np.abs(pts - knee_point(pts)).sum(1)))]
m = res.metrics
print(f"\nknee deployment (row {knee}):")
print(f"  worst latency {m['worst_latency_s'][knee] * 1e3:.1f} ms | "
      f"aggregate {m['agg_throughput_ips'][knee]:.1f}/s | "
      f"fairness {m['fairness'][knee]:.2f}")
for i, name in enumerate(names):
    pes = m["pes_split"][knee][i]
    buf = m["buf_split"][knee][i]
    bw = m["bw_split"][knee][i]
    spec = decode_design(res.designs.model(i), int(knee), len(nets[i]))
    print(f"  {name}: {pes:.0f} DSPs, {buf / 2**20:.2f} MiB BRAM, "
          f"{bw:.0%} bandwidth")
    print(f"    lat {m['per_model_latency_s'][knee][i] * 1e3:.1f} ms, "
          f"tp {m['per_model_throughput_ips'][knee][i]:.1f}/s")
    print(f"    {format_spec(spec, len(nets[i]))}")

eq = arms["equal_split"].front_points()
print(f"\nequal split never beats {eq[:, 0].min() * 1e3:.1f} ms worst "
      f"latency; the searched split reaches "
      f"{pts[:, 0].min() * 1e3:.1f} ms at the same budget.")

# ---- part 2: tight SLOs on a 3-model mix — the hybrid deployment space ---
print("\n=== SLO-driven hybrid deployments (3-model mix) ===")
names3 = ("resnet50", "mobilenetv2", "densenet121")
nets3 = [get_cnn(n) for n in names3]
slo_s = (0.120, 0.030, 0.130)        # per-model latency SLOs (s)
weights = (1.0, 2.0, 1.0)            # mobilenetv2 carries 2x the traffic
cfg = MultinetSearchConfig(pop_size=min(256, args.n), seed=0,
                           objective="slo", slo_s=slo_s, weights=weights)
slo_arms = {}
for arm in ("search", "temporal", "hybrid"):
    res = ses.deploy(nets3, args.n, strategy=arm, config=cfg)
    slo_arms[arm] = res
    best = res.metrics["slo_attainment_dist"].max()
    label = {"search": "pure spatial", "temporal": "pure temporal",
             "hybrid": "hybrid"}[arm]
    print(f"{label:>14}: best SLO attainment {best:.2f} "
          f"({res.n_evals} deployments, {res.seconds:.1f}s)")

res = slo_arms["hybrid"]
i = int(np.argmax(res.metrics["slo_attainment_dist"]))
m = res.metrics
print(f"\nbest hybrid deployment (attainment "
      f"{m['slo_attainment_dist'][i]:.2f}):")
for j, name in enumerate(names3):
    shared = m["assign"][i][j] > 0.5
    kind = "shared slice (RR)" if shared else "dedicated slice"
    extra = f", {m['time_share'][i][j]:.0%} of its slice's rounds" \
        if shared else ""
    print(f"  {name}: {kind} — {m['pes_split'][i][j]:.0f} DSPs{extra}; "
          f"lat {m['per_model_latency_s'][i][j] * 1e3:.1f} ms "
          f"(SLO {slo_s[j] * 1e3:.0f} ms)")
