"""Quickstart: express, build, and evaluate multiple-CE accelerators with
MCCM through the one front door — ``repro.api.Session`` — using the
paper's §III-B notation end to end.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.api import Session
from repro.cnn.registry import get_cnn
from repro.core.notation import format_spec, parse
from repro.fpga.archs import make_arch
from repro.fpga.boards import get_board

net = get_cnn("resnet50")           # paper Table III workload
dev = get_board("zcu102")           # paper Table II board
ses = Session(dev)                  # one session per process: owns the
                                    # tables + compiled-program caches

print(f"CNN: {net.name} ({len(net)} conv layers, "
      f"{net.total_weights/1e6:.1f}M weights); board: {dev.name} "
      f"({dev.pes} DSPs, {dev.on_chip_bytes/2**20:.1f} MiB BRAM)\n")

# -- 1. the paper's notation ------------------------------------------------
designs = {
    "SegmentedRR {L1-Last:CE1-CE4}": parse("{L1-Last:CE1-CE4}", len(net)),
    "Hybrid      {L1:CE1, L2:CE2, L3:CE3, L4-Last:CE4}":
        parse("{L1:CE1, L2:CE2, L3:CE3, L4-Last:CE4}", len(net)),
    "Segmented   (4 MAC-balanced single-CE segments)":
        make_arch("segmented", net, 4),
}

print(f"{'design':55s} {'latency':>9s} {'thpt':>7s} {'buffer':>9s} "
      f"{'access':>9s}")
for name, spec in designs.items():
    m = ses.evaluate(spec, net)     # scalar: full Metrics, exact reference
    print(f"{name:55s} {m.latency_s*1e3:7.1f}ms {m.throughput_ips:6.1f}/s "
          f"{m.buffer_bytes/2**20:7.2f}MiB {m.access_bytes/1e6:7.1f}MB")

# -- 2. fine-grained bottleneck view (paper use case 2) ----------------------
m = ses.evaluate(make_arch("segmented", net, 4), net)
print("\nper-segment breakdown (Segmented, 4 CEs):")
for s in m.per_segment:
    kind = "MEM-bound" if s.mem_s > s.compute_s else "compute-bound"
    print(f"  seg {s.index}: {s.n_layers:3d} layers  busy {s.busy_s*1e3:6.1f}ms"
          f"  util {s.utilization:5.1%}  {kind}")

# -- 3. any custom arrangement in one line -----------------------------------
custom = "{L1-L10:CE1-CE5, L11-L30:CE6, L31-Last:CE7}"
m = ses.evaluate(custom, net)       # notation strings parse in place
print(f"\ncustom {format_spec(parse(custom, len(net)), len(net))}:")
print(f"  latency {m.latency_s*1e3:.1f} ms, throughput "
      f"{m.throughput_ips:.1f}/s, buffers {m.buffer_bytes/2**20:.2f} MiB")

# -- 4. the same session batches: one jitted call over many designs ----------
batch = ses.evaluate(list(designs.values()) + [parse(custom, len(net))], net)
print(f"\nbatched re-evaluation of all {len(batch['latency_s'])} designs "
      f"(shared tables + one compiled program):")
print("  latencies:",
      " ".join(f"{x*1e3:.1f}ms" for x in batch["latency_s"]))
