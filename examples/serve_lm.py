"""Batched serving with the ServeEngine: prefill a request batch, decode
with greedy sampling, report prefill/decode throughput.

    PYTHONPATH=src python examples/serve_lm.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.models.runtime import Runtime
from repro.serve.engine import ServeEngine

cfg = get_config("llama3.2-1b").reduced()
engine = ServeEngine(cfg, rt=Runtime(), temperature=0.0)
params = engine.api.init(jax.random.key(0))

rng = np.random.default_rng(0)
prompts = [rng.integers(1, cfg.vocab_size, n).tolist()
           for n in (12, 24, 7, 18)]

res = engine.generate(params, prompts, max_new_tokens=24)
for i, (p, toks) in enumerate(zip(prompts, res.tokens)):
    print(f"request {i}: {len(p):2d} prompt toks -> "
          f"{toks[:10]}{'...' if len(toks) > 10 else ''}")
print(f"\nprefill: {res.n_prefill} positions in {res.prefill_s*1e3:.0f} ms")
print(f"decode : {res.n_steps} steps in {res.decode_s*1e3:.0f} ms "
      f"({res.tokens_per_s:.1f} tok/s across the batch)")

# temperature sampling variant
engine_t = ServeEngine(cfg, rt=Runtime(), temperature=0.8, seed=7)
res_t = engine_t.generate(params, prompts[:2], max_new_tokens=12)
print(f"\nsampled (T=0.8): {res_t.tokens[0][:10]}")
