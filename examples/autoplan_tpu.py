"""MCCM-TPU plan exploration (the paper's DSE, hardware-adapted): rank
parallelism plans for an assigned (arch × shape) cell analytically, in
milliseconds — then the top plan is what the dry-run verifies on the
production mesh.

    PYTHONPATH=src python examples/autoplan_tpu.py --arch qwen2.5-32b
"""
import argparse
import time

from repro.configs import SHAPES, get_config
from repro.tpu.autoplan import rank


class MeshView:     # mesh *shape* is all the analytical model needs
    def __init__(self, shape):
        self.shape = shape


ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen2.5-32b")
ap.add_argument("--shape", default="train_4k")
args = ap.parse_args()

cfg = get_config(args.arch)
shape = SHAPES[args.shape]
mesh = MeshView({"data": 16, "model": 16})

t0 = time.time()
ranked = rank(cfg, shape, mesh)
dt = time.time() - t0
print(f"{args.arch} × {args.shape} on 16×16: ranked {len(ranked)} plans "
      f"in {dt*1e3:.1f} ms ({dt/len(ranked)*1e6:.0f} µs/plan)\n")

print(f"{'plan':52s} {'step':>8s} {'dominant':>10s} {'HBM':>7s} fits")
for r in ranked[:8]:
    e = r.est
    print(f"{r.plan.name[:52]:52s} {r.step_s*1e3:6.1f}ms "
          f"{e.dominant():>10s} {e.hbm_capacity_bytes/2**30:5.1f}GB "
          f"{'✓' if e.fits else '✗'}")
worst = ranked[-1]
best = ranked[0]
print(f"\nbest plan is {worst.step_s/best.step_s:.1f}× faster than the "
      f"worst candidate — arrangement choice matters (the paper's thesis).")
