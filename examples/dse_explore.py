"""Design-space exploration (paper use case 3, Fig. 10) — find custom
multiple-CE designs that dominate the fixed templates, comparing the
paper's blind random sampling with the guided multi-objective search.

    PYTHONPATH=src python examples/dse_explore.py [--n 20000]
"""
import argparse

import numpy as np

from repro.api import Session
from repro.cnn.registry import get_cnn
from repro.core.dse import decode_design, dominating_indices, orient
from repro.core.notation import format_spec
from repro.fpga.archs import make_arch
from repro.fpga.boards import get_board

ap = argparse.ArgumentParser()
ap.add_argument("--n", type=int, default=20_000,
                help="evaluation budget for EACH strategy")
args = ap.parse_args()

net, dev = get_cnn("xception"), get_board("vcu110")
ses = Session(dev)                 # tables + compiles shared by every call
OBJ = ("throughput_ips", "buffer_bytes")

# templates to beat
best_seg = max((ses.evaluate(make_arch("segmented", net, n), net)
                for n in range(2, 12)), key=lambda m: m.throughput_ips)
print(f"template best: segmented tp {best_seg.throughput_ips:.1f}/s, "
      f"buffers {best_seg.buffer_bytes/2**20:.2f} MiB")

rnd = ses.explore(net, args.n, family="mixed", seed=0, objectives=OBJ)
print(f"random: {rnd.n_evals} designs in {rnd.seconds:.1f}s "
      f"({rnd.per_design_us:.0f} µs/design — paper: 6300 µs)")
srch = ses.explore(net, args.n, family="mixed", strategy="search",
                   seed=1, objectives=OBJ)
print(f"search: {srch.n_evals} designs in {srch.seconds:.1f}s "
      f"({srch.per_design_us:.0f} µs/design incl. search overhead)")


def show_front(label, res):
    tp = res.metrics["throughput_ips"]
    buf = res.metrics["buffer_bytes"]
    front = res.front
    print(f"\n{label} Pareto front ({len(front)} designs):")
    for i in front[np.argsort(-tp[front])][:8]:
        spec = decode_design(res.batch, int(i), len(net))
        print(f"  tp {tp[i]:6.1f}/s  buf {buf[i]/2**20:6.2f} MiB  "
              f"{format_spec(spec, len(net))[:70]}")


show_front("random", rnd)
show_front("search", srch)

# side by side: does the guided front dominate the random picks?
rp = orient(rnd.metrics, OBJ)
sp = orient(srch.metrics, OBJ)
ref = rp[int(np.argmin(rp[:, 0]))]          # random's best-throughput design
dom = dominating_indices(sp, ref)
print(f"\nsearch designs strictly dominating random's best-throughput "
      f"design: {len(dom)}")

for label, res in (("random", rnd), ("search", srch)):
    tp = res.metrics["throughput_ips"]
    buf = res.metrics["buffer_bytes"]
    match = tp >= best_seg.throughput_ips * 0.995
    if match.any():
        save = 1 - buf[match].min() / best_seg.buffer_bytes
        print(f"{label}: same throughput as the best template with "
              f"{save:.0%} less buffer (paper: up to 48%)")
