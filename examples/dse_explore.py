"""Design-space exploration (paper use case 3, Fig. 10) — find custom
multiple-CE designs that dominate the fixed templates.

    PYTHONPATH=src python examples/dse_explore.py [--n 20000]
"""
import argparse

import numpy as np

from repro.cnn.registry import get_cnn
from repro.core.dse import decode_design, explore, pareto
from repro.core.evaluator import evaluate_design
from repro.core.notation import format_spec
from repro.fpga.archs import make_arch
from repro.fpga.boards import get_board

ap = argparse.ArgumentParser()
ap.add_argument("--n", type=int, default=20_000)
args = ap.parse_args()

net, dev = get_cnn("xception"), get_board("vcu110")

# templates to beat
best_seg = max((evaluate_design(make_arch("segmented", net, n), net, dev)
                for n in range(2, 12)), key=lambda m: m.throughput_ips)
print(f"template best: segmented tp {best_seg.throughput_ips:.1f}/s, "
      f"buffers {best_seg.buffer_bytes/2**20:.2f} MiB")

res = explore(net, dev, n=args.n, family="mixed", seed=0)
print(f"evaluated {args.n} designs in {res.seconds:.1f}s "
      f"({res.per_design_us:.0f} µs/design — paper: 6300 µs)")

tp = res.metrics["throughput_ips"]
buf = res.metrics["buffer_bytes"]
front = pareto(np.stack([-tp, buf], axis=1))
print(f"\nPareto front ({len(front)} designs):")
for i in front[np.argsort(-tp[front])][:8]:
    spec = decode_design(res.batch, int(i), len(net))
    print(f"  tp {tp[i]:6.1f}/s  buf {buf[i]/2**20:6.2f} MiB  "
          f"{format_spec(spec, len(net))[:70]}")

match = tp >= best_seg.throughput_ips * 0.995
if match.any():
    save = 1 - buf[match].min() / best_seg.buffer_bytes
    print(f"\nsame throughput as the best template with {save:.0%} "
          f"less buffer (paper: up to 48%)")
