"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import json
import os
import time
from contextlib import contextmanager

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")


def get_session():
    """The harness-wide :class:`repro.api.Session`: every benchmark
    evaluates through it, so tables and compiled programs are shared
    across the whole ``benchmarks.run`` sweep (and the persistent compile
    cache is enabled once, via the session's resolved EvalConfig)."""
    from repro.api import default_session
    return default_session()


def save(name: str, payload: dict) -> str:
    os.makedirs(ART, exist_ok=True)
    path = os.path.join(ART, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    return path


@contextmanager
def timed(label: str):
    t0 = time.time()
    yield
    print(f"[{label}] {time.time() - t0:.2f}s")


def fmt_table(rows: list[list], headers: list[str]) -> str:
    widths = [max(len(str(r[i])) for r in [headers] + rows)
              for i in range(len(headers))]
    def line(r):
        return "  ".join(str(c).ljust(w) for c, w in zip(r, widths))
    sep = "  ".join("-" * w for w in widths)
    return "\n".join([line(headers), sep] + [line(r) for r in rows])
