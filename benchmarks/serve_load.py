"""Load-generate the serving front and report latency percentiles.

Replays a deterministic mixed-traffic trace — point probes and bulk
sweeps across the CNN zoo x paper boards, interactive and batch lanes —
against an in-process :class:`repro.serve.EvalServer`, pipelined over one
:class:`ServeClient` connection, and reports p50/p99 request latency and
aggregate designs/sec.  A background DSE job (``submit_search``) runs at
full budget for the second half of the replay, and one deadline-bearing
interactive probe is timed against it — the measured guarantee that the
batch lane cannot starve the interactive lane (docs/serving.md).

The trace is a pure function of ``--seed`` (``make_trace``): same seed,
same nets/boards/designs/arrival offsets, byte-identical ``--print-trace``
output (asserted by ``tests/test_serve_load.py``).  Everything heavyweight
imports inside :func:`run`, so ``--print-trace`` stays jax-free.

Gate wiring: ``benchmarks/perf_gate.py`` runs this at reduced budget and
commits the payload as the ``serve_load`` BENCH point with the
``serve_p99_bounded`` / ``serve_interactive_deadline`` checks.
"""
from __future__ import annotations

import argparse
import json
import random
import time

#: CNN x board mix of the trace (names resolved inside the server)
TRACE_NETS = ("mobilenetv2", "resnet50", "xception", "densenet121")
TRACE_BOARDS = ("zc706", "vcu108", "vcu110", "zcu102")
#: mean request inter-arrival of the replay schedule, seconds — chosen
#: so the offered design rate sits near half the drain's measured service
#: capacity for the 4 x 4 net x board mix, so the percentiles measure
#: serving overhead under load rather than unbounded saturation queueing
MEAN_ARRIVAL_S = 0.1
#: bulk-request share of the trace (batch lane)
BULK_FRACTION = 0.2


def _design(rng: random.Random) -> str:
    """One random-but-valid notation string.  Split points stay below 9
    (every zoo net is deeper), so the trace needs no net metadata."""
    kind = rng.random()
    if kind < 0.5:
        return f"{{L1-Last:CE1-CE{rng.randint(1, 8)}}}"
    m = rng.randint(1, 8)
    a = rng.randint(1, 4)
    b = rng.randint(1, 4)
    return (f"{{L1-L{m}:CE1-CE{a}, "
            f"L{m + 1}-Last:CE{a + 1}-CE{a + b}}}")


def make_trace(seed: int, n_requests: int = 64) -> list[dict]:
    """The deterministic request trace: ``n_requests`` entries of
    ``{t, net, board, designs, priority}`` with exponential arrival
    offsets.  Pure ``random.Random(seed)`` — no numpy, no jax — so the
    CLI can print it without touching the evaluation stack."""
    rng = random.Random(seed)
    t = 0.0
    trace = []
    for _ in range(n_requests):
        t += rng.expovariate(1.0 / MEAN_ARRIVAL_S)
        bulk = rng.random() < BULK_FRACTION
        n = rng.randint(64, 96) if bulk else rng.randint(1, 4)
        trace.append({
            "t": round(t, 6),
            "net": rng.choice(TRACE_NETS),
            "board": rng.choice(TRACE_BOARDS),
            "designs": [_design(rng) for _ in range(n)],
            "priority": "batch" if bulk else "interactive",
        })
    return trace


def percentile(xs: list[float], q: float) -> float:
    """Linear-interpolated percentile (numpy-free: the module must stay
    importable without the evaluation stack)."""
    if not xs:
        return float("nan")
    s = sorted(xs)
    pos = (len(s) - 1) * q
    lo = int(pos)
    hi = min(lo + 1, len(s) - 1)
    return s[lo] + (s[hi] - s[lo]) * (pos - lo)


def run(seed: int = 0, quick: bool = False, verbose: bool = True) -> dict:
    """Replay the trace against an in-process server; returns the BENCH
    payload (and saves it as ``BENCH_serve``)."""
    try:
        from .common import save          # python -m benchmarks.serve_load
    except ImportError:
        from common import save           # script run from benchmarks/
    from repro.api import EvalConfig, Session
    from repro.cnn.registry import get_cnn
    from repro.fpga.boards import get_board
    from repro.serve import EvalServer, ServeClient

    n_requests = 24 if quick else 64
    dse_budget = 2048 if quick else 100_000
    deadline_s = 120.0 if quick else 60.0
    trace = make_trace(seed, n_requests)
    designs_total = sum(len(e["designs"]) for e in trace)

    ses = Session(get_board("vcu110"), config=EvalConfig(
        linger_s=0.002, linger_max_s=0.02))
    srv = EvalServer(ses).start()
    host, port = srv.address
    lat: dict[int, float] = {}
    out = {}
    try:
        with ServeClient(host, port) as cli:
            cli.ping()
            # warm tables and every compiled ladder shape the replay can
            # hit (chunk pads are powers of two up to the largest bulk
            # request), so the percentiles measure serving overhead +
            # dispatch, not first-compile time
            warm_rng = random.Random(seed + 1)
            t_warm = time.monotonic()
            for net_name in sorted({e["net"] for e in trace}):
                net = get_cnn(net_name)
                for size in (1, 64, 128, 256):
                    ses.evaluate([_design(warm_rng) for _ in range(size)],
                                 net)
            for board in sorted({e["board"] for e in trace}):
                ses.evaluate(_design(warm_rng), get_cnn(trace[0]["net"]),
                             get_board(board))
            warm_s = time.monotonic() - t_warm

            t0 = time.monotonic()
            futs = []
            for i, e in enumerate(trace):
                now = time.monotonic() - t0
                if e["t"] > now:
                    time.sleep(e["t"] - now)
                t_send = time.monotonic()
                fut = cli.evaluate_async(
                    e["designs"], e["net"], board=e["board"],
                    priority=e["priority"])
                fut.add_done_callback(
                    lambda f, i=i, t=t_send:
                    lat.__setitem__(i, time.monotonic() - t))
                futs.append(fut)
            for f in futs:
                f.result(timeout=600)
            wall = time.monotonic() - t0

            # the contract probe: one deadline-bearing interactive
            # evaluation while a full-budget DSE job holds the batch lane
            dse_fut = ses.submit_search(get_cnn("mobilenetv2"),
                                        dse_budget, strategy="random",
                                        seed=seed)
            dse_running = not dse_fut.done()
            t_probe = time.monotonic()
            cli.evaluate("{L1-Last:CE1-CE4}", "resnet50", board="zc706",
                         deadline_s=deadline_s, priority="interactive")
            probe_s = time.monotonic() - t_probe
            t_dse = time.monotonic()
            dse = dse_fut.result(timeout=600)
            dse_wait = time.monotonic() - t_dse
            obs = cli.observability()
    finally:
        srv.stop()
        ses.close()

    ms = [v * 1e3 for v in lat.values()]
    stats = obs["stats"]
    out = {
        "seed": seed,
        "quick": quick,
        "n_requests": n_requests,
        "designs_total": designs_total,
        "warm_s": round(warm_s, 3),
        "wall_s": round(wall, 4),
        "designs_per_s": round(designs_total / wall, 1),
        "latency_ms": {
            "p50": round(percentile(ms, 0.50), 3),
            "p99": round(percentile(ms, 0.99), 3),
            "mean": round(sum(ms) / len(ms), 3),
            "max": round(max(ms), 3),
        },
        "dse": {"budget": dse_budget, "n_evals": int(dse.n_evals),
                "tail_wait_s": round(dse_wait, 3)},
        "interactive_under_dse": {
            "latency_s": round(probe_s, 4),
            "deadline_s": deadline_s,
            "met": probe_s < deadline_s,
            "dse_running_at_probe": dse_running,
        },
        "coalesce": {k: stats[k] for k in
                     ("megabatches", "megabatch_requests",
                      "coalesced_chunks", "coalesced_merges",
                      "coalesced_splits")},
        "caches": obs["caches"],
    }
    save("BENCH_serve", out)
    if verbose:
        print(json.dumps(out, indent=1))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true",
                    help="small trace + 2048-budget DSE (CI smoke)")
    ap.add_argument("--json", action="store_true",
                    help="print only the JSON payload")
    ap.add_argument("--print-trace", action="store_true",
                    help="print the deterministic trace and exit "
                         "(no evaluation, no jax import)")
    args = ap.parse_args(argv)
    if args.print_trace:
        print(json.dumps(make_trace(args.seed), indent=1))
        return 0
    out = run(seed=args.seed, quick=args.quick, verbose=not args.json)
    if args.json:
        print(json.dumps(out, indent=1))
    return 0 if out["interactive_under_dse"]["met"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
