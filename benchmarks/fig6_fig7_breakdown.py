"""Paper Fig. 6 + Fig. 7: fine-grained bottleneck analysis on ResNet50/ZC706.

Fig. 6 — per-segment compute vs memory-access time of (a) the best-
throughput SegmentedRR and (b) the best-throughput Segmented: SegmentedRR
has memory-bound segments (paper: CEs idle waiting for data ~29% of time);
Segmented has none.

Fig. 7 — off-chip access breakdown (weights vs FMs) of each architecture's
best-throughput instance: weights dominate SegmentedRR and Hybrid accesses
(so FM compression would be pure overhead — the paper's point).
"""
from __future__ import annotations

import numpy as np

from repro.cnn.registry import get_cnn
from repro.fpga.archs import make_arch
from repro.fpga.boards import get_board

from .common import get_session, save

ARCHS = ("segmented_rr", "segmented", "hybrid")
N_RANGE = range(2, 12)


def _best_by_throughput(net, dev):
    """Best-throughput CE count per architecture — ONE batched session
    call over the full (arch × n) candidate grid instead of 30 re-traced
    scalar evaluations."""
    specs = [make_arch(a, net, n) for a in ARCHS for n in N_RANGE]
    out = get_session().evaluate(specs, net, dev)
    tp = out["throughput_ips"].reshape(len(ARCHS), len(N_RANGE))
    best = {}
    for i, a in enumerate(ARCHS):
        j = int(np.argmax(tp[i]))
        k = i * len(N_RANGE) + j
        best[a] = dict(n=list(N_RANGE)[j],
                       **{m: out[m][k] for m in out})
    return best


def run(verbose: bool = True) -> dict:
    net, dev = get_cnn("resnet50"), get_board("zc706")
    best = _best_by_throughput(net, dev)
    # the per-segment / per-layer breakdown needs the scalar evaluator's
    # detail records — run it for the two winning instances only
    ses = get_session()
    detail = {a: ses.evaluate(make_arch(a, net, best[a]["n"]), net, dev)
              for a in ("segmented_rr", "segmented")}

    # ---- Fig 6: segment compute vs memory time ----
    fig6 = {}
    for arch in ("segmented_rr", "segmented"):
        m = detail[arch]
        total = sum(max(s.compute_s, s.mem_s) for s in m.per_segment) or 1.0
        fig6[arch] = {
            "n_ces": best[arch]["n"],
            "segments": [dict(idx=s.index, compute=s.compute_s / total,
                              mem=s.mem_s / total,
                              mem_bound=s.mem_s > s.compute_s)
                         for s in m.per_segment],
        }
    # per-layer granularity for the SegmentedRR block (its single block
    # spans all layers; paper's "segments 22-26" are layer groups)
    m_rr = detail["segmented_rr"]
    blk = m_rr.blocks[0]
    mem_bound_layers = [r.layer.index for r in blk.per_layer
                        if r.mem_cycles > r.compute_cycles]
    idle_frac = (sum(max(r.mem_cycles - r.compute_cycles, 0.0)
                     for r in blk.per_layer)
                 / sum(max(r.mem_cycles, r.compute_cycles)
                       for r in blk.per_layer))
    fig6["segmented_rr"]["mem_bound_layers"] = mem_bound_layers
    fig6["segmented_rr"]["idle_fraction"] = idle_frac

    # ---- Fig 7: access breakdown (straight from the batched metrics) ----
    fig7 = {}
    for arch, b in best.items():
        fig7[arch] = dict(n_ces=b["n"],
                          weights=float(b["weight_access_bytes"]),
                          fms=float(b["fm_access_bytes"]),
                          total=float(b["access_bytes"]))

    seg_mem_bound = any(s["mem_bound"] for s in fig6["segmented"]["segments"])
    checks = {
        "segmented_rr_has_memory_bound_layers": len(mem_bound_layers) > 0,
        "segmented_has_no_memory_bound_segments": not seg_mem_bound,
        "weights_dominate_rr_and_hybrid": all(
            fig7[a]["weights"] > fig7[a]["fms"]
            for a in ("segmented_rr", "hybrid")),
    }
    if verbose:
        print(f"SegmentedRR[{fig6['segmented_rr']['n_ces']}]: "
              f"{len(mem_bound_layers)} memory-bound layers, idle fraction "
              f"{idle_frac:.0%} (paper: 29%)")
        for a, d in fig7.items():
            print(f"Fig7 {a}[{d['n_ces']}]: weights {d['weights']/1e6:.1f} MB"
                  f" / FMs {d['fms']/1e6:.1f} MB")
        print("checks:", checks)
    out = {"fig6": fig6, "fig7": fig7, "checks": checks}
    save("fig6_fig7_breakdown", out)
    return out


if __name__ == "__main__":
    run()
