"""Perf gate: record the evaluate_batch hot-path trajectory.

Emits ``artifacts/bench/BENCH_eval.json`` with µs/design at the DSE batch
sizes, jit compile time and a peak-memory estimate, so every PR can be
checked against the recorded trajectory instead of folklore.

    python -m benchmarks.perf_gate            # full gate (B up to 65536)
    python -m benchmarks.perf_gate --quick    # CI smoke (small B)

The committed JSON is the trajectory; re-run and commit when the hot path
changes.  ``reference.pre_fusion_b4096_us`` pins the pre-fusion baseline
this PR replaced (measured on the same container) so speedups stay
auditable.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np

from repro.compat import enable_persistent_compilation_cache
from repro.cnn.registry import get_cnn
from repro.core import batch_eval
from repro.core.batch_eval import (DEFAULT_TILE, evaluate_batch,
                                   make_device_tables, make_tables,
                                   padded_rows, pes_hint)
from repro.core.dse.samplers import sample_mixed
from repro.fpga.boards import get_board
from repro.kernels.mccm_eval import pair_tables, resolve_backend

from .common import fmt_table, save

FULL_SIZES = (32, 4096, 65536)
QUICK_SIZES = (32, 512)
MULTINET_B_FULL = 1024
MULTINET_B_QUICK = 128

#: pre-fusion evaluate_batch at B=4096 (xception × vcu110, this container),
#: measured at the commit preceding the fused/tiled hot path
PRE_FUSION_B4096_US = 348.6


def _peak_bytes_estimate(B: int, tables, dev) -> int:
    """Analytic live-set estimate of the tiled hot path (see docs/perf.md):
    ~3 (tile, L, P) parallelism-search blocks + the per-tile layer maps
    (CE one-hot, segment one-hot, scan temporaries), plus the (B,)-sized
    in/out arrays."""
    from repro.core.dse.encoding import NC, NS

    pairs = pair_tables(tables.candidates, pes_hint(dev.pes))
    P = len(pairs.pair_prod)
    tile = DEFAULT_TILE
    per_tile = 3 * tile * tables.max_L * P * 4 \
        + tile * tables.max_L * (NC + NS + 8) * 4
    io = B * (3 * NS + NC) * 4
    return per_tile + io


def run(verbose: bool = True, quick: bool = False,
        sizes=None) -> dict:
    enable_persistent_compilation_cache()
    backend = resolve_backend(None)
    net, dev = get_cnn("xception"), get_board("vcu110")
    tables = make_tables(net)
    rng = np.random.default_rng(0)
    sizes = sizes or (QUICK_SIZES if quick else FULL_SIZES)

    jax.clear_caches()
    table, points = [], {}
    for B in sizes:
        db = sample_mixed(rng, len(net), B)
        t0 = time.time()
        r = evaluate_batch(db, tables, dev)
        jax.block_until_ready(r["latency_s"])
        first_s = time.time() - t0
        reps = 1 if quick else 3
        t0 = time.time()
        for _ in range(reps):
            r = evaluate_batch(db, tables, dev)
            jax.block_until_ready(r["latency_s"])
        steady_s = (time.time() - t0) / reps
        # batches pad to a tile multiple: B=32 executes 128 rows.  Both
        # views are recorded — us_per_design is the user-facing cost of a
        # B-design call, us_per_row the per-executed-row throughput.
        rows = padded_rows(B)
        us = steady_s / B * 1e6
        peak = _peak_bytes_estimate(B, tables, dev)
        try:
            devt = make_device_tables(dev)
            mem = batch_eval._evaluate_jit.lower(
                db, tables, devt, backend=backend, tile=DEFAULT_TILE,
                fm_tile_rows=2, pes_hint_static=pes_hint(dev.pes),
                design_tile=16).compile().memory_analysis()
            xla_peak = int(getattr(mem, "temp_size_in_bytes", 0) or 0)
        except Exception:  # noqa: BLE001 — backend without memory stats
            xla_peak = 0
        points[str(B)] = {
            "us_per_design": us,
            "us_per_row": steady_s / rows * 1e6,
            "rows_executed": rows,
            "steady_s": steady_s,
            "compile_s": max(first_s - steady_s, 0.0),
            "peak_bytes_estimate": peak,
            "xla_temp_bytes": xla_peak,
        }
        table.append([f"B={B}", f"{us:.1f}", f"{steady_s / rows * 1e6:.1f}",
                      str(rows), f"{max(first_s - steady_s, 0.0):.2f}",
                      f"{peak/1e6:.1f}"])

    # ---- multinet joint-eval point: µs/deployment at M=2 + compile count
    from repro.core.dse.encoding import stack_designs
    from repro.core.multinet import (DEFAULT_MAX_M, joint_evaluate,
                                     make_multi_tables, sample_shares)
    from repro.core.multinet import joint_eval as _je

    mb = MULTINET_B_QUICK if quick else MULTINET_B_FULL
    nets = [get_cnn("resnet50"), get_cnn("mobilenetv2")]
    mdev = get_board("zc706")
    mt = make_multi_tables(nets)
    md = stack_designs([sample_mixed(rng, len(n), mb) for n in nets],
                       DEFAULT_MAX_M)
    sh = [sample_shares(rng, mb, DEFAULT_MAX_M, 2) for _ in range(3)]
    misses0 = _je._joint_spatial_jit._cache_size()
    t0 = time.time()
    r = joint_evaluate(md, mt, mdev, pes_shares=sh[0], buf_shares=sh[1],
                       bw_shares=sh[2])
    jax.block_until_ready(r["worst_latency_s"])
    first_s = time.time() - t0
    reps = 1 if quick else 3
    t0 = time.time()
    for _ in range(reps):
        r = joint_evaluate(md, mt, mdev, pes_shares=sh[0],
                           buf_shares=sh[1], bw_shares=sh[2])
        jax.block_until_ready(r["worst_latency_s"])
    msteady = (time.time() - t0) / reps
    mcompiles = _je._joint_spatial_jit._cache_size() - misses0
    points["multinet_m2"] = {
        "B": mb,
        "max_m": DEFAULT_MAX_M,
        "us_per_deployment": msteady / mb * 1e6,
        "us_per_model_eval": msteady / (mb * 2) * 1e6,
        "steady_s": msteady,
        "compile_s": max(first_s - msteady, 0.0),
        "compile_count": mcompiles,
    }
    table.append([f"multinet M=2 B={mb}",
                  f"{msteady / mb * 1e6:.1f}",
                  f"{msteady / (mb * 2) * 1e6:.1f}", str(mb),
                  f"{max(first_s - msteady, 0.0):.2f}", "-"])

    # ---- hybrid joint-eval point: µs/deployment at M=3, mixed
    # spatial/shared assignments, single compile across assignment changes
    from repro.core.dse.encoding import sample_assign

    hnets = [get_cnn(n) for n in ("resnet50", "mobilenetv2",
                                  "densenet121")]
    hmt = make_multi_tables(hnets)
    hmd = stack_designs([sample_mixed(rng, len(n), mb) for n in hnets],
                        DEFAULT_MAX_M)
    hsh = [sample_shares(rng, mb, DEFAULT_MAX_M, 3) for _ in range(4)]
    asg = sample_assign(rng, mb, DEFAULT_MAX_M, 3)
    hmisses0 = _je._joint_hybrid_jit._cache_size()
    t0 = time.time()
    r = joint_evaluate(hmd, hmt, mdev, mode="hybrid", assign=asg,
                       pes_shares=hsh[0], buf_shares=hsh[1],
                       bw_shares=hsh[2], time_shares=hsh[3])
    jax.block_until_ready(r["worst_latency_s"])
    first_s = time.time() - t0
    # assignment changes (incl. the pure extremes) must reuse the compile
    asg2 = np.zeros_like(asg)
    asg3 = np.zeros_like(asg)
    asg3[:, :3] = 1.0
    assigns = [asg, asg2, asg3]
    t0 = time.time()
    for a in assigns:
        r = joint_evaluate(hmd, hmt, mdev, mode="hybrid", assign=a,
                           pes_shares=hsh[0], buf_shares=hsh[1],
                           bw_shares=hsh[2], time_shares=hsh[3])
        jax.block_until_ready(r["worst_latency_s"])
    hsteady = (time.time() - t0) / len(assigns)
    hcompiles = _je._joint_hybrid_jit._cache_size() - hmisses0
    points["multinet_hybrid_m3"] = {
        "B": mb,
        "max_m": DEFAULT_MAX_M,
        "us_per_deployment": hsteady / mb * 1e6,
        "us_per_model_eval": hsteady / (mb * 3) * 1e6,
        "steady_s": hsteady,
        "compile_s": max(first_s - hsteady, 0.0),
        "compile_count": hcompiles,
    }
    table.append([f"hybrid M=3 B={mb}",
                  f"{hsteady / mb * 1e6:.1f}",
                  f"{hsteady / (mb * 3) * 1e6:.1f}", str(mb),
                  f"{max(first_s - hsteady, 0.0):.2f}", "-"])

    # ---- session-cached re-evaluation: the Session front door at steady
    # state — memoized tables + shared compiles, so re-serving the same
    # net/board costs pure evaluation (table-build amortization made
    # visible in the trajectory)
    from repro.api import Session

    ses = Session(dev)
    sB = QUICK_SIZES[-1] if quick else 4096
    sdb = sample_mixed(rng, len(net), sB)
    r = ses.evaluate(sdb, net)                     # warmup (maybe compiles)
    jax.block_until_ready(r["latency_s"])
    sc0 = ses.compile_stats()["total"]
    t0 = time.time()
    r = ses.evaluate(sdb, net)
    jax.block_until_ready(r["latency_s"])
    first_s = time.time() - t0
    reps = 1 if quick else 3
    t0 = time.time()
    for _ in range(reps):
        r = ses.evaluate(sdb, net)
        jax.block_until_ready(r["latency_s"])
    ssteady = (time.time() - t0) / reps
    scompiles = ses.compile_stats()["total"] - sc0
    points["session_cached"] = {
        "B": sB,
        "us_per_design": ssteady / sB * 1e6,
        "steady_s": ssteady,
        "first_s_after_warmup": first_s,
        "compile_count_after_warmup": scompiles,
        "net_table_builds": ses.stats.net_table_builds,
        "net_table_hits": ses.stats.net_table_hits,
    }
    table.append([f"session B={sB}", f"{ssteady / sB * 1e6:.1f}",
                  f"{ssteady / sB * 1e6:.1f}", str(sB),
                  f"{max(first_s - ssteady, 0.0):.2f}", "-"])

    # ---- resilient session: the same steady-state call with the full
    # fault policy armed (deadline + admission control + retries +
    # breaker + per-call batch validation, docs/robustness.md) — the
    # policy is bookkeeping around the compiled call, gated to <5% of
    # session_cached
    rses = Session(dev, deadline_s=60.0, max_queue=256, max_retries=2,
                   fallback_backend="ref")
    r = rses.evaluate(sdb, net)                    # warmup (shares compiles)
    jax.block_until_ready(r["latency_s"])
    rc0 = rses.compile_stats()["total"]
    t0 = time.time()
    for _ in range(reps):
        r = rses.evaluate(sdb, net)
        jax.block_until_ready(r["latency_s"])
    rsteady = (time.time() - t0) / reps
    rcompiles = rses.compile_stats()["total"] - rc0
    resilient_overhead = rsteady / ssteady - 1.0
    points["resilient_session"] = {
        "B": sB,
        "us_per_design": rsteady / sB * 1e6,
        "steady_s": rsteady,
        "overhead_vs_session_cached": resilient_overhead,
        "compile_count_after_warmup": rcompiles,
        "degraded": rses.stats.degraded,
        "retried": rses.stats.retried,
    }
    table.append([f"resilient B={sB}", f"{rsteady / sB * 1e6:.1f}",
                  f"{rsteady / sB * 1e6:.1f}", str(sB),
                  f"{resilient_overhead * 100:+.1f}%", "-"])

    # ---- telemetry overhead: the same session_cached call with the
    # metrics registry + spans armed (in-process, no trace dir) against a
    # back-to-back disabled re-measure — the observability layer must be
    # a rounding error on the hot path (<3%, docs/observability.md)
    from repro import telemetry as _tele

    was_enabled = _tele.enabled()
    t0 = time.time()
    for _ in range(reps):
        r = ses.evaluate(sdb, net)
        jax.block_until_ready(r["latency_s"])
    toff = (time.time() - t0) / reps
    _tele.enable()                        # registry + spans, no JSONL sink
    t0 = time.time()
    for _ in range(reps):
        r = ses.evaluate(sdb, net)
        jax.block_until_ready(r["latency_s"])
    ton = (time.time() - t0) / reps
    if not was_enabled:
        _tele.disable()
    telemetry_overhead = ton / toff - 1.0
    points["telemetry_session"] = {
        "B": sB,
        "us_per_design_enabled": ton / sB * 1e6,
        "steady_s_enabled": ton,
        "steady_s_disabled": toff,
        "overhead_vs_disabled": telemetry_overhead,
    }
    table.append([f"telemetry B={sB}", f"{ton / sB * 1e6:.1f}",
                  f"{ton / sB * 1e6:.1f}", str(sB),
                  f"{telemetry_overhead * 100:+.1f}%", "-"])

    # ---- schedule search: the temporal-mapping refinement at batch
    # granularity (docs/schedule.md).  The candidate plane rides the
    # same ladder shapes as evaluate_batch, so the whole point costs one
    # compile cold and ZERO warm — and the structural never-worse
    # invariant (candidate 0 is the coarse mapping) holds on the batch
    from repro.schedule.search import _schedule_jit, schedule_batch

    sch0 = _schedule_jit._cache_size()
    t0 = time.time()
    r = schedule_batch(sdb, ses.tables(net), ses.device_tables(dev))
    jax.block_until_ready(r["ref_latency_s"])
    first_s = time.time() - t0
    sch_cold = _schedule_jit._cache_size() - sch0
    t0 = time.time()
    for _ in range(reps):
        r = schedule_batch(sdb, ses.tables(net), ses.device_tables(dev))
        jax.block_until_ready(r["ref_latency_s"])
    schsteady = (time.time() - t0) / reps
    sch_warm = _schedule_jit._cache_size() - sch0 - sch_cold
    sch_ok = bool(np.all(np.asarray(r["ref_latency_s"])
                         <= np.asarray(r["coarse_latency_s"])))
    points["schedule_search"] = {
        "B": sB,
        "us_per_design": schsteady / sB * 1e6,
        "steady_s": schsteady,
        "compile_s": max(first_s - schsteady, 0.0),
        "compile_count_cold": sch_cold,
        "compile_count_warm": sch_warm,
        "cost_vs_evaluate": schsteady / ssteady,
        "refined_leq_coarse": sch_ok,
    }
    table.append([f"schedule B={sB}", f"{schsteady / sB * 1e6:.1f}",
                  f"{schsteady / sB * 1e6:.1f}", str(sB),
                  f"{max(first_s - schsteady, 0.0):.2f}",
                  f"x{schsteady / ssteady:.1f} eval"])

    # ---- sharded weak-scaling: one subprocess per forced host-device
    # count (the backend pins its device count at init, so every point
    # needs a fresh interpreter; benchmarks.sharded_eval exports
    # REPRO_MESH_DEVICES before its first jax import)
    import json as _json
    import subprocess
    import sys

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    cores = os.cpu_count() or 1
    dev_counts = (1, 2) if quick else (1, 2, 4, 8)
    per_dev, recompiles = {}, 0
    for n in dev_counts:
        env["REPRO_MESH_DEVICES"] = str(n)
        cmd = [sys.executable, "-m", "benchmarks.sharded_eval",
               "--devices", str(n), "--json"]
        if quick:
            cmd.append("--quick")
        out = subprocess.run(cmd, env=env, cwd=root, capture_output=True,
                             text=True, timeout=1800)
        if out.returncode != 0:
            raise RuntimeError(f"sharded_eval --devices {n} failed:\n"
                               f"{out.stdout}\n{out.stderr}")
        p = _json.loads(out.stdout.strip().splitlines()[-1])
        per_dev[str(n)] = p
        recompiles += p["eval"]["recompiles_on_tail_reeval"]
        table.append([f"sharded n={n} B={p['eval']['B']}",
                      f"{p['eval']['us_per_design']:.1f}", "-",
                      f"{p['eval']['designs_per_sec']:.0f}/s",
                      f"{p['eval']['compile_s']:.2f}",
                      f"isl {p['search']['island_designs_per_sec']:.0f}/s"])
    base_dps = per_dev["1"]["eval"]["designs_per_sec"]
    scaling = {n: p["eval"]["designs_per_sec"]
               / (base_dps * min(int(n), cores))
               for n, p in per_dev.items()}
    # weak-scaling bounded by physical cores: on a 1-core host every
    # forced device multiplexes the same core, so the absolute-speedup
    # gate only arms when the silicon exists (docs/perf.md)
    session_dps = sB / ssteady
    gate_armed = cores >= 4 and "4" in per_dev and not quick
    speedup_vs_session = (per_dev.get("4", {}).get("eval", {})
                          .get("designs_per_sec", 0.0) / session_dps
                          if "4" in per_dev else None)
    points["sharded_eval"] = {
        "per_device_count": per_dev,
        "weak_scaling_efficiency": scaling,
        "cpu_count": cores,
        "aggregate_4dev_vs_session_cached": speedup_vs_session,
        "gate_2x_armed": gate_armed,
    }

    # ---- serving front: mixed CNN x board traffic over the socket
    # service, with a background DSE job on the batch lane.  Subprocess
    # for isolation: the load generator owns its Session/server and must
    # not inherit this process's warmed default session
    env.pop("REPRO_MESH_DEVICES", None)   # left over from the scan above
    cmd = [sys.executable, "-m", "benchmarks.serve_load", "--json",
           "--seed", "0"]
    if quick:
        cmd.append("--quick")
    out = subprocess.run(cmd, env=env, cwd=root, capture_output=True,
                         text=True, timeout=1800)
    if out.returncode != 0:
        raise RuntimeError(f"serve_load failed:\n{out.stdout}\n"
                           f"{out.stderr}")
    sv = _json.loads(out.stdout.strip())
    points["serve_load"] = sv
    table.append([f"serve n={sv['n_requests']}",
                  "-", "-", f"{sv['designs_per_s']:.0f}/s",
                  f"p50 {sv['latency_ms']['p50']:.1f}ms",
                  f"p99 {sv['latency_ms']['p99']:.1f}ms"])

    payload = {
        "benchmark": "evaluate_batch hot path (xception x vcu110)",
        "backend": backend,
        "tile": DEFAULT_TILE,
        "quick": bool(quick),
        "jax": jax.__version__,
        "cpu_count": os.cpu_count(),
        "created_unix": int(time.time()),
        "points": points,
        "reference": {"pre_fusion_b4096_us": PRE_FUSION_B4096_US,
                      "paper_us": 6300.0},
        "checks": {
            "speedup_2x_at_4096": (
                points["4096"]["us_per_design"] < PRE_FUSION_B4096_US / 2
                if "4096" in points else True),
            "multinet_single_compile": mcompiles == 1,
            "hybrid_single_compile_across_assignments": hcompiles == 1,
            "session_reeval_no_new_compiles": scompiles == 0,
            # the fault policy must stay out of the hot path: <5% over
            # session_cached at the same B, zero new compiles, nothing
            # degraded on a clean run (armed on full runs; quick CI
            # batches are too small to measure 5% reliably)
            "resilient_overhead_lt_5pct": (
                resilient_overhead < 0.05 if not quick else True),
            "resilient_no_new_compiles_no_degrade": (
                rcompiles == 0 and rses.stats.degraded == 0
                and rses.stats.retried == 0),
            # the observability layer must stay off the hot path: <3%
            # over the back-to-back disabled measure (armed on full runs;
            # quick CI batches are too noisy at this granularity)
            "telemetry_overhead_lt_3pct": (
                telemetry_overhead < 0.03 if not quick else True),
            # the schedule layer's compile policy + never-worse
            # invariant (docs/schedule.md): warm searches add zero
            # compiles, refined latency <= coarse on the whole batch
            "schedule_no_new_compiles_on_warm": sch_warm == 0,
            "schedule_refined_leq_coarse": sch_ok,
            "sharded_no_recompile_at_reeval": recompiles == 0,
            # scaled throughput: each in-cores device must hold >= 60%
            # of the single-device rate; vacuous on a 1-core host
            "sharded_weak_scaling_60pct": all(
                eff >= 0.6 for n, eff in scaling.items()
                if int(n) <= cores),
            # the ISSUE acceptance: >= 2x aggregate designs/sec over
            # session_cached with 4 devices — armed only when >= 4
            # physical cores exist (recorded raw either way)
            "sharded_2x_at_4dev": (speedup_vs_session >= 2.0
                                   if gate_armed else True),
            # serving front (docs/serving.md): request p99 stays under
            # 2s on the full mixed trace (armed on full runs — quick CI
            # hosts are too noisy for a latency bound), and an
            # interactive probe always lands inside its deadline while
            # the batch-lane DSE job runs
            "serve_p99_bounded": (
                sv["latency_ms"]["p99"] < 2000.0 if not quick else True),
            "serve_interactive_deadline": sv["interactive_under_dse"][
                "met"],
        },
    }
    if verbose:
        print(fmt_table(table, ["batch", "us/design", "us/row", "rows",
                                "compile_s", "peak_MB(est)"]))
        print("checks:", payload["checks"])
    save("BENCH_eval", payload)
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small batches only (CI smoke)")
    args = ap.parse_args(argv)
    payload = run(quick=args.quick)
    return 0 if all(payload["checks"].values()) else 1


if __name__ == "__main__":
    raise SystemExit(main())
