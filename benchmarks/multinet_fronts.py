"""Multinet co-scheduling fronts: searched spatial split vs the equal-split
and time-multiplexed baselines, at one evaluation budget.

Two deployment studies:

* ``resnet50 + mobilenetv2`` on zc706 — the heterogeneous pair: equal
  split starves ResNet-50 while MobileNetV2 wastes its slice;
* ``resnet50 + mobilenetv2 + densenet121`` on vcu110 — a 3-model mix.

Each runs three guided arms with identical budget, operators and seeds
(the equal-split arm IS the searched arm with the split frozen, so the
front gap isolates partition-awareness): Pareto fronts over
(worst-model latency, max-min model throughput), compared by hypervolume
and knee dominance.

    python -m benchmarks.multinet_fronts            # full budget
    python -m benchmarks.multinet_fronts --quick    # CI smoke
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.cnn.registry import get_cnn
from repro.core.dse.pareto import hypervolume_2d, knee_point
from repro.core.multinet import MultinetSearchConfig
from repro.fpga.boards import get_board

from .common import fmt_table, get_session, save

STUDIES = (
    ("resnet50+mobilenetv2", ("resnet50", "mobilenetv2"), "zc706"),
    ("resnet50+mobilenetv2+densenet121",
     ("resnet50", "mobilenetv2", "densenet121"), "vcu110"),
)
ARMS = ("search", "equal_split", "temporal")
FULL_BUDGET, FULL_POP = 6144, 512
QUICK_BUDGET, QUICK_POP = 768, 256


def _dominates_point(front: np.ndarray, q: np.ndarray) -> bool:
    return bool(((front <= q).all(1) & (front < q).any(1)).any())


def run(verbose: bool = True, quick: bool = False) -> dict:
    budget = QUICK_BUDGET if quick else FULL_BUDGET
    pop = QUICK_POP if quick else FULL_POP
    out: dict = {"budget": budget, "pop_size": pop, "studies": {}}
    checks: dict = {}
    rows = []
    for label, names, board in STUDIES:
        nets = [get_cnn(n) for n in names]
        dev = get_board(board)
        cfg = MultinetSearchConfig(pop_size=pop, seed=3)
        ses = get_session()
        arms = {a: ses.deploy(nets, budget, dev, strategy=a, config=cfg)
                for a in ARMS}
        fronts = {a: r.front_points() for a, r in arms.items()}
        # reference point strictly outside every front: pad each axis
        # OUTWARD (oriented coords can be negative, so scaling the max
        # would move the ref inward and drop boundary points)
        allp = np.concatenate(list(fronts.values()))
        ref = allp.max(0) + 0.05 * np.maximum(np.ptp(allp, 0), 1e-9)
        hv = {a: hypervolume_2d(f, ref) for a, f in fronts.items()}
        study = {
            "board": board,
            "models": list(names),
            "hypervolume": hv,
            "seconds": {a: arms[a].seconds for a in ARMS},
            "per_eval_us": {a: arms[a].per_eval_us for a in ARMS},
            "fronts": {a: fronts[a].tolist() for a in ARMS},
            "best_worst_latency_s": {
                a: float(fronts[a][:, 0].min()) for a in ARMS},
            "best_split_example": np.asarray(
                arms["search"].metrics["pes_split"]
            )[arms["search"].front[0]].tolist(),
        }
        for base in ("equal_split", "temporal"):
            dom = _dominates_point(fronts["search"], knee_point(fronts[base]))
            covers = all(_dominates_point(fronts["search"], q)
                         or (fronts["search"] <= q).all(1).any()
                         for q in fronts[base])
            checks[f"{label}:search_dominates_{base}_knee"] = dom
            checks[f"{label}:search_hv_beats_{base}"] = \
                hv["search"] > hv[base]
            study[f"search_covers_{base}_front"] = covers
        out["studies"][label] = study
        for a in ARMS:
            rows.append([label, a, f"{hv[a]:.3f}",
                         f"{fronts[a][:, 0].min() * 1e3:.1f}ms",
                         f"{arms[a].seconds:.1f}s"])
    out["checks"] = checks
    if verbose:
        print(fmt_table(rows, ["study", "arm", "hv", "best worst-lat",
                               "time"]))
        print("checks:", checks)
    save("multinet_fronts", out)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small budget (CI smoke)")
    args = ap.parse_args(argv)
    payload = run(quick=args.quick)
    return 0 if all(payload["checks"].values()) else 1


if __name__ == "__main__":
    raise SystemExit(main())
