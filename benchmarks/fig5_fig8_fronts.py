"""Paper Fig. 5 + Fig. 8: throughput-vs-accesses (ResNet50/ZC706) and
throughput-vs-buffers (XCp/VCU110) fronts, 10 instances per architecture.

Checks (paper's reading of the figures):
* Fig. 5 — SegmentedRR instances have considerably more off-chip accesses
  than Segmented/Hybrid on the small-BRAM ZC706;
* Fig. 8 — the fronts trade throughput against buffers; the best-throughput
  and min-buffer instances come from different architectures/CE counts.
"""
from __future__ import annotations

from repro.cnn.registry import get_cnn
from repro.fpga.archs import ARCH_NAMES, make_arch
from repro.fpga.boards import get_board

from .common import get_session, save


def _sweep(cnn: str, board: str) -> dict:
    net, dev = get_cnn(cnn), get_board(board)
    ses = get_session()
    pts = {}
    for arch in ARCH_NAMES:
        pts[arch] = []
        for n in range(2, 12):
            m = ses.evaluate(make_arch(arch, net, n), net, dev)
            pts[arch].append(dict(n=n, throughput=m.throughput_ips,
                                  accesses=m.access_bytes,
                                  buffers=float(m.buffer_bytes)))
    return pts


def run(verbose: bool = True) -> dict:
    fig5 = _sweep("resnet50", "zc706")
    fig8 = _sweep("xception", "vcu110")

    import numpy as np
    rr_acc = np.mean([p["accesses"] for p in fig5["segmented_rr"]])
    other_acc = np.mean([p["accesses"]
                         for a in ("segmented", "hybrid") for p in fig5[a]])
    best_tp = max(((a, p) for a in ARCH_NAMES for p in fig8[a]),
                  key=lambda t: t[1]["throughput"])
    min_buf = min(((a, p) for a in ARCH_NAMES for p in fig8[a]),
                  key=lambda t: t[1]["buffers"])
    checks = {
        "fig5_segmented_rr_access_heavy": bool(rr_acc > 1.3 * other_acc),
        "fig8_best_tp_and_min_buf_differ":
            (best_tp[0], best_tp[1]["n"]) != (min_buf[0], min_buf[1]["n"]),
    }
    if verbose:
        print(f"Fig5 ZC706/Res50: mean accesses segmented_rr "
              f"{rr_acc/1e6:.1f} MB vs others {other_acc/1e6:.1f} MB")
        print(f"Fig8 VCU110/XCp: best throughput {best_tp[0]}[{best_tp[1]['n']}]"
              f" = {best_tp[1]['throughput']:.1f} ips; min buffers "
              f"{min_buf[0]}[{min_buf[1]['n']}] = "
              f"{min_buf[1]['buffers']/2**20:.2f} MiB")
        print("checks:", checks)
    out = {"fig5": fig5, "fig8": fig8, "checks": checks}
    save("fig5_fig8_fronts", out)
    return out


if __name__ == "__main__":
    run()
