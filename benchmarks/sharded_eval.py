"""Sharded-evaluator benchmark: one process per device count.

Measures the design-sharded hot path (``EvalMesh`` over shard_map) and
the island-model DSE against their single-device equivalents:

    python -m benchmarks.sharded_eval                 # this host's devices
    python -m benchmarks.sharded_eval --devices 4     # force 4 host devices
    python -m benchmarks.sharded_eval --devices 4 --json   # machine output

Device count must be fixed before jax initialises its backend, so
``main`` exports ``REPRO_MESH_DEVICES`` *first* and only then imports the
repro stack — the same single env-var path users follow (docs/perf.md).
That also means one process measures exactly one device count;
``perf_gate`` spawns this module as a subprocess per point to build the
weak-scaling curve.

On CPU, forced host devices are real XLA devices scheduled across cores:
aggregate designs/sec scales with ``min(ndevices, physical cores)`` and
no further.  Raw numbers are recorded either way; hardware-dependent
gates live in perf_gate and only arm when the cores exist.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

EVAL_B_FULL = 4096
EVAL_B_QUICK = 512
SEARCH_BUDGET_FULL = 4096
SEARCH_BUDGET_QUICK = 1024
SEARCH_POP = 256


def run(ndevices: int, *, b: int | None = None, quick: bool = False,
        verbose: bool = True) -> dict:
    """Measure sharded eval + island search at the current device count.

    Call only after ``REPRO_MESH_DEVICES`` is exported (see ``main``);
    importing anything jax-backed before that pins the backend to one
    device and the mesh silently clamps.
    """
    from repro.core import shard  # noqa: F401  (env bootstrap, pre-jax)
    import jax
    import numpy as np

    from repro.compat import enable_persistent_compilation_cache
    from repro.cnn.registry import get_cnn
    from repro.core.batch_eval import evaluate_batch, make_tables
    from repro.core.dse.samplers import sample_mixed
    from repro.core.dse.search import SearchConfig, search
    from repro.core.shard import EvalMesh, mesh_compile_counts
    from repro.fpga.boards import get_board

    enable_persistent_compilation_cache()
    B = b or (EVAL_B_QUICK if quick else EVAL_B_FULL)
    mesh = EvalMesh()
    got = mesh.ndevices
    if got != ndevices and verbose:
        print(f"# requested {ndevices} devices, backend exposes {got}",
              file=sys.stderr)

    net, dev = get_cnn("xception"), get_board("vcu110")
    tables = make_tables(net)
    rng = np.random.default_rng(0)
    db = sample_mixed(rng, len(net), B)

    def _eval():
        r = evaluate_batch(db, tables, dev, mesh=mesh)
        jax.block_until_ready(r["latency_s"])
        return r

    t0 = time.time()
    _eval()
    first_s = time.time() - t0
    reps = 1 if quick else 3
    t0 = time.time()
    for _ in range(reps):
        _eval()
    steady_s = (time.time() - t0) / reps
    compiles = dict(mesh_compile_counts())

    # a tail batch in the same pad bucket must not trigger a recompile
    db_tail = sample_mixed(rng, len(net), B - 31)
    r = evaluate_batch(db_tail, tables, dev, mesh=mesh)
    jax.block_until_ready(r["latency_s"])
    recompiles = sum(mesh_compile_counts().values()) \
        - sum(compiles.values())

    # ---- island search vs the classic single-population loop at the
    # same evaluation budget (designs/sec is the honest comparison: the
    # island model pays migration + per-island archives for its
    # parallelism, so equal-budget throughput is what must win)
    budget = SEARCH_BUDGET_QUICK if quick else SEARCH_BUDGET_FULL
    scfg = dict(pop_size=SEARCH_POP, budget=budget, seed=0,
                migration_interval=2, migration_elites=8)

    def _timed_search(cfg, m):
        t0 = time.time()
        r = search(net, dev, cfg, mesh=m)
        return time.time() - t0, r

    island_cfg = SearchConfig(**scfg)           # islands = mesh devices
    single_cfg = SearchConfig(**scfg, n_islands=1)
    _timed_search(island_cfg, mesh)             # warm (compiles)
    isl_s, isl_r = _timed_search(island_cfg, mesh)
    _timed_search(single_cfg, None)
    sgl_s, sgl_r = _timed_search(single_cfg, None)

    payload = {
        "ndevices": got,
        "requested": ndevices,
        "cpu_count": os.cpu_count(),
        "quick": bool(quick),
        "jax": jax.__version__,
        "eval": {
            "B": B,
            "us_per_design": steady_s / B * 1e6,
            "designs_per_sec": B / steady_s,
            "steady_s": steady_s,
            "compile_s": max(first_s - steady_s, 0.0),
            "mesh_compiles": compiles,
            "recompiles_on_tail_reeval": int(recompiles),
        },
        "search": {
            "budget": budget,
            "pop_size": SEARCH_POP,
            "n_islands": got,
            "island_designs_per_sec": budget / isl_s,
            "single_designs_per_sec": budget / sgl_s,
            "island_seconds": isl_s,
            "single_seconds": sgl_s,
            "island_front": len(isl_r.front_idx),
            "single_front": len(sgl_r.front_idx),
        },
    }
    if verbose:
        e, s = payload["eval"], payload["search"]
        print(f"devices={got} eval B={B}: {e['us_per_design']:.1f} "
              f"us/design ({e['designs_per_sec']:.0f}/s), "
              f"recompiles={e['recompiles_on_tail_reeval']}")
        print(f"search budget={budget}: island {got}x "
              f"{s['island_designs_per_sec']:.0f}/s vs single "
              f"{s['single_designs_per_sec']:.0f}/s")
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=None,
                    help="host devices to force (default: REPRO_MESH_DEVICES"
                         " if set, else every visible device)")
    ap.add_argument("--b", type=int, default=None, help="eval batch size")
    ap.add_argument("--quick", action="store_true", help="CI smoke sizes")
    ap.add_argument("--json", action="store_true",
                    help="emit one JSON line on stdout (for perf_gate)")
    args = ap.parse_args(argv)

    n = args.devices
    if n is None:
        n = int(os.environ.get("REPRO_MESH_DEVICES", "0") or 0) \
            or (os.cpu_count() or 1)
    # before ANY jax-touching import: this is the whole trick
    os.environ["REPRO_MESH_DEVICES"] = str(n)

    payload = run(n, b=args.b, quick=args.quick, verbose=not args.json)
    if args.json:
        print(json.dumps(payload))
    else:
        from .common import save
        save("BENCH_sharded", payload)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
