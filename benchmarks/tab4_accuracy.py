"""Paper Table IV analog: model-accuracy validation, 150 experiments
(3 architectures × 10 CE counts × 5 CNNs on VCU108).

Vitis HLS is unavailable in this container, so the scalar reference
evaluator plays the role of ground truth for the *vectorized* model
(batch_eval) — the same Eq. 10 accuracy metric the paper uses:

    accuracy = 100 * (1 - |oracle - estimated| / oracle) %

The paper reports averages >90% vs synthesis; our vectorized-vs-scalar
agreement is >99.9% on latency/throughput/buffers and >99% on accesses
(f32 threshold flips on borderline buffer fits — see batch_eval docstring).
The *architecture-choice* fidelity check mirrors the paper's "MCCM
correctly predicted the best architecture in 139/150 (buffers) and 150/150
(latency/throughput/accesses)".
"""
from __future__ import annotations

import numpy as np

from repro.cnn.registry import CNN_NAMES, get_cnn
from repro.fpga.archs import ARCH_NAMES, make_arch
from repro.fpga.boards import get_board

from .common import fmt_table, get_session, save

METRICS = ("latency_s", "throughput_ips", "buffer_bytes", "access_bytes")


def run(verbose: bool = True) -> dict:
    dev = get_board("vcu108")
    ses = get_session()
    acc: dict[str, list[float]] = {m: [] for m in METRICS}
    best_match = {m: 0 for m in METRICS}
    n_cases = 0
    for cnn in CNN_NAMES:
        net = get_cnn(cnn)
        specs = [make_arch(a, net, n)
                 for a in ARCH_NAMES for n in range(2, 12)]
        scalar = [ses.evaluate(s, net, dev) for s in specs]
        batch = ses.evaluate(specs, net, dev)
        svals = {
            "latency_s": np.array([m.latency_s for m in scalar]),
            "throughput_ips": np.array([m.throughput_ips for m in scalar]),
            "buffer_bytes": np.array([float(m.buffer_bytes) for m in scalar]),
            "access_bytes": np.array([m.access_bytes for m in scalar]),
        }
        for metric in METRICS:
            o, e = svals[metric], np.asarray(batch[metric], np.float64)
            acc[metric].extend(
                (100.0 * (1.0 - np.abs(o - e) / np.maximum(o, 1e-12))).tolist())
        # per (cnn, n): does the vector model pick the same best arch?
        for n_i, n in enumerate(range(2, 12)):
            n_cases += 1
            idx = [a_i * 10 + n_i for a_i in range(len(ARCH_NAMES))]
            for metric in METRICS:
                o, e = svals[metric][idx], np.asarray(batch[metric])[idx]
                pick = np.argmax if metric == "throughput_ips" else np.argmin
                if pick(o) == pick(e):
                    best_match[metric] += 1

    rows = []
    summary = {}
    for metric in METRICS:
        a = np.array(acc[metric])
        summary[metric] = dict(mean=float(a.mean()), min=float(a.min()),
                               max=float(a.max()),
                               best_arch_match=f"{best_match[metric]}/{n_cases}")
        rows.append([metric, f"{a.mean():.2f}%", f"{a.min():.2f}%",
                     f"{a.max():.2f}%", summary[metric]["best_arch_match"]])
    checks = {f"{m}_mean_above_90": summary[m]["mean"] > 90.0
              for m in METRICS}
    if verbose:
        print(fmt_table(rows, ["metric", "mean acc", "min", "max",
                               "best-arch match"]))
        print("checks:", checks)
    out = {"summary": summary, "checks": checks,
           "n_experiments": len(acc["latency_s"])}
    save("tab4_accuracy", out)
    return out


if __name__ == "__main__":
    run()
