"""Paper Table IV analog: model-accuracy validation, 150 experiments
(3 architectures × 10 CE counts × 5 CNNs on VCU108).

Vitis HLS is unavailable in this container, so the scalar reference
evaluator plays the role of ground truth for the *vectorized* model
(batch_eval) — the same Eq. 10 accuracy metric the paper uses:

    accuracy = 100 * (1 - |oracle - estimated| / oracle) %

The paper reports averages >90% vs synthesis; our vectorized-vs-scalar
agreement is >99.9% on latency/throughput/buffers and >99% on accesses
(f32 threshold flips on borderline buffer fits — see batch_eval docstring).
The *architecture-choice* fidelity check mirrors the paper's "MCCM
correctly predicted the best architecture in 139/150 (buffers) and 150/150
(latency/throughput/accesses)".

``--schedule`` adds a second cross-validation axis (docs/schedule.md):
the per-CE temporal-mapping search replays the same grid with explicit
loop-order/tiling/buffering choices, and the coarse estimate is scored
against the schedule-refined one by the same Eq. 10 metric.  Because
candidate 0 of the mapping plane IS the coarse mapping, refined latency
is never worse — the gap measures exactly what the coarse model's
implied-ideal-mapping assumption costs, per board.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.cnn.registry import CNN_NAMES, get_cnn
from repro.fpga.archs import ARCH_NAMES, make_arch
from repro.fpga.boards import get_board
from repro.schedule import schedule_specs

from .common import fmt_table, get_session, save

METRICS = ("latency_s", "throughput_ips", "buffer_bytes", "access_bytes")

#: boards the schedule cross-validation sweeps: the paper's VCU108 plus
#: the tight-BRAM ZC706, where explicit mappings actually win buffer
SCHEDULE_BOARDS = ("vcu108", "zc706")


def run(verbose: bool = True, schedule: bool = False,
        quick: bool = False) -> dict:
    dev = get_board("vcu108")
    ses = get_session()
    cnns = CNN_NAMES[:2] if quick else CNN_NAMES
    n_range = range(2, 6) if quick else range(2, 12)
    acc: dict[str, list[float]] = {m: [] for m in METRICS}
    best_match = {m: 0 for m in METRICS}
    n_cases = 0
    for cnn in cnns:
        net = get_cnn(cnn)
        specs = [make_arch(a, net, n)
                 for a in ARCH_NAMES for n in n_range]
        scalar = [ses.evaluate(s, net, dev) for s in specs]
        batch = ses.evaluate(specs, net, dev)
        svals = {
            "latency_s": np.array([m.latency_s for m in scalar]),
            "throughput_ips": np.array([m.throughput_ips for m in scalar]),
            "buffer_bytes": np.array([float(m.buffer_bytes) for m in scalar]),
            "access_bytes": np.array([m.access_bytes for m in scalar]),
        }
        for metric in METRICS:
            o, e = svals[metric], np.asarray(batch[metric], np.float64)
            acc[metric].extend(
                (100.0 * (1.0 - np.abs(o - e) / np.maximum(o, 1e-12))).tolist())
        # per (cnn, n): does the vector model pick the same best arch?
        nn = len(n_range)
        for n_i, n in enumerate(n_range):
            n_cases += 1
            idx = [a_i * nn + n_i for a_i in range(len(ARCH_NAMES))]
            for metric in METRICS:
                o, e = svals[metric][idx], np.asarray(batch[metric])[idx]
                pick = np.argmax if metric == "throughput_ips" else np.argmin
                if pick(o) == pick(e):
                    best_match[metric] += 1

    rows = []
    summary = {}
    for metric in METRICS:
        a = np.array(acc[metric])
        summary[metric] = dict(mean=float(a.mean()), min=float(a.min()),
                               max=float(a.max()),
                               best_arch_match=f"{best_match[metric]}/{n_cases}")
        rows.append([metric, f"{a.mean():.2f}%", f"{a.min():.2f}%",
                     f"{a.max():.2f}%", summary[metric]["best_arch_match"]])
    checks = {f"{m}_mean_above_90": summary[m]["mean"] > 90.0
              for m in METRICS}
    if verbose:
        print(fmt_table(rows, ["metric", "mean acc", "min", "max",
                               "best-arch match"]))
        print("checks:", checks)
    out = {"summary": summary, "checks": checks,
           "n_experiments": len(acc["latency_s"])}

    if schedule:
        out["schedule"] = _schedule_crossval(ses, cnns, n_range, verbose)
        checks.update(out["schedule"]["checks"])
    save("tab4_accuracy", out)
    return out


def _schedule_crossval(ses, cnns, n_range, verbose: bool) -> dict:
    """Coarse-vs-schedule-refined cross-validation over the same grid:
    Eq. 10 accuracy of the coarse latency against the refined one, per
    board, plus the never-worse invariant as a hard check."""
    boards = {}
    any_worse = 0
    rows = []
    for bname in SCHEDULE_BOARDS:
        bdev = get_board(bname)
        accs, wins, savings = [], 0, []
        n_designs = 0
        for cnn in cnns:
            net = get_cnn(cnn)
            specs = [make_arch(a, net, n)
                     for a in ARCH_NAMES for n in n_range]
            r = schedule_specs(specs, net, ses.device_tables(bdev),
                               tables=ses.tables(net))
            coarse = np.asarray(r["coarse_latency_s"], np.float64)
            refined = np.asarray(r["ref_latency_s"], np.float64)
            n_designs += coarse.size
            any_worse += int((refined > coarse).sum())
            wins += int((refined < coarse).sum())
            accs.extend((100.0 * (1.0 - np.abs(refined - coarse)
                                  / np.maximum(refined, 1e-300))).tolist())
            savings.extend((1.0 - refined
                            / np.maximum(coarse, 1e-300)).tolist())
        a = np.array(accs)
        boards[bname] = {
            "n_designs": n_designs,
            "coarse_vs_refined_acc_mean": float(a.mean()),
            "coarse_vs_refined_acc_min": float(a.min()),
            "strict_refinements": wins,
            "max_saving_frac": float(np.max(savings)),
        }
        rows.append([bname, f"{a.mean():.2f}%", f"{a.min():.2f}%",
                     f"{wins}/{n_designs}",
                     f"{100.0 * float(np.max(savings)):.2f}%"])
    checks = {
        # the structural invariant: the mapping search can never make a
        # design slower than the coarse estimate
        "schedule_refined_leq_coarse": any_worse == 0,
        # the cross-validation verdict: the coarse model stays >90%
        # accurate against its own finer-grained mapping costs — the
        # implied-ideal-mapping assumption is cheap on every board
        "schedule_crossval_mean_above_90": all(
            b["coarse_vs_refined_acc_mean"] > 90.0
            for b in boards.values()),
    }
    if verbose:
        print(fmt_table(rows, ["board", "coarse-vs-refined acc", "min",
                               "refined designs", "max saving"]))
        print("schedule checks:", checks)
    return {"boards": boards, "checks": checks}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--schedule", action="store_true",
                    help="add the coarse-vs-schedule-refined "
                         "cross-validation (docs/schedule.md)")
    ap.add_argument("--quick", action="store_true",
                    help="2 CNNs x 4 CE counts (CI smoke)")
    args = ap.parse_args(argv)
    out = run(schedule=args.schedule, quick=args.quick)
    return 0 if all(out["checks"].values()) else 1


if __name__ == "__main__":
    raise SystemExit(main())
