"""§V-E evaluation speed: scalar vs vectorized MCCM vs the paper's 6.3 ms.

Reports µs/design for (a) the scalar reference evaluator (the paper-style
object walker), (b) the fused/tiled jitted batch evaluator at several
batch sizes up to the DSE generation size (B=4096).  The B>=4096 rows are
the ones ``benchmarks/perf_gate.py`` tracks over time.
"""
from __future__ import annotations

import itertools
import time

import jax
import numpy as np

from repro.cnn.registry import get_cnn
from repro.core.batch_eval import encode_specs, padded_rows
from repro.fpga.archs import make_arch
from repro.fpga.boards import get_board

from .common import fmt_table, get_session, save

PAPER_US = 6300.0
BATCH_SIZES = (30, 240, 1920, 4096)


def run(verbose: bool = True) -> dict:
    net, dev = get_cnn("xception"), get_board("vcu110")
    ses = get_session()
    specs = [make_arch(a, net, n)
             for a in ("segmented", "segmented_rr", "hybrid")
             for n in range(2, 12)]

    t0 = time.time()
    for s in specs:
        ses.evaluate(s, net, dev)
    scalar_us = (time.time() - t0) / len(specs) * 1e6

    rows = [["scalar (reference)", f"{scalar_us:.0f}", "-",
             f"{PAPER_US/scalar_us:.1f}x"]]
    out = {"scalar_us": scalar_us, "paper_us": PAPER_US}
    for B in BATCH_SIZES:
        cyc = itertools.islice(itertools.cycle(specs), B)
        batch = encode_specs(list(cyc), len(net))
        r = ses.evaluate(batch, net, dev)
        jax.block_until_ready(r["latency_s"])
        t0 = time.time()
        reps = 3
        for _ in range(reps):
            r = ses.evaluate(batch, net, dev)
            jax.block_until_ready(r["latency_s"])
        # small batches pad to a tile multiple — report the executed rows
        # next to the user-facing per-design cost so neither misleads
        n_rows = padded_rows(B)
        us = (time.time() - t0) / reps / B * 1e6
        out[f"batch{B}_us"] = us
        out[f"batch{B}_rows"] = n_rows
        rows.append([f"batched jit (B={B})", f"{us:.1f}", str(n_rows),
                     f"{PAPER_US/us:.0f}x"])
    if verbose:
        print(fmt_table(rows, ["evaluator", "us/design", "rows",
                               "vs paper 6300us"]))
    save("eval_speed", out)
    return out


if __name__ == "__main__":
    run()
