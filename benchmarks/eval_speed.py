"""§V-E evaluation speed: scalar vs vectorized MCCM vs the paper's 6.3 ms.

Reports µs/design for (a) the scalar reference evaluator (the paper-style
object walker), (b) the jitted batch evaluator at several batch sizes.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.cnn.registry import get_cnn
from repro.core.batch_eval import encode_specs, evaluate_batch, make_tables
from repro.core.evaluator import evaluate_design
from repro.fpga.archs import make_arch
from repro.fpga.boards import get_board

from .common import fmt_table, save

PAPER_US = 6300.0


def run(verbose: bool = True) -> dict:
    net, dev = get_cnn("xception"), get_board("vcu110")
    specs = [make_arch(a, net, n)
             for a in ("segmented", "segmented_rr", "hybrid")
             for n in range(2, 12)]

    t0 = time.time()
    for s in specs:
        evaluate_design(s, net, dev)
    scalar_us = (time.time() - t0) / len(specs) * 1e6

    tables = make_tables(net)
    rows = [["scalar (reference)", f"{scalar_us:.0f}",
             f"{PAPER_US/scalar_us:.1f}x"]]
    out = {"scalar_us": scalar_us, "paper_us": PAPER_US}
    for mult in (1, 8, 64):
        batch = encode_specs(specs * mult, len(net))
        r = evaluate_batch(batch, tables, dev)
        jax.block_until_ready(r["latency_s"])
        t0 = time.time()
        reps = 3
        for _ in range(reps):
            r = evaluate_batch(batch, tables, dev)
            jax.block_until_ready(r["latency_s"])
        us = (time.time() - t0) / reps / (len(specs) * mult) * 1e6
        out[f"batch{len(specs)*mult}_us"] = us
        rows.append([f"batched jit (B={len(specs)*mult})", f"{us:.1f}",
                     f"{PAPER_US/us:.0f}x"])
    if verbose:
        print(fmt_table(rows, ["evaluator", "us/design", "vs paper 6300us"]))
    save("eval_speed", out)
    return out


if __name__ == "__main__":
    run()
