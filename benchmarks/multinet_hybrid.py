"""SLO-driven hybrid spatial/temporal deployments vs the pure modes.

A 3-model mix (resnet50 + mobilenetv2 + densenet121) on the
resource-starved zc706 under tight per-model SLOs and a 1:2:1 request
mix.  Three guided arms run with identical budget, operators, seed and
``objective="slo"`` (graded deadline attainment under the per-model
deadline-scale grid); only the deployment space differs:

* ``search``   — pure spatial: every model owns a dedicated slice;
* ``temporal`` — pure time-multiplexing: full board, weighted RR;
* ``hybrid``   — the general space: per-model spatial/shared assignment,
  splits and time shares all evolve (anchored with both pure modes, so
  the hybrid front can only extend them).

The committed artifact records each arm's front over
(slo_attainment_dist, agg_throughput_ips) and checks that the hybrid
front attains at least the best SLO attainment of BOTH pure modes at
equal budget — the deployment-space inclusion made measurable.

    python -m benchmarks.multinet_hybrid            # full budget
    python -m benchmarks.multinet_hybrid --quick    # CI smoke
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.cnn.registry import get_cnn
from repro.core.dse.pareto import hypervolume_2d
from repro.core.multinet import MultinetSearchConfig
from repro.fpga.boards import get_board

from .common import fmt_table, get_session, save

MODELS = ("resnet50", "mobilenetv2", "densenet121")
BOARD = "zc706"
#: tight per-model latency SLOs (s): the 3-way spatial split of zc706's
#: 900 DSPs cannot serve all three, and the RR round wait breaks the pure
#: temporal mode — the regime where mixing the modes pays.
SLO_S = (0.120, 0.030, 0.130)
WEIGHTS = (1.0, 2.0, 1.0)           # mobilenetv2 carries 2x the traffic
ARMS = ("search", "temporal", "hybrid")
FULL_BUDGET, FULL_POP = 6144, 512
QUICK_BUDGET, QUICK_POP = 1536, 256


def run(verbose: bool = True, quick: bool = False) -> dict:
    budget = QUICK_BUDGET if quick else FULL_BUDGET
    pop = QUICK_POP if quick else FULL_POP
    nets = [get_cnn(n) for n in MODELS]
    dev = get_board(BOARD)
    ses = get_session()
    mt = ses.multi_tables(nets, weights=WEIGHTS, slo_s=SLO_S)

    arms = {}
    for arm in ARMS:
        cfg = MultinetSearchConfig(pop_size=pop, seed=3, objective="slo",
                                   slo_s=SLO_S, weights=WEIGHTS)
        arms[arm] = ses.deploy(nets, budget, dev, strategy=arm,
                               config=cfg)
    fronts = {a: r.front_points() for a, r in arms.items()}
    # oriented col 0 is -slo_attainment_dist: front-best attainment
    best_slo = {a: float(-fronts[a][:, 0].min()) for a in ARMS}
    allp = np.concatenate(list(fronts.values()))
    ref = allp.max(0) + 0.05 * np.maximum(np.ptp(allp, 0), 1e-9)
    hv = {a: hypervolume_2d(f, ref) for a, f in fronts.items()}

    hyb = arms["hybrid"]
    i = int(np.argmax(hyb.metrics["slo_attainment_dist"]))
    best_deploy = {
        "slo_attainment_dist": float(
            hyb.metrics["slo_attainment_dist"][i]),
        "assign": hyb.metrics["assign"][i][:len(MODELS)].tolist(),
        "pes_split": hyb.metrics["pes_split"][i][:len(MODELS)].tolist(),
        "time_share": hyb.metrics["time_share"][i][:len(MODELS)].tolist(),
        "per_model_latency_ms": (
            hyb.metrics["per_model_latency_s"][i][:len(MODELS)]
            * 1e3).tolist(),
    }
    front_assign = hyb.metrics["assign"][hyb.front][:, :len(MODELS)]
    n_shared = front_assign.sum(1)
    checks = {
        "hybrid_best_slo_ge_spatial":
            best_slo["hybrid"] >= best_slo["search"] - 1e-9,
        "hybrid_best_slo_ge_temporal":
            best_slo["hybrid"] >= best_slo["temporal"] - 1e-9,
    }
    out = {
        "benchmark": "SLO-driven hybrid deployments "
                     f"({'+'.join(MODELS)} on {BOARD})",
        "budget": budget, "pop_size": pop, "quick": bool(quick),
        "models": list(MODELS), "board": BOARD,
        "slo_s": list(SLO_S),
        "normalized_weights": mt.normalized_weights.tolist(),
        "objectives": list(arms["hybrid"].objectives),
        "best_slo_attainment": best_slo,
        "hypervolume": hv,
        "seconds": {a: arms[a].seconds for a in ARMS},
        "per_eval_us": {a: arms[a].per_eval_us for a in ARMS},
        "fronts": {a: fronts[a].tolist() for a in ARMS},
        "hybrid_front_shared_counts": n_shared.tolist(),
        "hybrid_best_deployment": best_deploy,
        "checks": checks,
    }
    if verbose:
        rows = [[a, f"{best_slo[a]:.3f}", f"{hv[a]:.3f}",
                 str(len(fronts[a])), f"{arms[a].seconds:.1f}s"]
                for a in ARMS]
        print(fmt_table(rows, ["arm", "best slo-att", "hv", "front",
                               "time"]))
        print("hybrid best deployment:", best_deploy)
        print("checks:", checks)
    save("multinet_hybrid", out)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small budget (CI smoke)")
    args = ap.parse_args(argv)
    payload = run(quick=args.quick)
    return 0 if all(payload["checks"].values()) else 1


if __name__ == "__main__":
    raise SystemExit(main())
