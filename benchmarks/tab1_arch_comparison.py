"""Paper Table I: the three architectures on ResNet50 / ZCU102, each metric
normalized to the best architecture for that metric.

Paper values (normalized): SegmentedRR latency 1.0 / buffers 2.64 / accesses
1.79; Segmented 4.7 / 1.0 / 1.99; Hybrid 1.11 / 1.74 / 1.0.  Table I does
not state the instances' CE counts; at ~10 CEs our model reproduces the
paper's structure (Segmented latency 4.4x vs paper 4.7x, Hybrid 1.0-1.15 vs
1.11, SegmentedRR worst buffers AND worst accesses, Hybrid best accesses).
We validate that *directional* structure; exact ratios differ because the
Builder heuristics are re-implemented from the paper's prose (DESIGN.md §7).
"""
from __future__ import annotations

from repro.cnn.registry import get_cnn
from repro.fpga.archs import make_arch
from repro.fpga.boards import get_board

from .common import fmt_table, get_session, save

N_CES = 10  # representative instance (see module docstring)
ARCHS = ("segmented_rr", "segmented", "hybrid")


def run(verbose: bool = True) -> dict:
    net = get_cnn("resnet50")
    dev = get_board("zcu102")
    # one batched session call over the three architectures (shares the
    # zoo-wide tables and compile with every other benchmark)
    out = get_session().evaluate([make_arch(a, net, N_CES) for a in ARCHS],
                                 net, dev)
    res = {arch: dict(latency=float(out["latency_s"][i]),
                      buffers=float(out["buffer_bytes"][i]),
                      accesses=float(out["access_bytes"][i]))
           for i, arch in enumerate(ARCHS)}

    lat0 = min(v["latency"] for v in res.values())
    buf0 = min(v["buffers"] for v in res.values())
    acc0 = min(v["accesses"] for v in res.values())
    rows, norm = [], {}
    paper = {"segmented_rr": (1.0, 2.64, 1.79),
             "segmented": (4.7, 1.0, 1.99),
             "hybrid": (1.11, 1.74, 1.0)}
    for arch, v in res.items():
        norm[arch] = dict(latency=v["latency"] / lat0,
                          buffers=v["buffers"] / buf0,
                          accesses=v["accesses"] / acc0)
        p = paper[arch]
        rows.append([arch, f"{norm[arch]['latency']:.2f}", f"{p[0]}",
                     f"{norm[arch]['buffers']:.2f}", f"{p[1]}",
                     f"{norm[arch]['accesses']:.2f}", f"{p[2]}"])
    checks = {
        "segmented_rr_best_latency":
            norm["segmented_rr"]["latency"]
            <= min(norm["segmented"]["latency"],
                   norm["hybrid"]["latency"]) + 0.2,
        "segmented_worst_latency":
            norm["segmented"]["latency"]
            >= max(norm["segmented_rr"]["latency"],
                   norm["hybrid"]["latency"]),
        "hybrid_best_accesses": norm["hybrid"]["accesses"] <= 1.0 + 1e-9,
        "segmented_rr_worst_buffers":
            norm["segmented_rr"]["buffers"]
            >= max(norm["segmented"]["buffers"], norm["hybrid"]["buffers"]),
    }
    if verbose:
        print(fmt_table(rows, ["arch", "lat", "(paper)", "buf", "(paper)",
                               "acc", "(paper)"]))
        print("directional checks vs paper Table I:", checks)
    out = {"normalized": norm, "paper": paper, "checks": checks,
           "n_ces": N_CES}
    save("tab1_arch_comparison", out)
    return out


if __name__ == "__main__":
    run()
