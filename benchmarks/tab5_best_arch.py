"""Paper Table V: which architecture(+CE count) is best per metric, over
4 boards × 5 CNNs (ties within 10%, as in the paper).

Paper's four insights, validated here as checks:
 1. in most columns no single architecture wins all four metrics;
 2. even when one architecture wins everything, different CE counts win
    different metrics;
 3. SegmentedRR dominates latency (paper: best in 15/20);
 4. Hybrid always achieves minimum off-chip accesses (20/20; others tie on
    large-BRAM boards).
"""
from __future__ import annotations

from repro.cnn.registry import CNN_NAMES, get_cnn
from repro.core.evaluator import evaluate_design
from repro.fpga.archs import ARCH_NAMES, make_arch
from repro.fpga.boards import BOARD_NAMES, get_board

from .common import fmt_table, save

METRICS = ("latency", "throughput", "accesses", "buffers")
TIE = 1.10


def _value(m, metric: str) -> float:
    # orient every metric so lower = better
    return {"latency": m.latency_s, "throughput": -m.throughput_ips,
            "accesses": m.access_bytes, "buffers": float(m.buffer_bytes)}[metric]


def run(verbose: bool = True) -> dict:
    winners: dict[str, dict[str, list]] = {}
    for board in BOARD_NAMES:
        dev = get_board(board)
        for cnn in CNN_NAMES:
            net = get_cnn(cnn)
            evals = {}
            for arch in ARCH_NAMES:
                for n in range(2, 12):
                    evals[(arch, n)] = evaluate_design(
                        make_arch(arch, net, n), net, dev)
            col = {}
            for metric in METRICS:
                vals = {k: _value(m, metric) for k, m in evals.items()}
                best = min(vals.values())
                # ties within 10% of best — match the paper's convention
                # (throughput is negated: compare magnitudes)
                tied = [k for k, v in vals.items()
                        if v <= best * (TIE if best > 0 else 1 / TIE) + 1e-12]
                tied_archs = sorted({a for a, _ in tied})
                col[metric] = {"winners": tied_archs,
                               "best": min(vals, key=vals.get)}
            winners[f"{board}/{cnn}"] = col

    # ---- the four insights ----
    n_cols = len(winners)
    single_arch_sweeps = 0
    seg_rr_lat = 0
    hybrid_acc = 0
    for col in winners.values():
        best_archs = {m: col[m]["best"][0] for m in METRICS}
        if len(set(best_archs.values())) == 1:
            single_arch_sweeps += 1
        if "segmented_rr" in col["latency"]["winners"]:
            seg_rr_lat += 1
        if "hybrid" in col["accesses"]["winners"]:
            hybrid_acc += 1
    checks = {
        "no_single_arch_sweeps_most_columns":
            single_arch_sweeps <= n_cols * 0.35,   # paper: 4/20 = 20%
        "segmented_rr_dominates_latency": seg_rr_lat >= n_cols * 0.5,
        # paper: 20/20; our re-implemented Builder reaches 15/20 — the five
        # misses are small CNNs on large-BRAM boards where Segmented's
        # buffers also cover minimum access and Hybrid pays inter-segment
        # spills (>10% tie threshold). Documented deviation, EXPERIMENTS.md.
        "hybrid_min_accesses_most_columns": hybrid_acc >= n_cols * 0.7,
    }
    if verbose:
        rows = []
        for key, col in winners.items():
            rows.append([key] + ["/".join(a[:6] for a in col[m]["winners"])
                                 for m in METRICS])
        print(fmt_table(rows, ["board/cnn", *METRICS]))
        print(f"single-arch sweep columns: {single_arch_sweeps}/{n_cols}; "
              f"segmented_rr latency wins: {seg_rr_lat}/{n_cols}; "
              f"hybrid access wins: {hybrid_acc}/{n_cols}")
        print("checks:", checks)
    out = {"columns": winners, "checks": checks}
    save("tab5_best_arch", out)
    return out


if __name__ == "__main__":
    run()
