"""Paper Table V: which architecture(+CE count) is best per metric, over
4 boards × 5 CNNs (ties within 10%, as in the paper).

Paper's four insights, validated here as checks:
 1. in most columns no single architecture wins all four metrics;
 2. even when one architecture wins everything, different CE counts win
    different metrics;
 3. SegmentedRR dominates latency (paper: best in 15/20);
 4. Hybrid always achieves minimum off-chip accesses (20/20; others tie on
    large-BRAM boards).

Extended with the guided-search column: for every CNN on the default
board, an equal-budget guided search (``explore(strategy="search")``) is
compared against the 30 template instances on (latency, buffers) —
showing the paper's "no template wins everywhere" insight carries a
constructive answer: searched custom designs dominate the templates.
"""
from __future__ import annotations

import numpy as np

from repro.cnn.registry import CNN_NAMES, get_cnn
from repro.core.dse import dominating_indices, orient
from repro.fpga.archs import ARCH_NAMES, make_arch
from repro.fpga.boards import BOARD_NAMES, DEFAULT_BOARD, get_board

from .common import fmt_table, get_session, save

METRICS = ("latency", "throughput", "accesses", "buffers")
TIE = 1.10
DSE_BUDGET = 16_384          # evaluations per CNN for the search column


def _value(m, metric: str) -> float:
    # orient every metric so lower = better
    return {"latency": m.latency_s, "throughput": -m.throughput_ips,
            "accesses": m.access_bytes, "buffers": float(m.buffer_bytes)}[metric]


def _search_vs_templates(dse_budget: int,
                         template_evals: dict[str, list]) -> dict:
    """Guided search vs every template instance on (latency, buffers),
    per CNN on the default board, at an equal per-CNN budget split between
    random sampling and guided search.  ``template_evals`` carries the
    default-board metrics run() already computed (no re-evaluation)."""
    dev = get_board()
    ses = get_session()
    out: dict[str, dict] = {}
    for cnn in CNN_NAMES:
        net = get_cnn(cnn)
        temps = template_evals[cnn]
        tpts = np.array([[m.latency_s, float(m.buffer_bytes)]
                         for m in temps])
        rnd = ses.explore(net, dse_budget // 2, dev, family="custom",
                          seed=7)
        srch = ses.explore(net, dse_budget // 2, dev, strategy="search",
                           seed=3)
        sp = orient(srch.metrics, ("latency_s", "buffer_bytes"))
        rp = orient(rnd.metrics, ("latency_s", "buffer_bytes"))
        dom_search = sum(bool(len(dominating_indices(sp, t)))
                         for t in tpts)
        dom_rand = sum(bool(len(dominating_indices(rp, t))) for t in tpts)
        out[cnn] = dict(
            templates=len(temps),
            dominated_by_search=dom_search,
            dominated_by_random=dom_rand,
            search_front_size=int(len(srch.front)),
            budget=srch.n_evals + rnd.n_evals,
        )
    return out


def run(verbose: bool = True, dse_budget: int = DSE_BUDGET) -> dict:
    ses = get_session()
    winners: dict[str, dict[str, list]] = {}
    default_board_evals: dict[str, list] = {}
    for board in BOARD_NAMES:
        dev = get_board(board)
        for cnn in CNN_NAMES:
            net = get_cnn(cnn)
            evals = {}
            for arch in ARCH_NAMES:
                for n in range(2, 12):
                    evals[(arch, n)] = ses.evaluate(
                        make_arch(arch, net, n), net, dev)
            if board == DEFAULT_BOARD:  # reused by _search_vs_templates
                default_board_evals[cnn] = list(evals.values())
            col = {}
            for metric in METRICS:
                vals = {k: _value(m, metric) for k, m in evals.items()}
                best = min(vals.values())
                # ties within 10% of best — match the paper's convention
                # (throughput is negated: compare magnitudes)
                tied = [k for k, v in vals.items()
                        if v <= best * (TIE if best > 0 else 1 / TIE) + 1e-12]
                tied_archs = sorted({a for a, _ in tied})
                col[metric] = {"winners": tied_archs,
                               "best": min(vals, key=vals.get)}
            winners[f"{board}/{cnn}"] = col

    # ---- the four insights ----
    n_cols = len(winners)
    single_arch_sweeps = 0
    seg_rr_lat = 0
    hybrid_acc = 0
    for col in winners.values():
        best_archs = {m: col[m]["best"][0] for m in METRICS}
        if len(set(best_archs.values())) == 1:
            single_arch_sweeps += 1
        if "segmented_rr" in col["latency"]["winners"]:
            seg_rr_lat += 1
        if "hybrid" in col["accesses"]["winners"]:
            hybrid_acc += 1
    dse = _search_vs_templates(dse_budget, default_board_evals)
    total_t = sum(c["templates"] for c in dse.values())
    dom_s = sum(c["dominated_by_search"] for c in dse.values())
    dom_r = sum(c["dominated_by_random"] for c in dse.values())

    checks = {
        "no_single_arch_sweeps_most_columns":
            single_arch_sweeps <= n_cols * 0.35,   # paper: 4/20 = 20%
        "segmented_rr_dominates_latency": seg_rr_lat >= n_cols * 0.5,
        # paper: 20/20; our re-implemented Builder reaches 15/20 — the five
        # misses are small CNNs on large-BRAM boards where Segmented's
        # buffers also cover minimum access and Hybrid pays inter-segment
        # spills (>10% tie threshold). Documented deviation, EXPERIMENTS.md.
        "hybrid_min_accesses_most_columns": hybrid_acc >= n_cols * 0.7,
        "search_dominates_most_templates": dom_s >= total_t * 0.8,
        "search_no_worse_than_random": dom_s >= dom_r,
    }
    if verbose:
        rows = []
        for key, col in winners.items():
            rows.append([key] + ["/".join(a[:6] for a in col[m]["winners"])
                                 for m in METRICS])
        print(fmt_table(rows, ["board/cnn", *METRICS]))
        print(f"single-arch sweep columns: {single_arch_sweeps}/{n_cols}; "
              f"segmented_rr latency wins: {seg_rr_lat}/{n_cols}; "
              f"hybrid access wins: {hybrid_acc}/{n_cols}")
        drows = [[cnn, c["templates"], c["dominated_by_search"],
                  c["dominated_by_random"], c["search_front_size"]]
                 for cnn, c in dse.items()]
        print("\nguided search vs templates (default board, "
              f"{dse_budget} evals/CNN):")
        print(fmt_table(drows, ["cnn", "templates", "dom. by search",
                                "dom. by random", "front size"]))
        print(f"templates dominated: search {dom_s}/{total_t}, "
              f"random {dom_r}/{total_t}")
        print("checks:", checks)
    out = {"columns": winners, "search_vs_templates": dse, "checks": checks}
    save("tab5_best_arch", out)
    return out


if __name__ == "__main__":
    run()
