"""§Roofline report: three terms per (arch × shape) on the single-pod mesh
(the assignment's baseline table), from the dry-run artifacts.

Run ``python -m repro.launch.dryrun`` first (or let run.py use whatever
artifacts exist).
"""
from __future__ import annotations

from repro.roofline.analysis import analyze_cell, load_artifacts

from .common import fmt_table, save


def run(verbose: bool = True) -> dict:
    recs = load_artifacts(mesh="single")
    if not recs:
        print("no dry-run artifacts found — run "
              "`PYTHONPATH=src python -m repro.launch.dryrun` first")
        return {"checks": {"artifacts_present": False}}
    rows, cells = [], {}
    for rec in recs:
        c = analyze_cell(rec)
        cells[c.cell] = c.__dict__
        rows.append(c.as_row())
    rows.sort(key=lambda r: (r[0], r[1]))
    dominant_counts: dict[str, int] = {}
    for c in cells.values():
        dominant_counts[c["dominant"]] = \
            dominant_counts.get(c["dominant"], 0) + 1
    if verbose:
        print(fmt_table(rows, ["arch", "shape", "mesh", "comp ms", "mem ms",
                               "coll ms", "dominant", "useful", "roofline",
                               "HBM GiB"]))
        print("dominant-term census:", dominant_counts)
    out = {"cells": cells, "dominant_counts": dominant_counts,
           "checks": {"artifacts_present": True,
                      "all_cells_analyzed": len(recs) == len(cells)}}
    save("roofline_report", out)
    return out


if __name__ == "__main__":
    run()
