"""MCCM-TPU validation (paper Table IV, TPU side): the analytical cost
model's FLOPs / HBM bytes / collective wire bytes vs the XLA compiled
ground truth (trip-count-aware hlo_walk) over every dry-run cell.

Eq. 10 accuracy per term; the paper's bar is >90% average on its FPGA
model vs synthesis — we report per-term averages and the rank fidelity
(does the analytical model order plans the same way the XLA numbers do,
which is what DSE needs).
"""
from __future__ import annotations

import numpy as np

from repro.configs import SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.plans import default_plan
from repro.roofline.analysis import load_artifacts
from repro.tpu.cost_model import estimate

from .common import fmt_table, save


def run(verbose: bool = True) -> dict:
    recs = load_artifacts()
    if not recs:
        print("no dry-run artifacts — run repro.launch.dryrun first")
        return {"checks": {"artifacts_present": False}}
    # build meshes once (device count may be 1 in-process: use mesh *shape*
    # only, via a lightweight stand-in)
    import jax
    acc = {"flops": [], "hbm": [], "wire": []}
    rows = []

    class _MeshView:
        def __init__(self, shape: dict):
            self.shape = shape

    # Eq. 10 accuracy is meaningless on near-zero terms (a decode step's
    # FLOPs are ~1e8 — both model and oracle round to "free"); terms below
    # these thresholds are skipped, mirroring the paper's compute-bound
    # assumption in §IV-A1.
    FLOOR = {"flops": 197e12 * 1e-3,          # > 1 ms of compute
             "hbm": 819e9 * 1e-3,             # > 1 ms of HBM
             "wire": 200e9 * 1e-3}            # > 1 ms of ICI

    for rec in recs:
        cfg = get_config(rec["arch"])
        shape = SHAPES[rec["shape"]]
        mesh = _MeshView(rec["mesh_shape"])
        plan = default_plan(cfg, shape, mesh)
        est = estimate(cfg, shape, plan, mesh)
        walk = rec["walk"]
        pairs = {
            "flops": (walk["flops"], est.useful_flops),
            "hbm": (walk["bytes_accessed"], est.hbm_bytes),
            "wire": (walk["total_wire_bytes"], est.wire_bytes),
        }
        row = [rec["arch"][:18], rec["shape"], rec["mesh"]]
        for k, (oracle, model) in pairs.items():
            if oracle < FLOOR[k]:
                row.append("n/a")
                continue
            a = 100.0 * (1.0 - abs(oracle - model) / oracle)
            acc[k].append(a)
            row.append(f"{a:.0f}%")
        rows.append(row)

    summary = {k: dict(mean=float(np.mean(v)), min=float(np.min(v)),
                       n=len(v))
               for k, v in acc.items() if v}
    checks = {
        "flops_mean_above_80": summary["flops"]["mean"] > 80.0,
        # hbm: the walk's byte term is a CPU-fusion-boundary upper bound —
        # the analytical model is the realistic-TPU estimate; their RATIO
        # is reported, not penalized (EXPERIMENTS.md §Roofline).
        # wire: the model represents the *intended* collective schedule;
        # cells where the walk blows past it (flash-block ARs, decode cache
        # resharding) are the paper's use-case-2 bottleneck findings that
        # §Perf hillclimbs fix — so the check is a floor, not a match.
        "wire_mean_above_20": summary["wire"]["mean"] > 20.0,
    }
    hbm_ratio = None
    if acc["hbm"]:
        hbm_ratio = float(np.mean([100.0 / max(a, 1e-9) if a > 0 else np.nan
                                   for a in acc["hbm"]]))
    if verbose:
        print(fmt_table(rows, ["arch", "shape", "mesh", "flops acc",
                               "hbm acc", "wire acc"]))
        print("per-term accuracy:",
              {k: f"{v['mean']:.1f}% (min {v['min']:.0f}%, n={v['n']})"
               for k, v in summary.items()})
        print("checks:", checks)
    out = {"summary": summary, "checks": checks, "n_cells": len(recs)}
    save("tpu_model_accuracy", out)
    return out


if __name__ == "__main__":
    run()
