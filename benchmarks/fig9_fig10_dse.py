"""Paper Fig. 9 + Fig. 10 + §V-E: bottleneck-guided DSE on XCp / VCU110.

Fig. 9 — per-segment buffer share and PE underutilization of the
best-throughput Segmented and the min-buffer Hybrid (the bottleneck hints
that motivate the custom family).

Fig. 10 — evaluate a 100k-design random sample of the custom family and
report eval speed plus the designs that dominate the fixed templates
(paper: custom designs match Segmented-best throughput with up to 48%
less buffer, or beat it by up to 17% with up to 39% less buffer).

Beyond the paper, this now also measures what the speed *buys*:

* vectorized-sampler throughput vs the per-design reference loop
  (must be >= 10x at the 100k scale);
* random sampling vs guided multi-objective search at the same
  evaluation budget, on Xception (side-by-side fronts) and on
  MobileNetV2 + the default board, where the search must strictly
  dominate the best design the random sweep finds.
"""
from __future__ import annotations

import time

import numpy as np

from repro.cnn.registry import get_cnn
from repro.core.dse import (
    best_scalar_index,
    decode_design,
    dominating_indices,
    orient,
    pareto,
    sample_custom,
    sample_custom_loop,
    sample_mixed,
    sample_mixed_loop,
)
from repro.core.notation import format_spec
from repro.fpga.archs import make_arch
from repro.fpga.boards import get_board

from .common import get_session, save

N_SAMPLE = 100_000
OBJ = ("latency_s", "buffer_bytes")


def _time_samplers(n_layers: int, n: int) -> dict:
    """Vectorized vs per-design-loop sampling of a full DesignBatch.
    Both paths get a small warmup call (allocator/jax init), then the
    vectorized path is best-of-2 and the loop is measured at 20k and
    scaled — it is O(n) in Python-loop iterations."""
    rng = np.random.default_rng(0)
    for f in (sample_custom, sample_mixed, sample_custom_loop,
              sample_mixed_loop):
        f(rng, n_layers, 256)
    vec_s = np.inf
    for _ in range(2):
        t0 = time.time()
        sample_custom(rng, n_layers, n // 2)
        sample_mixed(rng, n_layers, n - n // 2)
        vec_s = min(vec_s, time.time() - t0)
    n_loop = min(n, 20_000)             # the loop at full n takes many sec
    t0 = time.time()
    sample_custom_loop(rng, n_layers, n_loop // 2)
    sample_mixed_loop(rng, n_layers, n_loop - n_loop // 2)
    loop_s = (time.time() - t0) * (n / n_loop)
    return dict(n=n, vectorized_s=vec_s, loop_s_scaled=loop_s,
                loop_n_measured=n_loop, speedup=loop_s / max(vec_s, 1e-9))


def _front_list(points: np.ndarray, front: np.ndarray) -> list[dict]:
    fp = points[front]
    order = np.argsort(fp[:, 0])
    return [dict(latency_ms=float(fp[i, 0] * 1e3),
                 buffer_mib=float(fp[i, 1] / 2**20)) for i in order]


def _search_vs_random(net, dev, n: int, *, family: str,
                      seed_rnd: int = 7, seed_srch: int = 3,
                      rnd=None) -> dict:
    """Equal-budget comparison; reference picks come from the random run
    (pass ``rnd`` to reuse an already-computed random sweep)."""
    ses = get_session()
    if rnd is None:
        rnd = ses.explore(net, n, dev, family=family, seed=seed_rnd)
    srch = ses.explore(net, n, dev, family=family, strategy="search",
                       seed=seed_srch)
    rp = orient(rnd.metrics, OBJ)
    sp = orient(srch.metrics, OBJ)
    refs = {
        "best_latency": rp[int(np.argmin(rp[:, 0]))],
        "best_buffer": rp[int(np.argmin(rp[:, 1]))],
        "scalar_knee": rp[best_scalar_index(rnd.metrics)],
    }
    dom = {k: int(len(dominating_indices(sp, ref)))
           for k, ref in refs.items()}
    rf = rp[rnd.front]
    sf = sp[srch.front]
    covered = sum(bool(len(dominating_indices(sf, p))) for p in rf)
    return dict(
        n_evals_random=rnd.n_evals, n_evals_search=srch.n_evals,
        seconds_random=rnd.seconds, seconds_search=srch.seconds,
        random_best=({k: dict(latency_ms=float(v[0] * 1e3),
                              buffer_mib=float(v[1] / 2**20))
                      for k, v in refs.items()}),
        search_designs_dominating=dom,
        random_front_points_strictly_dominated=f"{covered}/{len(rf)}",
        random_front=_front_list(rp, rnd.front),
        search_front=_front_list(sp, srch.front),
    )


def run(verbose: bool = True, n_sample: int = N_SAMPLE) -> dict:
    net, dev = get_cnn("xception"), get_board("vcu110")
    ses = get_session()

    # ---- Fig 9: bottlenecks of the two promising template instances ----
    seg_cands = [(ses.evaluate(make_arch("segmented", net, n), net, dev), n)
                 for n in range(2, 12)]
    m_seg, n_seg = max(seg_cands, key=lambda t: t[0].throughput_ips)
    hyb_cands = [(ses.evaluate(make_arch("hybrid", net, n), net, dev), n)
                 for n in range(2, 12)]
    m_hyb, n_hyb = min(hyb_cands, key=lambda t: t[0].buffer_bytes)

    def seg_profile(m):
        tot_buf = sum(s.buffer_bytes for s in m.per_segment) or 1
        return [dict(idx=s.index, buf_share=s.buffer_bytes / tot_buf,
                     underutil=1.0 - s.utilization, busy_s=s.busy_s)
                for s in m.per_segment]

    fig9 = {"segmented": {"n": n_seg, "segments": seg_profile(m_seg)},
            "hybrid": {"n": n_hyb, "segments": seg_profile(m_hyb)}}

    # ---- sampler speed: vectorized vs the seed's per-design loop ----
    sampler_speed = _time_samplers(len(net), n_sample)

    # ---- Fig 10: 100k-design DSE (half custom family, half the mixed
    # superset — mirrors "explore architectures that mitigate these
    # bottlenecks") ----
    res = ses.explore(net, n_sample, dev, family="both", seed=0)
    tp = res.metrics["throughput_ips"]
    buf = res.metrics["buffer_bytes"]

    ref_tp, ref_buf = m_seg.throughput_ips, float(m_seg.buffer_bytes)
    # custom designs matching the template's throughput with less buffer
    match = (tp >= ref_tp * 0.995)
    buf_saving_at_tp = 1.0 - (buf[match].min() / ref_buf) if match.any() else 0.0
    beat = tp > ref_tp
    tp_gain = (tp[beat].max() / ref_tp - 1.0) if beat.any() else 0.0
    if beat.any():
        best_beat = np.argmax(tp)
        buf_saving_at_best = 1.0 - buf[best_beat] / ref_buf
    else:
        buf_saving_at_best = 0.0

    # do custom designs Pareto-dominate every template instance?
    temps = [(f"{a}[{n}]",
              ses.evaluate(make_arch(a, net, n), net, dev))
             for a in ("segmented", "segmented_rr", "hybrid")
             for n in range(2, 12)]
    dominated = sum(
        bool(((tp >= m.throughput_ips) & (buf <= m.buffer_bytes)
              & ((tp > m.throughput_ips * 1.001)
                 | (buf < m.buffer_bytes * 0.999))).any())
        for _, m in temps)

    front = pareto(np.stack([-tp, buf], 1))

    # ---- guided search vs random at the same budget (the Fig. 10 sweep
    # above doubles as the xception random arm — no second 100k sweep) ----
    xcp = _search_vs_random(net, dev, n_sample, family="both", rnd=res)
    mnv2 = _search_vs_random(get_cnn("mobilenetv2"), get_board(),
                             n_sample, family="custom")

    checks = {
        "found_equal_tp_less_buffer": bool(match.any()
                                           and buf_saving_at_tp > 0.10),
        "found_higher_tp_designs": bool(beat.any()),
        "all_templates_dominated": dominated == len(temps),
        "sampler_speedup_ge_10x": sampler_speed["speedup"] >= 10.0,
        # acceptance: guided search strictly dominates the best design an
        # equal-budget random sweep reports (MobileNetV2, default board)
        "search_dominates_random_best_latency":
            mnv2["search_designs_dominating"]["best_latency"] > 0,
        "search_dominates_random_knee":
            mnv2["search_designs_dominating"]["scalar_knee"] > 0,
    }
    seconds = res.seconds
    us = seconds / n_sample * 1e6
    summary = dict(
        n_designs=n_sample,
        seconds=seconds,
        us_per_design=us,
        paper_us_per_design=6300.0,
        speedup_vs_paper=6300.0 / us,
        template_tp=ref_tp, template_buf_mib=ref_buf / 2**20,
        buf_saving_at_equal_tp=buf_saving_at_tp,
        tp_gain_best=tp_gain,
        buf_saving_at_best_tp=buf_saving_at_best,
        templates_dominated=f"{dominated}/{len(temps)}",
        pareto_size=int(len(front)),
    )
    if verbose:
        print(f"DSE: {n_sample} designs in {seconds:.1f}s "
              f"({us:.0f} us/design; paper 6300 us -> "
              f"{summary['speedup_vs_paper']:.0f}x)")
        print(f"samplers: vectorized {sampler_speed['vectorized_s']:.2f}s "
              f"vs loop {sampler_speed['loop_s_scaled']:.1f}s for "
              f"{n_sample} designs -> {sampler_speed['speedup']:.0f}x")
        print(f"templates Pareto-dominated by custom designs: "
              f"{dominated}/{len(temps)}")
        print(f"template segmented[{n_seg}]: tp {ref_tp:.1f} ips, "
              f"buf {ref_buf/2**20:.2f} MiB")
        print(f"equal-throughput buffer saving: {buf_saving_at_tp:.0%} "
              f"(paper: up to 48%)")
        print(f"best custom: +{tp_gain:.0%} throughput with "
              f"{buf_saving_at_best:.0%} buffer saving (paper: +17%, -39%)")
        i = front[np.argmax(tp[front])]
        print("best design:",
              format_spec(decode_design(res.batch, int(i), len(net)),
                          len(net))[:100])
        for name, cmp in (("xception/vcu110", xcp),
                          ("mobilenetv2/default", mnv2)):
            print(f"\nrandom vs guided search ({name}, "
                  f"{cmp['n_evals_search']} evals):")
            print(f"  random best-latency "
                  f"{cmp['random_best']['best_latency']}")
            print(f"  search designs dominating it: "
                  f"{cmp['search_designs_dominating']['best_latency']}; "
                  f"knee: {cmp['search_designs_dominating']['scalar_knee']}")
            print(f"  random front points strictly dominated: "
                  f"{cmp['random_front_points_strictly_dominated']}")
        print("checks:", checks)
    out = {"fig9": fig9, "fig10": summary, "sampler_speed": sampler_speed,
           "search_vs_random": {"xception_vcu110": xcp,
                                "mobilenetv2_default": mnv2},
           "checks": checks}
    save("fig9_fig10_dse", out)
    return out


if __name__ == "__main__":
    run()
