"""Paper Fig. 9 + Fig. 10 + §V-E: bottleneck-guided DSE on XCp / VCU110.

Fig. 9 — per-segment buffer share and PE underutilization of the
best-throughput Segmented and the min-buffer Hybrid (the bottleneck hints
that motivate the custom family).

Fig. 10 — evaluate a 100k-design random sample of the custom family
(Hybrid-like pipelined first block + Segmented-like rest); report eval
speed and the designs that dominate the fixed templates:
paper: custom designs match Segmented-best throughput with up to 48% less
buffer, or beat it by up to 17% with up to 39% less buffer.
"""
from __future__ import annotations

import numpy as np

from repro.cnn.registry import get_cnn
from repro.core.dse import decode_design, explore, pareto
from repro.core.evaluator import evaluate_design
from repro.core.notation import format_spec
from repro.fpga.archs import make_arch
from repro.fpga.boards import get_board

from .common import save

N_SAMPLE = 100_000


def run(verbose: bool = True, n_sample: int = N_SAMPLE) -> dict:
    net, dev = get_cnn("xception"), get_board("vcu110")

    # ---- Fig 9: bottlenecks of the two promising template instances ----
    seg_cands = [(evaluate_design(make_arch("segmented", net, n), net, dev), n)
                 for n in range(2, 12)]
    m_seg, n_seg = max(seg_cands, key=lambda t: t[0].throughput_ips)
    hyb_cands = [(evaluate_design(make_arch("hybrid", net, n), net, dev), n)
                 for n in range(2, 12)]
    m_hyb, n_hyb = min(hyb_cands, key=lambda t: t[0].buffer_bytes)

    def seg_profile(m):
        tot_buf = sum(s.buffer_bytes for s in m.per_segment) or 1
        return [dict(idx=s.index, buf_share=s.buffer_bytes / tot_buf,
                     underutil=1.0 - s.utilization, busy_s=s.busy_s)
                for s in m.per_segment]

    fig9 = {"segmented": {"n": n_seg, "segments": seg_profile(m_seg)},
            "hybrid": {"n": n_hyb, "segments": seg_profile(m_hyb)}}

    # ---- Fig 10: 100k-design DSE (half paper-custom family, half the
    # mixed superset family — mirrors "explore architectures that mitigate
    # these bottlenecks") ----
    res = explore(net, dev, n=n_sample // 2, family="custom", seed=0)
    res2 = explore(net, dev, n=n_sample - n_sample // 2, family="mixed",
                   seed=1)
    tp = np.concatenate([res.metrics["throughput_ips"],
                         res2.metrics["throughput_ips"]])
    buf = np.concatenate([res.metrics["buffer_bytes"],
                          res2.metrics["buffer_bytes"]])

    ref_tp, ref_buf = m_seg.throughput_ips, float(m_seg.buffer_bytes)
    # custom designs matching the template's throughput with less buffer
    match = (tp >= ref_tp * 0.995)
    buf_saving_at_tp = 1.0 - (buf[match].min() / ref_buf) if match.any() else 0.0
    beat = tp > ref_tp
    tp_gain = (tp[beat].max() / ref_tp - 1.0) if beat.any() else 0.0
    if beat.any():
        best_beat = np.argmax(tp)
        buf_saving_at_best = 1.0 - buf[best_beat] / ref_buf
    else:
        buf_saving_at_best = 0.0

    # do custom designs Pareto-dominate every template instance?
    temps = [(f"{a}[{n}]",
              evaluate_design(make_arch(a, net, n), net, dev))
             for a in ("segmented", "segmented_rr", "hybrid")
             for n in range(2, 12)]
    dominated = sum(
        bool(((tp >= m.throughput_ips) & (buf <= m.buffer_bytes)
              & ((tp > m.throughput_ips * 1.001)
                 | (buf < m.buffer_bytes * 0.999))).any())
        for _, m in temps)

    front = pareto(np.stack([-tp, buf], 1))
    checks = {
        "found_equal_tp_less_buffer": bool(match.any()
                                           and buf_saving_at_tp > 0.10),
        "found_higher_tp_designs": bool(beat.any()),
        "all_templates_dominated": dominated == len(temps),
    }
    seconds = res.seconds + res2.seconds
    us = seconds / n_sample * 1e6
    summary = dict(
        n_designs=n_sample,
        seconds=seconds,
        us_per_design=us,
        paper_us_per_design=6300.0,
        speedup_vs_paper=6300.0 / us,
        template_tp=ref_tp, template_buf_mib=ref_buf / 2**20,
        buf_saving_at_equal_tp=buf_saving_at_tp,
        tp_gain_best=tp_gain,
        buf_saving_at_best_tp=buf_saving_at_best,
        templates_dominated=f"{dominated}/{len(temps)}",
        pareto_size=int(len(front)),
    )
    if verbose:
        print(f"DSE: {n_sample} designs in {seconds:.1f}s "
              f"({us:.0f} us/design; paper 6300 us -> "
              f"{summary['speedup_vs_paper']:.0f}x)")
        print(f"templates Pareto-dominated by custom designs: "
              f"{dominated}/{len(temps)}")
        print(f"template segmented[{n_seg}]: tp {ref_tp:.1f} ips, "
              f"buf {ref_buf/2**20:.2f} MiB")
        print(f"equal-throughput buffer saving: {buf_saving_at_tp:.0%} "
              f"(paper: up to 48%)")
        print(f"best custom: +{tp_gain:.0%} throughput with "
              f"{buf_saving_at_best:.0%} buffer saving (paper: +17%, -39%)")
        i = front[np.argmax(tp[front])]
        print("best design:",
              format_spec(decode_design(res.batch, int(i), len(net)),
                          len(net))[:100])
        print("checks:", checks)
    out = {"fig9": fig9, "fig10": summary, "checks": checks}
    save("fig9_fig10_dse", out)
    return out


if __name__ == "__main__":
    run()
