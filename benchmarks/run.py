"""Benchmark harness: one entry per paper table/figure + the TPU-side
dry-run/roofline reports.  ``python -m benchmarks.run [--quick]``.

Prints ``name,seconds,checks`` CSV at the end; artifacts land in
``artifacts/bench/*.json``.
"""
from __future__ import annotations

import argparse
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduce the Fig10 DSE sample to 10k designs")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    args = ap.parse_args(argv)

    from repro.compat import enable_persistent_compilation_cache

    # opt-in on-disk jit cache (REPRO_JAX_CACHE_DIR=...): repeated harness
    # runs skip every compile — see docs/perf.md
    enable_persistent_compilation_cache()

    from . import (eval_speed, fig5_fig8_fronts, fig6_fig7_breakdown,
                   fig9_fig10_dse, multinet_fronts, multinet_hybrid,
                   perf_gate,
                   roofline_report, tab1_arch_comparison, tab4_accuracy,
                   tab5_best_arch, tpu_model_accuracy)

    entries = [
        ("tab1_arch_comparison", tab1_arch_comparison.run, {}),
        ("tab4_accuracy", tab4_accuracy.run, {}),
        ("tab5_best_arch", tab5_best_arch.run, {}),
        ("fig5_fig8_fronts", fig5_fig8_fronts.run, {}),
        ("fig6_fig7_breakdown", fig6_fig7_breakdown.run, {}),
        ("fig9_fig10_dse", fig9_fig10_dse.run,
         {"n_sample": 10_000 if args.quick else 100_000}),
        ("multinet_fronts", multinet_fronts.run, {"quick": args.quick}),
        ("multinet_hybrid", multinet_hybrid.run, {"quick": args.quick}),
        ("eval_speed", eval_speed.run, {}),
        ("perf_gate", perf_gate.run, {"quick": args.quick}),
        ("roofline_report", roofline_report.run, {}),
        ("tpu_model_accuracy", tpu_model_accuracy.run, {}),
    ]
    if args.only:
        keep = set(args.only.split(","))
        entries = [e for e in entries if e[0] in keep]

    results = []
    failed = 0
    for name, fn, kw in entries:
        print(f"\n===== {name} =====")
        t0 = time.time()
        try:
            out = fn(verbose=True, **kw)
            checks = out.get("checks", {})
            ok = all(checks.values()) if checks else True
        except Exception as e:  # noqa: BLE001
            print(f"[{name}] FAILED: {type(e).__name__}: {e}")
            ok, checks = False, {}
        dt = time.time() - t0
        failed += 0 if ok else 1
        results.append((name, dt, ok, checks))

    print("\nname,seconds,all_checks_pass")
    for name, dt, ok, _ in results:
        print(f"{name},{dt:.1f},{ok}")
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
