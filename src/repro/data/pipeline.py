"""Synthetic sharded token pipeline with host-side prefetch.

Stands in for a production data loader: deterministic per-step synthetic
batches (seeded, reproducible across restarts — the checkpoint stores the
step, and the pipeline regenerates the exact stream from it), placed onto
the mesh with the plan's batch sharding, with a background prefetch queue so
host data generation overlaps device compute.

The token stream is a mixture of Zipf-distributed ids with a repeating
n-gram structure, so the loss actually *decreases* during the example runs
(pure-uniform tokens would pin the loss at ln(V))."""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Any, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, ShapeSpec


@dataclass
class DataSpec:
    batch: int
    seq_len: int
    vocab_size: int
    seed: int = 0


def _zipf_tokens(rng: np.random.Generator, shape, vocab: int) -> np.ndarray:
    """Zipf-ish token ids with local n-gram repetition (learnable)."""
    ranks = rng.zipf(1.3, size=shape).astype(np.int64)
    toks = (ranks - 1) % vocab
    # inject repeated bigrams: token[t] == token[t-2] with prob ~ 0.3
    rep = rng.random(shape) < 0.3
    toks[..., 2:] = np.where(rep[..., 2:], toks[..., :-2], toks[..., 2:])
    return toks.astype(np.int32)


def synth_batch(cfg: ModelConfig, shape: ShapeSpec, step: int, *,
                seed: int = 0, batch_override: int | None = None) -> dict:
    """One deterministic synthetic batch for (cfg, shape, step)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    B = batch_override or shape.global_batch
    S = shape.seq_len
    if cfg.family == "encdec":
        S_dec = max(S // cfg.dec_ratio, 8)
        toks = _zipf_tokens(rng, (B, S_dec + 1), cfg.vocab_size)
        return {
            "frames": rng.standard_normal((B, S, cfg.frontend_dim),
                                          dtype=np.float32).astype(np.float16),
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
        }
    if cfg.family == "vlm":
        S_text = max(S - cfg.n_patches, 8)
        toks = _zipf_tokens(rng, (B, S_text + 1), cfg.vocab_size)
        return {
            "patches": rng.standard_normal((B, cfg.n_patches, cfg.frontend_dim),
                                           dtype=np.float32).astype(np.float16),
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
        }
    toks = _zipf_tokens(rng, (B, S + 1), cfg.vocab_size)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class Pipeline:
    """Background-prefetching iterator of device-placed batches."""

    def __init__(self, cfg: ModelConfig, shape: ShapeSpec, *,
                 shardings: Any | None = None, seed: int = 0,
                 start_step: int = 0, prefetch: int = 2,
                 batch_override: int | None = None):
        self.cfg, self.shape = cfg, shape
        self.shardings = shardings
        self.seed = seed
        self.batch_override = batch_override
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self):
        step = self._step
        while not self._stop.is_set():
            host = synth_batch(self.cfg, self.shape, step, seed=self.seed,
                               batch_override=self.batch_override)
            self._q.put((step, host))
            step += 1

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        return self

    def __next__(self) -> tuple[int, dict]:
        step, host = self._q.get()
        if self.shardings is not None:
            dev = jax.tree.map(
                lambda a, s: jax.device_put(a, s), host, self.shardings)
        else:
            dev = jax.tree.map(jnp.asarray, host)
        return step, dev

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
