"""Uniform model API + dry-run input specs.

``get_model(cfg)`` returns a :class:`ModelApi` wrapping the family module.
``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
step input of a given assigned shape cell — weak-type-correct, shardable,
and allocation-free, for ``jax.jit(...).lower(...)`` dry-runs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeSpec
from . import encdec, ssm_lm, transformer, vlm
from .runtime import Runtime


@dataclass(frozen=True)
class ModelApi:
    cfg: ModelConfig
    init: Callable
    loss: Callable
    init_cache: Callable
    prefill: Callable
    decode_step: Callable
    forward: Callable | None = None


def get_model(cfg: ModelConfig) -> ModelApi:
    if cfg.family in ("dense", "moe"):
        m = transformer
    elif cfg.family in ("ssm", "hybrid"):
        m = ssm_lm
    elif cfg.family == "encdec":
        m = encdec
    elif cfg.family == "vlm":
        m = vlm
    else:
        raise KeyError(f"unknown family {cfg.family!r}")
    # dense/moe/ssm/hybrid prefill on a token array; encdec/vlm on the batch
    # dict (they consume the frontend stub inputs too).
    tok_only = cfg.family in ("dense", "moe", "ssm", "hybrid")

    def _prefill(params, batch, rt, **kw):
        inp = batch["tokens"] if (tok_only and isinstance(batch, dict)) else batch
        return m.prefill(params, inp, cfg, rt, **kw)

    return ModelApi(
        cfg=cfg,
        init=lambda key: m.init(key, cfg),
        loss=lambda params, batch, rt: m.loss(params, batch, cfg, rt),
        init_cache=lambda batch, max_len, rt, **kw: m.init_cache(
            cfg, batch, max_len, rt, **kw),
        prefill=_prefill,
        decode_step=lambda params, cache, tokens, rt: m.decode_step(
            params, cache, tokens, cfg, rt),
        forward=(lambda params, tokens, rt, **kw: m.forward(
            params, tokens, cfg, rt, **kw))
        if hasattr(m, "forward") else None,
    )


# --------------------------------------------------------------------------
# input specs (ShapeDtypeStructs) per assigned shape cell
# --------------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict[str, Any]:
    """Batch-input stand-ins for the step lowered for this cell.

    train  -> loss() batch;   prefill -> prefill() inputs;
    decode -> decode_step() (tokens only — cache specs via cache_specs()).
    """
    B, S = shape.global_batch, shape.seq_len
    i32, dt = jnp.int32, cfg.np_dtype

    if cfg.family == "encdec":
        S_dec = max(S // cfg.dec_ratio, 8)
        if shape.kind == "train":
            return {"frames": _sds((B, S, cfg.frontend_dim), dt),
                    "tokens": _sds((B, S_dec), i32),
                    "labels": _sds((B, S_dec), i32)}
        if shape.kind == "prefill":
            return {"frames": _sds((B, S, cfg.frontend_dim), dt),
                    "tokens": _sds((B, S_dec), i32)}
        return {"tokens": _sds((B, 1), i32)}

    if cfg.family == "vlm":
        P = cfg.n_patches
        S_text = max(S - P, 8)
        if shape.kind == "train":
            return {"patches": _sds((B, P, cfg.frontend_dim), dt),
                    "tokens": _sds((B, S_text), i32),
                    "labels": _sds((B, S_text), i32)}
        if shape.kind == "prefill":
            return {"patches": _sds((B, P, cfg.frontend_dim), dt),
                    "tokens": _sds((B, S_text), i32)}
        return {"tokens": _sds((B, 1), i32)}

    if shape.kind == "train":
        return {"tokens": _sds((B, S), i32), "labels": _sds((B, S), i32)}
    if shape.kind == "prefill":
        return {"tokens": _sds((B, S), i32)}
    return {"tokens": _sds((B, 1), i32)}


def cache_specs(cfg: ModelConfig, shape: ShapeSpec, rt: Runtime):
    """ShapeDtypeStructs of the decode cache for this cell."""
    api = get_model(cfg)
    B, S = shape.global_batch, shape.seq_len
    kw = {}
    if cfg.family == "encdec":
        kw["enc_len"] = S
        max_len = max(S // cfg.dec_ratio, 8) + 8
    else:
        max_len = S
    return jax.eval_shape(lambda: api.init_cache(B, max_len, rt, **kw))


def param_specs(cfg: ModelConfig):
    """ShapeDtypeStructs of the parameter pytree (no allocation)."""
    api = get_model(cfg)
    return jax.eval_shape(lambda: api.init(jax.random.key(0)))
