"""Core transformer layer primitives (pure JAX, pjit/SPMD-friendly).

Conventions
-----------
* Params are nested dicts of jnp arrays; ``init_*`` builds them, ``*_fwd``
  applies them.  Layer stacks are *scanned*: every per-layer param leaf gets a
  leading ``n_layers`` axis (see ``models/transformer.py``) so the HLO stays
  O(1) in depth.
* Activations are ``cfg.dtype`` (bf16 by default); norms, softmax and the
  final loss accumulate in fp32 (``preferred_element_type``).
* Attention is GQA with RoPE.  Two execution paths:
  - ``dense``: materialised scores — fine for short sequences;
  - ``chunked``: lax.scan over KV blocks with an online softmax
    (flash-attention recurrence in pure jnp) — the *functional twin* of
    ``repro.kernels.flash_attn`` and the only path whose working set is
    O(S·blk) instead of O(S^2), required for the 32k/500k shapes.
* Sliding-window attention (h2o-danube) masks the same two paths.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

Params = dict[str, Any]


# --------------------------------------------------------------------------
# initialisation helpers
# --------------------------------------------------------------------------
def _dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    std = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def init_rmsnorm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(x, params: Params, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------
# rotary position embeddings
# --------------------------------------------------------------------------
def rope_angles(positions, head_dim: int, theta: float):
    """(..., S) int positions -> cos/sin tables (..., S, head_dim/2), fp32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., S, H, D). cos/sin: (..., S, D/2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(x.dtype) if x.ndim == cos.ndim + 2 else cos.astype(x.dtype)
    s = sin[..., None, :].astype(x.dtype) if x.ndim == sin.ndim + 2 else sin.astype(x.dtype)
    # rotate-half convention (llama/qwen)
    if x.ndim == 4 and cos.ndim == 2:  # (B,S,H,D) with (S, half)
        c = cos[None, :, None, :].astype(x.dtype)
        s = sin[None, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# --------------------------------------------------------------------------
# attention (GQA + optional sliding window), dense and chunked paths
# --------------------------------------------------------------------------
def init_attention(key, cfg) -> Params:
    d, hd = cfg.d_model, cfg.head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    dt = cfg.np_dtype
    p = {
        "wq": _dense_init(ks[0], (d, nq * hd), dt),
        "wk": _dense_init(ks[1], (d, nkv * hd), dt),
        "wv": _dense_init(ks[2], (d, nkv * hd), dt),
        "wo": _dense_init(ks[3], (nq * hd, d), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nq * hd,), dt)
        p["bk"] = jnp.zeros((nkv * hd,), dt)
        p["bv"] = jnp.zeros((nkv * hd,), dt)
    return p


def _qkv(params, x, cfg):
    B, S, _ = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    return q, k, v


def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    B, S, H, D = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (B, S, H, n_rep, D)).reshape(
        B, S, H * n_rep, D
    )


def dense_attention(q, k, v, *, causal: bool, window: int | None,
                    q_offset: int = 0, scale: float | None = None):
    """Materialised-scores attention. q:(B,Sq,H,D) k/v:(B,Sk,Hkv,D)."""
    B, Sq, H, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    k = _repeat_kv(k, H // Hkv)
    v = _repeat_kv(v, H // Hkv)
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    qpos = jnp.arange(Sq)[:, None] + q_offset
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def _flash_mask(q_abs, k_abs, Sk_real, causal, window):
    """(q_blk, kv_blk) bool validity mask for one block pair."""
    msk = (k_abs < Sk_real)[None, :]
    if causal:
        msk &= k_abs[None, :] <= q_abs[:, None]
    if window is not None:
        msk &= k_abs[None, :] > q_abs[:, None] - window
    return msk


def _opaque_zero(x) -> jnp.ndarray:
    """An int32 zero that is *data-dependent* so trace-time partial
    evaluation cannot constant-fold it.

    Flash block masks are pure functions of the loop counter; if that chain
    is constant-foldable, scan linearization hoists every iteration's
    broadcast mask into ONE stacked (nq, nk, B, H, q_blk, kv_blk) residual —
    a full S² buffer (measured: 16 GiB/device on the kimi train cell).
    Seeding the counter from runtime data keeps the masks inside the loop;
    XLA later simplifies f - f == 0 locally without re-stacking."""
    f = jnp.isnan(x.reshape(-1)[0]).astype(jnp.int32)
    return f - f


def _flash_hint(rt, n_heads: int, q_blk: int, kv_blk: int):
    """Sharding-hint closure for the per-block tensors inside the flash
    scans.  Without it, SPMD may shard head_dim — the contraction dim of
    the scores einsum — forcing an all-reduce per (layer, q-block,
    kv-block): measured 131k ARs / 2.9 TB on qwen2.5-32b prefill (§Perf
    hillclimb A).

    * heads divide tp  -> shard heads: every flash einsum is local;
    * else             -> shard the q-block dim (fwd-only safe: backward
      dk/dv einsums contract q, so this mode is applied to inference
      paths; training keeps XLA's choice — documented limitation).
    Returns f(x, role) with role in {"q", "kv", "stat"} or None."""
    if rt is None or rt.mesh is None or not rt.tp_axis:
        return None
    tp = rt.mesh.shape.get(rt.tp_axis, 1)
    if tp <= 1 or n_heads % tp != 0:
        return None          # non-dividing heads are PADDED by the caller
    dp = rt.dp_axes or None
    ax = rt.tp_axis

    def f(x, role):
        if role == "stat":                 # (B, H, q_blk)
            return rt.constrain(x, dp, ax, None)
        return rt.constrain(x, dp, ax, None, None)
    return f


def _flash_fwd_blocks(q, k, v, causal, window, q_offset, q_blk, kv_blk,
                      scale, Sk_real, hint=None):
    """Blocked forward returning (out bf16-like, lse f32).

    q: (nq, B, H, q_blk, D); k/v: (nk, B, H, kv_blk, D).

    Block indices are *loop-carried* (not scanned iota inputs): constant-
    derived masks would otherwise be hoisted by partial-eval into one
    stacked (nq, nk, B, H, q_blk, kv_blk) tensor — a full S² buffer that
    defeats the whole point of blocking (EXPERIMENTS.md §Perf).
    """
    nq, B, H, _, D = q.shape
    nk = k.shape[0]

    def q_block(carry_q, q_i):
        qi = carry_q
        if hint is not None:
            q_i = hint(q_i, "q")
        q_i = q_i * scale
        q_abs = qi * q_blk + jnp.arange(q_blk) + q_offset

        def kv_step(carry, inp):
            kj, acc, m, l = carry
            k_j, v_j = inp
            if hint is not None:
                k_j = hint(k_j, "kv")
                v_j = hint(v_j, "kv")
            s = jnp.einsum("bhqd,bhkd->bhqk", q_i, k_j,
                           preferred_element_type=jnp.float32)
            k_abs = kj * kv_blk + jnp.arange(kv_blk)
            msk = _flash_mask(q_abs, k_abs, Sk_real, causal, window)
            s = jnp.where(msk[None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(-1))
            m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(msk[None, None], p, 0.0)
            alpha = jnp.where(jnp.isneginf(m), 0.0, jnp.exp(m - m_safe))
            l_new = l * alpha + p.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(v_j.dtype), v_j,
                preferred_element_type=jnp.float32)
            return (kj + 1, acc, m_new, l_new), None

        acc0 = jnp.zeros((B, H, q_blk, D), jnp.float32)
        m0 = jnp.full((B, H, q_blk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, H, q_blk), jnp.float32)
        (_, acc, m, l), _ = lax.scan(
            kv_step, (_opaque_zero(k), acc0, m0, l0), (k, v))
        lse = jnp.where(l > 0.0, m + jnp.log(jnp.maximum(l, 1e-37)), -jnp.inf)
        l = jnp.where(l == 0.0, 1.0, l)
        out_i = (acc / l[..., None]).astype(v.dtype)  # (B,H,q,D)
        return qi + 1, (out_i, lse)

    _, (out, lse) = lax.scan(q_block, _opaque_zero(q), q)
    return out, lse


def _tri_eligible(causal, window, q_offset, q_blk, kv_blk, nq, nk):
    """Split-half triangular iteration applies to plain causal self-attn
    with square blocks and an even block count."""
    return (causal and window is None and q_offset == 0
            and q_blk == kv_blk and nq == nk and nq >= 2 and nq % 2 == 0)


def _flash_fwd_tri(q, k, v, q_blk, scale, Sk_real, hint):
    """Causal forward over the lower triangle only: row pair (t, nq-1-t)
    shares one inner scan of nq+1 block steps — (nq/2)(nq+1) block pairs
    instead of nq², i.e. ~2x fewer flash einsums AND k/v block reads
    (§Perf hillclimb B).  Returns (out, lse) shaped like the dense path."""
    nq, B, H, _, D = q.shape

    def row_pair(carry_t, _):
        t = carry_t
        i_lo, i_hi = t, nq - 1 - t
        q_lo = jax.lax.dynamic_index_in_dim(q, i_lo, 0, keepdims=False)
        q_hi = jax.lax.dynamic_index_in_dim(q, i_hi, 0, keepdims=False)
        if hint is not None:
            q_lo = hint(q_lo, "q")
            q_hi = hint(q_hi, "q")
        q_lo = q_lo * scale
        q_hi = q_hi * scale

        def kv_step(carry, _):
            (j, acc_lo, m_lo, l_lo, acc_hi, m_hi, l_hi) = carry
            serve_lo = j <= i_lo
            kj = jnp.where(serve_lo, j, j - i_lo - 1)
            k_j = jax.lax.dynamic_index_in_dim(k, kj, 0, keepdims=False)
            v_j = jax.lax.dynamic_index_in_dim(v, kj, 0, keepdims=False)
            if hint is not None:
                k_j = hint(k_j, "kv")
                v_j = hint(v_j, "kv")
            q_i = jnp.where(serve_lo, q_lo, q_hi)
            i_cur = jnp.where(serve_lo, i_lo, i_hi)
            s = jnp.einsum("bhqd,bhkd->bhqk", q_i, k_j,
                           preferred_element_type=jnp.float32)
            q_abs = i_cur * q_blk + jnp.arange(q_blk)
            k_abs = kj * q_blk + jnp.arange(q_blk)
            msk = (k_abs[None, :] <= q_abs[:, None]) & \
                (k_abs < Sk_real)[None, :]
            s = jnp.where(msk[None, None], s, -jnp.inf)
            m_old = jnp.where(serve_lo, m_lo, m_hi)
            l_old = jnp.where(serve_lo, l_lo, l_hi)
            acc_old = jnp.where(serve_lo, acc_lo, acc_hi)
            m_new = jnp.maximum(m_old, s.max(-1))
            m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
            p = jnp.where(msk[None, None], jnp.exp(s - m_safe[..., None]),
                          0.0)
            alpha = jnp.where(jnp.isneginf(m_old), 0.0,
                              jnp.exp(m_old - m_safe))
            l_new = l_old * alpha + p.sum(-1)
            acc_new = acc_old * alpha[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(v_j.dtype), v_j,
                preferred_element_type=jnp.float32)
            acc_lo = jnp.where(serve_lo, acc_new, acc_lo)
            m_lo2 = jnp.where(serve_lo, m_new, m_lo)
            l_lo2 = jnp.where(serve_lo, l_new, l_lo)
            acc_hi = jnp.where(serve_lo, acc_hi, acc_new)
            m_hi2 = jnp.where(serve_lo, m_hi, m_new)
            l_hi2 = jnp.where(serve_lo, l_hi, l_new)
            return (j + 1, acc_lo, m_lo2, l_lo2, acc_hi, m_hi2, l_hi2), None

        z = jnp.zeros((B, H, q_blk, D), jnp.float32)
        mi = jnp.full((B, H, q_blk), -jnp.inf, jnp.float32)
        li = jnp.zeros((B, H, q_blk), jnp.float32)
        (_, acc_lo, m_lo, l_lo, acc_hi, m_hi, l_hi), _ = lax.scan(
            kv_step, (_opaque_zero(k), z, mi, li, z, mi, li), None,
            length=nq + 1)

        def fin(acc, m, l):
            lse = jnp.where(l > 0.0, m + jnp.log(jnp.maximum(l, 1e-37)),
                            -jnp.inf)
            l = jnp.where(l == 0.0, 1.0, l)
            return (acc / l[..., None]).astype(v.dtype), lse

        o_lo, lse_lo = fin(acc_lo, m_lo, l_lo)
        o_hi, lse_hi = fin(acc_hi, m_hi, l_hi)
        return t + 1, (o_lo, lse_lo, o_hi, lse_hi)

    _, (o_lo, lse_lo, o_hi, lse_hi) = lax.scan(
        row_pair, _opaque_zero(q), None, length=nq // 2)
    idx_lo = jnp.arange(nq // 2)
    idx_hi = nq - 1 - idx_lo
    out = jnp.zeros((nq, B, H, q_blk, D), o_lo.dtype)
    out = out.at[idx_lo].set(o_lo).at[idx_hi].set(o_hi)
    lse = jnp.zeros((nq, B, H, q_blk), lse_lo.dtype)
    lse = lse.at[idx_lo].set(lse_lo).at[idx_hi].set(lse_hi)
    return out, lse


def _flash(causal, window, q_offset, q_blk, kv_blk, scale, Sq, Sk, hint,
           q, k, v):
    if _tri_eligible(causal, window, q_offset, q_blk, kv_blk,
                     q.shape[0], k.shape[0]):
        out, _ = _flash_fwd_tri(q, k, v, q_blk, scale, Sk, hint)
        return out
    out, _ = _flash_fwd_blocks(q, k, v, causal, window, q_offset, q_blk,
                               kv_blk, scale, Sk, hint)
    return out


_flash = jax.custom_vjp(_flash, nondiff_argnums=(0, 1, 2, 3, 4, 5, 6, 7, 8))


def _flash_vjp_fwd(causal, window, q_offset, q_blk, kv_blk, scale, Sq, Sk,
                   hint, q, k, v):
    if _tri_eligible(causal, window, q_offset, q_blk, kv_blk,
                     q.shape[0], k.shape[0]):
        out, lse = _flash_fwd_tri(q, k, v, q_blk, scale, Sk, hint)
    else:
        out, lse = _flash_fwd_blocks(q, k, v, causal, window, q_offset,
                                     q_blk, kv_blk, scale, Sk, hint)
    return out, (q, k, v, out, lse)


def _flash_bwd_tri(q, k, v, out, lse, dout, q_blk, scale, Sk_real, hint):
    """Triangular FlashAttention-2 backward: same split-half row pairing as
    the forward — (nq/2)(nq+1) block pairs, dk/dv accumulated in-place at
    the served kv index."""
    nq, B, H, _, D = q.shape
    Drow = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), -1)
    lse_safe = jnp.where(jnp.isneginf(lse), 0.0, lse)

    def row_pair(carry, _):
        t, dk_acc, dv_acc = carry
        i_lo, i_hi = t, nq - 1 - t

        def pick(a, i):
            return jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False)

        q_lo, q_hi = pick(q, i_lo), pick(q, i_hi)
        do_lo, do_hi = pick(dout, i_lo), pick(dout, i_hi)
        lse_lo, lse_hi = pick(lse_safe, i_lo), pick(lse_safe, i_hi)
        D_lo, D_hi = pick(Drow, i_lo), pick(Drow, i_hi)
        if hint is not None:
            q_lo, q_hi = hint(q_lo, "q"), hint(q_hi, "q")
            do_lo, do_hi = hint(do_lo, "q"), hint(do_hi, "q")
            lse_lo, lse_hi = hint(lse_lo, "stat"), hint(lse_hi, "stat")
            D_lo, D_hi = hint(D_lo, "stat"), hint(D_hi, "stat")

        def kv_step(carry2, _):
            j, dq_lo, dq_hi, dk_a, dv_a = carry2
            serve_lo = j <= i_lo
            kj = jnp.where(serve_lo, j, j - i_lo - 1)
            k_j = jax.lax.dynamic_index_in_dim(k, kj, 0, keepdims=False)
            v_j = jax.lax.dynamic_index_in_dim(v, kj, 0, keepdims=False)
            if hint is not None:
                k_j = hint(k_j, "kv")
                v_j = hint(v_j, "kv")
            q_i = jnp.where(serve_lo, q_lo, q_hi)
            do_i = jnp.where(serve_lo, do_lo, do_hi)
            lse_i = jnp.where(serve_lo, lse_lo, lse_hi)
            D_i = jnp.where(serve_lo, D_lo, D_hi)
            i_cur = jnp.where(serve_lo, i_lo, i_hi)
            q_s = (q_i * scale).astype(q_i.dtype)
            q_abs = i_cur * q_blk + jnp.arange(q_blk)
            k_abs = kj * q_blk + jnp.arange(q_blk)
            msk = (k_abs[None, :] <= q_abs[:, None]) & \
                (k_abs < Sk_real)[None, :]
            s = jnp.einsum("bhqd,bhkd->bhqk", q_s, k_j,
                           preferred_element_type=jnp.float32)
            p = jnp.where(msk[None, None],
                          jnp.exp(s - lse_i[..., None]), 0.0)
            dv_j = jnp.einsum("bhqk,bhqd->bhkd", p.astype(do_i.dtype), do_i,
                              preferred_element_type=jnp.float32)
            dp = jnp.einsum("bhqd,bhkd->bhqk", do_i, v_j,
                            preferred_element_type=jnp.float32)
            ds = p * (dp - D_i[..., None]) * scale
            dsl = ds.astype(q_i.dtype)
            dq_i = jnp.einsum("bhqk,bhkd->bhqd", dsl, k_j,
                              preferred_element_type=jnp.float32)
            dk_j = jnp.einsum("bhqk,bhqd->bhkd", dsl, q_i,
                              preferred_element_type=jnp.float32)
            dq_lo = jnp.where(serve_lo, dq_lo + dq_i, dq_lo)
            dq_hi = jnp.where(serve_lo, dq_hi, dq_hi + dq_i)
            old_k = jax.lax.dynamic_index_in_dim(dk_a, kj, 0, keepdims=False)
            old_v = jax.lax.dynamic_index_in_dim(dv_a, kj, 0, keepdims=False)
            dk_a = jax.lax.dynamic_update_index_in_dim(
                dk_a, old_k + dk_j, kj, 0)
            dv_a = jax.lax.dynamic_update_index_in_dim(
                dv_a, old_v + dv_j, kj, 0)
            return (j + 1, dq_lo, dq_hi, dk_a, dv_a), None

        z = jnp.zeros((B, H, q_blk, D), jnp.float32)
        (_, dq_lo, dq_hi, dk_acc, dv_acc), _ = lax.scan(
            kv_step, (_opaque_zero(k), z, z, dk_acc, dv_acc), None,
            length=nq + 1)
        return (t + 1, dk_acc, dv_acc), (dq_lo, dq_hi)

    zk = jnp.zeros((nq, B, H, q_blk, D), jnp.float32)
    (_, dk, dv), (dq_lo, dq_hi) = lax.scan(
        row_pair, (_opaque_zero(q), zk, zk), None, length=nq // 2)
    idx_lo = jnp.arange(nq // 2)
    idx_hi = nq - 1 - idx_lo
    dq = jnp.zeros((nq, B, H, q_blk, D), jnp.float32)
    dq = dq.at[idx_lo].set(dq_lo).at[idx_hi].set(dq_hi)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


def _flash_vjp_bwd(causal, window, q_offset, q_blk, kv_blk, scale, Sq, Sk,
                   hint, res, dout):
    """FlashAttention-2 backward: recompute scores blockwise from (q,k,v,lse)
    — saves O(S) residuals instead of autodiff's O(S²) block probabilities
    (the single largest HBM term of the naive chunked backward, see
    EXPERIMENTS.md §Perf)."""
    q, k, v, out, lse = res
    if _tri_eligible(causal, window, q_offset, q_blk, kv_blk,
                     q.shape[0], k.shape[0]):
        return _flash_bwd_tri(q, k, v, out, lse, dout, q_blk, scale, Sk,
                              hint)
    nq, B, H, _, D = q.shape
    nk = k.shape[0]
    # D_i = rowsum(dO ⊙ O), (nq, B, H, q_blk), f32
    Drow = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), -1)
    lse_safe = jnp.where(jnp.isneginf(lse), 0.0, lse)

    def q_block_step(carry, inp):
        qi, dk_acc, dv_acc = carry                  # (nk,B,H,kv,D) f32
        q_i, do_i, lse_i, D_i = inp
        if hint is not None:
            q_i = hint(q_i, "q")
            do_i = hint(do_i, "q")
            lse_i = hint(lse_i, "stat")
            D_i = hint(D_i, "stat")
        q_abs = qi * q_blk + jnp.arange(q_blk) + q_offset
        q_s = (q_i * scale).astype(q_i.dtype)

        def kv_step(carry2, inp2):
            kj, dq_acc = carry2
            k_j, v_j = inp2
            if hint is not None:
                k_j = hint(k_j, "kv")
                v_j = hint(v_j, "kv")
            k_abs = kj * kv_blk + jnp.arange(kv_blk)
            msk = _flash_mask(q_abs, k_abs, Sk, causal, window)
            s = jnp.einsum("bhqd,bhkd->bhqk", q_s, k_j,
                           preferred_element_type=jnp.float32)
            p = jnp.where(msk[None, None],
                          jnp.exp(s - lse_i[..., None]), 0.0)
            dv_j = jnp.einsum("bhqk,bhqd->bhkd", p.astype(do_i.dtype), do_i,
                              preferred_element_type=jnp.float32)
            dp = jnp.einsum("bhqd,bhkd->bhqk", do_i, v_j,
                            preferred_element_type=jnp.float32)
            ds = p * (dp - D_i[..., None]) * scale
            dsl = ds.astype(q_i.dtype)
            dq_acc = dq_acc + jnp.einsum(
                "bhqk,bhkd->bhqd", dsl, k_j,
                preferred_element_type=jnp.float32)
            dk_j = jnp.einsum("bhqk,bhqd->bhkd", dsl, q_i,
                              preferred_element_type=jnp.float32)
            return (kj + 1, dq_acc), (dk_j, dv_j)

        dq0 = jnp.zeros((B, H, q_blk, D), jnp.float32)
        (_, dq_i), (dk_p, dv_p) = lax.scan(
            kv_step, (_opaque_zero(k), dq0), (k, v))
        return (qi + 1, dk_acc + dk_p, dv_acc + dv_p), dq_i

    zk = jnp.zeros((nk, B, H, kv_blk, D), jnp.float32)
    (_, dk, dv), dq = lax.scan(
        q_block_step, (_opaque_zero(q), zk, zk),
        (q, dout, lse_safe, Drow))
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def chunked_attention(q, k, v, *, causal: bool, window: int | None,
                      q_offset: int = 0, q_blk: int = 512, kv_blk: int = 1024,
                      scale: float | None = None, rt=None):
    """Flash-style online-softmax attention, O(S*blk) working set — forward
    AND backward (custom VJP, FlashAttention-2 recompute).

    Pure-jnp twin of ``repro.kernels.flash_attn`` (the Pallas TPU kernel);
    both are validated against ``dense_attention`` in tests.
    """
    B, Sq, H, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    k = _repeat_kv(k, H // Hkv)
    v = _repeat_kv(v, H // Hkv)
    scale = scale if scale is not None else 1.0 / math.sqrt(D)

    # heads that don't divide the tp width (qwen2.5: 40 on 16) are padded to
    # the next multiple: ~20% attention-flops waste buys fully LOCAL flash
    # einsums — vs 131k per-block all-reduces (2.9 TB) unguided, or 10 TB of
    # k/v replication in a q-sharded layout (§Perf hillclimb A log).
    Hp = H
    if rt is not None and rt.mesh is not None and rt.tp_axis:
        tp = rt.mesh.shape.get(rt.tp_axis, 1)
        if tp > 1 and H % tp:
            Hp = -(-H // tp) * tp
            hp = Hp - H
            q = jnp.pad(q, ((0, 0), (0, 0), (0, hp), (0, 0)))
            k = jnp.pad(k, ((0, 0), (0, 0), (0, hp), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, 0), (0, hp), (0, 0)))

    q_blk = min(q_blk, Sq)
    kv_blk = min(kv_blk, Sk)
    if causal and window is None and q_offset == 0 and Sq == Sk:
        kv_blk = q_blk        # square blocks -> triangular split-half path
    nq = -(-Sq // q_blk)
    nk = -(-Sk // kv_blk)
    pq, pk = nq * q_blk - Sq, nk * kv_blk - Sk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))

    qb = q.reshape(B, nq, q_blk, Hp, D).transpose(1, 0, 3, 2, 4)  # (nq,B,H,q,D)
    kb = k.reshape(B, nk, kv_blk, Hp, D).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(B, nk, kv_blk, Hp, D).transpose(1, 0, 3, 2, 4)

    hint = _flash_hint(rt, Hp, q_blk, kv_blk)
    out = _flash(causal, window, q_offset, q_blk, kv_blk, scale, Sq, Sk,
                 hint, qb, kb, vb)
    out = out.transpose(1, 0, 3, 2, 4).reshape(B, nq * q_blk, Hp, D)
    return out[:, :Sq, :H]


def attention_fwd(params: Params, x, cfg, *, positions=None, causal=True,
                  mode: str = "auto", q_offset: int = 0, rt=None):
    """Self-attention over x:(B,S,D) -> (B,S,D).

    ``rt`` pins q/k/v to a batch+head sharding before the blocked flash
    path: without the hint, SPMD picks a layout for the 5-D blocked
    tensors that forces a reduction per (q, kv) block pair — measured
    131k all-reduces / 2.9 TB wire on the qwen2.5 prefill cell
    (EXPERIMENTS.md §Perf hillclimb A)."""
    B, S, _ = x.shape
    q, k, v = _qkv(params, x, cfg)
    if positions is None:
        positions = jnp.arange(S) + q_offset
    if cfg.pos_emb == "rope":
        cos, sin = rope_angles(positions, cfg.head_dim, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    window = cfg.sliding_window
    if mode == "auto":
        mode = "chunked" if S > 2048 else "dense"
    if mode == "chunked":
        out = chunked_attention(q, k, v, causal=causal, window=window,
                                q_offset=q_offset, rt=rt)
    else:
        out = dense_attention(q, k, v, causal=causal, window=window,
                              q_offset=q_offset)
    out = out.reshape(B, S, cfg.n_heads * cfg.head_dim)
    return out @ params["wo"]


def attention_decode(params: Params, x, cfg, cache_k, cache_v, cache_len):
    """One-token decode with a KV cache.

    x: (B, 1, D); cache_k/v: (B, S_max, Hkv, hd); cache_len: () int32 —
    number of valid cache positions.  Returns (out, new_k, new_v).
    """
    B = x.shape[0]
    q, k, v = _qkv(params, x, cfg)
    pos = jnp.full((1,), cache_len, jnp.int32)
    if cfg.pos_emb == "rope":
        cos, sin = rope_angles(pos, cfg.head_dim, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    new_k = lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), cache_len, axis=1)
    new_v = lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), cache_len, axis=1)

    S_max, Hkv = new_k.shape[1], new_k.shape[2]
    H = cfg.n_heads
    rep = H // Hkv
    # grouped-GQA einsum: the kv cache is NEVER repeated — a materialized
    # repeat of an (L, B, S, Hkv, hd) cache forced a full f32 all-gather of
    # the cache per layer per token (§Perf hillclimb D)
    qg = q.reshape(B, 1, Hkv, rep, cfg.head_dim)
    scale = 1.0 / math.sqrt(cfg.head_dim)
    s = jnp.einsum("bqkrd,bskd->bkrqs", qg, new_k,
                   preferred_element_type=jnp.float32) * scale
    kpos = jnp.arange(S_max)
    valid = kpos <= cache_len
    if cfg.sliding_window is not None:
        valid &= kpos > cache_len - cfg.sliding_window
    s = jnp.where(valid[None, None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkrqs,bskd->bqkrd", p, new_v)
    out = out.reshape(B, 1, H * cfg.head_dim) @ params["wo"]
    return out, new_k, new_v


def cross_attention_fwd(params: Params, x, enc_out, cfg):
    """Decoder cross-attention: queries from x, keys/values from enc_out."""
    B, Sq, _ = x.shape
    Sk = enc_out.shape[1]
    q = (x @ params["wq"]).reshape(B, Sq, cfg.n_heads, cfg.head_dim)
    k = (enc_out @ params["wk"]).reshape(B, Sk, cfg.n_kv_heads, cfg.head_dim)
    v = (enc_out @ params["wv"]).reshape(B, Sk, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qkv_bias:
        q = q + params["bq"].reshape(1, 1, cfg.n_heads, cfg.head_dim)
        k = k + params["bk"].reshape(1, 1, cfg.n_kv_heads, cfg.head_dim)
        v = v + params["bv"].reshape(1, 1, cfg.n_kv_heads, cfg.head_dim)
    mode = chunked_attention if max(Sq, Sk) > 2048 else dense_attention
    out = mode(q, k, v, causal=False, window=None)
    return out.reshape(B, Sq, cfg.n_heads * cfg.head_dim) @ params["wo"]


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------
def init_mlp(key, cfg, d_ff: int | None = None) -> Params:
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    dt = cfg.np_dtype
    ks = jax.random.split(key, 3)
    if cfg.mlp_act == "swiglu":
        return {
            "wg": _dense_init(ks[0], (d, f), dt),
            "wu": _dense_init(ks[1], (d, f), dt),
            "wd": _dense_init(ks[2], (f, d), dt),
        }
    return {  # gelu 2-matrix MLP (whisper)
        "wu": _dense_init(ks[0], (d, f), dt),
        "bu": jnp.zeros((f,), dt),
        "wd": _dense_init(ks[1], (f, d), dt),
        "bd": jnp.zeros((d,), dt),
    }


def mlp_fwd(params: Params, x, cfg):
    if "wg" in params:
        return (jax.nn.silu(x @ params["wg"]) * (x @ params["wu"])) @ params["wd"]
    h = jax.nn.gelu(x @ params["wu"] + params["bu"])
    return h @ params["wd"] + params["bd"]


# --------------------------------------------------------------------------
# embeddings / unembedding
# --------------------------------------------------------------------------
def init_embedding(key, cfg) -> Params:
    dt = cfg.np_dtype
    p = {"table": _dense_init(key, (cfg.padded_vocab, cfg.d_model), dt, scale=0.02)}
    if cfg.pos_emb == "abs":
        p["pos"] = _dense_init(
            jax.random.fold_in(key, 1), (cfg.max_abs_positions, cfg.d_model), dt, scale=0.02
        )
    return p


def embed(params: Params, tokens, cfg, *, offset: int = 0):
    x = jnp.take(params["table"], tokens, axis=0)
    if cfg.pos_emb == "abs":
        S = tokens.shape[-1]
        x = x + lax.dynamic_slice_in_dim(params["pos"], offset, S, axis=0)
    return x


def unembed(params_emb: Params, params_head: Params | None, x, cfg):
    """Project to vocab logits (fp32). Tied or separate head."""
    w = params_emb["table"] if params_head is None else params_head["w"]
    if params_head is None:
        return jnp.einsum("bsd,vd->bsv", x, w, preferred_element_type=jnp.float32)
    return jnp.einsum("bsd,dv->bsv", x, w, preferred_element_type=jnp.float32)


def init_lm_head(key, cfg) -> Params | None:
    if cfg.tie_embeddings:
        return None
    return {"w": _dense_init(key, (cfg.d_model, cfg.padded_vocab), cfg.np_dtype, scale=0.02)}
