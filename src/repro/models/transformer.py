"""Decoder-only transformer LM (dense and MoE families).

Layers are *scanned*: every per-layer param leaf carries a leading
``n_layers`` axis, so HLO size (and compile time) is O(1) in depth — a hard
requirement for the 64-layer/61-layer dry-run cells.

API (used by ``models/registry.py``):
    init(key, cfg)                          -> params
    forward(params, tokens, cfg, rt)        -> (logits, aux)
    loss(params, batch, cfg, rt)            -> (loss, metrics)
    prefill(params, tokens, cfg, rt)        -> (last_logits, cache)
    init_cache(cfg, batch, max_len, rt)     -> cache
    decode_step(params, cache, tokens, cfg, rt) -> (logits, cache)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from . import layers as L
from . import moe as M


# --------------------------------------------------------------------------
# one decoder block
# --------------------------------------------------------------------------
def init_block(key, cfg):
    ks = jax.random.split(key, 4)
    p = {
        "ln1": L.init_rmsnorm(cfg.d_model, cfg.np_dtype),
        "attn": L.init_attention(ks[0], cfg),
        "ln2": L.init_rmsnorm(cfg.d_model, cfg.np_dtype),
    }
    if cfg.n_experts:
        p["moe"] = M.init_moe(ks[1], cfg)
    else:
        p["mlp"] = L.init_mlp(ks[2], cfg)
    return p


def block_fwd(p, x, cfg, rt, *, return_kv: bool = False):
    """Full-sequence block. x: (B,S,D) -> (x', aux[, (k,v)])."""
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    if return_kv:
        B, S, _ = h.shape
        q, k, v = L._qkv(p["attn"], h, cfg)
        pos = jnp.arange(S)
        if cfg.pos_emb == "rope":
            cos, sin = L.rope_angles(pos, cfg.head_dim, cfg.rope_theta)
            q = L.apply_rope(q, cos, sin)
            k = L.apply_rope(k, cos, sin)
        mode = rt.attn_mode
        if mode == "auto":
            mode = "chunked" if S > 2048 else "dense"
        if mode == "chunked":
            o = L.chunked_attention(q, k, v, causal=True,
                                    window=cfg.sliding_window, rt=rt)
        else:
            o = L.dense_attention(q, k, v, causal=True,
                                  window=cfg.sliding_window)
        attn_out = o.reshape(B, S, cfg.n_heads * cfg.head_dim) @ p["attn"]["wo"]
        kv = (k, v)
    else:
        attn_out = L.attention_fwd(p["attn"], h, cfg, mode=rt.attn_mode, rt=rt)
        kv = None
    x = x + attn_out
    x = rt.constrain(x, *rt.act_spec(3))
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.n_experts:
        y, aux = M.moe_fwd(p["moe"], h, cfg, rt)
    else:
        y, aux = L.mlp_fwd(p["mlp"], h, cfg), jnp.zeros((), jnp.float32)
    x = x + y
    x = rt.constrain(x, *rt.act_spec(3))
    return (x, aux, kv) if return_kv else (x, aux)


def block_decode(p, x, cfg, rt, cache_k, cache_v, cache_len):
    """One-token block step with KV cache update."""
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    attn_out, nk, nv = L.attention_decode(p["attn"], h, cfg,
                                          cache_k, cache_v, cache_len)
    x = x + attn_out
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.n_experts:
        y, _ = M.moe_fwd(p["moe"], h, cfg, rt)
    else:
        y = L.mlp_fwd(p["mlp"], h, cfg)
    return x + y, nk, nv


# --------------------------------------------------------------------------
# full model
# --------------------------------------------------------------------------
def init(key, cfg):
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    params = {
        "embed": L.init_embedding(k_emb, cfg),
        "layers": jax.vmap(lambda k: init_block(k, cfg))(layer_keys),
        "final_norm": L.init_rmsnorm(cfg.d_model, cfg.np_dtype),
    }
    head = L.init_lm_head(k_head, cfg)
    if head is not None:
        params["head"] = head
    return params


def _scan_blocks(params, x, cfg, rt, *, return_kv: bool = False):
    def body(carry, lp):
        x, aux = carry
        if return_kv:
            x, a, kv = block_fwd(lp, x, cfg, rt, return_kv=True)
            return (x, aux + a), kv
        x, a = block_fwd(lp, x, cfg, rt)
        return (x, aux + a), None

    init = (x, jnp.zeros((), jnp.float32))
    g = rt.remat_group if rt.remat else 1
    if rt.remat and g > 1 and not return_kv:
        # grouped remat: save residuals every g layers only — HBM for saved
        # activations drops g×, each group's interior is recomputed once in
        # the backward pass.  Layer counts that don't divide g (61 is prime)
        # run the remainder as per-layer-checkpointed tail layers.
        n_grp = cfg.n_layers // g
        n_tail = cfg.n_layers - n_grp * g
        head = jax.tree.map(lambda a: a[:n_grp * g], params["layers"])
        grouped = jax.tree.map(
            lambda a: a.reshape((n_grp, g) + a.shape[1:]), head)

        def group_body(carry, gp):
            carry, _ = lax.scan(body, carry, gp)
            return carry, None

        group_body = jax.checkpoint(group_body, prevent_cse=False)
        (x, aux), _ = lax.scan(group_body, init, grouped)
        if n_tail:
            tail = jax.tree.map(lambda a: a[n_grp * g:], params["layers"])
            tail_body = jax.checkpoint(body, prevent_cse=False)
            (x, aux), _ = lax.scan(tail_body, (x, aux), tail)
        return x, aux, None

    if rt.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), kvs = lax.scan(body, init, params["layers"])
    return x, aux, kvs


def forward(params, tokens, cfg, rt, *, embeds=None):
    """tokens (B,S) int32 -> (logits (B,S,V) fp32, aux). ``embeds`` lets the
    VLM/audio frontends inject precomputed embeddings for a prefix."""
    x = L.embed(params["embed"], tokens, cfg)
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    x = rt.constrain(x, *rt.act_spec(3))
    x, aux, _ = _scan_blocks(params, x, cfg, rt)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], params.get("head"), x, cfg)
    return logits, aux


def cross_entropy(logits, labels, mask=None):
    """Mean token NLL in fp32. logits (B,S,V), labels (B,S) int32."""
    lse = jax.scipy.special.logsumexp(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll.astype(jnp.float32)
    if mask is None:
        return nll.mean()
    m = mask.astype(jnp.float32)
    return (nll * m).sum() / jnp.maximum(m.sum(), 1.0)


def chunked_xent(x, params, labels, cfg, rt, mask=None):
    """Cross-entropy without materialising (B,S,V): scan over S chunks.

    Peak logits memory drops from B*S*V to B*chunk*V — the difference between
    fitting and not fitting the 150k-vocab train cells in HBM.
    """
    B, S, D = x.shape
    c = rt.loss_chunk
    nc = -(-S // c)
    pad = nc * c - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        pm = jnp.pad(mask if mask is not None
                     else jnp.ones((B, S), bool), ((0, 0), (0, pad)))
    else:
        pm = mask if mask is not None else jnp.ones((B, S), bool)
    xc = x.reshape(B, nc, c, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nc, c).transpose(1, 0, 2)
    mc = pm.reshape(B, nc, c).transpose(1, 0, 2)

    def body(carry, inp):
        tot, cnt = carry
        xi, li, mi = inp
        logits = L.unembed(params["embed"], params.get("head"), xi, cfg)
        lse = jax.scipy.special.logsumexp(logits.astype(jnp.float32), -1)
        ll = jnp.take_along_axis(logits, li[..., None], -1)[..., 0]
        nll = (lse - ll.astype(jnp.float32)) * mi.astype(jnp.float32)
        return (tot + nll.sum(), cnt + mi.sum()), None

    body = jax.checkpoint(body, prevent_cse=False)
    (tot, cnt), _ = lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)


def loss(params, batch, cfg, rt):
    """batch: {tokens (B,S), labels (B,S)[, mask]} -> (scalar, metrics)."""
    tokens, labels = batch["tokens"], batch["labels"]
    mask = batch.get("mask")
    if rt.loss_chunk:
        x = L.embed(params["embed"], tokens, cfg)
        x = rt.constrain(x, *rt.act_spec(3))
        x, aux, _ = _scan_blocks(params, x, cfg, rt)
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        nll = chunked_xent(x, params, labels, cfg, rt, mask)
    else:
        logits, aux = forward(params, tokens, cfg, rt)
        nll = cross_entropy(logits, labels, mask)
    total = nll + cfg.aux_loss_coef * aux
    return total, {"nll": nll, "aux": aux}


# --------------------------------------------------------------------------
# serving: prefill + decode
# --------------------------------------------------------------------------
def init_cache(cfg, batch: int, max_len: int, rt, dtype=None):
    dtype = dtype or cfg.np_dtype
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def prefill(params, tokens, cfg, rt, *, embeds=None, max_len: int | None = None):
    """Run the prompt, return (last-position logits, filled cache).

    ``max_len`` pads the KV cache's sequence axis so ``decode_step`` can
    append up to ``max_len - prompt_len`` generated tokens."""
    x = L.embed(params["embed"], tokens, cfg)
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    x = rt.constrain(x, *rt.act_spec(3))
    x, aux, kvs = _scan_blocks(params, x, cfg, rt, return_kv=True)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    last = x[:, -1:, :]
    logits = L.unembed(params["embed"], params.get("head"), last, cfg)
    k, v = kvs
    if max_len is not None and max_len > k.shape[2]:
        pad = max_len - k.shape[2]  # k/v: (L, B, S, Hkv, hd)
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {"k": k, "v": v,
             "len": jnp.asarray(x.shape[1], jnp.int32)}
    return logits, cache


def decode_step(params, cache, tokens, cfg, rt):
    """tokens (B,1) -> (logits (B,1,V), cache). Scans layers, carries x."""
    x = jnp.take(params["embed"]["table"], tokens, axis=0)
    if cfg.pos_emb == "abs":
        x = x + lax.dynamic_slice_in_dim(
            params["embed"]["pos"], cache["len"], 1, axis=0)
    x = rt.constrain(x, *rt.act_spec(3))

    def body(x, inp):
        lp, ck, cv = inp
        x, nk, nv = block_decode(lp, x, cfg, rt, ck, cv, cache["len"])
        return x, (nk, nv)

    x, (nk, nv) = lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], params.get("head"), x, cfg)
    new_cache = {"k": nk, "v": nv, "len": cache["len"] + 1}
    return logits, new_cache
