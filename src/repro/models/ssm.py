"""Mamba2 (SSD — state-space duality) blocks, pure JAX.

Training/prefill uses the *chunked SSD* algorithm of Dao & Gu (2024): the
sequence is split into chunks of Q tokens; within a chunk the recurrence is
computed as a masked (decay-weighted) attention-like matmul (MXU-friendly),
across chunks a short ``lax.scan`` carries the (H, P, N) state.  Decode is the
O(1) recurrent step on the carried state — this is what makes the
``long_500k`` shape feasible where full attention is quadratic.

Shapes: d_inner = expand*d_model; H heads of headdim P (H*P = d_inner);
state size N (= cfg.ssm_state); G groups share B/C projections.

``repro.kernels.ssd_chunk`` is the Pallas TPU kernel for the intra-chunk
term; :func:`ssd_chunked` is its pure-jnp oracle (ref) and the default path.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from .layers import _dense_init, rms_norm

Params = dict[str, Any]


# --------------------------------------------------------------------------
# parameter init
# --------------------------------------------------------------------------
def init_mamba(key, cfg) -> Params:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    H = di // cfg.ssm_headdim
    G, N, K = cfg.n_ssm_groups, cfg.ssm_state, cfg.ssm_conv
    dt = cfg.np_dtype
    conv_dim = di + 2 * G * N
    ks = jax.random.split(key, 4)
    return {
        # fused in-projection: [z (di), x (di), B (G*N), C (G*N), dt (H)]
        "in_proj": _dense_init(ks[0], (d, 2 * di + 2 * G * N + H), dt),
        "conv_w": _dense_init(ks[1], (K, conv_dim), dt, scale=0.5),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "A_log": jnp.zeros((H,), jnp.float32),          # A = -exp(A_log)
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),               # skip connection
        "norm": {"scale": jnp.ones((di,), dt)},         # gated RMSNorm
        "out_proj": _dense_init(ks[2], (di, d), dt),
    }


def _split_in_proj(zxbcdt, cfg):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    G, N = cfg.n_ssm_groups, cfg.ssm_state
    H = di // cfg.ssm_headdim
    z = zxbcdt[..., :di]
    x = zxbcdt[..., di: 2 * di]
    Bm = zxbcdt[..., 2 * di: 2 * di + G * N]
    Cm = zxbcdt[..., 2 * di + G * N: 2 * di + 2 * G * N]
    dtr = zxbcdt[..., 2 * di + 2 * G * N:]
    return z, x, Bm, Cm, dtr, di, G, N, H


def _causal_conv(u, w, b):
    """Depthwise causal conv1d. u: (B,S,C), w: (K,C)."""
    K = w.shape[0]
    up = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(u)
    for i in range(K):  # K=4: unrolled adds, no conv primitive needed
        out = out + up[:, i: i + u.shape[1], :] * w[i]
    return out + b


# --------------------------------------------------------------------------
# chunked SSD (training / prefill)
# --------------------------------------------------------------------------
def ssd_chunked(xh, dt, A, Bm, Cm, *, chunk: int = 256, h0=None):
    """Chunked SSD scan.

    xh: (B,S,H,P) inputs; dt: (B,S,H) positive step sizes;
    A: (H,) negative decay rates; Bm/Cm: (B,S,G,N).
    Returns (y: (B,S,H,P), h_last: (B,H,P,N)).
    """
    B_, S, H, Pd = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Q = min(chunk, S)
    nc = -(-S // Q)
    pad = nc * Q - S
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))

    # chunked views: (B, nc, Q, ...)
    xc = xh.reshape(B_, nc, Q, H, Pd)
    dtc = dt.reshape(B_, nc, Q, H)
    Bc = Bm.reshape(B_, nc, Q, G, N)
    Cc = Cm.reshape(B_, nc, Q, G, N)

    la = dtc * A  # (B,nc,Q,H) log-decay per step (A<0)
    cum = jnp.cumsum(la, axis=2)                      # inclusive within chunk
    dtx = xc * dtc[..., None]                         # dt-scaled inputs

    # ---- intra-chunk: masked decay attention  y[i] += C_i.B_j e^{cum_i-cum_j} dtx_j
    Bh = jnp.repeat(Bc, rep, axis=3)                  # (B,nc,Q,H,N)
    Ch = jnp.repeat(Cc, rep, axis=3)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Ch, Bh,
                        preferred_element_type=jnp.float32)
    # decay(i,j) = exp(cum_i - cum_j), lower-triangular (j <= i)
    cum_h = cum.transpose(0, 1, 3, 2)                 # (B,nc,H,Q)
    dmat = cum_h[..., :, None] - cum_h[..., None, :]  # (B,nc,H,Q,Q)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    dmat = jnp.where(tri, dmat, -jnp.inf)
    scores = scores * jnp.exp(dmat)
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", scores.astype(xh.dtype),
                         dtx, preferred_element_type=jnp.float32)

    # ---- chunk summary states: S_c = sum_j e^{cumQ - cum_j} B_j (x) dtx_j
    wj = jnp.exp(cum_h[..., -1:] - cum_h)             # (B,nc,H,Q)
    states = jnp.einsum("bchq,bcqhn,bcqhp->bchpn",
                        wj.astype(xh.dtype), Bh, dtx,
                        preferred_element_type=jnp.float32)  # (B,nc,H,P,N)
    alpha = jnp.exp(cum_h[..., -1])                   # (B,nc,H) total chunk decay

    # ---- inter-chunk recurrence over nc (small): h_c = alpha_c h_{c-1} + S_c
    def step(h, inp):
        a_c, s_c = inp                                # (B,H), (B,H,P,N)
        h = h * a_c[..., None, None] + s_c
        return h, h

    h_init = (jnp.zeros((B_, H, Pd, N), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))
    hs_last, hs = lax.scan(step, h_init,
                           (alpha.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4)))
    hs = hs.transpose(1, 0, 2, 3, 4)                  # (B,nc,H,P,N) post-chunk states
    h_prev = jnp.concatenate([h_init[:, None], hs[:, :-1]], axis=1)

    # ---- inter-chunk contribution: y[i] += C_i . (e^{cum_i} h_prev)
    win = jnp.exp(cum_h)                              # (B,nc,H,Q)
    y_inter = jnp.einsum("bcqhn,bchpn,bchq->bcqhp",
                         Ch, h_prev.astype(xh.dtype),
                         win.astype(xh.dtype),
                         preferred_element_type=jnp.float32)

    y = (y_intra + y_inter).astype(xh.dtype).reshape(B_, nc * Q, H, Pd)
    return y[:, :S], hs_last


def mamba_fwd(params: Params, x, cfg, *, chunk: int = 256,
              return_state: bool = False):
    """Full Mamba2 block. x: (B,S,D) -> (B,S,D) [, decode cache]."""
    B_, S, _ = x.shape
    z, xs, Bm, Cm, dtr, di, G, N, H = _split_in_proj(x @ params["in_proj"], cfg)
    P_ = cfg.ssm_headdim
    # causal conv over [x, B, C]
    xbc_raw = jnp.concatenate([xs, Bm, Cm], axis=-1)
    xbc = jax.nn.silu(_causal_conv(xbc_raw, params["conv_w"], params["conv_b"]))
    xs, Bm, Cm = xbc[..., :di], xbc[..., di:di + G * N], xbc[..., di + G * N:]

    dt = jax.nn.softplus(dtr.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    A = -jnp.exp(params["A_log"])                     # (H,)
    xh = xs.reshape(B_, S, H, P_)
    y, h_last = ssd_chunked(xh, dt, A, Bm.reshape(B_, S, G, N),
                            Cm.reshape(B_, S, G, N), chunk=chunk)
    y = y + xh * params["D"][None, None, :, None].astype(xh.dtype)
    y = y.reshape(B_, S, di)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = y @ params["out_proj"]
    if return_state:
        K = cfg.ssm_conv
        conv = xbc_raw[:, -(K - 1):, :] if S >= K - 1 else jnp.pad(
            xbc_raw, ((0, 0), (K - 1 - S, 0), (0, 0)))
        return out, {"conv": conv, "ssm": h_last}
    return out


# --------------------------------------------------------------------------
# recurrent decode step
# --------------------------------------------------------------------------
def init_mamba_cache(cfg, batch: int, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    H = di // cfg.ssm_headdim
    G, N, K = cfg.n_ssm_groups, cfg.ssm_state, cfg.ssm_conv
    return {
        "conv": jnp.zeros((batch, K - 1, di + 2 * G * N), dtype),
        "ssm": jnp.zeros((batch, H, cfg.ssm_headdim, N), jnp.float32),
    }


def mamba_step(params: Params, x, cache, cfg):
    """One-token recurrent step. x: (B,1,D). Returns (y, new_cache)."""
    B_ = x.shape[0]
    z, xs, Bm, Cm, dtr, di, G, N, H = _split_in_proj(x @ params["in_proj"], cfg)
    P_ = cfg.ssm_headdim
    xbc = jnp.concatenate([xs, Bm, Cm], axis=-1)[:, 0]        # (B,C)
    hist = jnp.concatenate([cache["conv"], xbc[:, None]], axis=1)  # (B,K,C)
    conv_out = jnp.einsum("bkc,kc->bc", hist.astype(jnp.float32),
                          params["conv_w"].astype(jnp.float32))
    xbc_f = jax.nn.silu(conv_out + params["conv_b"].astype(jnp.float32)
                        ).astype(x.dtype)
    xs1, Bm1, Cm1 = (xbc_f[:, :di], xbc_f[:, di:di + G * N],
                     xbc_f[:, di + G * N:])
    dt = jax.nn.softplus(dtr[:, 0].astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    a = jnp.exp(dt * A)                                       # (B,H)
    xh = xs1.reshape(B_, H, P_)
    Bh = jnp.repeat(Bm1.reshape(B_, G, N), H // G, axis=1)    # (B,H,N)
    Ch = jnp.repeat(Cm1.reshape(B_, G, N), H // G, axis=1)
    dtx = xh * dt[..., None]
    h = cache["ssm"] * a[..., None, None] + jnp.einsum(
        "bhp,bhn->bhpn", dtx.astype(jnp.float32), Bh.astype(jnp.float32))
    y = jnp.einsum("bhpn,bhn->bhp", h, Ch.astype(jnp.float32))
    y = y.astype(x.dtype) + xh * params["D"][None, :, None].astype(x.dtype)
    y = y.reshape(B_, 1, di)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    new_cache = {"conv": hist[:, 1:].astype(cache["conv"].dtype), "ssm": h}
    return y @ params["out_proj"], new_cache
