"""Mixture-of-Experts layer with expert parallelism.

Two execution strategies with identical math (validated against each other):

* ``local``   — single-shard gather/scatter dispatch; runs anywhere under
  plain ``jit`` (CPU smoke tests, tiny configs).
* ``ep``      — ``jax.shard_map`` over the mesh: tokens are sharded over the
  data axes and *replicated* over the EP axis; experts are sharded over the
  EP axis.  Each EP shard locally selects the tokens routed to its own
  experts (no dispatch all-to-all needed because activations are already
  replicated across EP), computes them, scatters partial outputs, and one
  ``psum`` over the EP axis combines — the same collective footprint as a
  dense tensor-parallel MLP.  An all-to-all dispatch variant
  (``ep_a2a``) trades the psum for two all-to-alls; see
  EXPERIMENTS.md §Perf for when each wins.

Capacity-based dropless-ish routing: per-shard capacity
``C = ceil(top_k * n_tokens * cf / n_experts)``; overflow tokens are dropped
(standard GShard/Switch semantics), with an auxiliary load-balancing loss.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P
from ..compat import shard_map

from .layers import _dense_init

Params = dict[str, Any]


def init_moe(key, cfg) -> Params:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    dt = cfg.np_dtype
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(ks[0], (d, e), jnp.float32, scale=0.02),
        "wg": _dense_init(ks[1], (e, d, f), dt),
        "wu": _dense_init(ks[2], (e, d, f), dt),
        "wd": _dense_init(ks[3], (e, f, d), dt),
    }
    if cfg.n_shared_experts:
        fs = cfg.moe_d_ff * cfg.n_shared_experts
        p["shared"] = {
            "wg": _dense_init(ks[4], (d, fs), dt),
            "wu": _dense_init(jax.random.fold_in(ks[4], 1), (d, fs), dt),
            "wd": _dense_init(jax.random.fold_in(ks[4], 2), (fs, d), dt),
        }
    return p


def _route(xf, router_w, cfg):
    """Router: top-k expert ids + normalised gates + aux load-balance loss."""
    logits = (xf.astype(jnp.float32) @ router_w)            # (n, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = lax.top_k(probs, cfg.experts_per_token)    # (n, k)
    if cfg.norm_topk_prob:
        gates = gates / (gates.sum(-1, keepdims=True) + 1e-9)
    # Switch-style aux loss: E * sum_e f_e * p_e
    e = cfg.n_experts
    chosen = jax.nn.one_hot(eidx[:, 0], e, dtype=jnp.float32)  # top-1 counts
    f_e = chosen.mean(0)
    p_e = probs.mean(0)
    aux = e * jnp.sum(f_e * p_e)
    return eidx, gates, aux


def _expert_ffn(x_ecd, wg, wu, wd):
    """Grouped SwiGLU over (E, C, d) with per-expert weights (E, d, f)."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x_ecd, wg)) * jnp.einsum(
        "ecd,edf->ecf", x_ecd, wu)
    return jnp.einsum("ecf,efd->ecd", h, wd)


def _dispatch_compute_combine(xf, eidx, gates, wg, wu, wd, *, e0, e_local, cap):
    """Shared local dispatch kernel. xf:(n,d); experts [e0, e0+e_local)."""
    n, d = xf.shape
    k = eidx.shape[1]
    flat_e = eidx.reshape(-1) - e0                      # (n*k,)
    flat_g = gates.reshape(-1)
    tok = jnp.repeat(jnp.arange(n), k)
    local = (flat_e >= 0) & (flat_e < e_local)
    e_c = jnp.where(local, flat_e, e_local)             # park non-local
    # position within expert, computed over the flattened assignment order
    oh = jax.nn.one_hot(e_c, e_local, dtype=jnp.int32)  # (n*k, E_l)
    pos = jnp.take_along_axis(
        jnp.cumsum(oh, axis=0), jnp.clip(e_c, 0, e_local - 1)[:, None], axis=1
    )[:, 0] - 1
    keep = local & (pos < cap)
    # out-of-bounds scatter indices are dropped under jit -> park at e_local
    e_s = jnp.where(keep, e_c, e_local)
    x_disp = jnp.zeros((e_local, cap, d), xf.dtype).at[e_s, pos].set(xf[tok])
    y_ecd = _expert_ffn(x_disp, wg, wu, wd)
    # combine: gather each assignment's output, weight by its gate.  The gate
    # is cast *first* so the (n*k, d) gather stays in the activation dtype —
    # an f32 promotion here doubles the largest MoE buffer (§Perf).
    contrib = y_ecd[jnp.clip(e_s, 0, e_local - 1), pos]  # reads clip; masked below
    gate = (flat_g * keep).astype(contrib.dtype)
    contrib = contrib * gate[:, None]
    return jnp.zeros((n, d), xf.dtype).at[tok].add(contrib)


def _capacity(n_tokens: int, cfg) -> int:
    c = math.ceil(cfg.experts_per_token * n_tokens * cfg.capacity_factor
                  / cfg.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8 for TPU lane alignment


def moe_local(params: Params, x, cfg):
    """Single-shard MoE. x: (B,S,D) -> (y, aux_loss)."""
    B, S, D = x.shape
    xf = x.reshape(B * S, D)
    eidx, gates, aux = _route(xf, params["router"], cfg)
    cap = _capacity(B * S, cfg)
    y = _dispatch_compute_combine(
        xf, eidx, gates, params["wg"], params["wu"], params["wd"],
        e0=0, e_local=cfg.n_experts, cap=cap)
    y = y.reshape(B, S, D)
    if cfg.n_shared_experts:
        sp = params["shared"]
        y = y + (jax.nn.silu(x @ sp["wg"]) * (x @ sp["wu"])) @ sp["wd"]
    return y, aux


def moe_ep(params: Params, x, cfg, mesh, *, ep_axis: str, dp_axes: tuple[str, ...]):
    """Expert-parallel MoE via shard_map (see module docstring)."""
    e_local = -(-cfg.n_experts // mesh.shape[ep_axis])

    def local_fn(x_l, router_w, wg, wu, wd):
        B, S, D = x_l.shape
        xf = x_l.reshape(B * S, D)
        eidx, gates, aux = _route(xf, router_w, cfg)
        cap = _capacity(B * S, cfg)
        e0 = lax.axis_index(ep_axis) * e_local
        y = _dispatch_compute_combine(
            xf, eidx, gates, wg, wu, wd, e0=e0, e_local=e_local, cap=cap)
        y = lax.psum(y, ep_axis)                 # combine expert partials
        aux = lax.pmean(aux, dp_axes) if dp_axes else aux
        return y.reshape(B, S, D), aux

    xs = P(*([dp_axes] + [None] * (x.ndim - 1)))
    y, aux = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(xs, P(), P(ep_axis), P(ep_axis), P(ep_axis)),
        out_specs=(xs, P()),
    )(x, params["router"], params["wg"], params["wu"], params["wd"])
    if cfg.n_shared_experts:
        sp = params["shared"]
        y = y + (jax.nn.silu(x @ sp["wg"]) * (x @ sp["wu"])) @ sp["wd"]
    return y, aux


def moe_ep_a2a(params: Params, x, cfg, mesh, *, ep_axis: str,
               dp_axes: tuple[str, ...]):
    """All-to-all dispatch variant (DeepSpeed-MoE style).

    Tokens stay sharded over ``dp_axes`` *and* the EP axis (the EP axis acts
    as an extra data dimension pre-dispatch).  Each shard routes its own
    tokens, builds an (E, C_l, d) dispatch tensor, and two ``all_to_all``
    exchanges move token blocks to/from the shard owning each expert.
    Collective bytes per layer: 2 * k * cf * tokens_local * d  (vs. a full
    (n, d) psum for :func:`moe_ep`) — the beyond-paper optimisation logged in
    EXPERIMENTS.md §Perf.

    Tokens are sharded over ``dp_axes`` (batch) and ``ep_axis`` (sequence),
    so each shard routes only S/ep of the sequence before the exchange.
    """
    ep = mesh.shape[ep_axis]
    e_local = -(-cfg.n_experts // ep)

    def local_fn(x_l, router_w, wg, wu, wd):
        B, S, D = x_l.shape
        n = B * S
        xf = x_l.reshape(n, D)
        eidx, gates, aux = _route(xf, router_w, cfg)
        cap = _capacity(n, cfg)
        k = eidx.shape[1]
        flat_e = eidx.reshape(-1)
        flat_g = gates.reshape(-1)
        tok = jnp.repeat(jnp.arange(n), k)
        # position of each assignment within its (global) expert bucket
        oh = jax.nn.one_hot(flat_e, cfg.n_experts, dtype=jnp.int32)
        pos = jnp.take_along_axis(jnp.cumsum(oh, axis=0), flat_e[:, None],
                                  axis=1)[:, 0] - 1
        keep = pos < cap
        e_s = jnp.where(keep, flat_e, cfg.n_experts)
        x_disp = jnp.zeros((cfg.n_experts, cap, D), xf.dtype).at[e_s, pos].set(
            xf[tok])
        # (E, C, d) = (ep, e_local, C, d); a2a over dim 0 sends each expert
        # block to the shard that owns it and gathers the ep source shards.
        x_disp = x_disp.reshape(ep, e_local, cap, D)
        x_recv = lax.all_to_all(x_disp, ep_axis, split_axis=0, concat_axis=0,
                                tiled=True)          # (ep, e_local, C, d)
        # my e_local experts each see ep*C candidate tokens
        x_mine = x_recv.transpose(1, 0, 2, 3).reshape(e_local, ep * cap, D)
        y_mine = _expert_ffn(x_mine, wg, wu, wd)
        y_send = y_mine.reshape(e_local, ep, cap, D).transpose(1, 0, 2, 3)
        y_back = lax.all_to_all(y_send, ep_axis, split_axis=0, concat_axis=0,
                                tiled=True).reshape(cfg.n_experts, cap, D)
        contrib = y_back[jnp.clip(e_s, 0, cfg.n_experts - 1), pos]
        gate = (flat_g * keep).astype(contrib.dtype)
        contrib = contrib * gate[:, None]
        y = jnp.zeros((n, D), xf.dtype).at[tok].add(contrib)
        aux = lax.pmean(aux, dp_axes + (ep_axis,))
        return y.reshape(B, S, D), aux

    # tokens sharded over dp axes (batch) AND the EP axis (sequence): each
    # shard routes only its own S/ep slice, then a2a moves expert blocks.
    xs = P(dp_axes, ep_axis, None)
    y, aux = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(xs, P(), P(ep_axis), P(ep_axis), P(ep_axis)),
        out_specs=(xs, P()),
    )(x, params["router"], params["wg"], params["wu"], params["wd"])
    if cfg.n_shared_experts:
        sp = params["shared"]
        y = y + (jax.nn.silu(x @ sp["wg"]) * (x @ sp["wu"])) @ sp["wd"]
    return y, aux


def moe_fwd(params: Params, x, cfg, rt):
    """Dispatch on the runtime's MoE implementation choice."""
    if rt.moe_impl == "local" or rt.mesh is None:
        return moe_local(params, x, cfg)
    ep = rt.mesh.shape.get(rt.ep_axis, 1) if rt.ep_axis else 1
    if rt.moe_impl == "ep_a2a" and x.shape[1] % max(ep, 1) == 0:
        return moe_ep_a2a(params, x, cfg, rt.mesh, ep_axis=rt.ep_axis,
                          dp_axes=rt.dp_axes)
    # psum variant — also the decode fallback (a2a needs S divisible by EP;
    # a one-token step can't sequence-shard)
    return moe_ep(params, x, cfg, rt.mesh, ep_axis=rt.ep_axis,
                  dp_axes=rt.dp_axes)
