"""Runtime: how a model executes on a mesh (orthogonal to ModelConfig).

``ModelConfig`` says *what* the network is; ``Runtime`` says *how* it runs —
which mesh axes exist, which MoE dispatch strategy, attention path, remat.
The launcher builds one from a :class:`repro.launch.plans.ParallelPlan`.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class Runtime:
    mesh: Any = None                    # jax.sharding.Mesh | None
    dp_axes: tuple[str, ...] = ()       # batch-sharding axes ("pod","data")
    tp_axis: str | None = None          # tensor-parallel axis ("model")
    ep_axis: str | None = None          # expert-parallel axis (defaults tp)
    moe_impl: str = "local"             # local | ep | ep_a2a
    attn_mode: str = "auto"             # dense | chunked | auto
    remat: bool = False
    remat_group: int = 1                # layers per remat block (g>1: save
                                        # only every g-th residual — trades
                                        # recompute for HBM, see §Perf)
    act_shard: str = "none"             # none | seq — Megatron-SP-style
                                        # residual-stream sharding over tp
    ssd_chunk: int = 256
    loss_chunk: int = 0                 # 0 = unchunked cross-entropy

    def constrain(self, x, *spec):
        """with_sharding_constraint if a mesh is attached, else no-op."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, P(*spec)))

    def act_spec(self, ndim: int):
        """Activation spec for the (B, S, ...) residual stream: batch over dp
        axes; sequence over tp when act_shard == 'seq' (the saved remat
        residuals shrink by the tp width; XLA re-gathers at use sites)."""
        seq = (self.tp_axis if (self.act_shard == "seq" and self.tp_axis)
               else None)
        if ndim < 2:
            return (self.dp_axes,) + (None,) * (ndim - 1)
        return (self.dp_axes, seq) + (None,) * (ndim - 2)


LOCAL = Runtime()
