"""Mamba2 LM (attention-free) and Zamba2-style hybrid LM.

Zamba2 layout: ``n_layers`` Mamba2 blocks; after every ``attn_every``-th
block, one *shared* (weight-tied) attention+MLP block is applied.  The stack
is scanned in groups so the shared block appears once in the HLO:

    outer scan over G groups { inner scan over `attn_every` mamba blocks;
                               shared attn block }   + scanned tail blocks
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from . import layers as L
from .ssm import (init_mamba, init_mamba_cache, mamba_fwd, mamba_step)


# --------------------------------------------------------------------------
# blocks
# --------------------------------------------------------------------------
def init_mamba_block(key, cfg):
    return {
        "ln": L.init_rmsnorm(cfg.d_model, cfg.np_dtype),
        "mixer": init_mamba(key, cfg),
    }


def mamba_block_fwd(p, x, cfg, rt):
    return x + mamba_fwd(p["mixer"], L.rms_norm(x, p["ln"], cfg.norm_eps),
                         cfg, chunk=rt.ssd_chunk)


def init_shared_attn_block(key, cfg):
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.init_rmsnorm(cfg.d_model, cfg.np_dtype),
        "attn": L.init_attention(ks[0], cfg),
        "ln2": L.init_rmsnorm(cfg.d_model, cfg.np_dtype),
        "mlp": L.init_mlp(ks[1], cfg),
    }


def shared_attn_fwd(p, x, cfg, rt):
    x = x + L.attention_fwd(p["attn"], L.rms_norm(x, p["ln1"], cfg.norm_eps),
                            cfg, mode=rt.attn_mode, rt=rt)
    x = x + L.mlp_fwd(p["mlp"], L.rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
    return rt.constrain(x, *rt.act_spec(3))


def _group_split(cfg) -> tuple[int, int]:
    """(#full groups, #tail layers) for the hybrid layout."""
    if not cfg.attn_every:
        return 0, cfg.n_layers
    g = cfg.n_layers // cfg.attn_every
    return g, cfg.n_layers - g * cfg.attn_every


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------
def init(key, cfg):
    k_emb, k_body, k_shared, k_head = jax.random.split(key, 4)
    params = {
        "embed": L.init_embedding(k_emb, cfg),
        "final_norm": L.init_rmsnorm(cfg.d_model, cfg.np_dtype),
    }
    g, tail = _group_split(cfg)
    keys = jax.random.split(k_body, cfg.n_layers)
    if g:
        gk = keys[: g * cfg.attn_every].reshape(g, cfg.attn_every)
        params["groups"] = jax.vmap(jax.vmap(lambda k: init_mamba_block(k, cfg)))(gk)
        params["shared"] = init_shared_attn_block(k_shared, cfg)
    if tail:
        params["tail"] = jax.vmap(lambda k: init_mamba_block(k, cfg))(
            keys[cfg.n_layers - tail:])
    head = L.init_lm_head(k_head, cfg)
    if head is not None:
        params["head"] = head
    return params


# --------------------------------------------------------------------------
# forward / loss
# --------------------------------------------------------------------------
def _backbone(params, x, cfg, rt):
    def mamba_body(x, lp):
        return mamba_block_fwd(lp, x, cfg, rt), None

    def plain_body(x, lp):
        return mamba_block_fwd(lp, x, cfg, rt), None

    if rt.remat:
        mamba_body = jax.checkpoint(mamba_body, prevent_cse=False)

    if "groups" in params:
        def group_body(x, gp):
            x, _ = lax.scan(mamba_body, x, gp)
            x = shared_attn_fwd(params["shared"], x, cfg, rt)
            return x, None
        if rt.remat:
            group_body = jax.checkpoint(group_body, prevent_cse=False)
        x, _ = lax.scan(group_body, x, params["groups"])
    if "tail" in params:
        n_tail = jax.tree.leaves(params["tail"])[0].shape[0]
        g = rt.remat_group if rt.remat else 1
        if rt.remat and g > 1 and n_tail % g == 0:
            # grouped remat (see transformer._scan_blocks)
            grouped = jax.tree.map(
                lambda a: a.reshape((n_tail // g, g) + a.shape[1:]),
                params["tail"])

            def tail_group(x, gp):
                x, _ = lax.scan(plain_body, x, gp)
                return x, None

            tail_group = jax.checkpoint(tail_group, prevent_cse=False)
            x, _ = lax.scan(tail_group, x, grouped)
        else:
            x, _ = lax.scan(mamba_body, x, params["tail"])
    return x


def forward(params, tokens, cfg, rt, *, embeds=None):
    x = L.embed(params["embed"], tokens, cfg)
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    x = rt.constrain(x, *rt.act_spec(3))
    x = _backbone(params, x, cfg, rt)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], params.get("head"), x, cfg)
    return logits, jnp.zeros((), jnp.float32)


def loss(params, batch, cfg, rt):
    from .transformer import chunked_xent, cross_entropy  # shared helpers
    tokens, labels = batch["tokens"], batch["labels"]
    if rt.loss_chunk:
        x = L.embed(params["embed"], tokens, cfg)
        x = rt.constrain(x, *rt.act_spec(3))
        x = _backbone(params, x, cfg, rt)
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        nll = chunked_xent(x, params, labels, cfg, rt, batch.get("mask"))
    else:
        logits, _ = forward(params, tokens, cfg, rt)
        nll = cross_entropy(logits, labels, batch.get("mask"))
    return nll, {"nll": nll, "aux": jnp.zeros((), jnp.float32)}


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------
def init_cache(cfg, batch: int, max_len: int, rt, dtype=None):
    dtype = dtype or cfg.np_dtype
    g, tail = _group_split(cfg)
    one = init_mamba_cache(cfg, batch, dtype)
    cache = {"len": jnp.zeros((), jnp.int32)}
    if g:
        cache["groups"] = jax.tree.map(
            lambda a: jnp.zeros((g, cfg.attn_every) + a.shape, a.dtype), one)
        hd = cfg.head_dim
        cache["shared_k"] = jnp.zeros((g, batch, max_len, cfg.n_kv_heads, hd), dtype)
        cache["shared_v"] = jnp.zeros((g, batch, max_len, cfg.n_kv_heads, hd), dtype)
    if tail:
        cache["tail"] = jax.tree.map(
            lambda a: jnp.zeros((tail,) + a.shape, a.dtype), one)
    return cache


def decode_step(params, cache, tokens, cfg, rt):
    """tokens (B,1) -> (logits, cache). O(1) state for mamba blocks; the
    shared attention block (hybrid) reads its per-invocation KV cache."""
    x = jnp.take(params["embed"]["table"], tokens, axis=0)

    def mamba_body(x, inp):
        lp, c = inp
        h = L.rms_norm(x, lp["ln"], cfg.norm_eps)
        y, nc = mamba_step(lp["mixer"], h, c, cfg)
        return x + y, nc

    new_cache = {"len": cache["len"] + 1}
    if "groups" in params:
        def group_body(x, inp):
            gp, gc, ck, cv = inp
            x, nc = lax.scan(mamba_body, x, (gp, gc))
            h = L.rms_norm(x, params["shared"]["ln1"], cfg.norm_eps)
            att, nk, nv = L.attention_decode(params["shared"]["attn"], h, cfg,
                                             ck, cv, cache["len"])
            x = x + att
            x = x + L.mlp_fwd(params["shared"]["mlp"],
                              L.rms_norm(x, params["shared"]["ln2"], cfg.norm_eps), cfg)
            return x, (nc, nk, nv)

        x, (ncg, nk, nv) = lax.scan(
            group_body, x,
            (params["groups"], cache["groups"], cache["shared_k"], cache["shared_v"]))
        new_cache["groups"] = ncg
        new_cache["shared_k"] = nk
        new_cache["shared_v"] = nv
    if "tail" in params:
        x, nct = lax.scan(mamba_body, x, (params["tail"], cache["tail"]))
        new_cache["tail"] = nct
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], params.get("head"), x, cfg)
    return logits, new_cache


def prefill(params, tokens, cfg, rt, *, max_len: int | None = None):
    """Prompt pass -> (last logits, cache).  Chunked SSD already produces the
    final recurrent state per block (``h_last``) and the conv cache is the
    last K-1 pre-conv activations, so the cache is exact."""
    x = jnp.take(params["embed"]["table"], tokens, axis=0)
    x = rt.constrain(x, *rt.act_spec(3))
    S = tokens.shape[1]

    def mamba_body(x, lp):
        h = L.rms_norm(x, lp["ln"], cfg.norm_eps)
        y, st = mamba_fwd(lp["mixer"], h, cfg, chunk=rt.ssd_chunk,
                          return_state=True)
        return x + y, st

    cache = {"len": jnp.asarray(S, jnp.int32)}
    if "groups" in params:
        def group_body(x, gp):
            x, st = lax.scan(mamba_body, x, gp)
            # shared attn with KV capture
            h = L.rms_norm(x, params["shared"]["ln1"], cfg.norm_eps)
            B = h.shape[0]
            q, k, v = L._qkv(params["shared"]["attn"], h, cfg)
            pos = jnp.arange(S)
            if cfg.pos_emb == "rope":
                cos, sin = L.rope_angles(pos, cfg.head_dim, cfg.rope_theta)
                q = L.apply_rope(q, cos, sin)
                k = L.apply_rope(k, cos, sin)
            if rt.attn_mode == "chunked" or (rt.attn_mode == "auto"
                                             and S > 2048):
                o = L.chunked_attention(q, k, v, causal=True,
                                        window=cfg.sliding_window, rt=rt)
            else:
                o = L.dense_attention(q, k, v, causal=True,
                                      window=cfg.sliding_window)
            o = o.reshape(B, S, cfg.n_heads * cfg.head_dim)
            x = x + o @ params["shared"]["attn"]["wo"]
            x = x + L.mlp_fwd(params["shared"]["mlp"],
                              L.rms_norm(x, params["shared"]["ln2"], cfg.norm_eps),
                              cfg)
            return x, (st, k, v)

        x, (gst, ks, vs) = lax.scan(group_body, x, params["groups"])
        if max_len is not None and max_len > ks.shape[2]:
            pad = max_len - ks.shape[2]  # (G, B, S, Hkv, hd)
            ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        cache["groups"] = gst
        cache["shared_k"] = ks
        cache["shared_v"] = vs
    if "tail" in params:
        x, tst = lax.scan(mamba_body, x, params["tail"])
        cache["tail"] = tst
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], params.get("head"), x[:, -1:], cfg)
    return logits, cache
