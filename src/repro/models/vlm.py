"""InternVL2-style VLM: stubbed ViT frontend + LM backbone.

Per the assignment, the vision frontend is a STUB: ``input_specs`` provides
precomputed patch embeddings (B, n_patches, frontend_dim); a learned
projector maps them into the backbone's embedding space.  ``seq_len`` counts
*backbone* tokens: n_patches image tokens + (seq_len - n_patches) text.
Loss is computed on text positions only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from . import transformer as T


def init(key, cfg):
    k_proj, k_lm = jax.random.split(key)
    params = T.init(k_lm, cfg)
    params["projector"] = {
        "w": L._dense_init(k_proj, (cfg.frontend_dim, cfg.d_model), cfg.np_dtype),
        "b": jnp.zeros((cfg.d_model,), cfg.np_dtype),
    }
    return params


def _project(params, patches, cfg):
    return patches.astype(cfg.np_dtype) @ params["projector"]["w"] + \
        params["projector"]["b"]


def forward(params, batch, cfg, rt):
    embeds = _project(params, batch["patches"], cfg)
    return T.forward(params, batch["tokens"], cfg, rt, embeds=embeds)


def loss(params, batch, cfg, rt):
    """batch: {patches (B,P,F), tokens (B,S_text), labels (B,S_text)}."""
    logits, aux = forward(params, batch, cfg, rt)
    text_logits = logits[:, batch["patches"].shape[1]:, :]
    nll = T.cross_entropy(text_logits, batch["labels"], batch.get("mask"))
    total = nll + cfg.aux_loss_coef * aux
    return total, {"nll": nll, "aux": aux}


def init_cache(cfg, batch: int, max_len: int, rt, dtype=None):
    return T.init_cache(cfg, batch, max_len, rt, dtype)


def prefill(params, batch, cfg, rt, *, max_len: int | None = None):
    embeds = _project(params, batch["patches"], cfg)
    return T.prefill(params, batch["tokens"], cfg, rt, embeds=embeds,
                     max_len=max_len)


def decode_step(params, cache, tokens, cfg, rt):
    return T.decode_step(params, cache, tokens, cfg, rt)
