"""Whisper-style encoder-decoder (audio backbone; conv frontend stubbed).

Inputs per the assignment: the modality frontend is a STUB — ``input_specs``
hands *precomputed frame embeddings* (B, S_enc, frontend_dim); a learned
linear adapter maps them to d_model.  Shape convention (DESIGN.md):
``seq_len`` is the encoder length; decoder length = seq_len // dec_ratio for
train/prefill and 1 (+cross-attn over seq_len encoder states) for decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from . import layers as L
from .transformer import cross_entropy


# --------------------------------------------------------------------------
# blocks
# --------------------------------------------------------------------------
def _init_enc_block(key, cfg):
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.init_rmsnorm(cfg.d_model, cfg.np_dtype),
        "attn": L.init_attention(ks[0], cfg),
        "ln2": L.init_rmsnorm(cfg.d_model, cfg.np_dtype),
        "mlp": L.init_mlp(ks[1], cfg),
    }


def _init_dec_block(key, cfg):
    ks = jax.random.split(key, 3)
    return {
        "ln1": L.init_rmsnorm(cfg.d_model, cfg.np_dtype),
        "attn": L.init_attention(ks[0], cfg),
        "lnx": L.init_rmsnorm(cfg.d_model, cfg.np_dtype),
        "xattn": L.init_attention(ks[1], cfg),
        "ln2": L.init_rmsnorm(cfg.d_model, cfg.np_dtype),
        "mlp": L.init_mlp(ks[2], cfg),
    }


def _enc_block_fwd(p, x, cfg, rt):
    x = x + L.attention_fwd(p["attn"], L.rms_norm(x, p["ln1"], cfg.norm_eps),
                            cfg, causal=False, mode=rt.attn_mode, rt=rt)
    x = x + L.mlp_fwd(p["mlp"], L.rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
    return rt.constrain(x, *rt.act_spec(3))


def _dec_block_fwd(p, x, enc_out, cfg, rt):
    x = x + L.attention_fwd(p["attn"], L.rms_norm(x, p["ln1"], cfg.norm_eps),
                            cfg, causal=True, mode=rt.attn_mode, rt=rt)
    x = x + L.cross_attention_fwd(p["xattn"],
                                  L.rms_norm(x, p["lnx"], cfg.norm_eps),
                                  enc_out, cfg)
    x = x + L.mlp_fwd(p["mlp"], L.rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
    return rt.constrain(x, *rt.act_spec(3))


# --------------------------------------------------------------------------
# model
# --------------------------------------------------------------------------
def init(key, cfg):
    ks = jax.random.split(key, 6)
    enc_keys = jax.random.split(ks[0], cfg.n_enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_dec_layers)
    params = {
        "adapter": {"w": L._dense_init(ks[2], (cfg.frontend_dim, cfg.d_model),
                                       cfg.np_dtype)},
        "enc_pos": L._dense_init(ks[3], (cfg.max_abs_positions, cfg.d_model),
                                 cfg.np_dtype, scale=0.02),
        "embed": L.init_embedding(ks[4], cfg),   # decoder tokens (+abs pos)
        "enc_layers": jax.vmap(lambda k: _init_enc_block(k, cfg))(enc_keys),
        "enc_norm": L.init_rmsnorm(cfg.d_model, cfg.np_dtype),
        "dec_layers": jax.vmap(lambda k: _init_dec_block(k, cfg))(dec_keys),
        "final_norm": L.init_rmsnorm(cfg.d_model, cfg.np_dtype),
    }
    head = L.init_lm_head(ks[5], cfg)
    if head is not None:
        params["head"] = head
    return params


def encode(params, frames, cfg, rt):
    """frames: (B, S_enc, frontend_dim) precomputed stub embeddings."""
    S = frames.shape[1]
    x = frames.astype(cfg.np_dtype) @ params["adapter"]["w"]
    x = x + lax.dynamic_slice_in_dim(params["enc_pos"], 0, S, axis=0)
    x = rt.constrain(x, *rt.act_spec(3))

    def body(x, lp):
        return _enc_block_fwd(lp, x, cfg, rt), None
    if rt.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = lax.scan(body, x, params["enc_layers"])
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def decode_train(params, enc_out, tokens, cfg, rt):
    x = L.embed(params["embed"], tokens, cfg)
    x = rt.constrain(x, *rt.act_spec(3))

    def body(x, lp):
        return _dec_block_fwd(lp, x, enc_out, cfg, rt), None
    if rt.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = lax.scan(body, x, params["dec_layers"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return L.unembed(params["embed"], params.get("head"), x, cfg)


def loss(params, batch, cfg, rt):
    """batch: {frames (B,S_enc,F), tokens (B,S_dec), labels (B,S_dec)}."""
    enc_out = encode(params, batch["frames"], cfg, rt)
    logits = decode_train(params, enc_out, batch["tokens"], cfg, rt)
    nll = cross_entropy(logits, batch["labels"], batch.get("mask"))
    return nll, {"nll": nll, "aux": jnp.zeros((), jnp.float32)}


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------
def init_cache(cfg, batch: int, max_len: int, rt, dtype=None, enc_len=None):
    """max_len: decoder self-attn capacity; enc_len: encoder states length."""
    dtype = dtype or cfg.np_dtype
    enc_len = enc_len or max_len
    hd = cfg.head_dim
    Ld = cfg.n_dec_layers
    return {
        "enc_out": jnp.zeros((batch, enc_len, cfg.d_model), dtype),
        "k": jnp.zeros((Ld, batch, max_len, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((Ld, batch, max_len, cfg.n_kv_heads, hd), dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def prefill(params, batch, cfg, rt, *, max_len: int | None = None):
    """Encode frames + run decoder prompt -> (last logits, cache)."""
    enc_out = encode(params, batch["frames"], cfg, rt)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = L.embed(params["embed"], tokens, cfg)

    def body(x, lp):
        h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = L._qkv(lp["attn"], h, cfg)
        o = L.dense_attention(q, k, v, causal=True, window=None)
        x = x + o.reshape(B, S, -1) @ lp["attn"]["wo"]
        x = x + L.cross_attention_fwd(lp["xattn"],
                                      L.rms_norm(x, lp["lnx"], cfg.norm_eps),
                                      enc_out, cfg)
        x = x + L.mlp_fwd(lp["mlp"], L.rms_norm(x, lp["ln2"], cfg.norm_eps), cfg)
        return x, (k, v)

    x, (ks, vs) = lax.scan(body, x, params["dec_layers"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], params.get("head"), x[:, -1:], cfg)
    if max_len is not None and max_len > ks.shape[2]:
        pad = max_len - ks.shape[2]  # (Ld, B, S, Hkv, hd)
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {"enc_out": enc_out, "k": ks, "v": vs,
             "len": jnp.asarray(S, jnp.int32)}
    return logits, cache


def decode_step(params, cache, tokens, cfg, rt):
    """One decoder token; cross-attends the cached encoder states."""
    x = jnp.take(params["embed"]["table"], tokens, axis=0)
    x = x + lax.dynamic_slice_in_dim(params["embed"]["pos"], cache["len"], 1,
                                     axis=0)

    def body(x, inp):
        lp, ck, cv = inp
        h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        att, nk, nv = L.attention_decode(lp["attn"], h, cfg, ck, cv,
                                         cache["len"])
        x = x + att
        x = x + L.cross_attention_fwd(lp["xattn"],
                                      L.rms_norm(x, lp["lnx"], cfg.norm_eps),
                                      cache["enc_out"], cfg)
        x = x + L.mlp_fwd(lp["mlp"], L.rms_norm(x, lp["ln2"], cfg.norm_eps), cfg)
        return x, (nk, nv)

    x, (nk, nv) = lax.scan(body, x, (params["dec_layers"], cache["k"],
                                     cache["v"]))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], params.get("head"), x, cfg)
    return logits, {"enc_out": cache["enc_out"], "k": nk, "v": nv,
                    "len": cache["len"] + 1}
