"""Model zoo: the ten assigned architectures as composable JAX modules."""
from .registry import (ModelApi, cache_specs, get_model, input_specs,
                       param_specs)
from .runtime import LOCAL, Runtime

__all__ = ["LOCAL", "ModelApi", "Runtime", "cache_specs", "get_model",
           "input_specs", "param_specs"]
