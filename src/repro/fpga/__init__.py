from .archs import ARCH_NAMES, hybrid, make_arch, segmented, segmented_rr
from .boards import BOARD_NAMES, BOARDS, get_board

__all__ = [
    "ARCH_NAMES",
    "BOARD_NAMES",
    "BOARDS",
    "get_board",
    "hybrid",
    "make_arch",
    "segmented",
    "segmented_rr",
]
