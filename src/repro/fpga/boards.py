"""FPGA boards from paper Table II."""
from __future__ import annotations

from ..core.device import DeviceSpec, mib

BOARDS = {
    "zc706": DeviceSpec("zc706", pes=900, on_chip_bytes=mib(2.4), off_chip_gbps=3.2),
    "vcu108": DeviceSpec("vcu108", pes=768, on_chip_bytes=mib(7.6), off_chip_gbps=19.2),
    "vcu110": DeviceSpec("vcu110", pes=1800, on_chip_bytes=mib(4.0), off_chip_gbps=19.2),
    "zcu102": DeviceSpec("zcu102", pes=2520, on_chip_bytes=mib(16.6), off_chip_gbps=19.2),
}

BOARD_NAMES = tuple(BOARDS)

# the board used when none is named — the paper's main DSE target (XCp
# custom-family exploration, Fig. 10, runs on VCU110)
DEFAULT_BOARD = "vcu110"


def get_board(name: str = DEFAULT_BOARD) -> DeviceSpec:
    if name not in BOARDS:
        raise KeyError(f"unknown board {name!r}; known: {sorted(BOARDS)}")
    return BOARDS[name]
