"""The three state-of-the-art multiple-CE architecture templates (paper §II-C).

* Segmented    — Shen et al. [33]: n single-CE segments, coarse pipelining.
* SegmentedRR  — Wei et al. [41] tiling + Ma et al. [23] engines: one
                 pipelined-CEs block processing all layers round-robin.
* Hybrid       — Qararyah et al. [30] (FiBHA): n-1 per-layer pipelined CEs,
                 then one pooled CE for the rest, coarse pipelining between.
"""
from __future__ import annotations

from ..core.notation import AcceleratorSpec, SegmentSpec
from ..core.workload import Network

ARCH_NAMES = ("segmented", "segmented_rr", "hybrid")


def balanced_partition(weights: list[float], n: int) -> list[int]:
    """Contiguous partition of ``weights`` into n parts with near-equal sums.

    Returns the (exclusive) end index of each part.  Prefix-crossing
    heuristic: boundary i at the first prefix >= (i+1)/n of the total.
    """
    n = min(n, len(weights))
    total = sum(weights)
    bounds, acc, k = [], 0.0, 1
    for i, x in enumerate(weights):
        acc += x
        remaining_items = len(weights) - (i + 1)
        remaining_parts = n - k
        if (acc >= total * k / n and remaining_items >= remaining_parts) or (
            remaining_items == remaining_parts and len(bounds) < k
        ):
            if len(bounds) < k - 0:
                bounds.append(i + 1)
                k += 1
            if k > n - 1:
                break
    while len(bounds) < n - 1:  # degenerate fill
        bounds.append(min(len(weights) - (n - 1 - len(bounds)), len(weights) - 1))
    bounds.append(len(weights))
    return bounds


def segmented(net: Network, n_ces: int) -> AcceleratorSpec:
    """n single-CE segments, MAC-balanced, coarse (inter-segment) pipelining."""
    macs = [float(l.macs) for l in net]
    bounds = balanced_partition(macs, n_ces)
    segs, lo = [], 0
    for ce, hi in enumerate(bounds):
        segs.append(SegmentSpec(lo, hi - 1, ce, ce))
        lo = hi
    return AcceleratorSpec(
        name=f"segmented[{len(segs)}]",
        segments=tuple(segs),
        inter_segment_pipelining=True,
    )


def segmented_rr(net: Network, n_ces: int) -> AcceleratorSpec:
    """{L1-Last:CE1-CEn}: tile-grained pipelined round-robin block."""
    return AcceleratorSpec(
        name=f"segmented_rr[{n_ces}]",
        segments=(SegmentSpec(0, len(net) - 1, 0, n_ces - 1),),
        inter_segment_pipelining=False,
    )


def hybrid(net: Network, n_ces: int) -> AcceleratorSpec:
    """First n-1 layers on per-layer pipelined CEs; the rest on one big CE."""
    if n_ces < 2:
        raise ValueError("hybrid needs >= 2 CEs")
    first = n_ces - 1
    segs = (
        SegmentSpec(0, first - 1, 0, first - 1),
        SegmentSpec(first, len(net) - 1, first, first),
    )
    return AcceleratorSpec(
        name=f"hybrid[{n_ces}]",
        segments=segs,
        inter_segment_pipelining=True,
    )


def make_arch(arch: str, net: Network, n_ces: int) -> AcceleratorSpec:
    if arch == "segmented":
        return segmented(net, n_ces)
    if arch == "segmented_rr":
        return segmented_rr(net, n_ces)
    if arch == "hybrid":
        return hybrid(net, n_ces)
    raise KeyError(f"unknown architecture {arch!r}; known: {ARCH_NAMES}")
