"""Device abstraction under MCCM.

The paper instantiates the model on FPGA boards (PEs = DSPs, on-chip = BRAM,
off-chip = DDR).  The same record also carries the TPU instantiation used by
``repro.tpu`` (PEs = MXU lanes, on-chip = HBM per chip, off-chip = ICI), which
is how the cost model is hardware-adapted without changing its equations.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class DeviceSpec:
    """Resource budget the Builder distributes among CEs."""

    name: str
    pes: int                    # number of MAC units (DSPs on FPGA)
    on_chip_bytes: int          # BRAM capacity
    off_chip_gbps: float        # DRAM bandwidth, GB/s
    clock_hz: float = 2.0e8     # 200 MHz, typical of the cited HLS designs
    wordbytes: int = 1          # int8 weights/activations (FiBHA-style)

    @property
    def off_chip_bytes_per_cycle(self) -> float:
        return self.off_chip_gbps * 1e9 / self.clock_hz

    def macs_per_second(self) -> float:
        return self.pes * self.clock_hz


def mib(x: float) -> int:
    return int(x * 1024 * 1024)
