"""Multiple-CE Builder (paper §III-A): notation + CNN + board -> concrete accelerator.

Implements the resource-distribution heuristics the paper attributes to the
Builder module (inspired by [3], [23], [30], [33], [41]):

* **PEs** are distributed across all CEs proportionally to the MAC workload
  each CE is responsible for (largest-remainder rounding, >=1 PE each);
* **parallelism** per CE is the 3-D <filters, OFM-rows, OFM-cols> vector that
  minimises the CE's total cycles over its assigned layers (Ma et al. [23]);
* **buffers**: every block first receives a floor (minimal working tiles),
  inter-segment double buffers are placed on-chip smallest-first while they
  fit, and the remaining budget is distributed proportionally to each block's
  outstanding minimum-access requirement (Eq. 4 / Eq. 5), capped at it.
"""
from __future__ import annotations

from dataclasses import dataclass

from .accelerator import ConcreteAccelerator, ConcreteSegment
from .blocks import CE, best_parallelism, pipelined_min_buffer, single_ce_min_buffer
from .device import DeviceSpec
from .notation import AcceleratorSpec
from .workload import ConvLayer, Network


@dataclass
class BuilderOptions:
    fm_tile_rows: int = 2
    par_candidates: tuple[int, ...] | None = None


def _largest_remainder(shares: list[float], total: int, floor: int = 1) -> list[int]:
    """Distribute ``total`` integers proportionally to ``shares`` (>= floor)."""
    n = len(shares)
    total = max(total, n * floor)
    s = sum(shares) or 1.0
    raw = [max(x / s * total, floor) for x in shares]
    out = [max(int(r), floor) for r in raw]
    rem = total - sum(out)
    # hand out remaining units to the largest fractional remainders
    order = sorted(range(n), key=lambda i: raw[i] - int(raw[i]), reverse=True)
    i = 0
    while rem > 0 and n:
        out[order[i % n]] += 1
        rem -= 1
        i += 1
    while rem < 0 and n:  # over-allocated due to floors: take from largest
        j = max(range(n), key=lambda k: out[k])
        if out[j] > floor:
            out[j] -= 1
            rem += 1
        else:
            break
    return out


def _ce_layer_map(spec: AcceleratorSpec, net: Network) -> dict[int, list[ConvLayer]]:
    """Which layers each physical CE id processes (round-robin for pipelined).

    CEs with no layers (a pipelined block wider than its segment) are dead
    silicon: present with an empty list, allotted no resources."""
    assign: dict[int, list[ConvLayer]] = {}
    for seg in spec.segments:
        n_ces = seg.n_ces
        for ce_id in range(seg.ce_lo, seg.ce_hi + 1):
            assign.setdefault(ce_id, [])
        for k, li in enumerate(range(seg.layer_lo, seg.layer_hi + 1)):
            ce_id = seg.ce_lo + (k % n_ces)
            assign[ce_id].append(net[li])
    return assign


def _wtile_bytes(layer: ConvLayer, par_f: int, wb: int) -> int:
    c = 1 if layer.kind == "dw" else layer.in_ch
    return min(par_f, layer.out_ch) * c * layer.kh * layer.kw * wb


def build(
    spec: AcceleratorSpec,
    net: Network,
    dev: DeviceSpec,
    opts: BuilderOptions | None = None,
) -> ConcreteAccelerator:
    opts = opts or BuilderOptions()
    spec.validate(len(net))
    wb = dev.wordbytes

    # ---- 1. PE distribution (proportional to per-CE MACs) ----------------
    assign = _ce_layer_map(spec, net)
    ce_ids = sorted(assign)
    live = [c for c in ce_ids if assign[c]]
    macs = [sum(l.macs for l in assign[c]) for c in live]
    pes = dict(zip(live, _largest_remainder(macs, dev.pes)))
    for c in ce_ids:           # dead slots (block wider than segment)
        pes.setdefault(c, 0)

    # ---- 2. parallelism vectors ------------------------------------------
    pars = {
        c: (best_parallelism(pes[c], assign[c], opts.par_candidates)
            if assign[c] else {"f": 1, "oh": 1, "ow": 1})
        for c in ce_ids
    }

    # ---- 3. buffer floors and desires per block --------------------------
    floors: list[int] = []
    desires: list[int] = []
    for seg in spec.segments:
        layers = net.slice(seg.layer_lo, seg.layer_hi)
        if seg.pipelined:
            floor = 0
            for k, l in enumerate(layers):
                ce_id = seg.ce_lo + (k % seg.n_ces)
                floor += 2 * l.out_ch * l.ow * opts.fm_tile_rows * wb
                floor += _wtile_bytes(l, pars[ce_id].get("f", 1), wb)
            desire = pipelined_min_buffer(layers, dev, opts.fm_tile_rows)
        else:
            par_f = pars[seg.ce_lo].get("f", 1)
            floor = max(
                _wtile_bytes(l, par_f, wb)
                + l.in_ch * l.kh * l.iw * wb  # kh-row IFM band
                + l.out_ch * l.ow * wb        # one OFM row
                for l in layers
            )
            desire = single_ce_min_buffer(layers, par_f, wb)
        floors.append(floor)
        desires.append(max(desire, floor))

    budget = dev.on_chip_bytes
    alloc = list(floors)
    if sum(alloc) > budget:  # degenerate: scale floors down proportionally
        scale = budget / sum(alloc)
        alloc = [int(a * scale) for a in alloc]
    remaining = budget - sum(alloc)

    # ---- 4. inter-segment double buffers, smallest-first -----------------
    n_bounds = len(spec.segments) - 1
    inter_sizes = [
        net[spec.segments[i].layer_hi].ofm_size * wb for i in range(n_bounds)
    ]
    inter_onchip = [False] * n_bounds
    if spec.inter_segment_pipelining:
        for i in sorted(range(n_bounds), key=lambda k: inter_sizes[k]):
            if 2 * inter_sizes[i] <= remaining:
                inter_onchip[i] = True
                remaining -= 2 * inter_sizes[i]

    # ---- 5. distribute remaining budget toward minimum-access sizes ------
    gaps = [max(d - a, 0) for d, a in zip(desires, alloc)]
    gap_sum = sum(gaps)
    if gap_sum and remaining > 0:
        grant = min(remaining, gap_sum)
        for i, g in enumerate(gaps):
            alloc[i] += int(grant * (g / gap_sum))

    # ---- 6. materialise CEs ----------------------------------------------
    segments: list[ConcreteSegment] = []
    for i, seg in enumerate(spec.segments):
        layers = net.slice(seg.layer_lo, seg.layer_hi)
        if seg.pipelined:
            # split the block budget across its CEs by per-CE desire share
            ce_list = []
            ce_desires = []
            for slot in range(seg.n_ces):
                ls = [l for k, l in enumerate(layers) if k % seg.n_ces == slot]
                ce_desires.append(
                    sum(
                        (l.weights_size + 2 * l.out_ch * l.ow * opts.fm_tile_rows) * wb
                        for l in ls
                    )
                )
            d_sum = sum(ce_desires) or 1
            for slot in range(seg.n_ces):
                ce_id = seg.ce_lo + slot
                ce_list.append(
                    CE(
                        name=f"CE{ce_id + 1}",
                        pes=pes[ce_id],
                        par=pars[ce_id],
                        buffer_bytes=int(alloc[i] * ce_desires[slot] / d_sum),
                    )
                )
            resident = alloc[i] >= desires[i]
            segments.append(ConcreteSegment(spec=seg, ces=ce_list, weights_resident=resident))
        else:
            ce_id = seg.ce_lo
            ce = CE(
                name=f"CE{ce_id + 1}",
                pes=pes[ce_id],
                par=pars[ce_id],
                buffer_bytes=alloc[i],
            )
            segments.append(ConcreteSegment(spec=seg, ces=[ce]))

    return ConcreteAccelerator(
        spec=spec,
        network=net,
        device=dev,
        segments=segments,
        inter_seg_onchip=inter_onchip,
        inter_seg_buffer_bytes=inter_sizes,
    )
