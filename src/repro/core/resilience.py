"""Resilience primitives for the evaluation service and the long searches.

MCCM's pitch is *trustworthy* microsecond evaluation; this module is what
makes the serving and search layers trustworthy under faults instead of
best-effort:

* :class:`EvalError` — the structured error taxonomy every session-level
  failure is expressed in (``INVALID_INPUT`` / ``NONFINITE_METRICS`` /
  ``BACKEND_FAULT`` / ``DEADLINE_EXCEEDED`` / ``QUEUE_FULL``), with
  :func:`classify`/:func:`wrap` mapping arbitrary exceptions onto it;
* :class:`CircuitBreaker` — trips after repeated primary-backend faults so
  a broken Pallas kernel degrades the session to the bit-tested ``ref``
  backend instead of failing every call; periodic probes re-arm it.  The
  breaker is deterministic (counts, not wall clock) so chaos tests are
  exactly reproducible;
* retry backoff — :func:`retry_delay` is the exponential schedule
  ``Session`` sleeps between transient-fault retries;
* finite guards — :func:`nonfinite_keys` backs the NaN/Inf row isolation
  of the megabatch drain loop;
* checkpoints — :func:`save_checkpoint` / :func:`load_checkpoint`, a small
  versioned+checksummed writer (atomic rename, sha256 over the payload)
  that ``dse.search`` and ``multinet.search`` snapshot through, plus
  :func:`rng_state`/:func:`rng_from_state` so a resumed run replays the
  exact random stream and stays bit-identical to an uninterrupted one.

Semantics, file format and recipes: ``docs/robustness.md``.
"""
from __future__ import annotations

import copy
import hashlib
import os
import pickle
import threading

import numpy as np

from . import telemetry

__all__ = [
    "EvalError", "classify", "wrap", "CircuitBreaker", "retry_delay",
    "nonfinite_keys", "save_checkpoint", "load_checkpoint", "rng_state",
    "rng_from_state", "CHECKPOINT_VERSION",
]


# --------------------------------------------------------------------------
# error taxonomy
# --------------------------------------------------------------------------
class EvalError(RuntimeError):
    """A structured evaluation-service failure.

    ``code`` is one of the class attributes below; the rendered message is
    ``[CODE] detail`` so logs stay grep-able.  Callers branch on
    ``err.code`` (or the class attributes, e.g.
    ``EvalError.QUEUE_FULL``) — never on message text.
    """

    #: the request itself is malformed: unparseable notation, an invalid
    #: ``DesignBatch`` row, an empty design list, a broken net/board
    INVALID_INPUT = "INVALID_INPUT"
    #: evaluation produced NaN/Inf metrics for this request's designs
    NONFINITE_METRICS = "NONFINITE_METRICS"
    #: the evaluation backend (kernel compile/dispatch) faulted
    BACKEND_FAULT = "BACKEND_FAULT"
    #: the request's deadline passed before its result could be delivered
    DEADLINE_EXCEEDED = "DEADLINE_EXCEEDED"
    #: admission control: the bounded submit queue is full
    QUEUE_FULL = "QUEUE_FULL"

    CODES = (INVALID_INPUT, NONFINITE_METRICS, BACKEND_FAULT,
             DEADLINE_EXCEEDED, QUEUE_FULL)

    def __init__(self, code: str, message: str):
        if code not in self.CODES:
            raise ValueError(f"unknown EvalError code {code!r}; "
                             f"known: {self.CODES}")
        super().__init__(f"[{code}] {message}")
        self.code = code
        self.message = message


#: exception families that mean "the caller's input was bad" rather than
#: "the backend broke" — these never trip the circuit breaker
_INPUT_ERRORS = (ValueError, TypeError, KeyError, IndexError,
                 AttributeError)


def classify(exc: BaseException) -> str:
    """Map an arbitrary exception onto an :class:`EvalError` code."""
    if isinstance(exc, EvalError):
        return exc.code
    if isinstance(exc, _INPUT_ERRORS):
        return EvalError.INVALID_INPUT
    return EvalError.BACKEND_FAULT


def wrap(exc: BaseException, code: str | None = None) -> EvalError:
    """Wrap ``exc`` as an :class:`EvalError` (pass-through if it already
    is one), keeping the original message so callers matching on detail
    text keep working."""
    if isinstance(exc, EvalError):
        return exc
    return EvalError(code or classify(exc),
                     f"{type(exc).__name__}: {exc}")


# --------------------------------------------------------------------------
# retry backoff + circuit breaker (deterministic: counts, not wall clock)
# --------------------------------------------------------------------------
#: base delay of the exponential retry backoff (doubles per attempt)
RETRY_BASE_DELAY_S = 0.05
#: backoff ceiling
RETRY_MAX_DELAY_S = 2.0


def retry_delay(attempt: int) -> float:
    """Exponential backoff: ``base * 2**(attempt-1)``, capped.  ``attempt``
    is 1-based (the first *retry* is attempt 1)."""
    return min(RETRY_BASE_DELAY_S * (2.0 ** max(attempt - 1, 0)),
               RETRY_MAX_DELAY_S)


class CircuitBreaker:
    """Trip-open after ``fail_threshold`` consecutive primary-backend
    faults; while open, :meth:`allow_primary` admits only every
    ``probe_interval``-th call as a recovery probe (the rest degrade to
    the fallback backend).  A successful probe closes it again.

    Deterministic by construction — state advances on *calls*, never on
    wall-clock time — so fault-injection tests replay exactly.  Thread
    safe: the session's drain thread and synchronous callers share one.
    """

    def __init__(self, fail_threshold: int = 3, probe_interval: int = 8):
        if fail_threshold < 1 or probe_interval < 1:
            raise ValueError("fail_threshold and probe_interval must be "
                             ">= 1")
        self.fail_threshold = fail_threshold
        self.probe_interval = probe_interval
        self._lock = threading.Lock()
        self._consecutive = 0
        self._open = False
        self._asked_while_open = 0
        #: total times the breaker tripped open (observability)
        self.trips = 0

    @property
    def is_open(self) -> bool:
        with self._lock:
            return self._open

    def allow_primary(self) -> bool:
        """Should the next call attempt the primary backend?"""
        with self._lock:
            if not self._open:
                return True
            self._asked_while_open += 1
            return self._asked_while_open % self.probe_interval == 0

    def record_success(self) -> None:
        with self._lock:
            closed = self._open
            self._consecutive = 0
            self._open = False
            self._asked_while_open = 0
        if closed:  # emit outside the lock: telemetry has its own
            telemetry.event("resilience.breaker_close",
                            {"trips": self.trips})

    def record_failure(self) -> None:
        tripped = False
        with self._lock:
            self._consecutive += 1
            if not self._open and self._consecutive >= self.fail_threshold:
                self._open = True
                self._asked_while_open = 0
                self.trips += 1
                tripped = True
        if tripped:
            telemetry.event("resilience.breaker_open",
                            {"consecutive": self.fail_threshold,
                             "trips": self.trips})


# --------------------------------------------------------------------------
# finite guards
# --------------------------------------------------------------------------
def nonfinite_keys(out: dict) -> list[str]:
    """Metric keys of ``out`` containing any NaN/Inf entry (host check;
    device arrays are pulled)."""
    return [k for k, v in out.items()
            if not np.isfinite(np.asarray(v)).all()]


# --------------------------------------------------------------------------
# versioned checkpoints (what the search loops snapshot through)
# --------------------------------------------------------------------------
CHECKPOINT_MAGIC = b"RPROCKPT\n"
CHECKPOINT_VERSION = 1
_DIGEST_LEN = hashlib.sha256().digest_size


def save_checkpoint(path: str, kind: str, state: dict,
                    meta: dict | None = None) -> str:
    """Atomically write a checkpoint: magic + sha256(payload) + pickled
    ``{format, version, kind, meta, state}``.  The temp-file +
    ``os.replace`` dance means a kill mid-write leaves the previous
    checkpoint intact — a reader sees the old snapshot or the new one,
    never a torn file."""
    payload = pickle.dumps(
        {"format": "repro-checkpoint", "version": CHECKPOINT_VERSION,
         "kind": kind, "meta": dict(meta or {}), "state": state},
        protocol=pickle.HIGHEST_PROTOCOL)
    digest = hashlib.sha256(payload).digest()
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "wb") as f:
        f.write(CHECKPOINT_MAGIC)
        f.write(digest)
        f.write(payload)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    if telemetry.enabled():
        telemetry.count(f"checkpoint.writes.{kind}")
        telemetry.event("checkpoint.write",
                        {"kind": kind, "bytes": len(payload)})
    return path


def load_checkpoint(path: str, kind: str | None = None) -> dict:
    """Read + verify a checkpoint; returns ``{kind, meta, state}``.

    Raises :class:`EvalError` (``INVALID_INPUT``) on a missing file, a
    corrupt/torn payload (checksum mismatch), a format/version mismatch,
    or — when ``kind`` is given — a checkpoint of the wrong kind.
    """
    try:
        with open(path, "rb") as f:
            blob = f.read()
    except OSError as e:
        raise EvalError(EvalError.INVALID_INPUT,
                        f"cannot read checkpoint {path}: {e}") from e
    if not blob.startswith(CHECKPOINT_MAGIC):
        raise EvalError(EvalError.INVALID_INPUT,
                        f"{path} is not a repro checkpoint (bad magic)")
    start = len(CHECKPOINT_MAGIC)
    digest = blob[start:start + _DIGEST_LEN]
    payload = blob[start + _DIGEST_LEN:]
    if hashlib.sha256(payload).digest() != digest:
        raise EvalError(EvalError.INVALID_INPUT,
                        f"corrupt checkpoint {path} (checksum mismatch)")
    obj = pickle.loads(payload)
    if obj.get("format") != "repro-checkpoint":
        raise EvalError(EvalError.INVALID_INPUT,
                        f"{path}: unknown checkpoint format")
    if obj.get("version") != CHECKPOINT_VERSION:
        raise EvalError(
            EvalError.INVALID_INPUT,
            f"{path}: checkpoint version {obj.get('version')} != "
            f"{CHECKPOINT_VERSION}")
    if kind is not None and obj.get("kind") != kind:
        raise EvalError(EvalError.INVALID_INPUT,
                        f"{path}: checkpoint kind {obj.get('kind')!r} != "
                        f"expected {kind!r}")
    return {"kind": obj["kind"], "meta": obj["meta"], "state": obj["state"]}


def rng_state(rng: np.random.Generator) -> dict:
    """A picklable snapshot of a numpy ``Generator``'s full state."""
    return copy.deepcopy(rng.bit_generator.state)


def rng_from_state(state: dict) -> np.random.Generator:
    """Rebuild a ``Generator`` replaying exactly from :func:`rng_state`."""
    bit_gen = getattr(np.random, state["bit_generator"])()
    bit_gen.state = copy.deepcopy(state)
    return np.random.Generator(bit_gen)
