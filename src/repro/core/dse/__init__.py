"""Design-space exploration subsystem (paper §V-E, use case 3).

Four layers over one shared design encoding:

* :mod:`~repro.core.dse.encoding` — ``DesignBatch`` fixed-shape arrays,
  spec encode/decode round-trip, batch validity checks (also the encoding
  used by ``core.batch_eval``);
* :mod:`~repro.core.dse.samplers` — fully vectorized random samplers for
  the paper's custom family and the mixed superset family;
* :mod:`~repro.core.dse.pareto`   — O(N log N) non-dominated fronts and
  the incremental ``ParetoArchive``;
* :mod:`~repro.core.dse.search`   — guided multi-objective evolutionary
  search operating directly on ``DesignBatch`` arrays.

``driver.explore`` ties them together; all public names re-export here so
``from repro.core.dse import explore, pareto, sample_mixed`` keeps working
exactly as it did when this was a single module.
"""
from .driver import (
    DEFAULT_OBJECTIVES,
    DSEResult,
    best_scalar_index,
    dominating_indices,
    explore,
)
from .encoding import (
    NC,
    NS,
    DesignBatch,
    MultiDesignBatch,
    concat_batches,
    decode_batch,
    decode_design,
    encode_specs,
    pad_deployments,
    sample_assign,
    stack_designs,
    validate_batch,
)
from .pareto import ParetoArchive, hypervolume_2d, pareto
from .samplers import (
    sample_custom,
    sample_custom_loop,
    sample_mixed,
    sample_mixed_loop,
)
from .search import SearchConfig, SearchResult, make_children, orient, search

__all__ = [
    "DEFAULT_OBJECTIVES",
    "DSEResult",
    "DesignBatch",
    "MultiDesignBatch",
    "NC",
    "NS",
    "ParetoArchive",
    "SearchConfig",
    "SearchResult",
    "best_scalar_index",
    "concat_batches",
    "decode_batch",
    "decode_design",
    "dominating_indices",
    "encode_specs",
    "explore",
    "hypervolume_2d",
    "make_children",
    "orient",
    "pad_deployments",
    "pareto",
    "stack_designs",
    "sample_assign",
    "sample_custom",
    "sample_custom_loop",
    "sample_mixed",
    "sample_mixed_loop",
    "search",
    "validate_batch",
]
