"""Guided multi-objective search over DesignBatch arrays (paper use case 3).

Instead of blindly sampling the ~97.1e9-design space, an evolutionary loop
mutates and recombines whole *batches* of designs between jitted
``evaluate_batch`` calls — the style of guided exploration f-CNNx
(arXiv:1805.10174) and Shen et al.'s resource partitioning
(arXiv:1607.00064) use to find dominating designs, here running entirely
on the fixed-shape segment encoding so every generation is a handful of
NumPy ops plus one XLA dispatch.

Variation operators (all vectorized over the population, expressed on a
per-layer boundary bitmask):

* segment-boundary shift   — move one cut point ±1 layer;
* segment split / merge    — insert or delete a cut point;
* CE-count perturbation    — ±1 CE on one segment;
* pipeline-flag flip       — toggle a segment between single-CE and a
                             2-CE pipelined block (canonical pipe ⇔ nce>1);
* inter-segment-pipelining flip;
* one-point crossover      — child takes parent A's boundaries below a
                             random cut layer and parent B's above it.

Selection keeps a persistent :class:`ParetoArchive` (mode="pareto") or a
weighted-scalarization elite (mode="scalarized"); children violating the
NS/NC/CE-count constraints are repaired, and anything that slips through
is filtered before it can enter the archive.

The generation step is ONE jitted device program (evaluation, constraint
repair, validity, objective orientation and selection scoring — see
``_search_step_impl``): metrics stay on device for the whole run,
population buffers are donated off-CPU, every sub-batch is padded to
``pop_size`` so the entire search compiles once, and per generation the
host pulls only the objective points (for the archive), the validity mask
and the scores.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field, fields as dc_fields

import numpy as np

from .. import resilience, telemetry
from ..resilience import EvalError
from .encoding import NC, NS, DesignBatch, concat_batches
from .pareto import ParetoArchive, hypervolume_2d
from .samplers import sample_custom, sample_mixed

# metrics where HIGHER is better get flipped when building objective points
# (single-model metrics plus the multinet system metrics, so `orient` serves
# both the single-model and the joint co-scheduling searches)
ORIENT_MAX = frozenset({"throughput_ips", "utilization",
                        "agg_throughput_ips", "min_model_throughput_ips",
                        "fairness", "slo_attainment",
                        "slo_attainment_dist"})


def orient(metrics: dict[str, np.ndarray],
           objectives: tuple[str, ...]) -> np.ndarray:
    """Stack selected metrics into (N, M) points, lower always better."""
    cols = [(-1.0 if k in ORIENT_MAX else 1.0) * np.asarray(metrics[k],
                                                            np.float64)
            for k in objectives]
    return np.stack(cols, axis=1)


@dataclass
class SearchConfig:
    pop_size: int = 4096
    budget: int = 100_000             # total design evaluations
    objectives: tuple[str, ...] = ("latency_s", "buffer_bytes")
    mode: str = "pareto"              # "pareto" | "scalarized"
    weights: tuple[float, ...] | None = None   # scalarized-mode weights
    min_ces: int = 2
    max_ces: int = 11
    seed: int = 0
    crossover_frac: float = 0.5
    shift_frac: float = 0.6
    split_frac: float = 0.15
    merge_frac: float = 0.15
    nce_frac: float = 0.4
    flip_frac: float = 0.15
    inter_frac: float = 0.1
    immigrant_frac: float = 0.15      # fresh random designs per generation
    elite_frac: float = 0.25          # scalarized top-slice joining parents
    init_family: str = "both"         # sampler for init/immigrants:
                                      # "custom" | "mixed" | "both"
    # ---- island model (multi-device search; see docs/dse.md) ----------
    n_islands: int | None = None      # None: one island per mesh device
                                      # (1 without a mesh — classic loop)
    migration_interval: int = 4       # generations between elite exchanges
    migration_elites: int = 8         # per-island elites broadcast at each
                                      # migration (0 disables migration)
    # ---- checkpoint/resume (docs/robustness.md) -----------------------
    checkpoint_path: str | None = None  # snapshot file; None disables
    checkpoint_interval: int = 8      # generations between snapshots
    resume: bool = False              # resume from checkpoint_path if it
                                      # exists (a resumed run is
                                      # bit-identical to an uninterrupted
                                      # one); missing file = fresh start


@dataclass
class SearchResult:
    batch: DesignBatch                # every evaluated design, in order
    metrics: dict[str, np.ndarray]
    points: np.ndarray                # (n_evals, M) oriented objectives
    front_idx: np.ndarray             # archive rows, as indices into batch
    objectives: tuple[str, ...]
    n_evals: int
    seconds: float
    history: list[dict] = field(default_factory=list)
    island_fronts: list = field(default_factory=list)  # per-island front
                                      # indices into batch ([] single-pop)


# --------------------------------------------------------------------------
# boundary-bitmask domain: (P, L+1) cut mask + per-cut CE count
# --------------------------------------------------------------------------
def _to_boundary(seg_end: np.ndarray, seg_nce: np.ndarray,
                 n_layers: int) -> tuple[np.ndarray, np.ndarray]:
    P = len(seg_end)
    prev = np.concatenate(
        [np.zeros((P, 1), seg_end.dtype), seg_end[:, :-1]], axis=1)
    active = seg_end > prev
    bnd = np.zeros((P, n_layers + 1), bool)
    nce_at = np.ones((P, n_layers + 1), np.int64)
    rows = np.nonzero(active)[0]
    ends = seg_end[active].astype(np.int64)
    bnd[rows, ends] = True
    nce_at[rows, ends] = seg_nce[active]
    return bnd, nce_at


def _from_boundary(bnd: np.ndarray, nce_at: np.ndarray, n_layers: int,
                   max_segments: int) -> tuple[np.ndarray, np.ndarray]:
    """Compress the bitmask back to canonical (P, NS) arrays, keeping at
    most ``max_segments`` segments (surplus cut points merge away)."""
    P = bnd.shape[0]
    bnd = bnd.copy()
    bnd[:, 0] = False
    bnd[:, n_layers] = True
    internal = bnd.copy()
    internal[:, n_layers] = False
    irank = np.cumsum(internal, axis=1)
    keep = internal & (irank <= min(NS, max_segments) - 1)
    keep[:, n_layers] = True
    rows, poss = np.nonzero(keep)
    counts = np.bincount(rows, minlength=P)
    col = np.arange(len(rows)) - np.repeat(np.cumsum(counts) - counts, counts)
    seg_end = np.full((P, NS), n_layers, np.int64)
    seg_end[rows, col] = poss
    seg_nce = np.ones((P, NS), np.int64)
    seg_nce[rows, col] = nce_at[rows, poss]
    return seg_end, seg_nce


def _pick(rng: np.random.Generator,
          mask: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """One uniformly random True column per row -> (has_any, col)."""
    keys = np.where(mask, rng.random(mask.shape), -1.0)
    return mask.any(1), np.argmax(keys, axis=1)


def _crossover(rng, bnd_a, nce_a, bnd_b, nce_b, frac):
    P, W = bnd_a.shape
    cut = rng.integers(1, max(W - 1, 2), size=P)
    do = (rng.random(P) < frac)[:, None]
    left = np.arange(W)[None, :] <= cut[:, None]
    bnd = np.where(do, np.where(left, bnd_a, bnd_b), bnd_a)
    nce = np.where(do, np.where(left, nce_a, nce_b), nce_a)
    return bnd, nce


def _op_shift(rng, bnd, nce_at, frac):
    P, W = bnd.shape
    internal = bnd.copy()
    internal[:, 0] = internal[:, W - 1] = False
    has, col = _pick(rng, internal)
    tgt = np.clip(col + np.where(rng.random(P) < 0.5, -1, 1), 1, W - 2)
    do = has & (rng.random(P) < frac) & (tgt != col) \
        & ~bnd[np.arange(P), tgt]
    r = np.nonzero(do)[0]
    bnd[r, tgt[r]] = True
    nce_at[r, tgt[r]] = nce_at[r, col[r]]
    bnd[r, col[r]] = False
    nce_at[r, col[r]] = 1


def _op_split(rng, bnd, nce_at, frac):
    P, W = bnd.shape
    inner = ~bnd
    inner[:, 0] = inner[:, W - 1] = False
    has, col = _pick(rng, inner)
    do = has & (rng.random(P) < frac)
    r = np.nonzero(do)[0]
    bnd[r, col[r]] = True
    nce_at[r, col[r]] = 1            # new left half starts single-CE


def _op_merge(rng, bnd, nce_at, frac):
    P, W = bnd.shape
    internal = bnd.copy()
    internal[:, 0] = internal[:, W - 1] = False
    has, col = _pick(rng, internal)
    do = has & (rng.random(P) < frac)
    r = np.nonzero(do)[0]
    bnd[r, col[r]] = False
    nce_at[r, col[r]] = 1


def _op_nce(rng, bnd, nce_at, frac):
    P, W = bnd.shape
    cuts = bnd.copy()
    cuts[:, W - 1] = True            # the final segment is perturbable too
    cuts[:, 0] = False
    has, col = _pick(rng, cuts)
    do = has & (rng.random(P) < frac)
    delta = np.where(rng.random(P) < 0.5, -1, 1)
    r = np.nonzero(do)[0]
    nce_at[r, col[r]] = np.clip(nce_at[r, col[r]] + delta[r], 1, NC)


def _op_flip(rng, bnd, nce_at, frac):
    cuts = bnd.copy()
    cuts[:, -1] = True
    cuts[:, 0] = False
    has, col = _pick(rng, cuts)
    do = has & (rng.random(len(bnd)) < frac)
    r = np.nonzero(do)[0]
    cur = nce_at[r, col[r]]
    nce_at[r, col[r]] = np.where(cur > 1, 1, 2)   # pipe <-> single


def _repair_ces(seg_end, seg_nce, min_ces, max_ces, rng):
    """Bounded take-from-largest / give-to-random passes until every row's
    total CE count sits in [min_ces, min(max_ces, NC)]."""
    cap = min(max_ces, NC)
    P = len(seg_end)
    prev = np.concatenate(
        [np.zeros((P, 1), seg_end.dtype), seg_end[:, :-1]], axis=1)
    active = seg_end > prev
    nce = np.where(active, seg_nce, 1)
    rows = np.arange(P)
    for _ in range(2 * NC):
        total = (nce * active).sum(1)
        over = total > cap
        if not over.any():
            break
        shrinkable = active & (nce > 1)
        cand = np.where(shrinkable, nce.astype(np.float64), -np.inf)
        col = np.argmax(cand + rng.random(cand.shape) * 0.5, axis=1)
        sel = over & shrinkable.any(1)
        if not sel.any():
            break
        r = rows[sel]
        nce[r, col[sel]] -= 1
    for _ in range(2 * NC):
        total = (nce * active).sum(1)
        under = total < min_ces
        if not under.any():
            break
        has, col = _pick(rng, active)
        r = rows[under & has]
        nce[r, col[under & has]] += 1
    return np.where(active, nce, 1)


def make_children(rng: np.random.Generator, parents: DesignBatch,
                  n_layers: int, cfg: SearchConfig, n: int) -> DesignBatch:
    """Breed ``n`` children from ``parents`` (crossover + mutation ops),
    returning canonical, constraint-repaired designs."""
    seg_end, _, seg_nce, inter = parents.to_numpy()
    pa = rng.integers(0, len(seg_end), size=n)
    pb = rng.integers(0, len(seg_end), size=n)
    bnd_a, nce_a = _to_boundary(seg_end[pa], seg_nce[pa], n_layers)
    bnd_b, nce_b = _to_boundary(seg_end[pb], seg_nce[pb], n_layers)
    bnd, nce_at = _crossover(rng, bnd_a, nce_a, bnd_b, nce_b,
                             cfg.crossover_frac)
    _op_shift(rng, bnd, nce_at, cfg.shift_frac)
    _op_split(rng, bnd, nce_at, cfg.split_frac)
    _op_merge(rng, bnd, nce_at, cfg.merge_frac)
    _op_nce(rng, bnd, nce_at, cfg.nce_frac)
    _op_flip(rng, bnd, nce_at, cfg.flip_frac)
    end, nce = _from_boundary(bnd, nce_at, n_layers,
                              max_segments=min(NS, cfg.max_ces))
    nce = _repair_ces(end, nce, cfg.min_ces, cfg.max_ces, rng)
    prev = np.concatenate([np.zeros((n, 1), end.dtype), end[:, :-1]], axis=1)
    pipe = (end > prev) & (nce > 1)
    child_inter = np.where(rng.random(n) < cfg.inter_frac,
                           ~inter[pa], inter[pa])
    return DesignBatch.from_numpy(end, pipe, nce, child_inter)


# --------------------------------------------------------------------------
# the jitted generation step
# --------------------------------------------------------------------------
# One device dispatch per (sub-)generation: constraint repair, evaluation,
# validity, objective orientation and selection scoring all run inside the
# jit; the host only pulls the (pop, M) points for the Pareto archive, the
# validity mask and the scores.  Metrics stay on device until the end of
# the whole search.  Population buffers are donated off-CPU (XLA reuses
# them for the repaired copy); CPU ignores donation, so we skip it there
# to avoid the warning.
_STEP_CACHE: dict = {}


def _search_step_impl(seg_end, seg_pipe, seg_nce, inter, tables, devt, w,
                      lo, hi, *, objectives, min_ces, max_ces, backend,
                      tile, hint):
    import jax.numpy as jnp

    from ..batch_eval import evaluate_batch_traced
    from .encoding import repair_batch_jax, validate_batch_jax

    design = DesignBatch(seg_end, seg_pipe, seg_nce, inter)
    design = repair_batch_jax(design, tables.L, min_ces=min_ces,
                              max_ces=max_ces)
    metrics = evaluate_batch_traced(design, tables, devt, backend=backend,
                                    tile=tile, pes_hint_static=hint)
    pts = jnp.stack(
        [(-1.0 if k in ORIENT_MAX else 1.0) * metrics[k]
         for k in objectives], axis=1)
    ok = validate_batch_jax(design, tables.L, min_ces=min_ces,
                            max_ces=max_ces)
    ok &= jnp.isfinite(pts).all(1)
    lo = jnp.minimum(lo, jnp.where(ok[:, None], pts, jnp.inf).min(0))
    hi = jnp.maximum(hi, jnp.where(ok[:, None], pts, -jnp.inf).max(0))
    span = jnp.maximum(hi - lo, 1e-30)
    score = jnp.where(ok, ((pts - lo) / span) @ w, jnp.inf)
    return ((design.seg_end, design.seg_pipe, design.seg_nce,
             design.inter_pipe), metrics, pts, ok, score, lo, hi)


def _jitted_step(donate: bool):
    import jax
    if donate not in _STEP_CACHE:
        _STEP_CACHE[donate] = jax.jit(
            _search_step_impl,
            static_argnames=("objectives", "min_ces", "max_ces", "backend",
                             "tile", "hint"),
            donate_argnums=(0, 1, 2, 3) if donate else ())
    return _STEP_CACHE[donate]


def _island_step_body(seg_end, seg_pipe, seg_nce, inter, tables, devt, w,
                      lo, hi, *, objectives, min_ces, max_ces, backend,
                      tile, hint):
    """Per-shard body of the sharded island step: each mesh device holds
    ONE island's pop_n rows plus that island's (1, n_obj) weight and
    normalization planes — the math is exactly the single-device
    generation step, run once per island with no cross-island traffic."""
    darrs, metrics, pts, ok, score, lo2, hi2 = _search_step_impl(
        seg_end, seg_pipe, seg_nce, inter, tables, devt, w[0], lo[0], hi[0],
        objectives=objectives, min_ces=min_ces, max_ces=max_ces,
        backend=backend, tile=tile, hint=hint)
    return darrs, metrics, pts, ok, score, lo2[None], hi2[None]


# --------------------------------------------------------------------------
# checkpoint plumbing (shared by the serial and island loops)
# --------------------------------------------------------------------------
#: checkpoint interval floor — every write costs a host sync of the halls
_CKPT_KINDS = ("dse-search", "dse-search-island")


def _cfg_fingerprint(cfg, n_layers: int) -> dict:
    """The search-trajectory-determining identity a checkpoint is bound
    to: every config field except the checkpoint knobs themselves, plus
    the workload size.  A resume under a different fingerprint would NOT
    reproduce the uninterrupted run, so it is refused."""
    skip = {"checkpoint_path", "checkpoint_interval", "resume"}
    fp = {f.name: getattr(cfg, f.name) for f in dc_fields(cfg)
          if f.name not in skip}
    fp["n_layers"] = n_layers
    return fp


def _checkpoint_meta(cfg, n_layers: int) -> dict:
    return {"fingerprint": _cfg_fingerprint(cfg, n_layers)}


def _load_search_checkpoint(cfg, n_layers: int, kind: str) -> dict | None:
    """The state dict of a resumable checkpoint, or None for a fresh
    start (no path / resume off / file absent)."""
    path = cfg.checkpoint_path
    if not path or not cfg.resume or not os.path.exists(path):
        return None
    snap = resilience.load_checkpoint(path, kind=kind)
    want = _cfg_fingerprint(cfg, n_layers)
    if snap["meta"].get("fingerprint") != want:
        raise EvalError(
            EvalError.INVALID_INPUT,
            f"checkpoint {path} was written by a different search "
            f"configuration/workload; refusing to resume (a resumed run "
            f"must be bit-identical to an uninterrupted one)")
    return snap["state"]


def _merged_metrics(all_metrics: list[dict]) -> dict:
    """One host dict over everything evaluated so far (device slices are
    pulled exactly once per checkpoint)."""
    if not all_metrics:
        return {}
    return {k: np.concatenate([np.asarray(m[k]) for m in all_metrics])
            for k in all_metrics[0]}


# --------------------------------------------------------------------------
# the search loop
# --------------------------------------------------------------------------
def _initial_pop(rng, n_layers, cfg, n):
    fam = cfg.init_family
    if fam not in ("custom", "mixed", "both"):
        raise ValueError(f"unknown init_family {fam!r}")
    if cfg.max_ces < 2 or fam == "mixed":   # custom needs a >= 2-CE head
        return sample_mixed(rng, n_layers, n,
                            min_ces=cfg.min_ces, max_ces=cfg.max_ces)
    if fam == "custom":
        return sample_custom(rng, n_layers, n,
                             min_ces=max(cfg.min_ces, 2),
                             max_ces=cfg.max_ces)
    n_custom = n // 2
    a = sample_custom(rng, n_layers, n_custom,
                      min_ces=max(cfg.min_ces, 2), max_ces=cfg.max_ces)
    b = sample_mixed(rng, n_layers, n - n_custom,
                     min_ces=cfg.min_ces, max_ces=cfg.max_ces)
    return concat_batches([a, b])


def _gen_telemetry(kind: str, gen: int, evals: int, points,
                   extra: dict | None = None) -> None:
    """Per-generation search telemetry (``docs/observability.md``): a
    generation counter, the current front size, the 2-objective dominated
    hypervolume (ref = the front's own max corner, so it is monotone in
    front quality without needing a user reference), and one trace event.
    No-op — no host pulls, no allocation — when telemetry is disabled."""
    if not telemetry.enabled():
        return
    telemetry.count(f"{kind}.generations")
    front = 0 if points is None else len(points)
    telemetry.gauge(f"{kind}.front_size", front)
    attrs = {"gen": gen, "evals": evals, "front": front}
    if extra:
        attrs.update(extra)
    if points is not None and front and points.shape[1] == 2:
        ref = points.max(0) * 1.1 + 1e-30
        hv = hypervolume_2d(points, ref)
        telemetry.gauge(f"{kind}.hypervolume", hv)
        attrs["hypervolume"] = hv
    telemetry.event(f"{kind}.generation", attrs)


def search(net, dev, config: SearchConfig | None = None,
           tables=None, backend: str | None = None,
           mesh=None) -> SearchResult:
    """Run the guided loop: sample -> evaluate -> archive -> breed.

    Caller-provided ``tables`` are used verbatim; an explicit ``backend``
    overrides the env-resolved kernel backend (what the Session passes).

    ``mesh`` (a ``core.shard.EvalMesh``) turns the loop into an island
    model — one sub-population per device — via ``cfg.n_islands`` (None
    resolves to the mesh device count).  With one island the classic
    single-population loop below runs unchanged."""
    import jax
    import jax.numpy as jnp

    from ..batch_eval import (DEFAULT_TILE, _pad_rows, make_device_tables,
                              make_tables, pes_hint)
    from ...kernels.mccm_eval import resolve_backend

    cfg = config or SearchConfig()
    n_obj = len(cfg.objectives)
    if cfg.budget < 1 or cfg.pop_size < 1:
        raise ValueError(
            f"budget and pop_size must be >= 1 "
            f"(got {cfg.budget}, {cfg.pop_size})")
    if cfg.mode not in ("pareto", "scalarized"):
        raise ValueError(f"unknown mode {cfg.mode!r}")
    if cfg.mode == "scalarized" and cfg.weights is not None \
            and len(cfg.weights) != n_obj:
        raise ValueError("weights must match objectives")
    tables = tables if tables is not None else make_tables(net)

    n_islands = cfg.n_islands
    if n_islands is None:
        n_islands = mesh.ndevices \
            if mesh is not None and getattr(mesh, "is_sharded", False) else 1
    if n_islands < 1:
        raise ValueError(f"n_islands must be >= 1, got {n_islands}")
    n_islands = min(n_islands, cfg.budget)
    if n_islands > 1:
        return _island_search(dev, cfg, tables,
                              resolve_backend(backend), mesh, n_islands)

    n_layers = tables.n_layers
    rng = np.random.default_rng(cfg.seed)

    devt = make_device_tables(dev)
    hint = pes_hint(dev.pes)
    backend = resolve_backend(backend)
    step = _jitted_step(donate=jax.default_backend() != "cpu")
    statics = dict(objectives=tuple(cfg.objectives), min_ces=cfg.min_ces,
                   max_ces=cfg.max_ces, backend=backend, tile=DEFAULT_TILE,
                   hint=hint)

    # generation sizes: pop_n each, the final one absorbing the remainder
    # so the evaluation count equals the budget EXACTLY.  Every device
    # call is padded to pop_n rows (the final oversized generation splits
    # into pop_n-shaped sub-batches) — ONE compile for the whole search.
    pop_n = min(cfg.pop_size, cfg.budget)
    gens = max(1, cfg.budget // pop_n)
    sizes = [pop_n] * gens
    sizes[-1] += cfg.budget - gens * pop_n
    total = cfg.budget

    hall_end = np.empty((total, NS), np.int32)
    hall_pipe = np.empty((total, NS), bool)
    hall_nce = np.empty((total, NS), np.int32)
    hall_inter = np.empty((total,), bool)
    all_points = np.empty((total, n_obj))
    hall_ok = np.zeros((total,), bool)
    all_metrics: list[dict] = []

    archive = ParetoArchive(n_obj)
    lo = jnp.full(n_obj, jnp.inf, jnp.float32)
    hi = jnp.full(n_obj, -jnp.inf, jnp.float32)
    history: list[dict] = []

    def eval_gen(pop: DesignBatch, w, lo, hi):
        """Evaluate a generation in pop_n-shaped padded sub-batches."""
        n = pop.batch
        pts_l, ok_l, score_l, design_l = [], [], [], []
        for s in range(0, n, pop_n):
            sub = _pad_rows(pop.take(np.arange(s, min(s + pop_n, n))), pop_n)
            keep = min(s + pop_n, n) - s
            (darrs, metrics, pts, ok, score, lo, hi) = step(
                sub.seg_end, sub.seg_pipe, sub.seg_nce, sub.inter_pipe,
                tables, devt, jnp.asarray(w, jnp.float32), lo, hi, **statics)
            all_metrics.append({k: v[:keep] for k, v in metrics.items()})
            design_l.append([np.asarray(a)[:keep] for a in darrs])
            pts_l.append(np.asarray(pts, np.float64)[:keep])
            ok_l.append(np.asarray(ok)[:keep])
            score_l.append(np.asarray(score, np.float64)[:keep])
        cat = lambda xs: np.concatenate(xs) if len(xs) > 1 else xs[0]
        darrs = [cat([d[i] for d in design_l]) for i in range(4)]
        return darrs, cat(pts_l), cat(ok_l), cat(score_l), lo, hi

    # ---- checkpoint/resume: restore loop state exactly as it was at
    # the top of generation `start_gen` (before that gen's RNG draws),
    # so the remaining generations replay bit-identically --------------
    start_gen, base, elapsed0, pop = 0, 0, 0.0, None
    snap = _load_search_checkpoint(cfg, n_layers, "dse-search")
    if snap is not None:
        start_gen, base = snap["gen"], snap["base"]
        rng = resilience.rng_from_state(snap["rng"])
        pop = DesignBatch.from_numpy(*snap["pop"])
        hall_end[:base], hall_pipe[:base] = snap["hall"][0], snap["hall"][1]
        hall_nce[:base], hall_inter[:base] = snap["hall"][2], snap["hall"][3]
        all_points[:base] = snap["points"]
        hall_ok[:base] = snap["ok"]
        if snap["metrics"]:
            all_metrics.append(snap["metrics"])
        archive.points = snap["archive"][0].copy()
        archive.payload = snap["archive"][1].copy()
        lo, hi = jnp.asarray(snap["lo"]), jnp.asarray(snap["hi"])
        history.extend(snap["history"])
        elapsed0 = snap["elapsed_s"]
    if pop is None:
        pop = _initial_pop(rng, n_layers, cfg, sizes[0])
    ckpt_every = max(1, cfg.checkpoint_interval)
    t0 = time.time() - elapsed0
    for gen in range(start_gen, gens):
        if cfg.checkpoint_path and gen > 0 and gen % ckpt_every == 0:
            resilience.save_checkpoint(
                cfg.checkpoint_path, "dse-search",
                {"gen": gen, "base": base,
                 "rng": resilience.rng_state(rng),
                 "pop": tuple(pop.to_numpy()),
                 "hall": (hall_end[:base].copy(), hall_pipe[:base].copy(),
                          hall_nce[:base].copy(), hall_inter[:base].copy()),
                 "points": all_points[:base].copy(),
                 "ok": hall_ok[:base].copy(),
                 "metrics": _merged_metrics(all_metrics),
                 "archive": (archive.points.copy(), archive.payload.copy()),
                 "lo": np.asarray(lo), "hi": np.asarray(hi),
                 "history": list(history),
                 "elapsed_s": time.time() - t0},
                meta=_checkpoint_meta(cfg, n_layers))
        if cfg.mode == "scalarized":
            w = np.asarray(cfg.weights if cfg.weights is not None
                           else np.ones(n_obj))
        else:
            w = rng.random(n_obj) + 0.1       # fresh direction each gen
        w = w / w.sum()

        (e, p, c, i), pts, ok, score, lo, hi = eval_gen(pop, w, lo, hi)
        idx = np.arange(base, base + sizes[gen])
        base += sizes[gen]
        hall_end[idx], hall_pipe[idx] = e, p
        hall_nce[idx], hall_inter[idx] = c, i
        all_points[idx] = pts
        hall_ok[idx] = ok
        archive.update(pts[ok], idx[ok])

        if gen == gens - 1:
            break

        # ---- parents: archive front + this generation's elite slice ----
        n_elite = max(1, int(sizes[gen] * cfg.elite_frac))
        elite = idx[np.argsort(score, kind="stable")[:n_elite]]
        pool = np.unique(np.concatenate([archive.payload, elite]))
        parents = DesignBatch.from_numpy(
            hall_end[pool], hall_pipe[pool], hall_nce[pool], hall_inter[pool])

        n_imm = int(sizes[gen + 1] * cfg.immigrant_frac)
        children = make_children(rng, parents, n_layers, cfg,
                                 sizes[gen + 1] - n_imm)
        imm = _initial_pop(rng, n_layers, cfg, n_imm) if n_imm else None
        pop = concat_batches([children, imm]) if imm is not None else children

        history.append(dict(gen=gen, evals=base,
                            archive=len(archive),
                            best=dict(zip(cfg.objectives,
                                          archive.points.min(0).tolist()))
                            if len(archive) else {}))
        _gen_telemetry("dse", gen, base,
                       archive.points if len(archive) else None)

    seconds = time.time() - t0
    # one host pull per metric for the whole search (they stayed on device)
    metrics = {k: np.concatenate([np.asarray(m[k]) for m in all_metrics])
               for k in all_metrics[0]}
    lo_h = np.asarray(lo, np.float64)
    hi_h = np.asarray(hi, np.float64)
    # best single design under one CONSISTENT scalarization (final
    # normalization span; configured weights, equal if none)
    w = np.asarray(cfg.weights) if cfg.weights is not None \
        else np.ones(n_obj)
    w = w / w.sum()
    final_scores = np.where(
        hall_ok,
        ((all_points - lo_h) / np.maximum(hi_h - lo_h, 1e-30)) @ w, np.inf)
    best_scalar_idx = int(np.argmin(final_scores))
    history.append(dict(gen=gens - 1, evals=total, archive=len(archive),
                        best=dict(zip(cfg.objectives,
                                      archive.points.min(0).tolist()))
                        if len(archive) else {},
                        best_scalar_idx=best_scalar_idx))
    _gen_telemetry("dse", gens - 1, total,
                   archive.points if len(archive) else None)
    return SearchResult(
        batch=DesignBatch.from_numpy(hall_end, hall_pipe, hall_nce,
                                     hall_inter),
        metrics=metrics,
        points=all_points,
        front_idx=np.sort(archive.payload.copy()),
        objectives=cfg.objectives,
        n_evals=total,
        seconds=seconds,
        history=history,
    )


# --------------------------------------------------------------------------
# the island model (multi-device search)
# --------------------------------------------------------------------------
def _migration_pick(archive: ParetoArchive, k: int) -> np.ndarray:
    """Up to ``k`` elites from one island's front, spread along the first
    objective (deterministic — no RNG, so migration never perturbs the
    per-island random streams)."""
    pay = archive.payload
    if len(pay) <= k:
        return pay.copy()
    order = np.argsort(archive.points[:, 0], kind="stable")
    sel = np.round(np.linspace(0, len(order) - 1, k)).astype(int)
    return pay[order[sel]]


def _island_search(dev, cfg: SearchConfig, tables, backend: str, mesh,
                   n_islands: int) -> SearchResult:
    """The island model: ``n_islands`` sub-populations, each evolving
    under the same jitted generation step, with periodic migration of
    Pareto elites between islands and a final merged-front reduction.

    When ``mesh`` is sharded with exactly ``n_islands`` devices, every
    generation is ONE sharded device call — island i's pop_n rows live on
    device i, with per-island weight/normalization planes sharded
    alongside and NetTables/DeviceTables replicated.  Otherwise (no mesh,
    or an island count overriding the device count) the islands take
    turns through the existing single-device step — same semantics,
    serial execution.  Breeding stays host-side per island
    (``make_children``), each island on its own ``[seed, island]`` RNG
    stream, so results are deterministic given (seed, island count)."""
    import jax
    import jax.numpy as jnp

    from ..batch_eval import (DEFAULT_TILE, _pad_rows, make_device_tables,
                              pes_hint)

    n_obj = len(cfg.objectives)
    n_layers = tables.n_layers
    devt = make_device_tables(dev)
    hint = pes_hint(dev.pes)
    statics = dict(objectives=tuple(cfg.objectives), min_ces=cfg.min_ces,
                   max_ces=cfg.max_ces, backend=backend, tile=DEFAULT_TILE,
                   hint=hint)
    I = n_islands

    # per-generation island sizes: pop_n each, the final generation
    # absorbing the remainder so evaluations equal the budget EXACTLY;
    # every device call is padded to I x pop_n rows (one compile).
    pop_n = min(cfg.pop_size, max(cfg.budget // I, 1))
    per_gen = pop_n * I
    gens = max(1, cfg.budget // per_gen)
    sizes = np.full((gens, I), pop_n, np.int64)
    rem = cfg.budget - gens * per_gen
    sizes[-1] += rem // I
    sizes[-1, :rem % I] += 1
    total = cfg.budget

    sharded = (mesh is not None and getattr(mesh, "is_sharded", False)
               and mesh.ndevices == I)
    if sharded:
        raw = mesh.shard_jit("dse_island_step", _island_step_body,
                             replicated=(4, 5), static_kwargs=statics)

        def step_all(stacked, w_arr, lo, hi):
            return raw(stacked.seg_end, stacked.seg_pipe, stacked.seg_nce,
                       stacked.inter_pipe, tables, devt,
                       jnp.asarray(w_arr, jnp.float32), lo, hi)
    else:
        raw = _jitted_step(donate=jax.default_backend() != "cpu")

        def step_all(stacked, w_arr, lo, hi):
            parts, los, his = [], [], []
            for i in range(I):
                sl = slice(i * pop_n, (i + 1) * pop_n)
                out = raw(stacked.seg_end[sl], stacked.seg_pipe[sl],
                          stacked.seg_nce[sl], stacked.inter_pipe[sl],
                          tables, devt,
                          jnp.asarray(w_arr[i], jnp.float32),
                          lo[i], hi[i], **statics)
                parts.append(out[:5])
                los.append(out[5])
                his.append(out[6])
            darrs = tuple(jnp.concatenate([p[0][j] for p in parts])
                          for j in range(4))
            metrics = {k: jnp.concatenate([p[1][k] for p in parts])
                       for k in parts[0][1]}
            cat = lambda j: jnp.concatenate([p[j] for p in parts])
            return (darrs, metrics, cat(2), cat(3), cat(4),
                    jnp.stack(los), jnp.stack(his))

    hall_end = np.empty((total, NS), np.int32)
    hall_pipe = np.empty((total, NS), bool)
    hall_nce = np.empty((total, NS), np.int32)
    hall_inter = np.empty((total,), bool)
    all_points = np.empty((total, n_obj))
    hall_ok = np.zeros((total,), bool)
    all_metrics: list[dict] = []

    merged = ParetoArchive(n_obj)
    islands = [ParetoArchive(n_obj) for _ in range(I)]
    rngs = [np.random.default_rng([cfg.seed, i]) for i in range(I)]
    lo = jnp.full((I, n_obj), jnp.inf, jnp.float32)
    hi = jnp.full((I, n_obj), -jnp.inf, jnp.float32)
    history: list[dict] = []

    # ---- checkpoint/resume (same contract as the serial loop, with
    # per-island RNG streams / populations / archives in the state) ----
    start_gen, base, elapsed0 = 0, 0, 0.0
    snap = _load_search_checkpoint(cfg, n_layers, "dse-search-island")
    if snap is None:
        pops = [_initial_pop(rngs[i], n_layers, cfg, int(sizes[0, i]))
                for i in range(I)]
    else:
        start_gen, base = snap["gen"], snap["base"]
        rngs = [resilience.rng_from_state(s) for s in snap["rngs"]]
        pops = [DesignBatch.from_numpy(*p) for p in snap["pops"]]
        hall_end[:base], hall_pipe[:base] = snap["hall"][0], snap["hall"][1]
        hall_nce[:base], hall_inter[:base] = snap["hall"][2], snap["hall"][3]
        all_points[:base] = snap["points"]
        hall_ok[:base] = snap["ok"]
        if snap["metrics"]:
            all_metrics.append(snap["metrics"])
        for arch, (apts, apay) in zip(islands, snap["islands"]):
            arch.points, arch.payload = apts.copy(), apay.copy()
        merged.points = snap["merged"][0].copy()
        merged.payload = snap["merged"][1].copy()
        lo, hi = jnp.asarray(snap["lo"]), jnp.asarray(snap["hi"])
        history.extend(snap["history"])
        elapsed0 = snap["elapsed_s"]
    ckpt_every = max(1, cfg.checkpoint_interval)
    t0 = time.time() - elapsed0
    for gen in range(start_gen, gens):
        if cfg.checkpoint_path and gen > 0 and gen % ckpt_every == 0:
            resilience.save_checkpoint(
                cfg.checkpoint_path, "dse-search-island",
                {"gen": gen, "base": base,
                 "rngs": [resilience.rng_state(r) for r in rngs],
                 "pops": [tuple(p.to_numpy()) for p in pops],
                 "hall": (hall_end[:base].copy(), hall_pipe[:base].copy(),
                          hall_nce[:base].copy(), hall_inter[:base].copy()),
                 "points": all_points[:base].copy(),
                 "ok": hall_ok[:base].copy(),
                 "metrics": _merged_metrics(all_metrics),
                 "islands": [(a.points.copy(), a.payload.copy())
                             for a in islands],
                 "merged": (merged.points.copy(), merged.payload.copy()),
                 "lo": np.asarray(lo), "hi": np.asarray(hi),
                 "history": list(history),
                 "elapsed_s": time.time() - t0},
                meta=_checkpoint_meta(cfg, n_layers))
        ws = []
        for i in range(I):
            if cfg.mode == "scalarized":
                w = np.asarray(cfg.weights if cfg.weights is not None
                               else np.ones(n_obj))
            else:
                w = rngs[i].random(n_obj) + 0.1   # per-island direction
            ws.append(w / w.sum())
        w_arr = np.asarray(ws, np.float32)

        # sub-rounds: only the final (oversized) generation needs k > 1
        k = -(-int(sizes[gen].max()) // pop_n)
        gen_idx = [[] for _ in range(I)]
        gen_score = [[] for _ in range(I)]
        for j in range(k):
            subs, keeps = [], []
            for i in range(I):
                s = j * pop_n
                e = min(int(sizes[gen, i]), s + pop_n)
                keep = max(e - s, 0)
                rows = np.arange(s, e) if keep else np.arange(1)
                subs.append(_pad_rows(pops[i].take(rows), pop_n))
                keeps.append(keep)
            stacked = concat_batches(subs)
            darrs, metrics, pts, ok, score, lo, hi = step_all(
                stacked, w_arr, lo, hi)
            darrs_h = [np.asarray(a) for a in darrs]
            pts_h = np.asarray(pts, np.float64)
            ok_h = np.asarray(ok)
            score_h = np.asarray(score, np.float64)
            for i in range(I):
                keep = keeps[i]
                if keep == 0:
                    continue
                sl = slice(i * pop_n, i * pop_n + keep)
                idx = np.arange(base, base + keep)
                base += keep
                hall_end[idx], hall_pipe[idx] = darrs_h[0][sl], darrs_h[1][sl]
                hall_nce[idx], hall_inter[idx] = darrs_h[2][sl], darrs_h[3][sl]
                all_points[idx] = pts_h[sl]
                hall_ok[idx] = ok_h[sl]
                all_metrics.append({kk: vv[sl] for kk, vv in metrics.items()})
                gen_idx[i].append(idx)
                gen_score[i].append(score_h[sl])
                okm = ok_h[sl]
                islands[i].update(pts_h[sl][okm], idx[okm])
                merged.update(pts_h[sl][okm], idx[okm])

        if gen == gens - 1:
            break

        # ---- migration: all-gather each island's elite slice ----------
        migrate = (cfg.migration_elites > 0 and cfg.migration_interval > 0
                   and (gen + 1) % cfg.migration_interval == 0)
        migrants = np.empty(0, np.int64)
        if migrate:
            picks = [_migration_pick(islands[i], cfg.migration_elites)
                     for i in range(I)]
            migrants = np.unique(np.concatenate(picks)) \
                if picks else migrants

        # ---- per-island breeding: front + elite slice (+ migrants) ----
        for i in range(I):
            idx_i = np.concatenate(gen_idx[i])
            score_i = np.concatenate(gen_score[i])
            n_elite = max(1, int(len(idx_i) * cfg.elite_frac))
            elite = idx_i[np.argsort(score_i, kind="stable")[:n_elite]]
            pool = [islands[i].payload, elite]
            if migrate:
                pool.append(migrants)
            pool = np.unique(np.concatenate(pool))
            parents = DesignBatch.from_numpy(
                hall_end[pool], hall_pipe[pool], hall_nce[pool],
                hall_inter[pool])
            nxt = int(sizes[gen + 1, i])
            n_imm = int(nxt * cfg.immigrant_frac)
            children = make_children(rngs[i], parents, n_layers, cfg,
                                     nxt - n_imm)
            imm = _initial_pop(rngs[i], n_layers, cfg, n_imm) \
                if n_imm else None
            pops[i] = concat_batches([children, imm]) \
                if imm is not None else children

        history.append(dict(gen=gen, evals=base, archive=len(merged),
                            islands=[len(a) for a in islands],
                            migrants=int(len(migrants)),
                            best=dict(zip(cfg.objectives,
                                          merged.points.min(0).tolist()))
                            if len(merged) else {}))
        if len(migrants):
            telemetry.count("dse.migrations", int(len(migrants)))
        _gen_telemetry("dse", gen, base,
                       merged.points if len(merged) else None,
                       {"islands": len(islands),
                        "migrants": int(len(migrants))})

    seconds = time.time() - t0
    metrics = {k: np.concatenate([np.asarray(m[k]) for m in all_metrics])
               for k in all_metrics[0]}
    lo_h = np.asarray(lo, np.float64).min(0)
    hi_h = np.asarray(hi, np.float64).max(0)
    w = np.asarray(cfg.weights) if cfg.weights is not None \
        else np.ones(n_obj)
    w = w / w.sum()
    final_scores = np.where(
        hall_ok,
        ((all_points - lo_h) / np.maximum(hi_h - lo_h, 1e-30)) @ w, np.inf)
    best_scalar_idx = int(np.argmin(final_scores))
    history.append(dict(gen=gens - 1, evals=total, archive=len(merged),
                        islands=[len(a) for a in islands],
                        migrants=0,
                        best=dict(zip(cfg.objectives,
                                      merged.points.min(0).tolist()))
                        if len(merged) else {},
                        best_scalar_idx=best_scalar_idx))
    _gen_telemetry("dse", gens - 1, total,
                   merged.points if len(merged) else None,
                   {"islands": len(islands), "migrants": 0})
    return SearchResult(
        batch=DesignBatch.from_numpy(hall_end, hall_pipe, hall_nce,
                                     hall_inter),
        metrics=metrics,
        points=all_points,
        front_idx=np.sort(merged.payload.copy()),
        objectives=cfg.objectives,
        n_evals=total,
        seconds=seconds,
        history=history,
        island_fronts=[np.sort(a.payload.copy()) for a in islands],
    )
