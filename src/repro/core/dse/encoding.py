"""Shared design-encoding layer: fixed-shape arrays <-> AcceleratorSpec.

Every DSE component — the vectorized samplers, the guided search, the jitted
``batch_eval.evaluate_batch`` and the builder round-trip — speaks the same
(B, NS) encoding defined here:

* ``seg_end``   int32 (B, NS): exclusive end layer of each segment, sorted
  nondecreasing; padding columns repeat ``n_layers``.
* ``seg_pipe``  bool  (B, NS): segment is a pipelined block.
* ``seg_nce``   int32 (B, NS): CEs of the segment (1 for single-CE).
* ``inter_pipe`` bool (B,): coarse inter-segment pipelining.

Canonical form (what samplers/search produce and ``encode_specs`` emits):
segments are compact (no empty segment before a non-empty one), a valid
segment is pipelined iff ``seg_nce > 1``, and padding columns carry
``end == n_layers, nce == 1, pipe == False``.  ``validate_batch`` checks
exactly this plus the NS/NC CE-count bounds, and ``decode_design`` ->
``encode_specs`` round-trips any canonical row bit-exactly.

Multi-model deployments (``core.multinet``) extend the encoding along a
model axis: :class:`MultiDesignBatch` stacks M per-model design planes
into (B, M, NS) arrays, and a hybrid deployment adds one more gene — the
**assignment** plane, a float (B, M) array where ``assign[b, m] > 0.5``
places model m in deployment b's single time-multiplexed *shared slice*
and anything else gives it a dedicated spatial slice.  ``sample_assign``
draws random assignments; the traced evaluator canonicalizes them with a
plain ``> 0.5`` threshold (and masks padded model columns), so the search
mutates assignment genes as freely as resource shares without forking
compiles.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp

from ..notation import AcceleratorSpec, SegmentSpec

NS = 12          # max segments per design
NC = 16          # max CEs per design


@jax.tree_util.register_dataclass
@dataclass
class DesignBatch:
    """(B, NS) arrays; invalid segments have end == previous end."""

    seg_end: jnp.ndarray       # int32 (B, NS) exclusive end layer
    seg_pipe: jnp.ndarray      # bool  (B, NS)
    seg_nce: jnp.ndarray       # int32 (B, NS) >= 1
    inter_pipe: jnp.ndarray    # bool  (B,)

    @property
    def batch(self) -> int:
        """Number of designs in the batch."""
        return self.seg_end.shape[0]

    @classmethod
    def from_numpy(cls, seg_end, seg_pipe, seg_nce, inter_pipe) -> "DesignBatch":
        """Host arrays -> device DesignBatch with canonical dtypes."""
        return cls(jnp.asarray(seg_end, jnp.int32), jnp.asarray(seg_pipe, bool),
                   jnp.asarray(seg_nce, jnp.int32), jnp.asarray(inter_pipe, bool))

    def to_numpy(self) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """(seg_end, seg_pipe, seg_nce, inter_pipe) as host arrays."""
        return (np.asarray(self.seg_end), np.asarray(self.seg_pipe),
                np.asarray(self.seg_nce), np.asarray(self.inter_pipe))

    def take(self, idx) -> "DesignBatch":
        """Row subset (numpy/jnp fancy index)."""
        return DesignBatch(self.seg_end[idx], self.seg_pipe[idx],
                           self.seg_nce[idx], self.inter_pipe[idx])


def concat_batches(batches: list[DesignBatch]) -> DesignBatch:
    """Row-concatenate DesignBatches (all for the same n_layers)."""
    return DesignBatch(
        jnp.concatenate([b.seg_end for b in batches]),
        jnp.concatenate([b.seg_pipe for b in batches]),
        jnp.concatenate([b.seg_nce for b in batches]),
        jnp.concatenate([b.inter_pipe for b in batches]))


@jax.tree_util.register_dataclass
@dataclass
class MultiDesignBatch:
    """The model-axis extension of :class:`DesignBatch`: row b describes a
    *deployment* of ``n_models`` co-resident accelerators — model m of row
    b runs design ``(seg_end[b, m], ...)`` on its slice of the board.

    Segment arrays are (B, M, NS), ``inter_pipe`` is (B, M).  Each model's
    plane is a canonical DesignBatch for *that model's* layer count, so
    every single-model invariant (validate/repair/decode) applies
    per-plane via :meth:`model`.
    """

    seg_end: jnp.ndarray       # int32 (B, M, NS)
    seg_pipe: jnp.ndarray      # bool  (B, M, NS)
    seg_nce: jnp.ndarray       # int32 (B, M, NS)
    inter_pipe: jnp.ndarray    # bool  (B, M)

    @property
    def batch(self) -> int:
        """Number of deployment rows."""
        return self.seg_end.shape[0]

    @property
    def n_models(self) -> int:
        """Padded model-axis length (max_m)."""
        return self.seg_end.shape[1]

    def model(self, m: int) -> DesignBatch:
        """Model m's plane as a plain (B, NS) DesignBatch."""
        return DesignBatch(self.seg_end[:, m], self.seg_pipe[:, m],
                           self.seg_nce[:, m], self.inter_pipe[:, m])

    def take(self, idx) -> "MultiDesignBatch":
        """Row subset (numpy/jnp fancy index)."""
        return MultiDesignBatch(self.seg_end[idx], self.seg_pipe[idx],
                                self.seg_nce[idx], self.inter_pipe[idx])

    def to_numpy(self):
        """(seg_end, seg_pipe, seg_nce, inter_pipe) as host arrays."""
        return (np.asarray(self.seg_end), np.asarray(self.seg_pipe),
                np.asarray(self.seg_nce), np.asarray(self.inter_pipe))


def stack_designs(batches: list[DesignBatch],
                  max_m: int | None = None) -> MultiDesignBatch:
    """Stack per-model DesignBatches (equal B) into a MultiDesignBatch,
    padding the model axis to ``max_m`` by repeating the LAST entry — the
    same padding rule ``multinet.make_multi_tables`` applies to the stacked
    NetTables, so padded design planes always pair with matching tables.
    """
    if not batches:
        raise ValueError("stack_designs needs at least one DesignBatch")
    if len({b.batch for b in batches}) != 1:
        raise ValueError("all model DesignBatches must share one batch size")
    if max_m is None:
        max_m = len(batches)
    if len(batches) > max_m:
        raise ValueError(f"{len(batches)} models exceed max_m={max_m}")
    batches = list(batches) + [batches[-1]] * (max_m - len(batches))
    stack = lambda f: jnp.stack([getattr(b, f) for b in batches], axis=1)
    return MultiDesignBatch(stack("seg_end"), stack("seg_pipe"),
                            stack("seg_nce"), stack("inter_pipe"))


def sample_assign(rng: np.random.Generator, n: int, max_m: int,
                  n_models: int | None = None,
                  p_shared: float = 0.5) -> np.ndarray:
    """(n, max_m) random hybrid-deployment assignments: each real model is
    a shared-slice member with probability ``p_shared`` (1.0 on the gene ==
    member, 0.0 == dedicated spatial slice); padded columns stay 0.

    This is the assignment-gene twin of ``multinet.sample_shares`` — the
    raw genome the traced hybrid evaluator consumes (see
    ``multinet.partition.slice_masks`` for the canonicalization)."""
    m = max_m if n_models is None else n_models
    out = np.zeros((n, max_m), np.float32)
    out[:, :m] = (rng.random((n, m)) < p_shared).astype(np.float32)
    return out


def pad_plane(a, n: int):
    """Edge-pad one (B, ...) array to ``n`` rows by repeating the last row
    — how the share/assign planes ride along when their deployments are
    padded (``pad_deployments``) for tiling or mesh sharding."""
    pad = n - a.shape[0]
    if pad <= 0:
        return a
    return jnp.concatenate([a, jnp.repeat(a[-1:], pad, 0)], 0)


def pad_deployments(md: MultiDesignBatch, n: int) -> MultiDesignBatch:
    """Edge-pad a MultiDesignBatch to ``n`` rows (the model-axis analogue
    of ``batch_eval._pad_rows``; padded rows are evaluated and sliced off)."""
    if n <= md.batch:
        return md
    return MultiDesignBatch(pad_plane(md.seg_end, n),
                            pad_plane(md.seg_pipe, n),
                            pad_plane(md.seg_nce, n),
                            pad_plane(md.inter_pipe, n))


def encode_specs(specs: list[AcceleratorSpec], n_layers: int) -> DesignBatch:
    """AcceleratorSpecs -> one canonical (B, NS) DesignBatch (the inverse
    of :func:`decode_design`; round-trips bit-exactly)."""
    B = len(specs)
    seg_end = np.full((B, NS), n_layers, np.int32)
    seg_pipe = np.zeros((B, NS), bool)
    seg_nce = np.ones((B, NS), np.int32)
    inter = np.zeros((B,), bool)
    for b, spec in enumerate(specs):
        if len(spec.segments) > NS:
            raise ValueError(f"{spec.name}: more than {NS} segments")
        end = 0
        for s, seg in enumerate(spec.segments):
            end = seg.layer_hi + 1
            seg_end[b, s] = end
            seg_pipe[b, s] = seg.pipelined
            seg_nce[b, s] = seg.n_ces
        seg_end[b, len(spec.segments):] = end
        inter[b] = spec.inter_segment_pipelining
    return DesignBatch.from_numpy(seg_end, seg_pipe, seg_nce, inter)


def decode_design(batch: DesignBatch, i: int, n_layers: int) -> AcceleratorSpec:
    """Row i of a DesignBatch -> AcceleratorSpec (for the scalar evaluator
    or for pretty-printing in the paper's notation)."""
    seg_end = np.asarray(batch.seg_end[i])
    seg_pipe = np.asarray(batch.seg_pipe[i])
    seg_nce = np.asarray(batch.seg_nce[i])
    segs, lo, ce = [], 0, 0
    for s in range(NS):
        hi = int(seg_end[s])
        if hi <= lo:
            continue
        n = int(seg_nce[s]) if seg_pipe[s] else 1
        segs.append(SegmentSpec(lo, hi - 1, ce, ce + n - 1))
        ce += n
        lo = hi
        if hi >= n_layers:
            break
    return AcceleratorSpec(name=f"custom[{i}]", segments=tuple(segs),
                           inter_segment_pipelining=bool(batch.inter_pipe[i]))


def decode_batch(batch: DesignBatch, n_layers: int) -> list[AcceleratorSpec]:
    """Decode every row of a DesignBatch (see :func:`decode_design`)."""
    return [decode_design(batch, i, n_layers) for i in range(batch.batch)]


def validate_batch_jax(batch: DesignBatch, n_layers, *,
                       min_ces: int = 1, max_ces: int = NC) -> jnp.ndarray:
    """Traced twin of :func:`validate_batch` (``n_layers`` may be a traced
    scalar) — lets the guided search keep validity checking on device."""
    seg_end, seg_pipe, seg_nce = batch.seg_end, batch.seg_pipe, batch.seg_nce
    B = seg_end.shape[0]
    prev = jnp.concatenate(
        [jnp.zeros((B, 1), seg_end.dtype), seg_end[:, :-1]], axis=1)
    d = seg_end - prev
    active = d > 0
    ok = (d >= 0).all(1)
    ok &= (seg_end[:, -1] == n_layers) & (seg_end[:, 0] >= 1)
    ok &= (seg_end <= n_layers).all(1)
    # compact: once a segment is empty, all later ones are empty too
    prefix_active = jnp.cumprod(active.astype(jnp.int32), axis=1) > 0
    ok &= ~(active & ~prefix_active).any(1)
    ok &= (seg_nce >= 1).all(1)
    ok &= (seg_pipe == ((seg_nce > 1) & active)).all(1)
    ok &= (jnp.where(active, 1, seg_nce) == 1).all(1)   # padding nce == 1
    total = (seg_nce * active).sum(1)
    ok &= (total >= min_ces) & (total <= min(max_ces, NC))
    return ok


def repair_batch_jax(batch: DesignBatch, n_layers, *,
                     min_ces: int = 1, max_ces: int = NC) -> DesignBatch:
    """Traced constraint repair: canonicalize a batch and clamp its CE
    totals into [min_ces, min(max_ces, NC)].

    Bit-identity on already-canonical rows (sorting, compaction and both
    clamp loops are no-ops there), so the guided search can run it inside
    the jitted generation step as a safety net without perturbing the
    host-side breeding pipeline.  Deterministic (takes from the largest
    segment, gives to the first) where the host repair randomizes.

    Repair never merges segments: a row with more active segments than
    ``max_ces`` cannot reach the cap (each needs >= 1 CE) and stays
    invalid — the breeding pipeline already bounds segment counts by
    ``min(NS, max_ces)``, and ``validate_batch_jax`` screens the rest.
    """
    B = batch.batch
    end0 = jnp.clip(batch.seg_end, 0, n_layers)
    order = jnp.argsort(end0, axis=1, stable=True)
    end = jnp.take_along_axis(end0, order, axis=1)
    nce = jnp.take_along_axis(jnp.clip(batch.seg_nce, 1, NC), order, axis=1)
    end = end.at[:, -1].set(jnp.broadcast_to(n_layers, (B,)))
    prev = jnp.concatenate(
        [jnp.zeros((B, 1), end.dtype), end[:, :-1]], axis=1)
    active = end > prev
    # compaction: actives first (stable keeps ascending order), padding
    # columns forced to the canonical (n_layers, 1, False)
    corder = jnp.argsort(~active, axis=1, stable=True)
    active_s = jnp.take_along_axis(active, corder, axis=1)
    end = jnp.where(active_s, jnp.take_along_axis(end, corder, axis=1),
                    n_layers)
    nce = jnp.where(active_s, jnp.take_along_axis(nce, corder, axis=1), 1)
    prev = jnp.concatenate(
        [jnp.zeros((B, 1), end.dtype), end[:, :-1]], axis=1)
    active = end > prev

    cap = min(max_ces, NC)
    floor_ces = min(min_ces, cap)
    rows = jnp.arange(B)

    def shrink(_, nc):
        over = (nc * active).sum(1) > cap
        key = jnp.where(active & (nc > 1), nc, -1)
        col = jnp.argmax(key, axis=1)
        hit = over & (key.max(1) > 0)
        return nc.at[rows, col].add(-jnp.where(hit, 1, 0))

    def grow(_, nc):
        under = (nc * active).sum(1) < floor_ces
        col = jnp.argmax(active, axis=1)
        return nc.at[rows, col].add(jnp.where(under & active.any(1), 1, 0))

    # worst case needs NS*NC - cap decrements (all NS segments at nce=NC)
    nce = jax.lax.fori_loop(0, NS * NC, shrink, nce)
    nce = jax.lax.fori_loop(0, 2 * NC, grow, nce)
    nce = jnp.where(active, nce, 1)
    pipe = (nce > 1) & active
    return DesignBatch(end.astype(jnp.int32), pipe,
                       nce.astype(jnp.int32), batch.inter_pipe)


def validate_batch(batch: DesignBatch, n_layers: int, *,
                   min_ces: int = 1, max_ces: int = NC) -> np.ndarray:
    """Per-row canonical-form + constraint check -> bool mask (B,).

    A row is valid iff its segments are a compact, nondecreasing partition
    of [0, n_layers); ``pipe`` agrees with ``nce > 1`` on valid segments;
    padding carries (n_layers, 1, False); and the total CE count lies in
    [min_ces, min(max_ces, NC)].
    """
    seg_end, seg_pipe, seg_nce, _ = batch.to_numpy()
    prev = np.concatenate(
        [np.zeros((seg_end.shape[0], 1), seg_end.dtype), seg_end[:, :-1]],
        axis=1)
    d = seg_end - prev
    active = d > 0
    ok = (d >= 0).all(1)
    ok &= (seg_end[:, -1] == n_layers) & (seg_end[:, 0] >= 1)
    ok &= (seg_end <= n_layers).all(1)
    # compact: once a segment is empty, all later ones are empty too
    ok &= ~(active & ~np.logical_and.accumulate(active, axis=1)).any(1)
    ok &= (seg_nce >= 1).all(1)
    ok &= (seg_pipe == ((seg_nce > 1) & active)).all(1)
    ok &= (np.where(active, 1, seg_nce) == 1).all(1)   # padding nce == 1
    total = (seg_nce * active).sum(1)
    ok &= (total >= min_ces) & (total <= min(max_ces, NC))
    return ok
