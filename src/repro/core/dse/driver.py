"""End-to-end DSE drivers: random sampling and guided search behind one
``explore()`` call (paper §V-E, use case 3).

``explore(net, dev, n, strategy="random")`` reproduces the paper's blind
100k-sample sweep with the vectorized samplers; ``strategy="search"``
spends the same evaluation budget on the guided evolutionary loop and
returns the persistent Pareto archive as the front.  Both report the
whole evaluated sample so benchmarks can compare fronts side by side.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from .encoding import DesignBatch, concat_batches
from .pareto import dominates_matrix, pareto
from .samplers import sample_custom, sample_mixed
from .search import SearchConfig, SearchResult, orient, search

DEFAULT_OBJECTIVES = ("latency_s", "buffer_bytes")


@dataclass
class DSEResult:
    batch: DesignBatch
    metrics: dict[str, np.ndarray]
    seconds: float
    per_design_us: float
    strategy: str = "random"
    n_evals: int = 0
    objectives: tuple[str, ...] = DEFAULT_OBJECTIVES
    front: np.ndarray = field(default_factory=lambda: np.empty(0, np.intp))
    #: schedule-refined front metrics, front-aligned arrays — populated
    #: only by ``Session.explore(refine="schedule")`` (docs/schedule.md)
    refined: dict | None = None

    def front_points(self) -> np.ndarray:
        """Oriented (lower-better) objective points of the front rows."""
        return orient(self.metrics, self.objectives)[self.front]


def _explore(net, dev, n: int = 100_000, *,
             family: str = "custom", seed: int = 0, chunk: int = 4096,
             strategy: str = "random",
             objectives: tuple[str, ...] = DEFAULT_OBJECTIVES,
             config: SearchConfig | None = None,
             tables=None, backend: str | None = None,
             mesh=None) -> DSEResult:
    """Implementation behind ``Session.explore`` and the deprecated
    ``explore`` shim: evaluate ``n`` designs and return the sample plus
    its Pareto front.  ``mesh`` (a ``core.shard.EvalMesh``) shards the
    random sweep's design axis and turns the search into the island
    model; None keeps the single-device paths bit-identical.

    strategy="random": sample ``family`` ("custom" | "mixed" | "both") and
    evaluate, exactly the paper's use case;  strategy="search": run the
    guided multi-objective loop at the same evaluation budget, with
    ``family`` seeding the initial population/immigrants (the variation
    operators explore the full encoding space from there).  ``chunk``
    applies to the random strategy only — the search equivalent is
    ``config.pop_size``.

    A ``config``, when given, is authoritative for the search (only the
    budget comes from ``n``); the ``seed``/``objectives``/``family``
    keywords configure the search only when no config is passed.
    Caller-provided ``tables`` are used verbatim (never rebuilt); an
    explicit ``backend`` overrides the env-resolved kernel backend.
    """
    if n < 1:
        raise ValueError(f"n must be >= 1, got {n}")
    if strategy == "search":
        if config is not None:
            cfg = SearchConfig(**{**config.__dict__, "budget": n})
        else:
            cfg = SearchConfig(budget=n, seed=seed,
                               objectives=tuple(objectives),
                               init_family=family)
        objectives = cfg.objectives
        res: SearchResult = search(net, dev, cfg, tables=tables,
                                   backend=backend, mesh=mesh)
        return DSEResult(
            batch=res.batch, metrics=res.metrics, seconds=res.seconds,
            per_design_us=res.seconds / max(res.n_evals, 1) * 1e6,
            strategy="search", n_evals=res.n_evals,
            objectives=tuple(objectives), front=res.front_idx)
    if strategy != "random":
        raise ValueError(f"unknown strategy {strategy!r}")

    import jax

    from ...compat import enable_persistent_compilation_cache
    from ..batch_eval import _pad_rows, evaluate_batch, make_tables

    enable_persistent_compilation_cache()

    def sampler(rng, n_layers, b):
        if family == "custom":
            return sample_custom(rng, n_layers, b)
        if family == "mixed":
            return sample_mixed(rng, n_layers, b)
        if family == "both":
            half = b // 2
            return concat_batches([sample_custom(rng, n_layers, half),
                                   sample_mixed(rng, n_layers, b - half)])
        raise ValueError(f"unknown family {family!r}")

    rng = np.random.default_rng(seed)
    tables = make_tables(net) if tables is None else tables
    n_layers = tables.n_layers
    outs: list[dict] = []
    batches: list[DesignBatch] = []
    t0 = time.time()
    done = 0
    while done < n:
        b = min(chunk, n - done)
        batch = sampler(rng, n_layers, b)
        # pad the tail chunk to the full chunk size: a 100k-design sweep
        # compiles exactly once (padded rows are sliced off below)
        out = evaluate_batch(_pad_rows(batch, min(chunk, n)), tables, dev,
                             backend=backend, mesh=mesh)
        jax.block_until_ready(out["latency_s"])
        outs.append({k: np.asarray(v)[:b] for k, v in out.items()})
        batches.append(batch)
        done += b
    dt = time.time() - t0
    merged = concat_batches(batches)
    metrics = {k: np.concatenate([o[k] for o in outs]) for k in outs[0]}
    front = pareto(orient(metrics, objectives))
    return DSEResult(batch=merged, metrics=metrics, seconds=dt,
                     per_design_us=dt / n * 1e6, strategy="random",
                     n_evals=n, objectives=tuple(objectives), front=front)


def explore(net, dev, n: int = 100_000, *,
            family: str = "custom", seed: int = 0, chunk: int = 4096,
            strategy: str = "random",
            objectives: tuple[str, ...] = DEFAULT_OBJECTIVES,
            config: SearchConfig | None = None,
            tables=None, backend: str | None = None) -> DSEResult:
    """Deprecated shim over :func:`_explore` — use
    :meth:`repro.api.Session.explore` (bit-identical results)."""
    from .._deprecation import warn_deprecated
    warn_deprecated("explore", "repro.api.Session.explore")
    return _explore(net, dev, n, family=family, seed=seed, chunk=chunk,
                    strategy=strategy, objectives=objectives, config=config,
                    tables=tables, backend=backend)


def best_scalar_index(metrics: dict[str, np.ndarray],
                      objectives: tuple[str, ...] = DEFAULT_OBJECTIVES,
                      weights=None) -> int:
    """Index of the best design under normalized weighted scalarization —
    the single 'best sample' a random sweep would report."""
    pts = orient(metrics, objectives)
    lo, hi = pts.min(0), pts.max(0)
    norm = (pts - lo) / np.maximum(hi - lo, 1e-30)
    w = np.ones(pts.shape[1]) if weights is None else np.asarray(weights)
    return int(np.argmin(norm @ (w / w.sum())))


def dominating_indices(points: np.ndarray, ref: np.ndarray) -> np.ndarray:
    """Indices of rows that strictly dominate ``ref`` (all <=, any <)."""
    points = np.asarray(points, np.float64)
    ref = np.asarray(ref, np.float64)
    return np.nonzero(dominates_matrix(points, ref[None, :])[:, 0])[0]
