"""Vectorized design samplers — a whole DesignBatch in a handful of array ops.

The seed implementation drew one design per Python-loop iteration
(~25–60 µs/design just to *sample*); here the entire batch comes out of
batched NumPy RNG calls: random contiguous partitions via per-row key
sorting, CE allocation via balls-into-bins ``bincount``.  The per-design
loop variants are kept as ``sample_custom_loop``/``sample_mixed_loop`` —
the distribution reference for tests and the speed baseline for
``benchmarks/fig9_fig10_dse.py``.

Families (paper §V-E, use case 3):
``sample_custom`` — pipelined first block (one CE per layer), then 1..k
                    single-CE segments, coarse pipelining between;
``sample_mixed``  — superset family: every segment independently single
                    or pipelined (contains all three templates).
"""
from __future__ import annotations

import numpy as np

from .encoding import NC, NS, DesignBatch


def _rand_partitions(rng: np.random.Generator, hi: np.ndarray,
                     n_parts: np.ndarray, width: int) -> np.ndarray:
    """Batched random contiguous partitions.

    For each row i, draw ``n_parts[i] - 1`` distinct sorted cut points in
    [1, hi[i] - 1] and return the exclusive part ends padded with
    ``hi[i]``: an int32 (n, width) nondecreasing array whose first
    ``n_parts[i]`` entries end the parts (the last of them == hi[i]).
    """
    n = len(hi)
    hi = np.maximum(hi, 1)
    n_parts = np.clip(n_parts, 1, np.minimum(hi, width))
    max_cuts = int(min(width - 1, max(int(hi.max()) - 1, 0),
                       max(int(n_parts.max()) - 1, 1) if len(n_parts) else 1))
    if max_cuts == 0 or len(hi) == 0:
        return np.repeat(hi[:, None], width, axis=1).astype(np.int32)
    # positions 1..hi-1 get random keys; the n_parts-1 smallest keys win.
    # argpartition to the <= NS-1 winners, then rank just those few columns
    # (a full stable argsort of the key matrix costs 3x more).
    keys = rng.random((n, int(hi.max()) - 1), dtype=np.float32)
    if (hi != hi[0]).any():             # constant hi: every position valid
        pos = np.arange(1, keys.shape[1] + 1)
        keys[pos[None, :] > (hi - 1)[:, None]] = np.inf
    if max_cuts < keys.shape[1]:
        part = np.argpartition(keys, max_cuts - 1, axis=1)[:, :max_cuts]
    else:
        part = np.broadcast_to(np.arange(max_cuts), (n, max_cuts))
    sel_keys = np.take_along_axis(keys, part, axis=1)
    order = np.take_along_axis(part, np.argsort(sel_keys, axis=1), axis=1)
    cuts = (order + 1).astype(np.int64)
    # keep only the first n_parts-1 cuts, pad the rest with hi
    cuts = np.where(np.arange(max_cuts)[None, :] < (n_parts - 1)[:, None],
                    cuts, hi[:, None])
    cuts.sort(axis=1)
    ends = np.full((n, width), 0, np.int64)
    ends[:, :max_cuts] = cuts
    ends[:, max_cuts:] = hi[:, None]
    return ends.astype(np.int32)


def _balls_into_bins(rng: np.random.Generator, n_balls: np.ndarray,
                     n_bins: np.ndarray, width: int) -> np.ndarray:
    """Row i drops ``n_balls[i]`` balls u.a.r. into its first ``n_bins[i]``
    bins; returns int64 counts (n, width).  Matches the seed loop's
    one-increment-at-a-time distribution (multinomial, equal p)."""
    n = len(n_balls)
    m = int(n_balls.max()) if n else 0
    if n == 0 or m == 0:
        return np.zeros((n, width), np.int64)
    bins = rng.integers(0, np.maximum(n_bins, 1)[:, None], size=(n, m))
    live = np.arange(m)[None, :] < n_balls[:, None]
    flat = (np.arange(n)[:, None] * width + bins)[live]
    return np.bincount(flat, minlength=n * width).reshape(n, width)


def sample_custom(rng: np.random.Generator, n_layers: int, n: int,
                  min_ces: int = 2, max_ces: int = 11) -> DesignBatch:
    """The paper's custom family: pipelined first block (one CE per layer),
    then 1..k single-CE segments, coarse pipelining between segments."""
    if not 2 <= min_ces <= max_ces <= NC:
        raise ValueError(f"need 2 <= min_ces <= max_ces <= {NC}")
    total = rng.integers(min_ces, max_ces + 1, size=n)
    first = rng.integers(1, total)                 # CEs in the pipelined head
    # degenerate edge: the head (one layer per CE) may not consume every
    # layer — clamp so at least one tail layer remains (unless L == 1)
    first = np.minimum(first, max(n_layers - 1, 1))
    head_end = first.astype(np.int64)
    tail = n_layers - head_end                     # tail layers (>= 0)
    rest = np.clip(total - first, 1, np.maximum(tail, 1))
    ends_tail = head_end[:, None] + _rand_partitions(
        rng, np.maximum(tail, 1), rest, NS - 1)
    ends_tail = np.minimum(ends_tail, n_layers)    # tail == 0 -> all padding
    seg_end = np.concatenate([head_end[:, None], ends_tail], axis=1)
    seg_nce = np.ones((n, NS), np.int32)
    seg_nce[:, 0] = first
    seg_pipe = np.zeros((n, NS), bool)
    seg_pipe[:, 0] = first > 1
    return DesignBatch.from_numpy(seg_end, seg_pipe, seg_nce,
                                  np.ones((n,), bool))


def sample_mixed(rng: np.random.Generator, n_layers: int, n: int,
                 min_ces: int = 2, max_ces: int = 11,
                 max_segments: int = 6) -> DesignBatch:
    """Superset family: each segment independently single or pipelined."""
    if not 1 <= min_ces <= max_ces <= NC:
        raise ValueError(f"need 1 <= min_ces <= max_ces <= {NC}")
    total = rng.integers(min_ces, max_ces + 1, size=n)
    cap = np.minimum(np.minimum(max_segments, total),
                     min(n_layers, NS))
    n_seg = rng.integers(1, cap + 1)
    seg_end = _rand_partitions(rng, np.full(n, n_layers, np.int64), n_seg, NS)
    alloc = 1 + _balls_into_bins(rng, total - n_seg, n_seg, NS)
    cols = np.arange(NS)[None, :]
    active = cols < n_seg[:, None]
    seg_nce = np.where(active, alloc, 1).astype(np.int32)
    seg_pipe = active & (seg_nce > 1)
    inter = (n_seg > 1) & (rng.integers(0, 2, size=n) > 0)
    return DesignBatch.from_numpy(seg_end, seg_pipe, seg_nce, inter)


# --------------------------------------------------------------------------
# per-design reference loops (seed implementation, kept for tests and the
# sampler-speed benchmark; do not use on large n)
# --------------------------------------------------------------------------
def _random_partition(rng: np.random.Generator, n_layers: int,
                      n_parts: int) -> np.ndarray:
    """Random contiguous partition: sorted cut points (exclusive ends)."""
    cuts = rng.choice(np.arange(1, n_layers), size=n_parts - 1, replace=False)
    return np.sort(np.concatenate([cuts, [n_layers]]))


def sample_custom_loop(rng: np.random.Generator, n_layers: int, n: int,
                       min_ces: int = 2, max_ces: int = 11) -> DesignBatch:
    seg_end = np.full((n, NS), n_layers, np.int32)
    seg_pipe = np.zeros((n, NS), bool)
    seg_nce = np.ones((n, NS), np.int32)
    for i in range(n):
        total_ces = rng.integers(min_ces, max_ces + 1)
        first = rng.integers(1, total_ces)         # CEs in the pipelined head
        first = min(int(first), max(n_layers - 1, 1))   # degenerate clamp
        rest = total_ces - first                   # single-CE segments after
        head_end = int(first)                      # one layer per head CE
        tail_layers = n_layers - head_end
        rest = max(1, min(rest, max(tail_layers, 1)))
        if tail_layers > 0:
            ends = head_end + _random_partition(rng, tail_layers, rest)
            seg_end[i, 1:1 + rest] = ends
            seg_end[i, 1 + rest:] = n_layers
        seg_end[i, 0] = head_end
        seg_pipe[i, 0] = first > 1
        seg_nce[i, 0] = first
    return DesignBatch.from_numpy(seg_end, seg_pipe, seg_nce,
                                  np.ones((n,), bool))


def sample_mixed_loop(rng: np.random.Generator, n_layers: int, n: int,
                      min_ces: int = 2, max_ces: int = 11,
                      max_segments: int = 6) -> DesignBatch:
    seg_end = np.full((n, NS), n_layers, np.int32)
    seg_pipe = np.zeros((n, NS), bool)
    seg_nce = np.ones((n, NS), np.int32)
    inter = np.zeros((n,), bool)
    for i in range(n):
        total = rng.integers(min_ces, max_ces + 1)
        n_seg = int(rng.integers(1, min(max_segments, total, n_layers) + 1))
        ends = _random_partition(rng, n_layers, n_seg)
        alloc = np.ones(n_seg, np.int64)           # >= 1 CE per segment
        for _ in range(total - n_seg):
            alloc[rng.integers(0, n_seg)] += 1
        seg_end[i, :n_seg] = ends
        seg_nce[i, :n_seg] = alloc
        seg_pipe[i, :n_seg] = alloc > 1
        inter[i] = n_seg > 1 and bool(rng.integers(0, 2))
    return DesignBatch.from_numpy(seg_end, seg_pipe, seg_nce, inter)
