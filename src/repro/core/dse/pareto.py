"""Non-dominated fronts, vectorized.

``pareto`` replaces the seed's quadratic Python scan: the 2-D case (the
common (latency, buffer) / (-throughput, buffer) fronts) is a lexsort +
running-min — O(N log N) and bit-identical to the seed implementation,
including its 1e-12 slack and keep-first-duplicate convention.  Higher
dimensions use the standard iterative strict-domination filter whose inner
step is one broadcast compare (near-linear passes when the front is small,
as it is for DSE metric sets).

``ParetoArchive`` is the incremental variant the guided search loop uses:
each update refronts the (small) archived front together with the incoming
batch — one ``pareto()`` pass over archive+batch instead of over the whole
history, which keeps the archive exactly equal to ``pareto()`` of
everything seen (pairwise screening only approximates the EPS slack and
keep-first-duplicate conventions).
"""
from __future__ import annotations

import numpy as np

EPS = 1e-12


def _front_2d(points: np.ndarray) -> np.ndarray:
    order = np.lexsort((points[:, 1], points[:, 0]))
    y = points[order, 1]
    prev_min = np.concatenate(([np.inf], np.minimum.accumulate(y)[:-1]))
    keep = y < prev_min - EPS
    keep[0] = True
    return np.sort(order[keep])


def _front_nd(points: np.ndarray) -> np.ndarray:
    # lexsort first: guarantees keep-first among duplicates and that no
    # earlier point is strictly dominated by a later one
    order = np.lexsort(points.T[::-1])
    pts = points[order]
    alive = order.copy()
    i = 0
    while i < len(pts):
        nd = np.any(pts < pts[i], axis=1)   # survives iff not (weakly)
        nd[i] = True                        # dominated by pts[i]
        alive, pts = alive[nd], pts[nd]
        i = int(nd[:i].sum()) + 1
    return np.sort(alive)


def pareto(points: np.ndarray) -> np.ndarray:
    """Indices of the non-dominated front.  ``points`` (N, M): every metric
    oriented so LOWER is better.  Duplicates keep one representative."""
    points = np.asarray(points, np.float64)
    if points.ndim != 2:
        raise ValueError(f"points must be (N, M), got {points.shape}")
    if len(points) == 0:
        return np.empty((0,), np.intp)
    if points.shape[1] == 2:
        return _front_2d(points)
    return _front_nd(points)


def hypervolume_2d(points: np.ndarray, ref: np.ndarray) -> float:
    """Dominated hypervolume of a 2-D lower-is-better point set w.r.t. a
    reference (upper-bound) point — the scalar the multinet benchmarks use
    to compare searched fronts against baseline fronts.

    Points at or beyond ``ref`` in either coordinate contribute nothing.
    """
    points = np.asarray(points, np.float64)
    ref = np.asarray(ref, np.float64)
    if points.ndim != 2 or points.shape[1] != 2:
        raise ValueError(f"points must be (N, 2), got {points.shape}")
    inside = (points < ref[None, :]).all(1)
    points = points[inside]
    if len(points) == 0:
        return 0.0
    front = points[pareto(points)]
    order = np.argsort(front[:, 0], kind="stable")
    x, y = front[order, 0], front[order, 1]
    # ascending x => strictly descending y on a clean front; guard ties
    y = np.minimum.accumulate(y)
    prev_y = np.concatenate(([ref[1]], y[:-1]))
    return float(((ref[0] - x) * (prev_y - y)).sum())


def knee_point(points: np.ndarray) -> np.ndarray:
    """The span-normalized best-sum point of an oriented (lower-better)
    point set — the single 'knee' the multinet benchmarks and examples
    report from a front."""
    points = np.asarray(points, np.float64)
    span = np.maximum(np.ptp(points, 0), 1e-30)
    return points[np.argmin(((points - points.min(0)) / span).sum(1))]


def dominates_matrix(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """(len(a), len(b)) bool: a[i] dominates b[j] (all <=, any <)."""
    le = (a[:, None, :] <= b[None, :, :]).all(-1)
    lt = (a[:, None, :] < b[None, :, :]).any(-1)
    return le & lt


class ParetoArchive:
    """Persistent non-dominated archive over lower-is-better points.

    ``update`` screens a batch of candidates against the current front and
    returns the mask of candidates that entered; each archived point
    carries an integer payload (e.g. a global design index) so callers can
    recover the designs behind the front.
    """

    def __init__(self, n_obj: int):
        self.points = np.empty((0, n_obj), np.float64)
        self.payload = np.empty((0,), np.int64)

    def __len__(self) -> int:
        return len(self.points)

    def update(self, points: np.ndarray, payload: np.ndarray) -> np.ndarray:
        points = np.asarray(points, np.float64)
        payload = np.asarray(payload, np.int64)
        if len(points) == 0:
            return np.zeros((0,), bool)
        # refront the (small) archive + the incoming batch in one pass so
        # the archive is ``pareto()`` of everything seen, by construction
        # (including its 1e-12 slack / keep-first-duplicate conventions —
        # pairwise screening replicated those only approximately)
        n_arch = len(self.points)
        combined = np.concatenate([self.points, points])
        keep = pareto(combined)
        self.points = combined[keep]
        self.payload = np.concatenate([self.payload, payload])[keep]
        entered = np.zeros(len(points), bool)
        entered[keep[keep >= n_arch] - n_arch] = True
        return entered
