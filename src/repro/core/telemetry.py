"""Zero-dependency observability: spans, a metrics registry, exporters.

MCCM's second headline use case is *fine-grained evaluation that finds
performance bottlenecks*; this module makes the reproduction itself
observable the same way.  Three pieces, stdlib-only:

* **spans** — :func:`span` is a context manager recording monotonic
  wall time, nesting (thread-local stack -> parent/trace ids) and
  per-span attributes; :func:`event` attaches point-in-time events
  (retries, breaker transitions, degradations, checkpoint writes) to the
  current span;
* **metrics registry** — process-wide counters, gauges and fixed-bucket
  histograms (:func:`count` / :func:`gauge` / :func:`observe`).  The
  bucket ladder makes p50/p99/p999 derivable without storing samples;
* **exporters** — a JSONL trace file (one event per line, gated by
  ``REPRO_TELEMETRY_DIR``), a Prometheus-style text :func:`prometheus_text`
  snapshot, and the in-process :func:`snapshot` dict that
  ``Session.observability()`` merges into its reporting.

Telemetry is **off by default and cheap when off**: every entry point
checks one module-level flag and returns a shared singleton — the
disabled path allocates nothing (``tests/test_telemetry.py`` pins this,
``benchmarks/perf_gate.py`` gates the enabled-path overhead under 3% of
the ``session_cached`` point).  Enable it with the env var::

    REPRO_TELEMETRY_DIR=/tmp/traces python ...   # metrics + JSONL trace

or programmatically with :func:`enable` (no directory = in-process
metrics only).  Span catalog, metric names and the trace schema:
``docs/observability.md``.
"""
from __future__ import annotations

import contextlib
import json
import os
import threading
import time

__all__ = [
    "TELEMETRY_DIR_ENV", "enable", "disable", "enabled", "reset",
    "span", "event", "count", "gauge", "observe",
    "snapshot", "prometheus_text", "trace_path",
    "validate_trace_line", "read_trace", "profile",
    "Histogram", "DEFAULT_BUCKETS",
]

#: trace-export directory; setting it (before import or via
#: :func:`enable`) turns telemetry on with a JSONL sink
TELEMETRY_DIR_ENV = "REPRO_TELEMETRY_DIR"
#: opt-in ``jax.profiler`` deep-dive directory (see :func:`profile`)
PROFILE_ENV = "REPRO_TELEMETRY_PROFILE"

#: the one flag every instrumentation site checks first.  Plain module
#: global (not behind a lock): reads are atomic in CPython and the
#: disabled path must stay branch-cheap.
_ENABLED = False


# --------------------------------------------------------------------------
# metrics registry: counters, gauges, fixed-bucket histograms
# --------------------------------------------------------------------------
def _log_buckets(lo: float, hi: float, per_decade: int) -> tuple:
    """Log-spaced bucket upper bounds covering [lo, hi]."""
    import math
    n = int(round(math.log10(hi / lo) * per_decade))
    return tuple(lo * 10.0 ** (i / per_decade) for i in range(n + 1))

#: default histogram ladder: 1 µs .. 1000 s, 4 buckets per decade —
#: wide enough for queue waits and whole-search spans, fine enough that
#: adjacent bounds differ by ~78% (p50/p99 resolution for latencies)
DEFAULT_BUCKETS = _log_buckets(1e-6, 1e3, 4)


class Histogram:
    """Fixed-bucket histogram: percentiles without storing samples.

    ``bounds`` are ascending bucket *upper* bounds; an implicit +inf
    bucket catches the overflow.  :meth:`percentile` returns the upper
    bound of the bucket holding the q-th observation (Prometheus
    ``histogram_quantile`` semantics without interpolation), so feeding
    values that sit exactly on bucket bounds makes percentiles exact —
    the property the unit tests pin.
    """

    __slots__ = ("bounds", "counts", "total", "sum")

    def __init__(self, bounds=DEFAULT_BUCKETS):
        self.bounds = tuple(float(b) for b in bounds)
        if list(self.bounds) != sorted(set(self.bounds)):
            raise ValueError("histogram bounds must be strictly ascending")
        self.counts = [0] * (len(self.bounds) + 1)   # +1: the +inf bucket
        self.total = 0
        self.sum = 0.0

    def _bucket_of(self, value: float) -> int:
        # binary search over <= 50 bounds; bisect keeps it allocation-free
        import bisect
        return bisect.bisect_left(self.bounds, value)

    def observe(self, value: float) -> None:
        self.counts[self._bucket_of(value)] += 1
        self.total += 1
        self.sum += value

    def percentile(self, q: float) -> float:
        """Upper bound of the bucket containing the ``q``-quantile
        observation (``0 < q <= 1``); NaN when empty, +inf when the
        quantile lands in the overflow bucket."""
        if not 0.0 < q <= 1.0:
            raise ValueError(f"q must be in (0, 1], got {q}")
        if self.total == 0:
            return float("nan")
        rank = max(1, int(-(-q * self.total // 1)))   # ceil(q * total)
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank:
                return self.bounds[i] if i < len(self.bounds) \
                    else float("inf")
        return float("inf")                           # pragma: no cover

    def as_dict(self) -> dict:
        return {
            "count": self.total,
            "sum": self.sum,
            "mean": self.sum / self.total if self.total else float("nan"),
            "p50": self.percentile(0.50),
            "p90": self.percentile(0.90),
            "p99": self.percentile(0.99),
            "p999": self.percentile(0.999),
        }


class _Registry:
    """Process-wide metric store.  One lock — every mutation is a dict
    op, contention is negligible next to the evaluations being timed."""

    def __init__(self):
        self.lock = threading.Lock()
        self.counters: dict[str, float] = {}
        self.gauges: dict[str, float] = {}
        self.histograms: dict[str, Histogram] = {}

    def size(self) -> int:
        with self.lock:
            return (len(self.counters) + len(self.gauges)
                    + len(self.histograms))


_REGISTRY = _Registry()


def count(name: str, n: float = 1) -> None:
    """Increment counter ``name`` by ``n`` (no-op while disabled)."""
    if not _ENABLED:
        return
    r = _REGISTRY
    with r.lock:
        r.counters[name] = r.counters.get(name, 0) + n


def gauge(name: str, value: float) -> None:
    """Set gauge ``name`` to ``value`` (no-op while disabled)."""
    if not _ENABLED:
        return
    r = _REGISTRY
    with r.lock:
        r.gauges[name] = float(value)


def observe(name: str, value: float, bounds=DEFAULT_BUCKETS) -> None:
    """Record ``value`` into histogram ``name`` (no-op while disabled);
    ``bounds`` applies only on first touch."""
    if not _ENABLED:
        return
    r = _REGISTRY
    with r.lock:
        h = r.histograms.get(name)
        if h is None:
            h = r.histograms[name] = Histogram(bounds)
        h.observe(float(value))


# --------------------------------------------------------------------------
# spans: nested, monotonic-timed, attributed
# --------------------------------------------------------------------------
_LOCAL = threading.local()
_ID_LOCK = threading.Lock()
_NEXT_ID = [1]


def _new_id() -> int:
    with _ID_LOCK:
        i = _NEXT_ID[0]
        _NEXT_ID[0] += 1
        return i


def _stack() -> list:
    st = getattr(_LOCAL, "stack", None)
    if st is None:
        st = _LOCAL.stack = []
    return st


class _NoopSpan:
    """The shared disabled-path span: every method is a no-op and
    :func:`span` always returns THIS object, so the disabled path
    allocates nothing (identity-tested)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set_attr(self, name, value):
        pass

    def add_event(self, name, **attrs):
        pass


_NOOP = _NoopSpan()


class Span:
    """One timed unit of work.  Use via :func:`span`."""

    __slots__ = ("name", "attrs", "span_id", "parent_id", "trace_id",
                 "events", "t_wall", "_t0", "dur_s")

    def __init__(self, name: str, attrs: dict | None):
        self.name = name
        self.attrs = dict(attrs) if attrs else {}
        self.span_id = _new_id()
        self.parent_id = None
        self.trace_id = None
        self.events: list[dict] = []
        self.t_wall = 0.0
        self._t0 = 0.0
        self.dur_s = 0.0

    def set_attr(self, name: str, value) -> None:
        self.attrs[name] = value

    def add_event(self, name: str, **attrs) -> None:
        self.events.append({"name": name,
                            "t": time.perf_counter() - self._t0,
                            "attrs": attrs})

    def __enter__(self) -> "Span":
        st = _stack()
        if st:
            self.parent_id = st[-1].span_id
            self.trace_id = st[-1].trace_id
        else:
            self.trace_id = self.span_id
        st.append(self)
        self.t_wall = time.time()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.dur_s = time.perf_counter() - self._t0
        st = _stack()
        if st and st[-1] is self:
            st.pop()
        elif self in st:                   # tolerate misnested exits
            st.remove(self)
        if exc_type is not None:
            self.attrs["error"] = exc_type.__name__
        if _ENABLED:
            observe(f"span.{self.name}.s", self.dur_s)
            _write({"type": "span", "name": self.name,
                    "trace": self.trace_id, "span": self.span_id,
                    "parent": self.parent_id, "t_wall": self.t_wall,
                    "dur_s": self.dur_s, "attrs": self.attrs,
                    "events": self.events})
        return False


def span(name: str, attrs: dict | None = None):
    """A context manager timing one named unit of work.  Returns the
    shared no-op singleton while telemetry is disabled — zero allocation
    on the disabled path."""
    if not _ENABLED:
        return _NOOP
    return Span(name, attrs)


def current_span():
    """The innermost open span of this thread (the no-op singleton when
    disabled or outside any span)."""
    if not _ENABLED:
        return _NOOP
    st = _stack()
    return st[-1] if st else _NOOP


def event(name: str, attrs: dict | None = None) -> None:
    """Record a point-in-time event: attached to the current span (if
    any), counted (``event.<name>``), and written to the trace sink as
    its own line.  No-op while disabled."""
    if not _ENABLED:
        return
    count(f"event.{name}")
    st = _stack()
    parent = st[-1] if st else None
    if parent is not None:
        parent.add_event(name, **(attrs or {}))
    _write({"type": "event", "name": name,
            "trace": parent.trace_id if parent else None,
            "span": parent.span_id if parent else None,
            "t_wall": time.time(), "attrs": dict(attrs or {})})


# --------------------------------------------------------------------------
# the JSONL trace sink
# --------------------------------------------------------------------------
class _Sink:
    def __init__(self, directory: str):
        self.directory = directory
        self.lock = threading.Lock()
        self._fh = None

    @property
    def path(self) -> str:
        return os.path.join(self.directory, f"trace-{os.getpid()}.jsonl")

    def write(self, obj: dict) -> None:
        line = json.dumps(obj, separators=(",", ":"), default=str)
        with self.lock:
            if self._fh is None:
                os.makedirs(self.directory, exist_ok=True)
                self._fh = open(self.path, "a", encoding="utf-8")
            self._fh.write(line + "\n")
            self._fh.flush()

    def close(self) -> None:
        with self.lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None


_SINK: _Sink | None = None


def _write(obj: dict) -> None:
    sink = _SINK
    if sink is not None:
        sink.write(obj)


def trace_path() -> str | None:
    """The JSONL file this process is writing, or None (disabled / no
    export directory configured)."""
    return _SINK.path if _SINK is not None else None


#: required keys per trace-line type (the schema CI validates)
_SCHEMA = {
    "span": {"name": str, "trace": int, "span": int,
             "t_wall": float, "dur_s": float, "attrs": dict,
             "events": list},
    "event": {"name": str, "t_wall": float, "attrs": dict},
}


def validate_trace_line(obj) -> list[str]:
    """Schema problems of one decoded trace line ([] = valid)."""
    if not isinstance(obj, dict):
        return ["line is not an object"]
    kind = obj.get("type")
    if kind not in _SCHEMA:
        return [f"unknown type {kind!r}"]
    problems = []
    for key, typ in _SCHEMA[kind].items():
        if key not in obj:
            problems.append(f"{kind}: missing key {key!r}")
        elif typ is float:
            if not isinstance(obj[key], (int, float)):
                problems.append(f"{kind}.{key}: not a number")
        elif not isinstance(obj[key], typ):
            problems.append(f"{kind}.{key}: not a {typ.__name__}")
    if kind == "span" and not problems:
        if obj["dur_s"] < 0:
            problems.append("span.dur_s: negative")
        for ev in obj["events"]:
            if not isinstance(ev, dict) or "name" not in ev:
                problems.append("span.events: malformed entry")
    return problems


def read_trace(path: str) -> list[dict]:
    """Decode + schema-validate a JSONL trace; raises ``ValueError`` on
    the first invalid line."""
    out = []
    with open(path, encoding="utf-8") as f:
        for i, line in enumerate(f, 1):
            if not line.strip():
                continue
            obj = json.loads(line)
            problems = validate_trace_line(obj)
            if problems:
                raise ValueError(f"{path}:{i}: {'; '.join(problems)}")
            out.append(obj)
    return out


# --------------------------------------------------------------------------
# snapshots + Prometheus export
# --------------------------------------------------------------------------
def snapshot() -> dict:
    """The in-process metric state: ``{counters, gauges, histograms}``
    (histograms summarized as count/sum/mean/p50/p90/p99/p999)."""
    r = _REGISTRY
    with r.lock:
        return {
            "enabled": _ENABLED,
            "counters": dict(r.counters),
            "gauges": dict(r.gauges),
            "histograms": {k: h.as_dict()
                           for k, h in r.histograms.items()},
        }


def _prom_name(name: str) -> str:
    out = "".join(c if c.isalnum() or c == "_" else "_" for c in name)
    return f"repro_{out}"


def prometheus_text() -> str:
    """A Prometheus text-exposition snapshot of the registry (counters,
    gauges, and histograms with cumulative ``le`` buckets)."""
    r = _REGISTRY
    lines = []
    with r.lock:
        for name in sorted(r.counters):
            p = _prom_name(name)
            lines += [f"# TYPE {p} counter", f"{p} {r.counters[name]:g}"]
        for name in sorted(r.gauges):
            p = _prom_name(name)
            lines += [f"# TYPE {p} gauge", f"{p} {r.gauges[name]:g}"]
        for name in sorted(r.histograms):
            h = r.histograms[name]
            p = _prom_name(name)
            lines.append(f"# TYPE {p} histogram")
            cum = 0
            for bound, c in zip(h.bounds, h.counts):
                cum += c
                lines.append(f'{p}_bucket{{le="{bound:g}"}} {cum}')
            lines.append(f'{p}_bucket{{le="+Inf"}} {h.total}')
            lines.append(f"{p}_sum {h.sum:g}")
            lines.append(f"{p}_count {h.total}")
    return "\n".join(lines) + "\n"


# --------------------------------------------------------------------------
# lifecycle
# --------------------------------------------------------------------------
def enabled() -> bool:
    return _ENABLED


def enable(directory: str | None = None) -> None:
    """Turn telemetry on.  With ``directory`` (or ``REPRO_TELEMETRY_DIR``
    already set) spans/events also export to a JSONL trace file there;
    without one, only the in-process registry records."""
    global _ENABLED, _SINK
    directory = directory or os.environ.get(TELEMETRY_DIR_ENV) or None
    if directory:
        if _SINK is None or _SINK.directory != directory:
            if _SINK is not None:
                _SINK.close()
            _SINK = _Sink(directory)
    _ENABLED = True


def disable() -> None:
    """Turn telemetry off (the registry keeps its contents; see
    :func:`reset`)."""
    global _ENABLED, _SINK
    _ENABLED = False
    if _SINK is not None:
        _SINK.close()
        _SINK = None


def reset() -> None:
    """Clear every counter/gauge/histogram (test isolation helper)."""
    r = _REGISTRY
    with r.lock:
        r.counters.clear()
        r.gauges.clear()
        r.histograms.clear()


# env-gated activation: REPRO_TELEMETRY_DIR set at import time = on
if os.environ.get(TELEMETRY_DIR_ENV):
    enable(os.environ[TELEMETRY_DIR_ENV])


# --------------------------------------------------------------------------
# opt-in deep dive: jax.profiler
# --------------------------------------------------------------------------
@contextlib.contextmanager
def profile(directory: str | None = None):
    """Wrap a block in ``jax.profiler.trace`` (TensorBoard-readable)
    when a directory is given or ``REPRO_TELEMETRY_PROFILE`` is set;
    otherwise a no-op.  Import failures degrade to a no-op too — the
    telemetry layer itself stays dependency-free."""
    directory = directory or os.environ.get(PROFILE_ENV) or None
    if not directory:
        yield
        return
    try:
        import jax
        ctx = jax.profiler.trace(directory)
    except Exception:  # noqa: BLE001 — profiler unavailable: stay silent
        yield
        return
    with span("telemetry.profile", {"dir": directory}), ctx:
        yield
