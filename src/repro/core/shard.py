"""Design-axis sharding: one mesh, many devices, same numbers.

``EvalMesh`` partitions the *design axis* of the evaluation programs
across devices with ``shard_map``: NetTables / DeviceTables are
replicated (small traced pytrees), ``DesignBatch`` rows are sharded, and
tails are padded to ``ndevices x tile`` so every shard sees identical
static shapes.  All evaluator arithmetic is row-local (reductions only
run *within* a design row), so the sharded program is bit-identical to
the single-device one — and on one device the mesh simply delegates to
the existing jits (zero extra compiles).

Device discovery honours ``REPRO_MESH_DEVICES`` (docs/perf.md).  For CPU
scaling runs the module force-splits the host platform into that many
devices, provided it is imported before jax initialises its backends —
the one supported path; callers never craft ``XLA_FLAGS`` by hand.
"""
from __future__ import annotations

import os
from functools import partial

import numpy as np

MESH_ENV = "REPRO_MESH_DEVICES"
MESH_AXIS = "designs"
_FORCE_FLAG = "--xla_force_host_platform_device_count"


def force_host_devices(n: int) -> bool:
    """Ask XLA for ``n`` host (CPU) devices.  Must run before jax
    initialises its backends; importing this module with
    ``REPRO_MESH_DEVICES`` set does it for you.  No-op (returns True)
    when a forced count is already in place; returns False for n < 2."""
    if n < 2:
        return False
    flags = os.environ.get("XLA_FLAGS", "")
    if _FORCE_FLAG in flags:
        return True
    os.environ["XLA_FLAGS"] = f"{flags} {_FORCE_FLAG}={n}".strip()
    return True


def env_mesh_devices() -> int | None:
    """Parse ``REPRO_MESH_DEVICES`` (None when unset/empty)."""
    raw = os.environ.get(MESH_ENV)
    if not raw:
        return None
    n = int(raw)
    if n < 1:
        raise ValueError(f"{MESH_ENV} must be >= 1, got {raw!r}")
    return n


# Applied at import time so ``REPRO_MESH_DEVICES=4 python ...`` is the
# whole multi-device recipe on CPU hosts.  Harmless under real
# accelerator backends — the flag only affects the host platform.
_env_n = os.environ.get(MESH_ENV, "")
if _env_n.isdigit():
    force_host_devices(int(_env_n))

import jax                                           # noqa: E402
from jax.sharding import Mesh, PartitionSpec as P    # noqa: E402

from ..compat import shard_map                       # noqa: E402
from . import telemetry                              # noqa: E402
from .batch_eval import (                            # noqa: E402
    DEFAULT_TILE, _pad_rows, evaluate_batch_traced, padded_rows)
from .cache import (                                 # noqa: E402
    DEFAULT_MAX_JITS, JITS_ENV, BoundedLRU, env_bound)

#: every *live* sharded jit (name, jitted fn) — Session.compile_stats
#: sums ``_cache_size()`` over this to count per-mesh compiles.
_REGISTRY: list[tuple[str, object]] = []
#: compile counts of evicted jits, folded in at eviction time so
#: ``mesh_compile_counts`` stays monotone across LRU turnover (a cache
#: that forgets a program must not forget that it was compiled).
_EVICTED_COUNTS: dict[str, int] = {}


def mesh_compile_counts() -> dict[str, int]:
    """Compiled-program count per sharded entry point, over all meshes —
    live jits plus everything evicted by the bounded registry (monotone:
    eviction frees the program, not its history)."""
    out: dict[str, int] = dict(_EVICTED_COUNTS)
    for name, fn in _REGISTRY:
        out[name] = out.get(name, 0) + fn._cache_size()
    return out


class EvalMesh:
    """A 1-D device mesh over the design axis.

    ``ndevices`` resolution order: explicit argument, then
    ``REPRO_MESH_DEVICES``, then every visible device.  A request beyond
    the visible device count clamps (recorded in ``requested``) — asking
    for 8 devices on a 1-device host lands on the single-device fallback,
    it is not an error.
    """

    def __init__(self, ndevices: int | None = None, *, devices=None,
                 max_jits: int | None = None):
        if devices is None:
            avail = jax.devices()
            want = ndevices if ndevices is not None else env_mesh_devices()
            want = len(avail) if want is None else want
            if want < 1:
                raise ValueError(f"ndevices must be >= 1, got {want}")
            self.requested = want
            devices = avail[:min(want, len(avail))]
        else:
            devices = list(devices)
            self.requested = len(devices)
        self.devices = tuple(devices)
        self._mesh: Mesh | None = None
        # bounded: a long-lived server cycling many (backend, tile, ...)
        # statics must not pin every sharded program forever.  Eviction
        # drops the program (a re-request recompiles) but folds its
        # compile count into _EVICTED_COUNTS so observability stays
        # monotone.  max_jits <= 0 disables eviction.
        if max_jits is None:
            max_jits = env_bound(JITS_ENV, DEFAULT_MAX_JITS)
        self._jits = BoundedLRU(max_jits, on_evict=self._on_evict_jit)

    @property
    def jit_evictions(self) -> int:
        """Sharded programs dropped by this mesh's bounded jit registry."""
        return self._jits.evictions

    @property
    def max_jits(self) -> int:
        return self._jits.maxsize

    def _on_evict_jit(self, key, jitted) -> None:
        name = key[0]
        _EVICTED_COUNTS[name] = _EVICTED_COUNTS.get(name, 0) \
            + jitted._cache_size()
        for i, (n, fn) in enumerate(_REGISTRY):
            if fn is jitted:
                del _REGISTRY[i]
                break
        telemetry.count("shard.jit_evictions")
        telemetry.event("shard.jit_evict",
                        {"name": name, "ndevices": self.ndevices})

    @property
    def ndevices(self) -> int:
        return len(self.devices)

    @property
    def is_sharded(self) -> bool:
        return self.ndevices > 1

    @property
    def mesh(self) -> Mesh:
        if self._mesh is None:
            self._mesh = Mesh(np.asarray(self.devices), (MESH_AXIS,))
        return self._mesh

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (f"EvalMesh(ndevices={self.ndevices}, "
                f"requested={self.requested})")

    def padded_rows(self, B: int, tile: int = DEFAULT_TILE) -> int:
        """Rows actually executed for a B-design sharded call."""
        return padded_rows(B, tile, self.ndevices)

    # -- generic sharded-jit factory ------------------------------------
    def shard_jit(self, name: str, fn, *, replicated=(), static_kwargs=None,
                  donate_argnums=()):
        """``jit(shard_map(partial(fn, **static_kwargs)))`` with
        positional arg ``i`` replicated when ``i in replicated`` and
        row-sharded otherwise; memoised per (name, statics) so repeat
        calls reuse the compiled program."""
        statics = tuple(sorted((static_kwargs or {}).items()))
        key = (name, statics)
        cached = self._jits.get(key)      # refreshes LRU recency on a hit
        if cached is not None:
            return cached
        body = partial(fn, **dict(statics)) if statics else fn
        mesh = self.mesh
        repl = frozenset(replicated)

        def run(*args):
            specs = tuple(P() if i in repl else P(MESH_AXIS)
                          for i in range(len(args)))
            return shard_map(body, mesh=mesh, in_specs=specs,
                             out_specs=P(MESH_AXIS))(*args)

        jitted = jax.jit(run, donate_argnums=donate_argnums)
        _REGISTRY.append((name, jitted))
        self._jits.put(key, jitted)
        telemetry.count("shard.jit_builds")
        telemetry.event("shard.jit_build",
                        {"name": name, "ndevices": self.ndevices})
        return jitted

    # -- the evaluator entry point --------------------------------------
    def evaluate_padded(self, design, tables, devt, *, backend, tile,
                        fm_tile_rows, pes_hint_static, design_tile):
        """Sharded ``evaluate_batch``: pad rows to ``ndevices x tile``,
        shard the design axis, slice the pad back off.  Each shard holds
        a whole number of ``lax.map`` tiles, so tile grouping — and hence
        every intermediate — matches the single-device program exactly."""
        B = design.batch
        run = self.shard_jit(
            "evaluate_batch", evaluate_batch_traced, replicated=(1, 2),
            static_kwargs=dict(backend=backend, tile=tile,
                               fm_tile_rows=fm_tile_rows,
                               pes_hint_static=pes_hint_static,
                               design_tile=design_tile))
        padded = _pad_rows(design, self.padded_rows(B, tile))
        out = run(padded, tables, devt)
        return {k: v[:B] for k, v in out.items()}
