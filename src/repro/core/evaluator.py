"""Scalar MCCM facade: notation/spec + CNN + board -> Metrics.

This is the exact (reference) evaluation path; ``batch_eval`` mirrors it in
vectorised JAX for design-space exploration.

``evaluate_design`` is kept as a deprecated shim — the supported entry
point is :meth:`repro.api.Session.evaluate`, which delegates to the same
implementation (``_evaluate_design``) bit for bit.
"""
from __future__ import annotations

from ._deprecation import warn_deprecated
from .accelerator import ConcreteAccelerator, Metrics, evaluate
from .builder import BuilderOptions, build
from .device import DeviceSpec
from .notation import AcceleratorSpec, parse
from .workload import Network


def _evaluate_design(
    design: str | AcceleratorSpec,
    net: Network,
    dev: DeviceSpec,
    opts: BuilderOptions | None = None,
    inter_segment_pipelining: bool = True,
) -> Metrics:
    """Implementation behind ``Session.evaluate`` (scalar) and the
    deprecated ``evaluate_design`` shim."""
    if isinstance(design, str):
        spec = parse(design, len(net), inter_segment_pipelining=inter_segment_pipelining)
    else:
        spec = design
    acc = build(spec, net, dev, opts)
    return evaluate(acc)


def evaluate_design(
    design: str | AcceleratorSpec,
    net: Network,
    dev: DeviceSpec,
    opts: BuilderOptions | None = None,
    inter_segment_pipelining: bool = True,
) -> Metrics:
    warn_deprecated("evaluate_design", "repro.api.Session.evaluate")
    return _evaluate_design(design, net, dev, opts,
                            inter_segment_pipelining=inter_segment_pipelining)


def build_design(
    design: str | AcceleratorSpec,
    net: Network,
    dev: DeviceSpec,
    opts: BuilderOptions | None = None,
    inter_segment_pipelining: bool = True,
) -> ConcreteAccelerator:
    # forwards inter_segment_pipelining exactly as _evaluate_design does,
    # so a built accelerator always agrees with its evaluated metrics
    if isinstance(design, str):
        spec = parse(design, len(net), inter_segment_pipelining=inter_segment_pipelining)
    else:
        spec = design
    return build(spec, net, dev, opts)
