"""Scalar MCCM facade: notation/spec + CNN + board -> Metrics.

This is the exact (reference) evaluation path; ``batch_eval`` mirrors it in
vectorised JAX for design-space exploration.
"""
from __future__ import annotations

from .accelerator import ConcreteAccelerator, Metrics, evaluate
from .builder import BuilderOptions, build
from .device import DeviceSpec
from .notation import AcceleratorSpec, parse
from .workload import Network


def evaluate_design(
    design: str | AcceleratorSpec,
    net: Network,
    dev: DeviceSpec,
    opts: BuilderOptions | None = None,
    inter_segment_pipelining: bool = True,
) -> Metrics:
    if isinstance(design, str):
        spec = parse(design, len(net), inter_segment_pipelining=inter_segment_pipelining)
    else:
        spec = design
    acc = build(spec, net, dev, opts)
    return evaluate(acc)


def build_design(
    design: str | AcceleratorSpec,
    net: Network,
    dev: DeviceSpec,
    opts: BuilderOptions | None = None,
) -> ConcreteAccelerator:
    if isinstance(design, str):
        spec = parse(design, len(net))
    else:
        spec = design
    return build(spec, net, dev, opts)
