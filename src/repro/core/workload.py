"""Workload records: the layer-level inputs to MCCM.

A *layer* here is the unit the paper's equations operate on: a convolution
(standard, depthwise, or pointwise) with its six loop dimensions
(F = filters/out-channels, C = in-channels, KH, KW, OH, OW) plus the sizes
MCCM needs (weights, IFMs, OFMs, MACs).

Everything is counted in *elements*; byte conversion happens at the device
level (``DeviceSpec.wordbytes``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Iterable, Sequence

# The six disjoint dimensions (DD in Eq. 1) of a convolution loop nest.
DIMS = ("f", "c", "kh", "kw", "oh", "ow")


@dataclass(frozen=True)
class ConvLayer:
    """One convolutional layer's workload record."""

    index: int
    name: str
    kind: str  # 'conv' | 'dw' | 'pw'
    in_ch: int
    out_ch: int
    kh: int
    kw: int
    stride: int
    ih: int  # IFM height
    iw: int  # IFM width
    residual: bool = False  # FMs buffer must hold an extra copy (Eq. 4 note)
    padding: str = "same"

    # ---- derived geometry ----
    @property
    def oh(self) -> int:
        if self.padding == "same":
            return -(-self.ih // self.stride)
        return (self.ih - self.kh) // self.stride + 1

    @property
    def ow(self) -> int:
        if self.padding == "same":
            return -(-self.iw // self.stride)
        return (self.iw - self.kw) // self.stride + 1

    # ---- sizes (elements) ----
    @property
    def ifm_size(self) -> int:
        return self.in_ch * self.ih * self.iw

    @property
    def ofm_size(self) -> int:
        return self.out_ch * self.oh * self.ow

    @property
    def fms_size(self) -> int:
        """IFMs + OFMs (+ residual copy) held concurrently — Eq. 4 term."""
        extra = self.ofm_size if self.residual else 0
        return self.ifm_size + self.ofm_size + extra

    @property
    def weights_size(self) -> int:
        if self.kind == "dw":
            return self.out_ch * self.kh * self.kw
        return self.out_ch * self.in_ch * self.kh * self.kw

    @property
    def macs(self) -> int:
        return self.weights_size * self.oh * self.ow

    # ---- Eq. 1 loop dimensions ----
    def dims(self) -> dict[str, int]:
        c = 1 if self.kind == "dw" else self.in_ch
        return {
            "f": self.out_ch,
            "c": c,
            "kh": self.kh,
            "kw": self.kw,
            "oh": self.oh,
            "ow": self.ow,
        }

    def replace(self, **kw) -> "ConvLayer":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class Network:
    """A CNN as MCCM sees it: an ordered list of conv layers."""

    name: str
    layers: tuple[ConvLayer, ...]

    def __post_init__(self):
        for i, l in enumerate(self.layers):
            if l.index != i:
                raise ValueError(f"layer {l.name} has index {l.index}, expected {i}")

    def __len__(self) -> int:
        return len(self.layers)

    def __getitem__(self, i):
        return self.layers[i]

    def __iter__(self):
        return iter(self.layers)

    # ---- aggregates ----
    @property
    def total_macs(self) -> int:
        return sum(l.macs for l in self.layers)

    @property
    def total_weights(self) -> int:
        return sum(l.weights_size for l in self.layers)

    def slice(self, lo: int, hi: int) -> Sequence[ConvLayer]:
        """Layers lo..hi inclusive (0-based)."""
        return self.layers[lo : hi + 1]


def make_network(name: str, specs: Iterable[dict]) -> Network:
    """Build a Network from plain dicts (used by the CNN zoo)."""
    layers = []
    for i, s in enumerate(specs):
        layers.append(ConvLayer(index=i, **s))
    return Network(name=name, layers=tuple(layers))
