"""Batch-size-aware coalescing for the megabatch drain.

``Session.submit`` queues requests of wildly different shapes: a point
probe of one design next to a 10k-design sweep, across mixed CNNs and
boards.  The drain used to evaluate one padded chunk *per request* — a
stream of single-design probes each paid a full ``tile``-row dispatch.
This module plans the megabatch instead:

* **merge** — requests that share evaluation state (same ``NetTables``
  object + same board) pack into shared chunks, so k tiny probes cost one
  padded dispatch instead of k;
* **split** — a request larger than the compiled chunk size splits at
  chunk boundaries (the compiled-shape ceiling is explicit in the plan,
  not buried in ``_evaluate_specs``'s inner loop);
* **bound** — every chunk pads to the same bucket ladder the evaluator
  compiles (``tile x ndevices x 2^k``, capped at ``chunk``), so
  coalescing never mints a shape the ladder doesn't already serve — and
  therefore never forks a compile (property-tested in
  ``tests/test_serve_coalesce.py``).

The planner is a pure function of ``(group, size)`` pairs — deterministic
next-fit packing that preserves within-request order — so the exactly-
once / ordering / padding guarantees are testable without a session.
:class:`ArrivalEstimator` is the adaptive linger policy that rides on
top: the drain waits ~2 observed inter-arrival times for peers, never
more than the configured cap (``docs/serving.md``).
"""
from __future__ import annotations

from dataclasses import dataclass


def ladder_pad(rows: int, chunk: int, tile: int, ndevices: int = 1) -> int:
    """Padded size of a ``rows``-design chunk: the smallest bucket-ladder
    shape (``tile x ndevices x 2^k``) holding it, capped at ``chunk`` —
    the compiled-shape ceiling.  Mirrors ``batch_eval._bucket`` so the
    plan's shapes are exactly the shapes the evaluator compiles."""
    if rows > chunk:
        raise ValueError(f"chunk rows {rows} exceed the compiled chunk "
                         f"size {chunk}")
    n = tile * max(int(ndevices), 1)
    while n < rows:
        n *= 2
    return min(n, chunk)


@dataclass(frozen=True)
class Part:
    """One request's contribution to a chunk: specs ``[lo, hi)`` of
    request ``req`` (an index into the planner's input order)."""

    req: int
    lo: int
    hi: int

    def __len__(self) -> int:
        return self.hi - self.lo


@dataclass(frozen=True)
class Chunk:
    """One padded dispatch unit: same-group parts, packed in order."""

    group: object
    parts: tuple[Part, ...]
    rows: int                    # sum of part lengths
    pad: int                     # padded rows (ladder shape, <= chunk)


@dataclass(frozen=True)
class Plan:
    """The megabatch plan: chunks in execution order plus summary
    counters (``merges`` = requests sharing a chunk with another,
    ``splits`` = requests spanning more than one chunk)."""

    chunks: tuple[Chunk, ...]
    merges: int
    splits: int

    @property
    def shared_pad(self) -> int:
        """One shared padded shape across the whole megabatch (what
        ``_evaluate_specs_multi`` pads every job to, so mixed chunk sizes
        still reuse one compiled program)."""
        return max((c.pad for c in self.chunks), default=0)


def plan_megabatch(requests, chunk: int, tile: int,
                   ndevices: int = 1) -> Plan:
    """Plan chunks for ``requests`` — a sequence of ``(group, size)``
    pairs in queue order (``group`` must be hashable; requests merge only
    within a group).

    Deterministic next-fit packing: each request's specs append to its
    group's open chunk, splitting at the ``chunk`` boundary.  Guarantees
    (property-tested): every (request, spec) position appears exactly
    once; a request's parts are emitted in spec order; chunks hold one
    group only; ``rows <= pad <= chunk`` with ``pad`` on the bucket
    ladder."""
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    if tile < 1:
        raise ValueError(f"tile must be >= 1, got {tile}")
    open_parts: dict[object, list[Part]] = {}
    open_rows: dict[object, int] = {}
    order: list[object] = []          # group first-appearance order
    closed: list[Chunk] = []
    split_reqs: set[int] = set()

    def close(group) -> None:
        parts = open_parts.pop(group, [])
        rows = open_rows.pop(group, 0)
        if parts:
            closed.append(Chunk(group, tuple(parts), rows,
                                ladder_pad(rows, chunk, tile, ndevices)))

    for i, (group, size) in enumerate(requests):
        size = int(size)
        if size < 1:
            raise ValueError(f"request {i} has size {size}; empty "
                             f"requests are rejected at submit()")
        if group not in open_parts:
            open_parts[group] = []
            open_rows[group] = 0
            order.append(group)
        lo = 0
        while lo < size:
            space = chunk - open_rows[group]
            if space == 0:
                close(group)
                open_parts[group] = []
                open_rows[group] = 0
                space = chunk
            take = min(size - lo, space)
            open_parts[group].append(Part(i, lo, lo + take))
            open_rows[group] += take
            if take < size - lo or lo > 0:
                split_reqs.add(i)
            lo += take

    for group in order:
        close(group)

    merges = 0
    for c in closed:
        reqs_in_chunk = {p.req for p in c.parts}
        if len(reqs_in_chunk) > 1:
            merges += len(reqs_in_chunk)
    return Plan(tuple(closed), merges=merges, splits=len(split_reqs))


def validate_plan(plan: Plan, requests, chunk: int, tile: int,
                  ndevices: int = 1) -> list[str]:
    """Every violated guarantee as a human-readable string (empty = the
    plan is sound).  The property tests drive arbitrary request streams
    through this."""
    problems: list[str] = []
    seen: dict[int, int] = {}         # req -> next expected spec index
    for ci, c in enumerate(plan.chunks):
        rows = sum(len(p) for p in c.parts)
        if rows != c.rows:
            problems.append(f"chunk {ci}: rows {c.rows} != parts {rows}")
        if c.rows > c.pad:
            problems.append(f"chunk {ci}: rows {c.rows} > pad {c.pad}")
        if c.pad > chunk:
            problems.append(f"chunk {ci}: pad {c.pad} exceeds compiled "
                            f"chunk {chunk}")
        if c.pad != ladder_pad(c.rows, chunk, tile, ndevices):
            problems.append(f"chunk {ci}: pad {c.pad} off the bucket "
                            f"ladder")
        for p in c.parts:
            group, size = requests[p.req]
            if group != c.group:
                problems.append(f"chunk {ci}: request {p.req} of group "
                                f"{group!r} in chunk of {c.group!r}")
            want = seen.get(p.req, 0)
            if p.lo != want:
                problems.append(f"request {p.req}: part starts at "
                                f"{p.lo}, expected {want} (reorder/gap)")
            if not (0 <= p.lo < p.hi <= size):
                problems.append(f"request {p.req}: part [{p.lo},{p.hi}) "
                                f"outside size {size}")
            seen[p.req] = p.hi
    for i, (_, size) in enumerate(requests):
        if seen.get(i, 0) != size:
            problems.append(f"request {i}: covered {seen.get(i, 0)} of "
                            f"{size} specs")
    return problems


class ArrivalEstimator:
    """Adaptive linger from the observed request arrival rate.

    Keeps an EWMA of submit inter-arrival times; the drain lingers
    ``gain x`` that estimate (time for ~``gain`` more peers to arrive),
    clamped to ``[0, max_s]``.  Under a hot stream the window shrinks
    toward the true inter-arrival gap — latency tracks load instead of a
    fixed worst-case linger; when traffic is sparse the cap bounds the
    idle wait.  Pure host arithmetic, fed monotonic timestamps, so the
    policy is testable without a clock."""

    def __init__(self, alpha: float = 0.2, gain: float = 2.0):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.alpha = alpha
        self.gain = gain
        self._last_t: float | None = None
        self._dt: float | None = None   # EWMA inter-arrival seconds

    def observe(self, t: float) -> None:
        """Record one arrival at monotonic time ``t``."""
        if self._last_t is not None:
            dt = max(t - self._last_t, 0.0)
            self._dt = dt if self._dt is None \
                else (1.0 - self.alpha) * self._dt + self.alpha * dt
        self._last_t = t

    @property
    def interarrival_s(self) -> float | None:
        return self._dt

    def linger(self, max_s: float) -> float:
        """The linger window for the next drain: ``gain x`` the EWMA
        inter-arrival, clamped to ``[0, max_s]`` (``max_s`` before any
        estimate exists — a cold queue waits the full window once)."""
        if max_s <= 0.0:
            return 0.0
        if self._dt is None:
            return max_s
        return min(max(self.gain * self._dt, 0.0), max_s)
