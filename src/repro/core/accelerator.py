"""Bottom-up composition: from block models to a full accelerator (paper §IV-B).

Given a *concrete* accelerator (CE resources already distributed by the
Builder), evaluates latency, throughput, on-chip buffers and off-chip accesses
using generalized versions of Eqs. 1-7, i.e. Eqs. 8-9 and the §IV-B1 rules:

* inter-segment pipelining  -> throughput = 1 / slowest-stage busy time,
  latency = sum of segment latencies (+ inter-segment communication);
* no inter-segment pipelining -> throughput = 1 / latency;
* a CE serving multiple segments is busy for the sum of those segments
  (its buffer was sized for the worst case by the Builder, Eq. 8);
* inter-segment double buffers spill to off-chip when they do not fit,
  adding 2x their size to accesses (Eq. 9).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

from .blocks import CE, BlockResult, eval_pipelined, eval_single_ce
from .device import DeviceSpec
from .notation import AcceleratorSpec, SegmentSpec
from .workload import Network


@dataclass
class ConcreteSegment:
    spec: SegmentSpec
    ces: list[CE]                       # one (single) or many (pipelined)
    weights_resident: bool | None = None  # pipelined blocks only


@dataclass
class ConcreteAccelerator:
    """Builder output: spec + concrete resources, ready to evaluate."""

    spec: AcceleratorSpec
    network: Network
    device: DeviceSpec
    segments: list[ConcreteSegment]
    inter_seg_onchip: list[bool] = field(default_factory=list)  # per boundary
    inter_seg_buffer_bytes: list[int] = field(default_factory=list)


@dataclass
class SegmentMetrics:
    index: int
    n_layers: int
    latency_s: float
    busy_s: float
    compute_s: float
    mem_s: float
    buffer_bytes: int
    access_bytes: float
    utilization: float


@dataclass
class Metrics:
    """The four headline MCCM outputs + fine-grained breakdowns.

    ``buffer_bytes`` is the Eq. 8 *requirement* — the on-chip buffer the
    design needs to guarantee minimum off-chip accesses (Σ per-segment
    Eq. 4/5 + all inter-segment double buffers), the quantity the paper
    reports in Table I/V and Figs. 8–10.  ``buffer_alloc_bytes`` is what
    the Builder could actually allocate within the board's BRAM (used by
    the access model, Eq. 6/7)."""

    latency_s: float
    throughput_ips: float
    buffer_bytes: int              # requirement (Eq. 8)
    buffer_alloc_bytes: int        # allocation within the board budget
    access_bytes: float
    weight_access_bytes: float
    fm_access_bytes: float
    per_segment: list[SegmentMetrics]
    blocks: list[BlockResult]
    #: steady-state busy seconds charged to each physical CE id (the
    #: Eq. 8 busy-time ledger; its max bounds pipelined throughput) —
    #: what `repro.telemetry.report` ranks for bottleneck attribution
    ce_busy_s: dict[int, float] = field(default_factory=dict)

    def as_dict(self) -> dict:
        return {
            "latency_s": self.latency_s,
            "throughput_ips": self.throughput_ips,
            "buffer_mib": self.buffer_bytes / 2**20,
            "access_mb": self.access_bytes / 1e6,
        }


def evaluate(acc: ConcreteAccelerator) -> Metrics:
    dev, net, spec = acc.device, acc.network, acc.spec
    bps = dev.off_chip_gbps * 1e9

    blocks: list[BlockResult] = []
    seg_metrics: list[SegmentMetrics] = []
    # steady-state busy time charged to each physical CE id (Eq. 8 note:
    # one CE may serve several segments -> its busy times add up)
    ce_busy: dict[int, float] = {}

    for i, (sseg, cseg) in enumerate(zip(spec.segments, acc.segments)):
        layers = net.slice(sseg.layer_lo, sseg.layer_hi)
        prev_onchip = i > 0 and acc.inter_seg_onchip[i - 1]
        if sseg.pipelined:
            res = eval_pipelined(
                layers, cseg.ces, dev, weights_resident=cseg.weights_resident
            )
        else:
            res = eval_single_ce(layers, cseg.ces[0], dev, ifm_onchip_first=prev_onchip)
        blocks.append(res)
        for off, ce_id in enumerate(range(sseg.ce_lo, sseg.ce_hi + 1)):
            if sseg.pipelined:
                # per-CE busy recorded inside block busy (max); approximate by
                # charging the block busy to its slowest CE and 0 to others —
                # the block-level max is what bounds throughput.
                ce_busy[ce_id] = ce_busy.get(ce_id, 0.0)
            else:
                ce_busy[ce_id] = ce_busy.get(ce_id, 0.0) + res.busy_cycles
        if sseg.pipelined:
            slow = sseg.ce_lo  # representative slot for the block max
            ce_busy[slow] = ce_busy.get(slow, 0.0) + res.busy_cycles

        comp = sum(r.compute_cycles for r in res.per_layer)
        mem = sum(r.mem_cycles for r in res.per_layer)
        util = (
            sum(r.utilization * r.layer.macs for r in res.per_layer)
            / max(sum(r.layer.macs for r in res.per_layer), 1)
        )
        seg_metrics.append(
            SegmentMetrics(
                index=i,
                n_layers=sseg.n_layers,
                latency_s=res.latency_cycles / dev.clock_hz,
                busy_s=res.busy_cycles / dev.clock_hz,
                compute_s=comp / dev.clock_hz,
                mem_s=mem / dev.clock_hz,
                buffer_bytes=res.buffer_bytes,
                access_bytes=res.access_bytes,
                utilization=util,
            )
        )

    # ---- interfaces: mandatory first-IFM load / last-OFM store + Eq. 9 ----
    wb = dev.wordbytes
    access = sum(b.access_bytes for b in blocks)
    w_access = sum(b.weight_access_bytes for b in blocks)
    fm_access = sum(b.fm_access_bytes for b in blocks)
    mandatory = (net.layers[0].ifm_size + net.layers[-1].ofm_size) * wb
    access += mandatory
    fm_access += mandatory

    comm_cycles = 0.0
    for i in range(len(spec.segments) - 1):
        boundary = net.layers[spec.segments[i].layer_hi]
        size = boundary.ofm_size * wb
        if not acc.inter_seg_onchip[i]:
            access += 2 * size          # Eq. 9: store + load
            fm_access += 2 * size
            comm_cycles += 2 * size / bps * dev.clock_hz
        else:
            comm_cycles += size / bps * dev.clock_hz  # on-chip hand-off: modelled free-ish

    latency_cycles = sum(s.latency_s for s in seg_metrics) * dev.clock_hz + comm_cycles
    latency_s = latency_cycles / dev.clock_hz

    if spec.inter_segment_pipelining and len(spec.segments) > 1:
        bottleneck = max(ce_busy.values()) if ce_busy else latency_cycles
        throughput = dev.clock_hz / bottleneck if bottleneck else math.inf
    else:
        # single block (e.g. SegmentedRR): its internal pipelining still
        # decouples throughput from latency via block busy time
        busy = max((b.busy_cycles for b in blocks), default=latency_cycles)
        if len(blocks) > 1:
            busy = latency_cycles  # sequential segments, no overlap
        throughput = dev.clock_hz / busy if busy else math.inf

    buffer_alloc = sum(b.buffer_bytes for b in blocks) + sum(
        2 * sz for sz, on in zip(acc.inter_seg_buffer_bytes, acc.inter_seg_onchip) if on
    )
    # Eq. 8 requirement: per-segment minimum-access buffers + double buffers
    # on every boundary (when inter-segment pipelining is used)
    buffer_req = sum(b.min_access_buffer_bytes for b in blocks)
    if spec.inter_segment_pipelining:
        buffer_req += sum(2 * sz for sz in acc.inter_seg_buffer_bytes)

    return Metrics(
        latency_s=latency_s,
        throughput_ips=throughput,
        buffer_bytes=buffer_req,
        buffer_alloc_bytes=buffer_alloc,
        access_bytes=access,
        weight_access_bytes=w_access,
        fm_access_bytes=fm_access,
        per_segment=seg_metrics,
        blocks=blocks,
        ce_busy_s={ce: busy / dev.clock_hz
                   for ce, busy in sorted(ce_busy.items())},
    )
