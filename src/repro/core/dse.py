"""Design-space exploration over multiple-CE arrangements (paper §V-E).

The paper's use case 3: take the bottleneck insights from fine-grained
evaluation, define a *custom* architecture family (a Hybrid-like pipelined
first block followed by Segmented-like single-CE blocks), sample the space
(~97.1e9 designs for XCp with 2–11 CEs), and evaluate 100 000 samples fast
enough to find designs that dominate the fixed templates.

``sample_custom``  — random designs from the paper's custom family;
``sample_mixed``   — broader family: every segment independently single or
                     pipelined (superset of all three templates);
``pareto``         — non-dominated front over (maximize, minimize) metrics;
``explore``        — end-to-end driver returning the evaluated sample.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .batch_eval import NS, DesignBatch, evaluate_batch, make_tables
from .device import DeviceSpec
from .notation import AcceleratorSpec, SegmentSpec
from .workload import Network


def _random_partition(rng: np.random.Generator, n_layers: int,
                      n_parts: int) -> np.ndarray:
    """Random contiguous partition: sorted cut points (exclusive ends)."""
    cuts = rng.choice(np.arange(1, n_layers), size=n_parts - 1, replace=False)
    return np.sort(np.concatenate([cuts, [n_layers]]))


def sample_custom(rng: np.random.Generator, n_layers: int, n: int,
                  min_ces: int = 2, max_ces: int = 11) -> DesignBatch:
    """The paper's custom family: pipelined first block (one CE per layer),
    then 1..k single-CE segments, coarse pipelining between segments."""
    seg_end = np.full((n, NS), n_layers, np.int32)
    seg_pipe = np.zeros((n, NS), bool)
    seg_nce = np.ones((n, NS), np.int32)
    for i in range(n):
        total_ces = rng.integers(min_ces, max_ces + 1)
        first = rng.integers(1, total_ces)         # CEs in the pipelined head
        rest = total_ces - first                   # single-CE segments after
        head_end = int(first)                      # one layer per head CE
        tail_layers = n_layers - head_end
        rest = max(1, min(rest, tail_layers))
        ends = head_end + _random_partition(rng, tail_layers, rest)
        seg_end[i, 0] = head_end
        seg_pipe[i, 0] = first > 1
        seg_nce[i, 0] = first
        seg_end[i, 1:1 + rest] = ends
        seg_end[i, 1 + rest:] = n_layers
    import jax.numpy as jnp
    return DesignBatch(jnp.asarray(seg_end), jnp.asarray(seg_pipe),
                       jnp.asarray(seg_nce),
                       jnp.ones((n,), bool))


def sample_mixed(rng: np.random.Generator, n_layers: int, n: int,
                 min_ces: int = 2, max_ces: int = 11,
                 max_segments: int = 6) -> DesignBatch:
    """Superset family: each segment independently single or pipelined."""
    seg_end = np.full((n, NS), n_layers, np.int32)
    seg_pipe = np.zeros((n, NS), bool)
    seg_nce = np.ones((n, NS), np.int32)
    inter = np.zeros((n,), bool)
    for i in range(n):
        total = rng.integers(min_ces, max_ces + 1)
        n_seg = int(rng.integers(1, min(max_segments, total) + 1))
        ends = _random_partition(rng, n_layers, n_seg)
        # distribute CEs over segments (>=1 each)
        alloc = np.ones(n_seg, np.int64)
        for _ in range(total - n_seg):
            alloc[rng.integers(0, n_seg)] += 1
        seg_end[i, :n_seg] = ends
        seg_nce[i, :n_seg] = alloc
        seg_pipe[i, :n_seg] = alloc > 1
        inter[i] = n_seg > 1 and bool(rng.integers(0, 2))
    import jax.numpy as jnp
    return DesignBatch(jnp.asarray(seg_end), jnp.asarray(seg_pipe),
                       jnp.asarray(seg_nce), jnp.asarray(inter))


def decode_design(batch: DesignBatch, i: int, n_layers: int) -> AcceleratorSpec:
    """Row i of a DesignBatch -> AcceleratorSpec (for the scalar evaluator
    or for pretty-printing in the paper's notation)."""
    seg_end = np.asarray(batch.seg_end[i])
    seg_pipe = np.asarray(batch.seg_pipe[i])
    seg_nce = np.asarray(batch.seg_nce[i])
    segs, lo, ce = [], 0, 0
    for s in range(NS):
        hi = int(seg_end[s])
        if hi <= lo:
            continue
        n = int(seg_nce[s]) if seg_pipe[s] else 1
        segs.append(SegmentSpec(lo, hi - 1, ce, ce + n - 1))
        ce += n
        lo = hi
        if hi >= n_layers:
            break
    return AcceleratorSpec(name=f"custom[{i}]", segments=tuple(segs),
                           inter_segment_pipelining=bool(batch.inter_pipe[i]))


def pareto(points: np.ndarray) -> np.ndarray:
    """Indices of the non-dominated front.  ``points`` (N, M): every metric
    oriented so LOWER is better."""
    n = points.shape[0]
    order = np.lexsort(points.T[::-1])
    keep = []
    best = np.full(points.shape[1], np.inf)
    for i in order:
        if np.any(points[i] < best - 1e-12) or not keep:
            if not any(np.all(points[j] <= points[i]) and
                       np.any(points[j] < points[i]) for j in keep):
                keep.append(i)
                best = np.minimum(best, points[i])
    return np.asarray(sorted(keep))


@dataclass
class DSEResult:
    batch: DesignBatch
    metrics: dict[str, np.ndarray]
    seconds: float
    per_design_us: float


def explore(net: Network, dev: DeviceSpec, n: int = 100_000, *,
            family: str = "custom", seed: int = 0,
            chunk: int = 4096) -> DSEResult:
    """Sample + evaluate ``n`` designs; returns metrics for the whole sample."""
    import time

    import jax

    rng = np.random.default_rng(seed)
    sampler = sample_custom if family == "custom" else sample_mixed
    tables = make_tables(net)
    outs: list[dict] = []
    batches: list[DesignBatch] = []
    t0 = time.time()
    done = 0
    while done < n:
        b = min(chunk, n - done)
        batch = sampler(rng, len(net), b)
        out = evaluate_batch(batch, tables, dev)
        jax.block_until_ready(out["latency_s"])
        outs.append({k: np.asarray(v) for k, v in out.items()})
        batches.append(batch)
        done += b
    dt = time.time() - t0
    import jax.numpy as jnp
    merged = DesignBatch(
        jnp.concatenate([b.seg_end for b in batches]),
        jnp.concatenate([b.seg_pipe for b in batches]),
        jnp.concatenate([b.seg_nce for b in batches]),
        jnp.concatenate([b.inter_pipe for b in batches]))
    metrics = {k: np.concatenate([o[k] for o in outs]) for k in outs[0]}
    return DSEResult(batch=merged, metrics=metrics, seconds=dt,
                     per_design_us=dt / n * 1e6)
