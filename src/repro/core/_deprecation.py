"""DeprecationWarning helper for the legacy free-function entry points.

The scattered entry points (``evaluate_design``, ``evaluate_specs``,
``evaluate_specs_multi``, ``explore``, ``joint_explore``) are kept as thin
shims over the same implementations the :class:`repro.api.Session` front
door uses, so migrating is a mechanical rename — results are
bit-identical (asserted in ``tests/test_session.py``).
"""
from __future__ import annotations

import warnings


def warn_deprecated(old: str, new: str, *, stacklevel: int = 3) -> None:
    """Emit the standard migration warning for a legacy entry point."""
    warnings.warn(
        f"{old} is deprecated; use {new} (see docs/api.md for the "
        f"migration table). The shim delegates to the same implementation, "
        f"so results are bit-identical.",
        DeprecationWarning, stacklevel=stacklevel)
