"""MCCM building-block models: single-CE and pipelined-CEs (paper §IV-A).

Implements Eq. 1 (single-CE latency with PE underutilisation), Eq. 2/3
(pipelined-CEs latency/throughput), Eq. 4/5 (minimum-access buffer
requirements) and Eq. 6/7 (off-chip accesses under a finite buffer budget).

Conventions
-----------
* latencies are in **cycles** (DeviceSpec converts to seconds),
* sizes are in **elements** unless the name says ``_bytes``,
* a *block* evaluation returns per-layer records so the fine-grained
  use case (paper Fig. 6/7/9) can break results down.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Sequence

from .device import DeviceSpec
from .workload import DIMS, ConvLayer


# --------------------------------------------------------------------------
# Compute engines
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class CE:
    """A compute engine: a PE grid with a parallelism vector and a buffer."""

    name: str
    pes: int
    par: dict[str, int]          # parallelism per loop dim, prod <= pes
    buffer_bytes: int = 0        # on-chip buffer allocated to this CE

    def __post_init__(self):
        prod = 1
        for d in DIMS:
            prod *= self.par.get(d, 1)
        if prod > max(self.pes, 1):
            raise ValueError(
                f"CE {self.name}: parallelism product {prod} exceeds PEs {self.pes}"
            )

    def par_of(self, d: str) -> int:
        return self.par.get(d, 1)


def layer_cycles(layer: ConvLayer, ce: CE) -> int:
    """Eq. 1 inner term: Lat(L_i, CE_j) = prod_d ceil(|d| / Par(CE_j, d))."""
    cyc = 1
    dims = layer.dims()
    for d in DIMS:
        cyc *= -(-dims[d] // ce.par_of(d))
    return cyc


def layer_utilization(layer: ConvLayer, ce: CE) -> float:
    """Fraction of PE-cycles doing useful MACs (1 - underutilisation)."""
    cyc = layer_cycles(layer, ce)
    par = 1
    for d in DIMS:
        par *= ce.par_of(d)
    return layer.macs / (cyc * par) if cyc else 0.0


CANDIDATES_DEFAULT = (1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64, 96, 128,
                      192, 256, 384, 512)


def best_parallelism(
    pes: int, layers: Sequence[ConvLayer], candidates: Sequence[int] | None = None
) -> dict[str, int]:
    """Builder heuristic: pick <par_f, par_oh, par_ow> minimising total cycles.

    3-D parallelism across filters and within OFM rows/cols, per the
    exhaustive FPGA analysis of Ma et al. [23] cited by the paper.
    """
    if candidates is None:
        candidates = list(CANDIDATES_DEFAULT)
    pes = max(pes, 1)
    best, best_cost = {"f": 1, "oh": 1, "ow": 1}, None
    for pf in candidates:
        if pf > pes:
            break
        for ph in candidates:
            if pf * ph > pes:
                break
            # greedily take the largest feasible pw candidate
            pw = 1
            for c in candidates:
                if pf * ph * c <= pes:
                    pw = c
                else:
                    break
            par = {"f": pf, "oh": ph, "ow": pw}
            ce = CE(name="probe", pes=pes, par=par)
            cost = sum(layer_cycles(l, ce) for l in layers)
            if best_cost is None or cost < best_cost:
                best, best_cost = par, cost
    return best


# --------------------------------------------------------------------------
# Per-layer / per-block result records
# --------------------------------------------------------------------------
@dataclass
class LayerResult:
    layer: ConvLayer
    compute_cycles: int
    mem_cycles: float
    access_bytes: float
    weight_access_bytes: float
    fm_access_bytes: float
    utilization: float

    @property
    def cycles(self) -> float:
        # double-buffered overlap: the slower of compute and memory wins
        return max(self.compute_cycles, self.mem_cycles)


@dataclass
class BlockResult:
    kind: str                       # 'single' | 'pipelined'
    latency_cycles: float           # one-input latency
    busy_cycles: float              # steady-state per-input occupancy (1/thpt)
    buffer_bytes: int               # allocated
    min_access_buffer_bytes: int    # Eq. 4 / Eq. 5 requirement
    access_bytes: float             # per-input steady state
    weight_access_bytes: float
    fm_access_bytes: float
    per_layer: list[LayerResult] = field(default_factory=list)

    @property
    def throughput(self) -> float:
        return 1.0 / self.busy_cycles if self.busy_cycles else math.inf


# --------------------------------------------------------------------------
# single-CE block (paper Fig. 4a)
# --------------------------------------------------------------------------
def single_ce_min_buffer(layers: Sequence[ConvLayer], ce_par_f: int, wordbytes: int) -> int:
    """Eq. 4: max FMs + max weights tile (elements -> bytes)."""
    max_fms = max(l.fms_size for l in layers)
    max_wtile = max(_weight_tile(l, ce_par_f) for l in layers)
    return (max_fms + max_wtile) * wordbytes


def _weight_tile(layer: ConvLayer, par_f: int) -> int:
    """Weights slice in flight: the filters currently being computed."""
    c = 1 if layer.kind == "dw" else layer.in_ch
    return min(par_f, layer.out_ch) * c * layer.kh * layer.kw


def _single_layer_access(
    layer: ConvLayer,
    buffer_bytes: int,
    par_f: int,
    wordbytes: int,
    ifm_onchip: bool,
) -> tuple[float, float, float, bool]:
    """Eq. 6 for one layer.

    Returns (total_access_bytes, weight_bytes, fm_bytes, ofm_stays_onchip).
    """
    wb = wordbytes
    w, ifm, ofm = layer.weights_size * wb, layer.ifm_size * wb, layer.ofm_size * wb
    extra = layer.ofm_size * wb if layer.residual else 0
    wtile = _weight_tile(layer, par_f) * wb

    # Ideal: IFM + OFM (+res) + streaming weight tile fit -> one access/weight.
    if ifm + ofm + extra + wtile <= buffer_bytes:
        fm_acc = 0.0 if ifm_onchip else ifm
        return w + fm_acc, w, fm_acc, True

    # OFM kept on-chip if it fits next to minimal working tiles.
    ifm_tile = min(ifm, layer.in_ch * layer.kh * layer.iw * wb)  # kh-row band
    ofm_onchip = ofm + extra + wtile + ifm_tile <= buffer_bytes
    ofm_resident = (ofm + extra) if ofm_onchip else 0
    ofm_acc = 0.0 if ofm_onchip else float(ofm)

    if ifm_onchip:
        # Whole IFM already resident from the previous layer: weights stream once.
        return ofm_acc + w, w, ofm_acc, ofm_onchip

    # Option A — output-stationary, locally input-stationary:
    ifm_buf = max(buffer_bytes - ofm_resident - wtile, ifm_tile)
    loads_a = w * math.ceil(ifm / ifm_buf) + ifm if ifm_buf < ifm else w + ifm
    wacc_a = loads_a - ifm
    # Option B — output-stationary, locally weight-stationary:
    w_buf = max(buffer_bytes - ofm_resident - ifm_tile, wtile)
    loads_b = ifm * math.ceil(w / w_buf) + w if w_buf < w else ifm + w
    facc_b = loads_b - w

    if loads_a <= loads_b:
        return ofm_acc + loads_a, wacc_a, ofm_acc + ifm, ofm_onchip
    return ofm_acc + loads_b, float(w), ofm_acc + facc_b, ofm_onchip


def eval_single_ce(
    layers: Sequence[ConvLayer],
    ce: CE,
    dev: DeviceSpec,
    ifm_onchip_first: bool = False,
) -> BlockResult:
    """Evaluate a single-CE block over a layer range (Eq. 1 + 4 + 6)."""
    bpc = dev.off_chip_bytes_per_cycle
    results: list[LayerResult] = []
    ifm_onchip = ifm_onchip_first
    for layer in layers:
        comp = layer_cycles(layer, ce)
        acc, wacc, facc, ofm_onchip = _single_layer_access(
            layer, ce.buffer_bytes, ce.par_of("f"), dev.wordbytes, ifm_onchip
        )
        results.append(
            LayerResult(
                layer=layer,
                compute_cycles=comp,
                mem_cycles=acc / bpc,
                access_bytes=acc,
                weight_access_bytes=wacc,
                fm_access_bytes=facc,
                utilization=layer_utilization(layer, ce),
            )
        )
        ifm_onchip = ofm_onchip
    latency = sum(r.cycles for r in results)
    return BlockResult(
        kind="single",
        latency_cycles=latency,
        busy_cycles=latency,
        buffer_bytes=ce.buffer_bytes,
        min_access_buffer_bytes=single_ce_min_buffer(layers, ce.par_of("f"), dev.wordbytes),
        access_bytes=sum(r.access_bytes for r in results),
        weight_access_bytes=sum(r.weight_access_bytes for r in results),
        fm_access_bytes=sum(r.fm_access_bytes for r in results),
        per_layer=results,
    )


# --------------------------------------------------------------------------
# pipelined-CEs block (paper Fig. 4b)
# --------------------------------------------------------------------------
def pipelined_min_buffer(
    layers: Sequence[ConvLayer], dev: DeviceSpec, fm_tile_rows: int = 2
) -> int:
    """Eq. 5: sum of all weights + 2x FM tile buffers (double buffering)."""
    wb = dev.wordbytes
    total = 0
    for l in layers:
        fm_tile = l.out_ch * l.ow * fm_tile_rows
        total += l.weights_size * wb + 2 * fm_tile * wb
    return total


def fm_tile_buffer(layer: ConvLayer, fm_tile_rows: int = 2) -> int:
    return layer.out_ch * layer.ow * fm_tile_rows


def _pipeline_rounds(n_layers: int, n_ces: int) -> list[list[int]]:
    """Round-robin layer assignment: round r -> layers [r*n .. r*n+n-1]."""
    return [
        list(range(r * n_ces, min((r + 1) * n_ces, n_layers)))
        for r in range(-(-n_layers // n_ces))
    ]


def pipeline_stage_sum(tile_lats: Sequence[float], n_tiles: int) -> float:
    """Eq. 2 closed form: sum over stages of max over active CEs.

    CE_j (0-based) processes tile t at stage t + j; stages run
    0 .. n_tiles + n_ces - 2; active at stage s: {j : s - n_tiles < j <= s}.
    """
    n = len(tile_lats)
    if n == 0:
        return 0.0
    total = 0.0
    for s in range(n_tiles + n - 1):
        lo, hi = max(0, s - n_tiles + 1), min(n - 1, s)
        total += max(tile_lats[lo : hi + 1])
    return total


def eval_pipelined(
    layers: Sequence[ConvLayer],
    ces: Sequence[CE],
    dev: DeviceSpec,
    weights_resident: bool | None = None,
    fm_tile_rows: int = 2,
) -> BlockResult:
    """Evaluate a pipelined-CEs block (Eq. 2 + 3 + 5 + 7).

    ``weights_resident``: all weights of the block's layers stay on-chip after
    the first image (the Eq. 5 minimum-access regime).  If None it is derived
    from the CE buffer allocations.
    """
    wb, bpc = dev.wordbytes, dev.off_chip_bytes_per_cycle
    n_ces = len(ces)
    rounds = _pipeline_rounds(len(layers), n_ces)
    multi_round = len(rounds) > 1

    if weights_resident is None:
        need = pipelined_min_buffer(layers, dev, fm_tile_rows)
        weights_resident = (not multi_round) and sum(c.buffer_bytes for c in ces) >= need

    per_layer: list[LayerResult] = []
    latency = 0.0
    busy = [0.0] * n_ces  # per-CE steady-state occupancy per input (Eq. 3)
    for rnd in rounds:
        n_tiles = max(layers[li].oh for li in rnd)  # row-granular tiles
        tile_lats = []
        for slot, li in enumerate(rnd):
            layer, ce = layers[li], ces[slot]
            comp = layer_cycles(layer, ce)
            # Eq. 7: weight traffic if not resident; reload per round.
            w_bytes = layer.weights_size * wb
            if weights_resident:
                w_acc = 0.0  # amortised after first image
            elif ce.buffer_bytes >= w_bytes:
                w_acc = float(w_bytes)  # buffered per round, streamed once/image
            else:
                # cannot hold the layer's weights: re-streamed every tile-stage
                w_acc = float(w_bytes) * n_tiles
            mem_cyc = w_acc / bpc
            tile_lat = max(comp, mem_cyc) / n_tiles
            tile_lats.append(tile_lat)
            busy[slot] += max(comp, mem_cyc)
            per_layer.append(
                LayerResult(
                    layer=layer,
                    compute_cycles=comp,
                    mem_cycles=mem_cyc,
                    access_bytes=w_acc,
                    weight_access_bytes=w_acc,
                    fm_access_bytes=0.0,
                    utilization=layer_utilization(layer, ce),
                )
            )
        latency += pipeline_stage_sum(tile_lats, n_tiles)

    return BlockResult(
        kind="pipelined",
        latency_cycles=latency,
        busy_cycles=max(busy) if busy else 0.0,
        buffer_bytes=sum(c.buffer_bytes for c in ces),
        min_access_buffer_bytes=pipelined_min_buffer(layers, dev, fm_tile_rows),
        access_bytes=sum(r.access_bytes for r in per_layer),
        weight_access_bytes=sum(r.weight_access_bytes for r in per_layer),
        fm_access_bytes=0.0,
        per_layer=per_layer,
    )
