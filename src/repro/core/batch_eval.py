"""Vectorized MCCM: evaluate thousands of multiple-CE designs as ONE jitted
JAX program — recompile-free across CNNs, boards and sweep sizes.

The scalar path (``evaluator.evaluate_design``) walks Python objects at
~100 µs–1 ms per design; the paper's own C++/Python model reports 6.3 ms.
Here every design in a batch is encoded as fixed-shape arrays (segments
padded to ``NS``, CEs to ``NC``) and Eqs. 1–9 are evaluated with masked
tensor ops.

Exactness: this is the *same* model, not an approximation —
``tests/test_batch_eval.py`` asserts agreement with the scalar evaluator on
every baseline architecture × CNN × CE-count (largest-remainder PE
distribution, the discrete ⟨pf, ph, pw⟩ parallelism search, Eq. 6's two
buffered-access options, and the exact pipeline stage-sum via the
prefix/suffix-max identity all replicated in vector form).

Layout (see docs/perf.md for the why)
-------------------------------------
* ``NetTables``  — per-CNN arrays as a *traced pytree*, padded to a shared
  ``max_L`` with a layer-valid mask, so every CNN shares one compiled
  program.
* ``DeviceTables`` — the board as traced scalars, ditto for boards.
* ``DesignBatch`` — (B, NS) segment encoding (``core.dse.encoding``).
* ``evaluate_batch`` — jitted core.  Designs are processed in tiles of
  ``tile`` via ``lax.map``; per tile the ⟨pf, ph, pw⟩ search builds only a
  (tile, L, P) cost block (cache/VMEM-resident) instead of the old
  (B, L, 18, 18) HBM tensor, dispatched to ``kernels.mccm_eval`` (pure-jnp
  ref on CPU, the fused Pallas kernel on TPU, ``interpret=True`` under CI).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from ..kernels.mccm_eval import pair_tables, parallelism_search, resolve_backend
from .blocks import CANDIDATES_DEFAULT
from .device import DeviceSpec
from .dse.encoding import NC, NS, DesignBatch, encode_specs  # noqa: F401
from .notation import AcceleratorSpec
from .workload import Network

NEG = -1.0e30

#: base of the layer-axis padding ladder: covers the whole CNN zoo
#: (resnet152 = 155), so one compiled program serves every registered CNN.
DEFAULT_MAX_L = 160

#: bucket step above the base — larger nets pad to the next multiple, one
#: extra compile per new size bucket instead of one per net.
MAX_L_STEP = 32


def bucket_max_L(L: int, base: int = DEFAULT_MAX_L,
                 step: int = MAX_L_STEP) -> int:
    """Shared layer-padding bucket for an L-layer net.

    Every net at or under ``base`` layers shares the base bucket (one
    compile for the whole zoo); larger nets land on the next ``step``
    multiple, so two 200-ish-layer nets still share a compile instead of
    each minting its own shape.
    """
    if L <= base:
        return base
    return -(-L // step) * step


def shared_max_L(layer_counts) -> int:
    """The one bucket a set of nets must share to be stacked/megabatched
    (e.g. the model axis of ``core.multinet``): the max over their
    individual buckets."""
    counts = list(layer_counts)
    if not counts:
        return DEFAULT_MAX_L
    return max(bucket_max_L(int(c)) for c in counts)

#: design-tile width of the lax.map hot loop (the CPU analogue of the
#: Pallas kernel's VMEM design tile).
DEFAULT_TILE = 128

#: static PE-budget hints for pruning the ⟨pf, ph⟩ pair grid.  Every
#: registered board (<= 2520 DSPs) lands in the first bucket, keeping a
#: single compile across boards; exotic devices fall into coarser buckets.
PES_HINTS = (2520, 8192, 65536)


# --------------------------------------------------------------------------
# static-per-CNN tables, as a traced pytree
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class NetTables:
    """Per-network layer tables, padded to ``max_L`` (= ``F.shape[0]``).

    All array fields are pytree *data* — a NetTables is traced, never a
    static jit argument, so switching CNNs does not recompile.  Padded
    layers carry zeros and ``valid`` masks them out.
    """

    L: jnp.ndarray         # ()  i32 true layer count
    valid: jnp.ndarray     # (max_L,) f32 1.0 for real layers
    F: jnp.ndarray         # out channels
    CKK: jnp.ndarray       # c * kh * kw  (c=1 for depthwise)
    OH: jnp.ndarray
    OW: jnp.ndarray
    MACS: jnp.ndarray
    W: jnp.ndarray         # weights (elements)
    IFM: jnp.ndarray
    OFM: jnp.ndarray
    EXTRA: jnp.ndarray     # residual OFM copy (elements)
    BAND: jnp.ndarray      # in_ch * kh * iw  (IFM row band)
    OFM_ROW: jnp.ndarray   # out_ch * ow
    CEIL_F: jnp.ndarray    # (max_L, K) ceil(F / cand)
    CEIL_OH: jnp.ndarray
    CEIL_OW: jnp.ndarray
    CAND: jnp.ndarray      # (K,)
    candidates: tuple = CANDIDATES_DEFAULT   # static metadata

    @property
    def n_layers(self) -> int:
        """Concrete layer count (host-side use only)."""
        return int(self.L)

    @property
    def max_L(self) -> int:
        return self.F.shape[0]


jax.tree_util.register_dataclass(
    NetTables,
    data_fields=["L", "valid", "F", "CKK", "OH", "OW", "MACS", "W", "IFM",
                 "OFM", "EXTRA", "BAND", "OFM_ROW", "CEIL_F", "CEIL_OH",
                 "CEIL_OW", "CAND"],
    meta_fields=["candidates"],
)


def make_tables(net: Network, candidates=CANDIDATES_DEFAULT,
                max_L: int | None = None) -> NetTables:
    cand = np.asarray(candidates, np.float64)
    L = len(net)
    if max_L is None:
        max_L = bucket_max_L(L)
    elif L > max_L:
        max_L = bucket_max_L(L, base=max_L)
    dims = [l.dims() for l in net]

    def pad(vals):
        a = np.zeros(max_L, np.float64)
        a[:L] = vals
        return jnp.asarray(a, jnp.float32)

    F = np.array([d["f"] for d in dims], np.float64)
    OH = np.array([d["oh"] for d in dims], np.float64)
    OW = np.array([d["ow"] for d in dims], np.float64)

    def pad2(ceil_tab):
        a = np.zeros((max_L, len(cand)), np.float64)
        a[:L] = ceil_tab
        return jnp.asarray(a, jnp.float32)

    return NetTables(
        L=jnp.asarray(L, jnp.int32),
        valid=pad(np.ones(L)),
        F=pad(F),
        CKK=pad([d["c"] * d["kh"] * d["kw"] for d in dims]),
        OH=pad(OH), OW=pad(OW),
        MACS=pad([l.macs for l in net]),
        W=pad([l.weights_size for l in net]),
        IFM=pad([l.ifm_size for l in net]),
        OFM=pad([l.ofm_size for l in net]),
        EXTRA=pad([l.ofm_size if l.residual else 0 for l in net]),
        BAND=pad([l.in_ch * l.kh * l.iw for l in net]),
        OFM_ROW=pad([l.out_ch * l.ow for l in net]),
        CEIL_F=pad2(np.ceil(F[:, None] / cand[None, :])),
        CEIL_OH=pad2(np.ceil(OH[:, None] / cand[None, :])),
        CEIL_OW=pad2(np.ceil(OW[:, None] / cand[None, :])),
        CAND=jnp.asarray(cand, jnp.float32),
        candidates=tuple(candidates),
    )


# --------------------------------------------------------------------------
# the board, as traced scalars
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class DeviceTables:
    """DeviceSpec as a traced scalar struct — boards don't recompile."""

    pes: jnp.ndarray
    on_chip_bytes: jnp.ndarray
    bpc: jnp.ndarray           # off-chip bytes per cycle
    bps: jnp.ndarray           # off-chip bytes per second
    clock_hz: jnp.ndarray
    wordbytes: jnp.ndarray


jax.tree_util.register_dataclass(
    DeviceTables,
    data_fields=["pes", "on_chip_bytes", "bpc", "bps", "clock_hz",
                 "wordbytes"],
    meta_fields=[],
)


def make_device_tables(dev: DeviceSpec) -> DeviceTables:
    s = lambda x: jnp.asarray(x, jnp.float32)
    return DeviceTables(
        pes=s(dev.pes), on_chip_bytes=s(dev.on_chip_bytes),
        bpc=s(dev.off_chip_bytes_per_cycle), bps=s(dev.off_chip_gbps * 1e9),
        clock_hz=s(dev.clock_hz), wordbytes=s(dev.wordbytes))


def pes_hint(pes: float) -> int | None:
    """Static pair-pruning bucket for a concrete PE count (None = no
    pruning for devices beyond the ladder)."""
    for h in PES_HINTS:
        if pes <= h:
            return h
    return None


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------
def _largest_remainder(shares, total, valid):
    """Vectorized largest-remainder rounding (floor 1 per valid CE).

    shares: (B, NC) f32; total: scalar; valid: (B, NC) bool.
    Mirrors builder._largest_remainder including tie-breaking by index.
    """
    n = valid.sum(-1)                                  # (B,)
    s = jnp.where(shares.sum(-1) > 0, shares.sum(-1), 1.0)
    raw = jnp.maximum(shares / s[:, None] * total, 1.0)
    raw = jnp.where(valid, raw, 0.0)
    out = jnp.where(valid, jnp.maximum(jnp.floor(raw), 1.0), 0.0)
    rem = total - out.sum(-1)                          # (B,) can be +/-
    frac = jnp.where(valid, raw - jnp.floor(raw), -1.0)
    # positive remainder: +1 to the rem largest fractions (cyclically the
    # scalar hands out one each in frac order; rem < n in practice)
    order = jnp.argsort(-frac, axis=-1, stable=True)
    rank = jnp.argsort(order, axis=-1, stable=True)    # rank in frac order
    give = rank < jnp.maximum(rem, 0)[:, None]
    out = out + jnp.where(valid & give, 1.0, 0.0)
    # negative remainder: take from the largest allocations (scalar loops;
    # one pass suffices when floors forced the overflow)
    deficit = jnp.maximum(-rem, 0.0)
    big_order = jnp.argsort(-out, axis=-1, stable=True)
    big_rank = jnp.argsort(big_order, axis=-1, stable=True)
    take = (big_rank < deficit[:, None]) & (out > 1.0)
    out = out - jnp.where(take, 1.0, 0.0)
    return out


def _seg_onehot(seg_of_layer, valid_layer):
    """(B, L, NS) one-hot of each layer's segment id."""
    oh = jax.nn.one_hot(seg_of_layer, NS, dtype=jnp.float32)
    return oh * valid_layer[..., None]


def _seg_sum(x, onehot):
    """sum of per-layer x (B, L) into segments -> (B, NS)."""
    return jnp.einsum("bl,bls->bs", x, onehot)


def _seg_max(x, onehot):
    big = jnp.where(onehot > 0, x[..., None], NEG)
    return big.max(axis=1)


def seg_scan_max(vals, start_flags, reverse=False):
    """Running max within groups delimited by start_flags (B, L).

    Associative, so log2(L) vector steps; a flagged element STARTS its own
    group.  With ``reverse=True`` the scan runs right-to-left (flags then
    mark group *ends*)."""
    def combine(a, b):
        fa, va = a
        fb, vb = b
        return fa | fb, jnp.where(fb, vb, jnp.maximum(va, vb))
    flags = start_flags[..., ::-1] if reverse else start_flags
    v = vals[..., ::-1] if reverse else vals
    _, out = jax.lax.associative_scan(combine, (flags, v), axis=1)
    return out[..., ::-1] if reverse else out


# --------------------------------------------------------------------------
# the traced core (works on any batch size; callers tile it)
# --------------------------------------------------------------------------
class _CEMaps(NamedTuple):
    seg_start: jnp.ndarray
    seg_len: jnp.ndarray
    seg_valid: jnp.ndarray
    n_seg: jnp.ndarray
    seg_of_layer: jnp.ndarray
    onehot: jnp.ndarray
    valid_b: jnp.ndarray        # (B, max_L) bool
    idx_in_seg: jnp.ndarray
    nce_of_layer: jnp.ndarray
    pipe_bool: jnp.ndarray      # (B, max_L) bool (masked to valid layers)
    slot_of_layer: jnp.ndarray
    round_of_layer: jnp.ndarray
    ce_base: jnp.ndarray
    ce_of_layer: jnp.ndarray    # clipped to [0, NC)
    ce_oh: jnp.ndarray
    pes_ce: jnp.ndarray
    ce_valid: jnp.ndarray


def _ce_maps(design: DesignBatch, t: NetTables, dev: DeviceTables) -> _CEMaps:
    """Layer -> segment / CE maps + the PE distribution (Eq. 1 prologue)."""
    B, max_L = design.batch, t.max_L
    layer_ix = jnp.arange(max_L)

    seg_end = design.seg_end                      # (B, NS)
    seg_start = jnp.concatenate(
        [jnp.zeros((B, 1), jnp.int32), seg_end[:, :-1]], axis=1)
    seg_len = seg_end - seg_start                 # (B, NS)
    seg_valid = seg_len > 0
    n_seg = seg_valid.sum(-1)                     # (B,)

    # seg of layer: first segment with end > l (padded layers clip to the
    # last column; the valid mask removes them from every reduction)
    seg_of_layer = jnp.minimum(jnp.sum(
        (layer_ix[None, :, None] >= seg_end[:, None, :]).astype(jnp.int32),
        axis=-1), NS - 1)                         # (B, max_L)
    valid_b = layer_ix[None, :] < t.L             # (B, max_L) bool
    valid_layer = valid_b.astype(jnp.float32) * t.valid[None, :]
    onehot = _seg_onehot(seg_of_layer, valid_layer)     # (B, max_L, NS)

    idx_in_seg = layer_ix[None, :] - jnp.take_along_axis(
        seg_start, seg_of_layer, axis=1)
    nce_of_layer = jnp.take_along_axis(design.seg_nce, seg_of_layer, axis=1)
    pipe_bool = (jnp.take_along_axis(
        design.seg_pipe.astype(jnp.int32), seg_of_layer, axis=1) > 0) \
        & valid_b
    slot_of_layer = idx_in_seg % jnp.maximum(nce_of_layer, 1)
    round_of_layer = idx_in_seg // jnp.maximum(nce_of_layer, 1)

    ce_base = jnp.cumsum(design.seg_nce * seg_valid, axis=-1) \
        - design.seg_nce * seg_valid
    ce_of_layer = jnp.take_along_axis(ce_base, seg_of_layer, axis=1) \
        + slot_of_layer                            # (B, max_L)
    # overflowing CEs (non-canonical rows) and padded layers map to a zero
    # one-hot row; clip keeps the ref path's gathers in bounds
    ce_oh = jax.nn.one_hot(ce_of_layer, NC, dtype=jnp.float32) \
        * valid_layer[..., None]
    ce_of_layer = jnp.clip(ce_of_layer, 0, NC - 1)

    # PE distribution (largest remainder over per-CE MACs)
    macs_ce = jnp.einsum("l,blc->bc", jnp.asarray(t.MACS), ce_oh)
    ce_valid = jnp.einsum("blc->bc", ce_oh) > 0
    pes_ce = _largest_remainder(macs_ce, dev.pes, ce_valid)
    return _CEMaps(seg_start, seg_len, seg_valid, n_seg, seg_of_layer,
                   onehot, valid_b, idx_in_seg, nce_of_layer, pipe_bool,
                   slot_of_layer, round_of_layer, ce_base, ce_of_layer,
                   ce_oh, pes_ce, ce_valid)


def _pair_layer_tables(t: NetTables, pairs):
    """Per-(layer, pair) factor tables for the fused search."""
    pi = jnp.asarray(pairs.pair_i, jnp.int32)
    pj = jnp.asarray(pairs.pair_j, jnp.int32)
    fc_pair = t.CEIL_F[:, pi] * t.CKK[:, None]      # (max_L, P)
    coh_pair = t.CEIL_OH[:, pj]                     # (max_L, P)
    return fc_pair, coh_pair


class LayerState(NamedTuple):
    """Per-layer cost state between Eq. 1 and the Eq. 2–9 composition.

    ``layer_state`` computes it; ``compose_metrics`` reduces it to the
    metric dict.  The split exists for the schedule layer
    (``repro.schedule``): temporal-mapping search re-scores the
    per-layer fields (latency/busy/traffic) for its chosen mappings and
    re-runs the SAME composition, so coarse and schedule-refined costs
    stay in one currency — when every layer keeps the ideal mapping the
    result is bit-identical to ``_evaluate_core``.

    Per-layer arrays are (B, L); per-segment arrays are (B, NS).
    """

    # Eq. 1 compute + utilization
    comp: jnp.ndarray           # compute cycles
    util: jnp.ndarray
    # single-CE (Eq. 6) costs
    lat_single: jnp.ndarray     # max(comp, mem) — pre single_l masking
    acc_single: jnp.ndarray     # off-chip bytes
    wacc_single: jnp.ndarray
    facc_single: jnp.ndarray
    mem_cyc_single: jnp.ndarray
    # pipelined (Eq. 7) costs
    busy_pipe: jnp.ndarray      # max(comp, mem) per layer slot
    w_acc_pipe: jnp.ndarray
    mem_cyc_pipe: jnp.ndarray
    n_tiles_l: jnp.ndarray
    # mapping inputs the schedule search scores candidates against
    buf_l: jnp.ndarray          # single: the segment's buffer alloc
    ce_buf_l: jnp.ndarray       # pipelined: the layer's CE buffer slice
    wtile: jnp.ndarray          # streaming weight-tile bytes (pf rows)
    fm_tile2: jnp.ndarray       # double-buffered fm tile bytes
    ofm_res: jnp.ndarray        # OFM bytes held resident (Eq. 6)
    ofm_acc: jnp.ndarray        # OFM bytes streamed off-chip
    ideal: jnp.ndarray          # bool: whole working set fits
    ifm_onchip: jnp.ndarray     # bool: IFM left on chip by producer
    use_a: jnp.ndarray          # bool: Eq. 6 picked option A (IS) over B
    resident_l: jnp.ndarray     # bool: Eq. 5 whole-segment weight regime
    # per-segment allocations / boundaries
    alloc: jnp.ndarray
    desires: jnp.ndarray
    inter_onchip: jnp.ndarray   # bool
    bound_valid: jnp.ndarray    # bool
    is_pipe_seg: jnp.ndarray    # bool


def layer_state(design: DesignBatch, t: NetTables, dev: DeviceTables,
                m: _CEMaps, par, fm_tile_rows: int) -> LayerState:
    """Eqs. 1 + 4–7 given the CE maps and the ⟨pf, ph, pw⟩ winners:
    buffer allocation, per-layer compute/memory costs, residency regimes."""
    B, max_L = design.batch, t.max_L
    wb = dev.wordbytes
    bpc = dev.bpc
    pf_ce, ph_ce, pw_ce = par
    (seg_start, seg_len, seg_valid, n_seg, seg_of_layer, onehot, valid_b,
     idx_in_seg, nce_of_layer, pipe_bool, slot_of_layer, _round,
     ce_base, _ce_of_layer, ce_oh, _pes, ce_valid) = m
    valid_f = valid_b.astype(jnp.float32)
    seg_end = design.seg_end

    # ---- per-layer compute cycles & utilization --------------------------
    macs = jnp.asarray(t.MACS)
    ckk = jnp.asarray(t.CKK)
    pf_l = jnp.where(valid_b, jnp.einsum("bc,blc->bl", pf_ce, ce_oh), 1.0)
    ph_l = jnp.where(valid_b, jnp.einsum("bc,blc->bl", ph_ce, ce_oh), 1.0)
    pw_l = jnp.where(valid_b, jnp.einsum("bc,blc->bl", pw_ce, ce_oh), 1.0)
    F = jnp.asarray(t.F)
    OH = jnp.asarray(t.OH)
    OW = jnp.asarray(t.OW)
    comp = (jnp.ceil(F[None] / pf_l) * ckk[None]
            * jnp.ceil(OH[None] / ph_l) * jnp.ceil(OW[None] / pw_l))
    par_total = pf_l * ph_l * pw_l
    util = macs[None] / jnp.maximum(comp * par_total, 1.0)

    pipe_l = pipe_bool.astype(jnp.float32)
    single_l = (1.0 - pipe_l) * valid_f

    # ---- buffer floors / desires (Eq. 4 / 5) ------------------------------
    W = jnp.asarray(t.W)
    IFM = jnp.asarray(t.IFM)
    OFM = jnp.asarray(t.OFM)
    EXTRA = jnp.asarray(t.EXTRA)
    BAND = jnp.asarray(t.BAND)
    OFM_ROW = jnp.asarray(t.OFM_ROW)
    FMS = IFM + OFM + EXTRA

    wtile = jnp.minimum(pf_l, F[None]) * ckk[None] * wb  # (B, L)
    fm_tile2 = 2.0 * OFM_ROW[None] * fm_tile_rows * wb

    # pipelined: floor = sum(2*fm_tile + wtile); desire = sum(W + 2*fm_tile)
    floor_pipe = _seg_sum((fm_tile2 + wtile) * pipe_l, onehot)
    desire_pipe = _seg_sum((W[None] * wb + fm_tile2) * pipe_l, onehot)
    # single: floor = max(wtile + band + ofm_row); desire = max FMS + max wtile
    floor_single = _seg_max(
        jnp.where(single_l > 0, wtile + (BAND + OFM_ROW)[None] * wb, NEG),
        onehot)
    max_fms = _seg_max(jnp.where(single_l > 0, FMS[None] * wb, NEG), onehot)
    max_wtile = _seg_max(jnp.where(single_l > 0, wtile, NEG), onehot)
    desire_single = max_fms + max_wtile

    is_pipe_seg = design.seg_pipe & seg_valid
    floors = jnp.where(is_pipe_seg, floor_pipe,
                       jnp.where(seg_valid, jnp.maximum(floor_single, 0.0),
                                 0.0))
    desires = jnp.where(is_pipe_seg, desire_pipe,
                        jnp.where(seg_valid,
                                  jnp.maximum(desire_single, 0.0), 0.0))
    desires = jnp.maximum(desires, floors)

    budget_b = dev.on_chip_bytes
    alloc = floors
    over = alloc.sum(-1) > budget_b
    scale = jnp.where(over, budget_b / jnp.maximum(alloc.sum(-1), 1.0), 1.0)
    alloc = jnp.floor(alloc * scale[:, None])
    remaining = budget_b - alloc.sum(-1)                 # (B,)

    # ---- inter-segment double buffers, smallest-first ---------------------
    # boundary i lives after segment i (valid while i < n_seg - 1)
    b_ix = jnp.arange(NS)
    bound_valid = (b_ix[None, :] < (n_seg - 1)[:, None])
    last_of_seg = jnp.clip(seg_end - 1, 0, t.L - 1)      # (B, NS)
    bound_size = OFM[last_of_seg] * wb                   # (B, NS)
    bound_size = jnp.where(bound_valid, bound_size, jnp.inf)
    order = jnp.argsort(bound_size, axis=-1, stable=True)
    sorted_sz = jnp.take_along_axis(bound_size, order, axis=-1)
    csum = jnp.cumsum(jnp.where(jnp.isfinite(sorted_sz), 2 * sorted_sz, 0.0),
                      axis=-1)
    fit_sorted = (csum <= remaining[:, None]) & jnp.isfinite(sorted_sz)
    fit = jnp.zeros_like(fit_sorted).at[
        jnp.arange(B)[:, None], order].set(fit_sorted)
    inter_onchip = fit & bound_valid & design.inter_pipe[:, None]
    remaining = remaining - (2 * jnp.where(inter_onchip, OFM[last_of_seg]
                                           * wb, 0.0)).sum(-1)

    # ---- grant remaining toward minimum-access desires --------------------
    gaps = jnp.maximum(desires - alloc, 0.0)
    gap_sum = gaps.sum(-1)
    grant = jnp.minimum(jnp.maximum(remaining, 0.0), gap_sum)
    alloc = alloc + jnp.where(gap_sum[:, None] > 0,
                              jnp.floor(grant[:, None] * gaps
                                        / jnp.maximum(gap_sum[:, None], 1.0)),
                              0.0)

    # ---- pipelined per-CE buffer split (desire share within segment) ------
    ce_desire_l = (W[None] * wb + fm_tile2) * pipe_l     # (B, L)
    ce_desire = jnp.einsum("bl,blc->bc", ce_desire_l, ce_oh)
    seg_of_ce_desire = _seg_sum(ce_desire_l, onehot)     # (B, NS)
    alloc_of_layer = jnp.take_along_axis(alloc, seg_of_layer, axis=1)
    segdes_of_layer = jnp.take_along_axis(
        jnp.maximum(seg_of_ce_desire, 1.0), seg_of_layer, axis=1)
    cedes_of_layer = jnp.einsum("bc,blc->bl", ce_desire, ce_oh)
    ce_buf_of_layer = jnp.floor(
        alloc_of_layer * cedes_of_layer / segdes_of_layer)

    # weights resident (Eq. 5 regime): alloc covers the Eq. 5 requirement
    resident_seg = (alloc >= desire_pipe) & is_pipe_seg
    resident_l = jnp.take_along_axis(
        resident_seg.astype(jnp.int32), seg_of_layer, axis=1) > 0

    # n_tiles per layer: max OH over the layers of the same (seg, round).
    # Rounds are contiguous layer runs, so the group max is the combine of
    # a forward and a backward segmented max-scan — no (B, NS*rounds)
    # scatter map needed.
    is_round_start = slot_of_layer == 0
    is_round_last = (slot_of_layer == nce_of_layer - 1) | \
        (idx_in_seg == jnp.take_along_axis(seg_len, seg_of_layer, axis=1) - 1)
    OH_b = jnp.broadcast_to(OH[None], (B, max_L))
    n_tiles_l = jnp.maximum(
        jnp.maximum(seg_scan_max(OH_b, is_round_start),
                    seg_scan_max(OH_b, is_round_last, reverse=True)), 1.0)

    # ---- off-chip accesses ------------------------------------------------
    # pipelined (Eq. 7)
    w_bytes = W[None] * wb
    w_acc_pipe = jnp.where(
        resident_l, 0.0,
        jnp.where(ce_buf_of_layer >= w_bytes, w_bytes,
                  w_bytes * n_tiles_l))
    mem_cyc_pipe = w_acc_pipe / bpc

    # single (Eq. 6) — fully vectorized: the ifm_onchip "chain" has no true
    # recurrence (layer l's residency verdict doesn't depend on the carry),
    # so it's a shift-by-one within each segment, not a scan.
    buf = alloc_of_layer                                 # (B, L)
    wl = W[None] * wb
    ifml = IFM[None] * wb
    ofml = OFM[None] * wb
    extral = EXTRA[None] * wb
    ideal = ifml + ofml + extral + wtile <= buf          # (B, L)

    ifm_tile = jnp.minimum(ifml, BAND[None] * wb)
    ofm_on = ofml + extral + wtile + ifm_tile <= buf
    ofm_res = jnp.where(ofm_on, ofml + extral, 0.0)
    ofm_acc = jnp.where(ofm_on, 0.0, ofml)

    # layer l leaves its OFM on-chip for l+1 iff ideal or ofm_on
    next_on = jnp.where(ideal, True, ofm_on)             # (B, L)
    prev_on = jnp.concatenate(
        [jnp.zeros((B, 1), bool), next_on[:, :-1]], axis=1)
    is_seg_start = idx_in_seg == 0
    prev_boundary_onchip = jnp.take_along_axis(
        inter_onchip, jnp.maximum(seg_of_layer - 1, 0), axis=1) \
        & (seg_of_layer > 0)
    ifm_onchip = jnp.where(is_seg_start, prev_boundary_onchip, prev_on)

    fm_ideal = jnp.where(ifm_onchip, 0.0, ifml)
    acc_prev_resident = ofm_acc + wl                     # ifm already on-chip
    ifm_buf = jnp.maximum(buf - ofm_res - wtile, ifm_tile)
    loads_a = jnp.where(ifm_buf < ifml,
                        wl * jnp.ceil(ifml / jnp.maximum(ifm_buf, 1.0))
                        + ifml,
                        wl + ifml)
    wacc_a = loads_a - ifml
    w_buf = jnp.maximum(buf - ofm_res - ifm_tile, wtile)
    loads_b = jnp.where(w_buf < wl,
                        ifml * jnp.ceil(wl / jnp.maximum(w_buf, 1.0)) + wl,
                        ifml + wl)
    facc_b = loads_b - wl
    use_a = loads_a <= loads_b
    acc_opt = ofm_acc + jnp.where(use_a, loads_a, loads_b)
    wacc_opt = jnp.where(use_a, wacc_a, wl)
    facc_opt = ofm_acc + jnp.where(use_a, ifml, facc_b)

    acc_single = jnp.where(ideal, wl + fm_ideal,
                           jnp.where(ifm_onchip, acc_prev_resident, acc_opt))
    wacc_single = jnp.where(ideal, wl,
                            jnp.where(ifm_onchip, wl, wacc_opt))
    facc_single = jnp.where(ideal, fm_ideal,
                            jnp.where(ifm_onchip, ofm_acc, facc_opt))
    mem_cyc_single = acc_single / bpc

    return LayerState(
        comp=comp, util=util,
        lat_single=jnp.maximum(comp, mem_cyc_single),
        acc_single=acc_single, wacc_single=wacc_single,
        facc_single=facc_single, mem_cyc_single=mem_cyc_single,
        busy_pipe=jnp.maximum(comp, mem_cyc_pipe),
        w_acc_pipe=w_acc_pipe, mem_cyc_pipe=mem_cyc_pipe,
        n_tiles_l=n_tiles_l,
        buf_l=buf, ce_buf_l=ce_buf_of_layer, wtile=wtile,
        fm_tile2=fm_tile2, ofm_res=ofm_res, ofm_acc=ofm_acc,
        ideal=ideal, ifm_onchip=ifm_onchip, use_a=use_a,
        resident_l=resident_l,
        alloc=alloc, desires=desires, inter_onchip=inter_onchip,
        bound_valid=bound_valid, is_pipe_seg=is_pipe_seg)


def compose_metrics(design: DesignBatch, t: NetTables, dev: DeviceTables,
                    m: _CEMaps, st: LayerState) -> dict[str, jnp.ndarray]:
    """Eqs. 2–3 + 8–9: per-layer costs -> design metrics.

    Monotone nondecreasing in every per-layer latency/busy/traffic field
    of ``st`` — the property the schedule layer's refined-≤-coarse
    guarantee rests on."""
    B, max_L = design.batch, t.max_L
    wb = dev.wordbytes
    (seg_start, seg_len, seg_valid, n_seg, seg_of_layer, onehot, valid_b,
     idx_in_seg, nce_of_layer, pipe_bool, slot_of_layer, _round,
     ce_base, _ce_of_layer, ce_oh, _pes, ce_valid) = m
    valid_f = valid_b.astype(jnp.float32)
    seg_end = design.seg_end
    pipe_l = pipe_bool.astype(jnp.float32)
    single_l = (1.0 - pipe_l) * valid_f
    is_round_start = slot_of_layer == 0
    is_round_last = (slot_of_layer == nce_of_layer - 1) | \
        (idx_in_seg == jnp.take_along_axis(seg_len, seg_of_layer, axis=1) - 1)
    last_of_seg = jnp.clip(seg_end - 1, 0, t.L - 1)      # (B, NS)
    OFM = jnp.asarray(t.OFM)
    IFM = jnp.asarray(t.IFM)
    macs = jnp.asarray(t.MACS)
    n_tiles_l = st.n_tiles_l
    inter_onchip = st.inter_onchip
    bound_valid = st.bound_valid
    is_pipe_seg = st.is_pipe_seg
    alloc, desires = st.alloc, st.desires

    # ---- latency / busy ---------------------------------------------------
    lat_l_single = st.lat_single * single_l
    seg_lat_single = _seg_sum(lat_l_single, onehot)      # (B, NS)

    # pipelined: tile lat per layer; exact stage-sum per round via the
    # prefix/suffix-max identity (segmented max-scans, log2(L) steps).
    tile_lat = st.busy_pipe / n_tiles_l                  # (B, L)
    pmax_seq = seg_scan_max(tile_lat, is_round_start)
    smax_seq = seg_scan_max(tile_lat, is_round_last, reverse=True)
    pipe_f = pipe_bool
    prefix_sum_all = jnp.where(pipe_f, pmax_seq, 0.0).sum(-1)
    suffix_sum_all = jnp.where(pipe_f, smax_seq, 0.0).sum(-1)
    gmax_l = jnp.where(pipe_f & is_round_last, pmax_seq, 0.0)

    # round latency = prefix_sum(0..n-1) + suffix_sum(0..n-1) - gmax
    #                 + (T - n) * gmax            [T = n_tiles, n = slots]
    slots_round = jnp.where(pipe_f & is_round_last,
                            slot_of_layer.astype(jnp.float32) + 1.0, 0.0)
    T_round = jnp.where(pipe_f & is_round_last, n_tiles_l, 0.0)
    lat_pipe_total = (prefix_sum_all + suffix_sum_all
                      + ((T_round - slots_round - 1.0) * gmax_l).sum(-1))

    # per-CE busy (Eq. 3 / throughput)
    busy_l = st.busy_pipe                                # pipelined layers
    busy_slot = jnp.einsum("bl,blc->bc", busy_l * pipe_l, ce_oh)  # (B, NC)
    # pipelined block busy = max over its slots; map back per segment:
    seg_of_ce = jnp.sum(
        (jnp.arange(NC)[None, :, None]
         >= (ce_base + design.seg_nce * seg_valid)[:, None, :]),
        axis=-1)                                         # (B, NC)
    seg_ce_oh = jax.nn.one_hot(seg_of_ce, NS, dtype=jnp.float32)
    busy_pipe_seg = jnp.where(
        is_pipe_seg,
        jnp.max(jnp.where(seg_ce_oh > 0, busy_slot[..., None], NEG), axis=1),
        0.0)
    busy_single_seg = jnp.where(~design.seg_pipe & seg_valid,
                                seg_lat_single, 0.0)

    # single-CE ids may serve multiple segments: busy adds per CE
    ce_first = ce_base                                   # (B, NS)
    add_single = jnp.zeros((B, NC)).at[
        jnp.arange(B)[:, None], ce_first].add(
        jnp.where(~design.seg_pipe & seg_valid, busy_single_seg, 0.0))
    add_pipe = jnp.zeros((B, NC)).at[
        jnp.arange(B)[:, None], ce_first].add(busy_pipe_seg)
    ce_busy = add_single + add_pipe

    # ---- interfaces: mandatory IO + Eq. 9 ---------------------------------
    access = (st.acc_single * single_l + st.w_acc_pipe * pipe_l).sum(-1)
    w_access = (st.wacc_single * single_l + st.w_acc_pipe * pipe_l).sum(-1)
    fm_access = (st.facc_single * single_l).sum(-1)
    mandatory = (IFM[0] + jnp.take(OFM, t.L - 1)) * wb
    access = access + mandatory
    fm_access = fm_access + mandatory

    bound_sz = jnp.where(bound_valid, OFM[last_of_seg] * wb, 0.0)
    spill = bound_valid & ~inter_onchip
    access = access + (2 * jnp.where(spill, bound_sz, 0.0)).sum(-1)
    fm_access = fm_access + (2 * jnp.where(spill, bound_sz, 0.0)).sum(-1)
    comm_cyc = ((jnp.where(spill, 2 * bound_sz, bound_sz) / dev.bps)
                * dev.clock_hz * bound_valid).sum(-1)

    latency_cyc = seg_lat_single.sum(-1) + lat_pipe_total + comm_cyc
    latency_s = latency_cyc / dev.clock_hz

    multi = (n_seg > 1) & design.inter_pipe
    bottleneck = jnp.where(multi, ce_busy.max(-1),
                           jnp.where(n_seg > 1, latency_cyc,
                                     jnp.maximum(ce_busy.max(-1), 1.0)))
    throughput = dev.clock_hz / jnp.maximum(bottleneck, 1.0)

    buffer_alloc = alloc.sum(-1) + (
        2 * jnp.where(inter_onchip, bound_sz, 0.0)).sum(-1)
    # Eq. 8 requirement (what the paper's buffer metric reports)
    buffer_req = desires.sum(-1) + jnp.where(
        design.inter_pipe, (2 * bound_sz).sum(-1), 0.0)

    util_avg = (st.util * macs[None]).sum(-1) / jnp.maximum(macs.sum(), 1.0)

    return {
        "latency_s": latency_s,
        "throughput_ips": throughput,
        "buffer_bytes": buffer_req,
        "buffer_alloc_bytes": buffer_alloc,
        "access_bytes": access,
        "weight_access_bytes": w_access,
        "fm_access_bytes": fm_access,
        "utilization": util_avg,
        "n_ces": ce_valid.sum(-1),
    }


def _evaluate_core(design: DesignBatch, t: NetTables, dev: DeviceTables,
                   m: _CEMaps, par, fm_tile_rows: int) -> dict:
    """Full MCCM evaluation: per-layer state then Eq. 2–9 composition."""
    return compose_metrics(design, t, dev, m,
                           layer_state(design, t, dev, m, par, fm_tile_rows))


def _pad_rows(design: DesignBatch, n: int) -> DesignBatch:
    """Edge-pad a DesignBatch to ``n`` rows (padded rows are evaluated and
    discarded — keeping shapes static kills tail recompiles)."""
    pad = n - design.batch
    if pad <= 0:
        return design
    rep = lambda a: jnp.concatenate([a, jnp.repeat(a[-1:], pad, 0)], 0)
    return DesignBatch(rep(design.seg_end), rep(design.seg_pipe),
                       rep(design.seg_nce), rep(design.inter_pipe))


def padded_rows(B: int, tile: int = DEFAULT_TILE, ndevices: int = 1) -> int:
    """Rows actually executed for a B-design call (B padded to a multiple
    of ``ndevices x tile``) — the single source of the tiling policy for
    benchmarks and the mesh layer.  Rounding to the *device-count*
    multiple keeps every shard an identical whole number of tiles, so a
    B not divisible by the device count never reshards or recompiles."""
    unit = tile * max(int(ndevices), 1)
    return -(-B // unit) * unit


def eval_design_block(design: DesignBatch, tables: NetTables,
                      dev: DeviceTables, pairs, fc_pair, coh_pair, *,
                      backend: str = "ref", design_tile: int = 16,
                      fm_tile_rows: int = 2) -> dict[str, jnp.ndarray]:
    """Fully traced evaluation of one design block (no tiling/padding):
    CE maps -> fused ⟨pf, ph, pw⟩ search -> Eqs. 2–9.

    The shared building block: the ``lax.map`` hot loop below runs it per
    design tile, and ``core.multinet`` vmaps it across the model axis with
    per-row partitioned devices."""
    m = _ce_maps(design, tables, dev)
    pf, ph, pw, _cost = parallelism_search(
        m.pes_ce, m.ce_of_layer, m.ce_oh, fc_pair, coh_pair,
        tables.CEIL_OW, tables.OW[:, None], pairs, backend=backend,
        design_tile=design_tile)
    return _evaluate_core(design, tables, dev, m, (pf, ph, pw), fm_tile_rows)


def evaluate_batch_traced(design: DesignBatch, tables: NetTables,
                          dev: DeviceTables, *, backend: str = "ref",
                          tile: int = DEFAULT_TILE, fm_tile_rows: int = 2,
                          pes_hint_static: int | None = None,
                          design_tile: int = 16) -> dict[str, jnp.ndarray]:
    """The traced hot path (call under jit; ``evaluate_batch`` wraps it).

    Designs are processed in ``tile``-wide blocks through ``lax.map`` so
    every intermediate — most importantly the (tile, L, P) parallelism-
    search block — stays cache/VMEM-resident; per tile the search
    dispatches to the selected ``kernels.mccm_eval`` backend.

    ``pes_hint_static`` prunes the candidate-pair grid and is only sound
    when the device's PE total is <= the hint; the default (None) keeps
    every pair.  ``evaluate_batch``/``search`` pass the bucket computed
    from the concrete device.
    """
    B = design.batch
    pairs = pair_tables(tables.candidates, pes_hint_static)
    fc_pair, coh_pair = _pair_layer_tables(tables, pairs)

    nt = -(-B // tile)
    padded = _pad_rows(design, nt * tile)

    def one(args):
        return eval_design_block(
            DesignBatch(*args), tables, dev, pairs, fc_pair, coh_pair,
            backend=backend, design_tile=design_tile,
            fm_tile_rows=fm_tile_rows)

    out = jax.lax.map(one, (padded.seg_end.reshape(nt, tile, NS),
                            padded.seg_pipe.reshape(nt, tile, NS),
                            padded.seg_nce.reshape(nt, tile, NS),
                            padded.inter_pipe.reshape(nt, tile)))
    return {k: v.reshape(nt * tile)[:B] for k, v in out.items()}


@partial(jax.jit, static_argnames=("backend", "tile", "fm_tile_rows",
                                   "pes_hint_static", "design_tile"))
def _evaluate_jit(design, tables, dev, *, backend, tile, fm_tile_rows,
                  pes_hint_static, design_tile):
    return evaluate_batch_traced(
        design, tables, dev, backend=backend, tile=tile,
        fm_tile_rows=fm_tile_rows, pes_hint_static=pes_hint_static,
        design_tile=design_tile)


def evaluate_batch(design: DesignBatch, tables: NetTables,
                   dev: DeviceSpec | DeviceTables, fm_tile_rows: int = 2,
                   *, backend: str | None = None, tile: int = DEFAULT_TILE,
                   design_tile: int = 16, mesh=None) -> dict[str, jnp.ndarray]:
    """DesignBatch -> metric arrays, one jitted dispatch.

    One compiled program serves every CNN (tables are traced, padded to a
    shared ``max_L``) and every board (traced scalars); only the batch
    shape and the static knobs key the jit cache.

    ``mesh`` (a ``core.shard.EvalMesh``, duck-typed to avoid an import
    cycle) shards the design axis across its devices; a None or
    single-device mesh takes this unchanged single-device path.
    """
    backend = resolve_backend(backend)
    if isinstance(dev, DeviceSpec):
        hint = pes_hint(dev.pes)
        devt = make_device_tables(dev)
    else:
        devt = dev
        hint = pes_hint(float(dev.pes))
    if mesh is not None and getattr(mesh, "is_sharded", False):
        return mesh.evaluate_padded(
            design, tables, devt, backend=backend, tile=tile,
            fm_tile_rows=fm_tile_rows, pes_hint_static=hint,
            design_tile=design_tile)
    return _evaluate_jit(design, tables, devt, backend=backend, tile=tile,
                         fm_tile_rows=fm_tile_rows, pes_hint_static=hint,
                         design_tile=design_tile)


# --------------------------------------------------------------------------
# spec-list convenience wrappers (recompile-free chunking)
# --------------------------------------------------------------------------
def _bucket(b: int, tile: int, ndevices: int = 1) -> int:
    """Smallest power-of-two multiple of ``ndevices x tile`` holding ``b``
    designs — bounds the number of distinct compiled shapes to the ladder
    size, and keeps every bucket evenly shardable across the mesh."""
    n = tile * max(int(ndevices), 1)
    while n < b:
        n *= 2
    return n


def _evaluate_specs(specs: list[AcceleratorSpec], net: Network,
                    dev: DeviceSpec, chunk: int = 2048, *,
                    tables: NetTables | None = None,
                    backend: str | None = None,
                    tile: int = DEFAULT_TILE,
                    pad_to: int | None = None,
                    fm_tile_rows: int = 2,
                    design_tile: int = 16, mesh=None) -> dict[str, np.ndarray]:
    """Implementation behind ``Session.evaluate`` (spec lists) and the
    deprecated ``evaluate_specs`` shim: specs -> stacked metric arrays
    (chunked).

    Every chunk — including the tail — is padded to a static shape, so a
    100k-design sweep compiles exactly once (and shares that compile with
    every other CNN × board sweep at the same chunk size).  ``pad_to``
    overrides the bucket (``_evaluate_specs_multi`` uses it to share one
    shape across differently-sized jobs).  Under a sharded ``mesh`` the
    bucket rounds to a multiple of ``ndevices x tile`` so no B triggers a
    resharding recompile."""
    if not specs:
        raise ValueError("no specs to evaluate (empty design list)")
    tables = make_tables(net) if tables is None else tables
    nd = mesh.ndevices if mesh is not None and mesh.is_sharded else 1
    n_layers = len(net)
    outs: list[dict] = []
    n = len(specs)
    if pad_to is None:
        pad_to = chunk if n > chunk else _bucket(max(n, 1), tile, nd)
    for i in range(0, n, chunk):
        sub = specs[i:i + chunk]
        batch = _pad_rows(encode_specs(sub, n_layers), pad_to)
        out = evaluate_batch(batch, tables, dev, fm_tile_rows,
                             backend=backend, tile=tile,
                             design_tile=design_tile, mesh=mesh)
        outs.append({k: np.asarray(v)[:len(sub)] for k, v in out.items()})
    return {k: np.concatenate([o[k] for o in outs]) for k in outs[0]}


def evaluate_specs(specs: list[AcceleratorSpec], net: Network,
                   dev: DeviceSpec, chunk: int = 2048, *,
                   tables: NetTables | None = None,
                   backend: str | None = None,
                   tile: int = DEFAULT_TILE,
                   pad_to: int | None = None) -> dict[str, np.ndarray]:
    from ._deprecation import warn_deprecated
    warn_deprecated("evaluate_specs", "repro.api.Session.evaluate")
    return _evaluate_specs(specs, net, dev, chunk, tables=tables,
                           backend=backend, tile=tile, pad_to=pad_to)


def _evaluate_specs_multi(jobs, chunk: int = 2048, *,
                          backend: str | None = None,
                          tile: int = DEFAULT_TILE,
                          tables=None, fm_tile_rows: int = 2,
                          design_tile: int = 16, mesh=None) -> list[dict]:
    """Implementation behind ``Session.submit``'s drain loop and the
    deprecated ``evaluate_specs_multi`` shim: cross-(CNN × board)
    megabatch.  ``jobs`` is a sequence of ``(specs, net, dev)`` triples;
    returns one metric dict per job.  ``tables``, when given, is one
    prebuilt ``NetTables`` per job (the Session passes its memoized
    tables here).

    Because NetTables / DeviceTables are traced pytrees padded to shared
    shapes, and every job's chunks are padded to one shared bucket, the
    whole sweep runs through a single compiled program — the per-job work
    differs only in array *values*."""
    nd = mesh.ndevices if mesh is not None and mesh.is_sharded else 1
    sizes = [min(max(len(specs), 1), chunk) for specs, _, _ in jobs]
    pad_to = max((_bucket(s, tile, nd) for s in sizes), default=tile * nd)
    results = []
    for i, (specs, net, dev) in enumerate(jobs):
        results.append(_evaluate_specs(
            specs, net, dev, chunk,
            tables=None if tables is None else tables[i],
            backend=backend, tile=tile, pad_to=pad_to,
            fm_tile_rows=fm_tile_rows, design_tile=design_tile, mesh=mesh))
    return results


def evaluate_specs_multi(jobs, chunk: int = 2048, *,
                         backend: str | None = None,
                         tile: int = DEFAULT_TILE) -> list[dict]:
    from ._deprecation import warn_deprecated
    warn_deprecated("evaluate_specs_multi",
                    "repro.api.Session.submit (or Session.evaluate per job)")
    return _evaluate_specs_multi(jobs, chunk, backend=backend, tile=tile)
