"""Vectorized MCCM: evaluate thousands of multiple-CE designs as ONE jitted
JAX program.

The scalar path (``evaluator.evaluate_design``) walks Python objects at
~100 µs–1 ms per design; the paper's own C++/Python model reports 6.3 ms.
Here every design in a batch is encoded as fixed-shape arrays (segments
padded to ``NS``, CEs to ``NC``) and Eqs. 1–9 are evaluated with masked
tensor ops — the whole DSE sample becomes a handful of XLA kernels.

Exactness: this is the *same* model, not an approximation —
``tests/test_batch_eval.py`` asserts agreement with the scalar evaluator on
every baseline architecture × CNN × CE-count (largest-remainder PE
distribution, the discrete ⟨pf, ph, pw⟩ parallelism search, Eq. 6's two
buffered-access options, and the exact pipeline stage-sum via the
prefix/suffix-max identity all replicated in vector form).

Layout
------
* ``NetTables``  — static per-CNN arrays (layer dims, ceil-div tables).
* ``DesignBatch`` — (B, NS) segment encoding, defined in
  ``core.dse.encoding`` (re-exported here for compatibility).
* ``evaluate_batch`` — jitted core: DesignBatch -> metric arrays.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from .blocks import CANDIDATES_DEFAULT
from .device import DeviceSpec
from .dse.encoding import NC, NS, DesignBatch, encode_specs  # noqa: F401
from .notation import AcceleratorSpec
from .workload import Network

NEG = -1.0e30


# --------------------------------------------------------------------------
# static per-network tables
# --------------------------------------------------------------------------
@dataclass(frozen=True, eq=False)      # eq=False: identity hash — the
class NetTables:                       # tables are static jit args
    name: str
    L: int
    F: np.ndarray          # out channels
    CKK: np.ndarray        # c * kh * kw  (c=1 for depthwise)
    OH: np.ndarray
    OW: np.ndarray
    MACS: np.ndarray
    W: np.ndarray          # weights (elements)
    IFM: np.ndarray
    OFM: np.ndarray
    EXTRA: np.ndarray      # residual OFM copy (elements)
    BAND: np.ndarray       # in_ch * kh * iw  (IFM row band)
    OFM_ROW: np.ndarray    # out_ch * ow
    CEIL_F: np.ndarray     # (L, NCAND) ceil(F / cand)
    CEIL_OH: np.ndarray
    CEIL_OW: np.ndarray
    CAND: np.ndarray


def make_tables(net: Network,
                candidates=CANDIDATES_DEFAULT) -> NetTables:
    cand = np.asarray(candidates, np.int32)
    L = len(net)
    dims = [l.dims() for l in net]
    F = np.array([d["f"] for d in dims], np.float64)
    CKK = np.array([d["c"] * d["kh"] * d["kw"] for d in dims], np.float64)
    OH = np.array([d["oh"] for d in dims], np.float64)
    OW = np.array([d["ow"] for d in dims], np.float64)
    return NetTables(
        name=net.name, L=L, F=F, CKK=CKK, OH=OH, OW=OW,
        MACS=np.array([l.macs for l in net], np.float64),
        W=np.array([l.weights_size for l in net], np.float64),
        IFM=np.array([l.ifm_size for l in net], np.float64),
        OFM=np.array([l.ofm_size for l in net], np.float64),
        EXTRA=np.array([l.ofm_size if l.residual else 0 for l in net],
                       np.float64),
        BAND=np.array([l.in_ch * l.kh * l.iw for l in net], np.float64),
        OFM_ROW=np.array([l.out_ch * l.ow for l in net], np.float64),
        CEIL_F=np.ceil(F[:, None] / cand[None, :]),
        CEIL_OH=np.ceil(OH[:, None] / cand[None, :]),
        CEIL_OW=np.ceil(OW[:, None] / cand[None, :]),
        CAND=cand,
    )


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------
def _largest_remainder(shares, total, valid):
    """Vectorized largest-remainder rounding (floor 1 per valid CE).

    shares: (B, NC) f64; total: scalar; valid: (B, NC) bool.
    Mirrors builder._largest_remainder including tie-breaking by index.
    """
    n = valid.sum(-1)                                  # (B,)
    s = jnp.where(shares.sum(-1) > 0, shares.sum(-1), 1.0)
    raw = jnp.maximum(shares / s[:, None] * total, 1.0)
    raw = jnp.where(valid, raw, 0.0)
    out = jnp.where(valid, jnp.maximum(jnp.floor(raw), 1.0), 0.0)
    rem = total - out.sum(-1)                          # (B,) can be +/-
    frac = jnp.where(valid, raw - jnp.floor(raw), -1.0)
    # positive remainder: +1 to the rem largest fractions (cyclically the
    # scalar hands out one each in frac order; rem < n in practice)
    order = jnp.argsort(-frac, axis=-1, stable=True)
    rank = jnp.argsort(order, axis=-1, stable=True)    # rank in frac order
    give = rank < jnp.maximum(rem, 0)[:, None]
    out = out + jnp.where(valid & give, 1.0, 0.0)
    # negative remainder: take from the largest allocations (scalar loops;
    # one pass suffices when floors forced the overflow)
    deficit = jnp.maximum(-rem, 0.0)
    big_order = jnp.argsort(-out, axis=-1, stable=True)
    big_rank = jnp.argsort(big_order, axis=-1, stable=True)
    take = (big_rank < deficit[:, None]) & (out > 1.0)
    out = out - jnp.where(take, 1.0, 0.0)
    return out


def _seg_onehot(seg_of_layer, valid_layer):
    """(B, L, NS) one-hot of each layer's segment id."""
    oh = jax.nn.one_hot(seg_of_layer, NS, dtype=jnp.float32)
    return oh * valid_layer[..., None]


def _seg_sum(x, onehot):
    """sum of per-layer x (B, L) into segments -> (B, NS)."""
    return jnp.einsum("bl,bls->bs", x, onehot)


def _seg_max(x, onehot):
    big = jnp.where(onehot > 0, x[..., None], NEG)
    return big.max(axis=1)


# --------------------------------------------------------------------------
# the jitted core
# --------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("tables", "dev", "fm_tile_rows"))
def evaluate_batch(design: DesignBatch, tables: NetTables, dev: DeviceSpec,
                   fm_tile_rows: int = 2) -> dict[str, jnp.ndarray]:
    t, B, L = tables, design.batch, tables.L
    wb = float(dev.wordbytes)
    bpc = dev.off_chip_bytes_per_cycle
    cand = jnp.asarray(t.CAND, jnp.float32)
    ncand = cand.shape[0]
    layer_ix = jnp.arange(L)

    # ---- layer -> segment / CE maps --------------------------------------
    seg_end = design.seg_end                      # (B, NS)
    seg_start = jnp.concatenate(
        [jnp.zeros((B, 1), jnp.int32), seg_end[:, :-1]], axis=1)
    seg_len = seg_end - seg_start                 # (B, NS)
    seg_valid = seg_len > 0
    n_seg = seg_valid.sum(-1)                     # (B,)

    # seg of layer: first segment with end > l
    seg_of_layer = jnp.sum(
        (layer_ix[None, :, None] >= seg_end[:, None, :]).astype(jnp.int32),
        axis=-1)                                  # (B, L)
    valid_layer = jnp.ones((B, L), jnp.float32)   # all layers always covered
    onehot = _seg_onehot(seg_of_layer, valid_layer)     # (B, L, NS)

    idx_in_seg = layer_ix[None, :] - jnp.take_along_axis(
        seg_start, seg_of_layer, axis=1)
    nce_of_layer = jnp.take_along_axis(design.seg_nce, seg_of_layer, axis=1)
    pipe_of_layer = jnp.take_along_axis(
        design.seg_pipe.astype(jnp.int32), seg_of_layer, axis=1) > 0
    slot_of_layer = idx_in_seg % jnp.maximum(nce_of_layer, 1)
    round_of_layer = idx_in_seg // jnp.maximum(nce_of_layer, 1)

    ce_base = jnp.cumsum(design.seg_nce * seg_valid, axis=-1) \
        - design.seg_nce * seg_valid
    ce_of_layer = jnp.take_along_axis(ce_base, seg_of_layer, axis=1) \
        + slot_of_layer                            # (B, L) in [0, NC)
    ce_oh = jax.nn.one_hot(ce_of_layer, NC, dtype=jnp.float32)  # (B, L, NC)

    # ---- 1. PE distribution (largest remainder over per-CE MACs) --------
    macs = jnp.asarray(t.MACS)
    macs_ce = jnp.einsum("l,blc->bc", macs, ce_oh)       # (B, NC)
    ce_valid = jnp.einsum("blc->bc", ce_oh) > 0
    pes_ce = _largest_remainder(macs_ce, float(dev.pes), ce_valid)  # (B, NC)

    # ---- 2. parallelism search: best <pf, ph, pw> per CE -----------------
    # pw index per (B, NC, i, j): largest cand with pf*ph*pw <= pes
    pf_ph = cand[:, None] * cand[None, :]                # (i, j)
    budget = pes_ce[:, :, None, None] / pf_ph[None, None]
    pw_idx = jnp.clip(
        jnp.searchsorted(cand, jnp.floor(budget), side="right") - 1,
        0, ncand - 1)                                    # (B, NC, i, j)
    feasible = budget >= 1.0                             # pf*ph <= pes

    ceil_f = jnp.asarray(t.CEIL_F)                       # (L, i)
    ceil_oh = jnp.asarray(t.CEIL_OH)                     # (L, j)
    ceil_ow = jnp.asarray(t.CEIL_OW)                     # (L, w)
    ckk = jnp.asarray(t.CKK)

    # cost accumulation as ONE batched GEMM: per-layer cycles for every
    # (i, j) with the layer's own CE's pw budget, then contract over layers
    # against the CE one-hot.  (A lax.scan formulation was 50x slower —
    # 53 dispatches moving a (B, NC, 18, 18) carry each step.)
    pw_sel = jnp.take_along_axis(
        pw_idx, ce_of_layer[:, :, None, None], axis=1)   # (B, L, i, j)
    cow_sel = ceil_ow[jnp.arange(L)[None, :, None, None], pw_sel]
    Hmat = (ceil_f[None, :, :, None] * ckk[None, :, None, None]
            * ceil_oh[None, :, None, :] * cow_sel)       # (B, L, i, j)
    cost_ce = jnp.einsum("blk,blc->bck",
                         Hmat.reshape(B, L, ncand * ncand),
                         ce_oh).reshape(B, NC, ncand, ncand)
    cost_ce = jnp.where(feasible, cost_ce, jnp.inf)
    flat = cost_ce.reshape(B, NC, -1)
    best = jnp.argmin(flat, axis=-1)                     # (B, NC)
    bi, bj = best // ncand, best % ncand
    pf_ce = cand[bi]                                     # (B, NC)
    ph_ce = cand[bj]
    pw_ce = cand[jnp.take_along_axis(
        pw_idx.reshape(B, NC, -1), best[..., None], axis=-1)[..., 0]]

    # ---- per-layer compute cycles & utilization --------------------------
    pf_l = jnp.einsum("bc,blc->bl", pf_ce, ce_oh)        # (B, L)
    ph_l = jnp.einsum("bc,blc->bl", ph_ce, ce_oh)
    pw_l = jnp.einsum("bc,blc->bl", pw_ce, ce_oh)
    F = jnp.asarray(t.F)
    OH = jnp.asarray(t.OH)
    OW = jnp.asarray(t.OW)
    comp = (jnp.ceil(F[None] / pf_l) * ckk[None]
            * jnp.ceil(OH[None] / ph_l) * jnp.ceil(OW[None] / pw_l))
    par_total = pf_l * ph_l * pw_l
    util = macs[None] / jnp.maximum(comp * par_total, 1.0)

    # ---- 3. buffer floors / desires (Eq. 4 / 5) ---------------------------
    W = jnp.asarray(t.W)
    IFM = jnp.asarray(t.IFM)
    OFM = jnp.asarray(t.OFM)
    EXTRA = jnp.asarray(t.EXTRA)
    BAND = jnp.asarray(t.BAND)
    OFM_ROW = jnp.asarray(t.OFM_ROW)
    FMS = IFM + OFM + EXTRA

    wtile = jnp.minimum(pf_l, F[None]) * ckk[None] * wb  # (B, L)
    fm_tile2 = 2.0 * OFM_ROW[None] * fm_tile_rows * wb

    pipe_l = pipe_of_layer.astype(jnp.float32)
    # pipelined: floor = sum(2*fm_tile + wtile); desire = sum(W + 2*fm_tile)
    floor_pipe = _seg_sum((fm_tile2 + wtile) * pipe_l, onehot)
    desire_pipe = _seg_sum((W[None] * wb + fm_tile2) * pipe_l, onehot)
    # single: floor = max(wtile + band + ofm_row); desire = max FMS + max wtile
    single_l = 1.0 - pipe_l
    floor_single = _seg_max(
        jnp.where(single_l > 0, wtile + (BAND + OFM_ROW)[None] * wb, NEG),
        onehot)
    max_fms = _seg_max(jnp.where(single_l > 0, FMS[None] * wb, NEG), onehot)
    max_wtile = _seg_max(jnp.where(single_l > 0, wtile, NEG), onehot)
    desire_single = max_fms + max_wtile

    is_pipe_seg = design.seg_pipe & seg_valid
    floors = jnp.where(is_pipe_seg, floor_pipe,
                       jnp.where(seg_valid, jnp.maximum(floor_single, 0.0),
                                 0.0))
    desires = jnp.where(is_pipe_seg, desire_pipe,
                        jnp.where(seg_valid,
                                  jnp.maximum(desire_single, 0.0), 0.0))
    desires = jnp.maximum(desires, floors)

    budget_b = float(dev.on_chip_bytes)
    alloc = floors
    over = alloc.sum(-1) > budget_b
    scale = jnp.where(over, budget_b / jnp.maximum(alloc.sum(-1), 1.0), 1.0)
    alloc = jnp.floor(alloc * scale[:, None])
    remaining = budget_b - alloc.sum(-1)                 # (B,)

    # ---- 4. inter-segment double buffers, smallest-first ------------------
    # boundary i lives after segment i (valid while i < n_seg - 1)
    b_ix = jnp.arange(NS)
    bound_valid = (b_ix[None, :] < (n_seg - 1)[:, None])
    last_of_seg = jnp.clip(seg_end - 1, 0, L - 1)        # (B, NS)
    bound_size = OFM[last_of_seg] * wb                   # (B, NS)
    bound_size = jnp.where(bound_valid, bound_size, jnp.inf)
    order = jnp.argsort(bound_size, axis=-1, stable=True)
    sorted_sz = jnp.take_along_axis(bound_size, order, axis=-1)
    csum = jnp.cumsum(jnp.where(jnp.isfinite(sorted_sz), 2 * sorted_sz, 0.0),
                      axis=-1)
    fit_sorted = (csum <= remaining[:, None]) & jnp.isfinite(sorted_sz)
    fit = jnp.zeros_like(fit_sorted).at[
        jnp.arange(B)[:, None], order].set(fit_sorted)
    inter_onchip = fit & bound_valid & design.inter_pipe[:, None]
    remaining = remaining - (2 * jnp.where(inter_onchip, OFM[last_of_seg]
                                           * wb, 0.0)).sum(-1)

    # ---- 5. grant remaining toward minimum-access desires -----------------
    gaps = jnp.maximum(desires - alloc, 0.0)
    gap_sum = gaps.sum(-1)
    grant = jnp.minimum(jnp.maximum(remaining, 0.0), gap_sum)
    alloc = alloc + jnp.where(gap_sum[:, None] > 0,
                              jnp.floor(grant[:, None] * gaps
                                        / jnp.maximum(gap_sum[:, None], 1.0)),
                              0.0)

    # ---- pipelined per-CE buffer split (desire share within segment) ------
    ce_desire_l = (W[None] * wb + fm_tile2) * pipe_l     # (B, L)
    ce_desire = jnp.einsum("bl,blc->bc", ce_desire_l, ce_oh)
    seg_of_ce_desire = _seg_sum(ce_desire_l, onehot)     # (B, NS) == desire_pipe
    alloc_of_layer = jnp.take_along_axis(alloc, seg_of_layer, axis=1)
    segdes_of_layer = jnp.take_along_axis(
        jnp.maximum(seg_of_ce_desire, 1.0), seg_of_layer, axis=1)
    cedes_of_layer = jnp.einsum("bc,blc->bl", ce_desire, ce_oh)
    ce_buf_of_layer = jnp.floor(
        alloc_of_layer * cedes_of_layer / segdes_of_layer)

    # weights resident (Eq. 5 regime): alloc covers the Eq. 5 requirement
    # (mirrors builder: resident = alloc >= pipelined_min_buffer)
    resident_seg = (alloc >= desire_pipe) & is_pipe_seg
    resident_l = jnp.take_along_axis(
        resident_seg.astype(jnp.int32), seg_of_layer, axis=1) > 0

    # n_tiles per layer: max OH over the layers of the same (seg, round)
    # round key: seg * 256 + round  (round < 256 given L <= 255)
    rkey = seg_of_layer * 256 + jnp.clip(round_of_layer, 0, 255)
    # max OH per key via segment max over sorted keys: use scatter-max
    ntile_map = jnp.full((B, NS * 256), 0.0).at[
        jnp.arange(B)[:, None], rkey].max(OH[None].repeat(B, 0))
    n_tiles_l = jnp.take_along_axis(ntile_map, rkey, axis=1)
    n_tiles_l = jnp.maximum(n_tiles_l, 1.0)

    # ---- 6. off-chip accesses --------------------------------------------
    # pipelined (Eq. 7)
    w_bytes = W[None] * wb
    w_acc_pipe = jnp.where(
        resident_l, 0.0,
        jnp.where(ce_buf_of_layer >= w_bytes, w_bytes,
                  w_bytes * n_tiles_l))
    mem_cyc_pipe = w_acc_pipe / bpc

    # single (Eq. 6) — fully vectorized: the ifm_onchip "chain" has no true
    # recurrence (layer l's residency verdict doesn't depend on the carry),
    # so it's a shift-by-one within each segment, not a scan.
    buf = alloc_of_layer                                 # (B, L)
    wl = W[None] * wb
    ifml = IFM[None] * wb
    ofml = OFM[None] * wb
    extral = EXTRA[None] * wb
    ideal = ifml + ofml + extral + wtile <= buf          # (B, L)

    ifm_tile = jnp.minimum(ifml, BAND[None] * wb)
    ofm_on = ofml + extral + wtile + ifm_tile <= buf
    ofm_res = jnp.where(ofm_on, ofml + extral, 0.0)
    ofm_acc = jnp.where(ofm_on, 0.0, ofml)

    # layer l leaves its OFM on-chip for l+1 iff ideal or ofm_on
    next_on = jnp.where(ideal, True, ofm_on)             # (B, L)
    prev_on = jnp.concatenate(
        [jnp.zeros((B, 1), bool), next_on[:, :-1]], axis=1)
    is_seg_start = idx_in_seg == 0
    prev_boundary_onchip = jnp.take_along_axis(
        inter_onchip, jnp.maximum(seg_of_layer - 1, 0), axis=1) \
        & (seg_of_layer > 0)
    ifm_onchip = jnp.where(is_seg_start, prev_boundary_onchip, prev_on)

    fm_ideal = jnp.where(ifm_onchip, 0.0, ifml)
    acc_prev_resident = ofm_acc + wl                     # ifm already on-chip
    ifm_buf = jnp.maximum(buf - ofm_res - wtile, ifm_tile)
    loads_a = jnp.where(ifm_buf < ifml,
                        wl * jnp.ceil(ifml / jnp.maximum(ifm_buf, 1.0))
                        + ifml,
                        wl + ifml)
    wacc_a = loads_a - ifml
    w_buf = jnp.maximum(buf - ofm_res - ifm_tile, wtile)
    loads_b = jnp.where(w_buf < wl,
                        ifml * jnp.ceil(wl / jnp.maximum(w_buf, 1.0)) + wl,
                        ifml + wl)
    facc_b = loads_b - wl
    use_a = loads_a <= loads_b
    acc_opt = ofm_acc + jnp.where(use_a, loads_a, loads_b)
    wacc_opt = jnp.where(use_a, wacc_a, wl)
    facc_opt = ofm_acc + jnp.where(use_a, ifml, facc_b)

    acc_single = jnp.where(ideal, wl + fm_ideal,
                           jnp.where(ifm_onchip, acc_prev_resident, acc_opt))
    wacc_single = jnp.where(ideal, wl,
                            jnp.where(ifm_onchip, wl, wacc_opt))
    facc_single = jnp.where(ideal, fm_ideal,
                            jnp.where(ifm_onchip, ofm_acc, facc_opt))
    mem_cyc_single = acc_single / bpc

    # ---- latency / busy ---------------------------------------------------
    lat_l_single = jnp.maximum(comp, mem_cyc_single) * single_l
    seg_lat_single = _seg_sum(lat_l_single, onehot)      # (B, NS)

    # pipelined: tile lat per layer; exact stage-sum per round via the
    # prefix/suffix-max identity.  The within-round running maxima are
    # *segmented* max-scans — associative, so log2(L) vector steps.
    tile_lat = jnp.maximum(comp, mem_cyc_pipe) / n_tiles_l   # (B, L)

    def seg_scan_max(vals, start_flags, reverse=False):
        """Running max within groups delimited by start_flags (B, L)."""
        def combine(a, b):
            fa, va = a
            fb, vb = b
            return fa | fb, jnp.where(fb, vb, jnp.maximum(va, vb))
        flags = start_flags[..., ::-1] if reverse else start_flags
        v = vals[..., ::-1] if reverse else vals
        # shift flags so each element STARTS its own group when flagged
        _, out = jax.lax.associative_scan(combine, (flags, v), axis=1)
        return out[..., ::-1] if reverse else out

    is_round_start = slot_of_layer == 0
    is_round_last = (slot_of_layer == nce_of_layer - 1) | \
        (idx_in_seg == jnp.take_along_axis(seg_len, seg_of_layer, axis=1) - 1)
    pmax_seq = seg_scan_max(tile_lat, is_round_start)
    smax_seq = seg_scan_max(tile_lat, is_round_last, reverse=True)
    pipe_f = pipe_of_layer
    prefix_sum_all = jnp.where(pipe_f, pmax_seq, 0.0).sum(-1)
    suffix_sum_all = jnp.where(pipe_f, smax_seq, 0.0).sum(-1)
    gmax_l = jnp.where(pipe_f & is_round_last, pmax_seq, 0.0)

    # round latency = prefix_sum(0..n-1) + suffix_sum(0..n-1) - gmax
    #                 + (T - n) * gmax            [T = n_tiles, n = slots]
    # prefix_sum_all already sums prefix maxes over all slots (incl. last =
    # gmax); suffix likewise. slots per round:
    slots_round = jnp.where(pipe_of_layer & is_round_last,
                            slot_of_layer.astype(jnp.float32) + 1.0, 0.0)
    T_round = jnp.where(pipe_of_layer & is_round_last, n_tiles_l, 0.0)
    lat_pipe_total = (prefix_sum_all + suffix_sum_all
                      + ((T_round - slots_round - 1.0) * gmax_l).sum(-1))
    seg_lat_pipe_share = None  # folded into total below

    # per-CE busy (Eq. 3 / throughput)
    busy_l = jnp.maximum(comp, mem_cyc_pipe)             # pipelined layers
    busy_slot = jnp.einsum("bl,blc->bc", busy_l * pipe_l, ce_oh)  # (B, NC)
    # pipelined block busy = max over its slots; map back per segment:
    # compute per (B, NS) = max over CEs in segment
    seg_of_ce = jnp.sum(
        (jnp.arange(NC)[None, :, None]
         >= (ce_base + design.seg_nce * seg_valid)[:, None, :]),
        axis=-1)                                         # (B, NC)
    seg_ce_oh = jax.nn.one_hot(seg_of_ce, NS, dtype=jnp.float32)
    busy_pipe_seg = jnp.where(
        is_pipe_seg,
        jnp.max(jnp.where(seg_ce_oh > 0, busy_slot[..., None], NEG), axis=1),
        0.0)
    busy_single_seg = jnp.where(~design.seg_pipe & seg_valid,
                                seg_lat_single, 0.0)

    # single-CE ids may serve multiple segments: busy adds per CE
    ce_busy = busy_slot * 0.0
    ce_first = ce_base                                   # (B, NS)
    add_single = jnp.zeros((B, NC)).at[
        jnp.arange(B)[:, None], ce_first].add(
        jnp.where(~design.seg_pipe & seg_valid, busy_single_seg, 0.0))
    add_pipe = jnp.zeros((B, NC)).at[
        jnp.arange(B)[:, None], ce_first].add(busy_pipe_seg)
    ce_busy = add_single + add_pipe

    # ---- interfaces: mandatory IO + Eq. 9 ---------------------------------
    access = (acc_single * single_l + w_acc_pipe * pipe_l).sum(-1)
    w_access = (wacc_single * single_l + w_acc_pipe * pipe_l).sum(-1)
    fm_access = (facc_single * single_l).sum(-1)
    mandatory = (t.IFM[0] + t.OFM[-1]) * wb
    access = access + mandatory
    fm_access = fm_access + mandatory

    bound_sz = jnp.where(bound_valid, OFM[last_of_seg] * wb, 0.0)
    spill = bound_valid & ~inter_onchip
    access = access + (2 * jnp.where(spill, bound_sz, 0.0)).sum(-1)
    fm_access = fm_access + (2 * jnp.where(spill, bound_sz, 0.0)).sum(-1)
    bps = dev.off_chip_gbps * 1e9
    comm_cyc = ((jnp.where(spill, 2 * bound_sz, bound_sz) / bps)
                * dev.clock_hz * bound_valid).sum(-1)

    latency_cyc = seg_lat_single.sum(-1) + lat_pipe_total + comm_cyc
    latency_s = latency_cyc / dev.clock_hz

    multi = (n_seg > 1) & design.inter_pipe
    bottleneck = jnp.where(multi, ce_busy.max(-1),
                           jnp.where(n_seg > 1, latency_cyc,
                                     jnp.maximum(ce_busy.max(-1), 1.0)))
    throughput = dev.clock_hz / jnp.maximum(bottleneck, 1.0)

    buffer_alloc = alloc.sum(-1) + (
        2 * jnp.where(inter_onchip, bound_sz, 0.0)).sum(-1)
    # Eq. 8 requirement (what the paper's buffer metric reports)
    buffer_req = desires.sum(-1) + jnp.where(
        design.inter_pipe, (2 * bound_sz).sum(-1), 0.0)

    util_avg = (util * macs[None]).sum(-1) / macs.sum()

    return {
        "latency_s": latency_s,
        "throughput_ips": throughput,
        "buffer_bytes": buffer_req,
        "buffer_alloc_bytes": buffer_alloc,
        "access_bytes": access,
        "weight_access_bytes": w_access,
        "fm_access_bytes": fm_access,
        "utilization": util_avg,
        "n_ces": ce_valid.sum(-1),
    }


def evaluate_specs(specs: list[AcceleratorSpec], net: Network,
                   dev: DeviceSpec, chunk: int = 2048) -> dict[str, np.ndarray]:
    """Convenience wrapper: specs -> stacked metric arrays (chunked)."""
    tables = make_tables(net)
    outs: list[dict] = []
    for i in range(0, len(specs), chunk):
        batch = encode_specs(specs[i:i + chunk], len(net))
        outs.append({k: np.asarray(v)
                     for k, v in evaluate_batch(batch, tables, dev).items()})
    return {k: np.concatenate([o[k] for o in outs]) for k in outs[0]}
