"""Multi-CNN co-scheduling: joint cost model + partition-aware DSE for
multi-tenant FPGA deployments.

Three layers over the single-model MCCM stack:

* :mod:`~repro.core.multinet.partition`  — spatial DSP/BRAM/bandwidth
  splits (traced validity/repair) and temporal round-robin time shares;
* :mod:`~repro.core.multinet.joint_eval` — the (M, ...) NetTables
  megabatch and the one-compile joint evaluator producing system metrics
  (aggregate throughput, worst-model latency, fairness, SLO attainment,
  off-chip traffic);
* :mod:`~repro.core.multinet.search` / ``driver`` — joint DSE over
  (per-model budget split × per-model CE arrangement), Pareto over system
  metrics, with equal-split and time-multiplexed baseline arms.
"""
from .driver import JointDSEResult, joint_explore
from .joint_eval import (
    JOINT_TILE,
    MultiNetTables,
    joint_evaluate,
    make_multi_tables,
)
from .partition import (
    BUF_GRANULE,
    DEFAULT_FLOORS,
    DEFAULT_MAX_M,
    PartitionBatch,
    equal_shares,
    partition_devices,
    repair_partition_jax,
    repair_time_shares_jax,
    sample_shares,
    validate_partition,
)
from .search import MultinetSearchConfig, MultinetSearchResult, joint_search

__all__ = [
    "BUF_GRANULE",
    "DEFAULT_FLOORS",
    "DEFAULT_MAX_M",
    "JOINT_TILE",
    "JointDSEResult",
    "MultiNetTables",
    "MultinetSearchConfig",
    "MultinetSearchResult",
    "PartitionBatch",
    "equal_shares",
    "joint_evaluate",
    "joint_explore",
    "joint_search",
    "make_multi_tables",
    "partition_devices",
    "repair_partition_jax",
    "repair_time_shares_jax",
    "sample_shares",
    "validate_partition",
]
