"""Multi-CNN co-scheduling: joint cost model + deployment-aware DSE for
multi-tenant FPGA boards.

Three layers over the single-model MCCM stack:

* :mod:`~repro.core.multinet.partition`  — spatial DSP/BRAM/bandwidth
  splits (traced validity/repair), temporal round-robin time shares, and
  the hybrid slice structure (dedicated spatial slices + one
  time-multiplexed shared slice, per-row);
* :mod:`~repro.core.multinet.joint_eval` — the (M, ...) NetTables
  megabatch and the one-compile joint evaluator for all three
  co-execution modes, producing system metrics (aggregate throughput,
  worst-model latency, fairness, SLO attainment — binary and graded under
  per-model deadline distributions — off-chip traffic);
* :mod:`~repro.core.multinet.search` / ``driver`` — joint DSE over
  (per-model budget split × per-model CE arrangement × spatial/shared
  assignment), Pareto over system metrics, with equal-split,
  time-multiplexed and hybrid arms plus the SLO-driven objective.
"""
from .driver import JointDSEResult, joint_explore
from .joint_eval import (
    DEADLINE_SCALES,
    JOINT_TILE,
    MultiNetTables,
    joint_evaluate,
    make_multi_tables,
    slo_attainment_dist,
)
from .partition import (
    BUF_GRANULE,
    DEFAULT_FLOORS,
    DEFAULT_MAX_M,
    PartitionBatch,
    equal_shares,
    gather_slices,
    partition_devices,
    repair_partition_jax,
    repair_time_shares_jax,
    sample_shares,
    slice_masks,
    slice_shares,
    validate_partition,
)
from .search import (
    JOINT_OBJECTIVES,
    SLO_OBJECTIVES,
    MultinetSearchConfig,
    MultinetSearchResult,
    joint_search,
)

__all__ = [
    "BUF_GRANULE",
    "DEADLINE_SCALES",
    "DEFAULT_FLOORS",
    "DEFAULT_MAX_M",
    "JOINT_OBJECTIVES",
    "JOINT_TILE",
    "JointDSEResult",
    "MultiNetTables",
    "MultinetSearchConfig",
    "MultinetSearchResult",
    "PartitionBatch",
    "SLO_OBJECTIVES",
    "equal_shares",
    "gather_slices",
    "joint_evaluate",
    "joint_explore",
    "joint_search",
    "make_multi_tables",
    "partition_devices",
    "repair_partition_jax",
    "repair_time_shares_jax",
    "sample_shares",
    "slice_masks",
    "slice_shares",
    "slo_attainment_dist",
    "validate_partition",
]
