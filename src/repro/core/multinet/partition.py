"""Resource partitioning for multi-CNN co-scheduling — the co-execution
modes of a shared FPGA (Shen et al.'s resource-partitioning design space,
arXiv:1607.00064, made analytic):

* **spatial** — the board's DSPs / BRAM / off-chip bandwidth are split into
  M disjoint slices, one per-model multiple-CE accelerator each.  Splits
  are integer (DSPs; BRAM in 1-KiB granules) and live in the *traced* path:
  ``repair_partition_jax`` turns arbitrary positive shares into a valid
  split inside the jitted joint evaluator, so the joint DSE mutates raw
  shares freely and one compile serves every split.
* **temporal** — one full-board accelerator per model, time-multiplexed by
  weighted round-robin; ``repair_time_shares_jax`` normalizes the slice
  weights the same way.
* **hybrid** — the general deployment: each model either owns a dedicated
  spatial slice or is a member of the row's single time-multiplexed
  *shared slice* (partial reconfiguration within one region).  The
  per-row (B, M) assignment is folded into slice-level masks and shares by
  ``slice_masks`` / ``slice_shares``; the shared slice is represented by
  its first member column (the *leader*), the spatial split repair runs
  over slice columns, and ``gather_slices`` maps every model back to its
  slice's resources.  An all-spatial assignment reduces bit-identically to
  the spatial mode, an all-shared assignment to the temporal mode (the
  single remaining slice takes the board verbatim).

Host-side twins (`sample_shares`, `equal_shares`, `validate_partition`,
`dse.encoding.sample_assign`) feed the search and the property tests.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp

from ..batch_eval import DeviceTables

#: model-axis padding: deployments of 1..MAX_M models share one compiled
#: joint program (the model axis is padded, never a static shape change).
DEFAULT_MAX_M = 4

#: BRAM split granularity (bytes).  Multi-model splits allocate whole
#: granules — physical BRAM comes in blocks, and granule totals stay exact
#: in f32 where raw byte counts (> 2^24) would not.
BUF_GRANULE = 1024

#: default per-model resource floors, as fractions of the board budget —
#: repair never starves a co-resident model below its floor.
DEFAULT_FLOORS = (0.05, 0.05, 0.05)   # (pes, buf, bw)


@jax.tree_util.register_dataclass
@dataclass
class PartitionBatch:
    """(B, M) per-deployment resource split: integer DSPs, integer BRAM
    bytes (1-KiB granules), and off-chip bandwidth fractions.  Invalid
    (padded) model columns carry zeros."""

    pes: jnp.ndarray   # f32 (B, M) integer-valued DSP split
    buf: jnp.ndarray   # f32 (B, M) integer-valued BRAM bytes
    bw: jnp.ndarray    # f32 (B, M) bandwidth fractions, sum 1 over valid

    @property
    def batch(self) -> int:
        """Number of deployment rows."""
        return self.pes.shape[0]

    @property
    def n_models(self) -> int:
        """Padded model-axis length of the split arrays."""
        return self.pes.shape[1]

    def take(self, idx) -> "PartitionBatch":
        """Row subset (numpy/jnp fancy index)."""
        return PartitionBatch(self.pes[idx], self.buf[idx], self.bw[idx])

    def to_numpy(self):
        """(pes, buf, bw) as host arrays."""
        return (np.asarray(self.pes), np.asarray(self.buf),
                np.asarray(self.bw))


def _proportional_split(shares, total, valid, floor_frac):
    """Traced largest-remainder split of an integer ``total`` (traced
    scalar) proportional to ``shares`` (B, M), each valid model floored at
    ``floor_frac * total`` (static float).

    Sums exactly to ``total`` on every row; invalid columns get 0.  Rows
    with a single valid model get the whole budget verbatim (bit-exact
    M=1 reduction to the single-model evaluator).
    """
    valid_f = valid.astype(jnp.float32)
    nv = jnp.maximum(valid_f.sum(-1, keepdims=True), 1.0)      # (B, 1)
    fl = jnp.floor(jnp.minimum(floor_frac * total,
                               jnp.floor(total / nv)))          # (B, 1)
    rem_total = total - fl * nv                                 # (B, 1)
    s = jnp.where(shares > 0, shares, 0.0) * valid_f
    ssum = s.sum(-1, keepdims=True)
    s = jnp.where(ssum > 0, s / jnp.maximum(ssum, 1e-30), valid_f / nv)
    raw = s * rem_total
    base = jnp.floor(raw)
    short = rem_total[..., 0] - (base * valid_f).sum(-1)        # (B,)
    frac = jnp.where(valid, raw - base, -1.0)
    order = jnp.argsort(-frac, axis=-1, stable=True)
    rank = jnp.argsort(order, axis=-1, stable=True)
    bonus = (rank < short[:, None]) & valid
    out = (fl + base + bonus) * valid_f
    # single-model rows take the budget verbatim (no floor/granule detour)
    single = (valid_f.sum(-1, keepdims=True) == 1.0) & valid
    return jnp.where(single, jnp.broadcast_to(total, out.shape), out)


def _as_mask(model_valid, shape):
    """(M,) model validity or an explicit (B, M) per-row mask -> (B, M)
    bool.  The 1-D form broadcasts one validity row over the batch (the
    spatial/temporal modes); the 2-D form carries per-row slice structure
    (the hybrid mode)."""
    mv = jnp.asarray(model_valid)
    if mv.ndim == 2:
        return mv if mv.dtype == jnp.bool_ else mv > 0
    return jnp.broadcast_to((mv > 0)[None, :], shape)


def repair_partition_jax(pes_shares, buf_shares, bw_shares,
                         dev: DeviceTables, model_valid,
                         floors=DEFAULT_FLOORS) -> PartitionBatch:
    """Traced spatial-split repair: arbitrary positive (B, M) shares ->
    a valid :class:`PartitionBatch` for board ``dev``.

    Guarantees, per row (over valid columns):
    * ``pes`` are integers summing exactly to ``dev.pes``;
    * ``buf`` are 1-KiB multiples summing exactly to the board's BRAM
      rounded down to the granule (single-column rows take the full budget);
    * ``bw`` fractions sum to 1;
    * every valid column receives at least its ``floors`` fraction (clamped
      to an equal split when M * floor > 1).

    ``model_valid`` is the (M,) model mask or, for hybrid deployments, a
    per-row (B, M) *slice* mask (see :func:`slice_masks`).  ``floors`` is a
    static (pes, buf, bw) fraction triple.
    """
    valid = _as_mask(model_valid, pes_shares.shape)
    valid_f = valid.astype(jnp.float32)
    pes = _proportional_split(pes_shares, dev.pes, valid, floors[0])
    buf_g = _proportional_split(buf_shares, jnp.floor(dev.on_chip_bytes
                                                      / BUF_GRANULE),
                                valid, floors[1])
    single = (valid_f.sum(-1, keepdims=True) == 1.0) & valid
    buf = jnp.where(single, jnp.broadcast_to(dev.on_chip_bytes, buf_g.shape),
                    buf_g * BUF_GRANULE)
    bw = repair_time_shares_jax(bw_shares, model_valid, floor=floors[2])
    return PartitionBatch(pes, buf, bw)


def repair_time_shares_jax(raw, model_valid, floor: float = 0.05):
    """Traced share normalization: positive (B, M) raw weights -> fractions
    summing to 1 over valid columns, each at least ``floor`` (clamped to an
    equal split when M * floor > 1).  Used for bandwidth fractions
    (spatial), round-robin time slices (temporal), and — with a per-row
    (B, M) membership mask — the within-shared-slice time shares of hybrid
    deployments.  Rows with an all-False mask return zeros."""
    valid = _as_mask(model_valid, raw.shape)
    valid_f = valid.astype(jnp.float32)
    nv = jnp.maximum(valid_f.sum(-1, keepdims=True), 1.0)
    fl = jnp.minimum(floor, 1.0 / nv)
    s = jnp.where(raw > 0, raw, 0.0) * valid_f
    ssum = s.sum(-1, keepdims=True)
    s = jnp.where(ssum > 0, s / jnp.maximum(ssum, 1e-30), valid_f / nv)
    return (fl + (1.0 - nv * fl) * s) * valid_f


def partition_devices(dev: DeviceTables, part: PartitionBatch,
                      model_valid) -> DeviceTables:
    """Per-(row, model) DeviceTables for the spatial mode: every leaf is
    (B, M).  Invalid (padded) model columns get the FULL board — their
    metrics are numerically safe and masked out of every system metric."""
    valid = jnp.broadcast_to((model_valid > 0)[None, :], part.pes.shape)
    full = lambda x: jnp.broadcast_to(x, part.pes.shape)
    return DeviceTables(
        pes=jnp.where(valid, part.pes, full(dev.pes)),
        on_chip_bytes=jnp.where(valid, part.buf, full(dev.on_chip_bytes)),
        bpc=jnp.where(valid, part.bw * dev.bpc, full(dev.bpc)),
        bps=jnp.where(valid, part.bw * dev.bps, full(dev.bps)),
        clock_hz=full(dev.clock_hz),
        wordbytes=full(dev.wordbytes))


# --------------------------------------------------------------------------
# hybrid deployments: per-row spatial-slice / shared-slice structure
# --------------------------------------------------------------------------
def slice_masks(assign, model_valid):
    """Traced slice structure of a hybrid deployment batch.

    ``assign`` is the (B, M) deployment assignment (see
    ``dse.encoding.sample_assign``): values > 0.5 mark membership in the
    row's single time-multiplexed *shared slice*; every other valid model
    owns a dedicated spatial slice.  Returns ``(shared, slice_valid,
    slice_col)``:

    * ``shared``      (B, M) bool — model is a shared-slice member;
    * ``slice_valid`` (B, M) bool — column represents a slice in the
      spatial split: every dedicated model plus the shared slice's
      *leader* (its first member column);
    * ``slice_col``   (B, M) i32  — the column model m draws its slice
      resources from (itself when dedicated, the leader when shared).

    An all-spatial row has ``slice_valid == model_valid`` and an identity
    ``slice_col`` (the spatial mode, bit for bit); an all-shared row has a
    single valid slice, which the split repair hands the board verbatim
    (the temporal mode, bit for bit).
    """
    valid = _as_mask(model_valid, assign.shape)
    shared = (assign > 0.5) & valid
    is_leader = shared & (jnp.cumsum(shared.astype(jnp.int32), axis=-1) == 1)
    slice_valid = (valid & ~shared) | is_leader
    leader_col = jnp.argmax(is_leader, axis=-1)           # (B,)
    cols = jnp.arange(assign.shape[1], dtype=jnp.int32)[None, :]
    slice_col = jnp.where(shared, leader_col[:, None].astype(jnp.int32),
                          cols)
    return shared, slice_valid, slice_col


def slice_shares(raw, shared, slice_valid):
    """Fold model-level raw resource shares into slice-level shares: the
    shared slice (its leader column) claims the sum of its members'
    positive shares, dedicated columns keep their own, non-leader shared
    columns zero.  With no shared members this returns ``raw`` unchanged —
    the all-spatial reduction stays bit-identical."""
    pos = jnp.where(raw > 0, raw, 0.0) * shared.astype(raw.dtype)
    pooled = pos.sum(-1, keepdims=True)
    return jnp.where(shared,
                     jnp.where(slice_valid, pooled, jnp.zeros_like(raw)),
                     raw)


def gather_slices(part: PartitionBatch, slice_col) -> PartitionBatch:
    """Map a slice-level :class:`PartitionBatch` back to per-model view:
    model m's columns become its slice's resources (shared members all see
    the full shared slice — they time-multiplex within it)."""
    g = lambda a: jnp.take_along_axis(a, slice_col, axis=1)
    return PartitionBatch(g(part.pes), g(part.buf), g(part.bw))


# --------------------------------------------------------------------------
# host-side helpers (search init, baselines, tests)
# --------------------------------------------------------------------------
def sample_shares(rng: np.random.Generator, n: int, max_m: int,
                  n_models: int | None = None) -> np.ndarray:
    """(n, max_m) random positive shares (Dirichlet over the real models,
    zeros on padded columns) — the raw genome the traced repair consumes."""
    m = max_m if n_models is None else n_models
    out = np.zeros((n, max_m), np.float32)
    out[:, :m] = rng.dirichlet(np.ones(m), size=n).astype(np.float32)
    return out


def equal_shares(n: int, max_m: int, n_models: int | None = None) -> np.ndarray:
    """(n, max_m) equal shares over the real models — the equal-split
    baseline's frozen genome."""
    m = max_m if n_models is None else n_models
    out = np.zeros((n, max_m), np.float32)
    out[:, :m] = 1.0 / m
    return out


def validate_partition(part: PartitionBatch, dev, model_valid,
                       floors=DEFAULT_FLOORS) -> np.ndarray:
    """Host-side check of the repair guarantees -> bool mask (B,).

    ``dev`` is a DeviceSpec (exact host integers).  Budgets are compared
    against the f32 board values the traced path sees.
    """
    pes, buf, bw = part.to_numpy()
    valid = np.asarray(model_valid) > 0
    nv = int(valid.sum())
    pes_total = float(np.float32(dev.pes))
    buf_total = float(np.float32(dev.on_chip_bytes))
    ok = np.abs((pes * valid[None, :]).sum(-1) - pes_total) < 0.5
    if nv == 1:
        ok &= np.abs((buf * valid[None, :]).sum(-1) - buf_total) < 0.5
    else:
        gran_total = np.floor(buf_total / BUF_GRANULE) * BUF_GRANULE
        ok &= np.abs((buf * valid[None, :]).sum(-1) - gran_total) < 0.5
    ok &= np.abs((bw * valid[None, :]).sum(-1) - 1.0) < 1e-5
    fl_pes = np.floor(min(floors[0], 1.0 / nv) * pes_total)
    fl_buf = np.floor(min(floors[1], 1.0 / nv)
                      * np.floor(buf_total / BUF_GRANULE)) * BUF_GRANULE
    fl_bw = min(floors[2], 1.0 / nv)
    ok &= (pes[:, valid] >= fl_pes - 0.5).all(-1)
    ok &= (buf[:, valid] >= fl_buf - 0.5).all(-1)
    ok &= (bw[:, valid] >= fl_bw - 1e-6).all(-1)
    ok &= (pes[:, ~valid] == 0).all(-1)    # padded columns stay zeroed
    ok &= (buf[:, ~valid] == 0).all(-1)
    ok &= (bw[:, ~valid] == 0).all(-1)
    return ok
