"""Joint cost model for M CNNs sharing one board — vectorized, one compile.

A *deployment* row pairs M per-model multiple-CE designs with a resource
split (spatial mode), round-robin time shares (temporal mode), or a
spatial/shared assignment plus both (hybrid mode).  The existing padded
``NetTables`` pytrees are stacked into an (M, ...) megabatch
(``MultiNetTables``) and the single-model hot path
(``batch_eval.eval_design_block``) is reused under ``vmap`` — once over
the model axis with per-(row, model) partitioned devices, once over the
rows of each ``lax.map`` design tile.  Because the model axis is padded to
``DEFAULT_MAX_M``, the layer axis to a shared ``bucket_max_L`` bucket, and
the batch to a tile multiple, ONE jit compile serves any model set × board
× split — the single-model cache-miss-counter guarantee, extended.

The three co-execution modes of :func:`joint_evaluate`:

* ``"spatial"``  — M disjoint board slices, one accelerator each;
* ``"temporal"`` — one full-board accelerator per model, weighted
  round-robin with per-round weight-reload (+ ``reconfig_s``) charges;
* ``"hybrid"``   — a per-row (B, M) *assignment* gives each model either a
  dedicated spatial slice or membership in the row's single
  time-multiplexed shared slice (weighted RR within the slice, weight
  reload charged against the slice's bandwidth).  An all-spatial
  assignment is bit-identical to ``"spatial"``, an all-shared assignment
  to ``"temporal"``, and assignments are traced data — they never fork
  compiles.

System-level outputs per deployment row:

* ``agg_throughput_ips``   — summed model throughputs;
* ``worst_latency_s``      — max per-model latency (temporal/hybrid:
                             including the round-robin wait);
* ``fairness``             — Jain's index over request-weight-normalized
                             throughputs;
* ``slo_attainment``       — fraction of models meeting their latency SLO;
* ``traffic_bytes_per_s``  — aggregate off-chip traffic at steady state;

plus the per-model metric planes (``per_model_*``, each (B, M)) and the
repaired deployment actually evaluated (``pes_split``/``buf_split``/
``bw_split``, ``time_share``/``round_period_s``, and for hybrid the
canonical ``assign`` plane).  :func:`slo_attainment_dist` refines the
binary per-model SLO check into attainment under a per-model deadline
*distribution* (the ``slo_s`` grid scaled by ``DEADLINE_SCALES``) — the
objective the SLO-driven joint DSE climbs.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from ...kernels.mccm_eval import pair_tables, resolve_backend
from ..batch_eval import (DeviceTables, DeviceSpec, NetTables,
                          _pair_layer_tables, eval_design_block,
                          evaluate_batch_traced, make_device_tables,
                          make_tables, pes_hint, shared_max_L)
from ..dse.encoding import (DesignBatch, MultiDesignBatch, pad_deployments,
                            pad_plane)
from ..workload import Network
from .partition import (DEFAULT_FLOORS, DEFAULT_MAX_M, PartitionBatch,
                        gather_slices, partition_devices,
                        repair_partition_jax, repair_time_shares_jax,
                        slice_masks, slice_shares)

NEG = -1.0e30

#: deployment-tile width of the joint lax.map loop.  Each row carries
#: MAX_M model lanes, so the tile is narrower than the single-model one.
JOINT_TILE = 32

#: per-model latency metrics the joint path reports as (B, M) planes
PER_MODEL_KEYS = ("latency_s", "throughput_ips", "buffer_bytes",
                  "access_bytes", "utilization", "n_ces")


# --------------------------------------------------------------------------
# stacked per-model tables
# --------------------------------------------------------------------------
@jax.tree_util.register_dataclass
@dataclass
class MultiNetTables:
    """M CNNs as one traced pytree: ``tables`` is a NetTables whose leaves
    carry a leading model axis (padded to ``max_m`` by repeating the last
    net), ``model_valid`` masks the real models.  Weights are normalized
    request rates; ``slo_s`` per-model latency SLOs (inf = none)."""

    tables: NetTables          # leaves (max_m, ...)
    model_valid: jnp.ndarray   # (max_m,) f32
    weights: jnp.ndarray       # (max_m,) f32, sum 1 over valid
    slo_s: jnp.ndarray         # (max_m,) f32

    @property
    def max_m(self) -> int:
        """Padded model-axis length (the compile-shape constant)."""
        return self.model_valid.shape[0]

    @property
    def n_models(self) -> int:
        """Number of real (unpadded) models (host-side use only)."""
        return int(np.asarray(self.model_valid).sum())

    @property
    def normalized_weights(self) -> np.ndarray:
        """The normalized per-model request weights actually used by the
        system metrics, as a host (n_models,) array — what benchmarks
        should report alongside fairness/SLO numbers."""
        return np.asarray(self.weights)[:self.n_models]

    def n_layers(self, m: int) -> int:
        """Concrete layer count of model m (host-side use only)."""
        return int(self.tables.L[m])


def _per_model_vector(x, m: int, name: str) -> np.ndarray:
    """Validate + broadcast a per-model parameter: a scalar broadcasts to
    all ``m`` models, a length-m sequence passes through; anything else is
    a shape error named after the parameter."""
    a = np.asarray(x, np.float64)
    if a.ndim == 0:
        a = np.full(m, float(a), np.float64)
    if a.shape != (m,):
        raise ValueError(f"{name} must be a scalar or have one entry per "
                         f"model (got shape {a.shape} for {m} models)")
    return a


def make_multi_tables(nets: list[Network], *, weights=None, slo_s=None,
                      max_m: int = DEFAULT_MAX_M,
                      max_L: int | None = None) -> MultiNetTables:
    """Stack per-model NetTables into the (max_m, ...) megabatch.

    All models share one ``bucket_max_L`` layer bucket (adaptive — a
    200-layer net bumps every model in the deployment to the next bucket
    rather than silently truncating or forking compiles).  The model axis
    pads by repeating the LAST net, matching ``dse.stack_designs``.

    ``weights`` (per-model request rates) and ``slo_s`` (per-model latency
    SLOs in seconds; ``inf`` = none) broadcast consistently: a scalar
    applies to every model, a length-``len(nets)`` sequence is taken
    verbatim.  Weights must be finite, non-negative and not all zero
    (each condition gets its own error); they are normalized to sum to 1
    and the normalized values are exposed as
    :attr:`MultiNetTables.normalized_weights`.  SLOs must be positive
    (``inf`` allowed, NaN rejected).
    """
    if not nets:
        raise ValueError("make_multi_tables needs at least one network")
    if len(nets) > max_m:
        raise ValueError(f"{len(nets)} models exceed max_m={max_m}; raise "
                         f"max_m (costs one extra compile per new value)")
    if max_L is None:
        max_L = shared_max_L(len(n) for n in nets)
    per = [make_tables(net, max_L=max_L) for net in nets]
    per = per + [per[-1]] * (max_m - len(per))
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per)

    m = len(nets)
    valid = np.zeros(max_m, np.float32)
    valid[:m] = 1.0
    w = np.ones(m, np.float64) if weights is None \
        else _per_model_vector(weights, m, "weights")
    if not np.isfinite(w).all():
        raise ValueError(f"weights must be finite, got {w.tolist()}")
    if (w < 0).any():
        raise ValueError(f"weights must be non-negative, got {w.tolist()}")
    if w.sum() <= 0:
        raise ValueError("weights must not be all zero — at least one "
                         "model needs a positive request rate")
    wfull = np.zeros(max_m, np.float32)
    wfull[:m] = (w / w.sum()).astype(np.float32)
    sfull = np.full(max_m, np.inf, np.float32)
    if slo_s is not None:
        s = _per_model_vector(slo_s, m, "slo_s")
        if np.isnan(s).any() or (s <= 0).any():
            raise ValueError(f"slo_s entries must be positive seconds "
                             f"(inf = no SLO), got {s.tolist()}")
        sfull[:m] = s
    return MultiNetTables(tables=stacked, model_valid=jnp.asarray(valid),
                          weights=jnp.asarray(wfull),
                          slo_s=jnp.asarray(sfull))


# --------------------------------------------------------------------------
# system metrics from per-model planes
# --------------------------------------------------------------------------
def _system_metrics(per: dict[str, jnp.ndarray], mt: MultiNetTables
                    ) -> dict[str, jnp.ndarray]:
    """Per-model (B, M) metric planes -> (B,) system metrics."""
    valid = mt.model_valid[None, :]                       # (1, M)
    vmask = valid > 0
    nv = jnp.maximum(mt.model_valid.sum(), 1.0)
    tp = per["throughput_ips"]
    lat = per["latency_s"]
    acc = per["access_bytes"]

    agg_tp = (tp * valid).sum(-1)
    worst_lat = jnp.max(jnp.where(vmask, lat, NEG), axis=-1)
    # request-weight-normalized service rates: Jain's index as the reported
    # fairness, the max-min rate as the (non-gameable) search objective.
    # Zero-weight (deployed but trafficless) models are excluded — they
    # would otherwise overflow the normalized rate.
    wpos = vmask & (mt.weights[None, :] > 0)
    nw = jnp.maximum(wpos.sum(-1).astype(jnp.float32), 1.0)
    x = jnp.where(wpos, tp / jnp.maximum(mt.weights[None, :], 1e-30), 0.0)
    fairness = jnp.square(x.sum(-1)) / jnp.maximum(
        nw * jnp.square(x).sum(-1), 1e-30)
    # normalized so equal weights reduce to the plain min model throughput
    min_tp = jnp.min(jnp.where(wpos, x, jnp.inf), axis=-1) / nw
    slo_ok = jnp.where(vmask, (lat <= mt.slo_s[None, :]).astype(jnp.float32),
                       0.0)
    slo_att = slo_ok.sum(-1) / nv
    traffic = (tp * acc * valid).sum(-1)
    return {
        "agg_throughput_ips": agg_tp,
        "worst_latency_s": worst_lat,
        "min_model_throughput_ips": min_tp,
        "fairness": fairness,
        "slo_attainment": slo_att,
        "traffic_bytes_per_s": traffic,
    }


def _package(per, mt):
    out = _system_metrics(per, mt)
    for k in PER_MODEL_KEYS:
        out[f"per_model_{k}"] = per[k]
    return out


# --------------------------------------------------------------------------
# shared core: evaluate deployments on per-(row, model) devices
# --------------------------------------------------------------------------
def _eval_on_devices(md: MultiDesignBatch, mt: MultiNetTables,
                     devs: DeviceTables, *, backend: str, tile: int,
                     fm_tile_rows: int, pes_hint_static: int | None,
                     design_tile: int) -> dict[str, jnp.ndarray]:
    """The lax.map(vmap(row) ∘ vmap(model)) evaluation core shared by the
    spatial and hybrid modes: every (row, model) design runs on its own
    ``devs`` slice (leaves (B, M)); returns the per-model metric planes,
    each (B, M).  ``pes_hint_static`` uses the FULL board's bucket —
    partition slices never exceed it, so pair pruning stays sound for
    every split."""
    B, max_m = md.batch, md.n_models

    pairs = pair_tables(mt.tables.candidates, pes_hint_static)
    fc_pair, coh_pair = jax.vmap(
        lambda t: _pair_layer_tables(t, pairs))(mt.tables)  # (M, L, P)

    def one_row(se, sp, sn, ip, dv):
        # one deployment: design leaves (M, NS), device leaves (M,)
        def one_model(se_m, sp_m, sn_m, ip_m, t_m, dv_m, fc_m, coh_m):
            d = DesignBatch(se_m[None], sp_m[None], sn_m[None], ip_m[None])
            out = eval_design_block(d, t_m, dv_m, pairs, fc_m, coh_m,
                                    backend=backend, design_tile=design_tile,
                                    fm_tile_rows=fm_tile_rows)
            return {k: v[0] for k, v in out.items()}
        return jax.vmap(one_model)(se, sp, sn, ip, mt.tables, dv,
                                   fc_pair, coh_pair)

    nt = -(-B // tile)
    pmd = pad_deployments(md, nt * tile)
    pad_dev = jax.tree_util.tree_map(
        lambda a: jnp.concatenate(
            [a, jnp.repeat(a[-1:], nt * tile - B, 0)], 0)
        if a.shape[0] < nt * tile else a, devs)

    def one_tile(args):
        se, sp, sn, ip, dv_leaves = args
        dv = DeviceTables(*dv_leaves)
        return jax.vmap(one_row, in_axes=(0, 0, 0, 0, 0))(se, sp, sn, ip, dv)

    shp = lambda a: a.reshape((nt, tile) + a.shape[1:])
    out = jax.lax.map(one_tile, (
        shp(pmd.seg_end), shp(pmd.seg_pipe), shp(pmd.seg_nce),
        shp(pmd.inter_pipe),
        tuple(shp(l) for l in (pad_dev.pes, pad_dev.on_chip_bytes,
                               pad_dev.bpc, pad_dev.bps, pad_dev.clock_hz,
                               pad_dev.wordbytes))))
    return {k: v.reshape(nt * tile, max_m)[:B] for k, v in out.items()}


# --------------------------------------------------------------------------
# spatial mode: per-(row, model) partitioned devices
# --------------------------------------------------------------------------
def joint_spatial_traced(md: MultiDesignBatch, mt: MultiNetTables,
                         dev: DeviceTables, pes_shares, buf_shares,
                         bw_shares, *, backend: str = "ref",
                         tile: int = JOINT_TILE, fm_tile_rows: int = 2,
                         pes_hint_static: int | None = None,
                         design_tile: int = 16,
                         floors=DEFAULT_FLOORS) -> dict[str, jnp.ndarray]:
    """The traced spatial joint path (call under jit).

    Raw shares are repaired in-trace (every deployment row becomes a valid
    split), the board is sliced into per-(row, model) DeviceTables, and
    ``eval_design_block`` runs under vmap(model) ∘ vmap(row) inside
    ``lax.map`` deployment tiles (see :func:`_eval_on_devices`).
    """
    B = md.batch
    part = repair_partition_jax(pes_shares, buf_shares, bw_shares, dev,
                                mt.model_valid, floors=floors)
    devs = partition_devices(dev, part, mt.model_valid)   # leaves (B, M)
    per = _eval_on_devices(md, mt, devs, backend=backend, tile=tile,
                           fm_tile_rows=fm_tile_rows,
                           pes_hint_static=pes_hint_static,
                           design_tile=design_tile)
    res = _package(per, mt)
    res["pes_split"] = part.pes[:B]
    res["buf_split"] = part.buf[:B]
    res["bw_split"] = part.bw[:B]
    return res


# --------------------------------------------------------------------------
# temporal mode: full board, weighted round-robin
# --------------------------------------------------------------------------
def joint_temporal_traced(md: MultiDesignBatch, mt: MultiNetTables,
                          dev: DeviceTables, time_shares, *,
                          backend: str = "ref", tile: int = JOINT_TILE,
                          fm_tile_rows: int = 2,
                          pes_hint_static: int | None = None,
                          design_tile: int = 16,
                          share_floor: float = DEFAULT_FLOORS[2],
                          reconfig_s: float = 0.0
                          ) -> dict[str, jnp.ndarray]:
    """Weighted round-robin time multiplexing: every model's design runs on
    the FULL board; model m holds the fabric for a ``w_m`` fraction of each
    round.

    Context switches are not free: when a model's slice starts, its
    weights must re-stream from DDR (the board cannot hold every model's
    weights resident), charging ``sw_m = weight_bytes_m / bps`` per round
    (plus ``reconfig_s`` for boards that partially reconfigure).  The
    shortest feasible round is ``T = max_m((lat_m + sw_m) / w_m)`` (every
    slice fits its reload plus >= 1 inference); model m then sustains
    ``w_m * tp_m - sw_m * tp_m / T`` and its worst-case response time is
    ``(1 - w_m) * T + sw_m + lat_m``."""
    B = md.batch
    tsh = repair_time_shares_jax(time_shares, mt.model_valid,
                                 floor=share_floor)       # (B, M)
    per_mb = jax.vmap(
        lambda se, sp, sn, ip, t: evaluate_batch_traced(
            DesignBatch(se, sp, sn, ip), t, dev, backend=backend, tile=tile,
            fm_tile_rows=fm_tile_rows, pes_hint_static=pes_hint_static,
            design_tile=design_tile),
        in_axes=(1, 1, 1, 1, 0), out_axes=1,
    )(md.seg_end, md.seg_pipe, md.seg_nce, md.inter_pipe, mt.tables)
    per = dict(per_mb)                                    # leaves (B, M)

    vmask = mt.model_valid[None, :] > 0
    safe_w = jnp.maximum(tsh, 1e-30)
    lat_full = per["latency_s"]
    w_bytes = (mt.tables.W * mt.tables.valid).sum(-1) * dev.wordbytes  # (M,)
    sw = (w_bytes / dev.bps + reconfig_s)[None, :]        # (1, M)
    T = jnp.max(jnp.where(vmask, (lat_full + sw) / safe_w, NEG),
                axis=-1)                                  # (B,)
    per["throughput_ips"] = per["throughput_ips"] * jnp.maximum(
        tsh - sw / T[:, None], 0.0)
    per["latency_s"] = jnp.where(vmask,
                                 lat_full + sw + (1.0 - tsh) * T[:, None],
                                 lat_full)
    res = _package(per, mt)
    res["time_share"] = tsh
    res["round_period_s"] = T
    return res


# --------------------------------------------------------------------------
# hybrid mode: dedicated spatial slices + one time-multiplexed shared slice
# --------------------------------------------------------------------------
def joint_hybrid_traced(md: MultiDesignBatch, mt: MultiNetTables,
                        dev: DeviceTables, assign, pes_shares, buf_shares,
                        bw_shares, time_shares, *, backend: str = "ref",
                        tile: int = JOINT_TILE, fm_tile_rows: int = 2,
                        pes_hint_static: int | None = None,
                        design_tile: int = 16, floors=DEFAULT_FLOORS,
                        reconfig_s: float = 0.0) -> dict[str, jnp.ndarray]:
    """Hybrid spatial+temporal deployments (call under jit).

    ``assign`` (B, M) marks each model as either a dedicated spatial slice
    owner (<= 0.5) or a member of the row's single time-multiplexed shared
    slice (> 0.5).  The board is split over *slices* (dedicated models +
    the shared slice, whose share pools its members' raw shares); every
    model's design is then evaluated on its slice exactly as in the
    spatial mode, and shared members are weighted-round-robin adjusted
    within their slice: per round the incoming model's weights re-stream
    over the slice's bandwidth (``sw_m = weight_bytes_m / slice_bps +
    reconfig_s``), the shortest feasible round is ``T = max_members((lat_m
    + sw_m) / w_m)``, member m sustains ``w_m·tp_m − sw_m·tp_m/T`` and
    responds in ``lat_m + sw_m + (1 − w_m)·T`` — the temporal model's
    arithmetic, applied per-slice.

    Reductions (asserted bit-exact in ``tests/test_multinet.py``): an
    all-spatial assignment equals ``joint_spatial_traced`` on the same
    shares; an all-shared assignment equals ``joint_temporal_traced`` on
    the same time shares (the lone slice takes the board verbatim).
    The assignment is traced data: changing it never forks compiles.
    """
    B = md.batch
    shared, slice_valid, slice_col = slice_masks(assign, mt.model_valid)
    part = repair_partition_jax(
        slice_shares(pes_shares, shared, slice_valid),
        slice_shares(buf_shares, shared, slice_valid),
        slice_shares(bw_shares, shared, slice_valid),
        dev, slice_valid, floors=floors)
    mpart = gather_slices(part, slice_col)                # per-model view
    devs = partition_devices(dev, mpart, mt.model_valid)  # leaves (B, M)
    per = _eval_on_devices(md, mt, devs, backend=backend, tile=tile,
                           fm_tile_rows=fm_tile_rows,
                           pes_hint_static=pes_hint_static,
                           design_tile=design_tile)

    # weighted round-robin within the shared slice (no-op for dedicated
    # models: their lanes keep the raw metrics bit for bit)
    tsh = repair_time_shares_jax(time_shares, shared, floor=floors[2])
    safe_w = jnp.maximum(tsh, 1e-30)
    lat_full = per["latency_s"]
    w_bytes = (mt.tables.W * mt.tables.valid).sum(-1) * dev.wordbytes  # (M,)
    sw = w_bytes[None, :] / devs.bps + reconfig_s         # (B, M)
    T = jnp.max(jnp.where(shared, (lat_full + sw) / safe_w, NEG),
                axis=-1)                                  # (B,)
    tp_rr = per["throughput_ips"] * jnp.maximum(
        tsh - sw / T[:, None], 0.0)
    lat_rr = lat_full + sw + (1.0 - tsh) * T[:, None]
    per["throughput_ips"] = jnp.where(shared, tp_rr, per["throughput_ips"])
    per["latency_s"] = jnp.where(shared, lat_rr, lat_full)

    res = _package(per, mt)
    valid_f = jnp.broadcast_to((mt.model_valid > 0)[None, :],
                               shared.shape).astype(jnp.float32)
    res["pes_split"] = mpart.pes[:B]
    res["buf_split"] = mpart.buf[:B]
    res["bw_split"] = mpart.bw[:B]
    res["time_share"] = jnp.where(shared, tsh, valid_f)
    res["round_period_s"] = jnp.where(shared.any(-1), T, 0.0)
    res["assign"] = shared.astype(jnp.float32)
    return res


# --------------------------------------------------------------------------
# jitted public entry points
# --------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("backend", "tile", "fm_tile_rows",
                                   "pes_hint_static", "design_tile",
                                   "floors"))
def _joint_spatial_jit(md, mt, dev, pes_shares, buf_shares, bw_shares, *,
                       backend, tile, fm_tile_rows, pes_hint_static,
                       design_tile, floors):
    return joint_spatial_traced(
        md, mt, dev, pes_shares, buf_shares, bw_shares, backend=backend,
        tile=tile, fm_tile_rows=fm_tile_rows,
        pes_hint_static=pes_hint_static, design_tile=design_tile,
        floors=floors)


@partial(jax.jit, static_argnames=("backend", "tile", "fm_tile_rows",
                                   "pes_hint_static", "design_tile",
                                   "share_floor", "reconfig_s"))
def _joint_temporal_jit(md, mt, dev, time_shares, *, backend, tile,
                        fm_tile_rows, pes_hint_static, design_tile,
                        share_floor, reconfig_s):
    return joint_temporal_traced(
        md, mt, dev, time_shares, backend=backend, tile=tile,
        fm_tile_rows=fm_tile_rows, pes_hint_static=pes_hint_static,
        design_tile=design_tile, share_floor=share_floor,
        reconfig_s=reconfig_s)


@partial(jax.jit, static_argnames=("backend", "tile", "fm_tile_rows",
                                   "pes_hint_static", "design_tile",
                                   "floors", "reconfig_s"))
def _joint_hybrid_jit(md, mt, dev, assign, pes_shares, buf_shares,
                      bw_shares, time_shares, *, backend, tile,
                      fm_tile_rows, pes_hint_static, design_tile, floors,
                      reconfig_s):
    return joint_hybrid_traced(
        md, mt, dev, assign, pes_shares, buf_shares, bw_shares,
        time_shares, backend=backend, tile=tile, fm_tile_rows=fm_tile_rows,
        pes_hint_static=pes_hint_static, design_tile=design_tile,
        floors=floors, reconfig_s=reconfig_s)


def _joint_sharded(mesh, mode: str, md, mt, devt, planes, *, backend, tile,
                   fm_tile_rows, hint, design_tile, floors, reconfig_s):
    """Sharded joint evaluation: the deployment axis is padded to a
    multiple of ``ndevices x tile`` and sharded across the mesh, tables
    replicated, pad rows sliced back off — the multinet analogue of
    ``EvalMesh.evaluate_padded`` (same row-local-arithmetic argument, so
    it is bit-identical to the single-device jits)."""
    B = md.batch
    n = mesh.padded_rows(B, tile)
    mdp = pad_deployments(md, n)
    planes = tuple(pad_plane(jnp.asarray(p), n) for p in planes)
    if mode == "spatial":
        run = mesh.shard_jit(
            "joint_spatial", joint_spatial_traced, replicated=(1, 2),
            static_kwargs=dict(backend=backend, tile=tile,
                               fm_tile_rows=fm_tile_rows,
                               pes_hint_static=hint,
                               design_tile=design_tile, floors=floors))
    elif mode == "temporal":
        run = mesh.shard_jit(
            "joint_temporal", joint_temporal_traced, replicated=(1, 2),
            static_kwargs=dict(backend=backend, tile=tile,
                               fm_tile_rows=fm_tile_rows,
                               pes_hint_static=hint,
                               design_tile=design_tile,
                               share_floor=float(floors[2]),
                               reconfig_s=reconfig_s))
    else:
        run = mesh.shard_jit(
            "joint_hybrid", joint_hybrid_traced, replicated=(1, 2),
            static_kwargs=dict(backend=backend, tile=tile,
                               fm_tile_rows=fm_tile_rows,
                               pes_hint_static=hint,
                               design_tile=design_tile, floors=floors,
                               reconfig_s=reconfig_s))
    out = run(mdp, mt, devt, *planes)
    return {k: v[:B] for k, v in out.items()}


def joint_evaluate(md: MultiDesignBatch, mt: MultiNetTables,
                   dev: DeviceSpec | DeviceTables, *, mode: str = "spatial",
                   pes_shares=None, buf_shares=None, bw_shares=None,
                   time_shares=None, assign=None,
                   backend: str | None = None,
                   tile: int = JOINT_TILE, fm_tile_rows: int = 2,
                   design_tile: int = 16, floors=DEFAULT_FLOORS,
                   reconfig_s: float = 0.0, mesh=None
                   ) -> dict[str, jnp.ndarray]:
    """Evaluate a batch of M-model deployments — one jitted dispatch.

    ``mode="spatial"`` consumes raw (B, M) resource shares (repaired
    in-trace; defaults to an equal split), ``mode="temporal"`` raw
    round-robin time shares, and ``mode="hybrid"`` an (B, M) ``assign``
    plane (> 0.5 = shared-slice member; defaults to all-spatial) plus both
    share families.  One compiled program per mode serves every model set
    (padded to ``DEFAULT_MAX_M``), board, split and assignment; only the
    batch shape and static knobs key the jit cache.  ``mesh`` (a
    ``core.shard.EvalMesh``) shards the deployment axis; None or a
    single-device mesh keeps the single-device jits.
    """
    backend = resolve_backend(backend)
    if isinstance(dev, DeviceSpec):
        hint = pes_hint(dev.pes)
        devt = make_device_tables(dev)
    else:
        devt = dev
        hint = pes_hint(float(dev.pes))
    sharded = mesh is not None and getattr(mesh, "is_sharded", False)
    B, max_m = md.batch, md.n_models
    ones = jnp.ones((B, max_m), jnp.float32)
    if mode == "spatial":
        pes_shares = ones if pes_shares is None else jnp.asarray(pes_shares)
        buf_shares = ones if buf_shares is None else jnp.asarray(buf_shares)
        bw_shares = ones if bw_shares is None else jnp.asarray(bw_shares)
        if sharded:
            return _joint_sharded(
                mesh, mode, md, mt, devt,
                (pes_shares, buf_shares, bw_shares), backend=backend,
                tile=tile, fm_tile_rows=fm_tile_rows, hint=hint,
                design_tile=design_tile, floors=tuple(floors),
                reconfig_s=float(reconfig_s))
        return _joint_spatial_jit(
            md, mt, devt, pes_shares, buf_shares, bw_shares,
            backend=backend, tile=tile, fm_tile_rows=fm_tile_rows,
            pes_hint_static=hint, design_tile=design_tile,
            floors=tuple(floors))
    if mode == "temporal":
        time_shares = ones if time_shares is None \
            else jnp.asarray(time_shares)
        if sharded:
            return _joint_sharded(
                mesh, mode, md, mt, devt, (time_shares,), backend=backend,
                tile=tile, fm_tile_rows=fm_tile_rows, hint=hint,
                design_tile=design_tile, floors=tuple(floors),
                reconfig_s=float(reconfig_s))
        return _joint_temporal_jit(
            md, mt, devt, time_shares, backend=backend, tile=tile,
            fm_tile_rows=fm_tile_rows, pes_hint_static=hint,
            design_tile=design_tile, share_floor=float(floors[2]),
            reconfig_s=float(reconfig_s))
    if mode == "hybrid":
        assign = jnp.zeros((B, max_m), jnp.float32) if assign is None \
            else jnp.asarray(assign)
        pes_shares = ones if pes_shares is None else jnp.asarray(pes_shares)
        buf_shares = ones if buf_shares is None else jnp.asarray(buf_shares)
        bw_shares = ones if bw_shares is None else jnp.asarray(bw_shares)
        time_shares = ones if time_shares is None \
            else jnp.asarray(time_shares)
        if sharded:
            return _joint_sharded(
                mesh, mode, md, mt, devt,
                (assign, pes_shares, buf_shares, bw_shares, time_shares),
                backend=backend, tile=tile, fm_tile_rows=fm_tile_rows,
                hint=hint, design_tile=design_tile, floors=tuple(floors),
                reconfig_s=float(reconfig_s))
        return _joint_hybrid_jit(
            md, mt, devt, assign, pes_shares, buf_shares, bw_shares,
            time_shares, backend=backend, tile=tile,
            fm_tile_rows=fm_tile_rows, pes_hint_static=hint,
            design_tile=design_tile, floors=tuple(floors),
            reconfig_s=float(reconfig_s))
    raise ValueError(f"unknown mode {mode!r}; known: spatial, temporal, "
                     f"hybrid")


# --------------------------------------------------------------------------
# SLO attainment under per-model deadline distributions
# --------------------------------------------------------------------------
#: default deadline grid: each model's ``slo_s`` is the central deadline of
#: a distribution of request deadlines sampled at these scale factors
#: (f-CNNx-style per-model performance constraints, graded rather than
#: binary so the search objective has slope near the SLO boundary).
DEADLINE_SCALES = (0.6, 0.8, 1.0, 1.25, 1.6)


def slo_attainment_dist(per_model_latency_s, mt: MultiNetTables, *,
                        scales=DEADLINE_SCALES) -> np.ndarray:
    """Host-side graded SLO attainment -> (B,) in [0, 1].

    Each model's deadline is sampled from its ``slo_s`` scaled by the
    ``scales`` grid (a per-model deadline distribution rather than a
    single hard SLO); a deployment's attainment is the request-weighted
    fraction of sampled deadlines its per-model latencies meet:

    ``sum_m w_m * mean_s 1[lat_m <= scale_s * slo_m]``

    with ``w`` the normalized request weights.  Models with ``slo_s=inf``
    always attain; latencies come from any ``joint_evaluate`` output's
    ``per_model_latency_s`` plane, so the metric composes with every
    co-execution mode without touching the traced path (no recompiles).
    """
    lat = np.asarray(per_model_latency_s, np.float64)     # (B, M)
    M = lat.shape[1]                    # full (B, max_m) planes or any
    if M < mt.n_models:                 # prefix covering the real models
        raise ValueError(f"latency plane covers {M} models; tables have "
                         f"{mt.n_models}")
    slo = np.asarray(mt.slo_s, np.float64)[:M]            # (M,)
    w = (np.asarray(mt.weights, np.float64)
         * np.asarray(mt.model_valid, np.float64))[:M]
    wsum = w.sum()
    w = w / wsum if wsum > 0 else w
    sc = np.asarray(scales, np.float64)
    deadlines = slo[None, :, None] * sc[None, None, :]    # (1, M, S)
    met = lat[:, :, None] <= deadlines                    # (B, M, S)
    return (met.mean(-1) * w[None, :]).sum(-1)
