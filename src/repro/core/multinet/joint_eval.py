"""Joint cost model for M CNNs sharing one board — vectorized, one compile.

A *deployment* row pairs M per-model multiple-CE designs with a resource
split (spatial mode) or round-robin time shares (temporal mode).  The
existing padded ``NetTables`` pytrees are stacked into an (M, ...)
megabatch (``MultiNetTables``) and the single-model hot path
(``batch_eval.eval_design_block``) is reused under ``vmap`` — once over
the model axis with per-(row, model) partitioned devices, once over the
rows of each ``lax.map`` design tile.  Because the model axis is padded to
``DEFAULT_MAX_M``, the layer axis to a shared ``bucket_max_L`` bucket, and
the batch to a tile multiple, ONE jit compile serves any model set × board
× split — the single-model cache-miss-counter guarantee, extended.

System-level outputs per deployment row:

* ``agg_throughput_ips``   — summed model throughputs;
* ``worst_latency_s``      — max per-model latency (temporal: including
                             the round-robin wait);
* ``fairness``             — Jain's index over request-weight-normalized
                             throughputs;
* ``slo_attainment``       — fraction of models meeting their latency SLO;
* ``traffic_bytes_per_s``  — aggregate off-chip traffic at steady state;

plus the per-model metric planes (``per_model_*``, each (B, M)) and the
repaired split actually evaluated (``pes_split``/``buf_split``/
``bw_split`` or ``time_share``).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from ...kernels.mccm_eval import pair_tables, resolve_backend
from ..batch_eval import (DeviceTables, DeviceSpec, NetTables,
                          _pair_layer_tables, eval_design_block,
                          evaluate_batch_traced, make_device_tables,
                          make_tables, pes_hint, shared_max_L)
from ..dse.encoding import DesignBatch, MultiDesignBatch, pad_deployments
from ..workload import Network
from .partition import (DEFAULT_FLOORS, DEFAULT_MAX_M, PartitionBatch,
                        partition_devices, repair_partition_jax,
                        repair_time_shares_jax)

NEG = -1.0e30

#: deployment-tile width of the joint lax.map loop.  Each row carries
#: MAX_M model lanes, so the tile is narrower than the single-model one.
JOINT_TILE = 32

#: per-model latency metrics the joint path reports as (B, M) planes
PER_MODEL_KEYS = ("latency_s", "throughput_ips", "buffer_bytes",
                  "access_bytes", "utilization", "n_ces")


# --------------------------------------------------------------------------
# stacked per-model tables
# --------------------------------------------------------------------------
@jax.tree_util.register_dataclass
@dataclass
class MultiNetTables:
    """M CNNs as one traced pytree: ``tables`` is a NetTables whose leaves
    carry a leading model axis (padded to ``max_m`` by repeating the last
    net), ``model_valid`` masks the real models.  Weights are normalized
    request rates; ``slo_s`` per-model latency SLOs (inf = none)."""

    tables: NetTables          # leaves (max_m, ...)
    model_valid: jnp.ndarray   # (max_m,) f32
    weights: jnp.ndarray       # (max_m,) f32, sum 1 over valid
    slo_s: jnp.ndarray         # (max_m,) f32

    @property
    def max_m(self) -> int:
        return self.model_valid.shape[0]

    @property
    def n_models(self) -> int:
        return int(np.asarray(self.model_valid).sum())

    def n_layers(self, m: int) -> int:
        """Concrete layer count of model m (host-side use only)."""
        return int(self.tables.L[m])


def make_multi_tables(nets: list[Network], *, weights=None, slo_s=None,
                      max_m: int = DEFAULT_MAX_M,
                      max_L: int | None = None) -> MultiNetTables:
    """Stack per-model NetTables into the (max_m, ...) megabatch.

    All models share one ``bucket_max_L`` layer bucket (adaptive — a
    200-layer net bumps every model in the deployment to the next bucket
    rather than silently truncating or forking compiles).  The model axis
    pads by repeating the LAST net, matching ``dse.stack_designs``.
    """
    if not nets:
        raise ValueError("make_multi_tables needs at least one network")
    if len(nets) > max_m:
        raise ValueError(f"{len(nets)} models exceed max_m={max_m}; raise "
                         f"max_m (costs one extra compile per new value)")
    if max_L is None:
        max_L = shared_max_L(len(n) for n in nets)
    per = [make_tables(net, max_L=max_L) for net in nets]
    per = per + [per[-1]] * (max_m - len(per))
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per)

    m = len(nets)
    valid = np.zeros(max_m, np.float32)
    valid[:m] = 1.0
    w = np.ones(m, np.float64) if weights is None \
        else np.asarray(weights, np.float64)
    if len(w) != m or (w <= 0).any():
        raise ValueError("weights must be positive, one per model")
    wfull = np.zeros(max_m, np.float32)
    wfull[:m] = (w / w.sum()).astype(np.float32)
    sfull = np.full(max_m, np.inf, np.float32)
    if slo_s is not None:
        s = np.asarray(slo_s, np.float64)
        if len(s) != m:
            raise ValueError("slo_s must have one entry per model")
        sfull[:m] = s
    return MultiNetTables(tables=stacked, model_valid=jnp.asarray(valid),
                          weights=jnp.asarray(wfull),
                          slo_s=jnp.asarray(sfull))


# --------------------------------------------------------------------------
# system metrics from per-model planes
# --------------------------------------------------------------------------
def _system_metrics(per: dict[str, jnp.ndarray], mt: MultiNetTables
                    ) -> dict[str, jnp.ndarray]:
    """Per-model (B, M) metric planes -> (B,) system metrics."""
    valid = mt.model_valid[None, :]                       # (1, M)
    vmask = valid > 0
    nv = jnp.maximum(mt.model_valid.sum(), 1.0)
    tp = per["throughput_ips"]
    lat = per["latency_s"]
    acc = per["access_bytes"]

    agg_tp = (tp * valid).sum(-1)
    worst_lat = jnp.max(jnp.where(vmask, lat, NEG), axis=-1)
    # request-weight-normalized service rates: Jain's index as the reported
    # fairness, the max-min rate as the (non-gameable) search objective
    x = jnp.where(vmask, tp / jnp.maximum(mt.weights[None, :], 1e-30), 0.0)
    fairness = jnp.square(x.sum(-1)) / jnp.maximum(
        nv * jnp.square(x).sum(-1), 1e-30)
    # normalized so equal weights reduce to the plain min model throughput
    min_tp = jnp.min(jnp.where(vmask, x, jnp.inf), axis=-1) / nv
    slo_ok = jnp.where(vmask, (lat <= mt.slo_s[None, :]).astype(jnp.float32),
                       0.0)
    slo_att = slo_ok.sum(-1) / nv
    traffic = (tp * acc * valid).sum(-1)
    return {
        "agg_throughput_ips": agg_tp,
        "worst_latency_s": worst_lat,
        "min_model_throughput_ips": min_tp,
        "fairness": fairness,
        "slo_attainment": slo_att,
        "traffic_bytes_per_s": traffic,
    }


def _package(per, mt):
    out = _system_metrics(per, mt)
    for k in PER_MODEL_KEYS:
        out[f"per_model_{k}"] = per[k]
    return out


# --------------------------------------------------------------------------
# spatial mode: per-(row, model) partitioned devices
# --------------------------------------------------------------------------
def joint_spatial_traced(md: MultiDesignBatch, mt: MultiNetTables,
                         dev: DeviceTables, pes_shares, buf_shares,
                         bw_shares, *, backend: str = "ref",
                         tile: int = JOINT_TILE, fm_tile_rows: int = 2,
                         pes_hint_static: int | None = None,
                         design_tile: int = 16,
                         floors=DEFAULT_FLOORS) -> dict[str, jnp.ndarray]:
    """The traced spatial joint path (call under jit).

    Raw shares are repaired in-trace (every deployment row becomes a valid
    split), the board is sliced into per-(row, model) DeviceTables, and
    ``eval_design_block`` runs under vmap(model) ∘ vmap(row) inside
    ``lax.map`` deployment tiles.  ``pes_hint_static`` uses the FULL
    board's bucket — partition slices never exceed it, so pair pruning
    stays sound for every split.
    """
    B, max_m = md.batch, md.n_models
    part = repair_partition_jax(pes_shares, buf_shares, bw_shares, dev,
                                mt.model_valid, floors=floors)
    devs = partition_devices(dev, part, mt.model_valid)   # leaves (B, M)

    pairs = pair_tables(mt.tables.candidates, pes_hint_static)
    fc_pair, coh_pair = jax.vmap(
        lambda t: _pair_layer_tables(t, pairs))(mt.tables)  # (M, L, P)

    def one_row(se, sp, sn, ip, dv):
        # one deployment: design leaves (M, NS), device leaves (M,)
        def one_model(se_m, sp_m, sn_m, ip_m, t_m, dv_m, fc_m, coh_m):
            d = DesignBatch(se_m[None], sp_m[None], sn_m[None], ip_m[None])
            out = eval_design_block(d, t_m, dv_m, pairs, fc_m, coh_m,
                                    backend=backend, design_tile=design_tile,
                                    fm_tile_rows=fm_tile_rows)
            return {k: v[0] for k, v in out.items()}
        return jax.vmap(one_model)(se, sp, sn, ip, mt.tables, dv,
                                   fc_pair, coh_pair)

    nt = -(-B // tile)
    pmd = pad_deployments(md, nt * tile)
    pad_dev = jax.tree_util.tree_map(
        lambda a: jnp.concatenate(
            [a, jnp.repeat(a[-1:], nt * tile - B, 0)], 0)
        if a.shape[0] < nt * tile else a, devs)

    def one_tile(args):
        se, sp, sn, ip, dv_leaves = args
        dv = DeviceTables(*dv_leaves)
        return jax.vmap(one_row, in_axes=(0, 0, 0, 0, 0))(se, sp, sn, ip, dv)

    shp = lambda a: a.reshape((nt, tile) + a.shape[1:])
    out = jax.lax.map(one_tile, (
        shp(pmd.seg_end), shp(pmd.seg_pipe), shp(pmd.seg_nce),
        shp(pmd.inter_pipe),
        tuple(shp(l) for l in (pad_dev.pes, pad_dev.on_chip_bytes,
                               pad_dev.bpc, pad_dev.bps, pad_dev.clock_hz,
                               pad_dev.wordbytes))))
    per = {k: v.reshape(nt * tile, max_m)[:B] for k, v in out.items()}
    res = _package(per, mt)
    res["pes_split"] = part.pes[:B]
    res["buf_split"] = part.buf[:B]
    res["bw_split"] = part.bw[:B]
    return res


# --------------------------------------------------------------------------
# temporal mode: full board, weighted round-robin
# --------------------------------------------------------------------------
def joint_temporal_traced(md: MultiDesignBatch, mt: MultiNetTables,
                          dev: DeviceTables, time_shares, *,
                          backend: str = "ref", tile: int = JOINT_TILE,
                          fm_tile_rows: int = 2,
                          pes_hint_static: int | None = None,
                          design_tile: int = 16,
                          share_floor: float = DEFAULT_FLOORS[2],
                          reconfig_s: float = 0.0
                          ) -> dict[str, jnp.ndarray]:
    """Weighted round-robin time multiplexing: every model's design runs on
    the FULL board; model m holds the fabric for a ``w_m`` fraction of each
    round.

    Context switches are not free: when a model's slice starts, its
    weights must re-stream from DDR (the board cannot hold every model's
    weights resident), charging ``sw_m = weight_bytes_m / bps`` per round
    (plus ``reconfig_s`` for boards that partially reconfigure).  The
    shortest feasible round is ``T = max_m((lat_m + sw_m) / w_m)`` (every
    slice fits its reload plus >= 1 inference); model m then sustains
    ``w_m * tp_m - sw_m * tp_m / T`` and its worst-case response time is
    ``(1 - w_m) * T + sw_m + lat_m``."""
    B = md.batch
    tsh = repair_time_shares_jax(time_shares, mt.model_valid,
                                 floor=share_floor)       # (B, M)
    per_mb = jax.vmap(
        lambda se, sp, sn, ip, t: evaluate_batch_traced(
            DesignBatch(se, sp, sn, ip), t, dev, backend=backend, tile=tile,
            fm_tile_rows=fm_tile_rows, pes_hint_static=pes_hint_static,
            design_tile=design_tile),
        in_axes=(1, 1, 1, 1, 0), out_axes=1,
    )(md.seg_end, md.seg_pipe, md.seg_nce, md.inter_pipe, mt.tables)
    per = dict(per_mb)                                    # leaves (B, M)

    vmask = mt.model_valid[None, :] > 0
    safe_w = jnp.maximum(tsh, 1e-30)
    lat_full = per["latency_s"]
    w_bytes = (mt.tables.W * mt.tables.valid).sum(-1) * dev.wordbytes  # (M,)
    sw = (w_bytes / dev.bps + reconfig_s)[None, :]        # (1, M)
    T = jnp.max(jnp.where(vmask, (lat_full + sw) / safe_w, NEG),
                axis=-1)                                  # (B,)
    per["throughput_ips"] = per["throughput_ips"] * jnp.maximum(
        tsh - sw / T[:, None], 0.0)
    per["latency_s"] = jnp.where(vmask,
                                 lat_full + sw + (1.0 - tsh) * T[:, None],
                                 lat_full)
    res = _package(per, mt)
    res["time_share"] = tsh
    res["round_period_s"] = T
    return res


# --------------------------------------------------------------------------
# jitted public entry points
# --------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("backend", "tile", "fm_tile_rows",
                                   "pes_hint_static", "design_tile",
                                   "floors"))
def _joint_spatial_jit(md, mt, dev, pes_shares, buf_shares, bw_shares, *,
                       backend, tile, fm_tile_rows, pes_hint_static,
                       design_tile, floors):
    return joint_spatial_traced(
        md, mt, dev, pes_shares, buf_shares, bw_shares, backend=backend,
        tile=tile, fm_tile_rows=fm_tile_rows,
        pes_hint_static=pes_hint_static, design_tile=design_tile,
        floors=floors)


@partial(jax.jit, static_argnames=("backend", "tile", "fm_tile_rows",
                                   "pes_hint_static", "design_tile",
                                   "share_floor", "reconfig_s"))
def _joint_temporal_jit(md, mt, dev, time_shares, *, backend, tile,
                        fm_tile_rows, pes_hint_static, design_tile,
                        share_floor, reconfig_s):
    return joint_temporal_traced(
        md, mt, dev, time_shares, backend=backend, tile=tile,
        fm_tile_rows=fm_tile_rows, pes_hint_static=pes_hint_static,
        design_tile=design_tile, share_floor=share_floor,
        reconfig_s=reconfig_s)


def joint_evaluate(md: MultiDesignBatch, mt: MultiNetTables,
                   dev: DeviceSpec | DeviceTables, *, mode: str = "spatial",
                   pes_shares=None, buf_shares=None, bw_shares=None,
                   time_shares=None, backend: str | None = None,
                   tile: int = JOINT_TILE, fm_tile_rows: int = 2,
                   design_tile: int = 16, floors=DEFAULT_FLOORS,
                   reconfig_s: float = 0.0) -> dict[str, jnp.ndarray]:
    """Evaluate a batch of M-model deployments — one jitted dispatch.

    ``mode="spatial"`` consumes raw (B, M) resource shares (repaired
    in-trace; defaults to an equal split), ``mode="temporal"`` raw
    round-robin time shares.  One compiled program per mode serves every
    model set (padded to ``DEFAULT_MAX_M``), board and split; only the
    batch shape and static knobs key the jit cache.
    """
    backend = resolve_backend(backend)
    if isinstance(dev, DeviceSpec):
        hint = pes_hint(dev.pes)
        devt = make_device_tables(dev)
    else:
        devt = dev
        hint = pes_hint(float(dev.pes))
    B, max_m = md.batch, md.n_models
    ones = jnp.ones((B, max_m), jnp.float32)
    if mode == "spatial":
        pes_shares = ones if pes_shares is None else jnp.asarray(pes_shares)
        buf_shares = ones if buf_shares is None else jnp.asarray(buf_shares)
        bw_shares = ones if bw_shares is None else jnp.asarray(bw_shares)
        return _joint_spatial_jit(
            md, mt, devt, pes_shares, buf_shares, bw_shares,
            backend=backend, tile=tile, fm_tile_rows=fm_tile_rows,
            pes_hint_static=hint, design_tile=design_tile,
            floors=tuple(floors))
    if mode == "temporal":
        time_shares = ones if time_shares is None \
            else jnp.asarray(time_shares)
        return _joint_temporal_jit(
            md, mt, devt, time_shares, backend=backend, tile=tile,
            fm_tile_rows=fm_tile_rows, pes_hint_static=hint,
            design_tile=design_tile, share_floor=float(floors[2]),
            reconfig_s=float(reconfig_s))
    raise ValueError(f"unknown mode {mode!r}; known: spatial, temporal")
