"""Joint DSE over (per-model budget split × per-model CE arrangement ×
spatial/temporal deployment assignment).

The multinet genome extends the single-model one: each deployment row is M
``DesignBatch`` planes (bred per model with the existing ``make_children``
operators, so every segment/CE/pipeline mutation carries over) plus raw
resource shares (spatial: DSP/BRAM/bandwidth; temporal: round-robin time
slices; hybrid: both) and — in hybrid mode — the per-model **assignment**
gene (dedicated spatial slice vs membership in the shared time-multiplexed
slice).  Share variation adds two operators of its own:

* share mutation          — one model's share scaled by a lognormal factor;
* transfer-of-budget      — crossover takes parent A's deployment and
  re-allocates budget model-wise from parent B, plus an explicit
  move-δ-from-model-i-to-j mutation.

Assignment variation adds three more (hybrid mode):

* assignment flip         — one model's spatial/shared bit toggled;
* slice merge / split     — a dedicated model folded INTO the shared slice,
  or a member pulled OUT into its own slice (directed flips, so slice
  structure changes even when flips would cancel);
* assignment crossover    — child keeps parent A's assignment but adopts
  parent B's choice on a random model subset (merging/splitting the
  shared slice exactly where the parents disagree).

Raw genes are repaired *inside* the jitted joint evaluator
(``repair_partition_jax`` / ``slice_masks``), so the breeding pipeline
never has to keep deployments feasible — mutation space stays
unconstrained and ONE compile per mode serves the whole search.
Selection keeps a :class:`ParetoArchive` over the oriented system
objectives: the default ``objective="serving"`` front is (worst-model
latency, max-min weighted throughput); ``objective="slo"`` drives the
front by graded SLO attainment under per-model deadline distributions
(``slo_attainment_dist``, paired with aggregate throughput) — the f-CNNx
observation that multi-CNN mappings are only useful under per-model
performance constraints, made a first-class search mode.

The equal-split baseline arm is the SAME search with
``freeze_partition=True`` (shares pinned to 1/M): identical budget,
operators and seeds — the front difference isolates exactly what
partition-awareness buys.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from .. import resilience
from ..dse.encoding import NS, DesignBatch, MultiDesignBatch, \
    sample_assign, stack_designs
from ..dse.pareto import ParetoArchive
from ..dse.samplers import sample_mixed
from ..dse.search import (SearchConfig, _checkpoint_meta, _gen_telemetry,
                          _load_search_checkpoint, _merged_metrics,
                          make_children, orient)
from .joint_eval import (DEADLINE_SCALES, make_multi_tables, joint_evaluate,
                         slo_attainment_dist)
from .partition import DEFAULT_FLOORS, DEFAULT_MAX_M, equal_shares, \
    sample_shares

#: default joint objectives: the multi-tenant serving trade-off — the
#: worst co-resident model's latency vs the max-min (weighted) model
#: throughput.  Aggregate throughput stays reported but is not the default
#: objective: it rewards starving the expensive model.
JOINT_OBJECTIVES = ("worst_latency_s", "min_model_throughput_ips")

#: objectives of ``objective="slo"``: graded deadline attainment (the
#: driver) traded against aggregate throughput (so the front spans
#: meet-the-SLOs vs serve-the-most instead of collapsing to one point).
SLO_OBJECTIVES = ("slo_attainment_dist", "agg_throughput_ips")

#: metric keys persisted for every evaluated deployment (system metrics
#: plus the repaired splits, so fronts decode straight to deployments)
_KEEP_SYS = ("agg_throughput_ips", "worst_latency_s",
             "min_model_throughput_ips", "fairness",
             "slo_attainment", "traffic_bytes_per_s",
             "per_model_latency_s", "per_model_throughput_ips",
             "per_model_access_bytes")
_KEEP_MODE = {"spatial": ("pes_split", "buf_split", "bw_split"),
              "temporal": ("time_share", "round_period_s"),
              "hybrid": ("pes_split", "buf_split", "bw_split",
                         "time_share", "round_period_s", "assign")}


@dataclass
class MultinetSearchConfig:
    """Knobs of the joint deployment search (see module docstring).

    ``mode`` picks the co-execution space (spatial splits, temporal
    round-robin, or the hybrid assignment space containing both);
    ``objective`` picks what drives the Pareto front: ``"serving"`` keeps
    the ``objectives`` tuple as given (default: worst-model latency vs
    max-min throughput), ``"slo"`` swaps an untouched default for
    ``SLO_OBJECTIVES`` and requires per-model SLOs (``slo_s`` here or on
    the supplied tables).  ``deadline_scales`` is the per-model deadline
    distribution grid of the graded attainment metric."""

    pop_size: int = 512
    budget: int = 4096                # total deployment evaluations
    objectives: tuple[str, ...] = JOINT_OBJECTIVES
    mode: str = "spatial"             # "spatial" | "temporal" | "hybrid"
    objective: str = "serving"        # "serving" | "slo"
    deadline_scales: tuple[float, ...] = DEADLINE_SCALES
    freeze_partition: bool = False    # pin shares to the equal split
    min_ces: int = 1                  # per-model CE bounds
    max_ces: int = 11
    seed: int = 0
    # per-model design variation (forwarded to dse.make_children)
    crossover_frac: float = 0.5
    shift_frac: float = 0.6
    split_frac: float = 0.15
    merge_frac: float = 0.15
    nce_frac: float = 0.4
    flip_frac: float = 0.15
    inter_frac: float = 0.1
    # share variation
    share_mutate_frac: float = 0.5
    share_sigma: float = 0.35
    transfer_frac: float = 0.4
    transfer_delta: float = 0.5
    share_crossover_frac: float = 0.5
    # assignment variation (hybrid mode).  The assignment gene is only M
    # bits, so it evolves on a slower timescale than shares/designs —
    # heavier churn here dilutes the per-assignment-class search depth and
    # the hybrid arm stops covering the pure subspaces it contains.
    assign_flip_frac: float = 0.08
    merge_split_frac: float = 0.15
    assign_crossover_frac: float = 0.25
    p_shared_init: float = 0.35       # shared-membership rate of fresh rows
    reconfig_s: float = 0.0           # per-round partial-reconfig charge
    #: trailing fraction of generations run memetically: children inherit a
    #: front parent's split (small jitter only), concentrating the design
    #: operators on the promising splits the explore phase surfaced
    exploit_frac: float = 0.4
    immigrant_frac: float = 0.15
    elite_frac: float = 0.25
    weights: tuple[float, ...] | None = None   # per-model request weights
    slo_s: tuple[float, ...] | None = None
    floors: tuple[float, float, float] = DEFAULT_FLOORS
    max_m: int = DEFAULT_MAX_M
    # ---- checkpoint/resume (docs/robustness.md; same contract as the
    # single-model SearchConfig: a resumed run is bit-identical) -------
    checkpoint_path: str | None = None
    checkpoint_interval: int = 8
    resume: bool = False

    def design_cfg(self) -> SearchConfig:
        """The per-model design-operator knobs, as the single-model
        SearchConfig that ``dse.make_children`` consumes."""
        return SearchConfig(
            min_ces=self.min_ces, max_ces=self.max_ces,
            crossover_frac=self.crossover_frac, shift_frac=self.shift_frac,
            split_frac=self.split_frac, merge_frac=self.merge_frac,
            nce_frac=self.nce_frac, flip_frac=self.flip_frac,
            inter_frac=self.inter_frac)


@dataclass
class MultinetSearchResult:
    """Everything :func:`joint_search` evaluated, in evaluation order:
    design planes, raw gene values (``shares`` also carries the
    ``"assign"`` genome in hybrid mode), archived metrics, the oriented
    objective points and the Pareto-front indices into all of them."""

    designs: MultiDesignBatch         # every evaluated deployment, in order
    shares: dict[str, np.ndarray]     # raw share genomes per resource
    metrics: dict[str, np.ndarray]    # system metrics + repaired splits
    points: np.ndarray                # (n_evals, n_obj) oriented objectives
    front_idx: np.ndarray
    objectives: tuple[str, ...]
    mode: str
    n_evals: int
    seconds: float
    history: list[dict] = field(default_factory=list)

    def front_points(self) -> np.ndarray:
        """Oriented (lower-better) objective points of the front rows."""
        return self.points[self.front_idx]


# --------------------------------------------------------------------------
# share variation operators (host numpy, raw positive genomes)
# --------------------------------------------------------------------------
def _mutate_shares(rng, shares, m, frac, sigma):
    """One random model's share scaled by lognormal(sigma), per row w.p.
    ``frac``.  Operates in place on the (n, max_m) raw genome."""
    n = len(shares)
    do = rng.random(n) < frac
    col = rng.integers(0, m, size=n)
    factor = np.exp(rng.normal(0.0, sigma, size=n)).astype(np.float32)
    rows = np.nonzero(do)[0]
    shares[rows, col[rows]] *= factor[rows]


def _transfer_budget(rng, shares, m, frac, delta):
    """Move ``delta`` of model i's share to model j (i != j), per row w.p.
    ``frac`` — the explicit budget-transfer mutation."""
    if m < 2:
        return
    n = len(shares)
    do = rng.random(n) < frac
    i = rng.integers(0, m, size=n)
    j = (i + rng.integers(1, m, size=n)) % m
    rows = np.nonzero(do)[0]
    moved = delta * shares[rows, i[rows]]
    shares[rows, i[rows]] -= moved
    shares[rows, j[rows]] += moved


def _crossover_shares(rng, a, b, m, frac):
    """Transfer-of-budget crossover: child keeps parent A's shares but,
    per row w.p. ``frac``, adopts parent B's allocation on a random
    nonempty model subset — budget moves between models exactly as the two
    parents disagreed."""
    n, max_m = a.shape
    take_b = rng.random((n, max_m)) < 0.5
    take_b[:, m:] = False
    none = ~take_b[:, :m].any(1)
    take_b[none, rng.integers(0, m, size=int(none.sum()))] = True
    do = (rng.random(n) < frac)[:, None]
    return np.where(do & take_b, b, a)


def _breed_shares(rng, pool_shares, pa, pb, m, cfg) -> np.ndarray:
    child = _crossover_shares(rng, pool_shares[pa].copy(),
                              pool_shares[pb], m,
                              cfg.share_crossover_frac)
    _transfer_budget(rng, child, m, cfg.transfer_frac, cfg.transfer_delta)
    _mutate_shares(rng, child, m, cfg.share_mutate_frac, cfg.share_sigma)
    return np.maximum(child, 1e-6 * child.max(initial=1.0))


# --------------------------------------------------------------------------
# assignment operators (hybrid mode; (n, max_m) 0/1 genomes, in place)
# --------------------------------------------------------------------------
def _flip_assign(rng, assign, m, frac):
    """Assignment-flip mutation: one random model's spatial/shared bit
    toggled, per row w.p. ``frac``."""
    n = len(assign)
    do = rng.random(n) < frac
    col = rng.integers(0, m, size=n)
    rows = np.nonzero(do)[0]
    assign[rows, col[rows]] = 1.0 - (assign[rows, col[rows]] > 0.5)


def _merge_split_assign(rng, assign, m, frac):
    """Slice merge/split mutation: per row w.p. ``frac``, either *merge* a
    random dedicated model into the shared slice or *split* a random
    member out into its own slice — directed flips, so the slice structure
    changes even when a uniform flip would pick an empty side."""
    if m < 2:
        return
    n = len(assign)
    do = rng.random(n) < frac
    merge = rng.random(n) < 0.5
    memb = assign[:, :m] > 0.5
    # pick a random column on the chosen side; rows whose chosen side is
    # empty (nothing to merge/split) are skipped
    side = np.where(merge[:, None], ~memb, memb)
    keys = np.where(side, rng.random((n, m)), -1.0)
    col = np.argmax(keys, axis=1)
    ok = do & side.any(1)
    rows = np.nonzero(ok)[0]
    assign[rows, col[rows]] = merge[rows].astype(np.float32)


def _crossover_assign(rng, a, b, m, frac):
    """Slice-merge/split crossover: child keeps parent A's assignment but,
    per row w.p. ``frac``, adopts parent B's spatial/shared choice on a
    random nonempty model subset — the shared slice merges or splits
    exactly where the parents disagreed."""
    n, max_m = a.shape
    take_b = rng.random((n, max_m)) < 0.5
    take_b[:, m:] = False
    none = ~take_b[:, :m].any(1)
    take_b[none, rng.integers(0, m, size=int(none.sum()))] = True
    do = (rng.random(n) < frac)[:, None]
    return np.where(do & take_b, b, a)


# --------------------------------------------------------------------------
# the search loop
# --------------------------------------------------------------------------
def joint_search(nets, dev, config: MultinetSearchConfig | None = None,
                 mtables=None, backend: str | None = None, mesh=None
                 ) -> MultinetSearchResult:
    """Run the joint loop: sample deployments -> joint evaluate -> archive
    -> breed designs, budget splits and (hybrid) assignments together.

    Caller-provided ``mtables`` are used verbatim; an explicit ``backend``
    overrides the env-resolved kernel backend (what the Session passes);
    a sharded ``mesh`` (``core.shard.EvalMesh``) shards every generation's
    deployment axis through the sharded ``joint_evaluate`` entry point."""
    cfg = config or MultinetSearchConfig()
    if cfg.budget < 1 or cfg.pop_size < 1:
        raise ValueError(f"budget and pop_size must be >= 1 "
                         f"(got {cfg.budget}, {cfg.pop_size})")
    if cfg.mode not in ("spatial", "temporal", "hybrid"):
        raise ValueError(f"unknown mode {cfg.mode!r}; known: spatial, "
                         f"temporal, hybrid")
    if cfg.objective not in ("serving", "slo"):
        raise ValueError(f"unknown objective {cfg.objective!r}; known: "
                         f"serving, slo")
    mt = mtables if mtables is not None else make_multi_tables(
        nets, weights=cfg.weights, slo_s=cfg.slo_s, max_m=cfg.max_m)
    objectives = tuple(cfg.objectives)
    slo_aware = bool(np.isfinite(np.asarray(mt.slo_s)).any())
    if cfg.objective == "slo":
        if not slo_aware:
            raise ValueError("objective='slo' needs per-model SLOs: pass "
                             "slo_s on the config or the tables")
        if objectives == JOINT_OBJECTIVES:   # untouched default -> swap
            objectives = SLO_OBJECTIVES
    m = len(nets)
    max_m = mt.max_m
    n_layers = [len(net) for net in nets]
    n_obj = len(objectives)
    rng = np.random.default_rng(cfg.seed)
    dcfg = cfg.design_cfg()
    resources = {"spatial": ("pes", "buf", "bw"), "temporal": ("time",),
                 "hybrid": ("pes", "buf", "bw", "time")}[cfg.mode]
    hybrid = cfg.mode == "hybrid"

    pop_n = min(cfg.pop_size, cfg.budget)
    gens = max(1, cfg.budget // pop_n)
    sizes = [pop_n] * gens
    sizes[-1] += cfg.budget - gens * pop_n
    total = cfg.budget

    def fresh_shares(n):
        if cfg.freeze_partition:
            sh = {r: equal_shares(n, max_m, m) for r in resources}
        else:
            sh = {r: sample_shares(rng, n, max_m, m) for r in resources}
            # anchor a few exact equal-split rows so the searched space
            # always contains the baseline deployment
            k = max(1, n // 16)
            for r in resources:
                sh[r][:k] = equal_shares(k, max_m, m)
        if hybrid:
            if cfg.freeze_partition:
                a = np.zeros((n, max_m), np.float32)
            else:
                a = sample_assign(rng, n, max_m, m,
                                  p_shared=cfg.p_shared_init)
                # anchor both pure modes so the hybrid front always
                # contains (and can only improve on) each pure space
                k = max(1, n // 8)
                a[:k] = 0.0
                a[k:2 * k, :m] = 1.0
            sh["assign"] = a
        return sh

    def fresh_designs(n):
        return [sample_mixed(rng, L, n, min_ces=cfg.min_ces,
                             max_ces=cfg.max_ces) for L in n_layers]

    # hall-of-everything buffers (preallocated; written incrementally)
    genes = tuple(resources) + (("assign",) if hybrid else ())
    hall_end = np.empty((total, max_m, NS), np.int32)
    hall_pipe = np.empty((total, max_m, NS), bool)
    hall_nce = np.empty((total, max_m, NS), np.int32)
    hall_inter = np.empty((total, max_m), bool)
    hall_sh = {r: np.empty((total, max_m), np.float32) for r in genes}
    all_points = np.empty((total, n_obj))
    all_metrics: list[dict] = []
    archive = ParetoArchive(n_obj)
    history: list[dict] = []

    def eval_gen(md: MultiDesignBatch, sh: dict) -> dict:
        """Evaluate one generation in pop_n-shaped sub-batches (the final
        oversized generation splits; every call is pop_n rows)."""
        n = md.batch
        outs = []
        for s in range(0, n, pop_n):
            idx = np.arange(s, min(s + pop_n, n))
            sub = md.take(idx)
            subsh = {r: v[idx] for r, v in sh.items()}
            if len(idx) < pop_n:
                pad = np.concatenate([idx, np.repeat(idx[-1:],
                                                     pop_n - len(idx))])
                sub = md.take(pad)
                subsh = {r: v[pad] for r, v in sh.items()}
            if cfg.mode == "spatial":
                out = joint_evaluate(sub, mt, dev,
                                     pes_shares=subsh["pes"],
                                     buf_shares=subsh["buf"],
                                     bw_shares=subsh["bw"],
                                     backend=backend,
                                     floors=cfg.floors, mesh=mesh)
            elif cfg.mode == "temporal":
                out = joint_evaluate(sub, mt, dev, mode="temporal",
                                     time_shares=subsh["time"],
                                     backend=backend,
                                     floors=cfg.floors,
                                     reconfig_s=cfg.reconfig_s, mesh=mesh)
            else:
                out = joint_evaluate(sub, mt, dev, mode="hybrid",
                                     assign=subsh["assign"],
                                     pes_shares=subsh["pes"],
                                     buf_shares=subsh["buf"],
                                     bw_shares=subsh["bw"],
                                     time_shares=subsh["time"],
                                     backend=backend,
                                     floors=cfg.floors,
                                     reconfig_s=cfg.reconfig_s, mesh=mesh)
            keep = _KEEP_SYS + _KEEP_MODE[cfg.mode]
            got = {k: np.asarray(out[k])[:len(idx)] for k in keep}
            if slo_aware:
                got["slo_attainment_dist"] = slo_attainment_dist(
                    got["per_model_latency_s"], mt,
                    scales=cfg.deadline_scales)
            outs.append(got)
        return {k: np.concatenate([o[k] for o in outs])
                if len(outs) > 1 else outs[0][k] for k in outs[0]}

    # ---- checkpoint/resume: restore loop state exactly as it was at
    # the top of generation `start_gen`, before that gen's RNG draws ---
    start_gen, base, elapsed0 = 0, 0, 0.0
    snap = _load_search_checkpoint(cfg, tuple(n_layers), "multinet-search")
    if snap is None:
        pop_md = stack_designs(fresh_designs(sizes[0]), max_m)
        pop_sh = fresh_shares(sizes[0])
    else:
        start_gen, base = snap["gen"], snap["base"]
        rng = resilience.rng_from_state(snap["rng"])
        pop_md = MultiDesignBatch(*snap["pop_md"])
        pop_sh = {r: v.copy() for r, v in snap["pop_sh"].items()}
        hall_end[:base], hall_pipe[:base] = snap["hall"][0], snap["hall"][1]
        hall_nce[:base], hall_inter[:base] = snap["hall"][2], snap["hall"][3]
        for r in genes:
            hall_sh[r][:base] = snap["hall_sh"][r]
        all_points[:base] = snap["points"]
        if snap["metrics"]:
            all_metrics.append(snap["metrics"])
        archive.points = snap["archive"][0].copy()
        archive.payload = snap["archive"][1].copy()
        history.extend(snap["history"])
        elapsed0 = snap["elapsed_s"]
    ckpt_every = max(1, cfg.checkpoint_interval)
    t0 = time.time() - elapsed0
    for gen in range(start_gen, gens):
        if cfg.checkpoint_path and gen > 0 and gen % ckpt_every == 0:
            resilience.save_checkpoint(
                cfg.checkpoint_path, "multinet-search",
                {"gen": gen, "base": base,
                 "rng": resilience.rng_state(rng),
                 "pop_md": tuple(np.asarray(a) for a in pop_md.to_numpy()),
                 "pop_sh": {r: v.copy() for r, v in pop_sh.items()},
                 "hall": (hall_end[:base].copy(), hall_pipe[:base].copy(),
                          hall_nce[:base].copy(), hall_inter[:base].copy()),
                 "hall_sh": {r: hall_sh[r][:base].copy() for r in genes},
                 "points": all_points[:base].copy(),
                 "metrics": _merged_metrics(all_metrics),
                 "archive": (archive.points.copy(), archive.payload.copy()),
                 "history": list(history),
                 "elapsed_s": time.time() - t0},
                meta=_checkpoint_meta(cfg, tuple(n_layers)))
        out = eval_gen(pop_md, pop_sh)
        pts = orient(out, objectives)
        ok = np.isfinite(pts).all(1)
        idx = np.arange(base, base + sizes[gen])
        base += sizes[gen]
        (hall_end[idx], hall_pipe[idx], hall_nce[idx],
         hall_inter[idx]) = pop_md.to_numpy()
        for r in genes:
            hall_sh[r][idx] = pop_sh[r]
        all_points[idx] = pts
        all_metrics.append(out)
        archive.update(pts[ok], idx[ok])

        if gen == gens - 1:
            break

        # ---- parents: archive front + this generation's elite slice ----
        lo, hi = np.nanmin(all_points[:base], 0), np.nanmax(
            np.where(np.isfinite(all_points[:base]), all_points[:base],
                     np.nan), 0)
        norm = (pts - lo) / np.maximum(hi - lo, 1e-30)
        score = np.where(ok, norm.sum(1), np.inf)
        n_elite = max(1, int(sizes[gen] * cfg.elite_frac))
        elite = idx[np.argsort(score, kind="stable")[:n_elite]]
        pool = np.unique(np.concatenate([archive.payload, elite]))
        pool_sh = {r: hall_sh[r][pool] for r in genes}

        n_next = sizes[gen + 1]
        n_imm = int(n_next * cfg.immigrant_frac)
        n_child = n_next - n_imm
        kids = [make_children(
            rng, DesignBatch.from_numpy(
                hall_end[pool][:, mm], hall_pipe[pool][:, mm],
                hall_nce[pool][:, mm], hall_inter[pool][:, mm]),
            n_layers[mm], dcfg, n_child) for mm in range(m)]
        exploit = gen + 1 >= gens - int((gens - 1) * cfg.exploit_frac)
        if cfg.freeze_partition:
            kid_sh = {r: equal_shares(n_child, max_m, m) for r in resources}
            if hybrid:
                kid_sh["assign"] = np.zeros((n_child, max_m), np.float32)
        else:
            pa = rng.integers(0, len(pool), size=n_child)
            pb = rng.integers(0, len(pool), size=n_child)
            if exploit:
                # memetic tail: inherit parent A's split (and assignment)
                # near-verbatim so design breeding refines the deployments
                # the explore phase surfaced
                kid_sh = {}
                for r in resources:
                    sh_r = pool_sh[r][pa].copy()
                    _mutate_shares(rng, sh_r, m, 0.3,
                                   0.2 * cfg.share_sigma)
                    kid_sh[r] = sh_r
                if hybrid:
                    a = pool_sh["assign"][pa].copy()
                    _flip_assign(rng, a, m, 0.2 * cfg.assign_flip_frac)
                    kid_sh["assign"] = a
            else:
                kid_sh = {r: _breed_shares(rng, pool_sh[r], pa, pb, m, cfg)
                          for r in resources}
                if hybrid:
                    a = _crossover_assign(rng, pool_sh["assign"][pa].copy(),
                                          pool_sh["assign"][pb], m,
                                          cfg.assign_crossover_frac)
                    _merge_split_assign(rng, a, m, cfg.merge_split_frac)
                    _flip_assign(rng, a, m, cfg.assign_flip_frac)
                    kid_sh["assign"] = a
        if n_imm:
            imm = fresh_designs(n_imm)
            if exploit and not cfg.freeze_partition:
                pi = rng.integers(0, len(pool), size=n_imm)
                imm_sh = {r: pool_sh[r][pi].copy() for r in genes}
            else:
                imm_sh = fresh_shares(n_imm)
            kids = [DesignBatch.from_numpy(
                np.concatenate([np.asarray(k.seg_end),
                                np.asarray(i.seg_end)]),
                np.concatenate([np.asarray(k.seg_pipe),
                                np.asarray(i.seg_pipe)]),
                np.concatenate([np.asarray(k.seg_nce),
                                np.asarray(i.seg_nce)]),
                np.concatenate([np.asarray(k.inter_pipe),
                                np.asarray(i.inter_pipe)]))
                for k, i in zip(kids, imm)]
            kid_sh = {r: np.concatenate([kid_sh[r], imm_sh[r]])
                      for r in genes}
        pop_md = stack_designs(kids, max_m)
        pop_sh = kid_sh

        history.append(dict(gen=gen, evals=base, archive=len(archive),
                            best=dict(zip(objectives,
                                          archive.points.min(0).tolist()))
                            if len(archive) else {}))
        _gen_telemetry("multinet", gen, base,
                       archive.points if len(archive) else None,
                       {"mode": cfg.mode})

    seconds = time.time() - t0
    cat_md = MultiDesignBatch(hall_end, hall_pipe, hall_nce, hall_inter)
    metrics = {k: np.concatenate([mtr[k] for mtr in all_metrics])
               if len(all_metrics) > 1 else all_metrics[0][k]
               for k in all_metrics[0]}
    history.append(dict(gen=gens - 1, evals=total, archive=len(archive),
                        best=dict(zip(objectives,
                                      archive.points.min(0).tolist()))
                        if len(archive) else {}))
    _gen_telemetry("multinet", gens - 1, total,
                   archive.points if len(archive) else None,
                   {"mode": cfg.mode})
    return MultinetSearchResult(
        designs=cat_md, shares=hall_sh, metrics=metrics, points=all_points,
        front_idx=np.sort(archive.payload.copy()),
        objectives=objectives, mode=cfg.mode, n_evals=total,
        seconds=seconds, history=history)
