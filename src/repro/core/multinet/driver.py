"""End-to-end multinet drivers: one ``joint_explore()`` call per arm.

Five strategies at one evaluation budget (deployments evaluated):

* ``"search"``      — joint DSE: per-model designs AND the spatial budget
                      split evolve together (the headline spatial arm);
* ``"equal_split"`` — the same search with the split frozen to 1/M — the
  ablation isolating what partition-awareness buys;
* ``"temporal"``    — time-multiplexed baseline: full-board designs and
  round-robin time shares evolve, no spatial split;
* ``"hybrid"``      — the general deployment space: designs, splits, time
  shares AND the per-model spatial/shared assignment evolve together
  (contains both pure modes; its initial population anchors them);
* ``"random"``      — blind sampling of designs + Dirichlet splits.

Every guided arm accepts ``objective="slo"`` to drive the front by graded
SLO attainment under per-model deadline distributions instead of the
default worst-latency/max-min-throughput trade-off.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from ..dse.encoding import MultiDesignBatch, stack_designs
from ..dse.pareto import hypervolume_2d, pareto
from ..dse.samplers import sample_mixed
from ..dse.search import orient
from .joint_eval import make_multi_tables, joint_evaluate
from .partition import DEFAULT_MAX_M, sample_shares
from .search import (JOINT_OBJECTIVES, MultinetSearchConfig,
                     MultinetSearchResult, _KEEP_MODE, _KEEP_SYS,
                     joint_search)


@dataclass
class JointDSEResult:
    """One :func:`joint_explore` arm's outcome: every evaluated deployment
    (designs + raw gene values in ``shares``), the archived system
    metrics, and the Pareto ``front`` indices over the arm's oriented
    ``objectives``."""

    designs: MultiDesignBatch
    metrics: dict[str, np.ndarray]
    seconds: float
    per_eval_us: float
    strategy: str = "search"
    mode: str = "spatial"
    n_evals: int = 0
    n_models: int = 0
    objectives: tuple[str, ...] = JOINT_OBJECTIVES
    front: np.ndarray = field(default_factory=lambda: np.empty(0, np.intp))
    #: raw share genomes per resource, one row per evaluated deployment —
    #: re-feeding row i to ``joint_evaluate`` reproduces its metrics
    shares: dict[str, np.ndarray] = field(default_factory=dict)

    def front_points(self) -> np.ndarray:
        """Oriented (lower-better) objective points of the front rows."""
        return orient(self.metrics, self.objectives)[self.front]

    def hypervolume(self, ref: np.ndarray) -> float:
        """Dominated 2-D hypervolume of the front w.r.t. ``ref`` (a point
        weakly dominated by every front point)."""
        return hypervolume_2d(self.front_points(), ref)


def _joint_explore(nets, dev, n: int = 4096, *, strategy: str = "search",
                   seed: int = 0, chunk: int = 512,
                   objectives: tuple[str, ...] = JOINT_OBJECTIVES,
                   objective: str = "serving",
                   config: MultinetSearchConfig | None = None,
                   weights=None, slo_s=None, mtables=None,
                   backend: str | None = None, mesh=None) -> JointDSEResult:
    """Implementation behind ``Session.deploy`` and the deprecated
    ``joint_explore`` shim: evaluate ``n`` deployments of ``nets`` on
    ``dev`` and return the sample plus its Pareto front over the system
    objectives.

    A ``config``, when given, is authoritative for the guided arms (only
    the budget comes from ``n``; strategy still selects mode/freeze).
    ``objective="slo"`` (when ``config`` is None) swaps the front driver
    to graded deadline attainment — see :class:`MultinetSearchConfig`.
    Caller-provided ``mtables`` (a prebuilt :class:`MultiNetTables`) are
    used verbatim by EVERY strategy — random included — instead of
    rebuilding them; an explicit ``backend`` overrides the env-resolved
    kernel backend.
    """
    if n < 1:
        raise ValueError(f"n must be >= 1, got {n}")
    m = len(nets)
    if strategy in ("search", "equal_split", "temporal", "hybrid"):
        base = config.__dict__ if config is not None else {}
        over = dict(budget=n,
                    mode={"temporal": "temporal",
                          "hybrid": "hybrid"}.get(strategy, "spatial"),
                    freeze_partition=strategy == "equal_split")
        if config is None:
            over.update(seed=seed, objectives=tuple(objectives),
                        objective=objective, weights=weights, slo_s=slo_s)
        cfg = MultinetSearchConfig(**{**base, **over})
        res: MultinetSearchResult = joint_search(nets, dev, cfg,
                                                 mtables=mtables,
                                                 backend=backend, mesh=mesh)
        return JointDSEResult(
            designs=res.designs, metrics=res.metrics, seconds=res.seconds,
            per_eval_us=res.seconds / max(res.n_evals, 1) * 1e6,
            strategy=strategy, mode=res.mode, n_evals=res.n_evals,
            n_models=m, objectives=res.objectives, front=res.front_idx,
            shares=res.shares)
    if strategy != "random":
        raise ValueError(f"unknown strategy {strategy!r}")

    rng = np.random.default_rng(seed)
    mt = mtables if mtables is not None else make_multi_tables(
        nets, weights=weights, slo_s=slo_s)
    max_m = mt.max_m
    keep = _KEEP_SYS + _KEEP_MODE["spatial"]
    outs, mds = [], []
    shares = {r: [] for r in ("pes", "buf", "bw")}
    t0 = time.time()
    done = 0
    while done < n:
        b = min(chunk, n - done)
        md = stack_designs([sample_mixed(rng, len(net), b, min_ces=1)
                            for net in nets], max_m)
        sh = [sample_shares(rng, b, max_m, m) for _ in range(3)]
        for r, s in zip(shares, sh):
            shares[r].append(s)
        if b < chunk:   # pad the tail chunk: the sweep compiles once
            pad = np.concatenate([np.arange(b),
                                  np.full(chunk - b, b - 1)])
            md = md.take(pad)
            sh = [s[pad] for s in sh]
        out = joint_evaluate(md, mt, dev, pes_shares=sh[0],
                             buf_shares=sh[1], bw_shares=sh[2],
                             backend=backend, mesh=mesh)
        outs.append({k: np.asarray(out[k])[:b] for k in keep})
        mds.append(md.take(np.arange(b)))
        done += b
    dt = time.time() - t0
    designs = MultiDesignBatch(
        np.concatenate([np.asarray(d.seg_end) for d in mds]),
        np.concatenate([np.asarray(d.seg_pipe) for d in mds]),
        np.concatenate([np.asarray(d.seg_nce) for d in mds]),
        np.concatenate([np.asarray(d.inter_pipe) for d in mds]))
    metrics = {k: np.concatenate([o[k] for o in outs]) for k in outs[0]}
    front = pareto(orient(metrics, objectives))
    return JointDSEResult(designs=designs, metrics=metrics, seconds=dt,
                          per_eval_us=dt / n * 1e6, strategy="random",
                          n_evals=n, n_models=m,
                          objectives=tuple(objectives), front=front,
                          shares={r: np.concatenate(v)
                                  for r, v in shares.items()})


def joint_explore(nets, dev, n: int = 4096, *, strategy: str = "search",
                  seed: int = 0, chunk: int = 512,
                  objectives: tuple[str, ...] = JOINT_OBJECTIVES,
                  objective: str = "serving",
                  config: MultinetSearchConfig | None = None,
                  weights=None, slo_s=None, mtables=None,
                  backend: str | None = None) -> JointDSEResult:
    """Deprecated shim over :func:`_joint_explore` — use
    :meth:`repro.api.Session.deploy` (bit-identical results)."""
    from .._deprecation import warn_deprecated
    warn_deprecated("joint_explore", "repro.api.Session.deploy")
    return _joint_explore(nets, dev, n, strategy=strategy, seed=seed,
                          chunk=chunk, objectives=objectives,
                          objective=objective, config=config,
                          weights=weights, slo_s=slo_s, mtables=mtables,
                          backend=backend)
