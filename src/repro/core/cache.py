"""Bounded LRU caches for the long-lived serving path.

A :class:`Session` memoizes ``NetTables``/``DeviceTables``/
``MultiNetTables`` and the mesh's sharded jits.  In a notebook those
memos only ever hold a handful of entries; a long-lived *server* under
millions of distinct (net, board) keys would grow them without bound.
:class:`BoundedLRU` is the shared eviction policy: least-recently-used
entries fall out once ``maxsize`` is reached, with an eviction counter
(surfaced in ``Session.observability()``) and an optional ``on_evict``
callback so owners can fold evicted state into their own accounting
(``core.shard`` keeps compile counters monotone this way).

Thread safety is the *owner's* job — the Session holds its table lock
across get+put, exactly as it did over the plain dicts.  Bounds resolve
from the environment once, at session construction:
``REPRO_CACHE_TABLES`` / ``REPRO_CACHE_JITS`` (``docs/serving.md``).
"""
from __future__ import annotations

import os
from collections import OrderedDict
from typing import Callable

#: env knobs (read once, in ``EvalConfig.resolved()``)
TABLES_ENV = "REPRO_CACHE_TABLES"
JITS_ENV = "REPRO_CACHE_JITS"

#: defaults for a long-lived server: generous enough that interactive
#: sessions and the test suite never evict, small enough to bound memory
DEFAULT_MAX_TABLES = 256
DEFAULT_MAX_JITS = 128


def env_bound(env: str, default: int) -> int:
    """Resolve a cache bound from the environment.  ``0`` (or a negative
    value) means *unbounded* — the cache never evicts."""
    raw = os.environ.get(env)
    if raw is None or raw == "":
        return default
    try:
        return int(raw)
    except ValueError as e:
        raise ValueError(f"{env} must be an integer, got {raw!r}") from e


class BoundedLRU:
    """An ordered mapping that evicts its least-recently-used entry past
    ``maxsize``.  ``maxsize <= 0`` disables eviction (plain memo dict).

    Not thread-safe by itself: callers hold their own lock across
    :meth:`get`/:meth:`put` (the Session's table lock already covers the
    check+build+insert sequence).
    """

    def __init__(self, maxsize: int = 0, *,
                 on_evict: Callable[[object, object], None] | None = None):
        self.maxsize = int(maxsize)
        self.on_evict = on_evict
        self.evictions = 0
        self._d: OrderedDict = OrderedDict()

    def __len__(self) -> int:
        return len(self._d)

    def __contains__(self, key) -> bool:
        return key in self._d

    def get(self, key, default=None):
        """Look up ``key``, refreshing its recency on a hit."""
        try:
            val = self._d[key]
        except KeyError:
            return default
        self._d.move_to_end(key)
        return val

    def put(self, key, value) -> None:
        """Insert (or refresh) ``key`` and evict LRU entries past the
        bound.  ``on_evict(key, value)`` runs for each victim — exceptions
        there propagate (the owner's accounting must not fail silently)."""
        self._d[key] = value
        self._d.move_to_end(key)
        if self.maxsize <= 0:
            return
        while len(self._d) > self.maxsize:
            k, v = self._d.popitem(last=False)
            self.evictions += 1
            if self.on_evict is not None:
                self.on_evict(k, v)

    def keys(self):
        return self._d.keys()

    def values(self):
        return self._d.values()

    def items(self):
        return self._d.items()

    def clear(self) -> None:
        self._d.clear()

    def stats(self) -> dict[str, int]:
        """Size / bound / eviction counters, as ``observability()``
        reports them."""
        return {"size": len(self._d), "maxsize": self.maxsize,
                "evictions": self.evictions}
