"""The front door: one :class:`Session` serves scalar, batch, DSE and
multinet evaluation behind shared compiled programs.

MCCM's speed claim is per-call — microseconds per design once compiled.
What erodes it in practice is everything *around* the call: every entry
point (``evaluate_design``, ``evaluate_specs``, ``explore``,
``joint_explore``) rebuilding ``NetTables``/``DeviceTables`` unless the
caller threads ``tables=`` by hand, and each reading its own
``backend``/``tile``/``chunk`` kwargs and ``REPRO_*`` env vars.  A Session
is constructed once per process and owns all of it:

* **memoized tables** — ``NetTables`` keyed by ``(net, bucketed max_L)``,
  ``DeviceTables`` keyed by board, ``MultiNetTables`` keyed by the model
  set + weights/SLOs, so the one-compile-serves-all property of
  ``batch_eval``/``joint_eval`` is automatic instead of opt-in;
* **one config** — :class:`EvalConfig` resolves the kernel backend and the
  persistent-compile-cache dir ONCE at session creation; every downstream
  call inherits it (no scattered env reads);
* **one surface** — :meth:`Session.evaluate` (scalar spec, spec list or
  ``DesignBatch``, dispatching on input), :meth:`Session.explore` (DSE),
  :meth:`Session.deploy` (multinet), and :meth:`Session.submit` → Future
  with a background drain loop that megabatches queued requests through
  one compiled program (the serve-many-users path).

The legacy free functions remain as deprecated shims over the same
implementations — bit-identical results (``tests/test_session.py``), one
``DeprecationWarning``.  Migration table: ``docs/api.md``.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, fields, replace

import numpy as np

from ..compat import enable_persistent_compilation_cache
from ..kernels.mccm_eval import resolve_backend
from . import telemetry
from .batch_eval import (DEFAULT_TILE, DeviceTables, NetTables,
                         _evaluate_specs, _evaluate_specs_multi,
                         bucket_max_L, evaluate_batch, make_device_tables,
                         make_tables)
from .cache import (DEFAULT_MAX_TABLES, TABLES_ENV, BoundedLRU, env_bound)
from .coalesce import ArrivalEstimator, plan_megabatch
from .device import DeviceSpec
from .dse.driver import DEFAULT_OBJECTIVES
from .dse.encoding import DesignBatch
from .evaluator import _evaluate_design, build_design
from .notation import AcceleratorSpec, parse
from .resilience import (CircuitBreaker, EvalError, classify,
                         nonfinite_keys, retry_delay, wrap)
from .workload import Network


# --------------------------------------------------------------------------
# configuration, resolved once
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class EvalConfig:
    """Every evaluation knob in one place, resolved at session creation.

    ``backend=None`` reads ``REPRO_MCCM_BACKEND`` (falling back to auto:
    pallas on TPU, ref elsewhere) and pins the result; ``cache_dir=None``
    reads ``REPRO_JAX_CACHE_DIR``.  Both env vars are consulted exactly
    once — at :class:`Session` construction — instead of per call.
    """

    #: parallelism-search kernel backend ("ref" | "pallas" |
    #: "pallas_interpret"); None resolves the env var / auto default
    backend: str | None = None
    #: design-tile width of the lax.map hot loop
    tile: int = DEFAULT_TILE
    #: feature-map tile rows of Eq. 4's double buffers.  Applies to the
    #: evaluate()/submit() paths; the explore()/deploy() search loops pin
    #: the engine default (2) so their compiled programs stay shared
    fm_tile_rows: int = 2
    #: VMEM design-tile width inside the fused kernel (same scope as
    #: ``fm_tile_rows``)
    design_tile: int = 16
    #: spec-list chunking of evaluate()/submit() (shapes pad per chunk)
    chunk: int = 2048
    #: model-axis padding of deploy()'s MultiNetTables; None = the
    #: multinet default (DEFAULT_MAX_M)
    max_m: int | None = None
    #: persistent jit-cache directory; None resolves REPRO_JAX_CACHE_DIR
    cache_dir: str | None = None
    #: submit() megabatching window: how long the drain loop lingers after
    #: the first queued request before evaluating, so concurrent callers
    #: land in one compiled dispatch
    linger_s: float = 0.002
    #: design-axis mesh width (devices).  None resolves REPRO_MESH_DEVICES,
    #: else every visible device; 1 pins the single-device path.  The
    #: session builds one ``core.shard.EvalMesh`` from this and threads it
    #: through evaluate()/explore()/deploy()/submit() (docs/perf.md)
    mesh: int | None = None
    #: default per-request wall-clock deadline of submit(), in seconds.
    #: A request still queued (or whose result is not yet delivered) when
    #: its deadline passes fails with ``EvalError.DEADLINE_EXCEEDED``
    #: instead of hanging; None disables.  submit(deadline_s=...) wins
    #: per request (docs/robustness.md)
    deadline_s: float | None = None
    #: admission control: maximum queued submit() requests.  Further
    #: submits fail fast with ``EvalError.QUEUE_FULL`` instead of growing
    #: the queue without bound; None = unbounded
    max_queue: int | None = None
    #: transient-fault retries of the primary backend per call, with
    #: exponential backoff (``resilience.retry_delay``) between attempts
    max_retries: int = 0
    #: degraded-mode backend when the primary faults past its retries (and
    #: when the circuit breaker is open): the bit-tested pure-jnp "ref"
    #: path by default.  None disables fallback entirely
    fallback_backend: str | None = "ref"
    #: adaptive linger cap, in seconds.  None keeps the fixed ``linger_s``
    #: window; a value arms the arrival-rate-driven policy (the drain
    #: lingers ~2 observed inter-arrivals, never more than this cap) —
    #: what the serving front runs with (docs/serving.md)
    linger_max_s: float | None = None
    #: megabatch coalescing: merge tiny same-(net, board) requests into
    #: shared padded chunks and split oversized requests at the compiled
    #: chunk size.  Bit-identical results (evaluation is row-local) and
    #: never forks compiles; off reproduces the one-padded-chunk-per-
    #: request drain
    coalesce: bool = True
    #: bound of EACH memoized table cache (NetTables / DeviceTables /
    #: MultiNetTables), in entries.  None resolves REPRO_CACHE_TABLES
    #: (default 256); 0 disables eviction.  LRU past the bound, with
    #: eviction counters in observability() (docs/serving.md)
    max_cached_tables: int | None = None
    #: bound of the mesh's sharded-jit registry, in compiled programs.
    #: None resolves REPRO_CACHE_JITS (default 128); 0 disables eviction
    max_cached_jits: int | None = None

    def resolved(self) -> "EvalConfig":
        """Pin the env-dependent fields (backend, cache_dir, mesh, cache
        bounds) to concrete values — called once by :class:`Session`."""
        import os

        from ..compat import CACHE_ENV
        from .shard import env_mesh_devices
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, "
                             f"got {self.max_retries}")
        if self.max_queue is not None and self.max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {self.max_queue}")
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError(f"deadline_s must be > 0, got {self.deadline_s}")
        if self.linger_max_s is not None and self.linger_max_s < 0:
            raise ValueError(f"linger_max_s must be >= 0, "
                             f"got {self.linger_max_s}")
        return replace(
            self,
            backend=resolve_backend(self.backend),
            fallback_backend=None if self.fallback_backend is None
            else resolve_backend(self.fallback_backend),
            cache_dir=self.cache_dir or os.environ.get(CACHE_ENV) or None,
            mesh=self.mesh if self.mesh is not None else env_mesh_devices(),
            max_cached_tables=env_bound(TABLES_ENV, DEFAULT_MAX_TABLES)
            if self.max_cached_tables is None else self.max_cached_tables,
            max_cached_jits=self.max_cached_jits)


@dataclass
class SessionStats:
    """Host-side counters of what a session reused vs rebuilt.

    Counters are mutated from BOTH the caller threads and the background
    drain thread (retries/degrades/deadlines happen on either side), so
    every mutation goes through :meth:`bump` under the stats lock —
    plain ``+=`` on the fields is a lost-update race
    (``tests/test_session.py::test_submit_hammer_counters_consistent``).
    """

    net_table_builds: int = 0
    net_table_hits: int = 0
    device_table_builds: int = 0
    device_table_hits: int = 0
    multi_table_builds: int = 0
    multi_table_hits: int = 0
    scalar_evals: int = 0
    batch_designs: int = 0
    explore_calls: int = 0
    deploy_calls: int = 0
    # schedule layer (docs/schedule.md)
    schedule_calls: int = 0
    schedule_builds: int = 0   # schedule searches actually run on device
    schedule_hits: int = 0     # artifacts served from the bounded memo
    submits: int = 0
    megabatches: int = 0
    megabatch_requests: int = 0
    # coalescing counters (docs/serving.md)
    coalesced_chunks: int = 0  # padded dispatch units planned
    coalesced_merges: int = 0  # requests that shared a chunk with another
    coalesced_splits: int = 0  # requests split at the compiled chunk size
    # priority-lane / search-job counters (docs/serving.md)
    search_jobs: int = 0       # submit_search() jobs accepted
    # cache-eviction counters (bounded table caches, docs/serving.md)
    net_table_evictions: int = 0
    device_table_evictions: int = 0
    multi_table_evictions: int = 0
    schedule_evictions: int = 0
    # resilience counters (docs/robustness.md)
    rejected: int = 0          # submits refused by admission control
    retried: int = 0           # primary-backend retry attempts
    degraded: int = 0          # calls served by the fallback backend
    deadline_missed: int = 0   # requests failed with DEADLINE_EXCEEDED

    def __post_init__(self):
        # not a dataclass field: stays out of fields()/as_dict()/repr
        self._lock = threading.Lock()

    def bump(self, name: str, n: int = 1) -> None:
        """Atomically increment counter ``name`` (and mirror it into the
        telemetry registry when enabled)."""
        with self._lock:
            setattr(self, name, getattr(self, name) + n)
        telemetry.count(f"session.{name}", n)

    def as_dict(self) -> dict[str, int]:
        return {f.name: getattr(self, f.name) for f in fields(self)}


#: submit() priority lanes, highest first.  The drain serves interactive
#: requests ahead of batch ones inside every megabatch, and search jobs
#: run on their own worker thread — bulk work can never starve a point
#: evaluation (docs/serving.md)
PRIORITIES = ("interactive", "batch")


class _Request:
    """One queued :meth:`Session.submit` unit of work."""

    __slots__ = ("specs", "net", "dev", "future", "scalar", "deadline",
                 "t_enq", "priority")

    def __init__(self, specs, net, dev, future, scalar, deadline=None,
                 priority="interactive"):
        self.specs = specs
        self.net = net
        self.dev = dev
        self.future = future
        self.scalar = scalar
        self.deadline = deadline   # absolute time.monotonic(), or None
        self.priority = priority
        self.t_enq = time.monotonic()   # queue-wait telemetry anchor


class _SearchJob:
    """One queued :meth:`Session.submit_search` long-running job (the
    batch lane's bulk work: explore/deploy searches)."""

    __slots__ = ("fn", "future", "deadline", "label", "t_enq")

    def __init__(self, fn, future, deadline=None, label="search"):
        self.fn = fn
        self.future = future
        self.deadline = deadline
        self.label = label
        self.t_enq = time.monotonic()


# --------------------------------------------------------------------------
# the session
# --------------------------------------------------------------------------
class Session:
    """One front door for every MCCM evaluation mode.

    Construct once per process (optionally with a default board) and call
    :meth:`evaluate`, :meth:`explore`, :meth:`deploy` or :meth:`submit`;
    tables and compiled programs are shared across all of them.

    >>> ses = Session(get_board("zc706"))
    >>> ses.evaluate("{L1-Last:CE1-CE4}", net)            # scalar Metrics
    >>> ses.evaluate([spec_a, spec_b], net)               # metric arrays
    >>> ses.explore(net, n=100_000, strategy="search")    # DSE front
    >>> ses.deploy([net_a, net_b], n=4096)                # multinet front
    >>> ses.submit(specs, net).result()                   # queued/megabatched
    """

    def __init__(self, dev: DeviceSpec | None = None, *,
                 config: EvalConfig | None = None, **overrides):
        base = config if config is not None else EvalConfig()
        if overrides:
            base = replace(base, **overrides)
        self.config = base.resolved()
        if self.config.cache_dir:
            enable_persistent_compilation_cache(self.config.cache_dir)
        from .shard import EvalMesh
        #: the session's design-axis mesh; single-device meshes delegate
        #: to the exact single-device jits (zero extra compiles)
        self.mesh = EvalMesh(ndevices=self.config.mesh,
                             max_jits=self.config.max_cached_jits)
        self.default_device = dev
        self.stats = SessionStats()
        #: trips on repeated primary-backend faults; while open, calls
        #: degrade to ``fallback_backend`` with periodic recovery probes
        self.breaker = CircuitBreaker()
        # memoization has its own lock (held across check+build+count, so
        # the drain thread and callers can't race a duplicate build); the
        # condition variable below is the submit queue's only.  The table
        # memos are LRU-bounded (config.max_cached_tables per cache) so a
        # long-lived server under unbounded distinct keys stays
        # memory-bounded — evicted entries rebuild on next use,
        # bit-identically (tests/test_session_cache.py)
        self._table_lock = threading.Lock()
        bound = self.config.max_cached_tables
        self._net_tables = BoundedLRU(
            bound, on_evict=lambda *_:
            self.stats.bump("net_table_evictions"))
        self._dev_tables = BoundedLRU(
            bound, on_evict=lambda *_:
            self.stats.bump("device_table_evictions"))
        self._multi_tables = BoundedLRU(
            bound, on_evict=lambda *_:
            self.stats.bump("multi_table_evictions"))
        # schedule artifacts per (net, board, design-hash): small decoded
        # dataclasses, but keys churn with every distinct design — same
        # bound, same eviction-counter contract (docs/schedule.md)
        self._schedule_memo = BoundedLRU(
            bound, on_evict=lambda *_:
            self.stats.bump("schedule_evictions"))
        self._cv = threading.Condition()
        self._pending: list[_Request] = []
        self._worker: threading.Thread | None = None
        #: adaptive-linger arrival tracking (armed by config.linger_max_s)
        self._arrivals = ArrivalEstimator()
        # the batch lane's job queue: long searches run on their own
        # worker so the megabatch drain — the interactive lane — never
        # blocks behind a 100k-budget DSE (docs/serving.md)
        self._jobs: list[_SearchJob] = []
        self._job_cv = threading.Condition()
        self._job_worker: threading.Thread | None = None
        self._job_running = False
        self._closed = False

    # ---- lifecycle -------------------------------------------------------
    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        """Flush the submit queue and stop the background drain loop and
        the search-job worker.  Queued-but-unstarted search jobs are
        cancelled (``Future.cancel()``); a *running* job finishes — its
        checkpoint, when configured, is what makes killing the process
        instead lossless (docs/robustness.md).  Idempotent; the session's
        caches stay usable afterwards, only :meth:`submit` /
        :meth:`submit_search` are refused."""
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        with self._job_cv:
            cancelled, self._jobs = self._jobs, []
            self._job_cv.notify_all()
        for j in cancelled:
            j.future.cancel()
        if self._worker is not None:
            self._worker.join(timeout=60.0)
            self._worker = None
        if self._job_worker is not None:
            self._job_worker.join(timeout=600.0)
            self._job_worker = None
        self.drain()

    # ---- memoized tables -------------------------------------------------
    @staticmethod
    def _net_key(net: Network) -> tuple:
        # content fingerprint, not identity: two builds of the same zoo
        # entry share tables, while same-named custom nets don't collide.
        # The per-layer tuple is order-sensitive — layer order is
        # load-bearing for segmentation, so permuted nets must not alias.
        layers = hash(tuple((l.macs, l.weights_size, l.ifm_size,
                             l.ofm_size, l.residual) for l in net))
        return (net.name, len(net), net.total_macs, layers)

    def _device(self, dev: DeviceSpec | None) -> DeviceSpec:
        dev = dev if dev is not None else self.default_device
        if dev is None:
            raise ValueError("no device: pass dev= or construct the "
                             "Session with a default board")
        return dev

    def tables(self, net: Network, max_L: int | None = None) -> NetTables:
        """Memoized ``NetTables`` for ``net``, keyed by (net, bucketed
        max_L) — every evaluate/explore call on the same net reuses one
        traced pytree, so they also share one compiled program."""
        if isinstance(net, NetTables):
            return net
        L = len(net)
        bucket = bucket_max_L(L) if max_L is None \
            else (max_L if L <= max_L else bucket_max_L(L, base=max_L))
        key = self._net_key(net) + (bucket,)
        with self._table_lock:
            hit = self._net_tables.get(key)
            if hit is not None:
                self.stats.bump("net_table_hits")
                return hit
            with telemetry.span("session.net_table_build") as sp:
                sp.set_attr("net", net.name)
                sp.set_attr("max_L", bucket)
                built = make_tables(net, max_L=bucket)
            self._net_tables.put(key, built)
            self.stats.bump("net_table_builds")
            return built

    def device_tables(self, dev: DeviceSpec | None = None) -> DeviceTables:
        """Memoized ``DeviceTables`` for a board."""
        dev = self._device(dev)
        with self._table_lock:
            hit = self._dev_tables.get(dev)
            if hit is not None:
                self.stats.bump("device_table_hits")
                return hit
            with telemetry.span("session.device_table_build"):
                built = make_device_tables(dev)
            self._dev_tables.put(dev, built)
            self.stats.bump("device_table_builds")
            return built

    def multi_tables(self, nets, *, weights=None, slo_s=None,
                     max_m: int | None = None):
        """Memoized ``MultiNetTables`` for a model set (+ request weights
        and per-model SLOs) — what :meth:`deploy` evaluates against.  An
        explicit ``max_m`` wins over the config (deploy passes the search
        config's, matching the legacy joint_search semantics)."""
        from .multinet.joint_eval import make_multi_tables
        from .multinet.partition import DEFAULT_MAX_M

        if max_m is None:
            max_m = self.config.max_m or DEFAULT_MAX_M
        wkey = None if weights is None else tuple(
            float(w) for w in np.atleast_1d(np.asarray(weights, np.float64)))
        skey = None if slo_s is None else tuple(
            float(s) for s in np.atleast_1d(np.asarray(slo_s, np.float64)))
        key = (tuple(self._net_key(n) for n in nets), wkey, skey, max_m)
        with self._table_lock:
            hit = self._multi_tables.get(key)
            if hit is not None:
                self.stats.bump("multi_table_hits")
                return hit
            with telemetry.span("session.multi_table_build") as sp:
                sp.set_attr("models", len(list(nets)))
                built = make_multi_tables(list(nets), weights=weights,
                                          slo_s=slo_s, max_m=max_m)
            self._multi_tables.put(key, built)
            self.stats.bump("multi_table_builds")
            return built

    # ---- resilience ------------------------------------------------------
    def _resilient_call(self, call):
        """Run ``call(backend)`` under the session's fault policy:

        * input-shaped errors (parse/encode/shape problems) raise
          ``EvalError(INVALID_INPUT)`` immediately — retrying can't help;
        * backend faults retry the primary up to ``max_retries`` times
          with exponential backoff, feeding the circuit breaker;
        * past the retries (or with the breaker open, minus its periodic
          recovery probes) the call degrades to ``fallback_backend``.
        """
        cfg = self.config
        fallback = cfg.fallback_backend
        has_fallback = fallback is not None and fallback != cfg.backend
        if has_fallback and not self.breaker.allow_primary():
            self.stats.bump("degraded")
            telemetry.event("resilience.degrade",
                            {"reason": "breaker_open", "backend": fallback})
            return call(fallback)
        last = None
        for attempt in range(cfg.max_retries + 1):
            if attempt:
                self.stats.bump("retried")
                telemetry.event("resilience.retry", {"attempt": attempt})
                time.sleep(retry_delay(attempt))
            try:
                out = call(cfg.backend)
            except Exception as e:  # noqa: BLE001 — classified below
                if classify(e) != EvalError.BACKEND_FAULT:
                    raise wrap(e) from e
                self.breaker.record_failure()
                last = e
            else:
                self.breaker.record_success()
                return out
        if has_fallback:
            self.stats.bump("degraded")
            telemetry.event("resilience.degrade",
                            {"reason": "retries_exhausted",
                             "backend": fallback})
            try:
                return call(fallback)
            except Exception as e:  # noqa: BLE001
                raise wrap(e) from e
        raise wrap(last, EvalError.BACKEND_FAULT) from last

    def _search_backend(self) -> str:
        """Backend for the explore()/deploy() search loops: the primary,
        unless the breaker is open and a fallback exists (a whole search
        is too expensive to gamble on a recovery probe)."""
        cfg = self.config
        fb = cfg.fallback_backend
        if fb is not None and fb != cfg.backend and self.breaker.is_open:
            self.stats.bump("degraded")
            telemetry.event("resilience.degrade",
                            {"reason": "breaker_open_search",
                             "backend": fb})
            return fb
        return cfg.backend

    # ---- evaluation ------------------------------------------------------
    def _parse(self, design, net: Network,
               inter_segment_pipelining: bool) -> AcceleratorSpec:
        if isinstance(design, str):
            return parse(design, len(net),
                         inter_segment_pipelining=inter_segment_pipelining)
        return design

    def evaluate(self, designs, net: Network, dev: DeviceSpec | None = None,
                 *, inter_segment_pipelining: bool = True):
        """Evaluate design(s) of ``net`` on ``dev``, dispatching on input:

        * a single spec / notation string -> the scalar reference path,
          returning a full :class:`Metrics` (with per-segment detail) —
          bit-identical to the deprecated ``evaluate_design``;
        * a list/tuple of specs or strings -> the chunked batch path,
          returning ``{metric: np.ndarray}`` — bit-identical to the
          deprecated ``evaluate_specs``;
        * a ``DesignBatch`` -> the jitted hot path verbatim, returning
          ``{metric: jnp.ndarray}`` (arrays stay on device).

        ``inter_segment_pipelining`` applies to notation strings only
        (specs already carry the flag).
        """
        with telemetry.span("session.evaluate") as sp:
            out = self._evaluate(designs, net, dev,
                                 inter_segment_pipelining, sp)
        return out

    def _evaluate(self, designs, net, dev, inter_segment_pipelining, sp):
        dev = self._device(dev)
        if isinstance(designs, (str, AcceleratorSpec)):
            sp.set_attr("kind", "scalar")
            self.stats.bump("scalar_evals")
            try:
                m = _evaluate_design(
                    designs, net, dev,
                    inter_segment_pipelining=inter_segment_pipelining)
            except Exception as e:  # noqa: BLE001 — taxonomy boundary
                raise wrap(e) from e
            if not np.isfinite([m.latency_s, m.throughput_ips,
                                float(m.buffer_bytes)]).all():
                raise EvalError(EvalError.NONFINITE_METRICS,
                                "scalar evaluation produced non-finite "
                                "metrics")
            return m
        cfg = self.config
        if isinstance(designs, DesignBatch):
            from .dse.encoding import NC, validate_batch
            try:
                ok = validate_batch(designs, len(net), min_ces=1,
                                    max_ces=NC)
            except Exception as e:  # noqa: BLE001 — malformed arrays
                raise wrap(e, EvalError.INVALID_INPUT) from e
            if not ok.all():
                bad = np.nonzero(~ok)[0]
                raise EvalError(
                    EvalError.INVALID_INPUT,
                    f"{bad.size} invalid DesignBatch row(s), first at "
                    f"index {int(bad[0])} (non-canonical segments or CE "
                    f"count outside [1, {NC}])")
            sp.set_attr("kind", "design_batch")
            sp.set_attr("designs", designs.batch)
            self.stats.bump("batch_designs", designs.batch)
            return self._resilient_call(lambda b: evaluate_batch(
                designs, self.tables(net), self.device_tables(dev),
                fm_tile_rows=cfg.fm_tile_rows, backend=b,
                tile=cfg.tile, design_tile=cfg.design_tile, mesh=self.mesh))
        try:
            specs = [self._parse(d, net, inter_segment_pipelining)
                     for d in designs]
        except Exception as e:  # noqa: BLE001
            raise wrap(e, EvalError.INVALID_INPUT) from e
        if not specs:
            raise EvalError(EvalError.INVALID_INPUT,
                            "no designs to evaluate (empty list)")
        sp.set_attr("kind", "spec_list")
        sp.set_attr("designs", len(specs))
        self.stats.bump("batch_designs", len(specs))
        out = self._resilient_call(lambda b: _evaluate_specs(
            specs, net, self.device_tables(dev),
            cfg.chunk, tables=self.tables(net),
            backend=b, tile=cfg.tile,
            fm_tile_rows=cfg.fm_tile_rows,
            design_tile=cfg.design_tile, mesh=self.mesh))
        bad = nonfinite_keys(out)
        if bad:
            raise EvalError(EvalError.NONFINITE_METRICS,
                            f"non-finite metrics {bad}")
        return out

    def build(self, design, net: Network, dev: DeviceSpec | None = None,
              *, opts=None, inter_segment_pipelining: bool = True):
        """Build the :class:`ConcreteAccelerator` for a design (the object
        ``evaluate`` scores — same parse flags, so they always agree)."""
        return build_design(design, net, self._device(dev), opts,
                            inter_segment_pipelining=inter_segment_pipelining)

    # ---- DSE -------------------------------------------------------------
    def explore(self, net: Network, n: int = 100_000,
                dev: DeviceSpec | None = None, *, strategy: str = "random",
                family: str = "custom", seed: int = 0, chunk: int = 4096,
                objectives: tuple[str, ...] = DEFAULT_OBJECTIVES,
                config=None, refine: str | None = None):
        """Single-model DSE (random sweep or guided search) through the
        session's cached tables — bit-identical to the deprecated
        ``explore`` free function at equal arguments.

        ``refine="schedule"`` re-scores the final Pareto front with the
        per-CE temporal-mapping search (``docs/schedule.md``): the sweep
        itself still runs on the coarse model (the refinement can only
        lower latency, never invalidate a front member), and the result
        gains a ``refined`` dict with schedule-refined latency/access
        arrays aligned with ``front``.
        """
        from .dse.driver import _explore

        if refine not in (None, "schedule"):
            raise EvalError(EvalError.INVALID_INPUT,
                            f"unknown refine mode {refine!r} "
                            "(expected None or 'schedule')")
        self.stats.bump("explore_calls")
        with telemetry.span("session.explore") as sp:
            sp.set_attr("n", n)
            sp.set_attr("strategy", strategy)
            res = _explore(net, self._device(dev), n, family=family,
                           seed=seed, chunk=chunk, strategy=strategy,
                           objectives=objectives, config=config,
                           tables=self.tables(net),
                           backend=self._search_backend(), mesh=self.mesh)
            if refine == "schedule" and res.front.size:
                res.refined = self._refine_front(res, net, dev, sp)
            return res

    def _refine_front(self, res, net: Network, dev, sp) -> dict:
        """Schedule-refine a DSE result's Pareto front: one batched
        schedule search over the front designs (padded to the ladder
        bucket — no compile forks), returning front-aligned arrays."""
        from ..schedule.search import schedule_batch
        from .batch_eval import _bucket, _pad_rows

        dev = self._device(dev)
        cfg = self.config
        front = res.batch.take(np.asarray(res.front))
        nf = int(res.front.size)
        padded = _pad_rows(front, _bucket(nf, cfg.tile))
        with telemetry.span("session.schedule_front") as fsp:
            fsp.set_attr("designs", nf)
            out = self._resilient_call(lambda b: schedule_batch(
                padded, self.tables(net), self.device_tables(dev),
                fm_tile_rows=cfg.fm_tile_rows, backend=b, tile=cfg.tile,
                design_tile=cfg.design_tile))
        lat = np.asarray(out["ref_latency_s"])[:nf]
        coarse = np.asarray(out["coarse_latency_s"])[:nf]
        telemetry.count("schedule.candidates",
                        int(np.asarray(out["valid_l"])[:nf].sum())
                        * self._ncand())
        sp.set_attr("refined_front", nf)
        return {
            "latency_s": lat,
            "coarse_latency_s": coarse,
            "throughput_ips": np.asarray(out["ref_throughput_ips"])[:nf],
            "access_bytes": np.asarray(out["ref_access_bytes"])[:nf],
            "coarse_access_bytes":
                np.asarray(out["coarse_access_bytes"])[:nf],
            "saving_frac": np.where(coarse > 0.0,
                                    1.0 - lat / np.maximum(coarse, 1e-30),
                                    0.0),
        }

    @staticmethod
    def _ncand() -> int:
        from ..kernels.schedule_score import NCAND
        return NCAND

    def deploy(self, nets, n: int = 4096, dev: DeviceSpec | None = None, *,
               strategy: str = "search", seed: int = 0, chunk: int = 512,
               objectives: tuple[str, ...] | None = None,
               objective: str = "serving", config=None, weights=None,
               slo_s=None):
        """Multi-CNN co-scheduling DSE (spatial / temporal / hybrid arms)
        through the session's cached ``MultiNetTables`` — bit-identical to
        the deprecated ``joint_explore`` at equal arguments."""
        from .multinet.driver import _joint_explore
        from .multinet.search import JOINT_OBJECTIVES

        # the tables must carry the same weights/SLOs/max_m the search
        # will use, whether they arrive via config or via the keywords
        w = config.weights if config is not None else weights
        s = config.slo_s if config is not None else slo_s
        mm = config.max_m if config is not None else None
        mt = self.multi_tables(nets, weights=w, slo_s=s, max_m=mm)
        self.stats.bump("deploy_calls")
        with telemetry.span("session.deploy") as sp:
            sp.set_attr("n", n)
            sp.set_attr("models", len(list(nets)))
            sp.set_attr("strategy", strategy)
            return _joint_explore(
                list(nets), self._device(dev), n, strategy=strategy,
                seed=seed, chunk=chunk,
                objectives=JOINT_OBJECTIVES if objectives is None
                else objectives,
                objective=objective, config=config, weights=weights,
                slo_s=slo_s, mtables=mt, backend=self._search_backend(),
                mesh=self.mesh)

    # ---- bottleneck attribution (paper use case 2) -----------------------
    def explain(self, design, net: Network, dev: DeviceSpec | None = None,
                *, inter_segment_pipelining: bool = True,
                refine: str | None = None) -> dict:
        """Rank where a single design's time and off-chip traffic go.

        Evaluates ``design`` through the exact scalar path (full
        per-segment / per-layer / per-CE detail) and returns the
        :func:`repro.telemetry.report.bottleneck_report` dict: segments
        ranked by occupancy with compute/memory bound verdicts, the
        busiest CE, Fig. 6's memory-bound layers + idle fraction and
        Fig. 7's weights-vs-FMs access split — bit-identical to
        ``benchmarks/fig6_fig7_breakdown.py``'s formulas
        (``docs/observability.md`` walks through the output).

        ``refine="schedule"`` additionally runs the per-CE temporal-
        mapping search (:meth:`schedule`) and attaches its refined
        per-segment costs as a ``"schedule"`` section — coarse vs
        refined cycles per segment and the headline latency saving
        (``docs/schedule.md``).
        """
        from ..telemetry.report import bottleneck_report

        if not isinstance(design, (str, AcceleratorSpec)):
            raise EvalError(
                EvalError.INVALID_INPUT,
                "explain() takes one design (notation string or "
                "AcceleratorSpec); use evaluate() for batches")
        if refine not in (None, "schedule"):
            raise EvalError(EvalError.INVALID_INPUT,
                            f"unknown refine mode {refine!r} "
                            "(expected None or 'schedule')")
        with telemetry.span("session.explain") as sp:
            m = self._evaluate(design, net, dev,
                               inter_segment_pipelining, sp)
            art = None
            if refine == "schedule":
                art = self.schedule(
                    design, net, dev,
                    inter_segment_pipelining=inter_segment_pipelining)
            return bottleneck_report(m, schedule=art)

    def schedule(self, design, net: Network, dev: DeviceSpec | None = None,
                 *, inter_segment_pipelining: bool = True):
        """Per-CE temporal-mapping search under one design: refine the
        coarse MCCM estimate by choosing each layer's loop order, tile
        size and buffering from an explicit candidate plane, scored in
        the same cost terms (``docs/schedule.md``).

        Returns the JSON-serializable
        :class:`~repro.schedule.ScheduleArtifact` — refined vs coarse
        latency/traffic/energy, per-layer chosen mappings, per-CE buffer
        plans and per-segment costs.  Refined latency never exceeds the
        coarse estimate (candidate 0 IS the coarse mapping).  Artifacts
        memoize per (net, board, design) in a bounded LRU; the device
        search rides the same bucket-ladder shapes as ``evaluate``, so
        warm calls add zero compiles.
        """
        from ..schedule import build_artifact
        from ..schedule.search import schedule_specs
        from .dse.encoding import encode_specs
        from .notation import format_spec

        if not isinstance(design, (str, AcceleratorSpec)):
            raise EvalError(
                EvalError.INVALID_INPUT,
                "schedule() takes one design (notation string or "
                "AcceleratorSpec)")
        dev = self._device(dev)
        self.stats.bump("schedule_calls")
        try:
            spec = self._parse(design, net, inter_segment_pipelining)
            spec.validate(len(net))
            enc = encode_specs([spec], len(net))
        except Exception as e:  # noqa: BLE001
            raise wrap(e, EvalError.INVALID_INPUT) from e
        key = (self._net_key(net), dev) + tuple(
            np.asarray(a).tobytes() for a in enc.to_numpy())
        with self._table_lock:
            hit = self._schedule_memo.get(key)
        if hit is not None:
            self.stats.bump("schedule_hits")
            return hit
        cfg = self.config
        with telemetry.span("session.schedule") as sp:
            sp.set_attr("net", net.name)
            sp.set_attr("board", dev.name)
            out = self._resilient_call(lambda b: schedule_specs(
                [spec], net, self.device_tables(dev),
                tables=self.tables(net), backend=b, tile=cfg.tile,
                fm_tile_rows=cfg.fm_tile_rows,
                design_tile=cfg.design_tile))
            if not np.isfinite([float(out["ref_latency_s"][0]),
                                float(out["coarse_latency_s"][0])]).all():
                raise EvalError(EvalError.NONFINITE_METRICS,
                                "schedule search produced non-finite "
                                "latency")
            art = build_artifact(
                out, 0, net=net, board_name=dev.name,
                design_repr=format_spec(spec, len(net)),
                wordbytes=dev.wordbytes)
            sp.set_attr("candidates", art.n_candidates)
            sp.set_attr("n_refined", art.meta.get("n_refined", 0))
        telemetry.count("schedule.candidates", art.n_candidates)
        telemetry.count("schedule.searches")
        with self._table_lock:
            self._schedule_memo.put(key, art)
        self.stats.bump("schedule_builds")
        return art

    # ---- queued requests (the serve-many-users path) ---------------------
    def submit(self, designs, net: Network,
               dev: DeviceSpec | None = None, *,
               inter_segment_pipelining: bool = True,
               deadline_s: float | None = None,
               priority: str = "interactive") -> Future:
        """Queue an evaluation request; returns a ``Future``.

        A background drain loop collects everything queued within the
        linger window (fixed ``linger_s``, or arrival-rate adaptive when
        ``linger_max_s`` is set), coalesces it — tiny same-(net, board)
        requests merge into shared padded chunks, oversized requests
        split at the compiled chunk size — and megabatches it through ONE
        compiled program (all chunks pad to a shared ladder shape, so
        mixed CNNs × boards still reuse the same compile).  The future
        resolves to ``{metric: np.ndarray}`` over the submitted specs; a
        single spec/string resolves to ``{metric: float}``.

        ``priority`` is the request's lane: ``"interactive"`` requests
        are planned and delivered ahead of ``"batch"`` ones in every
        drain, so bulk traffic cannot starve point evaluations
        (docs/serving.md).

        Failure semantics (docs/robustness.md): malformed designs raise
        ``EvalError(INVALID_INPUT)`` here, synchronously; with
        ``max_queue`` set, an over-full queue raises
        ``EvalError(QUEUE_FULL)``; ``deadline_s`` (defaulting to the
        config's) fails the future with ``EvalError(DEADLINE_EXCEEDED)``
        if the result can't be delivered in time — a request never hangs.
        """
        scalar = isinstance(designs, (str, AcceleratorSpec))
        raw = [designs] if scalar else list(designs)
        with telemetry.span("session.submit") as sp:
            sp.set_attr("designs", len(raw))
            sp.set_attr("priority", priority)
            return self._submit(raw, net, dev, scalar,
                                inter_segment_pipelining, deadline_s,
                                priority)

    def _submit(self, raw, net, dev, scalar, inter_segment_pipelining,
                deadline_s, priority="interactive") -> Future:
        if priority not in PRIORITIES:
            raise EvalError(EvalError.INVALID_INPUT,
                            f"unknown priority {priority!r}; "
                            f"known: {PRIORITIES}")
        try:
            specs = [self._parse(d, net, inter_segment_pipelining)
                     for d in raw]
        except Exception as e:  # noqa: BLE001 — taxonomy boundary
            raise wrap(e, EvalError.INVALID_INPUT) from e
        if not specs:
            # reject here: an empty job inside a megabatch would fail the
            # whole batch's futures, not just this one
            raise EvalError(EvalError.INVALID_INPUT,
                            "no designs to submit (empty list)")
        cfg = self.config
        if deadline_s is None:
            deadline_s = cfg.deadline_s
        deadline = None if deadline_s is None \
            else time.monotonic() + deadline_s
        req = _Request(specs, net, self._device(dev), Future(), scalar,
                       deadline, priority)
        with self._cv:
            if self._closed:
                raise RuntimeError(
                    "session closed: submit() is refused after close() "
                    "(the drain loop is stopped; synchronous evaluate() "
                    "still works)")
            if cfg.max_queue is not None \
                    and len(self._pending) + len(self._jobs) \
                    >= cfg.max_queue:
                self.stats.bump("rejected")
                telemetry.event("resilience.rejected",
                                {"queue": len(self._pending)})
                raise EvalError(
                    EvalError.QUEUE_FULL,
                    f"submit queue full ({cfg.max_queue} pending "
                    f"requests); retry after the queue drains")
            self._arrivals.observe(time.monotonic())
            self._pending.append(req)
            telemetry.gauge("session.queue_depth", len(self._pending))
            if self._worker is None:
                self._worker = threading.Thread(
                    target=self._drain_loop, name="repro-session-drain",
                    daemon=True)
                self._worker.start()
            self._cv.notify_all()
        self.stats.bump("submits")
        return req.future

    # ---- the batch lane: long search jobs --------------------------------
    def submit_search(self, nets, n: int = 100_000,
                      dev: DeviceSpec | None = None, *,
                      deadline_s: float | None = None,
                      checkpoint_path: str | None = None,
                      checkpoint_interval: int = 8,
                      **kw) -> Future:
        """Queue a long DSE job — :meth:`explore` for a single ``Network``,
        :meth:`deploy` for a list — on the batch lane; returns a
        ``Future`` resolving to the search result.

        Jobs run FIFO on a dedicated worker thread, so the interactive
        megabatch drain never blocks behind a 100k-budget search; the
        evaluations inside the job still flow through the session's
        cached tables and compiled programs.  ``checkpoint_path`` makes a
        ``strategy="search"`` job preemptible: the search snapshots every
        ``checkpoint_interval`` generations and a resubmitted job (or a
        restarted server) resumes bit-identically from the snapshot
        (docs/robustness.md).  Admission control (``max_queue``) counts
        queued jobs; a job whose ``deadline_s`` passes while queued fails
        with ``DEADLINE_EXCEEDED`` without spending any search budget.
        """
        from .workload import Network as _Network

        is_single = isinstance(nets, (_Network, NetTables))
        kind = "explore" if is_single else "deploy"
        if checkpoint_path is not None:
            if kw.get("strategy", "random" if is_single else "search") \
                    != "search":
                raise EvalError(
                    EvalError.INVALID_INPUT,
                    "checkpoint_path requires strategy='search' (the "
                    "random sweep has no loop state to snapshot)")
            config = kw.get("config")
            if config is None:
                from .dse.search import SearchConfig
                from .multinet.search import MultinetSearchConfig
                config = SearchConfig() if is_single \
                    else MultinetSearchConfig()
                if "seed" in kw:
                    config = replace(config, seed=kw["seed"])
            kw["config"] = replace(config,
                                   checkpoint_path=checkpoint_path,
                                   checkpoint_interval=checkpoint_interval,
                                   resume=True)

        def job():
            if kind == "explore":
                return self.explore(nets, n, dev, **kw)
            return self.deploy(nets, n, dev, **kw)

        cfg = self.config
        deadline = None if deadline_s is None \
            else time.monotonic() + deadline_s
        j = _SearchJob(job, Future(), deadline, label=kind)
        with self._job_cv:
            if self._closed:
                raise RuntimeError(
                    "session closed: submit_search() is refused after "
                    "close()")
            if cfg.max_queue is not None \
                    and len(self._jobs) + len(self._pending) \
                    >= cfg.max_queue:
                self.stats.bump("rejected")
                telemetry.event("resilience.rejected",
                                {"queue": len(self._jobs),
                                 "lane": "batch"})
                raise EvalError(
                    EvalError.QUEUE_FULL,
                    f"search-job queue full ({cfg.max_queue} pending); "
                    f"retry after the queue drains")
            self._jobs.append(j)
            telemetry.gauge("session.job_queue_depth", len(self._jobs))
            if self._job_worker is None:
                self._job_worker = threading.Thread(
                    target=self._job_loop, name="repro-session-jobs",
                    daemon=True)
                self._job_worker.start()
            self._job_cv.notify_all()
        self.stats.bump("search_jobs")
        return j.future

    def _job_loop(self) -> None:
        while True:
            with self._job_cv:
                while not self._jobs and not self._closed:
                    self._job_cv.wait()
                if not self._jobs:        # closed and drained
                    return
                j = self._jobs.pop(0)
                self._job_running = True
            try:
                self._run_job(j)
            finally:
                with self._job_cv:
                    self._job_running = False
                    self._job_cv.notify_all()

    def _run_job(self, j: _SearchJob) -> None:
        if not j.future.set_running_or_notify_cancel():
            return
        if j.deadline is not None and time.monotonic() > j.deadline:
            self.stats.bump("deadline_missed")
            telemetry.event("resilience.deadline_missed",
                            {"where": "job_queued"})
            j.future.set_exception(EvalError(
                EvalError.DEADLINE_EXCEEDED,
                "deadline passed while the search job was queued"))
            return
        with telemetry.span("session.search_job") as sp:
            sp.set_attr("kind", j.label)
            telemetry.observe("session.job_queue_wait_s",
                              time.monotonic() - j.t_enq)
            try:
                out = j.fn()
            except BaseException as e:  # noqa: BLE001 — job isolation
                j.future.set_exception(wrap(e))
                if not isinstance(e, Exception):
                    raise
            else:
                j.future.set_result(out)

    def drain(self) -> int:
        """Synchronously megabatch everything currently queued (also what
        the background loop runs); returns the number of requests served.
        Interactive-lane requests are planned and delivered ahead of
        batch-lane ones (stable within a lane)."""
        with self._cv:
            reqs, self._pending = self._pending, []
        if reqs:
            reqs.sort(key=lambda r: PRIORITIES.index(r.priority))
            self._run_megabatch(reqs)
        return len(reqs)

    def _linger(self) -> float:
        """The next drain's linger window: fixed ``linger_s``, or the
        arrival-rate-adaptive policy when ``linger_max_s`` is armed
        (~2 observed inter-arrivals, capped — docs/serving.md)."""
        cfg = self.config
        if cfg.linger_max_s is None:
            return cfg.linger_s
        return self._arrivals.linger(cfg.linger_max_s)

    def _drain_loop(self) -> None:
        while True:
            with self._cv:
                while not self._pending and not self._closed:
                    self._cv.wait()
                if self._closed and not self._pending:
                    return
            # linger so concurrent submitters land in the same megabatch
            time.sleep(self._linger())
            self.drain()

    def _deliver(self, r: _Request, out: dict) -> None:
        if not r.future.set_running_or_notify_cancel():
            return
        if r.scalar:
            out = {k: float(v[0]) for k, v in out.items()}
        r.future.set_result(out)

    def _fail(self, r: _Request, exc: BaseException) -> None:
        if r.future.set_running_or_notify_cancel():
            r.future.set_exception(wrap(exc))

    def _expire(self, reqs: list[_Request]) -> list[_Request]:
        """Fail requests whose deadline already passed (DEADLINE_EXCEEDED)
        before spending any evaluation on them; returns the live rest."""
        now = time.monotonic()
        live = []
        for r in reqs:
            if r.deadline is not None and now > r.deadline:
                self.stats.bump("deadline_missed")
                telemetry.event("resilience.deadline_missed",
                                {"where": "queued"})
                self._fail(r, EvalError(
                    EvalError.DEADLINE_EXCEEDED,
                    "deadline passed while the request was queued"))
            else:
                live.append(r)
        return live

    def _finish(self, r: _Request, out: dict) -> None:
        """Finite-guard + deadline-check one request's result, then
        deliver: NaN/Inf rows fail THEIR future, not the megabatch, and
        strict deadlines refuse late delivery."""
        bad = nonfinite_keys(out)
        if bad:
            self._fail(r, EvalError(EvalError.NONFINITE_METRICS,
                                    f"non-finite metrics {bad}"))
            return
        if r.deadline is not None and time.monotonic() > r.deadline:
            self.stats.bump("deadline_missed")
            telemetry.event("resilience.deadline_missed",
                            {"where": "evaluated"})
            self._fail(r, EvalError(EvalError.DEADLINE_EXCEEDED,
                                    "deadline passed during evaluation"))
            return
        self.stats.bump("megabatch_requests")
        telemetry.observe("session.request_latency_s",
                          time.monotonic() - r.t_enq)
        self._deliver(r, out)

    def _eval_one(self, r: _Request, backend: str | None = None) -> dict:
        cfg = self.config
        return _evaluate_specs(r.specs, r.net, self.device_tables(r.dev),
                               cfg.chunk, tables=self.tables(r.net),
                               backend=backend or cfg.backend,
                               tile=cfg.tile,
                               fm_tile_rows=cfg.fm_tile_rows,
                               design_tile=cfg.design_tile, mesh=self.mesh)

    def _run_megabatch(self, reqs: list[_Request]) -> None:
        # the outer net: whatever goes wrong below, every future resolves
        # — a drain must never leave callers hanging
        try:
            self._run_megabatch_inner(reqs)
        except BaseException as e:  # noqa: BLE001
            for r in reqs:
                if not r.future.done():
                    self._fail(r, e)
            if not isinstance(e, Exception):   # KeyboardInterrupt etc.
                raise

    def _run_megabatch_inner(self, reqs: list[_Request]) -> None:
        with telemetry.span("session.megabatch") as sp:
            sp.set_attr("requests", len(reqs))
            self._run_megabatch_spanned(reqs, sp)

    def _run_megabatch_spanned(self, reqs: list[_Request], sp) -> None:
        cfg = self.config
        reqs = self._expire(reqs)
        if not reqs:
            return
        if telemetry.enabled():
            # per-request queue wait + batch shape, measured at the top
            # of the drain (docs/observability.md metric catalog)
            now = time.monotonic()
            for r in reqs:
                telemetry.observe("session.queue_wait_s", now - r.t_enq)
            telemetry.observe("session.megabatch_fill",
                              len(reqs), bounds=tuple(
                                  float(2 ** i) for i in range(16)))
            telemetry.gauge("session.megabatch_size", len(reqs))
            telemetry.gauge("session.linger_s", cfg.linger_s)
        # memoized tables for BOTH axes, built per request under its own
        # guard: one request's broken net/board fails ITS future only,
        # the rest still megabatch together
        ready: list[tuple[_Request, object, object]] = []
        for r in reqs:
            try:
                tab = self.tables(r.net)
                dtab = self.device_tables(r.dev)
            except Exception as e:  # noqa: BLE001
                self._fail(r, wrap(e, EvalError.INVALID_INPUT))
            else:
                ready.append((r, tab, dtab))
        if not ready:
            return
        if cfg.coalesce:
            jobs, tabs, scatter = self._coalesce_jobs(ready, sp)
        else:
            # one padded chunk per request (the pre-coalescing drain)
            jobs = [(r.specs, r.net, dtab) for r, _, dtab in ready]
            tabs = [tab for _, tab, _ in ready]
            scatter = None
        try:
            results = self._resilient_call(
                lambda b: _evaluate_specs_multi(
                    jobs, cfg.chunk, backend=b,
                    tile=cfg.tile, tables=tabs,
                    fm_tile_rows=cfg.fm_tile_rows,
                    design_tile=cfg.design_tile, mesh=self.mesh))
        except Exception:  # noqa: BLE001 — isolate the bad job(s)
            # one malformed request must not poison its co-queued peers:
            # retry per request so each future gets ITS OWN result/error
            for r, _, _ in ready:
                try:
                    out = self._resilient_call(
                        lambda b, r=r: self._eval_one(r, b))
                except Exception as e:  # noqa: BLE001
                    self._fail(r, e)
                else:
                    self._finish(r, out)
            return
        self.stats.bump("megabatches")
        if scatter is None:
            for (r, _, _), out in zip(ready, results):
                self._finish(r, out)
            return
        scatter(results)

    def _coalesce_jobs(self, ready, sp):
        """Plan the coalesced megabatch: merge-compatible requests (same
        memoized ``NetTables`` object + same board) pack into shared
        chunks, oversized requests split at the compiled chunk size
        (``core.coalesce``).  Returns ``(jobs, tabs, scatter)`` where
        ``jobs`` holds one ``(specs, net, dev)`` triple per chunk and
        ``scatter(results)`` slices the per-chunk metric arrays back to
        each request's future — every request answered exactly once, in
        its own spec order, NaN rows still failing only their request."""
        cfg = self.config
        nd = self.mesh.ndevices if self.mesh.is_sharded else 1
        keyed = [((id(tab), id(dtab)), len(r.specs))
                 for r, tab, dtab in ready]
        plan = plan_megabatch(keyed, cfg.chunk, cfg.tile, nd)
        by_key = {}
        for i, (key, _) in enumerate(keyed):
            by_key.setdefault(key, i)
        jobs, tabs = [], []
        for c in plan.chunks:
            specs = []
            for p in c.parts:
                specs.extend(ready[p.req][0].specs[p.lo:p.hi])
            lead = ready[by_key[c.group]]
            jobs.append((specs, lead[0].net, lead[0].dev))
            tabs.append(lead[1])
        self.stats.bump("coalesced_chunks", len(plan.chunks))
        if plan.merges:
            self.stats.bump("coalesced_merges", plan.merges)
        if plan.splits:
            self.stats.bump("coalesced_splits", plan.splits)
        sp.set_attr("chunks", len(plan.chunks))
        sp.set_attr("shared_pad", plan.shared_pad)

        def scatter(results):
            pieces: dict[int, list] = {i: [] for i in range(len(ready))}
            for c, out in zip(plan.chunks, results):
                off = 0
                for p in c.parts:
                    n = len(p)
                    pieces[p.req].append(
                        (p.lo, {k: v[off:off + n]
                                for k, v in out.items()}))
                    off += n
            for i, (r, _, _) in enumerate(ready):
                parts = sorted(pieces[i], key=lambda t: t[0])
                outs = [d for _, d in parts]
                if len(outs) == 1:
                    self._finish(r, outs[0])
                else:
                    self._finish(r, {k: np.concatenate(
                        [o[k] for o in outs]) for k in outs[0]})

        return jobs, tabs, scatter

    # ---- observability ---------------------------------------------------
    def compile_stats(self) -> dict[str, int]:
        """Compiled-program counts of every jitted entry point the session
        drives.  ``total`` is the compile-miss counter the cache-reuse
        tests assert on: warm calls must not move it."""
        import importlib

        from . import batch_eval

        # the package re-exports a `search` FUNCTION, shadowing the
        # submodule attribute — resolve the module explicitly
        dse_search = importlib.import_module(".dse.search", __package__)
        counts = {
            "evaluate_batch": batch_eval._evaluate_jit._cache_size(),
            "dse_step": sum(f._cache_size()
                            for f in dse_search._STEP_CACHE.values()),
        }
        try:
            from .multinet import joint_eval as je
            counts["joint_spatial"] = je._joint_spatial_jit._cache_size()
            counts["joint_temporal"] = je._joint_temporal_jit._cache_size()
            counts["joint_hybrid"] = je._joint_hybrid_jit._cache_size()
        except ImportError:  # pragma: no cover — multinet always ships
            pass
        try:
            from ..schedule import search as sched
            counts["schedule_batch"] = sched._schedule_jit._cache_size()
            counts["schedule_plane"] = sched._plane_jit._cache_size()
        except ImportError:  # pragma: no cover — schedule always ships
            pass
        from .shard import mesh_compile_counts
        for name, n in mesh_compile_counts().items():
            counts[f"mesh_{name}"] = n
        counts["total"] = sum(v for k, v in counts.items() if k != "total")
        # resilience counters ride along for one-stop observability; they
        # are NOT compile counts, so they stay out of `total` (and are all
        # zero on a clean run — the warm-round equality tests still hold)
        counts["rejected"] = self.stats.rejected
        counts["retried"] = self.stats.retried
        counts["degraded"] = self.stats.degraded
        counts["deadline_missed"] = self.stats.deadline_missed
        return counts

    def cache_stats(self) -> dict[str, dict[str, int]]:
        """Size / bound / eviction counters of every bounded cache the
        session owns: the three table memos plus the mesh's sharded-jit
        registry (``docs/serving.md``).  A long-lived server's memory
        guarantee is exactly ``size <= maxsize`` here."""
        with self._table_lock:
            out = {
                "net_tables": self._net_tables.stats(),
                "device_tables": self._dev_tables.stats(),
                "multi_tables": self._multi_tables.stats(),
                "schedule_artifacts": self._schedule_memo.stats(),
            }
        out["mesh_jits"] = {"size": len(self.mesh._jits),
                            "maxsize": self.mesh.max_jits,
                            "evictions": self.mesh.jit_evictions}
        return out

    def observability(self) -> dict:
        """One-stop report: compile counts, session counters, bounded-
        cache occupancy/evictions, breaker state and — when telemetry is
        enabled — the full metrics registry snapshot (counters/gauges/
        histograms with p50/p90/p99/p999), merged into one dict
        (``docs/observability.md``)."""
        return {
            "compile": self.compile_stats(),
            "stats": self.stats.as_dict(),
            "caches": self.cache_stats(),
            "breaker": {"open": self.breaker.is_open,
                        "trips": self.breaker.trips},
            "telemetry": telemetry.snapshot(),
        }


# --------------------------------------------------------------------------
# the process-wide default session
# --------------------------------------------------------------------------
_DEFAULT_LOCK = threading.Lock()
_DEFAULT: Session | None = None


def default_session(**overrides) -> Session:
    """The process-wide shared session (what benchmarks and examples use).

    Created on first call; ``overrides`` (EvalConfig fields or ``dev=``)
    apply only then — asking for different settings once it exists is an
    error, construct a private :class:`Session` instead."""
    global _DEFAULT
    with _DEFAULT_LOCK:
        if _DEFAULT is None:
            _DEFAULT = Session(**overrides)
        elif overrides:
            raise ValueError(
                "the default session already exists; construct "
                "Session(...) directly for different settings")
        return _DEFAULT
