"""The paper's multiple-CE accelerator notation (§III-B).

Grammar (1-based layer/CE indices in the surface syntax)::

    accel    := '{' entry (',' entry)* '}'
    entry    := layers ':' ces
    layers   := 'L' idx | 'L' idx '-' ('L'? idx | 'Last')
    ces      := 'CE' idx | 'CE' idx '-' 'CE' idx

Examples from the paper:
    Segmented    {L1-L4:CE1, L5-L6:CE2, L7-L9:CE3, L10-L12:CE4}
    SegmentedRR  {L1-Last:CE1-CE4}
    Hybrid       {L1:CE1, L2:CE2, L3:CE3, L4-Last:CE4}
"""
from __future__ import annotations

import re
from dataclasses import dataclass


@dataclass(frozen=True)
class SegmentSpec:
    """Layers [layer_lo, layer_hi] on CEs [ce_lo, ce_hi] (0-based, inclusive)."""

    layer_lo: int
    layer_hi: int
    ce_lo: int
    ce_hi: int

    @property
    def pipelined(self) -> bool:
        return self.ce_hi > self.ce_lo

    @property
    def n_layers(self) -> int:
        return self.layer_hi - self.layer_lo + 1

    @property
    def n_ces(self) -> int:
        return self.ce_hi - self.ce_lo + 1


@dataclass(frozen=True)
class AcceleratorSpec:
    name: str
    segments: tuple[SegmentSpec, ...]
    inter_segment_pipelining: bool = True

    @property
    def n_ces(self) -> int:
        return max(s.ce_hi for s in self.segments) + 1

    def validate(self, n_layers: int) -> None:
        cover = []
        for s in self.segments:
            if not (0 <= s.layer_lo <= s.layer_hi < n_layers):
                raise ValueError(f"segment {s} out of range for {n_layers} layers")
            if s.ce_lo > s.ce_hi or s.ce_lo < 0:
                raise ValueError(f"bad CE range in {s}")
            cover.extend(range(s.layer_lo, s.layer_hi + 1))
        if cover != list(range(n_layers)):
            raise ValueError(
                "segments must cover all layers exactly once, in order "
                f"(got {len(cover)} assignments for {n_layers} layers)"
            )


_ENTRY = re.compile(
    r"^L(?P<lo>\d+)(?:-(?:L?(?P<hi>\d+)|(?P<last>Last)))?"
    r":CE(?P<clo>\d+)(?:-CE(?P<chi>\d+))?$",
    re.IGNORECASE,
)


def parse(text: str, n_layers: int, name: str = "custom",
          inter_segment_pipelining: bool = True) -> AcceleratorSpec:
    """Parse the paper's notation into an AcceleratorSpec."""
    body = text.strip()
    if body.startswith("{") and body.endswith("}"):
        body = body[1:-1]
    segments = []
    for raw in body.split(","):
        entry = raw.strip().replace(" ", "")
        if not entry:
            continue
        m = _ENTRY.match(entry)
        if not m:
            raise ValueError(f"cannot parse entry {raw!r}")
        lo = int(m.group("lo")) - 1
        if m.group("last"):
            hi = n_layers - 1
        elif m.group("hi"):
            hi = int(m.group("hi")) - 1
        else:
            hi = lo
        clo = int(m.group("clo")) - 1
        chi = int(m.group("chi")) - 1 if m.group("chi") else clo
        segments.append(SegmentSpec(lo, hi, clo, chi))
    spec = AcceleratorSpec(
        name=name,
        segments=tuple(segments),
        inter_segment_pipelining=inter_segment_pipelining,
    )
    spec.validate(n_layers)
    return spec


def format_spec(spec: AcceleratorSpec, n_layers: int | None = None) -> str:
    """Inverse of :func:`parse` (layer/CE indices back to 1-based)."""
    parts = []
    for s in spec.segments:
        if n_layers is not None and s.layer_hi == n_layers - 1 and s.layer_lo != s.layer_hi:
            layers = f"L{s.layer_lo + 1}-Last"
        elif s.layer_lo == s.layer_hi:
            layers = f"L{s.layer_lo + 1}"
        else:
            layers = f"L{s.layer_lo + 1}-L{s.layer_hi + 1}"
        ces = f"CE{s.ce_lo + 1}" if not s.pipelined else f"CE{s.ce_lo + 1}-CE{s.ce_hi + 1}"
        parts.append(f"{layers}:{ces}")
    return "{" + ", ".join(parts) + "}"
