"""MCCM core: the paper's analytical cost model (Eqs. 1-9) and builder."""
from .accelerator import ConcreteAccelerator, Metrics, SegmentMetrics, evaluate
from .blocks import (
    CE,
    BlockResult,
    LayerResult,
    best_parallelism,
    eval_pipelined,
    eval_single_ce,
    layer_cycles,
    layer_utilization,
    pipelined_min_buffer,
    single_ce_min_buffer,
)
from .builder import BuilderOptions, build
from .device import DeviceSpec, mib
from .evaluator import build_design, evaluate_design
from .notation import AcceleratorSpec, SegmentSpec, format_spec, parse
from .workload import DIMS, ConvLayer, Network, make_network

__all__ = [
    "CE",
    "DIMS",
    "AcceleratorSpec",
    "BlockResult",
    "BuilderOptions",
    "ConcreteAccelerator",
    "ConvLayer",
    "DeviceSpec",
    "LayerResult",
    "Metrics",
    "Network",
    "SegmentMetrics",
    "SegmentSpec",
    "best_parallelism",
    "build",
    "build_design",
    "evaluate",
    "evaluate_design",
    "eval_pipelined",
    "eval_single_ce",
    "format_spec",
    "layer_cycles",
    "layer_utilization",
    "make_network",
    "mib",
    "parse",
    "pipelined_min_buffer",
    "single_ce_min_buffer",
]
