"""MCCM core: the paper's analytical cost model (Eqs. 1-9) and builder."""
from .accelerator import ConcreteAccelerator, Metrics, SegmentMetrics, evaluate
from .blocks import (
    CE,
    BlockResult,
    LayerResult,
    best_parallelism,
    eval_pipelined,
    eval_single_ce,
    layer_cycles,
    layer_utilization,
    pipelined_min_buffer,
    single_ce_min_buffer,
)
from .builder import BuilderOptions, build
from .device import DeviceSpec, mib
from .evaluator import build_design, evaluate_design
from .notation import AcceleratorSpec, SegmentSpec, format_spec, parse
from .workload import DIMS, ConvLayer, Network, make_network

# The vectorized layer (dse package + batch_eval) re-exports lazily via
# PEP 562: it pulls in jax (~0.7 s), which scalar-model consumers of this
# package never need.
_LAZY = {name: ".dse" for name in (
    "DesignBatch", "DSEResult", "ParetoArchive", "SearchConfig",
    "SearchResult", "decode_design", "encode_specs", "explore", "pareto",
    "sample_custom", "sample_mixed", "search", "validate_batch")}
_LAZY.update({name: ".batch_eval" for name in (
    "evaluate_batch", "evaluate_specs", "evaluate_specs_multi",
    "make_tables")})
_LAZY.update({name: ".session" for name in (
    "EvalConfig", "Session", "SessionStats", "default_session")})


def __getattr__(name):
    if name in _LAZY:
        import importlib
        mod = importlib.import_module(_LAZY[name], __name__)
        value = getattr(mod, name)
        globals()[name] = value        # cache for subsequent lookups
        return value
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "CE",
    "DIMS",
    "AcceleratorSpec",
    "BlockResult",
    "BuilderOptions",
    "ConcreteAccelerator",
    "ConvLayer",
    "DSEResult",
    "DesignBatch",
    "DeviceSpec",
    "EvalConfig",
    "Session",
    "SessionStats",
    "LayerResult",
    "Metrics",
    "Network",
    "ParetoArchive",
    "SearchConfig",
    "SearchResult",
    "SegmentMetrics",
    "SegmentSpec",
    "best_parallelism",
    "build",
    "build_design",
    "decode_design",
    "default_session",
    "encode_specs",
    "evaluate",
    "evaluate_batch",
    "evaluate_design",
    "evaluate_specs",
    "evaluate_specs_multi",
    "eval_pipelined",
    "eval_single_ce",
    "explore",
    "format_spec",
    "layer_cycles",
    "layer_utilization",
    "make_network",
    "make_tables",
    "mib",
    "pareto",
    "parse",
    "pipelined_min_buffer",
    "sample_custom",
    "sample_mixed",
    "search",
    "single_ce_min_buffer",
    "validate_batch",
]
