"""MCCM reproduction — analytical cost model for multiple-CE CNN
accelerators, vectorized in JAX.

The supported entry point is the session front door::

    from repro.api import Session

(also re-exported lazily here: ``repro.Session``).  Subsystems live under
``repro.core`` (model, batch evaluator, DSE, multinet), ``repro.kernels``
(fused parallelism-search kernel), ``repro.cnn`` / ``repro.fpga`` (the
workload and board zoos).  See README.md and docs/api.md.
"""
from __future__ import annotations

# Everything re-exports lazily (PEP 562): `import repro` stays free of the
# jax import cost until a session (or the core package) is actually used.
_LAZY = {
    "EvalConfig": ".core.session",
    "Session": ".core.session",
    "SessionStats": ".core.session",
    "default_session": ".core.session",
}

# subpackages resolvable as attributes without eager import
_LAZY_MODULES = {"telemetry": ".telemetry"}


def __getattr__(name):
    if name in _LAZY:
        import importlib
        value = getattr(importlib.import_module(_LAZY[name], __name__), name)
        globals()[name] = value        # cache for subsequent lookups
        return value
    if name in _LAZY_MODULES:
        import importlib
        value = importlib.import_module(_LAZY_MODULES[name], __name__)
        globals()[name] = value
        return value
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = ["EvalConfig", "Session", "SessionStats", "default_session",
           "telemetry"]
