"""Step builders: (arch × shape × mesh × plan) -> lowered-ready jitted fns.

One place that knows how to assemble a *distributed* train / prefill /
decode step: model api + optimizer + in/out shardings.  Used by

* ``launch/dryrun.py`` — ``.lower(**ShapeDtypeStructs).compile()`` proof;
* ``launch/train.py`` / ``launch/serve.py`` — the real drivers;
* ``benchmarks/`` and the §Perf hillclimb harness.

Shape convention (assignment brief): ``decode_*`` / ``long_*`` cells lower
``serve_step`` — one new token against a KV cache of ``seq_len`` — not
``train_step``; ``prefill_*`` cells lower the prompt pass.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig, ShapeSpec
from ..models import registry as model_registry
from ..models.runtime import Runtime
from ..train.optimizer import AdamW, make_optimizer
from ..train.train_step import TrainState, make_train_step
from . import plans as PL

Pytree = Any


@dataclass
class BuiltStep:
    """A jitted step plus everything needed to lower or run it."""

    kind: str                  # train | prefill | decode
    fn: Callable               # jitted
    arg_specs: tuple           # ShapeDtypeStruct pytrees, positional
    in_shardings: tuple
    plan: PL.ParallelPlan
    rt: Runtime
    cfg: ModelConfig
    shape: ShapeSpec

    def lower(self):
        return self.fn.lower(*self.arg_specs)


def _named(tree: Pytree, mesh) -> Pytree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))


def make_optimizer_for(plan: PL.ParallelPlan, cfg: ModelConfig) -> AdamW:
    return make_optimizer(
        "adamw",
        state_dtype=plan.opt_state_dtype,
        factored=plan.opt_factored,
        momentum=plan.opt_momentum,
    )


def build_train(cfg: ModelConfig, shape: ShapeSpec, mesh,
                plan: PL.ParallelPlan | None = None) -> BuiltStep:
    plan = plan or PL.default_plan(cfg, shape, mesh)
    rt = plan.runtime(mesh)
    api = model_registry.get_model(cfg)
    opt = make_optimizer_for(plan, cfg)
    step = make_train_step(api, rt, opt, accum=plan.accum)

    # ---- specs (no allocation) ----
    params_sds = model_registry.param_specs(cfg)
    opt_sds = jax.eval_shape(opt.init, params_sds)
    state_sds = TrainState(params=params_sds, opt=opt_sds,
                           step=jax.ShapeDtypeStruct((), jnp.int32))
    batch_sds = model_registry.input_specs(cfg, shape)

    # ---- shardings ----
    p_specs = PL.sanitize_pspecs(PL.param_pspecs(params_sds, plan),
                                 params_sds, mesh)
    o_specs = PL.sanitize_pspecs(PL.opt_pspecs(opt_sds, p_specs, plan),
                                 opt_sds, mesh)
    state_specs = TrainState(params=p_specs, opt=o_specs, step=P())
    b_specs = PL.batch_pspecs(batch_sds, plan)
    in_sh = (_named(state_specs, mesh), _named(b_specs, mesh))
    out_sh = (in_sh[0], None)  # metrics: let XLA replicate

    jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(0,))
    return BuiltStep("train", jitted, (state_sds, batch_sds), in_sh,
                     plan, rt, cfg, shape)


def build_prefill(cfg: ModelConfig, shape: ShapeSpec, mesh,
                  plan: PL.ParallelPlan | None = None) -> BuiltStep:
    plan = plan or PL.default_plan(cfg, shape, mesh)
    rt = plan.runtime(mesh)
    api = model_registry.get_model(cfg)

    def prefill_fn(params, batch):
        return api.prefill(params, batch, rt)

    params_sds = model_registry.param_specs(cfg)
    batch_sds = model_registry.input_specs(cfg, shape)
    p_specs = PL.sanitize_pspecs(PL.param_pspecs(params_sds, plan),
                                 params_sds, mesh)
    b_specs = PL.batch_pspecs(batch_sds, plan)
    in_sh = (_named(p_specs, mesh), _named(b_specs, mesh))
    jitted = jax.jit(prefill_fn, in_shardings=in_sh)
    return BuiltStep("prefill", jitted, (params_sds, batch_sds), in_sh,
                     plan, rt, cfg, shape)


def build_decode(cfg: ModelConfig, shape: ShapeSpec, mesh,
                 plan: PL.ParallelPlan | None = None) -> BuiltStep:
    """serve_step: one new token with a KV cache of seq_len."""
    plan = plan or PL.default_plan(cfg, shape, mesh)
    rt = plan.runtime(mesh)
    api = model_registry.get_model(cfg)

    def decode_fn(params, cache, tokens):
        return api.decode_step(params, cache, tokens, rt)

    params_sds = model_registry.param_specs(cfg)
    cache_sds = model_registry.cache_specs(cfg, shape, rt)
    tok_sds = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)

    p_specs = PL.sanitize_pspecs(PL.param_pspecs(params_sds, plan),
                                 params_sds, mesh)
    c_specs = PL.sanitize_pspecs(PL.cache_pspecs(cache_sds, plan, cfg, mesh),
                                 cache_sds, mesh)
    t_spec = P(plan.dp_axes or None, None)
    in_sh = (_named(p_specs, mesh), _named(c_specs, mesh),
             NamedSharding(mesh, t_spec))
    out_sh = (None, in_sh[1])  # cache stays sharded in place
    jitted = jax.jit(decode_fn, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(1,))
    return BuiltStep("decode", jitted, (params_sds, cache_sds, tok_sds),
                     in_sh, plan, rt, cfg, shape)


def build_step(cfg: ModelConfig, shape: ShapeSpec, mesh,
               plan: PL.ParallelPlan | None = None) -> BuiltStep:
    """Dispatch on the cell kind (train / prefill / decode)."""
    if shape.kind == "train":
        return build_train(cfg, shape, mesh, plan)
    if shape.kind == "prefill":
        return build_prefill(cfg, shape, mesh, plan)
    return build_decode(cfg, shape, mesh, plan)
