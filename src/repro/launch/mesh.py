"""Production mesh construction.

Functions, not module-level constants, so importing this module never
touches jax device state.  Device discovery is shared with
``repro.core.shard``: set ``REPRO_MESH_DEVICES=N`` (the one supported
env-var path, see docs/perf.md) and import repro before first jax use —
on CPU hosts the host platform is force-split into N devices
automatically; callers never craft ``XLA_FLAGS`` by hand.  (The old
dry-run path that exported ``--xla_force_host_platform_device_count``
manually still works but is subsumed by the env var.)
"""
from __future__ import annotations

import jax

try:                                # jax >= 0.5 explicit-sharding API
    from jax.sharding import AxisType
except ImportError:                 # older jax: meshes are Auto by default
    AxisType = None


def _mesh(shape, axes, devices=None):
    if AxisType is None:
        if devices is not None:
            import numpy as np
            from jax.sharding import Mesh
            return Mesh(np.asarray(devices).reshape(shape), axes)
        return jax.make_mesh(shape, axes)
    if devices is not None:
        import numpy as np
        from jax.sharding import Mesh
        return Mesh(np.asarray(devices).reshape(shape), axes,
                    axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod (TPU v5e-256); 2 pods when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_mesh_spec(data: int, model: int, pod: int = 1):
    """Arbitrary mesh for DSE / hillclimbing (device count permitting)."""
    if pod > 1:
        return _mesh((pod, data, model), ("pod", "data", "model"))
    return _mesh((data, model), ("data", "model"))


def make_host_mesh(ndevices: int | None = None):
    """Whatever the current host offers (tests: 1 CPU device).

    Reuses :class:`repro.core.shard.EvalMesh` device discovery, so the
    resolution order is: explicit ``ndevices``, then
    ``REPRO_MESH_DEVICES``, then every visible device (requests beyond
    the visible count clamp)."""
    from ..core.shard import EvalMesh
    em = EvalMesh(ndevices=ndevices)
    return _mesh((em.ndevices, 1), ("data", "model"), devices=em.devices)
