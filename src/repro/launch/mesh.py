"""Production mesh construction.

Functions, not module-level constants, so importing this module never
touches jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import (see dryrun.py); smoke tests and benches see the real single device.
"""
from __future__ import annotations

import jax

try:                                # jax >= 0.5 explicit-sharding API
    from jax.sharding import AxisType
except ImportError:                 # older jax: meshes are Auto by default
    AxisType = None


def _mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod (TPU v5e-256); 2 pods when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_mesh_spec(data: int, model: int, pod: int = 1):
    """Arbitrary mesh for DSE / hillclimbing (device count permitting)."""
    if pod > 1:
        return _mesh((pod, data, model), ("pod", "data", "model"))
    return _mesh((data, model), ("data", "model"))


def make_host_mesh():
    """Whatever the current host offers (tests: 1 CPU device)."""
    n = len(jax.devices())
    return _mesh((n, 1), ("data", "model"))
