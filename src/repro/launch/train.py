"""End-to-end training driver with fault tolerance and elastic scaling.

Production behaviours exercised here (CPU-scaled, same code paths a pod
would run):

* checkpoint/restart — atomic committed checkpoints every ``--ckpt-every``
  steps; on start the driver restores the latest committed step and the
  data pipeline regenerates the exact stream from it (bitwise-resumable);
* crash injection — ``--crash-at N`` kills the process mid-run (between a
  step and its checkpoint) to prove restart recovers;
* elastic scaling — the checkpoint stores logical arrays; restoring under
  a different mesh/plan re-shards via device_put (``--dp/--tp`` may differ
  across restarts);
* straggler mitigation — per-step wall times feed an EWMA; steps slower
  than ``--straggler-factor``× the EWMA are logged with the offending
  step's metrics (at pod scale this signal drives re-slicing; here it
  drives the log + a counter the tests assert on);
* gradient compression — ``--compress`` switches to the int8
  error-feedback DDP step (shard_map path).

Usage (CPU smoke):
    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --reduced --steps 40 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp
from ..compat import shard_map

from ..configs import SHAPES, get_config
from ..configs.base import ShapeSpec
from ..data.pipeline import Pipeline
from ..models import registry as model_registry
from ..train import checkpoint as ckpt
from ..train.optimizer import make_optimizer
from ..train.train_step import (TrainState, init_residuals,
                                make_compressed_train_step, make_train_step)
from . import plans as PL
from .mesh import make_host_mesh, make_mesh_spec


def build(cfg, shape, mesh, plan, opt, accum=1, compress=False):
    rt = plan.runtime(mesh)
    api = model_registry.get_model(cfg)
    if compress:
        dp_axis = plan.dp_axes[0] if plan.dp_axes else "data"
        step = make_compressed_train_step(
            api, rt, opt, axis=dp_axis, n_shards=mesh.shape[dp_axis])
    else:
        step = make_train_step(api, rt, opt, accum=accum)
    return api, rt, step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--crash-at", type=int, default=None)
    ap.add_argument("--straggler-factor", type=float, default=3.0)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--dp", type=int, default=None)
    ap.add_argument("--tp", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    shape = SHAPES.get(args.shape) or ShapeSpec(
        args.shape, "train", args.seq, args.batch)
    if args.reduced:
        shape = ShapeSpec("train_smoke", "train", args.seq, args.batch)

    if args.dp or args.tp:
        mesh = make_mesh_spec(args.dp or 1, args.tp or 1)
    else:
        mesh = make_host_mesh()
    plan = PL.default_plan(cfg, shape, mesh)
    opt = make_optimizer("adamw", peak_lr=args.lr, warmup=20,
                         total_steps=max(args.steps, 100),
                         state_dtype=plan.opt_state_dtype,
                         factored=plan.opt_factored,
                         momentum=plan.opt_momentum)
    api, rt, step = build(cfg, shape, mesh, plan, opt,
                          accum=plan.accum, compress=args.compress)

    # ---- init or restore ---------------------------------------------------
    with mesh:
        state = TrainState(params=api.init(jax.random.key(0)),
                           opt=opt.init(api.init(jax.random.key(0))),
                           step=jnp.zeros((), jnp.int32))
        residuals = init_residuals(state.params) if args.compress else None
        start = 0
        if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
            state = ckpt.restore(args.ckpt_dir, jax.eval_shape(lambda: state))
            start = int(state.step)
            print(f"[restore] resumed from committed step {start} "
                  f"(mesh {dict(mesh.shape)})")

        jit_step = jax.jit(step, donate_argnums=(0,)) if not args.compress \
            else None
        if args.compress:
            from jax.sharding import PartitionSpec as P
            dp_axis = plan.dp_axes[0] if plan.dp_axes else "data"
            jit_step = jax.jit(
                shard_map(
                    step, mesh=mesh,
                    in_specs=(P(), P(), P(dp_axis)),
                    out_specs=(P(), P(), P()),
                    check_vma=False),
                donate_argnums=(0,))

        pipe = Pipeline(cfg, shape, start_step=start, prefetch=2)
        it = iter(pipe)
        ewma, stragglers = None, 0
        t_run = time.time()
        try:
            for i in range(start, args.steps):
                _, batch = next(it)
                t0 = time.time()
                if args.compress:
                    state, residuals, metrics = jit_step(state, residuals,
                                                         batch)
                else:
                    state, metrics = jit_step(state, batch)
                loss = float(metrics["loss"])
                dt = time.time() - t0
                if i > start + 1:  # skip compile step
                    ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
                    if ewma and dt > args.straggler_factor * ewma:
                        stragglers += 1
                        print(f"[straggler] step {i}: {dt:.3f}s vs "
                              f"EWMA {ewma:.3f}s")
                if i % args.log_every == 0 or i == args.steps - 1:
                    print(f"step {i:5d}  loss {loss:.4f}  "
                          f"gnorm {float(metrics['grad_norm']):.2f}  "
                          f"{dt*1e3:.0f} ms")
                if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
                    path = ckpt.save(args.ckpt_dir, i + 1, state,
                                     extra={"arch": cfg.name,
                                            "mesh": dict(mesh.shape),
                                            "plan": plan.name})
                    print(f"[ckpt] committed step {i+1} -> {path}")
                if args.crash_at is not None and i + 1 >= args.crash_at:
                    print(f"[crash] simulated failure after step {i+1}",
                          flush=True)
                    os._exit(42)
        finally:
            pipe.close()
        total = time.time() - t_run
        print(f"done: {args.steps - start} steps in {total:.1f}s; "
              f"final loss {loss:.4f}; stragglers {stragglers}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
