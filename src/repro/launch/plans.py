"""Parallelism plans: how an architecture is laid out on a mesh.

A :class:`ParallelPlan` is the TPU analogue of the paper's *CE arrangement*:
it decides which mesh axes carry data/tensor/expert parallelism, whether
parameters are FSDP-sharded, the remat policy, and the MoE dispatch
strategy.  ``repro.tpu.cost_model`` evaluates plans analytically (the MCCM
adaptation); this module materialises one into concrete
``jax.sharding.NamedSharding`` pytrees for pjit.

Sharding rules are *suffix-matched* on parameter paths, with leading ``None``
padding for scan-stacked leading axes — one table covers every family.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig, ShapeSpec
from ..models.runtime import Runtime

Pytree = Any


@dataclass(frozen=True)
class ParallelPlan:
    name: str = "default"
    dp_axes: tuple[str, ...] = ("data",)     # batch axes
    tp_axis: str | None = "model"            # tensor parallelism
    fsdp_axes: tuple[str, ...] = ()          # ZeRO-3 param sharding axes
    ep_axis: str | None = None               # expert parallelism (MoE)
    moe_impl: str = "local"                  # local | ep | ep_a2a
    seq_shard_cache: bool = False            # shard KV cache on sequence
    remat: bool = True
    remat_group: int = 1                     # layers per remat block
    act_shard: str = "none"                  # none | seq (Megatron-SP style)
    loss_chunk: int = 512
    attn_mode: str = "auto"
    accum: int = 1                           # gradient-accumulation steps
    # optimizer memory policy (per-plan: the 1T cell needs factored+bf16)
    opt_state_dtype: str = "float32"
    opt_factored: bool = False
    opt_momentum: bool = True

    def runtime(self, mesh) -> Runtime:
        return Runtime(
            mesh=mesh,
            dp_axes=tuple(a for a in self.dp_axes if a in mesh.shape),
            tp_axis=self.tp_axis,
            ep_axis=self.ep_axis or self.tp_axis,
            moe_impl=self.moe_impl,
            attn_mode=self.attn_mode,
            remat=self.remat,
            remat_group=self.remat_group,
            act_shard=self.act_shard,
            loss_chunk=self.loss_chunk,
        )


def default_plan(cfg: ModelConfig, shape: ShapeSpec, mesh) -> ParallelPlan:
    """Baseline plan per (arch x shape x mesh) — the paper-faithful starting
    point that §Perf hillclimbs from.

    Train defaults are ZeRO-3 everywhere (params+opt sharded over dp): the
    dominant HBM term at 4k×256 is optimizer state, and replicating it fits
    almost no cell.  Deep/wide nets additionally get sequence-sharded
    activations (act_shard='seq') and grouped remat so the saved residuals
    term stays sub-GiB/chip (derivation in EXPERIMENTS.md §Dry-run)."""
    axes = list(mesh.shape.keys())
    dp = tuple(a for a in ("pod", "data") if a in axes)
    tp = "model" if "model" in axes else None
    kw: dict = dict(
        name=f"{cfg.name}:{shape.name}:baseline",
        dp_axes=dp, tp_axis=tp,
    )
    if cfg.n_experts:
        # a2a dispatch: tokens stay S-sharded over the EP axis, so the
        # (tokens·k, d) dispatch/combine buffers shrink by the EP width —
        # the psum variant ("ep") replicates tokens over EP and is kept as
        # the ablation baseline (EXPERIMENTS.md §Perf).
        kw.update(ep_axis=tp, moe_impl="ep_a2a")
    if shape.kind == "train":
        kw.update(fsdp_axes=dp)                       # ZeRO-3 default
        if tp and cfg.d_model * shape.tokens * 2 > 64e9:
            kw.update(act_shard="seq")                # big residual stream
        if cfg.n_layers >= 32:
            kw.update(remat_group=4)                  # deep stacks
    else:
        kw.update(remat=False, loss_chunk=0)
        big = cfg.param_count() * 2 > 8e9             # >8 GB of bf16 params
        if big:
            kw.update(fsdp_axes=dp)                   # weights won't replicate
    if cfg.name == "kimi-k2-1t-a32b":
        # 1T params: factored second moment, bf16 state, no momentum buffer —
        # params+grads alone are 4.2 TB of the 4.4 TB single-pod HBM.
        # remat_group stays 1: grouped remat keeps g layers of *gathered
        # expert weights* live in the group backward, which dwarfs the
        # residual saving for MoE (measured 48→118 GiB temp, §Dry-run).
        kw.update(opt_factored=True, opt_state_dtype="bfloat16",
                  opt_momentum=False, fsdp_axes=dp)
        if shape.kind == "train":
            kw.update(act_shard="seq", remat_group=1)
    if shape.name == "long_500k":
        kw.update(dp_axes=(), seq_shard_cache=True)
    return ParallelPlan(**kw)


# --------------------------------------------------------------------------
# parameter sharding rules (suffix-matched)
# --------------------------------------------------------------------------
# symbols: "tp" -> plan.tp_axis, "fsdp" -> plan.fsdp_axes, "ep" -> plan.ep_axis
_RULES: tuple[tuple[str, tuple], ...] = (
    ("embed/table", ("tp", "fsdp")),
    ("embed/pos", (None, None)),
    ("head/w", ("fsdp", "tp")),
    ("attn/wq", ("fsdp", "tp")),
    ("attn/wk", ("fsdp", "tp")),
    ("attn/wv", ("fsdp", "tp")),
    ("attn/wo", ("tp", "fsdp")),
    ("attn/bq", ("tp",)),
    ("attn/bk", ("tp",)),
    ("attn/bv", ("tp",)),
    ("moe/router", (None, None)),
    ("moe/wg", ("ep", "fsdp", None)),
    ("moe/wu", ("ep", "fsdp", None)),
    ("moe/wd", ("ep", "fsdp", None)),
    ("shared/wg", ("fsdp", "tp")),      # moe shared expert / zamba shared mlp
    ("shared/wu", ("fsdp", "tp")),
    ("shared/wd", ("tp", "fsdp")),
    ("mlp/wg", ("fsdp", "tp")),
    ("mlp/wu", ("fsdp", "tp")),
    ("mlp/wd", ("tp", "fsdp")),
    ("mlp/bu", ("tp",)),
    ("mlp/bd", (None,)),
    ("mixer/in_proj", ("fsdp", "tp")),
    ("mixer/conv_w", (None, "tp")),
    ("mixer/conv_b", ("tp",)),
    ("mixer/A_log", (None,)),
    ("mixer/dt_bias", (None,)),
    ("mixer/D", (None,)),
    ("mixer/out_proj", ("tp", "fsdp")),
    ("projector/w", (None, "fsdp")),
    ("projector/b", (None,)),
    ("adapter/w", (None, "fsdp")),
    ("enc_pos", (None, None)),
)


def _resolve(sym, plan: ParallelPlan):
    if sym is None:
        return None
    if sym == "tp":
        return plan.tp_axis
    if sym == "ep":
        return plan.ep_axis or plan.tp_axis
    if sym == "fsdp":
        return plan.fsdp_axes if plan.fsdp_axes else None
    raise KeyError(sym)


def _path_key(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(getattr(p, "idx", p)))
    return "/".join(parts)


def spec_for(path_key: str, ndim: int, plan: ParallelPlan) -> P:
    for suffix, symbols in _RULES:
        if path_key.endswith(suffix):
            resolved = tuple(_resolve(s, plan) for s in symbols)
            pad = ndim - len(resolved)
            if pad < 0:   # leaf has fewer dims than rule (shouldn't happen)
                resolved = resolved[-ndim:] if ndim else ()
                pad = 0
            return P(*(((None,) * pad) + resolved))
    return P(*((None,) * ndim))


def param_pspecs(params_tree: Pytree, plan: ParallelPlan) -> Pytree:
    """PartitionSpec pytree matching ``params_tree`` (arrays or SDS)."""
    def one(path, leaf):
        return spec_for(_path_key(path), len(leaf.shape), plan)
    return jax.tree_util.tree_map_with_path(one, params_tree)


def opt_pspecs(opt_tree: Pytree, param_specs: Pytree, plan: ParallelPlan) -> Pytree:
    """Optimizer-state specs: moments inherit the param spec; factored
    row/col factors drop the corresponding trailing dim."""
    def one(path, leaf):
        key = _path_key(path)
        if key == "count":
            return P()
        # strip the trailing /m /v /v_row /v_col and the leading mu/
        parts = key.split("/")
        tail = parts[-1]
        pkey = "/".join(parts[1:-1])
        # factored leaves drop exactly one param dim (v_row: last, v_col:
        # second-to-last), so the param spec has leaf.ndim + 1 entries
        pad = 1 if tail in ("v_row", "v_col") else 0
        base = tuple(spec_for(pkey, len(leaf.shape) + pad, plan))
        if tail == "v_row":
            return P(*base[:-1])
        if tail == "v_col":
            return P(*(base[:-2] + base[-1:]))
        return P(*base)
    return jax.tree_util.tree_map_with_path(one, opt_tree)


def batch_pspecs(batch_tree: Pytree, plan: ParallelPlan) -> Pytree:
    dp = plan.dp_axes

    def one(leaf):
        if not dp:
            return P(*((None,) * len(leaf.shape)))
        return P(*((dp,) + (None,) * (len(leaf.shape) - 1)))
    return jax.tree.map(one, batch_tree)


def cache_pspecs(cache_tree: Pytree, plan: ParallelPlan, cfg: ModelConfig,
                 mesh=None) -> Pytree:
    """KV/SSM cache sharding: batch over dp, kv-heads over tp (falling back
    to head_dim when n_kv_heads doesn't divide the tp width — GQA caches
    with 8 kv-heads on a 16-wide model axis); sequence over data when the
    plan says so (long-context, batch=1 cells)."""
    dp, tp = plan.dp_axes, plan.tp_axis
    tp_size = mesh.shape[tp] if (mesh is not None and tp) else 1

    def heads_divide(n: int) -> bool:
        return tp is not None and n and n % max(tp_size, 1) == 0

    def one(path, leaf):
        key = _path_key(path)
        nd = len(leaf.shape)
        if key == "len":
            return P()
        if key == "enc_out":                      # (B, S_enc, D)
            seq = ("data",) if plan.seq_shard_cache else None
            return P(dp or None, seq, None)
        if key in ("k", "v", "shared_k", "shared_v"):
            # (L, B, S, Hkv, hd) or (G, B, S, Hkv, hd)
            seq = ("data",) if plan.seq_shard_cache else None
            if heads_divide(cfg.n_kv_heads):
                return P(None, dp or None, seq, tp, None)
            # kv heads don't divide tp: shard the SEQUENCE — the decode
            # softmax then pays tiny stat all-reduces instead of the
            # full-cache f32 gathers a head_dim sharding caused (§Perf D)
            if seq is None:
                return P(None, dp or None, tp, None, None)
            if heads_divide(cfg.head_dim):
                return P(None, dp or None, seq, None, tp)
            return P(None, dp or None, seq, None, None)
        if key.endswith("conv"):                  # (L.., B, K-1, C)
            pad = nd - 3
            conv_dim = leaf.shape[-1]
            ctp = tp if heads_divide(conv_dim) else None
            return P(*((None,) * pad), dp or None, None, ctp)
        if key.endswith("ssm"):                   # (L.., B, H, P, N)
            pad = nd - 4
            htp = tp if heads_divide(cfg.n_ssm_heads) else None
            return P(*((None,) * pad), dp or None, htp, None, None)
        return P(*((None,) * nd))
    return jax.tree_util.tree_map_with_path(one, cache_tree)


def sanitize_pspecs(spec_tree: Pytree, sds_tree: Pytree, mesh) -> Pytree:
    """Drop sharding on any dim the mesh axes don't divide evenly.

    jit ``in_shardings`` require exact divisibility; rather than hand-tuning
    every rule per architecture, non-dividing axes degrade to replication
    (correct, occasionally sub-optimal — the cost model sees the real spec).
    """
    def axis_size(a) -> int:
        if a is None:
            return 1
        if isinstance(a, (tuple, list)):
            n = 1
            for x in a:
                n *= mesh.shape[x]
            return n
        return mesh.shape[a]

    def one(spec, sds):
        nd = len(sds.shape)
        dims = (tuple(spec) + (None,) * nd)[:nd]
        fixed = tuple(
            d if sds.shape[i] % axis_size(d) == 0 else None
            for i, d in enumerate(dims))
        return P(*fixed)

    return jax.tree.map(one, spec_tree, sds_tree,
                        is_leaf=lambda x: isinstance(x, P))


def to_shardings(spec_tree: Pytree, mesh) -> Pytree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
