"""Serving driver: batched generation on any --arch (reduced configs on
CPU; full configs are exercised via the dry-run).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --reduced --batch 4 --new-tokens 16
"""
from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models.runtime import Runtime
from ..serve.engine import ServeEngine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    rng = np.random.default_rng(0)
    engine = ServeEngine(cfg, rt=Runtime(), temperature=args.temperature)
    params = engine.api.init(jax.random.key(0))

    prompts = [rng.integers(1, cfg.vocab_size,
                            rng.integers(4, args.prompt_len + 1)).tolist()
               for _ in range(args.batch)]
    extra = None
    if cfg.family == "vlm":
        extra = {"patches": jnp.asarray(
            rng.standard_normal((args.batch, cfg.n_patches,
                                 cfg.frontend_dim), dtype=np.float32),
            cfg.np_dtype)}
    if cfg.family == "encdec":
        S_enc = 64
        extra = {"frames": jnp.asarray(
            rng.standard_normal((args.batch, S_enc, cfg.frontend_dim),
                                dtype=np.float32), cfg.np_dtype)}

    res = engine.generate(params, prompts, max_new_tokens=args.new_tokens,
                          extra_inputs=extra)
    for i, toks in enumerate(res.tokens):
        print(f"req {i}: prompt {len(prompts[i])} toks -> {toks[:12]}"
              f"{'...' if len(toks) > 12 else ''}")
    print(f"prefill {res.prefill_s*1e3:.0f} ms; decode {res.n_steps} steps "
          f"in {res.decode_s*1e3:.0f} ms ({res.tokens_per_s:.1f} tok/s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
