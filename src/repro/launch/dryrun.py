import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (arch × shape × mesh) cell lowers,
compiles, and fits — without real hardware.

The two lines above MUST precede any jax import (jax locks the device count
on first init); do not move them.  Each cell:

    with mesh:
        lowered  = jax.jit(step, in_shardings=…, out_shardings=…) \
                      .lower(**input_specs(arch))
        compiled = lowered.compile()
        memory_analysis(), cost_analysis(), HLO collective census

Results are appended to a JSON artifact (``artifacts/dryrun/<cell>.json``)
that ``benchmarks/roofline_report.py`` and EXPERIMENTS.md read.  Already-
present cells are skipped, so the sweep is resumable.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
        --shape train_4k --mesh single,multi
    PYTHONPATH=src python -m repro.launch.dryrun --list
"""
import argparse
import json
import time
import traceback

import jax

from ..configs import ARCH_NAMES, SHAPES, cells, get_config
from ..tpu.hlo_stats import collective_stats
from ..tpu.hlo_walk import walk as hlo_walk
from .mesh import make_production_mesh
from .steps import build_step

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")

MESHES = ("single", "multi")


def cell_id(arch: str, shape: str, mesh: str) -> str:
    return f"{arch}__{shape}__{mesh}"


def _artifact_path(cid: str, out_dir: str) -> str:
    return os.path.join(out_dir, cid + ".json")


def run_cell(arch: str, shape_name: str, mesh_name: str,
             out_dir: str = ART_DIR, plan=None, tag: str | None = None,
             force: bool = False) -> dict:
    """Lower + compile one cell; return (and persist) its analysis record."""
    os.makedirs(out_dir, exist_ok=True)
    cid = cell_id(arch, shape_name, mesh_name) + (f"__{tag}" if tag else "")
    path = _artifact_path(cid, out_dir)
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    rec: dict = {
        "cell": cid, "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "mesh_shape": dict(mesh.shape), "kind": shape.kind,
        "plan": None, "ok": False,
    }
    t0 = time.time()
    try:
        with mesh:
            built = build_step(cfg, shape, mesh, plan)
            rec["plan"] = {
                k: v for k, v in vars(built.plan).items()
                if isinstance(v, (str, int, float, bool, tuple, type(None)))
            }
            lowered = built.lower()
            t_lower = time.time()
            compiled = lowered.compile()
            t_compile = time.time()

            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            if isinstance(cost, (list, tuple)):   # jax <= 0.4.x: per-program
                cost = cost[0] if cost else {}    # list; newer: one dict
            hlo = compiled.as_text()
            coll = collective_stats(hlo)
            walked = hlo_walk(hlo)  # trip-count-multiplied per-device costs

            rec.update(
                ok=True,
                lower_s=round(t_lower - t0, 2),
                compile_s=round(t_compile - t_lower, 2),
                memory=_mem_dict(mem),
                cost={k: float(v) for k, v in cost.items()
                      if isinstance(v, (int, float)) and
                      not k.startswith(("utilization", "bytes accessed"))},
                collectives=coll.as_dict(),
                walk=walked.as_dict(),
                hlo_bytes=len(hlo),
            )
    except Exception as e:  # noqa: BLE001 — recorded, sweep continues
        rec.update(ok=False, error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    rec["total_s"] = round(time.time() - t0, 2)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes", "host_argument_size_in_bytes",
              "host_output_size_in_bytes", "host_temp_size_in_bytes",
              "peak_memory_in_bytes", "serialized_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None,
                    help="comma-separated arch ids (default: all)")
    ap.add_argument("--shape", default=None,
                    help="comma-separated shape names (default: all)")
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--out", default=ART_DIR)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true",
                    help="list the assigned cells and exit")
    args = ap.parse_args(argv)

    assigned = cells(include_skipped=True)
    if args.list:
        for arch, shape, skip in assigned:
            print(f"{arch:24s} {shape:12s} {'SKIP' if skip else ''}")
        return 0

    archs = args.arch.split(",") if args.arch else list(ARCH_NAMES)
    shapes = args.shape.split(",") if args.shape else list(SHAPES)
    meshes = args.mesh.split(",")

    n_dev = len(jax.devices())
    assert n_dev == 512, f"dry-run needs 512 placeholder devices, got {n_dev}"

    want_skip = {(a, s): sk for a, s, sk in assigned}
    failed = 0
    for arch in archs:
        for shape in shapes:
            skip = want_skip.get((arch, shape))
            if skip is None:
                continue
            if skip:
                print(f"[skip] {arch} × {shape} — sub-quadratic only "
                      "(DESIGN.md §Arch-applicability)")
                continue
            for mesh in meshes:
                rec = run_cell(arch, shape, mesh, args.out, force=args.force)
                status = "ok" if rec["ok"] else "FAIL"
                peak = rec.get("memory", {}).get("peak_memory_in_bytes", 0)
                extra = (f"peak={peak/2**30:.2f}GiB "
                         f"wire={rec.get('collectives', {}).get('total_wire', 0)/2**30:.2f}GiB"
                         if rec["ok"] else rec.get("error", ""))
                print(f"[{status}] {rec['cell']}  "
                      f"(lower {rec.get('lower_s', '-')}s, "
                      f"compile {rec.get('compile_s', '-')}s)  {extra}",
                      flush=True)
                failed += 0 if rec["ok"] else 1
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
