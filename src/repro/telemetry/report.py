"""Bottleneck attribution: the paper's use-case-2 story as an API.

MCCM's fine-grained evaluation exists to answer *where does the time go*
— which segment stalls on memory (Fig. 6), whether weights or feature
maps dominate off-chip traffic (Fig. 7), and which CE bounds steady-state
throughput (Eq. 8's busy-time max).  The scalar evaluator already
computes all of it (``Metrics.per_segment`` / ``.blocks`` /
``.ce_busy_s``); :func:`bottleneck_report` turns those raw breakdowns
into one ranked, machine-readable attribution dict, and
:func:`format_report` renders it for humans.  ``Session.explain`` is the
front-door wrapper (``docs/observability.md``).

The numbers are bit-identical to ``benchmarks/fig6_fig7_breakdown.py``'s
formulas (pinned by ``tests/test_telemetry.py``): the report *is* the
fig6/fig7 analysis, reusable on any design instead of two hard-coded
winners.
"""
from __future__ import annotations

from ..core.accelerator import Metrics

__all__ = ["bottleneck_report", "format_report"]


def bottleneck_report(m: Metrics, schedule=None) -> dict:
    """Rank where a design's time and traffic go.

    Returns a dict with:

    * ``segments`` — per-segment compute vs memory seconds, bound kind
      and stall time, **ranked** by occupancy (``max(compute, mem)``)
      descending: the first row is the segment to fix;
    * ``ces`` — per-CE steady-state busy seconds ranked descending; the
      first row is the CE bounding pipelined throughput;
    * ``mem_bound_layers`` / ``idle_fraction`` — Fig. 6's layer-granular
      view: layers whose memory time exceeds compute time, and the
      fraction of occupied time CEs spend waiting for data;
    * ``access`` — Fig. 7's off-chip breakdown (weights vs feature
      maps) with the dominant class called out;
    * ``bottleneck`` — the one-line verdict: the ranked-first segment,
      its bound kind, and the busiest CE.

    ``schedule`` (a :class:`~repro.schedule.ScheduleArtifact`, what
    ``Session.explain(refine="schedule")`` passes) attaches a
    ``"schedule"`` section: refined-vs-coarse cycles per segment and the
    headline latency saving of the temporal-mapping search
    (``docs/schedule.md``).  The coarse attribution above is untouched —
    the section reports how much of each segment's cost an explicit
    mapping recovers.
    """
    total_occ = sum(max(s.compute_s, s.mem_s) for s in m.per_segment) or 1.0
    segments = []
    for s in m.per_segment:
        occ = max(s.compute_s, s.mem_s)
        segments.append({
            "index": s.index,
            "n_layers": s.n_layers,
            "compute_s": s.compute_s,
            "mem_s": s.mem_s,
            "busy_s": s.busy_s,
            "latency_s": s.latency_s,
            "occupancy_s": occ,
            "share": occ / total_occ,
            "bound": "memory" if s.mem_s > s.compute_s else "compute",
            "stall_s": max(s.mem_s - s.compute_s, 0.0),
            "utilization": s.utilization,
            "buffer_bytes": s.buffer_bytes,
            "access_bytes": s.access_bytes,
        })
    # stable rank: occupancy descending, original order breaking ties —
    # deterministic, so the ranking is reproducible bit-for-bit
    segments.sort(key=lambda d: (-d["occupancy_s"], d["index"]))
    for rank, d in enumerate(segments):
        d["rank"] = rank

    # ---- Fig. 6 layer granularity (the SegmentedRR story) -------------
    mem_bound_layers = [r.layer.index for b in m.blocks for r in b.per_layer
                        if r.mem_cycles > r.compute_cycles]
    occ_cycles = sum(max(r.mem_cycles, r.compute_cycles)
                     for b in m.blocks for r in b.per_layer)
    stall_cycles = sum(max(r.mem_cycles - r.compute_cycles, 0.0)
                       for b in m.blocks for r in b.per_layer)
    idle_fraction = stall_cycles / occ_cycles if occ_cycles else 0.0

    # ---- Eq. 8 busy-time ranking: the CE bounding throughput ----------
    ces = [{"ce": ce, "busy_s": busy}
           for ce, busy in m.ce_busy_s.items()]
    total_busy = sum(c["busy_s"] for c in ces) or 1.0
    for c in ces:
        c["share"] = c["busy_s"] / total_busy
    ces.sort(key=lambda d: (-d["busy_s"], d["ce"]))
    for rank, c in enumerate(ces):
        c["rank"] = rank

    # ---- Fig. 7 off-chip access breakdown ------------------------------
    access = {
        "weights_bytes": float(m.weight_access_bytes),
        "fm_bytes": float(m.fm_access_bytes),
        "total_bytes": float(m.access_bytes),
        "weights_frac": (float(m.weight_access_bytes)
                         / float(m.access_bytes) if m.access_bytes else 0.0),
        "dominant": ("weights" if m.weight_access_bytes > m.fm_access_bytes
                     else "fms"),
    }

    top = segments[0] if segments else None
    sched = None
    if schedule is not None:
        sched = {
            "latency_s": schedule.latency_s,
            "coarse_latency_s": schedule.coarse_latency_s,
            "saving_frac": (1.0 - schedule.latency_s
                            / schedule.coarse_latency_s
                            if schedule.coarse_latency_s else 0.0),
            "access_bytes": schedule.access_bytes,
            "coarse_access_bytes": schedule.coarse_access_bytes,
            "energy_j": schedule.energy_j,
            "n_refined_layers": schedule.meta.get("n_refined", 0),
            "segments": [{
                "index": s.segment,
                "pipelined": s.pipelined,
                "coarse_cyc": s.coarse_cyc,
                "refined_cyc": s.refined_cyc,
                "saving_frac": (1.0 - s.refined_cyc / s.coarse_cyc
                                if s.coarse_cyc else 0.0),
            } for s in schedule.segments],
        }
    return {
        "summary": {
            "latency_s": m.latency_s,
            "throughput_ips": m.throughput_ips,
            "buffer_bytes": int(m.buffer_bytes),
            "access_bytes": float(m.access_bytes),
        },
        "segments": segments,
        "ces": ces,
        "mem_bound_layers": mem_bound_layers,
        "idle_fraction": idle_fraction,
        "access": access,
        "bottleneck": {
            "segment": top["index"] if top else None,
            "bound": top["bound"] if top else None,
            "share": top["share"] if top else 0.0,
            "ce": ces[0]["ce"] if ces else None,
            "ce_busy_s": ces[0]["busy_s"] if ces else 0.0,
        },
        **({"schedule": sched} if sched is not None else {}),
    }


def format_report(rep: dict) -> str:
    """Human-readable rendering of :func:`bottleneck_report`."""
    s = rep["summary"]
    b = rep["bottleneck"]
    lines = [
        f"latency {s['latency_s'] * 1e3:.3f} ms | "
        f"throughput {s['throughput_ips']:.1f} inf/s | "
        f"buffer {s['buffer_bytes'] / 2**20:.2f} MiB | "
        f"off-chip {s['access_bytes'] / 1e6:.1f} MB",
        f"bottleneck: segment {b['segment']} ({b['bound']}-bound, "
        f"{b['share']:.0%} of occupancy), CE{b['ce']} busiest "
        f"({b['ce_busy_s'] * 1e3:.3f} ms/input)",
        f"idle fraction {rep['idle_fraction']:.1%} "
        f"({len(rep['mem_bound_layers'])} memory-bound layer(s))",
        f"off-chip split: weights {rep['access']['weights_frac']:.0%} "
        f"(dominant: {rep['access']['dominant']})",
        "",
        "rank  seg  bound    occupancy_s    stall_s      share  layers",
    ]
    for d in rep["segments"]:
        lines.append(
            f"{d['rank']:>4}  {d['index']:>3}  {d['bound']:<7}"
            f"{d['occupancy_s']:>12.6f} {d['stall_s']:>10.6f}"
            f"{d['share']:>10.1%}  {d['n_layers']}")
    lines.append("")
    lines.append("rank  CE   busy_s        share")
    for c in rep["ces"]:
        lines.append(f"{c['rank']:>4}  {c['ce']:<4}"
                     f"{c['busy_s']:>10.6f} {c['share']:>10.1%}")
    sched = rep.get("schedule")
    if sched is not None:
        lines.append("")
        lines.append(
            f"schedule refinement: {sched['latency_s'] * 1e3:.3f} ms "
            f"vs coarse {sched['coarse_latency_s'] * 1e3:.3f} ms "
            f"({sched['saving_frac']:.1%} saved, "
            f"{sched['n_refined_layers']} layer(s) remapped)")
        for s in sched["segments"]:
            lines.append(
                f"  seg {s['index']}: {s['refined_cyc']:.0f} cyc "
                f"vs {s['coarse_cyc']:.0f} ({s['saving_frac']:.1%})")
    return "\n".join(lines)
