"""``repro.telemetry`` — the observability front door.

Re-exports the zero-dependency core layer (``repro.core.telemetry``:
spans, the metrics registry, JSONL/Prometheus exporters) plus the
bottleneck-attribution report (:mod:`repro.telemetry.report`) that turns
``Metrics`` breakdowns into the paper's use-case-2 ranked tables.

    from repro import telemetry
    with telemetry.span("my.stage"):
        ...
    print(telemetry.prometheus_text())
    rep = telemetry.bottleneck_report(ses.evaluate(spec, net))

Enable with ``REPRO_TELEMETRY_DIR=<dir>`` (JSONL trace export) or
``telemetry.enable()`` (in-process only).  Catalog and schema:
``docs/observability.md``.
"""
from __future__ import annotations

from ..core.telemetry import (DEFAULT_BUCKETS, PROFILE_ENV,  # noqa: F401
                              TELEMETRY_DIR_ENV, Histogram, count,
                              current_span, disable, enable, enabled,
                              event, gauge, observe, profile,
                              prometheus_text, read_trace, reset,
                              snapshot, span, trace_path,
                              validate_trace_line)
from . import report  # noqa: F401
from .report import bottleneck_report, format_report  # noqa: F401

__all__ = [
    "TELEMETRY_DIR_ENV", "PROFILE_ENV", "DEFAULT_BUCKETS", "Histogram",
    "enable", "disable", "enabled", "reset",
    "span", "event", "count", "gauge", "observe", "current_span",
    "snapshot", "prometheus_text", "trace_path",
    "validate_trace_line", "read_trace", "profile",
    "report", "bottleneck_report", "format_report",
]
