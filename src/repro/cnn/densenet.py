"""DenseNet-121 layer generator (Huang et al. [16]) — 120 convs, ~8.1M weights."""
from __future__ import annotations

from ..core.workload import Network, make_network

_BLOCKS = (6, 12, 24, 16)
_GROWTH = 32
_BOTTLENECK = 4  # 1x1 produces 4*growth channels


def densenet121() -> tuple[Network, int]:
    specs = []
    h = w = 224

    def conv(kind, cin, cout, k, s):
        nonlocal h, w
        specs.append(
            dict(
                name=f"conv{len(specs) + 1}",
                kind=kind,
                in_ch=cin,
                out_ch=cout,
                kh=k,
                kw=k,
                stride=s,
                ih=h,
                iw=w,
            )
        )
        h = -(-h // s)
        w = -(-w // s)

    conv("conv", 3, 64, 7, 2)  # 224 -> 112
    h, w = h // 2, w // 2      # maxpool -> 56
    ch = 64
    for bi, n_layers in enumerate(_BLOCKS):
        for _ in range(n_layers):
            conv("pw", ch, _BOTTLENECK * _GROWTH, 1, 1)
            conv("conv", _BOTTLENECK * _GROWTH, _GROWTH, 3, 1)
            ch += _GROWTH  # dense concatenation grows the input of the next layer
        if bi < len(_BLOCKS) - 1:
            conv("pw", ch, ch // 2, 1, 1)  # transition compression
            ch //= 2
            h, w = h // 2, w // 2          # avgpool /2
    net = make_network("densenet121", specs)
    return net, ch * 1000
