from .registry import CNN_NAMES, TABLE_III, get_cnn, total_params

__all__ = ["CNN_NAMES", "TABLE_III", "get_cnn", "total_params"]
