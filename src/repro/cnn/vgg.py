"""VGG-16 layer generator (Simonyan & Zisserman).

13 conv layers (all 3x3 stride-1 'same', maxpool /2 between stages); the
three FC layers are reported separately for weight-count validation.  The
canonical 138.3M-parameter workload — the weight-heaviest net in the zoo,
which is exactly what makes it a useful multinet co-tenant (its weight
traffic punishes time-multiplexed deployments).
"""
from __future__ import annotations

from ..core.workload import Network, make_network

_STAGES = ((64, 2), (128, 2), (256, 3), (512, 3), (512, 3))


def vgg16() -> tuple[Network, int]:
    specs = []
    h = w = 224
    in_ch = 3
    for out_ch, n_convs in _STAGES:
        for _ in range(n_convs):
            specs.append(
                dict(
                    name=f"conv{len(specs) + 1}",
                    kind="conv",
                    in_ch=in_ch,
                    out_ch=out_ch,
                    kh=3,
                    kw=3,
                    stride=1,
                    ih=h,
                    iw=w,
                )
            )
            in_ch = out_ch
        h, w = h // 2, w // 2          # maxpool /2 after each stage
    net = make_network("vgg16", specs)
    fc_params = 512 * 7 * 7 * 4096 + 4096 * 4096 + 4096 * 1000
    return net, fc_params
