"""CNN zoo registry — the paper's five workloads (Table III)."""
from __future__ import annotations

from functools import lru_cache

from ..core.workload import Network
from .densenet import densenet121
from .mobilenetv2 import mobilenetv2
from .resnet import resnet50, resnet152
from .xception import xception

_FACTORIES = {
    "resnet152": resnet152,
    "resnet50": resnet50,
    "xception": xception,
    "densenet121": densenet121,
    "mobilenetv2": mobilenetv2,
}

# Paper Table III: (abbrev, weights in millions, conv layer count)
TABLE_III = {
    "resnet152": ("Res152", 60.4, 155),
    "resnet50": ("Res50", 25.6, 53),
    "xception": ("XCp", 22.9, 74),
    "densenet121": ("Dns121", 8.1, 120),
    "mobilenetv2": ("MobV2", 3.5, 52),
}

CNN_NAMES = tuple(_FACTORIES)


@lru_cache(maxsize=None)
def get_cnn(name: str) -> Network:
    """Conv-layer network for MCCM evaluation."""
    if name not in _FACTORIES:
        raise KeyError(f"unknown CNN {name!r}; known: {sorted(_FACTORIES)}")
    return _FACTORIES[name]()[0]


@lru_cache(maxsize=None)
def total_params(name: str) -> int:
    """Conv weights + classifier weights (for Table III validation)."""
    net, fc = _FACTORIES[name]()
    return net.total_weights + fc
