"""CNN zoo registry — the paper's five workloads (Table III) plus the
ResNet-101 / VGG-16 extensions, validated Table-III-style (total weights
and conv layer counts)."""
from __future__ import annotations

from functools import lru_cache

from ..core.workload import Network
from .densenet import densenet121
from .mobilenetv2 import mobilenetv2
from .resnet import resnet50, resnet101, resnet152
from .vgg import vgg16
from .xception import xception

_FACTORIES = {
    "resnet152": resnet152,
    "resnet101": resnet101,
    "resnet50": resnet50,
    "vgg16": vgg16,
    "xception": xception,
    "densenet121": densenet121,
    "mobilenetv2": mobilenetv2,
}

# Paper Table III, extended in the same format:
# (abbrev, total weights in millions, conv layer count).
# resnet101 / vgg16 are not in the paper's table; their reference counts
# are the canonical torchvision parameter totals.
TABLE_III = {
    "resnet152": ("Res152", 60.4, 155),
    "resnet101": ("Res101", 44.5, 104),
    "resnet50": ("Res50", 25.6, 53),
    "vgg16": ("VGG16", 138.3, 13),
    "xception": ("XCp", 22.9, 74),
    "densenet121": ("Dns121", 8.1, 120),
    "mobilenetv2": ("MobV2", 3.5, 52),
}

CNN_NAMES = tuple(_FACTORIES)


@lru_cache(maxsize=None)
def get_cnn(name: str) -> Network:
    """Conv-layer network for MCCM evaluation."""
    if name not in _FACTORIES:
        raise KeyError(f"unknown CNN {name!r}; known: {sorted(_FACTORIES)}")
    return _FACTORIES[name]()[0]


@lru_cache(maxsize=None)
def total_params(name: str) -> int:
    """Conv weights + classifier weights (for Table III validation)."""
    net, fc = _FACTORIES[name]()
    return net.total_weights + fc
