"""ResNet-50 / 101 / 152 layer generators (He et al. [15]).

Conv layers only (53 / 104 / 155 convs; 50/152 match paper Table III,
ResNet-101 extends the zoo Table-III-style); the final FC is reported
separately for weight-count validation.
"""
from __future__ import annotations

from ..core.workload import Network, make_network

_BLOCKS = {"resnet50": (3, 4, 6, 3), "resnet101": (3, 4, 23, 3),
           "resnet152": (3, 8, 36, 3)}


def _resnet(name: str, blocks: tuple[int, ...]) -> tuple[Network, int]:
    specs = []
    h = w = 224

    def conv(kind, cin, cout, k, s, residual=False):
        nonlocal h, w
        specs.append(
            dict(
                name=f"conv{len(specs) + 1}",
                kind=kind,
                in_ch=cin,
                out_ch=cout,
                kh=k,
                kw=k,
                stride=s,
                ih=h,
                iw=w,
                residual=residual,
            )
        )
        h = -(-h // s)
        w = -(-w // s)

    conv("conv", 3, 64, 7, 2)      # conv1, 224 -> 112
    h, w = h // 2, w // 2          # maxpool /2 -> 56
    in_ch = 64
    widths = (64, 128, 256, 512)
    for stage, (n_blocks, mid) in enumerate(zip(blocks, widths)):
        out_ch = mid * 4
        for b in range(n_blocks):
            stride = 2 if (stage > 0 and b == 0) else 1
            ih, iw = h, w
            conv("pw", in_ch, mid, 1, 1)
            conv("conv", mid, mid, 3, stride)
            conv("pw", mid, out_ch, 1, 1, residual=True)
            if b == 0:
                # projection shortcut, same input FM as the block entry
                specs.append(
                    dict(
                        name=f"conv{len(specs) + 1}_sc",
                        kind="pw",
                        in_ch=in_ch,
                        out_ch=out_ch,
                        kh=1,
                        kw=1,
                        stride=stride,
                        ih=ih,
                        iw=iw,
                        residual=False,
                    )
                )
            in_ch = out_ch
    net = make_network(name, specs)
    fc_params = 512 * 4 * 1000
    return net, fc_params


def resnet50() -> tuple[Network, int]:
    return _resnet("resnet50", _BLOCKS["resnet50"])


def resnet101() -> tuple[Network, int]:
    return _resnet("resnet101", _BLOCKS["resnet101"])


def resnet152() -> tuple[Network, int]:
    return _resnet("resnet152", _BLOCKS["resnet152"])
