"""MobileNetV2 layer generator (Sandler et al. [31]) — 52 convs, ~3.5M weights."""
from __future__ import annotations

from ..core.workload import Network, make_network

# (expansion t, out channels c, repeats n, stride s)
_CFG = [
    (1, 16, 1, 1),
    (6, 24, 2, 2),
    (6, 32, 3, 2),
    (6, 64, 4, 2),
    (6, 96, 3, 1),
    (6, 160, 3, 2),
    (6, 320, 1, 1),
]


def mobilenetv2() -> tuple[Network, int]:
    specs = []
    h = w = 224

    def conv(kind, cin, cout, k, s, residual=False):
        nonlocal h, w
        specs.append(
            dict(
                name=f"conv{len(specs) + 1}",
                kind=kind,
                in_ch=cin,
                out_ch=cout,
                kh=k,
                kw=k,
                stride=s,
                ih=h,
                iw=w,
                residual=residual,
            )
        )
        h = -(-h // s)
        w = -(-w // s)

    conv("conv", 3, 32, 3, 2)  # 224 -> 112
    in_ch = 32
    for t, c, n, s in _CFG:
        for b in range(n):
            stride = s if b == 0 else 1
            residual = stride == 1 and in_ch == c
            hidden = in_ch * t
            if t != 1:
                conv("pw", in_ch, hidden, 1, 1)
            conv("dw", hidden, hidden, 3, stride)
            conv("pw", hidden, c, 1, 1, residual=residual)
            in_ch = c
    conv("pw", in_ch, 1280, 1, 1)
    net = make_network("mobilenetv2", specs)
    return net, 1280 * 1000
