"""Xception layer generator (Chollet [10]) — 74 convs, ~22.9M weights.

Separable convs are modelled as depthwise + pointwise layer pairs (how the
paper counts them: 74 conv layers).
"""
from __future__ import annotations

from ..core.workload import Network, make_network


def xception() -> tuple[Network, int]:
    specs = []
    h = w = 299

    def conv(kind, cin, cout, k, s, residual=False, at=None):
        nonlocal h, w
        ih, iw = at if at else (h, w)
        specs.append(
            dict(
                name=f"conv{len(specs) + 1}",
                kind=kind,
                in_ch=cin,
                out_ch=cout,
                kh=k,
                kw=k,
                stride=s,
                ih=ih,
                iw=iw,
                residual=residual,
            )
        )
        if at is None:
            h = -(-h // s)
            w = -(-w // s)

    def sep(cin, cout, residual=False):
        conv("dw", cin, cin, 3, 1)
        conv("pw", cin, cout, 1, 1, residual=residual)

    # Entry flow
    conv("conv", 3, 32, 3, 2)    # 299 -> 150
    conv("conv", 32, 64, 3, 1)
    for cin, cout in ((64, 128), (128, 256), (256, 728)):
        ih, iw = h, w
        sep(cin, cout)
        sep(cout, cout, residual=True)
        conv("pw", cin, cout, 1, 2, at=(ih, iw))  # strided shortcut
        h, w = -(-h // 2), -(-w // 2)             # maxpool /2

    # Middle flow: 8 blocks of 3 separable convs @ 19x19
    for _ in range(8):
        sep(728, 728)
        sep(728, 728)
        sep(728, 728, residual=True)

    # Exit flow
    ih, iw = h, w
    sep(728, 728)
    sep(728, 1024, residual=True)
    conv("pw", 728, 1024, 1, 2, at=(ih, iw))  # strided shortcut
    h, w = -(-h // 2), -(-w // 2)             # maxpool /2
    sep(1024, 1536)
    sep(1536, 2048)

    net = make_network("xception", specs)
    return net, 2048 * 1000
