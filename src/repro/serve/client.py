"""Thin NDJSON/TCP client for :class:`repro.serve.server.EvalServer`.

One socket, one background reader thread: requests are written as JSON
lines with a client-assigned ``id``, responses are matched back to their
:class:`~concurrent.futures.Future` by that id — so a client can pipeline
many requests (``request_async``) and the server's out-of-order
completions resolve the right futures.  Wire errors re-raise as
:class:`EvalError` with the server's taxonomy code, so remote callers
branch on ``err.code`` exactly like local ones (``docs/serving.md``).

>>> with ServeClient(host, port) as cli:
...     cli.ping()
...     cli.evaluate("{L1-Last:CE1-CE4}", "resnet50", board="zc706")
...     cli.explore("mobilenetv2", n=512, strategy="random")
"""
from __future__ import annotations

import itertools
import json
import socket
import threading
from concurrent.futures import Future
from concurrent.futures import TimeoutError as FutureTimeout

from ..core.resilience import EvalError
from .server import ENCODING

#: default wall-clock wait of the blocking ``request`` helper, seconds
DEFAULT_TIMEOUT_S = 600.0


class ServeClient:
    """Client for one :class:`EvalServer`; thread-safe, pipelining."""

    def __init__(self, host: str, port: int, *,
                 timeout_s: float = DEFAULT_TIMEOUT_S):
        self.timeout_s = timeout_s
        self._sock = socket.create_connection((host, port))
        self._wlock = threading.Lock()
        self._plock = threading.Lock()
        self._pending: dict[int, Future] = {}
        self._ids = itertools.count(1)
        self._closed = False
        self._reader = threading.Thread(
            target=self._read_loop, name="repro-serve-client", daemon=True)
        self._reader.start()

    # ---- plumbing --------------------------------------------------------
    def request_async(self, op: str, **params) -> Future:
        """Send one request; the future resolves to the response's
        ``result`` or raises the reconstructed :class:`EvalError`."""
        rid = next(self._ids)
        fut: Future = Future()
        with self._plock:
            if self._closed:
                raise ConnectionError("client closed")
            self._pending[rid] = fut
        line = (json.dumps({"id": rid, "op": op, **params}) + "\n") \
            .encode(ENCODING)
        try:
            with self._wlock:
                self._sock.sendall(line)
        except OSError as e:
            with self._plock:
                self._pending.pop(rid, None)
            raise ConnectionError(f"send failed: {e}") from e
        return fut

    def request(self, op: str, *, timeout_s: float | None = None,
                **params):
        """Blocking :meth:`request_async`.

        ``timeout_s`` (or the client default) is a CLIENT-side deadline:
        when it passes the call raises ``EvalError(DEADLINE_EXCEEDED)``
        locally — same taxonomy code the server uses for its own expired
        deadlines, so callers branch one way — and the request id is
        abandoned (a late server response is dropped by ``_dispatch``,
        never delivered to a caller that already gave up).
        """
        fut = self.request_async(op, **params)
        wait = self.timeout_s if timeout_s is None else timeout_s
        try:
            return fut.result(timeout=wait)
        except FutureTimeout:
            with self._plock:                  # abandon the id
                self._pending = {k: v for k, v in self._pending.items()
                                 if v is not fut}
            raise EvalError(
                EvalError.DEADLINE_EXCEEDED,
                f"no response to {op!r} within {wait}s "
                "(client-side deadline)") from None

    def _read_loop(self) -> None:
        buf = b""
        err: Exception = ConnectionError("server closed the connection")
        try:
            while True:
                data = self._sock.recv(65536)
                if not data:
                    break
                buf += data
                while b"\n" in buf:
                    line, buf = buf.split(b"\n", 1)
                    if line.strip():
                        self._dispatch(json.loads(line.decode(ENCODING)))
        except OSError as e:
            if not self._closed:
                err = ConnectionError(f"connection lost: {e}")
        finally:
            with self._plock:
                pending = list(self._pending.values())
                self._pending.clear()
            for fut in pending:     # never leave a caller hanging
                fut.set_exception(err)

    def _dispatch(self, msg: dict) -> None:
        with self._plock:
            fut = self._pending.pop(msg.get("id"), None)
        if fut is None:
            return                  # unsolicited / already-abandoned id
        if msg.get("ok"):
            fut.set_result(msg.get("result"))
            return
        e = msg.get("error") or {}
        code, detail = e.get("code"), e.get("message", "server error")
        fut.set_exception(
            EvalError(code, detail) if code in EvalError.CODES
            else ConnectionError(f"[{code}] {detail}"))

    def close(self) -> None:
        self._closed = True
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self._sock.close()
        self._reader.join(timeout=5.0)

    def __enter__(self) -> "ServeClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ---- convenience ops -------------------------------------------------
    def ping(self) -> dict:
        return self.request("ping")

    def observability(self) -> dict:
        return self.request("observability")

    def shutdown(self, drain: bool = True) -> dict:
        return self.request("shutdown", drain=drain)

    def evaluate(self, designs, net: str, *, board: str | None = None,
                 **kw):
        """Evaluate notation design(s) of CNN ``net``; a single string
        returns ``{metric: float}``, a list returns ``{metric: [...]}``.
        Extra keywords (``priority``, ``deadline_s``) ride through."""
        return self.request("evaluate", designs=designs, net=net,
                            board=board, **kw)

    def evaluate_async(self, designs, net: str, *,
                       board: str | None = None, **kw) -> Future:
        return self.request_async("evaluate", designs=designs, net=net,
                                  board=board, **kw)

    def explore(self, net: str, n: int = 4096, *,
                board: str | None = None, **kw) -> dict:
        """Single-model DSE on the server's batch lane; returns the
        Pareto-front summary (``server.summarize_search``)."""
        return self.request("explore", net=net, n=n, board=board, **kw)

    def deploy(self, nets, n: int = 512, *, board: str | None = None,
               **kw) -> dict:
        """Multi-CNN co-scheduling DSE; ``nets`` is a list of CNN names."""
        return self.request("deploy", nets=list(nets), n=n, board=board,
                            **kw)
