"""Serving fronts: the LM generation engine and the MCCM socket service.

Lazy attribute resolution keeps the two independent: importing
``EvalServer``/``ServeClient`` (the evaluation service, docs/serving.md)
must not pull the generation engine's model stack, and vice versa.
"""
from __future__ import annotations

_EXPORTS = {
    "GenerationResult": ".engine",
    "ServeEngine": ".engine",
    "EvalServer": ".server",
    "jsonify": ".server",
    "summarize_search": ".server",
    "ServeClient": ".client",
}

__all__ = list(_EXPORTS)


def __getattr__(name: str):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute "
                             f"{name!r}")
    import importlib
    return getattr(importlib.import_module(mod, __name__), name)
