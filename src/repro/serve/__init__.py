from .engine import GenerationResult, ServeEngine  # noqa: F401
