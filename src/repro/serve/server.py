"""The serving front: MCCM evaluation over a socket.

One :class:`EvalServer` wraps one :class:`repro.api.Session` and speaks
newline-delimited JSON (NDJSON) over TCP — the thinnest wire that still
carries the whole session surface.  Every request is one JSON object on
one line::

    {"id": 7, "op": "evaluate", "net": "resnet50",
     "designs": ["{L1-Last:CE1-CE4}"], "board": "zc706"}

and every response echoes the id::

    {"id": 7, "ok": true, "result": {"latency_s": [...], ...}}
    {"id": 7, "ok": false,
     "error": {"code": "INVALID_INPUT", "message": "..."}}

Ops: ``ping``, ``evaluate``, ``explore``, ``deploy``, ``observability``,
``shutdown``.  Everything routes through ``Session.submit`` /
``Session.submit_search`` — evaluations ride the interactive lane and
coalesce into shared megabatch chunks across connections, long DSE jobs
ride the batch lane's worker thread — so a point probe is never starved
by a 100k-budget search (``docs/serving.md`` specifies the protocol).

Failure semantics mirror the session's :class:`EvalError` taxonomy: the
wire error object carries the taxonomy ``code`` verbatim
(``INVALID_INPUT`` for malformed JSON / unknown ops / unknown nets,
``DEADLINE_EXCEEDED`` / ``QUEUE_FULL`` straight from the session), so a
remote caller branches exactly like a local one.  A malformed line fails
only that line — the connection stays usable.

Responses are written from whichever thread completes the future (the
drain loop, the job worker, or the reader itself) under a per-connection
write lock, so pipelined requests may complete out of order — the id is
the correlation key, never arrival order.
"""
from __future__ import annotations

import json
import socket
import threading

import numpy as np

from ..cnn.registry import get_cnn
from ..core.resilience import EvalError, wrap
from ..core.session import PRIORITIES, Session
from ..core.workload import Network
from ..fpga.boards import get_board

#: every operation the wire accepts
OPS = ("ping", "evaluate", "explore", "deploy", "observability",
       "shutdown")
#: newline-delimited JSON; one request or response object per line
ENCODING = "utf-8"


def jsonify(obj):
    """Recursively convert ``obj`` to JSON-encodable types: numpy arrays
    become lists, numpy scalars become Python numbers, tuples become
    lists.  Raises ``TypeError`` for anything else non-encodable (better
    a loud server error than a silent drop)."""
    if isinstance(obj, dict):
        return {str(k): jsonify(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [jsonify(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, np.generic):
        return obj.item()
    return obj


def summarize_search(res) -> dict:
    """The wire form of a DSE result (``DSEResult`` / ``JointDSEResult``):
    the Pareto front plus run counters, NOT the full per-design metric
    arrays — a 100k-design sweep's front fits in one response line, its
    raw archive does not."""
    front = np.asarray(res.front)
    out = {
        "strategy": res.strategy,
        "n_evals": int(res.n_evals),
        "seconds": float(res.seconds),
        "objectives": list(res.objectives),
        "front_size": int(front.size),
        "front": front.tolist(),
        "front_points": res.front_points().tolist(),
        "front_metrics": {k: np.asarray(v)[front].tolist()
                          for k, v in res.metrics.items()},
    }
    if hasattr(res, "per_design_us"):
        out["per_design_us"] = float(res.per_design_us)
    if hasattr(res, "per_eval_us"):
        out["per_eval_us"] = float(res.per_eval_us)
    if hasattr(res, "mode"):
        out["mode"] = res.mode
    return out


def _error_obj(exc: BaseException) -> dict:
    e = exc if isinstance(exc, EvalError) else wrap(exc)
    return {"code": e.code, "message": e.message}


class _Connection:
    """One accepted client socket: a reader thread plus a write lock (the
    drain / job threads complete futures concurrently with the reader)."""

    def __init__(self, server: "EvalServer", sock: socket.socket):
        self.server = server
        self.sock = sock
        self.wlock = threading.Lock()
        self.closed = threading.Event()

    def send(self, obj: dict) -> None:
        data = (json.dumps(obj) + "\n").encode(ENCODING)
        try:
            with self.wlock:
                self.sock.sendall(data)
        except OSError:
            self.closed.set()    # client went away; nothing to deliver to

    def reply(self, rid, result) -> None:
        self.send({"id": rid, "ok": True, "result": jsonify(result)})

    def fail(self, rid, exc: BaseException) -> None:
        self.send({"id": rid, "ok": False, "error": _error_obj(exc)})

    def close(self) -> None:
        self.closed.set()
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self.sock.close()


class EvalServer:
    """Serve one :class:`Session` over NDJSON/TCP.

    >>> ses = Session(get_board("zc706"))
    >>> with EvalServer(ses) as srv:           # binds 127.0.0.1, any port
    ...     host, port = srv.address
    ...     ...                                # point ServeClient at it

    ``port=0`` (the default) binds an ephemeral port — read it back from
    :attr:`address`.  The server owns its sockets and threads but NOT the
    session: ``stop()`` drains in-flight requests and closes connections;
    closing the session is the caller's job (one session can outlive many
    servers, or serve local callers concurrently).
    """

    def __init__(self, session: Session, host: str = "127.0.0.1",
                 port: int = 0, *, default_priority: str = "interactive"):
        if default_priority not in PRIORITIES:
            raise ValueError(f"unknown priority {default_priority!r}; "
                             f"known: {PRIORITIES}")
        self.session = session
        self._host = host
        self._port = port
        self.default_priority = default_priority
        self._lsock: socket.socket | None = None
        self._accept_thread: threading.Thread | None = None
        self._lock = threading.Lock()
        self._conns: set[_Connection] = set()
        self._inflight: set = set()          # futures not yet delivered
        self._idle = threading.Condition(self._lock)
        self._stopping = threading.Event()
        self.requests_served = 0

    # ---- lifecycle -------------------------------------------------------
    def start(self) -> "EvalServer":
        if self._lsock is not None:
            return self
        ls = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        ls.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        ls.bind((self._host, self._port))
        ls.listen(64)
        self._lsock = ls
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="repro-serve-accept",
            daemon=True)
        self._accept_thread.start()
        return self

    @property
    def address(self) -> tuple[str, int]:
        """``(host, port)`` actually bound (resolves ``port=0``)."""
        if self._lsock is None:
            raise RuntimeError("server not started; call start() first")
        addr = self._lsock.getsockname()
        return addr[0], addr[1]

    def stop(self, drain: bool = True, timeout: float = 60.0) -> None:
        """Stop accepting, optionally wait for in-flight requests to
        deliver their responses (graceful), then close every connection.
        Idempotent; does NOT close the session."""
        self._stopping.set()
        ls, self._lsock = self._lsock, None
        if ls is not None:
            try:
                # shutdown() wakes the thread blocked in accept();
                # close() alone leaves the listener alive in the kernel
                # until the next connection arrives
                ls.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                ls.close()
            except OSError:
                pass
        if drain:
            with self._idle:
                self._idle.wait_for(lambda: not self._inflight,
                                    timeout=timeout)
        with self._lock:
            conns = list(self._conns)
            self._conns.clear()
        for c in conns:
            c.close()
        t = self._accept_thread
        if t is not None and t is not threading.current_thread():
            t.join(timeout=5.0)

    def __enter__(self) -> "EvalServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ---- accept / read ---------------------------------------------------
    def _accept_loop(self) -> None:
        ls = self._lsock
        while ls is not None and not self._stopping.is_set():
            try:
                sock, _ = ls.accept()
            except OSError:        # listener closed by stop()
                return
            conn = _Connection(self, sock)
            with self._lock:
                if self._stopping.is_set():
                    conn.close()
                    return
                self._conns.add(conn)
            threading.Thread(target=self._read_loop, args=(conn,),
                             name="repro-serve-conn", daemon=True).start()

    def _read_loop(self, conn: _Connection) -> None:
        buf = b""
        try:
            while not conn.closed.is_set():
                data = conn.sock.recv(65536)
                if not data:
                    break
                buf += data
                while b"\n" in buf:
                    line, buf = buf.split(b"\n", 1)
                    if line.strip():
                        self._handle_line(conn, line)
        except OSError:
            pass
        finally:
            conn.closed.set()
            with self._lock:
                self._conns.discard(conn)

    # ---- dispatch --------------------------------------------------------
    def _handle_line(self, conn: _Connection, line: bytes) -> None:
        rid = None
        try:
            try:
                msg = json.loads(line.decode(ENCODING))
            except (UnicodeDecodeError, json.JSONDecodeError) as e:
                raise EvalError(EvalError.INVALID_INPUT,
                                f"malformed request line: {e}") from e
            if not isinstance(msg, dict):
                raise EvalError(EvalError.INVALID_INPUT,
                                "request must be a JSON object")
            rid = msg.get("id")
            op = msg.get("op")
            if op not in OPS:
                raise EvalError(EvalError.INVALID_INPUT,
                                f"unknown op {op!r}; known: {OPS}")
            getattr(self, f"_op_{op}")(conn, rid, msg)
        except BaseException as e:  # noqa: BLE001 — wire error boundary
            conn.fail(rid, e)
            if not isinstance(e, Exception):
                raise

    def _track(self, conn: _Connection, rid, future, on_result) -> None:
        """Register ``future`` as in-flight and deliver its outcome to
        ``conn`` when it resolves — from whatever thread resolves it."""
        with self._lock:
            self._inflight.add(future)

        def done(f) -> None:
            # reply BEFORE leaving the in-flight set: stop(drain=True)
            # closes connections as soon as the set empties, and a
            # drained shutdown must deliver every accepted response
            try:
                try:
                    res = f.result()
                except BaseException as e:  # noqa: BLE001 — wire boundary
                    conn.fail(rid, e)
                    return
                try:
                    conn.reply(rid, on_result(res))
                    self.requests_served += 1
                except BaseException as e:  # noqa: BLE001
                    conn.fail(rid, e)
            finally:
                with self._idle:
                    self._inflight.discard(f)
                    self._idle.notify_all()

        future.add_done_callback(done)

    # ---- ops -------------------------------------------------------------
    @staticmethod
    def _net(msg, key: str = "net") -> Network:
        name = msg.get(key)
        if not isinstance(name, str):
            raise EvalError(EvalError.INVALID_INPUT,
                            f"{key!r} must be a CNN name string, "
                            f"got {name!r}")
        try:
            return get_cnn(name)
        except KeyError as e:
            raise EvalError(EvalError.INVALID_INPUT, str(e)) from e

    @staticmethod
    def _board(msg):
        name = msg.get("board")
        if name is None:
            return None          # session default board
        try:
            return get_board(name)
        except KeyError as e:
            raise EvalError(EvalError.INVALID_INPUT, str(e)) from e

    def _op_ping(self, conn, rid, msg) -> None:
        conn.reply(rid, {"pong": True})

    def _op_observability(self, conn, rid, msg) -> None:
        conn.reply(rid, self.session.observability())

    def _op_shutdown(self, conn, rid, msg) -> None:
        conn.reply(rid, {"stopping": True})
        threading.Thread(target=self.stop,
                         kwargs={"drain": bool(msg.get("drain", True))},
                         name="repro-serve-shutdown", daemon=True).start()

    def _op_evaluate(self, conn, rid, msg) -> None:
        designs = msg.get("designs")
        if isinstance(designs, str):
            designs = [designs]
        if not isinstance(designs, list) or not designs \
                or not all(isinstance(d, str) for d in designs):
            raise EvalError(EvalError.INVALID_INPUT,
                            "'designs' must be a notation string or a "
                            "non-empty list of notation strings")
        scalar = isinstance(msg.get("designs"), str)
        fut = self.session.submit(
            designs[0] if scalar else designs, self._net(msg),
            self._board(msg),
            deadline_s=msg.get("deadline_s"),
            priority=msg.get("priority", self.default_priority))
        self._track(conn, rid, fut, lambda m: m)

    def _op_explore(self, conn, rid, msg) -> None:
        fut = self.session.submit_search(
            self._net(msg), int(msg.get("n", 4096)), self._board(msg),
            deadline_s=msg.get("deadline_s"),
            checkpoint_path=msg.get("checkpoint_path"),
            checkpoint_interval=int(msg.get("checkpoint_interval", 8)),
            **{k: msg[k] for k in ("strategy", "family", "seed", "chunk")
               if k in msg})
        self._track(conn, rid, fut, summarize_search)

    def _op_deploy(self, conn, rid, msg) -> None:
        names = msg.get("nets")
        if not isinstance(names, list) or len(names) < 2:
            raise EvalError(EvalError.INVALID_INPUT,
                            "'nets' must be a list of >= 2 CNN names")
        nets = [self._net({"net": n}) for n in names]
        fut = self.session.submit_search(
            nets, int(msg.get("n", 512)), self._board(msg),
            deadline_s=msg.get("deadline_s"),
            checkpoint_path=msg.get("checkpoint_path"),
            checkpoint_interval=int(msg.get("checkpoint_interval", 8)),
            **{k: msg[k] for k in ("strategy", "seed", "chunk",
                                   "objective", "weights", "slo_s")
               if k in msg})
        self._track(conn, rid, fut, summarize_search)
