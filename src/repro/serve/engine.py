"""Batched serving engine: prefill + decode loop over a request batch.

The serve-side counterpart of ``launch/train.py``:

* ``prefill`` runs the whole (padded) prompt batch once and builds the KV
  (or SSM-state) cache with headroom ``max_new_tokens``;
* ``decode`` iterates single-token steps under jit (cache donated — the
  decode loop is allocation-free after the first step);
* sampling: greedy or temperature; stop tokens honoured per slot;
* static batching: requests are right-aligned padded to the batch's max
  prompt (the assignment's serve shapes are fixed-batch; continuous
  batching would slot-swap finished rows — noted in DESIGN.md).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models.registry import get_model
from ..models.runtime import Runtime


@dataclass
class GenerationResult:
    tokens: list[list[int]]
    n_prefill: int
    n_steps: int
    prefill_s: float = 0.0
    decode_s: float = 0.0

    @property
    def tokens_per_s(self) -> float:
        n = sum(len(t) for t in self.tokens)
        return n / self.decode_s if self.decode_s else float("inf")


@dataclass
class ServeEngine:
    cfg: ModelConfig
    rt: Runtime = field(default_factory=Runtime)
    temperature: float = 0.0
    seed: int = 0

    def __post_init__(self):
        self.api = get_model(self.cfg)
        self._decode = jax.jit(
            lambda p, c, t: self.api.decode_step(p, c, t, self.rt),
            donate_argnums=(1,))
        self._prefill = jax.jit(
            lambda p, b, ml: self.api.prefill(p, b, self.rt, max_len=ml),
            static_argnums=(2,))

    def _sample(self, logits, key):
        logits = logits[:, -1, :self.cfg.vocab_size]
        if self.temperature <= 0.0:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.temperature, -1).astype(jnp.int32)

    def generate(self, params, prompts: list[list[int]], *,
                 max_new_tokens: int = 32,
                 stop_token: int | None = None,
                 extra_inputs: dict | None = None) -> GenerationResult:
        import time
        B = len(prompts)
        Lp = max(len(p) for p in prompts)
        toks = np.zeros((B, Lp), np.int32)
        for i, p in enumerate(prompts):          # right-align (causal LM)
            toks[i, Lp - len(p):] = p
        batch = {"tokens": jnp.asarray(toks)}
        if extra_inputs:
            batch.update(extra_inputs)
        max_len = Lp + max_new_tokens + 1

        t0 = time.time()
        logits, cache = self._prefill(params, batch, max_len)
        logits.block_until_ready()
        t1 = time.time()

        key = jax.random.key(self.seed)
        out = [[] for _ in range(B)]
        done = np.zeros(B, bool)
        tok = self._sample(logits, key)
        steps = 0
        for step in range(max_new_tokens):
            t_host = np.asarray(tok)
            for i in range(B):
                if not done[i]:
                    out[i].append(int(t_host[i]))
                    if stop_token is not None and t_host[i] == stop_token:
                        done[i] = True
            steps += 1
            if done.all():
                break
            key, sub = jax.random.split(key)
            logits, cache = self._decode(params, cache, tok[:, None])
            tok = self._sample(logits, sub)
        jax.block_until_ready(tok)
        t2 = time.time()
        return GenerationResult(tokens=out, n_prefill=Lp, n_steps=steps,
                                prefill_s=t1 - t0, decode_s=t2 - t1)
