"""Version shims for the jax API surface this repo uses.

The container pins an older jax than the code was written against; every
difference is bridged here (and only here) so call sites stay on the
modern spelling:

* ``shard_map`` — top-level ``jax.shard_map`` (new) vs
  ``jax.experimental.shard_map.shard_map`` (old); the new ``check_vma``
  kwarg maps onto the old ``check_rep``.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, **kwargs):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    if "check_vma" in kwargs:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kwargs)
