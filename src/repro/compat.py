"""Version shims for the jax API surface this repo uses.

The container pins an older jax than the code was written against; every
difference is bridged here (and only here) so call sites stay on the
modern spelling:

* ``shard_map`` — top-level ``jax.shard_map`` (new) vs
  ``jax.experimental.shard_map.shard_map`` (old); the new ``check_vma``
  kwarg maps onto the old ``check_rep``.
"""
from __future__ import annotations

import os

import jax

#: opt-in persistent compilation cache (see docs/perf.md): point this env
#: var at a directory and compiled programs survive process restarts.
CACHE_ENV = "REPRO_JAX_CACHE_DIR"


def enable_persistent_compilation_cache(cache_dir: str | None = None
                                        ) -> str | None:
    """Enable jax's on-disk compilation cache: an explicit ``cache_dir``
    wins (what ``EvalConfig.cache_dir`` passes), else ``REPRO_JAX_CACHE_DIR``
    is read.  Returns the cache dir (or None when disabled).  Idempotent —
    safe to call from every entry point."""
    cache_dir = cache_dir or os.environ.get(CACHE_ENV)
    if not cache_dir:
        return None
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    # the DSE programs compile in ~1s; cache them all, not just the slow ones
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", 0)
    return cache_dir


def shard_map(f, *, mesh, in_specs, out_specs, **kwargs):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    if "check_vma" in kwargs:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kwargs)
