"""Hardware constants for the roofline terms (assignment-specified v5e)."""
from ..tpu.chip import V5E

PEAK_BF16 = V5E.peak_flops_bf16          # 197e12 FLOP/s per chip
HBM_BW = V5E.hbm_bytes_per_s             # 819e9  B/s per chip
ICI_BW = V5E.ici_link_bytes_per_s        # 50e9   B/s per link
ICI_LINKS = V5E.ici_links
HBM_CAP = V5E.hbm_capacity               # 16 GiB
