"""Roofline analysis over the dry-run artifacts (deliverable g).

For every (arch × shape × mesh) cell this derives, from the compiled HLO
(trip-count-aware ``hlo_walk`` numbers recorded by ``launch/dryrun.py``):

    compute_s    = HLO_FLOPs_per_device / peak_FLOP/s
    memory_s     = HLO_bytes_per_device / HBM_bw
    collective_s = collective_wire_bytes_per_device / (links × link_bw)

plus MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) and the
MODEL/HLO ratio (remat & padding waste), the dominant term, and a one-line
"what would move it" recommendation.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass

from ..configs import SHAPES, get_config
from .constants import HBM_BW, ICI_BW, ICI_LINKS, PEAK_BF16

ART_DIR = os.environ.get(
    "REPRO_DRYRUN_DIR",
    os.path.join(os.path.dirname(__file__), "..", "..", "..",
                 "artifacts", "dryrun"))


def model_flops(arch: str, shape_name: str) -> float:
    """6·N·D with N = total (dense) or active (MoE) params, D = tokens
    processed per step; decode steps process global_batch tokens."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.param_count(active_only=bool(cfg.n_experts))
    if shape.kind == "train":
        tokens, mult = shape.tokens, 6.0
    elif shape.kind == "prefill":
        tokens, mult = shape.tokens, 2.0
    else:
        tokens, mult = float(shape.global_batch), 2.0
    return mult * n * tokens


@dataclass
class CellRoofline:
    cell: str
    arch: str
    shape: str
    mesh: str
    n_dev: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops_per_dev: float
    useful_ratio: float          # MODEL / (HLO × devices)
    peak_fraction: float         # compute_s / max(term)s — roofline fraction
    hbm_args_gib: float
    hbm_temp_gib: float
    recommendation: str

    def as_row(self) -> list:
        return [self.arch, self.shape, self.mesh,
                f"{self.compute_s*1e3:.1f}", f"{self.memory_s*1e3:.1f}",
                f"{self.collective_s*1e3:.1f}", self.dominant,
                f"{self.useful_ratio:.2f}", f"{self.peak_fraction:.2f}",
                f"{self.hbm_args_gib + self.hbm_temp_gib:.1f}"]


_RECS = {
    "compute": "compute-bound: raise MXU utilisation (pad-free tiles, "
               "larger per-device matmuls — widen TP shards or batch)",
    "memory": "HBM-bound: cut activation traffic (flash/custom-VJP, fewer "
              "saved residuals, fused optimizer) or shard reads wider",
    "collective": "ICI-bound: reduce wire bytes (coarser FSDP gathers, "
                  "a2a instead of psum, gradient compression) or overlap "
                  "collectives with compute",
}


def analyze_cell(rec: dict) -> CellRoofline:
    walk = rec["walk"]
    n_dev = 1
    for v in rec["mesh_shape"].values():
        n_dev *= v
    comp = walk["flops"] / PEAK_BF16
    mem = walk["bytes_accessed"] / HBM_BW
    coll = walk["total_wire_bytes"] / (ICI_BW * ICI_LINKS)
    terms = {"compute": comp, "memory": mem, "collective": coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    useful = mf / max(walk["flops"] * n_dev, 1.0)
    peak_frac = comp / max(max(terms.values()), 1e-12)
    memo = rec.get("memory", {})
    return CellRoofline(
        cell=rec["cell"], arch=rec["arch"], shape=rec["shape"],
        mesh=rec["mesh"], n_dev=n_dev,
        compute_s=comp, memory_s=mem, collective_s=coll, dominant=dom,
        model_flops=mf, hlo_flops_per_dev=walk["flops"],
        useful_ratio=useful, peak_fraction=peak_frac,
        hbm_args_gib=memo.get("argument_size_in_bytes", 0) / 2**30,
        hbm_temp_gib=memo.get("temp_size_in_bytes", 0) / 2**30,
        recommendation=_RECS[dom],
    )


def load_artifacts(art_dir: str = ART_DIR, mesh: str | None = None
                   ) -> list[dict]:
    recs = []
    if not os.path.isdir(art_dir):
        return recs
    for name in sorted(os.listdir(art_dir)):
        if not name.endswith(".json"):
            continue
        with open(os.path.join(art_dir, name)) as f:
            rec = json.load(f)
        if not rec.get("ok"):
            continue
        if mesh and rec.get("mesh") != mesh:
            continue
        if rec["cell"].count("__") > 2:
            continue  # tagged (hillclimb) artifacts are reported separately
        recs.append(rec)
    return recs
