from .analysis import CellRoofline, analyze_cell, load_artifacts  # noqa: F401
from .constants import HBM_BW, ICI_BW, PEAK_BF16  # noqa: F401
