"""``repro.api`` — the one front door to the MCCM stack.

    from repro.api import Session

    ses = Session(get_board("zc706"))
    m = ses.evaluate("{L1-Last:CE1-CE4}", net)       # scalar Metrics
    out = ses.evaluate([spec_a, spec_b], net)        # batched metric arrays
    dse = ses.explore(net, n=100_000, strategy="search")
    dep = ses.deploy([net_a, net_b], n=4096)
    fut = ses.submit(specs, net)                     # queued, megabatched

One :class:`Session` owns the memoized ``NetTables``/``DeviceTables`` and
the resolved :class:`EvalConfig`, so every call shares the same compiled
programs.  Lifecycle, configuration reference and the migration table from
the deprecated free functions live in ``docs/api.md``.
"""
from __future__ import annotations

from . import telemetry  # noqa: F401
from .core.resilience import (EvalError, load_checkpoint,  # noqa: F401
                              save_checkpoint)
from .core.session import (EvalConfig, Session, SessionStats,
                           default_session)
from .schedule import ScheduleArtifact  # noqa: F401
from .telemetry import bottleneck_report, format_report  # noqa: F401

__all__ = ["EvalConfig", "EvalError", "ScheduleArtifact", "Session",
           "SessionStats", "bottleneck_report", "default_session",
           "format_report", "load_checkpoint", "save_checkpoint",
           "telemetry"]
