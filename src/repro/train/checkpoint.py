"""Fault-tolerant checkpointing with elastic resharding.

Layout (one directory per step)::

    <dir>/step_000100/
        manifest.json      # pytree structure, shapes, dtypes, mesh+plan info
        arrays.npz         # flat {path -> ndarray}
        COMMIT             # written last: a checkpoint without it is partial

Restore semantics:
* ``restore(dir)`` -> latest *committed* step (partial writes from a killed
  process are skipped — crash-safe by construction);
* the target mesh/sharding may differ from the one that saved (elastic
  scaling): arrays are re-placed with ``jax.device_put`` under the new
  sharding, which is exactly a logical reshard.

At true multi-host scale each process would write only its addressable
shards (same manifest, per-process array files); the single-process CPU
container exercises the full save -> crash -> restore -> reshard path.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any

_SEP = "/"


def _flatten(tree: Pytree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            # npz cannot round-trip ml_dtypes; store the raw bits
            arr = arr.view(np.uint16)
        flat[key] = arr
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(directory: str, step: int, state: Pytree, *, extra: dict | None = None,
         keep: int = 3) -> str:
    """Atomically write a committed checkpoint; prune old ones."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    try:
        flat = _flatten(state)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        treedef = jax.tree_util.tree_structure(state)
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "keys": sorted(flat),
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        with open(os.path.join(tmp, "COMMIT"), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _prune(directory, keep)
    return final


def _prune(directory: str, keep: int):
    steps = committed_steps(directory)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)


def committed_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and os.path.exists(
                os.path.join(directory, name, "COMMIT")):
            out.append(int(name.split("_")[1]))
    return sorted(out)


def latest_step(directory: str) -> int | None:
    steps = committed_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, target: Pytree, *, step: int | None = None,
            shardings: Pytree | None = None) -> Pytree:
    """Restore into the structure of ``target`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional pytree of NamedShardings for
    elastic re-placement onto a (possibly different) mesh."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}

    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(target)
    out_leaves = []
    for p, leaf in leaves_with_path:
        key = _SEP.join(_path_str(q) for q in p)
        if key not in flat:
            raise KeyError(f"checkpoint {path} missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != target {leaf.shape}")
        if leaf.dtype == jnp.bfloat16 and arr.dtype == np.uint16:
            arr = arr.view(jnp.bfloat16)   # bit-exact restore
        out_leaves.append(arr.astype(leaf.dtype))
    restored = jax.tree_util.tree_unflatten(treedef, out_leaves)
    if shardings is not None:
        restored = jax.tree.map(
            lambda a, s: jax.device_put(a, s), restored, shardings)
    else:
        restored = jax.tree.map(jnp.asarray, restored)
    return restored


def manifest(directory: str, step: int | None = None) -> dict:
    if step is None:
        step = latest_step(directory)
    with open(os.path.join(directory, f"step_{step:08d}", "manifest.json")) as f:
        return json.load(f)
