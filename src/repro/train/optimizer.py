"""Optimizers: AdamW and factored-second-moment (Adafactor-style) AdamW.

Self-contained (no optax).  Design points that matter at 1000-node scale:

* ``state_dtype`` — bf16 first/second moments halve optimizer HBM (with
  stochastic-rounding-style update in fp32 before casting back);
* ``factored=True`` — the second moment of every >=2-D weight is stored as a
  row+column factor pair (Adafactor), O(d1+d2) instead of O(d1*d2).  This is
  what lets the 1T-param kimi-k2 cell fit 512 x 16 GiB HBM (see
  EXPERIMENTS.md §Dry-run);
* the update is a pure pytree map — it inherits the parameter shardings, so
  optimizer state is automatically ZeRO-sharded wherever params are.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

Pytree = Any


# --------------------------------------------------------------------------
# LR schedules
# --------------------------------------------------------------------------
def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)
    return lr


def constant_lr(v: float) -> Callable:
    return lambda step: jnp.asarray(v, jnp.float32)


# --------------------------------------------------------------------------
# gradient utilities
# --------------------------------------------------------------------------
def global_norm(tree: Pytree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree: Pytree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        tree), norm


# --------------------------------------------------------------------------
# AdamW (+ factored option)
# --------------------------------------------------------------------------
def _should_factor(shape) -> bool:
    return len(shape) >= 2 and shape[-1] >= 128 and shape[-2] >= 128


@dataclass(frozen=True)
class AdamW:
    lr: Callable = constant_lr(1e-4)
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    state_dtype: str = "float32"
    factored: bool = False            # Adafactor-style v for big matrices
    momentum: bool = True             # False (Adafactor b1=0) drops the m
                                      # buffer — 2 bytes/param the 1T cell
                                      # cannot afford (EXPERIMENTS.md §Dry-run)
    max_grad_norm: float = 1.0

    # ---- state ----
    def init(self, params: Pytree) -> Pytree:
        sd = jnp.dtype(self.state_dtype)

        def leaf_state(p):
            st = {"m": jnp.zeros(p.shape, sd)} if self.momentum else {}
            if self.factored and _should_factor(p.shape):
                st["v_row"] = jnp.zeros(p.shape[:-1], jnp.float32)
                st["v_col"] = jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            else:
                st["v"] = jnp.zeros(p.shape, sd)
            return st

        return {
            "mu": jax.tree.map(leaf_state, params),
            "count": jnp.zeros((), jnp.int32),
        }

    # ---- update ----
    def update(self, grads: Pytree, state: Pytree, params: Pytree):
        count = state["count"] + 1
        grads, gnorm = clip_by_global_norm(grads, self.max_grad_norm)
        b1 = self.b1 if self.momentum else 0.0
        c1 = 1.0 - b1 ** count.astype(jnp.float32)
        c2 = 1.0 - self.b2 ** count.astype(jnp.float32)
        lr = self.lr(count)

        def leaf(p, g, st):
            g = g.astype(jnp.float32)
            m = (st["m"].astype(jnp.float32) * b1 + g * (1 - b1)
                 if self.momentum else g)
            if "v" in st:
                v = st["v"].astype(jnp.float32) * self.b2 + g * g * (1 - self.b2)
                vhat = v / c2
                new_v = {"v": v.astype(st["v"].dtype)}
            else:
                g2 = g * g + 1e-30
                v_row = st["v_row"] * self.b2 + g2.mean(-1) * (1 - self.b2)
                v_col = st["v_col"] * self.b2 + g2.mean(-2) * (1 - self.b2)
                # rank-1 reconstruction (Adafactor): R*C / mean(R)
                denom = v_row.mean(-1, keepdims=True) + 1e-30
                vhat = (v_row[..., None] * v_col[..., None, :]
                        / denom[..., None]) / c2
                new_v = {"v_row": v_row, "v_col": v_col}
            upd = (m / c1) / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay and p.ndim >= 2:
                upd = upd + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
            new_st = ({"m": m.astype(st["m"].dtype), **new_v}
                      if self.momentum else new_v)
            return new_p, new_st

        flat = jax.tree.map(leaf, params, grads, state["mu"],
                            is_leaf=lambda x: isinstance(x, dict)
                            and ("m" in x or "v" in x or "v_row" in x))
        new_params = jax.tree.map(lambda t: t[0], flat,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_mu = jax.tree.map(lambda t: t[1], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"mu": new_mu, "count": count}, gnorm


def make_optimizer(name: str = "adamw", *, peak_lr: float = 3e-4,
                   warmup: int = 100, total_steps: int = 10_000,
                   weight_decay: float = 0.1, state_dtype: str = "float32",
                   factored: bool = False, momentum: bool = True,
                   max_grad_norm: float = 1.0) -> AdamW:
    if name not in ("adamw", "adafactor"):
        raise KeyError(f"unknown optimizer {name!r}")
    return AdamW(
        lr=warmup_cosine(peak_lr, warmup, total_steps),
        weight_decay=weight_decay,
        state_dtype=state_dtype,
        factored=factored or name == "adafactor",
        momentum=momentum,
        max_grad_norm=max_grad_norm,
    )
