"""Train step factory: value_and_grad + optimizer, SPMD-ready.

Two step flavours:

* :func:`make_train_step` — canonical pjit path.  Batch is sharded over the
  dp axes; XLA inserts the gradient reduce-scatters/all-reduces implied by
  the parameter shardings (FSDP-style when params are dp-sharded).
* :func:`make_compressed_train_step` — explicit-DDP path via ``shard_map``:
  per-shard gradients are exchanged with an int8-quantised all-reduce with
  error-feedback residuals (gradient compression for slow cross-pod links).
  4x fewer bytes on the wire per step; see tests/test_train.py for the
  convergence check and EXPERIMENTS.md §Perf for the collective-bytes delta.

Gradient accumulation (microbatching) happens *inside* the step via
``lax.scan`` so the lowered HLO matches what runs on the pod.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from .optimizer import AdamW

Pytree = Any


@jax.tree_util.register_dataclass
@dataclass
class TrainState:
    params: Pytree
    opt: Pytree
    step: jax.Array


def init_state(api, opt: AdamW, key) -> TrainState:
    params = api.init(key)
    return TrainState(params=params, opt=opt.init(params),
                      step=jnp.zeros((), jnp.int32))


def _split_microbatches(batch: dict, accum: int) -> dict:
    return jax.tree.map(
        lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]), batch)


def make_train_step(api, rt, opt: AdamW, *, accum: int = 1,
                    donate: bool = True):
    """Returns step(state, batch) -> (state, metrics); un-jitted."""

    def lossfn(params, mb):
        loss, metrics = api.loss(params, mb, rt)
        return loss, metrics

    def step(state: TrainState, batch: dict):
        if accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                lossfn, has_aux=True)(state.params, batch)
        else:
            mbs = _split_microbatches(batch, accum)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (l, m), g = jax.value_and_grad(lossfn, has_aux=True)(
                    state.params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), m

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              state.params)
            (grads, loss), metrics = lax.scan(
                acc_body, (g0, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss / accum
            metrics = jax.tree.map(lambda m: m.mean(), metrics)

        new_params, new_opt, gnorm = opt.update(grads, state.opt, state.params)
        metrics = dict(metrics)
        metrics.update(loss=loss, grad_norm=gnorm)
        return TrainState(params=new_params, opt=new_opt,
                          step=state.step + 1), metrics

    return step


# --------------------------------------------------------------------------
# gradient compression (int8 quantised all-reduce with error feedback)
# --------------------------------------------------------------------------
def quantize_int8(x: jax.Array):
    """Per-tensor symmetric int8 quantisation. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(x, axis: str, residual, n_shards: int):
    """int8 mean-all-reduce of ``x`` over ``axis`` with error feedback.

    Wire protocol (what the HLO shows, and what a TPU pod would move):
      1. pmax of the local absmax -> one shared fp32 scale;
      2. quantise to int8, ``all_to_all`` the int8 chunks (1 B/elt);
      3. local int32 sum, requantise the mean to int8;
      4. ``all_gather`` the int8 partial means (1 B/elt).
    Total 2 B/elt on the wire vs 8 B/elt for an fp32 ring all-reduce — 4x
    compression.  The quantisation error stays local as an error-feedback
    residual re-added next step, restoring near-fp32 convergence
    (tests/test_train.py::test_compressed_ddp_matches_fp32).
    """
    xc = x.astype(jnp.float32) + residual
    shape = xc.shape
    flat = xc.reshape(-1)
    n = flat.shape[0]
    pad = -n % n_shards
    if pad:
        flat = jnp.pad(flat, (0, pad))
    scale = lax.pmax(jnp.max(jnp.abs(flat)), axis) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    qt = q.reshape(n_shards, -1)
    recv = lax.all_to_all(qt, axis, split_axis=0, concat_axis=0, tiled=True)
    part = recv.astype(jnp.int32).reshape(n_shards, -1).sum(0)  # my chunk's sum
    mean_chunk = part.astype(jnp.float32) / n_shards            # in scale units
    q2 = jnp.clip(jnp.round(mean_chunk), -127, 127).astype(jnp.int8)
    full = lax.all_gather(q2, axis, tiled=True).astype(jnp.float32) * scale
    out = full[:n].reshape(shape)
    # error feedback: what this shard failed to transmit
    deq_local = q.astype(jnp.float32)[:n].reshape(shape) * scale
    new_residual = xc - deq_local
    return out, new_residual


def make_compressed_train_step(api, rt, opt: AdamW, *, axis: str,
                               n_shards: int):
    """DDP train step with int8-compressed gradient all-reduce.

    Must run under ``shard_map`` over the dp axis (see launch/train.py); the
    state carries per-param error-feedback residuals.
    """
    import dataclasses

    # inside shard_map every mesh axis is manual — sharding constraints are
    # illegal; drop the mesh so rt.constrain becomes a no-op
    rt = dataclasses.replace(rt, mesh=None)

    def lossfn(params, mb):
        return api.loss(params, mb, rt)

    def step(state: TrainState, residuals: Pytree, batch: dict):
        (loss, metrics), grads = jax.value_and_grad(
            lossfn, has_aux=True)(state.params, batch)

        def red(g, r):
            return compressed_psum(g, axis, r, n_shards)

        flat = jax.tree.map(red, grads, residuals)
        grads = jax.tree.map(lambda t: t[0], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_res = jax.tree.map(lambda t: t[1], flat,
                               is_leaf=lambda x: isinstance(x, tuple))
        loss = lax.pmean(loss, axis)
        metrics = jax.tree.map(lambda m: lax.pmean(m, axis), metrics)
        new_params, new_opt, gnorm = opt.update(grads, state.opt, state.params)
        metrics = dict(metrics)
        metrics.update(loss=loss, grad_norm=gnorm)
        return TrainState(params=new_params, opt=new_opt,
                          step=state.step + 1), new_res, metrics

    return step


def init_residuals(params: Pytree) -> Pytree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
