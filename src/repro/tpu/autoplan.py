"""Plan-space DSE (paper use case 3, TPU form).

Where the FPGA DSE explores CE arrangements, MCCM-TPU explores
ParallelPlans: FSDP on/off, sequence-sharded activations, remat grouping,
MoE dispatch strategy, loss chunk.  The analytical cost model ranks
thousands of plans in milliseconds; the top plan can then be *verified*
with one XLA dry-run (the "synthesis" of this domain) — the same
fast-model-then-validate loop as the paper.
"""
from __future__ import annotations

import dataclasses
import itertools
from dataclasses import dataclass

from ..configs.base import ModelConfig, ShapeSpec
from ..launch.plans import ParallelPlan, default_plan
from .chip import ChipSpec, V5E
from .cost_model import CostEstimate, estimate


@dataclass
class RankedPlan:
    plan: ParallelPlan
    est: CostEstimate

    @property
    def step_s(self) -> float:
        """Serial roofline bound: max of the three terms (perfect overlap
        would approach this; summing is the no-overlap bound)."""
        return max(self.est.compute_s, self.est.memory_s,
                   self.est.collective_s)


def candidate_plans(cfg: ModelConfig, shape: ShapeSpec, mesh) -> list[ParallelPlan]:
    base = default_plan(cfg, shape, mesh)
    cands: list[ParallelPlan] = []
    if shape.kind == "train":
        fsdp_opts = [(), tuple(base.dp_axes)]
        act_opts = ["none", "seq"]
        remat_opts = [(True, 1), (True, 2), (True, 4), (True, 8), (False, 1)]
        moe_opts = (["ep_a2a", "ep"] if cfg.n_experts else [base.moe_impl])
        chunk_opts = [0, 512, 2048]
        for fsdp, act, (rm, g), moe, ck in itertools.product(
                fsdp_opts, act_opts, remat_opts, moe_opts, chunk_opts):
            cands.append(dataclasses.replace(
                base, fsdp_axes=fsdp, act_shard=act, remat=rm,
                remat_group=g, moe_impl=moe, loss_chunk=ck,
                name=f"{cfg.name}:{shape.name}:fsdp{len(fsdp)}-{act}-g{g}"
                     f"-{moe}-ck{ck}"))
    else:
        fsdp_opts = [(), tuple(base.dp_axes)]
        moe_opts = (["ep_a2a", "ep"] if cfg.n_experts else [base.moe_impl])
        for fsdp, moe in itertools.product(fsdp_opts, moe_opts):
            cands.append(dataclasses.replace(
                base, fsdp_axes=fsdp, moe_impl=moe,
                name=f"{cfg.name}:{shape.name}:fsdp{len(fsdp)}-{moe}"))
    return cands


def rank(cfg: ModelConfig, shape: ShapeSpec, mesh,
         chip: ChipSpec = V5E) -> list[RankedPlan]:
    """Evaluate every candidate plan analytically; feasible-first, fastest
    first."""
    out = [RankedPlan(p, estimate(cfg, shape, p, mesh, chip))
           for p in candidate_plans(cfg, shape, mesh)]
    out.sort(key=lambda r: (not r.est.fits, r.step_s))
    return out


def best_plan(cfg: ModelConfig, shape: ShapeSpec, mesh,
              chip: ChipSpec = V5E) -> RankedPlan:
    return rank(cfg, shape, mesh, chip)[0]
