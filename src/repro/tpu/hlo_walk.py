"""Trip-count-aware cost extraction from optimized HLO text.

``compiled.cost_analysis()`` counts each ``while`` (lax.scan) body ONCE —
for a 61-layer scanned transformer that under-reports FLOPs and collective
bytes by ~61x.  This walker parses the post-SPMD HLO, builds the
computation call graph, and multiplies every while body by its
``backend_config known_trip_count`` so the roofline terms reflect what a
device actually executes.

Extracted per entry module (all **per-device**, since the module is the
SPMD-partitioned program of one device):

* ``flops``          — 2*prod(out)*prod(contracting) per ``dot``,
                       2*prod(out)*prod(kernel)/out_features per
                       ``convolution`` (grouped convs handled);
* ``bytes``          — Σ (operand bytes + output bytes) over compute ops —
                       the fusion-boundary HBM-traffic model (intra-fusion
                       temporaries are free, boundaries pay);
* ``collectives``    — operand / wire bytes per collective kind (ring
                       estimates as in :mod:`hlo_stats`), trip-multiplied;
* ``transcendentals``— exp/log/tanh/... element counts (VPU term).
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0, "s2": 1, "u2": 1,
}

# one array shape like  bf16[16,256]{1,0}  (layout optional)
_ARR = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
# computation header:  %name (args) -> ret {     /  ENTRY %name (...)
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
# op line:  [ROOT] %name = <shape(s)> opcode(operands), attrs
_OP = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*"
    r"(?P<shape>\([^)]*\)|[a-z][a-z0-9]*\[[0-9,]*\](?:\{[0-9,a-zA-Z:()_\s]*\})?)\s+"
    r"(?P<op>[\w\-]+)\((?P<args>.*)$")
_OPERAND = re.compile(r"%([\w.\-]+)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS = re.compile(r"(?:calls|body|to_apply)=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_LHS_BATCH = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")
_DIM_LABELS = re.compile(r"dim_labels=([a-z0-9?]+)_([a-z0-9?]+)->([a-z0-9?]+)")
_FEATURE_GROUPS = re.compile(r"feature_group_count=(\d+)")
_REPLICA_ITOA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_REPLICA_LIST = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_TRANSCENDENTAL = {"exponential", "log", "tanh", "rsqrt", "sqrt", "power",
                   "logistic", "sine", "cosine", "exponential-minus-one",
                   "log-plus-one", "erf", "atan2"}
# ops that don't move data at run time
_FREE = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
         "after-all", "partition-id", "replica-id", "iota", "opt-barrier",
         "custom-call"}

# HBM-traffic model: the CPU backend barely fuses, so charging every op
# boundary models *CPU* fusion, wildly over-counting what XLA-TPU (which
# fuses elementwise/convert/broadcast chains into producers/consumers)
# would move.  Only ops with real data movement on TPU pay bytes; the rest
# are assumed fused.  This is the documented approximation of
# EXPERIMENTS.md §Roofline (validated against the analytical model).
_BYTES_OPS = {"dot", "convolution", "copy", "transpose", "dynamic-slice",
              "dynamic-update-slice", "gather", "scatter", "reduce",
              "reduce-window", "sort", "pad", "concatenate", "reverse",
              "slice", "rng", "rng-bit-generator", "cholesky",
              "triangular-solve", "fft", "select-and-scatter"}


def _shape_info(txt: str) -> tuple[int, tuple[int, ...]]:
    """(bytes, dims) of one (possibly tuple) shape string."""
    total, dims = 0, ()
    for dt, ds in _ARR.findall(txt):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if ds:
            for d in ds.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
        dims = tuple(int(d) for d in ds.split(",")) if ds else ()
    return total, dims


@dataclass
class _Op:
    name: str
    op: str
    out_bytes: int
    out_dims: tuple[int, ...]
    line: str


@dataclass
class _Computation:
    name: str
    ops: list[_Op] = field(default_factory=list)
    # locally accumulated costs (children charged via edges)
    flops: float = 0.0
    bytes_accessed: float = 0.0
    transcendentals: float = 0.0
    coll_operand: dict = field(default_factory=lambda: defaultdict(float))
    coll_wire: dict = field(default_factory=lambda: defaultdict(float))
    coll_count: dict = field(default_factory=lambda: defaultdict(float))
    edges: list[tuple[str, float]] = field(default_factory=list)  # (callee, mult)


def _group_size(line: str, default: int = 1) -> int:
    m = _REPLICA_LIST.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _REPLICA_ITOA.search(line)
    if m:
        return int(m.group(2))
    return default


def parse_hlo(text: str) -> dict[str, _Computation]:
    comps: dict[str, _Computation] = {}
    entry: str | None = None
    cur: _Computation | None = None
    shapes: dict[str, tuple[int, tuple[int, ...]]] = {}

    for raw in text.splitlines():
        hdr = _COMP_HDR.match(raw.strip())
        if hdr:
            cur = _Computation(name=hdr.group(2))
            comps[cur.name] = cur
            if hdr.group(1):
                entry = cur.name
            shapes = {}
            continue
        if cur is None:
            continue
        if raw.strip() == "}":
            cur = None
            continue
        m = _OP.match(raw)
        if not m:
            continue
        name, shape_txt, op = m.group("name"), m.group("shape"), m.group("op")
        out_bytes, out_dims = _shape_info(shape_txt)
        shapes[name] = (out_bytes, out_dims)
        base_op = re.sub(r"-(start|done|update)$", "", op)

        # --- call edges ---
        if op == "while":
            t = _TRIP.search(raw)
            trip = float(t.group(1)) if t else 1.0
            c = _CALLS.search(raw)  # body=%comp
            if c:
                cur.edges.append((c.group(1), trip))
            # carry is aliased in place; traffic is modelled by the body's
            # copies / dynamic-(update-)slices, not the while op itself
            continue
        if op in ("fusion", "call", "async-start"):
            c = _CALLS.search(raw)
            if c:
                cur.edges.append((c.group(1), 1.0))
        if op == "conditional":
            b = _BRANCHES.search(raw)
            if b:
                for br in _OPERAND.findall(b.group(1)):
                    cur.edges.append((br, 1.0))

        # --- operand bytes (locally defined names only) ---
        operand_bytes = 0
        args_txt = m.group("args")
        # cut attrs after the closing paren of the operand list: heuristic —
        # operands are leading %refs before any ), attr
        operand_refs = _OPERAND.findall(args_txt.split("),", 1)[0])
        for ref in operand_refs:
            if ref in shapes:
                operand_bytes += shapes[ref][0]

        # slicing ops touch the *slice*, not the whole (aliased) buffer —
        # critical for scan-stacked (L, ...) tensors or the count explodes L^2
        if op == "dynamic-slice":
            cur.bytes_accessed += 2 * out_bytes        # read slice + write
            continue
        if op == "dynamic-update-slice":
            upd = (shapes[operand_refs[1]][0]
                   if len(operand_refs) > 1 and operand_refs[1] in shapes
                   else out_bytes)
            cur.bytes_accessed += 2 * upd              # read update + write slice
            continue

        if op.endswith("-done"):
            continue  # counted at -start

        # --- collectives ---
        if base_op in _COLLECTIVES:
            n = _group_size(raw)
            frac = (n - 1) / n if n > 1 else 0.0
            size = max(out_bytes, operand_bytes)
            cur.coll_count[base_op] += 1
            cur.coll_operand[base_op] += operand_bytes or out_bytes
            if base_op == "all-reduce":
                cur.coll_wire[base_op] += 2 * size * frac
            elif base_op == "collective-permute":
                cur.coll_wire[base_op] += size
            else:
                cur.coll_wire[base_op] += size * frac
            cur.bytes_accessed += operand_bytes + out_bytes
            continue

        # --- flops ---
        if op == "dot":
            contract = 1
            lhs_ref = _OPERAND.findall(args_txt)
            lc = _LHS_CONTRACT.search(raw)
            if lhs_ref and lc and lhs_ref[0] in shapes:
                lhs_dims = shapes[lhs_ref[0]][1]
                for d in filter(None, lc.group(1).split(",")):
                    di = int(d)
                    if di < len(lhs_dims):
                        contract *= lhs_dims[di]
            out_elems = 1
            for d in out_dims:
                out_elems *= d
            cur.flops += 2.0 * out_elems * contract
        elif op == "convolution":
            out_elems = 1
            for d in out_dims:
                out_elems *= d
            refs = _OPERAND.findall(args_txt)
            k_elems, k_out = 1, 1
            if len(refs) >= 2 and refs[1] in shapes:
                k_dims = shapes[refs[1]][1]
                for d in k_dims:
                    k_elems *= d
                dl = _DIM_LABELS.search(raw)
                if dl:
                    kernel_labels = dl.group(2)
                    if "o" in kernel_labels:
                        k_out = k_dims[kernel_labels.index("o")]
            fg = _FEATURE_GROUPS.search(raw)
            groups = int(fg.group(1)) if fg else 1
            cur.flops += 2.0 * out_elems * (k_elems / max(k_out, 1)) / max(groups, 1) * groups / groups
            # note: k_elems/k_out = per-output-feature kernel volume (already
            # includes in_channels/groups for grouped convs)
        elif op in _TRANSCENDENTAL:
            out_elems = 1
            for d in out_dims:
                out_elems *= d
            cur.transcendentals += out_elems

        # --- bytes (TPU-fusion model: see _BYTES_OPS) ---
        if op in _BYTES_OPS:
            cur.bytes_accessed += operand_bytes + out_bytes

    if entry is None:
        raise ValueError("no ENTRY computation found in HLO text")
    comps["__entry__"] = comps[entry]
    return comps


@dataclass
class WalkCosts:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    transcendentals: float = 0.0
    coll_operand: dict = field(default_factory=dict)
    coll_wire: dict = field(default_factory=dict)
    coll_count: dict = field(default_factory=dict)

    @property
    def total_wire(self) -> float:
        return sum(self.coll_wire.values())

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "transcendentals": self.transcendentals,
            "collective_operand_bytes": dict(self.coll_operand),
            "collective_wire_bytes": dict(self.coll_wire),
            "collective_counts": dict(self.coll_count),
            "total_wire_bytes": self.total_wire,
        }


def walk(text: str) -> WalkCosts:
    """Total per-device costs of the entry module, trip-count multiplied."""
    comps = parse_hlo(text)
    memo: dict[str, tuple] = {}

    def total(name: str) -> tuple:
        if name in memo:
            return memo[name]
        c = comps.get(name)
        if c is None:
            return (0.0, 0.0, 0.0, {}, {}, {})
        memo[name] = (0.0,) * 3 + ({},) * 3  # cycle guard (shouldn't happen)
        fl, by, tr = c.flops, c.bytes_accessed, c.transcendentals
        co = defaultdict(float, c.coll_operand)
        cw = defaultdict(float, c.coll_wire)
        cc = defaultdict(float, c.coll_count)
        for callee, mult in c.edges:
            sfl, sby, str_, sco, scw, scc = total(callee)
            fl += mult * sfl
            by += mult * sby
            tr += mult * str_
            for k, v in sco.items():
                co[k] += mult * v
            for k, v in scw.items():
                cw[k] += mult * v
            for k, v in scc.items():
                cc[k] += mult * v
        memo[name] = (fl, by, tr, dict(co), dict(cw), dict(cc))
        return memo[name]

    fl, by, tr, co, cw, cc = total("__entry__")
    return WalkCosts(flops=fl, bytes_accessed=by, transcendentals=tr,
                     coll_operand=co, coll_wire=cw, coll_count=cc)
