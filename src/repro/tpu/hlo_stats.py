"""Post-SPMD HLO statistics: collective bytes, op census, remat waste.

``compiled.cost_analysis()`` gives FLOPs and HBM bytes but *not* collective
traffic — we recover it by parsing the optimized (post-partitioning) HLO
text for all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops and summing operand sizes.

Byte conventions (documented in EXPERIMENTS.md §Roofline):
* ``operand_bytes``  — sum of input-shape bytes of each collective op, per
  device (what the op touches);
* ``wire_bytes``     — ring-algorithm estimate of bytes a device actually
  moves: all-reduce 2x(n-1)/n, all-gather/reduce-scatter (n-1)/n,
  all-to-all (n-1)/n, collective-permute 1x.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# op line:  %name = <shape or tuple> op-name(...)
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"([a-z0-9\-]+)(?:-start|-done)?\(", re.MULTILINE)

_REPLICA_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_REPLICA_RE2 = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def shape_bytes(text: str) -> int:
    """Total bytes of all array shapes mentioned in ``text``."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    operand_bytes: dict[str, int] = field(default_factory=lambda: defaultdict(int))
    wire_bytes: dict[str, int] = field(default_factory=lambda: defaultdict(int))
    counts: dict[str, int] = field(default_factory=lambda: defaultdict(int))

    @property
    def total_operand(self) -> int:
        return sum(self.operand_bytes.values())

    @property
    def total_wire(self) -> int:
        return sum(self.wire_bytes.values())

    def as_dict(self) -> dict:
        return {
            "operand_bytes": dict(self.operand_bytes),
            "wire_bytes": dict(self.wire_bytes),
            "counts": dict(self.counts),
            "total_operand": self.total_operand,
            "total_wire": self.total_wire,
        }


def _group_size(line: str, default: int) -> int:
    m = _REPLICA_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _REPLICA_RE2.search(line)
    if m:  # iota form [groups, group_size]
        return int(m.group(2))
    return default


def collective_stats(hlo_text: str, n_devices: int = 1) -> CollectiveStats:
    """Scan optimized HLO for collective ops; sizes are per-device."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        s = line.lstrip()
        m = _OP_RE.match(line)
        if not m:
            continue
        shape_txt, op = m.group(1), m.group(2)
        if op not in _COLLECTIVES:
            continue
        if "-done" in s.split("=", 1)[1][:80] and f"{op}-done" in s:
            continue  # count the -start, not the -done
        size = shape_bytes(shape_txt)
        n = _group_size(line, n_devices)
        frac = (n - 1) / n if n > 1 else 0.0
        stats.counts[op] += 1
        stats.operand_bytes[op] += size
        if op == "all-reduce":
            stats.wire_bytes[op] += int(2 * size * frac)
        elif op == "collective-permute":
            stats.wire_bytes[op] += size
        else:  # all-gather (output-sized), reduce-scatter/a2a (input-sized)
            stats.wire_bytes[op] += int(size * frac)
    return stats


def op_census(hlo_text: str, top: int = 20) -> list[tuple[str, int]]:
    counts: dict[str, int] = defaultdict(int)
    for m in _OP_RE.finditer(hlo_text):
        counts[m.group(2)] += 1
    return sorted(counts.items(), key=lambda kv: -kv[1])[:top]


def fusion_count(hlo_text: str) -> int:
    return hlo_text.count(" fusion(")
