"""MCCM-TPU: the paper's analytical cost model, hardware-adapted.

Maps the FPGA equations onto a (arch × shape × mesh × plan) cell:

  Eq. 1  PE-underutilisation ceil-divs  -> MXU 128-tile padding factors
  Eq. 4/5 on-chip buffer requirements   -> per-chip HBM footprint
  Eq. 6/7 off-chip accesses             -> HBM traffic per step
  Eq. 8/9 inter-segment interfaces      -> ICI collective wire bytes

Outputs the same three roofline terms the dry-run extracts from compiled
HLO (``hlo_walk``), in seconds, plus a fits-in-HBM verdict — analytically,
in microseconds per plan, which is what makes plan DSE (``autoplan``)
practical.  Validation against the XLA ground truth over all dry-run cells:
``benchmarks/tpu_model_accuracy.py``.

All quantities are PER DEVICE unless suffixed ``_global``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

from ..configs.base import ModelConfig, ShapeSpec
from .chip import ChipSpec, V5E

BF16 = 2
F32 = 4


@dataclass
class PlanView:
    """The axis widths a ParallelPlan resolves to on a concrete mesh."""

    n_dev: int
    dp: int                    # product of data axes (incl. pod)
    tp: int
    fsdp: int                  # 1 if no param sharding
    ep: int
    remat: bool = True
    remat_group: int = 1
    act_shard_seq: bool = False
    moe_impl: str = "ep_a2a"
    loss_chunk: int = 512
    opt_factored: bool = False
    opt_momentum: bool = True
    opt_bytes: int = F32

    @classmethod
    def of(cls, plan, mesh) -> "PlanView":
        shape = dict(mesh.shape)
        dp = 1
        for a in plan.dp_axes:
            dp *= shape.get(a, 1)
        tp = shape.get(plan.tp_axis, 1) if plan.tp_axis else 1
        fsdp = 1
        for a in (plan.fsdp_axes or ()):
            fsdp *= shape.get(a, 1)
        ep = shape.get(plan.ep_axis, 1) if plan.ep_axis else 1
        n = 1
        for v in shape.values():
            n *= v
        return cls(n_dev=n, dp=dp, tp=tp, fsdp=max(fsdp, 1), ep=ep,
                   remat=plan.remat, remat_group=plan.remat_group,
                   act_shard_seq=(plan.act_shard == "seq"),
                   moe_impl=plan.moe_impl, loss_chunk=plan.loss_chunk,
                   opt_factored=plan.opt_factored,
                   opt_momentum=plan.opt_momentum,
                   opt_bytes=(2 if plan.opt_state_dtype == "bfloat16"
                              else F32))


@dataclass
class CostEstimate:
    flops: float               # per device, per step (MXU-padded)
    useful_flops: float = 0.0  # unpadded (for validation vs HLO)
    hbm_bytes: float = 0.0
    wire_bytes: float = 0.0
    hbm_capacity_bytes: float = 0.0  # resident footprint (params+opt+cache…)
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    fits: bool = True
    mxu_utilization: float = 1.0   # useful/padded flops (Eq. 1 analog)
    parts: dict = field(default_factory=dict)

    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)


def _pad(d: float, chip: ChipSpec) -> float:
    """MXU tile inflation factor for one matmul dim (Eq. 1 analog)."""
    return chip.mxu_pad(int(math.ceil(d))) / max(d, 1.0)


def _matmul(tokens: float, d_in: int, d_out: int, chip: ChipSpec,
            bwd_mult: float = 1.0):
    """(useful_flops, padded_flops) of tokens × (d_in -> d_out)."""
    useful = 2.0 * tokens * d_in * d_out * bwd_mult
    padded = useful * _pad(d_in, chip) * _pad(d_out, chip)
    return useful, padded


def _attn_ctx(S: int, kind: str, window: int | None) -> float:
    """Attended context length per query token — *implementation-faithful*:
    the blocked flash path computes every (q_blk, kv_blk) pair, masked or
    not, so causal/SWA do NOT reduce FLOPs today (block-skipping is the
    §Perf opportunity this term exposes; see EXPERIMENTS.md)."""
    return float(S)


class _Acc:
    """Accumulator for the three terms + capacity."""

    def __init__(self, chip: ChipSpec):
        self.chip = chip
        self.useful = 0.0
        self.padded = 0.0
        self.hbm = 0.0
        self.wire = 0.0
        self.cap = 0.0
        self.parts: dict[str, float] = {}

    def flops(self, useful: float, padded: float | None = None, tag=""):
        self.useful += useful
        self.padded += padded if padded is not None else useful
        if tag:
            self.parts[f"flops/{tag}"] = self.parts.get(f"flops/{tag}", 0.0) \
                + (padded if padded is not None else useful)

    def mem(self, b: float, tag=""):
        self.hbm += b
        if tag:
            self.parts[f"hbm/{tag}"] = self.parts.get(f"hbm/{tag}", 0.0) + b

    def coll(self, b: float, tag=""):
        self.wire += b
        if tag:
            self.parts[f"wire/{tag}"] = self.parts.get(f"wire/{tag}", 0.0) + b

    def capacity(self, b: float, tag=""):
        self.cap += b
        if tag:
            self.parts[f"cap/{tag}"] = self.parts.get(f"cap/{tag}", 0.0) + b


def _ar_wire(size: float, n: int) -> float:
    """ring all-reduce wire bytes per device."""
    return 2.0 * size * (n - 1) / n if n > 1 else 0.0


def _ag_wire(size_out: float, n: int) -> float:
    return size_out * (n - 1) / n if n > 1 else 0.0


def estimate(cfg: ModelConfig, shape: ShapeSpec, plan, mesh,
             chip: ChipSpec = V5E) -> CostEstimate:
    """Analytical per-device cost of one step of this cell under ``plan``."""
    pv = PlanView.of(plan, mesh)
    kind = shape.kind
    B, S = shape.global_batch, shape.seq_len
    d, V = cfg.d_model, cfg.padded_vocab
    hd = cfg.head_dim
    nq, nkv = max(cfg.n_heads, 1), max(cfg.n_kv_heads, 1)
    a = _Acc(chip)

    # backward multiplier: fwd=1; train adds bwd(2) + remat recompute(~1)
    if kind == "train":
        bwd = 3.0 + (1.0 if pv.remat else 0.0)
        if pv.remat and pv.remat_group > 1:
            bwd += (pv.remat_group - 1) / pv.remat_group  # interior recompute
    else:
        bwd = 1.0

    # tokens entering the dense stack, per device
    if kind == "decode":
        tok_global = float(B)              # one new token each
        ctx = _attn_ctx(S, "decode", cfg.sliding_window)
    else:
        tok_global = float(B) * S
        ctx = _attn_ctx(S, kind, cfg.sliding_window)
    tok = tok_global / pv.dp               # activations sharded over dp only

    # ---- per-layer compute, per device ------------------------------------
    # TP shards the head/ff dimension; each device computes 1/tp of it.
    def attn_layer(n_layers: int, seq_ctx: float, heads_q=None):
        hq = heads_q or nq
        u, p = _matmul(tok, d, (hq + 2 * nkv) * hd / pv.tp, chip, bwd)
        a.flops(u * n_layers, p * n_layers, "qkv")
        # scores + pv: per device hq/tp heads
        sc = 2.0 * tok * seq_ctx * (hq / pv.tp) * hd * 2 * bwd
        a.flops(sc * n_layers, sc * _pad(hd, chip) * n_layers, "attn")
        u, p = _matmul(tok, hq * hd / pv.tp, d, chip, bwd)
        a.flops(u * n_layers, p * n_layers, "attn_out")
        # flash working set: q,k,v,o read/write per layer
        qkvo = tok * (2 * hq + 2 * nkv) * hd * BF16 / pv.tp * 2
        a.mem(qkvo * (2 if kind == "train" else 1) * n_layers, "attn_io")
        if kind == "decode":
            # read the KV cache once per step (the decode bottleneck)
            kv_read = (2.0 * (B / pv.dp) * ctx * nkv * hd * BF16
                       / (pv.tp if (nkv % pv.tp == 0) else
                          (pv.tp if hd % pv.tp == 0 else 1)))
            a.mem(kv_read * n_layers, "kv_read")
        # TP collective: fwd+bwd all-reduce of the residual activation
        if pv.tp > 1:
            # fwd (bf16) + remat recompute (bf16) + bwd cotangent (f32 — the
            # einsums set preferred_element_type=f32)
            size = tok * d * BF16
            mult = (1 + (1 if pv.remat else 0) + 2) if kind == "train" else 1
            a.coll(_ar_wire(size, pv.tp) * mult * n_layers, "tp_ar_attn")

    def mlp_layer(n_layers: int, f: int, n_mats: int = 3):
        u, p = _matmul(tok, d, f / pv.tp, chip, bwd)
        a.flops(u * (n_mats - 1) * n_layers, p * (n_mats - 1) * n_layers,
                "mlp_in")
        u, p = _matmul(tok, f / pv.tp, d, chip, bwd)
        a.flops(u * n_layers, p * n_layers, "mlp_out")
        a.mem(tok * f / pv.tp * BF16 * 2 * (2 if kind == "train" else 1)
              * n_layers, "mlp_io")
        if pv.tp > 1:
            size = tok * d * BF16
            mult = (1 + (1 if pv.remat else 0) + 2) if kind == "train" else 1
            a.coll(_ar_wire(size, pv.tp) * mult * n_layers, "tp_ar_mlp")

    def moe_layer(n_layers: int):
        k, f = cfg.experts_per_token, cfg.moe_d_ff
        E = cfg.n_experts
        # implementation-faithful: both dispatch variants compute E_local
        # capacity-padded buckets — cap = ceil8(k·n_local·cf/E), floor 8
        # (moe.py _capacity), so small decode batches pay the bucket floor.
        a2a = (pv.moe_impl == "ep_a2a"
               and (S if kind != "decode" else 1) % pv.ep == 0)
        n_local = tok / pv.ep if a2a else tok
        cap = max(8.0, math.ceil(k * n_local * cfg.capacity_factor / E
                                 / 8.0) * 8.0)
        e_local = -(-E // pv.ep)
        tok_e = e_local * cap * (pv.ep if a2a else 1)  # a2a: each expert
        # sees ep source shards' buckets
        if not a2a:
            tok_e = e_local * cap
        u, p = _matmul(tok_e, d, f, chip, bwd)
        a.flops(u * 2 * n_layers, p * 2 * n_layers, "moe_in")
        u, p = _matmul(tok_e, f, d, chip, bwd)
        a.flops(u * n_layers, p * n_layers, "moe_out")
        # router
        u, p = _matmul(tok, d, cfg.n_experts, chip, bwd)
        a.flops(u * n_layers, p * n_layers, "router")
        if cfg.n_shared_experts:
            fs = f * cfg.n_shared_experts
            u, p = _matmul(tok, d, fs / pv.tp, chip, bwd)
            a.flops(u * 2 * n_layers, p * 2 * n_layers, "moe_shared")
            u, p = _matmul(tok, fs / pv.tp, d, chip, bwd)
            a.flops(u * n_layers, p * n_layers, "moe_shared")
        # dispatch/combine gathers + buffers
        a.mem(tok_e * d * BF16 * 4 * (2 if kind == "train" else 1)
              * n_layers, "moe_io")
        if pv.ep > 1:
            if a2a:
                # one a2a moves the full (E, cap, d) dispatch buffer;
                # 2 per pass (dispatch + combine); bwd of an a2a is an a2a
                sz = e_local * pv.ep * cap * d * BF16
                a.coll(2 * sz * (pv.ep - 1) / pv.ep
                       * (4 if kind == "train" else 1) * n_layers, "moe_a2a")
            else:
                size = tok * d * BF16
                a.coll(_ar_wire(size, pv.ep)
                       * (4 if kind == "train" else 1) * n_layers, "moe_psum")

    def mamba_layer(n_layers: int):
        di, g, n_ssm = cfg.d_inner, cfg.n_ssm_groups, cfg.ssm_state
        h = cfg.n_ssm_heads
        proj_out = 2 * di + 2 * g * n_ssm + h
        u, p = _matmul(tok, d, proj_out / pv.tp, chip, bwd)
        a.flops(u * n_layers, p * n_layers, "ssm_proj")
        # conv1d
        conv = 2.0 * tok * (di + 2 * g * n_ssm) * cfg.ssm_conv * bwd / pv.tp
        a.flops(conv * n_layers, conv * n_layers, "ssm_conv")
        # SSD (chunked): intra-chunk attention-like + state update
        c = 256 if kind != "decode" else 1
        ssd = (2.0 * tok * c * di / pv.tp            # intra-chunk qk-like
               + 2.0 * tok * c * di / pv.tp          # pv-like
               + 4.0 * tok * di * n_ssm / pv.tp) * bwd
        a.flops(ssd * n_layers, ssd * n_layers, "ssd")
        u, p = _matmul(tok, di / pv.tp, d, chip, bwd)
        a.flops(u * n_layers, p * n_layers, "ssm_out")
        a.mem(tok * di / pv.tp * BF16 * 6 * (2 if kind == "train" else 1)
              * n_layers, "ssm_io")
        if kind == "decode":
            st = ((B / pv.dp) * (h * (di // max(h, 1)) * n_ssm)
                  * F32 / pv.tp)
            a.mem(2 * st * n_layers, "ssm_state_io")
        if pv.tp > 1:
            size = tok * d * BF16
            a.coll(_ar_wire(size, pv.tp) * (2 if kind == "train" else 1)
                   * n_layers, "tp_ar_ssm")

    # ---- assemble the stack ------------------------------------------------
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        attn_layer(cfg.n_layers, ctx)
        if cfg.n_experts:
            moe_layer(cfg.n_layers)
        else:
            mlp_layer(cfg.n_layers, cfg.d_ff)
    elif fam == "encdec":
        S_dec = max(S // cfg.dec_ratio, 8)
        tok_enc = (B * S / pv.dp) if kind != "decode" else 0.0
        tok_dec = (B * S_dec / pv.dp) if kind != "decode" else B / pv.dp
        # encoder (skipped at decode: cached)
        tok_save = tok
        if kind != "decode":
            tok = tok_enc
            attn_layer(cfg.n_enc_layers, (S + 1) / 2 if False else S)
            mlp_layer(cfg.n_enc_layers, cfg.d_ff, n_mats=2)
        tok = tok_dec
        attn_layer(cfg.n_dec_layers,
                   _attn_ctx(S_dec, kind, None) if kind != "decode" else S_dec)
        # cross attention reads the encoder states
        u, p = _matmul(tok, d, (nq + 2 * nkv) * hd // pv.tp, chip, bwd)
        a.flops(u * cfg.n_dec_layers, p * cfg.n_dec_layers, "xattn_qkv")
        xa = 2.0 * tok * S * (nq / pv.tp) * hd * 2 * bwd
        a.flops(xa * cfg.n_dec_layers, xa * cfg.n_dec_layers, "xattn")
        mlp_layer(cfg.n_dec_layers, cfg.d_ff, n_mats=2)
        tok = tok_save
    elif fam == "ssm":
        mamba_layer(cfg.n_layers)
    elif fam == "hybrid":
        mamba_layer(cfg.n_layers)
        n_shared = cfg.n_layers // max(cfg.attn_every, 1)
        attn_layer(n_shared, ctx)
        mlp_layer(n_shared, cfg.d_ff)

    # ---- head / embedding --------------------------------------------------
    head_tok = tok if kind == "train" else (B / pv.dp)
    u, p = _matmul(head_tok, d, V / pv.tp, chip,
                   bwd if kind == "train" else 1)
    a.flops(u, p, "head")
    a.mem(head_tok * d * BF16, "embed_io")

    # ---- parameters: capacity + HBM traffic + FSDP collectives -------------
    n_params = cfg.param_count()
    p_local = n_params * BF16 / (pv.fsdp * pv.tp if pv.fsdp > 1 else pv.tp)
    if pv.fsdp == 1:
        p_local = n_params * BF16 / pv.tp  # TP-sharded, DP-replicated
    a.capacity(p_local, "params")
    # reads: fwd + bwd (+ recompute); the *gathered* stream passes HBM once
    reads = (3.0 if kind == "train" else 1.0) + \
        (1.0 if (kind == "train" and pv.remat) else 0.0)
    if kind == "decode" and cfg.n_experts:
        # only active experts are touched per token-batch (capacity-bound)
        active_frac = min(1.0, (B / pv.dp) * cfg.experts_per_token
                          / cfg.n_experts * 4)
        dense_p = cfg.param_count(active_only=True)
        expert_p = n_params - dense_p
        reads_bytes = (dense_p + active_frac * expert_p) * BF16 / pv.tp
        a.mem(reads_bytes, "param_read")
    else:
        a.mem(p_local * pv.fsdp * reads if pv.fsdp > 1 else
              n_params * BF16 / pv.tp * reads, "param_read")
    if kind == "train":
        # grads write+read, optimizer state read+write
        g_local = p_local
        a.capacity(g_local, "grads")
        a.mem(2 * g_local * (pv.fsdp if False else 1), "grad_io")
        opt_mult = (1 if pv.opt_momentum else 0) + (0.05 if pv.opt_factored
                                                    else 1)
        opt_local = n_params * pv.opt_bytes * opt_mult / (pv.fsdp * pv.tp)
        a.capacity(opt_local, "opt")
        a.mem(2 * opt_local, "opt_io")
        if pv.fsdp > 1:
            # ZeRO-3: all-gather params fwd + bwd(recompute), reduce-scatter
            ag = _ag_wire(n_params * BF16 / pv.tp, pv.fsdp)
            rs = _ag_wire(n_params * BF16 / pv.tp, pv.fsdp)
            a.coll(2 * ag + rs, "fsdp")
        elif pv.dp > 1:
            a.coll(_ar_wire(n_params * BF16 / pv.tp, pv.dp), "dp_ar")

    # ---- activations / residuals / caches ----------------------------------
    if kind == "train":
        resid_tok = tok / (pv.tp if pv.act_shard_seq else 1)
        n_resid = (cfg.n_layers / pv.remat_group if pv.remat
                   else cfg.n_layers)
        resid = resid_tok * d * BF16 * n_resid
        a.capacity(resid, "residuals")
        a.mem(2 * resid, "resid_io")
        # loss logits chunked
        chunk = pv.loss_chunk or S
        a.capacity((B / pv.dp) * chunk * V * F32 / pv.tp, "logits_chunk")
    if kind != "train":
        # KV / state cache resident
        if fam in ("dense", "moe", "vlm"):
            kv = 2.0 * (B / pv.dp) * min(S, 10**9) * nkv * hd * BF16 \
                * cfg.n_layers
            shard = pv.tp if (nkv % pv.tp == 0 or hd % pv.tp == 0) else 1
            a.capacity(kv / shard, "kv_cache")
        elif fam == "encdec":
            kv = 2.0 * (B / pv.dp) * S * nkv * hd * BF16 * cfg.n_dec_layers
            a.capacity(kv + (B / pv.dp) * S * d * BF16, "kv+enc")
        elif fam in ("ssm", "hybrid"):
            st = (B / pv.dp) * cfg.d_inner * cfg.ssm_state * F32 \
                * cfg.n_layers / pv.tp
            a.capacity(st, "ssm_state")
            if fam == "hybrid":
                n_g = cfg.n_layers // max(cfg.attn_every, 1)
                kv = 2.0 * (B / pv.dp) * S * nkv * hd * BF16 * n_g
                a.capacity(kv / (pv.tp if nkv % pv.tp == 0 else 1),
                           "shared_kv")

    # ---- roofline terms -----------------------------------------------------
    # embedding table gather (tp/fsdp-sharded -> full table per lookup)
    if pv.tp * pv.fsdp > 1:
        emb = V * d * BF16
        a.coll(_ag_wire(emb, pv.tp * pv.fsdp)
               * (2 if kind == "train" else 1), "embed_ag")

    est = CostEstimate(
        flops=a.padded, useful_flops=a.useful, hbm_bytes=a.hbm,
        wire_bytes=a.wire,
        hbm_capacity_bytes=a.cap,
        compute_s=a.padded / chip.peak_flops_bf16,
        memory_s=a.hbm / chip.hbm_bytes_per_s,
        collective_s=a.wire / (chip.ici_link_bytes_per_s * chip.ici_links),
        fits=a.cap <= chip.hbm_capacity * 0.92,   # XLA overhead headroom
        mxu_utilization=a.useful / max(a.padded, 1.0),
        parts=a.parts,
    )
    return est
