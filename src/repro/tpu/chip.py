"""TPU chip + pod model (v5e-class, per the assignment's constants).

The FPGA DeviceSpec analog one level up: where MCCM distributes DSPs/BRAM
among CEs, MCCM-TPU distributes chips/HBM among parallelism axes.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ChipSpec:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12      # MXU, bf16
    hbm_bytes_per_s: float = 819e9
    hbm_capacity: int = 16 * 2**30       # 16 GiB
    ici_link_bytes_per_s: float = 50e9   # per link
    ici_links: int = 4                   # 2D torus: +/-x, +/-y
    mxu_tile: int = 128                  # systolic array edge
    vreg_lanes: int = 128
    vreg_sublanes: int = 8
    vmem_bytes: int = 128 * 2**20

    def mxu_pad(self, d: int) -> int:
        """Eq. 1's ceil-div underutilisation, TPU form: dims are processed
        in 128-wide tiles; a dim of d costs ceil(d/128)*128 lanes."""
        t = self.mxu_tile
        return -(-max(d, 1) // t) * t


V5E = ChipSpec()


@dataclass(frozen=True)
class PodSpec:
    chip: ChipSpec = V5E
    chips: int = 256                     # 16x16 per pod
    pods: int = 1
    dci_bytes_per_s: float = 25e9        # inter-pod (data-center) per chip

    @property
    def total_chips(self) -> int:
        return self.chips * self.pods

    @property
    def total_hbm(self) -> int:
        return self.total_chips * self.chip.hbm_capacity


SINGLE_POD = PodSpec()
MULTI_POD = PodSpec(pods=2)
