"""Backend dispatch + candidate metadata for the schedule scorer.

The scorer itself (``ref.score_plane``) is namespace-generic; this
module picks the namespace.  ``device`` traces it under jit on the
bucket-ladder shapes (the production path); ``ref`` runs the identical
statement sequence in numpy on the host — the bit-parity oracle the
tests compare against, and a debugging escape hatch
(``REPRO_SCHEDULE_BACKEND=ref``) that keeps ``Session.schedule``
working with jax compilation out of the loop.
"""
from __future__ import annotations

import os

import jax.numpy as jnp
import numpy as np

from .ref import CAND_META, ORDER_NAMES, score_plane

#: env var selecting the schedule-scoring namespace: "device" (jnp under
#: jit — default), "ref" (numpy on host), or "auto" (device)
BACKEND_ENV = "REPRO_SCHEDULE_BACKEND"
BACKENDS = ("device", "ref")


def resolve_backend(backend: str | None = None) -> str:
    backend = backend or os.environ.get(BACKEND_ENV, "auto")
    if backend == "auto":
        return "device"
    if backend not in BACKENDS:
        raise ValueError(f"unknown schedule backend {backend!r}; known: "
                         f"{BACKENDS + ('auto',)}")
    return backend


#: test-only fault-injection hook (see tests/faults.py): when set, called
#: as ``hook("schedule_score", backend)`` at every dispatch — at trace
#: time for the device backend, so a raising hook aborts the compile
#: (mirrors kernels.mccm_eval; failed compiles are never cached)
_FAULT_HOOK = None


def set_fault_hook(hook):
    """Install (or, with ``None``, uninstall) the fault-injection hook;
    returns the previous hook so tests can restore it."""
    global _FAULT_HOOK
    prev, _FAULT_HOOK = _FAULT_HOOK, hook
    return prev


def score_plane_dispatch(backend: str, **inputs):
    """Score the candidate plane with the selected namespace."""
    if _FAULT_HOOK is not None:
        _FAULT_HOOK("schedule_score", backend)
    xp = jnp if backend == "device" else np
    return score_plane(xp, **inputs)


def candidate_meta(index: int) -> tuple[str, float, bool]:
    """(order_name, tile_frac, double_buffer) for a candidate index."""
    order_id, frac, db = CAND_META[int(index)]
    return ORDER_NAMES[order_id], float(frac), bool(db)


def decode_candidate(index: int) -> dict:
    """Argmin index -> JSON-ready mapping description."""
    order, frac, db = candidate_meta(index)
    return {"order": order, "tile_frac": frac, "double_buffer": db}
