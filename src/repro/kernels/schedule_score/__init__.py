"""Per-layer temporal-mapping candidate scoring (the schedule layer's
kernel): one (B, L, NCAND) plane of MCCM-cost-scored mapping candidates,
argmin-reduced on device.

``ref.py`` holds the namespace-generic scorer (pass ``jnp`` or ``numpy``
— same op sequence, so device results are bit-comparable against the
host reference); ``ops.py`` holds the backend dispatch + candidate
metadata used to decode an argmin index back into a mapping.
"""
from .ops import (BACKEND_ENV, BACKENDS, resolve_backend, set_fault_hook,
                  candidate_meta, decode_candidate)
from .ref import (NCAND, ORDER_NAMES, FRACS, CAND_ORDER, CAND_FRAC,
                  CAND_DB, BIG, score_plane)

__all__ = [
    "BACKEND_ENV", "BACKENDS", "resolve_backend", "set_fault_hook",
    "candidate_meta", "decode_candidate", "NCAND", "ORDER_NAMES",
    "FRACS", "CAND_ORDER", "CAND_FRAC", "CAND_DB", "BIG", "score_plane",
]
