"""Namespace-generic temporal-mapping candidate scorer.

``score_plane(xp, ...)`` is written against the array-API subset shared
by ``jax.numpy`` and ``numpy`` (elementwise mul/div/ceil/floor/clip/
minimum/maximum/where only, float32 throughout), so the SAME statement
sequence produces the device plane (``xp=jnp``, traced under the bucket
ladder) and the pure-host reference plane (``xp=numpy``).  Bit-parity
between the two is a tested contract (tests/test_schedule.py) — there is
no second implementation to drift.

Candidate space (NCAND = 1 + 3 orders x 3 tile fractions x 2 buffering
choices = 19):

- candidate 0, ``ideal``: the mapping the coarse MCCM model assumes
  (full buffer use, perfect load/compute overlap, the Eq. 5/6 residency
  chain).  Its cost is the coarse per-layer cost VERBATIM, so the argmin
  can never exceed the coarse estimate, and argmin's first-index
  tie-break keeps the refined result bit-identical to coarse whenever no
  explicit mapping beats it.
- ``input_stationary`` (loop order N-C-H-W-K-R-S): feature map tiles
  pinned on chip, weights streamed — Eq. 6 option A; at frac=1.0,
  db=True it reproduces option A exactly.
- ``weight_stationary`` (N-K-C-H-W-R-S): weights pinned, feature maps
  streamed — Eq. 6 option B (exact at frac=1.0, db=True).  On pipelined
  layers this is the all-or-nothing residency order: either the whole
  layer's weights fit beside the fm tiles or everything streams.
- ``row_streaming`` (N-H-W-K-C-R-S): outputs produced row by row.  On
  single-CE layers it needs the whole weight tensor resident beside one
  input row band.  On pipelined layers it is the PARTIAL-residency
  order: a fraction phi of the weights stays on chip across tile rounds
  and only the remainder re-streams — the genuine refinement over the
  coarse model's binary keep-all/stream-all choice (Eq. 7).

``frac`` scales how much of the free buffer the streamed-operand tile
(single) or the resident-weight slice (pipelined) may claim; ``db``
False trades load/compute overlap (latency becomes comp + mem instead
of max(comp, mem)) for a single-buffered fm tile, halving the fm floor
and freeing buffer for weight residency on pipelined layers.
"""
from __future__ import annotations

import numpy as np

#: large-but-finite infeasibility sentinel (inf would turn masked
#: products into NaN)
BIG = 1.0e30

ORDER_NAMES = ("ideal", "input_stationary", "weight_stationary",
               "row_streaming")
FRACS = (1.0, 0.5, 0.25)


def _build_meta():
    rows = [(0, 1.0, True)]          # candidate 0: the coarse/ideal mapping
    for order in (1, 2, 3):
        for frac in FRACS:
            for db in (True, False):
                rows.append((order, frac, db))
    return tuple(rows)


#: (order_id, tile_frac, double_buffer) per candidate, row-major
CAND_META = _build_meta()
NCAND = len(CAND_META)

CAND_ORDER = np.array([r[0] for r in CAND_META], np.float32)
CAND_FRAC = np.array([r[1] for r in CAND_META], np.float32)
CAND_DB = np.array([1.0 if r[2] else 0.0 for r in CAND_META], np.float32)


def score_plane(xp, *, comp, wl, ifml, ofml, wtile, fm_tile2, ifm_tile,
                buf, ce_buf, n_tiles, ofm_res, ofm_acc,
                lat_coarse, acc_coarse, wacc_coarse, facc_coarse,
                busy_coarse, wacc_pipe_coarse,
                ideal, ifm_onchip, resident, pipe, valid, bpc):
    """Score every mapping candidate for every layer: (B, L) inputs ->
    dict of (B, L, NCAND) float32 planes.

    All size inputs are bytes, ``comp`` is cycles, ``bpc`` bytes/cycle.
    ``ideal``/``ifm_onchip``/``resident``/``pipe``/``valid`` are bool
    masks.  Returns per-candidate refined per-layer cost fields (the
    LayerState substitutions), the argmin key ``score``, and the chosen
    working-set accounting (``tile_bytes``/``companion_bytes``/
    ``floor_bytes``/``budget_bytes``/``phi``) that the budget property
    tests assert against.
    """
    f32 = xp.float32
    order = xp.asarray(CAND_ORDER, f32)           # (NCAND,)
    frac = xp.asarray(CAND_FRAC, f32)
    db = xp.asarray(CAND_DB, f32)
    is_c0 = order == 0.0
    is_is = order == 1.0
    is_ws = order == 2.0
    is_row = order == 3.0

    def e(a):                                     # (B, L) -> (B, L, 1)
        return xp.asarray(a, f32)[..., None]

    def eb(a):                                    # bool mask -> (B, L, 1)
        return xp.asarray(a, bool)[..., None]

    zero = xp.asarray(0.0, f32)
    one = xp.asarray(1.0, f32)
    bpc = xp.asarray(bpc, f32)

    # ---- single-CE (Eq. 6 world) ------------------------------------------
    # OFM policy is inherited from the coarse state (ofm_res/ofm_acc);
    # candidates choose which streamed operand gets how much of the rest.
    avail_is = e(buf) - e(ofm_res) - e(wtile)
    ifm_buf = xp.maximum(avail_is * frac, e(ifm_tile))
    loads_a = xp.where(
        ifm_buf < e(ifml),
        e(wl) * xp.ceil(e(ifml) / xp.maximum(ifm_buf, one)) + e(ifml),
        e(wl) + e(ifml))
    wacc_a = loads_a - e(ifml)

    avail_ws = e(buf) - e(ofm_res) - e(ifm_tile)
    w_buf = xp.maximum(avail_ws * frac, e(wtile))
    loads_b = xp.where(
        w_buf < e(wl),
        e(ifml) * xp.ceil(e(wl) / xp.maximum(w_buf, one)) + e(wl),
        e(ifml) + e(wl))
    facc_b = loads_b - e(wl)

    # row streaming: whole weight tensor resident beside one row band
    row_fit = e(wl) + e(ifm_tile) + e(ofm_res) <= e(buf)
    loads_r = xp.where(row_fit, e(wl) + e(ifml), xp.asarray(BIG, f32))

    sel_acc = xp.where(is_is, loads_a, xp.where(is_ws, loads_b, loads_r))
    sel_wacc = xp.where(is_is, wacc_a,
                        xp.where(is_ws, e(wl) + zero * frac,
                                 xp.where(row_fit, e(wl) + zero * frac,
                                          xp.asarray(BIG, f32))))
    sel_facc = xp.where(is_is, e(ifml) + zero * frac,
                        xp.where(is_ws, facc_b,
                                 xp.where(row_fit, e(ifml) + zero * frac,
                                          xp.asarray(BIG, f32))))
    acc_c = e(ofm_acc) + sel_acc
    facc_c = e(ofm_acc) + sel_facc
    wacc_c = sel_wacc

    # residency-chain regimes (whole working set fits, or the producer
    # left the ifm on chip): every operand already moves at most once —
    # no mapping can improve, so all candidates collapse to the coarse
    # cost and the first-index tie-break keeps candidate 0.
    chain = eb(ideal) | eb(ifm_onchip)
    acc_c = xp.where(chain, e(acc_coarse), acc_c)
    wacc_c = xp.where(chain, e(wacc_coarse), wacc_c)
    facc_c = xp.where(chain, e(facc_coarse), facc_c)
    mem_c = acc_c / bpc
    lat_c = xp.where(db > 0, xp.maximum(e(comp), mem_c), e(comp) + mem_c)

    lat_c = xp.where(is_c0, e(lat_coarse), lat_c)
    acc_c = xp.where(is_c0, e(acc_coarse), acc_c)
    wacc_c = xp.where(is_c0, e(wacc_coarse), wacc_c)
    facc_c = xp.where(is_c0, e(facc_coarse), facc_c)

    # ---- pipelined (Eq. 7 world) ------------------------------------------
    fm_floor = xp.where(db > 0, e(fm_tile2), e(fm_tile2) * 0.5)
    w_budget = xp.maximum(e(ce_buf) - fm_floor - e(wtile), zero) * frac
    phi_max = xp.clip(w_budget / xp.maximum(e(wl), one), 0.0, 1.0)
    # order semantics: IS streams everything, WS is all-or-nothing
    # (floor(phi_max) is 1 only on a full fit), ROW keeps a partial slice.
    # phi is quantized DOWN to 1/256 steps: residency is allocated in
    # BRAM-granule slices, and on the grid every op of the blend below is
    # exact in f32 — so compiler reassociation/FMA contraction cannot
    # split the device plane from the host reference plane.
    phi = xp.where(is_is, zero * phi_max,
                   xp.where(is_ws, xp.floor(phi_max), phi_max))
    phi = xp.floor(phi * 256.0) / 256.0
    # streamed rounds per weight byte: phi once + (1-phi) every round —
    # exact (integer/256 arithmetic below 2^24), then ONE rounding at *wl
    blend = (one - phi) * e(n_tiles) + phi
    wacc_p = e(wl) * blend
    wacc_p = xp.where(eb(resident), zero * wacc_p, wacc_p)
    mem_p = wacc_p / bpc
    busy_c = xp.where(db > 0, xp.maximum(e(comp), mem_p), e(comp) + mem_p)

    busy_c = xp.where(is_c0, e(busy_coarse), busy_c)
    wacc_p = xp.where(is_c0, e(wacc_pipe_coarse), wacc_p)
    phi = xp.where(is_c0 | eb(resident), one + zero * phi, phi)

    # ---- argmin key + budget accounting -----------------------------------
    pipe_b = xp.asarray(pipe, bool)[..., None]
    valid_b = xp.asarray(valid, bool)[..., None]
    score = xp.where(pipe_b, busy_c, lat_c)
    score = xp.where(valid_b | is_c0, score, xp.asarray(BIG, f32))

    # working-set bookkeeping for the chosen mapping: the property tests
    # assert tile + companions <= budget OR tile == floor (the documented
    # minimal-working-set clamp, mirroring the coarse model's own floors)
    tile_s = xp.where(is_is, ifm_buf, xp.where(is_ws, w_buf, e(wl)))
    comp_s = xp.where(is_is, e(wtile) + e(ofm_res),
                      e(ifm_tile) + e(ofm_res))
    floor_s = xp.where(is_is, e(ifm_tile), xp.where(is_ws, e(wtile), e(wl)))
    tile_p = phi * e(wl) + e(wtile)
    comp_p = fm_floor
    floor_p = e(wtile) + zero * frac
    ws_collapsed = is_c0 | (chain & ~pipe_b) | (eb(resident) & pipe_b)
    tile_bytes = xp.where(ws_collapsed, zero * frac,
                          xp.where(pipe_b, tile_p, tile_s))
    companion_bytes = xp.where(ws_collapsed, zero * frac,
                               xp.where(pipe_b, comp_p, comp_s))
    floor_bytes = xp.where(ws_collapsed, zero * frac,
                           xp.where(pipe_b, floor_p, floor_s))
    budget_bytes = xp.where(pipe_b, e(ce_buf), e(buf)) + zero * frac

    return {
        "score": score,
        "lat_single": lat_c,
        "acc_single": acc_c,
        "wacc_single": wacc_c,
        "facc_single": facc_c,
        "busy_pipe": busy_c,
        "w_acc_pipe": wacc_p,
        "phi": phi,
        "tile_bytes": tile_bytes,
        "companion_bytes": companion_bytes,
        "floor_bytes": floor_bytes,
        "budget_bytes": budget_bytes,
    }
