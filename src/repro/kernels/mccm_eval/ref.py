"""Pure-jnp oracle for the MCCM latency kernel."""
from __future__ import annotations

import jax.numpy as jnp


def mccm_latency_ref(dims, par):
    """Eq. 1 over a design batch.

    dims: (L, 4) f32 — per-layer (F, CKK, OH, OW);
    par : (B, L, 3) f32 — per-design per-layer ⟨pf, ph, pw⟩ (already
          gathered from the layer's CE).
    Returns (B,) total cycles and (B, L) per-layer cycles.
    """
    F, CKK, OH, OW = dims[:, 0], dims[:, 1], dims[:, 2], dims[:, 3]
    cyc = (jnp.ceil(F[None] / par[..., 0]) * CKK[None]
           * jnp.ceil(OH[None] / par[..., 1])
           * jnp.ceil(OW[None] / par[..., 2]))
    return cyc.sum(-1), cyc
