"""Pure-jnp oracles for the MCCM evaluation kernels.

Two levels:

* ``mccm_latency_ref`` — the original Eq. 1 sweep (kept as the oracle of
  the simple latency kernel).
* ``parallelism_search_ref`` — the fused ⟨pf, ph, pw⟩ parallelism search
  that is the DSE hot path: for every design and CE, pick the candidate
  pair minimising the CE's total Eq. 1 cycles under its PE budget, with
  ``pw`` greedily maximised per pair.  This is the bit-exact reference the
  Pallas kernel (``kernel.parallelism_search_call``) and the tiled XLA
  path in ``core.batch_eval`` are tested against.

The search operates on a *static pair list* (see ``ops.pair_tables``):
the (i, j) candidate grid is flattened in row-major order with pairs
whose ``pf*ph`` product exceeds the device's PE budget hint pruned away.
Pruned pairs are infeasible for every CE (allocations never exceed the
device total), so selection is identical to an argmin over the full grid.
"""
from __future__ import annotations

import jax.numpy as jnp


def mccm_latency_ref(dims, par):
    """Eq. 1 over a design batch.

    dims: (L, 4) f32 — per-layer (F, CKK, OH, OW);
    par : (B, L, 3) f32 — per-design per-layer ⟨pf, ph, pw⟩ (already
          gathered from the layer's CE).
    Returns (B,) total cycles and (B, L) per-layer cycles.
    """
    F, CKK, OH, OW = dims[:, 0], dims[:, 1], dims[:, 2], dims[:, 3]
    cyc = (jnp.ceil(F[None] / par[..., 0]) * CKK[None]
           * jnp.ceil(OH[None] / par[..., 1])
           * jnp.ceil(OW[None] / par[..., 2]))
    return cyc.sum(-1), cyc


def parallelism_search_ref(pes_ce, ce_of_layer, ce_oh,
                           fc_pair, coh_pair, ceil_ow, cand,
                           pair_prod, pair_pf, pair_ph):
    """Fused per-CE parallelism search (the former (B, L, 18, 18) tensor).

    Arguments
    ---------
    pes_ce      (B, NC)    f32  PEs allocated to each CE.
    ce_of_layer (B, L)     i32  CE id of each layer, clipped to [0, NC).
    ce_oh       (B, L, NC) f32  one-hot of ``ce_of_layer`` (0-rows for
                                padded / unmapped layers).
    fc_pair     (L, P)     f32  ceil(F/pf) * CKK per (layer, pair).
    coh_pair    (L, P)     f32  ceil(OH/ph) per (layer, pair).
    ceil_ow     (L, K)     f32  ceil(OW/cand) table.
    cand        (K,)       f32  ascending parallelism candidates.
    pair_prod   (P,)       f32  pf*ph of each pair (row-major pair order).
    pair_pf/ph  (P,)       f32  pf / ph candidate values of each pair.

    Returns (pf, ph, pw, cost) each (B, NC) f32 — the per-CE winner and
    its total cycle cost (inf when no pair is feasible).
    """
    L = ce_of_layer.shape[1]
    ncand = cand.shape[0]
    budget = pes_ce[:, :, None] / pair_prod[None, None, :]      # (B, NC, P)
    feasible = budget >= 1.0
    # largest candidate with pf*ph*pw <= pes: searchsorted on the floor
    pw_idx = jnp.clip(
        jnp.searchsorted(cand, jnp.floor(budget), side="right") - 1,
        0, ncand - 1)                                           # (B, NC, P)
    pw_sel = jnp.take_along_axis(pw_idx, ce_of_layer[:, :, None], axis=1)
    cow = ceil_ow[jnp.arange(L)[None, :, None], pw_sel]         # (B, L, P)
    cost_l = fc_pair[None] * coh_pair[None] * cow               # (B, L, P)
    cost_ce = jnp.einsum("blp,blc->bcp", cost_l, ce_oh)         # (B, NC, P)
    cost_ce = jnp.where(feasible, cost_ce, jnp.inf)
    best = jnp.argmin(cost_ce, axis=-1)                         # (B, NC)
    pf = pair_pf[best]
    ph = pair_ph[best]
    pw = cand[jnp.take_along_axis(pw_idx, best[..., None], -1)[..., 0]]
    cost = jnp.take_along_axis(cost_ce, best[..., None], -1)[..., 0]
    return pf, ph, pw, cost
