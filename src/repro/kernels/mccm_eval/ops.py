"""jit'd wrapper for the MCCM latency kernel."""
from __future__ import annotations

from functools import partial

import jax

from .kernel import mccm_latency_call


@partial(jax.jit, static_argnames=("design_blk", "interpret"))
def mccm_latency(dims, par, *, design_blk: int = 512,
                 interpret: bool = True):
    """dims (L, 4) f32 [F, C*KH*KW, OH, OW]; par (B, L, 3) f32 ⟨pf, ph, pw⟩.

    Returns ((B,) total Eq. 1 cycles, (B, L) per-layer cycles)."""
    return mccm_latency_call(dims, par, design_blk=design_blk,
                             interpret=interpret)
