"""jit'd wrappers + shared static tables for the MCCM evaluation kernels."""
from __future__ import annotations

import os
from functools import lru_cache, partial
from typing import NamedTuple

import numpy as np

import jax

from .kernel import mccm_latency_call, parallelism_search_call
from .ref import parallelism_search_ref

#: env var selecting the parallelism-search backend for the DSE hot path:
#: "ref" (pure jnp, CPU default), "pallas" (compiled TPU kernel),
#: "pallas_interpret" (same kernel under the interpreter — what CPU CI
#: exercises), or "auto" (pallas on TPU, ref elsewhere).
BACKEND_ENV = "REPRO_MCCM_BACKEND"
BACKENDS = ("ref", "pallas", "pallas_interpret")


def resolve_backend(backend: str | None = None) -> str:
    backend = backend or os.environ.get(BACKEND_ENV, "auto")
    if backend == "auto":
        platform = jax.devices()[0].platform
        return "pallas" if platform == "tpu" else "ref"
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; known: "
                         f"{BACKENDS + ('auto',)}")
    return backend


class PairTables(NamedTuple):
    """Static ⟨pf, ph⟩ pair list, row-major over the candidate grid."""

    pair_i: np.ndarray      # (P,) i32 index into cand (pf)
    pair_j: np.ndarray      # (P,) i32 index into cand (ph)
    pair_prod: np.ndarray   # (P,) f32 pf*ph
    pair_pf: np.ndarray     # (P,) f32
    pair_ph: np.ndarray     # (P,) f32
    cand: np.ndarray        # (K,) f32 ascending


@lru_cache(maxsize=None)
def pair_tables(candidates: tuple, pes_hint: int | None) -> PairTables:
    """Flatten the candidate grid, pruning pairs with pf*ph > pes_hint.

    Pruned pairs are infeasible for every CE of every device whose total
    PE count is <= ``pes_hint`` (per-CE allocations never exceed the
    total), so the argmin over the pruned list selects exactly the pair
    the full-grid argmin would.  ``pes_hint=None`` keeps every pair.
    """
    cand = np.asarray(candidates, np.float64)
    k = len(cand)
    ii, jj = np.meshgrid(np.arange(k), np.arange(k), indexing="ij")
    ii, jj = ii.ravel(), jj.ravel()                 # row-major (i, j)
    prod = cand[ii] * cand[jj]
    if pes_hint is not None:
        keep = prod <= pes_hint
        keep[0] = True                              # (1, 1) always survives
        ii, jj, prod = ii[keep], jj[keep], prod[keep]
    return PairTables(ii.astype(np.int32), jj.astype(np.int32),
                      prod.astype(np.float32),
                      cand[ii].astype(np.float32),
                      cand[jj].astype(np.float32),
                      cand.astype(np.float32))


#: test-only fault-injection hook (see tests/faults.py): when set, called
#: as ``hook("parallelism_search", backend)`` at every dispatch — at TRACE
#: time, so a raising hook aborts the jit compile (failed compiles are not
#: cached, so every call through a faulty backend keeps faulting, which is
#: exactly the repeated-failure signature the circuit breaker consumes)
_FAULT_HOOK = None


def set_fault_hook(hook):
    """Install (or, with ``None``, uninstall) the fault-injection hook;
    returns the previous hook so tests can restore it."""
    global _FAULT_HOOK
    prev, _FAULT_HOOK = _FAULT_HOOK, hook
    return prev


def parallelism_search(pes_ce, ce_of_layer, ce_oh, fc_pair, coh_pair,
                       ceil_ow, ow, pairs: PairTables, *,
                       backend: str = "ref", design_tile: int = 16):
    """Backend dispatch for the fused search (traced; jit at the caller).

    ``ceil_ow`` (L, K) feeds the ref gather; ``ow`` (L, 1) feeds the
    kernel's in-VMEM ceil-div — both encode the same table.
    """
    import jax.numpy as jnp

    if _FAULT_HOOK is not None:
        _FAULT_HOOK("parallelism_search", backend)

    cand = jnp.asarray(pairs.cand)
    if backend == "ref":
        return parallelism_search_ref(
            pes_ce, ce_of_layer, ce_oh, fc_pair, coh_pair, ceil_ow, cand,
            jnp.asarray(pairs.pair_prod), jnp.asarray(pairs.pair_pf),
            jnp.asarray(pairs.pair_ph))
    return parallelism_search_call(
        pes_ce, ce_oh, fc_pair, coh_pair, ow, cand,
        jnp.asarray(pairs.pair_prod), jnp.asarray(pairs.pair_pf),
        jnp.asarray(pairs.pair_ph), design_tile=design_tile,
        interpret=(backend == "pallas_interpret"))


@partial(jax.jit, static_argnames=("design_blk", "interpret"))
def mccm_latency(dims, par, *, design_blk: int = 512,
                 interpret: bool = True):
    """dims (L, 4) f32 [F, C*KH*KW, OH, OW]; par (B, L, 3) f32 ⟨pf, ph, pw⟩.

    Returns ((B,) total Eq. 1 cycles, (B, L) per-layer cycles)."""
    return mccm_latency_call(dims, par, design_blk=design_blk,
                             interpret=interpret)
