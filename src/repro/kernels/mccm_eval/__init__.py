from .ops import (  # noqa: F401
    BACKEND_ENV,
    PairTables,
    mccm_latency,
    pair_tables,
    parallelism_search,
    resolve_backend,
    set_fault_hook,
)
