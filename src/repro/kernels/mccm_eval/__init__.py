from .ops import mccm_latency  # noqa: F401
