"""MCCM Eq. 1 latency sweep as a Pallas TPU kernel.

The DSE hot loop: for a tile of designs, compute per-layer ceil-div cycle
counts and reduce to per-design totals.  Grid: (ceil(B / design_blk),);
each instance holds a (design_blk, L, 3) parallelism tile + the shared
(L, 4) layer-dim table in VMEM and writes (design_blk,) totals.

design_blk × L × 3 × 4 B must fit VMEM: with L ≤ 256 and design_blk = 512,
the tile is ~1.5 MiB — far under the ~128 MiB v5e VMEM, leaving room for
the multi-buffer pipeline Mosaic builds across grid steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mccm_kernel(dims_ref, par_ref, tot_ref, cyc_ref):
    dims = dims_ref[...]                        # (L, 4)
    par = par_ref[...]                          # (design_blk, L, 3)
    F, CKK = dims[:, 0], dims[:, 1]
    OH, OW = dims[:, 2], dims[:, 3]
    cyc = (jnp.ceil(F[None] / par[..., 0]) * CKK[None]
           * jnp.ceil(OH[None] / par[..., 1])
           * jnp.ceil(OW[None] / par[..., 2]))  # (design_blk, L)
    cyc_ref[...] = cyc
    tot_ref[...] = cyc.sum(-1)


def mccm_latency_call(dims, par, *, design_blk: int = 512,
                      interpret: bool = True):
    """dims: (L, 4) f32; par: (B, L, 3) f32 -> ((B,) totals, (B, L) cycles)."""
    B, L, _ = par.shape
    nb = -(-B // design_blk)
    pad = nb * design_blk - B
    if pad:
        par = jnp.pad(par, ((0, pad), (0, 0), (0, 0)),
                      constant_values=1.0)
    tot, cyc = pl.pallas_call(
        _mccm_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((L, 4), lambda i: (0, 0)),
            pl.BlockSpec((design_blk, L, 3), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((design_blk,), lambda i: (i,)),
            pl.BlockSpec((design_blk, L), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb * design_blk,), jnp.float32),
            jax.ShapeDtypeStruct((nb * design_blk, L), jnp.float32),
        ],
        interpret=interpret,
    )(dims, par)
    return tot[:B], cyc[:B]
