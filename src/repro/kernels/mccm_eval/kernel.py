"""MCCM evaluation kernels in Pallas.

Two kernels:

* ``mccm_latency_call`` — the original Eq. 1 latency sweep (a given
  per-layer ⟨pf, ph, pw⟩, reduce to per-design totals).
* ``parallelism_search_call`` — the fused DSE hot path: for a tile of
  designs, search the best ⟨pf, ph, pw⟩ per CE.  Per design-tile the
  (tile, L, P) cycle-cost block is built in VMEM, contracted against the
  CE one-hot with the MXU, and arg-minimised — the full (B, L, 18, 18)
  cost tensor never exists in HBM.

VMEM budget of the search kernel (f32): the live set is ~3 × (tile, L, P)
blocks plus the (tile, L, NC) one-hot.  With L ≤ 160, P ≤ 324 and the
default ``design_tile = 16`` that is ≈ 8 MiB — comfortably under a
16 MiB/core VMEM with room for Mosaic's cross-step double buffering.
Raise ``design_tile`` on parts with more VMEM.

On CPU the kernels run under ``interpret=True`` (same code path, jnp
semantics); ``core.batch_eval`` selects the backend.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# --------------------------------------------------------------------------
# Eq. 1 latency sweep (kept from the original toy kernel)
# --------------------------------------------------------------------------
def _mccm_kernel(dims_ref, par_ref, tot_ref, cyc_ref):
    dims = dims_ref[...]                        # (L, 4)
    par = par_ref[...]                          # (design_blk, L, 3)
    F, CKK = dims[:, 0], dims[:, 1]
    OH, OW = dims[:, 2], dims[:, 3]
    cyc = (jnp.ceil(F[None] / par[..., 0]) * CKK[None]
           * jnp.ceil(OH[None] / par[..., 1])
           * jnp.ceil(OW[None] / par[..., 2]))  # (design_blk, L)
    cyc_ref[...] = cyc
    tot_ref[...] = cyc.sum(-1)


def mccm_latency_call(dims, par, *, design_blk: int = 512,
                      interpret: bool = True):
    """dims: (L, 4) f32; par: (B, L, 3) f32 -> ((B,) totals, (B, L) cycles)."""
    B, L, _ = par.shape
    nb = -(-B // design_blk)
    pad = nb * design_blk - B
    if pad:
        par = jnp.pad(par, ((0, pad), (0, 0), (0, 0)),
                      constant_values=1.0)
    tot, cyc = pl.pallas_call(
        _mccm_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((L, 4), lambda i: (0, 0)),
            pl.BlockSpec((design_blk, L, 3), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((design_blk,), lambda i: (i,)),
            pl.BlockSpec((design_blk, L), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb * design_blk,), jnp.float32),
            jax.ShapeDtypeStruct((nb * design_blk, L), jnp.float32),
        ],
        interpret=interpret,
    )(dims, par)
    return tot[:B], cyc[:B]


# --------------------------------------------------------------------------
# fused per-CE parallelism search
# --------------------------------------------------------------------------
def _search_kernel(ncand: int):
    """Kernel body builder (``ncand`` fixed so the pw scan unrolls)."""

    def kern(pes_ref, ceoh_ref, fc_ref, coh_ref, ow_ref, cand_ref,
             prod_ref, pfv_ref, phv_ref, pf_out, ph_out, pw_out, cost_out):
        pes = pes_ref[...]                              # (T, NC)
        ce_oh = ceoh_ref[...]                           # (T, L, NC)
        cand = cand_ref[...][0]                         # (K,)
        prod = prod_ref[...][0]                         # (P,)
        P = prod.shape[0]

        budget = pes[:, :, None] / prod[None, None, :]  # (T, NC, P)
        feasible = budget >= 1.0
        flb = jnp.floor(budget)
        # largest candidate <= floor(budget): unrolled ascending scan keeps
        # the working set at one (T, NC, P) block instead of (T, NC, P, K)
        pwv = jnp.zeros_like(flb)
        for k in range(ncand):
            pwv = jnp.where(flb >= cand[k], cand[k], pwv)

        # per-layer pw of the layer's CE: one-hot contraction (MXU)
        pw_l = jax.lax.dot_general(
            ce_oh, pwv, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)          # (T, L, P)
        cow = jnp.ceil(ow_ref[...][None] / jnp.maximum(pw_l, 1.0))
        cost_l = fc_ref[...][None] * coh_ref[...][None] * cow   # (T, L, P)
        cost_ce = jax.lax.dot_general(
            ce_oh, cost_l, (((1,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)          # (T, NC, P)
        cost_ce = jnp.where(feasible, cost_ce, jnp.inf)

        best = jnp.argmin(cost_ce, axis=-1)              # (T, NC)
        sel = best[..., None] == jax.lax.broadcasted_iota(
            jnp.int32, (1, 1, P), 2)                     # (T, NC, P)
        self_f = sel.astype(jnp.float32)
        pf_out[...] = (pfv_ref[...][0][None, None, :] * self_f).sum(-1)
        ph_out[...] = (phv_ref[...][0][None, None, :] * self_f).sum(-1)
        pw_out[...] = jnp.maximum((pwv * self_f).sum(-1), 1.0)
        cost_out[...] = jnp.where(sel, cost_ce, 0.0).sum(-1)

    return kern


def parallelism_search_call(pes_ce, ce_oh, fc_pair, coh_pair, ow,
                            cand, pair_prod, pair_pf, pair_ph, *,
                            design_tile: int = 16, interpret: bool = True):
    """Fused ⟨pf, ph, pw⟩ search over a design batch.

    pes_ce (B, NC); ce_oh (B, L, NC); fc_pair / coh_pair (L, P);
    ow (L, 1) per-layer OW; cand (K,) ascending; pair_* (P,).
    Returns (pf, ph, pw, cost) each (B, NC) f32.  Semantics match
    ``ref.parallelism_search_ref`` bit for bit (same pair order, same
    first-minimum tie-breaking).
    """
    B, NC = pes_ce.shape
    L, P = fc_pair.shape
    K = int(cand.shape[0])
    nb = -(-B // design_tile)
    pad = nb * design_tile - B
    if pad:  # padded designs get pes 0 -> all-infeasible -> (1, 1, 1)
        pes_ce = jnp.pad(pes_ce, ((0, pad), (0, 0)))
        ce_oh = jnp.pad(ce_oh, ((0, pad), (0, 0), (0, 0)))
    row = lambda a: a.reshape(1, -1).astype(jnp.float32)
    outs = pl.pallas_call(
        _search_kernel(K),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((design_tile, NC), lambda i: (i, 0)),
            pl.BlockSpec((design_tile, L, NC), lambda i: (i, 0, 0)),
            pl.BlockSpec((L, P), lambda i: (0, 0)),
            pl.BlockSpec((L, P), lambda i: (0, 0)),
            pl.BlockSpec((L, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, K), lambda i: (0, 0)),
            pl.BlockSpec((1, P), lambda i: (0, 0)),
            pl.BlockSpec((1, P), lambda i: (0, 0)),
            pl.BlockSpec((1, P), lambda i: (0, 0)),
        ],
        out_specs=[pl.BlockSpec((design_tile, NC), lambda i: (i, 0))] * 4,
        out_shape=[jax.ShapeDtypeStruct((nb * design_tile, NC), jnp.float32)
                   ] * 4,
        interpret=interpret,
    )(pes_ce.astype(jnp.float32), ce_oh.astype(jnp.float32),
      fc_pair.astype(jnp.float32), coh_pair.astype(jnp.float32),
      ow.astype(jnp.float32), row(cand), row(pair_prod), row(pair_pf),
      row(pair_ph))
    return tuple(o[:B] for o in outs)
