"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True,
                  window: int | None = None,
                  scale: float | None = None):
    """Materialised-scores attention.  q: (B, H, Sq, D); k/v: (B, H, Sk, D).

    fp32 softmax; masked rows return zeros (matching the kernel)."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    m = s.max(-1, keepdims=True)
    m = jnp.where(jnp.isneginf(m), 0.0, m)
    p = jnp.exp(s - m)
    p = jnp.where(mask[None, None], p, 0.0)
    l = p.sum(-1, keepdims=True)
    l = jnp.where(l == 0.0, 1.0, l)
    out = jnp.einsum("bhqk,bhkd->bhqd", (p / l).astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)
