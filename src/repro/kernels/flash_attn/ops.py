"""jit'd public wrapper: (B, S, H, D) GQA layout -> flash kernel layout."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import flash_fwd


@partial(jax.jit, static_argnames=("causal", "window", "q_blk", "kv_blk",
                                   "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: int | None = None, q_blk: int = 256,
                    kv_blk: int = 256, interpret: bool = True):
    """q: (B, Sq, H, D); k/v: (B, Sk, Hkv, D) — GQA heads broadcast.

    Returns (B, Sq, H, D).  ``interpret=True`` runs the kernel body in
    Python on CPU (this container); on TPU pass interpret=False.
    """
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    rep = H // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kt = k.transpose(0, 2, 1, 3).reshape(B * H, -1, D)
    vt = v.transpose(0, 2, 1, 3).reshape(B * H, -1, D)
    out = flash_fwd(qt, kt, vt, causal=causal, window=window,
                    q_blk=q_blk, kv_blk=kv_blk, interpret=interpret)
    return out.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
