"""FlashAttention forward as a Pallas TPU kernel.

Grid: (B*H, nq) — one program instance per (batch·head, q-block).  Each
instance streams the KV blocks for its q-block through VMEM with an
online-softmax recurrence; scores never leave VMEM (the HBM-traffic term
the pure-jnp twin pays, see EXPERIMENTS.md §Perf).

BlockSpecs (VMEM tiles):
    q   : (1, q_blk, D)     — this instance's query block
    k/v : (1, Sk, D)        — streamed; the kv loop is inside the kernel so
                              the (q_blk, kv_blk) score tile stays in VMEM
    o   : (1, q_blk, D)

Dims are MXU-aligned by the wrapper (q_blk, kv_blk multiples of 128; D is
the head dim, padded to 128 lanes by Mosaic).  Validated in interpret mode
against ``ref.attention_ref`` (CPU container; TPU is the target).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, *,
                      causal: bool, window: int | None, scale: float,
                      kv_blk: int, sk_real: int, q_blk: int):
    qi = pl.program_id(1)
    Sk_pad = k_ref.shape[1]
    nk = Sk_pad // kv_blk
    D = q_ref.shape[2]
    # NOTE: every indexer below is an explicit Slice — plain int indices
    # break jax 0.4.x interpret-mode state discharge (_load_discharge_rule
    # assumes indexers carry .shape)
    q = pl.load(q_ref, (pl.dslice(0, 1), pl.dslice(0, q_blk),
                        pl.dslice(0, D)))[0].astype(jnp.float32) * scale

    q_abs = qi * q_blk + jax.lax.broadcasted_iota(jnp.int32, (q_blk, 1), 0)

    def body(kj, carry):
        acc, m, l = carry
        k = pl.load(k_ref, (pl.dslice(0, 1), pl.dslice(kj * kv_blk, kv_blk),
                            pl.dslice(0, D)))[0].astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(0, 1), pl.dslice(kj * kv_blk, kv_blk),
                            pl.dslice(0, D)))[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        k_abs = kj * kv_blk + jax.lax.broadcasted_iota(
            jnp.int32, (1, kv_blk), 1)
        msk = k_abs < sk_real
        if causal:
            msk &= k_abs <= q_abs
        if window is not None:
            msk &= k_abs > q_abs - window
        s = jnp.where(msk, s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(-1, keepdims=True))
        m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        p = jnp.where(msk, jnp.exp(s - m_safe), 0.0)
        alpha = jnp.where(jnp.isneginf(m), 0.0, jnp.exp(m - m_safe))
        l_new = l * alpha + p.sum(-1, keepdims=True)
        acc_new = acc * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return acc_new, m_new, l_new

    acc0 = jnp.zeros((q_blk, D), jnp.float32)
    m0 = jnp.full((q_blk, 1), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((q_blk, 1), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, nk, body, (acc0, m0, l0))
    l = jnp.where(l == 0.0, 1.0, l)
    pl.store(o_ref, (pl.dslice(0, 1), pl.dslice(0, q_blk), pl.dslice(0, D)),
             (acc / l).astype(o_ref.dtype)[None])


def flash_fwd(q, k, v, *, causal: bool = True, window: int | None = None,
              scale: float | None = None, q_blk: int = 256,
              kv_blk: int = 256, interpret: bool = True):
    """q: (BH, Sq, D); k/v: (BH, Sk, D) -> (BH, Sq, D).

    The wrapper pads Sq/Sk to block multiples; padded KV positions are
    masked inside the kernel via ``sk_real``."""
    BH, Sq, D = q.shape
    Sk = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    q_blk = min(q_blk, max(Sq, 8))
    kv_blk = min(kv_blk, max(Sk, 8))
    nq = -(-Sq // q_blk)
    nk = -(-Sk // kv_blk)
    pq, pk = nq * q_blk - Sq, nk * kv_blk - Sk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))

    kern = functools.partial(
        _flash_fwd_kernel, causal=causal, window=window, scale=scale,
        kv_blk=kv_blk, sk_real=Sk, q_blk=q_blk)
    out = pl.pallas_call(
        kern,
        grid=(BH, nq),
        in_specs=[
            pl.BlockSpec((1, q_blk, D), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, nk * kv_blk, D), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, nk * kv_blk, D), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_blk, D), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, nq * q_blk, D), q.dtype),
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq]
