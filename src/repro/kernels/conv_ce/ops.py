"""jit'd wrapper + the Eq. 1 cycle predictor the kernel's grid realizes."""
from __future__ import annotations

from functools import partial

import jax

from .kernel import conv_ce_call


@partial(jax.jit, static_argnames=("stride", "par_f", "par_oh", "par_ow",
                                   "interpret"))
def conv_ce(x, w, *, stride: int = 1, par_f: int = 8, par_oh: int = 4,
            par_ow: int = 4, interpret: bool = True):
    return conv_ce_call(x, w, stride=stride, par_f=par_f, par_oh=par_oh,
                        par_ow=par_ow, interpret=interpret)


def predicted_cycles(F: int, C: int, KH: int, KW: int, OH: int, OW: int,
                     par_f: int, par_oh: int, par_ow: int) -> int:
    """Eq. 1: prod_d ceil(|d|/Par(d)) — with C, KH, KW unparallelized this
    is the kernel's grid size × its inner-loop trip count."""
    grid = (-(-F // par_f)) * (-(-OH // par_oh)) * (-(-OW // par_ow))
    return grid * C * KH * KW


def grid_size(F: int, OH: int, OW: int, par_f: int, par_oh: int,
              par_ow: int) -> int:
    return (-(-F // par_f)) * (-(-OH // par_oh)) * (-(-OW // par_ow))
