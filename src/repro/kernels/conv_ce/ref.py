"""Pure-jnp oracle for the conv_ce kernel (valid-padding direct conv)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def conv_ref(x, w, stride: int = 1):
    """x: (C, H, W); w: (F, C, KH, KW) -> (F, OH, OW), valid padding."""
    out = jax.lax.conv_general_dilated(
        x[None].astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(stride, stride), padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"))
    return out[0].astype(x.dtype)
