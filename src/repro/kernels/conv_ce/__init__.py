from .ops import conv_ce, predicted_cycles  # noqa: F401
