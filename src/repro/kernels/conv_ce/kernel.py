"""A "Compute Engine" as a Pallas TPU kernel: tiled direct convolution whose
grid IS the paper's Eq. 1.

The CE parallelism vector ⟨par_f, par_oh, par_ow⟩ becomes the output tile
shape; the pallas grid is then

    (ceil(F/par_f), ceil(OH/par_oh), ceil(OW/par_ow))

— the exact ceil-div product of Eq. 1, with MXU/VPU tile padding playing
the role of PE underutilisation (a tile smaller than the hardware lanes
wastes the remainder, exactly like idle PEs).  ``ops.predicted_cycles``
returns the Eq. 1 count; tests assert the kernel's grid agrees.

VMEM strategy: weights are blocked on F (the stationary operand — the
weight-stationary dataflow of §II-B); the input stays resident (validation
sizes; a production halo-exchange pipeline is noted in DESIGN.md).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv_kernel(x_ref, w_ref, o_ref, *, stride: int, par_f: int,
                 par_oh: int, par_ow: int, F: int, OH: int, OW: int):
    fi = pl.program_id(0)
    hi = pl.program_id(1)
    wi = pl.program_id(2)
    C, H, W = x_ref.shape
    KH, KW = w_ref.shape[2], w_ref.shape[3]

    w = w_ref[...].astype(jnp.float32)              # (par_f, C, KH, KW)
    wf = w.reshape(par_f, C * KH * KW)

    # gather the input patches for this (par_oh, par_ow) output tile
    oh0 = hi * par_oh
    ow0 = wi * par_ow

    def oh_body(dh, acc):
        def ow_body(dw, acc):
            patch = pl.load(
                x_ref,
                (slice(None),
                 pl.dslice((oh0 + dh) * stride, KH),
                 pl.dslice((ow0 + dw) * stride, KW))).astype(jnp.float32)
            col = patch.reshape(C * KH * KW)
            val = wf @ col                            # (par_f,) — MXU row
            return acc.at[:, dh, dw].set(val)
        return jax.lax.fori_loop(0, par_ow, ow_body, acc)

    acc = jnp.zeros((par_f, par_oh, par_ow), jnp.float32)
    acc = jax.lax.fori_loop(0, par_oh, oh_body, acc)

    # mask the ragged tails (ceil-div padding = idle PEs)
    f_abs = fi * par_f + jax.lax.broadcasted_iota(
        jnp.int32, (par_f, 1, 1), 0)
    h_abs = oh0 + jax.lax.broadcasted_iota(jnp.int32, (1, par_oh, 1), 1)
    w_abs = ow0 + jax.lax.broadcasted_iota(jnp.int32, (1, 1, par_ow), 2)
    valid = (f_abs < F) & (h_abs < OH) & (w_abs < OW)
    o_ref[...] = jnp.where(valid, acc, 0.0).astype(o_ref.dtype)


def conv_ce_call(x, w, *, stride: int = 1, par_f: int = 8, par_oh: int = 4,
                 par_ow: int = 4, interpret: bool = True):
    """x: (C, H, W); w: (F, C, KH, KW) -> (F, OH, OW) valid conv."""
    C, H, W = x.shape
    F, _, KH, KW = w.shape
    OH = (H - KH) // stride + 1
    OW = (W - KW) // stride + 1
    gf, gh, gw = -(-F // par_f), -(-OH // par_oh), -(-OW // par_ow)

    # pad weights on F so blocks divide evenly; input padded so every
    # in-bounds patch load is valid even for ragged output tiles
    wp = jnp.pad(w, ((0, gf * par_f - F), (0, 0), (0, 0), (0, 0)))
    pad_h = (gh * par_oh - 1) * stride + KH - H
    pad_w = (gw * par_ow - 1) * stride + KW - W
    xp = jnp.pad(x, ((0, 0), (0, max(pad_h, 0)), (0, max(pad_w, 0))))

    kern = functools.partial(_conv_kernel, stride=stride, par_f=par_f,
                             par_oh=par_oh, par_ow=par_ow, F=F, OH=OH, OW=OW)
    out = pl.pallas_call(
        kern,
        grid=(gf, gh, gw),
        in_specs=[
            pl.BlockSpec(xp.shape, lambda f, h, w_: (0, 0, 0)),
            pl.BlockSpec((par_f, C, KH, KW), lambda f, h, w_: (f, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((par_f, par_oh, par_ow),
                               lambda f, h, w_: (f, h, w_)),
        out_shape=jax.ShapeDtypeStruct((gf * par_f, gh * par_oh,
                                        gw * par_ow), x.dtype),
        interpret=interpret,
    )(xp, wp)
    return out[:F, :OH, :OW]
