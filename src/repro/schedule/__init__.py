"""Schedule layer: per-CE temporal-mapping search under every evaluated
design (docs/schedule.md).

``search`` runs the candidate plane on device (bucket-ladder shapes, no
compile forks) and re-composes refined metrics through the exact Eq. 2–9
reduction; ``artifact`` decodes the result into the JSON-serializable
:class:`ScheduleArtifact` that ``Session.schedule`` returns.
"""
from .artifact import (CEPlan, LayerSchedule, ScheduleArtifact, SegmentCost,
                       build_artifact, energy_proxy)
from .search import (device_plane, plane_inputs, reference_plane,
                     schedule_batch, schedule_specs)

__all__ = [
    "CEPlan", "LayerSchedule", "ScheduleArtifact", "SegmentCost",
    "build_artifact", "energy_proxy", "device_plane", "plane_inputs",
    "reference_plane", "schedule_batch", "schedule_specs",
]
