"""Per-design temporal-mapping search — the device path.

For every evaluated design, each (valid) layer gets a ``(B, L, NCAND)``
plane of mapping candidates (loop order x tile fraction x buffering
choice, ``kernels.schedule_score``) scored in the SAME MCCM cost terms
the design search runs on: compute cycles, off-chip weight/feature-map
traffic, bandwidth contention.  An on-device argmin picks the winner per
layer; the chosen per-layer costs are substituted back into the
:class:`~repro.core.batch_eval.LayerState` and re-composed through the
exact Eq. 2–9 reduction — so refined and coarse metrics stay in one
currency, and because candidate 0 carries the coarse (ideal-mapping)
cost verbatim and the composition is monotone in every per-layer field,
**refined latency can never exceed the coarse estimate**.

Compile policy: the plane rides the bucket-ladder ``NetTables`` path
unchanged — candidates are a fixed trailing axis (NCAND) over the same
``(tile, max_L)`` block shapes, so schedule search never forks a
compile: one ``_schedule_jit`` program per ladder shape serves every
CNN x board x design (compile-miss-counter tested,
``tests/test_schedule.py``).
"""
from __future__ import annotations

from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from ..core.batch_eval import (DEFAULT_TILE, NEG, DesignBatch, DeviceSpec,
                               DeviceTables, LayerState, NetTables, _bucket,
                               _ce_maps, _pad_rows, _pair_layer_tables,
                               _seg_max, _seg_sum, compose_metrics,
                               layer_state, make_device_tables, pes_hint)
from ..core.dse.encoding import NS, encode_specs
from ..kernels.mccm_eval import pair_tables, parallelism_search
from ..kernels.mccm_eval import resolve_backend as resolve_eval_backend
from ..kernels.schedule_score import NCAND
from ..kernels.schedule_score.ops import score_plane_dispatch


def plane_inputs(xp, t: NetTables, dev: DeviceTables, st: LayerState,
                 pipe, valid) -> dict:
    """Assemble ``score_plane`` inputs from the per-layer state.

    Namespace-generic like the scorer itself: the device path passes
    ``jnp`` (traced), the reference path passes ``numpy`` with a
    host-materialized ``st`` — same statement sequence either way.
    """
    f32 = xp.float32
    wb = xp.asarray(dev.wordbytes, f32)
    W = xp.asarray(t.W, f32)[None]
    IFM = xp.asarray(t.IFM, f32)[None]
    OFM = xp.asarray(t.OFM, f32)[None]
    BAND = xp.asarray(t.BAND, f32)[None]
    ifml = IFM * wb
    return dict(
        comp=st.comp, wl=W * wb, ifml=ifml, ofml=OFM * wb,
        wtile=st.wtile, fm_tile2=st.fm_tile2,
        ifm_tile=xp.minimum(ifml, BAND * wb),
        buf=st.buf_l, ce_buf=st.ce_buf_l, n_tiles=st.n_tiles_l,
        ofm_res=st.ofm_res, ofm_acc=st.ofm_acc,
        lat_coarse=st.lat_single, acc_coarse=st.acc_single,
        wacc_coarse=st.wacc_single, facc_coarse=st.facc_single,
        busy_coarse=st.busy_pipe, wacc_pipe_coarse=st.w_acc_pipe,
        ideal=st.ideal, ifm_onchip=st.ifm_onchip, resident=st.resident_l,
        pipe=pipe, valid=valid, bpc=dev.bpc)


def _refine_state(st: LayerState, plane: dict, choice, bpc) -> LayerState:
    """Substitute each layer's chosen candidate costs into the state."""
    shape = plane["score"].shape

    def take(a):
        return jnp.take_along_axis(jnp.broadcast_to(a, shape),
                                   choice[..., None], axis=-1)[..., 0]

    acc = take(plane["acc_single"])
    wacc_p = take(plane["w_acc_pipe"])
    return st._replace(
        lat_single=take(plane["lat_single"]), acc_single=acc,
        wacc_single=take(plane["wacc_single"]),
        facc_single=take(plane["facc_single"]),
        mem_cyc_single=acc / bpc,
        busy_pipe=take(plane["busy_pipe"]), w_acc_pipe=wacc_p,
        mem_cyc_pipe=wacc_p / bpc)


def schedule_block(design: DesignBatch, t: NetTables, dev: DeviceTables,
                   pairs, fc_pair, coh_pair, *, backend: str = "ref",
                   design_tile: int = 16,
                   fm_tile_rows: int = 2) -> dict[str, jnp.ndarray]:
    """Fully traced schedule search of one design block: CE maps ->
    ⟨pf, ph, pw⟩ -> coarse layer state -> candidate plane -> argmin ->
    refined composition.  Returns refined + coarse metrics plus the
    per-layer/per-segment detail the artifact is decoded from."""
    m = _ce_maps(design, t, dev)
    pf, ph, pw, _cost = parallelism_search(
        m.pes_ce, m.ce_of_layer, m.ce_oh, fc_pair, coh_pair,
        t.CEIL_OW, t.OW[:, None], pairs, backend=backend,
        design_tile=design_tile)
    st = layer_state(design, t, dev, m, (pf, ph, pw), fm_tile_rows)
    coarse = compose_metrics(design, t, dev, m, st)

    pipe, valid = m.pipe_bool, m.valid_b
    plane = score_plane_dispatch(
        "device", **plane_inputs(jnp, t, dev, st, pipe, valid))
    choice = jnp.argmin(plane["score"], axis=-1).astype(jnp.int32)
    st2 = _refine_state(st, plane, choice, dev.bpc)
    refined = compose_metrics(design, t, dev, m, st2)

    shape = plane["score"].shape

    def take(a):
        return jnp.take_along_axis(jnp.broadcast_to(a, shape),
                                   choice[..., None], axis=-1)[..., 0]

    valid_f = valid.astype(jnp.float32)
    pipe_f = pipe.astype(jnp.float32)
    lat_ref_l = jnp.where(pipe, st2.busy_pipe, st2.lat_single) * valid_f
    lat_coarse_l = jnp.where(pipe, st.busy_pipe, st.lat_single) * valid_f
    acc_ref_l = jnp.where(pipe, st2.w_acc_pipe, st2.acc_single) * valid_f
    acc_coarse_l = jnp.where(pipe, st.w_acc_pipe, st.acc_single) * valid_f

    def seg_cyc(state):
        single = _seg_sum(state.lat_single * (1.0 - pipe_f) * valid_f,
                          m.onehot)
        busy = _seg_max(jnp.where(pipe & valid, state.busy_pipe, NEG),
                        m.onehot)
        return single + jnp.maximum(busy, 0.0)

    out = {f"ref_{k}": v for k, v in refined.items()}
    out.update({f"coarse_{k}": v for k, v in coarse.items()})
    out.update(
        choice=choice,
        phi=take(plane["phi"]),
        tile_bytes=take(plane["tile_bytes"]),
        companion_bytes=take(plane["companion_bytes"]),
        floor_bytes=take(plane["floor_bytes"]),
        budget_bytes=take(plane["budget_bytes"]),
        lat_ref_l=lat_ref_l, lat_coarse_l=lat_coarse_l,
        acc_ref_l=acc_ref_l, acc_coarse_l=acc_coarse_l,
        pf_l=jnp.einsum("bc,blc->bl", pf, m.ce_oh),
        ph_l=jnp.einsum("bc,blc->bl", ph, m.ce_oh),
        pw_l=jnp.einsum("bc,blc->bl", pw, m.ce_oh),
        ce_of_layer=m.ce_of_layer, seg_of_layer=m.seg_of_layer,
        pipe_l=pipe,
        valid_l=jnp.broadcast_to(valid, (design.batch, t.max_L)),
        n_tiles_l=st.n_tiles_l,
        ce_buf_l=st.ce_buf_l, buf_l=st.buf_l,
        alloc_seg=st.alloc, seg_valid=m.seg_valid,
        seg_cyc_ref=seg_cyc(st2), seg_cyc_coarse=seg_cyc(st))
    return out


def schedule_batch_traced(design: DesignBatch, tables: NetTables,
                          dev: DeviceTables, *, backend: str = "ref",
                          tile: int = DEFAULT_TILE, fm_tile_rows: int = 2,
                          pes_hint_static: int | None = None,
                          design_tile: int = 16) -> dict[str, jnp.ndarray]:
    """The traced schedule hot path — same tiling/lax.map structure as
    ``evaluate_batch_traced`` so the two share the ladder shape policy."""
    B = design.batch
    pairs = pair_tables(tables.candidates, pes_hint_static)
    fc_pair, coh_pair = _pair_layer_tables(tables, pairs)

    nt = -(-B // tile)
    padded = _pad_rows(design, nt * tile)

    def one(args):
        return schedule_block(
            DesignBatch(*args), tables, dev, pairs, fc_pair, coh_pair,
            backend=backend, design_tile=design_tile,
            fm_tile_rows=fm_tile_rows)

    out = jax.lax.map(one, (padded.seg_end.reshape(nt, tile, NS),
                            padded.seg_pipe.reshape(nt, tile, NS),
                            padded.seg_nce.reshape(nt, tile, NS),
                            padded.inter_pipe.reshape(nt, tile)))
    return {k: v.reshape((nt * tile,) + v.shape[2:])[:B]
            for k, v in out.items()}


@partial(jax.jit, static_argnames=("backend", "tile", "fm_tile_rows",
                                   "pes_hint_static", "design_tile"))
def _schedule_jit(design, tables, dev, *, backend, tile, fm_tile_rows,
                  pes_hint_static, design_tile):
    return schedule_batch_traced(
        design, tables, dev, backend=backend, tile=tile,
        fm_tile_rows=fm_tile_rows, pes_hint_static=pes_hint_static,
        design_tile=design_tile)


def schedule_batch(design: DesignBatch, tables: NetTables,
                   dev: DeviceSpec | DeviceTables, fm_tile_rows: int = 2,
                   *, backend: str | None = None, tile: int = DEFAULT_TILE,
                   design_tile: int = 16) -> dict[str, jnp.ndarray]:
    """DesignBatch -> refined + coarse metrics + per-layer schedule
    detail, one jitted dispatch (mirrors ``evaluate_batch``)."""
    backend = resolve_eval_backend(backend)
    if isinstance(dev, DeviceSpec):
        hint = pes_hint(dev.pes)
        devt = make_device_tables(dev)
    else:
        devt = dev
        hint = pes_hint(float(dev.pes))
    return _schedule_jit(design, tables, devt, backend=backend, tile=tile,
                         fm_tile_rows=fm_tile_rows, pes_hint_static=hint,
                         design_tile=design_tile)


def schedule_specs(specs, net, dev, *, tables: NetTables | None = None,
                   backend: str | None = None, tile: int = DEFAULT_TILE,
                   fm_tile_rows: int = 2, design_tile: int = 16,
                   pad_to: int | None = None) -> dict[str, np.ndarray]:
    """Spec list -> host metric/detail arrays (padded to the ladder
    bucket like ``_evaluate_specs``, so repeat calls share one compile)."""
    from ..core.batch_eval import make_tables
    if not specs:
        raise ValueError("no specs to schedule (empty design list)")
    tables = make_tables(net) if tables is None else tables
    n = len(specs)
    if pad_to is None:
        pad_to = _bucket(n, tile)
    batch = _pad_rows(encode_specs(list(specs), len(net)), pad_to)
    out = schedule_batch(batch, tables, dev, fm_tile_rows,
                         backend=backend, tile=tile, design_tile=design_tile)
    return {k: np.asarray(v)[:n] for k, v in out.items()}


def reference_plane(design: DesignBatch, t: NetTables,
                    dev: DeviceTables, *, backend: str = "ref",
                    design_tile: int = 16, fm_tile_rows: int = 2):
    """Pure-host reference scoring: the identical candidate plane and
    argmin computed in numpy (``xp=np``) from a host-materialized layer
    state — the bit-parity oracle of tests/test_schedule.py.  Returns
    ``(plane, choice, state_np)``."""
    m = _ce_maps(design, t, dev)
    pf, ph, pw = _reference_par(design, t, dev, m, backend, design_tile)
    st = layer_state(design, t, dev, m, (pf, ph, pw), fm_tile_rows)
    stn = LayerState(*[np.asarray(x) for x in st])
    plane = score_plane_dispatch(
        "ref", **plane_inputs(np, t, dev, stn,
                              np.asarray(m.pipe_bool),
                              np.asarray(m.valid_b)))
    choice = np.argmin(plane["score"], axis=-1).astype(np.int32)
    return plane, choice, stn


def _reference_par(design, t, dev, m, backend, design_tile):
    pairs = pair_tables(t.candidates, None)
    fc_pair, coh_pair = _pair_layer_tables(t, pairs)
    pf, ph, pw, _cost = parallelism_search(
        m.pes_ce, m.ce_of_layer, m.ce_oh, fc_pair, coh_pair,
        t.CEIL_OW, t.OW[:, None], pairs, backend=backend,
        design_tile=design_tile)
    return pf, ph, pw


@partial(jax.jit, static_argnames=("backend", "fm_tile_rows",
                                   "pes_hint_static", "design_tile"))
def _plane_jit(design, tables, dev, *, backend, fm_tile_rows,
               pes_hint_static, design_tile):
    """Device plane WITHOUT the argmin/compose reduction — what the
    bit-parity tests compare field-by-field against ``reference_plane``.
    Test-only; the production path is ``_schedule_jit``."""
    pairs = pair_tables(tables.candidates, pes_hint_static)
    fc_pair, coh_pair = _pair_layer_tables(tables, pairs)
    m = _ce_maps(design, tables, dev)
    pf, ph, pw, _cost = parallelism_search(
        m.pes_ce, m.ce_of_layer, m.ce_oh, fc_pair, coh_pair,
        tables.CEIL_OW, tables.OW[:, None], pairs, backend=backend,
        design_tile=design_tile)
    st = layer_state(design, tables, dev, m, (pf, ph, pw), fm_tile_rows)
    plane = score_plane_dispatch(
        "device", **plane_inputs(jnp, tables, dev, st, m.pipe_bool,
                                 m.valid_b))
    plane["choice"] = jnp.argmin(plane["score"], axis=-1).astype(jnp.int32)
    return plane


def device_plane(design: DesignBatch, t: NetTables,
                 dev: DeviceSpec | DeviceTables, *,
                 backend: str | None = None, design_tile: int = 16,
                 fm_tile_rows: int = 2) -> dict[str, np.ndarray]:
    """Host-materialized jitted plane (see ``_plane_jit``)."""
    backend = resolve_eval_backend(backend)
    if isinstance(dev, DeviceSpec):
        hint = pes_hint(dev.pes)
        devt = make_device_tables(dev)
    else:
        devt = dev
        hint = pes_hint(float(dev.pes))
    out = _plane_jit(design, t, devt, backend=backend,
                     fm_tile_rows=fm_tile_rows, pes_hint_static=hint,
                     design_tile=design_tile)
    return {k: np.asarray(v) for k, v in out.items()}
