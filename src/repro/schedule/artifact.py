"""The executable schedule artifact: what ``Session.schedule`` returns.

A :class:`ScheduleArtifact` is the decoded, host-side form of one
design's schedule search — per-layer chosen mappings, per-CE buffer
plans, per-segment refined-vs-coarse costs, and the refined
latency/energy headline.  It is plain dataclasses over plain Python
scalars, JSON-serializable and bit-identically round-trippable
(``to_json``/``from_json``; floats survive exactly because every stored
value is a Python float — json's repr round-trip is exact for binary64).

Energy is a documented first-order proxy (the repo's cost model has no
energy term of its own): off-chip traffic at ``E_DRAM_J_PER_BYTE`` plus
MACs at ``E_MAC_J`` — Horowitz-style constants (~20 pJ/bit DRAM,
~0.5 pJ/16-bit MAC), useful for *comparing* schedules, not for absolute
board power.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field

import numpy as np

from ..kernels.schedule_score import NCAND, decode_candidate

#: off-chip DRAM access energy, J/byte (~20 pJ/bit)
E_DRAM_J_PER_BYTE = 160.0e-12
#: one 16-bit MAC, J (~0.5 pJ)
E_MAC_J = 0.5e-12


@dataclass(frozen=True)
class LayerSchedule:
    """One layer's chosen temporal mapping and its refined cost."""

    layer: int
    ce: int
    segment: int
    pipelined: bool
    order: str              # loop order (kernels.schedule_score.ORDER_NAMES)
    tile_frac: float
    double_buffer: bool
    phi: float              # resident weight fraction (pipelined orders)
    tile_bytes: float       # chosen streamed-operand / resident-slice tile
    buffer_bytes: float     # budget the tile was chosen under
    pf: float
    ph: float
    pw: float
    n_tiles: float
    latency_cyc: float      # refined per-layer cycles (busy for pipelined)
    coarse_cyc: float
    access_bytes: float     # refined off-chip bytes attributed to the layer


@dataclass(frozen=True)
class CEPlan:
    """One compute engine's buffer plan under the chosen schedule."""

    ce: int
    segment: int
    pipelined: bool
    buffer_bytes: float             # this CE's on-chip slice
    weight_resident_bytes: float    # resident weights across its layers
    layers: tuple[int, ...]


@dataclass(frozen=True)
class SegmentCost:
    """Per-segment refined-vs-coarse occupancy (explain attribution)."""

    segment: int
    pipelined: bool
    buffer_bytes: float
    coarse_cyc: float
    refined_cyc: float


@dataclass(frozen=True)
class ScheduleArtifact:
    """Everything the schedule search decided for one design."""

    net: str
    board: str
    design: str                     # notation / repr of the scheduled spec
    latency_s: float                # schedule-refined
    coarse_latency_s: float
    throughput_ips: float
    access_bytes: float             # schedule-refined off-chip traffic
    coarse_access_bytes: float
    energy_j: float                 # refined first-order proxy (module doc)
    coarse_energy_j: float
    buffer_bytes: float
    n_candidates: int               # mappings scored (valid layers x NCAND)
    layers: tuple[LayerSchedule, ...] = ()
    ce_plans: tuple[CEPlan, ...] = ()
    segments: tuple[SegmentCost, ...] = ()
    meta: dict = field(default_factory=dict)

    # ---- serialization ---------------------------------------------------
    def to_dict(self) -> dict:
        return asdict(self)

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_dict(cls, d: dict) -> "ScheduleArtifact":
        d = dict(d)
        d["layers"] = tuple(LayerSchedule(**l) for l in d.get("layers", ()))
        d["ce_plans"] = tuple(CEPlan(ce=c["ce"], segment=c["segment"],
                                     pipelined=c["pipelined"],
                                     buffer_bytes=c["buffer_bytes"],
                                     weight_resident_bytes=c[
                                         "weight_resident_bytes"],
                                     layers=tuple(c["layers"]))
                              for c in d.get("ce_plans", ()))
        d["segments"] = tuple(SegmentCost(**s) for s in d.get("segments", ()))
        return cls(**d)

    @classmethod
    def from_json(cls, s: str) -> "ScheduleArtifact":
        return cls.from_dict(json.loads(s))


def energy_proxy(access_bytes: float, total_macs: float) -> float:
    """First-order energy in joules (see module docstring)."""
    return float(access_bytes) * E_DRAM_J_PER_BYTE \
        + float(total_macs) * E_MAC_J


def build_artifact(detail: dict, index: int, *, net, board_name: str,
                   design_repr: str, wordbytes: float) -> ScheduleArtifact:
    """Decode one design row of ``schedule_specs`` output into the
    artifact.  ``detail`` holds the host arrays (leading axis = designs);
    ``net`` is the Network (for layer weight sizes / total MACs)."""
    def row(key):
        return np.asarray(detail[key])[index]

    n_layers = len(net)
    valid = np.asarray(row("valid_l"), bool)
    pipe = np.asarray(row("pipe_l"), bool)
    choice = np.asarray(row("choice"), np.int64)
    ce_of = np.asarray(row("ce_of_layer"), np.int64)
    seg_of = np.asarray(row("seg_of_layer"), np.int64)

    layers = []
    for l in range(n_layers):
        if not valid[l]:
            continue
        mapping = decode_candidate(int(choice[l]))
        layers.append(LayerSchedule(
            layer=l, ce=int(ce_of[l]), segment=int(seg_of[l]),
            pipelined=bool(pipe[l]),
            order=mapping["order"], tile_frac=mapping["tile_frac"],
            double_buffer=mapping["double_buffer"],
            phi=float(row("phi")[l]),
            tile_bytes=float(row("tile_bytes")[l]),
            buffer_bytes=float(row("budget_bytes")[l]),
            pf=float(row("pf_l")[l]), ph=float(row("ph_l")[l]),
            pw=float(row("pw_l")[l]),
            n_tiles=float(row("n_tiles_l")[l]),
            latency_cyc=float(row("lat_ref_l")[l]),
            coarse_cyc=float(row("lat_coarse_l")[l]),
            access_bytes=float(row("acc_ref_l")[l])))

    plans: dict[int, dict] = {}
    for ls in layers:
        p = plans.setdefault(ls.ce, {
            "segment": ls.segment, "pipelined": ls.pipelined,
            "buffer_bytes": float(
                row("ce_buf_l")[ls.layer] if ls.pipelined
                else row("buf_l")[ls.layer]),
            "resident": 0.0, "layers": []})
        p["layers"].append(ls.layer)
        wl = float(net[ls.layer].weights_size) * float(wordbytes)
        if ls.pipelined:
            p["resident"] += float(ls.phi) * wl
    ce_plans = tuple(
        CEPlan(ce=ce, segment=p["segment"], pipelined=p["pipelined"],
               buffer_bytes=p["buffer_bytes"],
               weight_resident_bytes=p["resident"],
               layers=tuple(p["layers"]))
        for ce, p in sorted(plans.items()))

    seg_valid = np.asarray(row("seg_valid"), bool)
    segments = tuple(
        SegmentCost(segment=s,
                    pipelined=bool(np.any(pipe & valid & (seg_of == s))),
                    buffer_bytes=float(row("alloc_seg")[s]),
                    coarse_cyc=float(row("seg_cyc_coarse")[s]),
                    refined_cyc=float(row("seg_cyc_ref")[s]))
        for s in range(seg_valid.size) if seg_valid[s])

    access = float(row("ref_access_bytes"))
    coarse_access = float(row("coarse_access_bytes"))
    macs = float(net.total_macs)
    return ScheduleArtifact(
        net=net.name, board=board_name, design=design_repr,
        latency_s=float(row("ref_latency_s")),
        coarse_latency_s=float(row("coarse_latency_s")),
        throughput_ips=float(row("ref_throughput_ips")),
        access_bytes=access, coarse_access_bytes=coarse_access,
        energy_j=energy_proxy(access, macs),
        coarse_energy_j=energy_proxy(coarse_access, macs),
        buffer_bytes=float(row("ref_buffer_bytes")),
        n_candidates=int(valid.sum()) * NCAND,
        layers=tuple(layers), ce_plans=ce_plans, segments=segments,
        meta={"n_layers": n_layers,
              "n_refined": int(sum(l.order != "ideal" for l in layers))})
