"""Llama-3.2-1B — dense, GQA kv=8, SwiGLU. [hf:meta-llama/Llama-3.2-1B]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8,
    d_ff=8192, vocab_size=128_256,
    tie_embeddings=True, rope_theta=5e5,
)
