"""Granite-3.0-1B-A400M — MoE, 32 experts top-8, 512-dim expert FFN.
[hf:ibm-granite/granite-3.0-1b-a400m-base]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
    d_ff=512, vocab_size=49_155,
    n_experts=32, experts_per_token=8, moe_d_ff=512,
    tie_embeddings=True, rope_theta=1e4,
)
