"""Zamba2-1.2B — Mamba2 backbone + one *shared* attention(+MLP) block applied
after every 6th mamba layer (tied weights). [arXiv:2411.15242]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32_000,
    ssm_state=64, ssm_conv=4, ssm_expand=2, ssm_headdim=64,
    n_ssm_groups=1, attn_every=6, tie_embeddings=True, rope_theta=1e4,
)
