"""Model/shape configuration records for the assigned architectures.

``ModelConfig`` is a frozen dataclass consumed by ``repro.models``;
``ShapeSpec`` describes one assigned input-shape cell.  ``reduced()`` yields
the CPU-smoke-test variant of a config (same family/topology, tiny sizes).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: int = 0              # 0 -> d_model // n_heads
    qkv_bias: bool = False
    sliding_window: int | None = None
    pos_emb: str = "rope"          # rope | abs
    rope_theta: float = 1e6
    mlp_act: str = "swiglu"        # swiglu | gelu
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    max_abs_positions: int = 0
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    norm_topk_prob: bool = True
    aux_loss_coef: float = 0.01
    # --- SSM (mamba2) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_headdim: int = 64
    n_ssm_groups: int = 1
    # --- hybrid (zamba2): shared attn block after every `attn_every` layers
    attn_every: int = 0
    # --- enc-dec (whisper) ---
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    dec_ratio: int = 8             # dec_len = seq_len // dec_ratio
    # --- vlm / audio frontend stubs ---
    n_patches: int = 0
    frontend_dim: int = 0
    dtype: str = "bfloat16"

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def np_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def padded_vocab(self) -> int:
        """Embedding/head tables are padded to a multiple of 256 so the vocab
        dim shards evenly over any tp width <= 256 and stays 128-lane aligned
        (MaxText-style).  Token ids never reach the padding; the extra logits
        are just unused classes."""
        return -(-self.vocab_size // 256) * 256

    @property
    def d_inner(self) -> int:       # mamba
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_headdim else 0

    # ---- parameter counting (roofline MODEL_FLOPS = 6*N*D) ----------------
    def _attn_params(self) -> int:
        hd = self.head_dim
        p = self.d_model * (self.n_heads + 2 * self.n_kv_heads) * hd
        p += self.n_heads * hd * self.d_model
        if self.qkv_bias:
            p += (self.n_heads + 2 * self.n_kv_heads) * hd
        return p

    def _mlp_params(self, f: int) -> int:
        n = 3 * self.d_model * f if self.mlp_act == "swiglu" \
            else 2 * self.d_model * f + f + self.d_model
        return n

    def _mamba_params(self) -> int:
        di, g, n, h = self.d_inner, self.n_ssm_groups, self.ssm_state, self.n_ssm_heads
        conv_dim = di + 2 * g * n
        return (self.d_model * (2 * di + 2 * g * n + h)
                + self.ssm_conv * conv_dim + conv_dim
                + 3 * h + di + di * self.d_model)

    def param_count(self, active_only: bool = False) -> int:
        """Total (or active-per-token) parameters, embeddings included."""
        emb = self.vocab_size * self.d_model
        if self.pos_emb == "abs":
            emb += self.max_abs_positions * self.d_model
        head = 0 if self.tie_embeddings else self.vocab_size * self.d_model
        if self.family == "encdec":
            per_enc = self._attn_params() + self._mlp_params(self.d_ff) + 2 * self.d_model
            per_dec = 2 * self._attn_params() + self._mlp_params(self.d_ff) + 3 * self.d_model
            return emb + head + self.n_enc_layers * per_enc + self.n_dec_layers * per_dec
        if self.family == "ssm":
            return emb + head + self.n_layers * (self._mamba_params() + self.d_model)
        if self.family == "hybrid":
            body = self.n_layers * (self._mamba_params() + self.d_model)
            shared = self._attn_params() + self._mlp_params(self.d_ff) + 2 * self.d_model
            return emb + head + body + shared
        per = self._attn_params() + 2 * self.d_model
        if self.n_experts:
            e = self.experts_per_token if active_only else self.n_experts
            per += e * 3 * self.d_model * self.moe_d_ff
            per += self.d_model * self.n_experts  # router
            per += self.n_shared_experts * 3 * self.d_model * self.moe_d_ff
        else:
            per += self._mlp_params(self.d_ff)
        n = emb + head + self.n_layers * per
        if self.family == "vlm":
            n += self.frontend_dim * self.d_model  # projector
        return n

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 2),
            d_model=64,
            d_ff=128 if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 256),
            head_dim=0,
        )
        if self.n_heads:
            kw["n_heads"] = 4
            kw["n_kv_heads"] = min(self.n_kv_heads, 2) or 2
        if self.sliding_window:
            kw["sliding_window"] = 16
        if self.n_experts:
            kw["n_experts"] = 4
            kw["experts_per_token"] = 2
            kw["moe_d_ff"] = 32
        if self.ssm_state:
            kw["ssm_state"] = 16
            kw["ssm_headdim"] = 16
        if self.attn_every:
            kw["attn_every"] = 2
            kw["n_layers"] = 4
        if self.family == "encdec":
            kw["n_enc_layers"] = 2
            kw["n_dec_layers"] = 2
            kw["max_abs_positions"] = 512
        if self.family == "vlm":
            kw["n_patches"] = 4
            kw["frontend_dim"] = 32
        return self.replace(**kw)


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned (input-shape) cell."""

    name: str
    kind: str                      # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def smoke_shape(kind: str) -> ShapeSpec:
    return {
        "train": ShapeSpec("smoke_train", "train", 32, 2),
        "prefill": ShapeSpec("smoke_prefill", "prefill", 32, 2),
        "decode": ShapeSpec("smoke_decode", "decode", 32, 2),
    }[kind]
