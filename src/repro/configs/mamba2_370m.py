"""Mamba2-370M — attention-free SSD (state-space duality).
[arXiv:2405.21060]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=50_280,
    ssm_state=128, ssm_conv=4, ssm_expand=2, ssm_headdim=64,
    n_ssm_groups=1, tie_embeddings=True,
)
