"""Config registry: ``--arch <id>`` -> ModelConfig; assigned shape cells."""
from __future__ import annotations

from .base import SHAPES, ModelConfig, ShapeSpec, smoke_shape
from .qwen1_5_0_5b import CONFIG as _qwen15
from .llama3_2_1b import CONFIG as _llama32
from .qwen2_5_32b import CONFIG as _qwen25
from .h2o_danube_1_8b import CONFIG as _danube
from .whisper_base import CONFIG as _whisper
from .internvl2_2b import CONFIG as _internvl
from .granite_moe_1b_a400m import CONFIG as _granite
from .kimi_k2_1t_a32b import CONFIG as _kimi
from .mamba2_370m import CONFIG as _mamba2
from .zamba2_1_2b import CONFIG as _zamba2

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in (_qwen15, _llama32, _qwen25, _danube, _whisper,
              _internvl, _granite, _kimi, _mamba2, _zamba2)
}

ARCH_NAMES = tuple(ARCHS)

# Sub-quadratic decode support: SSM/hybrid state is O(1) in context; SWA caps
# the KV window. Pure full-attention archs skip long_500k (see DESIGN.md).
SUBQUADRATIC = ("h2o-danube-1.8b", "mamba2-370m", "zamba2-1.2b")


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def cells(include_skipped: bool = False):
    """All assigned (arch × shape) cells; skipped ones flagged."""
    out = []
    for arch in ARCH_NAMES:
        for shape in SHAPES.values():
            skip = (shape.name == "long_500k" and arch not in SUBQUADRATIC)
            if include_skipped or not skip:
                out.append((arch, shape.name, skip))
    return out


__all__ = ["ARCHS", "ARCH_NAMES", "SHAPES", "SUBQUADRATIC", "ModelConfig",
           "ShapeSpec", "cells", "get_config", "smoke_shape"]
