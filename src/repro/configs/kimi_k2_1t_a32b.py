"""Kimi-K2 — trillion-parameter MoE: 61L, d=7168, 384 experts top-8 plus one
shared expert (paper-table scale). GQA kv=8 per the assignment (the released
model uses MLA; the assignment pins GQA — noted in DESIGN.md).
[arXiv:2501.kimi2]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=2048, vocab_size=163_840, head_dim=112,
    n_experts=384, experts_per_token=8, moe_d_ff=2048,
    n_shared_experts=1, rope_theta=5e4,
)
