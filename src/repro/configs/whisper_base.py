"""Whisper-base — enc-dec audio; conv frontend STUBBED (precomputed frame
embeddings via input_specs). [arXiv:2212.04356]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="encdec",
    n_layers=12,  # 6 enc + 6 dec (bookkeeping; enc/dec fields are canonical)
    n_enc_layers=6, n_dec_layers=6,
    d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab_size=51_865,
    pos_emb="abs", max_abs_positions=40_960, mlp_act="gelu",
    dec_ratio=8, frontend_dim=512,
)
