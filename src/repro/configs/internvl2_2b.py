"""InternVL2-2B — InternViT frontend STUBBED (precomputed patch embeddings),
InternLM2-1.8B backbone. [arXiv:2404.16821]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=8192, vocab_size=92_553, head_dim=128,
    rope_theta=1e6, n_patches=256, frontend_dim=1024,
)
