"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.conv_ce.ops import conv_ce, grid_size, predicted_cycles
from repro.kernels.conv_ce.ref import conv_ref
from repro.kernels.flash_attn.ops import flash_attention
from repro.kernels.flash_attn.ref import attention_ref
from repro.kernels.mccm_eval.ops import mccm_latency
from repro.kernels.mccm_eval.ref import mccm_latency_ref


# ------------------------------------------------------------- flash_attn
@pytest.mark.parametrize("B,Sq,Sk,H,Hkv,D,causal,window,dtype", [
    (2, 128, 128, 4, 2, 64, True, None, jnp.float32),
    (1, 200, 200, 2, 2, 32, True, 64, jnp.float32),
    (2, 64, 256, 4, 4, 64, False, None, jnp.float32),
    (1, 1, 300, 4, 2, 64, False, None, jnp.float32),      # decode-like
    (2, 96, 96, 2, 1, 128, True, None, jnp.bfloat16),
])
def test_flash_attention_vs_ref(B, Sq, Sk, H, Hkv, D, causal, window, dtype):
    q = jax.random.normal(jax.random.key(0), (B, Sq, H, D), dtype)
    k = jax.random.normal(jax.random.key(1), (B, Sk, Hkv, D), dtype)
    v = jax.random.normal(jax.random.key(2), (B, Sk, Hkv, D), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          q_blk=64, kv_blk=64)
    kk = jnp.repeat(k, H // Hkv, 2)
    vv = jnp.repeat(v, H // Hkv, 2)
    ref = attention_ref(q.transpose(0, 2, 1, 3), kk.transpose(0, 2, 1, 3),
                        vv.transpose(0, 2, 1, 3), causal=causal,
                        window=window).transpose(0, 2, 1, 3)
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


# ------------------------------------------------------------- conv_ce
@pytest.mark.parametrize("C,H,W,F,K,stride,par", [
    (3, 16, 16, 8, 3, 1, (4, 4, 4)),
    (4, 15, 15, 6, 3, 2, (4, 3, 5)),
    (1, 12, 12, 5, 1, 1, (2, 4, 4)),
    (8, 10, 10, 16, 5, 1, (16, 2, 3)),
    (2, 9, 9, 3, 3, 1, (2, 2, 2)),      # ragged everything
])
def test_conv_ce_vs_ref(C, H, W, F, K, stride, par):
    x = jax.random.normal(jax.random.key(0), (C, H, W), jnp.float32)
    w = jax.random.normal(jax.random.key(1), (F, C, K, K), jnp.float32)
    out = conv_ce(x, w, stride=stride, par_f=par[0], par_oh=par[1],
                  par_ow=par[2])
    ref = conv_ref(x, w, stride=stride)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_conv_ce_grid_is_eq1():
    """The kernel's grid × inner trip count IS Eq. 1 — same ceil-divs as
    blocks.layer_cycles."""
    from repro.core.blocks import CE, layer_cycles
    from repro.core.workload import ConvLayer
    F, C, K, OH, OW = 6, 4, 3, 7, 7
    par = (4, 2, 2)
    cyc = predicted_cycles(F, C, K, K, OH, OW, *par)
    l = ConvLayer(index=0, name="l", kind="conv", in_ch=C, out_ch=F,
                  kh=K, kw=K, stride=1, ih=OH, iw=OW, padding="same")
    ce = CE("ce", pes=int(np.prod(par)),
            par={"f": par[0], "oh": par[1], "ow": par[2]})
    assert cyc == layer_cycles(l, ce)
    assert grid_size(F, OH, OW, *par) == \
        -(-F // par[0]) * -(-OH // par[1]) * -(-OW // par[2])


# ------------------------------------------------------------- mccm_eval
@pytest.mark.parametrize("B,L,blk", [(7, 53, 8), (64, 155, 64), (130, 74, 32)])
def test_mccm_latency_vs_ref(B, L, blk):
    rng = np.random.default_rng(0)
    dims = jnp.asarray(rng.integers(1, 512, (L, 4)), jnp.float32)
    par = jnp.asarray(rng.choice([1, 2, 4, 8, 16, 32], (B, L, 3)),
                      jnp.float32)
    tot, cyc = mccm_latency(dims, par, design_blk=blk)
    rtot, rcyc = mccm_latency_ref(dims, par)
    np.testing.assert_allclose(np.asarray(tot), np.asarray(rtot), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(cyc), np.asarray(rcyc), rtol=1e-6)
