"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.conv_ce.ops import conv_ce, grid_size, predicted_cycles
from repro.kernels.conv_ce.ref import conv_ref
from repro.kernels.flash_attn.ops import flash_attention
from repro.kernels.flash_attn.ref import attention_ref
from repro.kernels.mccm_eval.ops import mccm_latency
from repro.kernels.mccm_eval.ref import mccm_latency_ref


# ------------------------------------------------------------- flash_attn
@pytest.mark.parametrize("B,Sq,Sk,H,Hkv,D,causal,window,dtype", [
    (2, 128, 128, 4, 2, 64, True, None, jnp.float32),
    (1, 200, 200, 2, 2, 32, True, 64, jnp.float32),
    (2, 64, 256, 4, 4, 64, False, None, jnp.float32),
    (1, 1, 300, 4, 2, 64, False, None, jnp.float32),      # decode-like
    (2, 96, 96, 2, 1, 128, True, None, jnp.bfloat16),
])
def test_flash_attention_vs_ref(B, Sq, Sk, H, Hkv, D, causal, window, dtype):
    q = jax.random.normal(jax.random.key(0), (B, Sq, H, D), dtype)
    k = jax.random.normal(jax.random.key(1), (B, Sk, Hkv, D), dtype)
    v = jax.random.normal(jax.random.key(2), (B, Sk, Hkv, D), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          q_blk=64, kv_blk=64)
    kk = jnp.repeat(k, H // Hkv, 2)
    vv = jnp.repeat(v, H // Hkv, 2)
    ref = attention_ref(q.transpose(0, 2, 1, 3), kk.transpose(0, 2, 1, 3),
                        vv.transpose(0, 2, 1, 3), causal=causal,
                        window=window).transpose(0, 2, 1, 3)
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


# ------------------------------------------------------------- conv_ce
@pytest.mark.parametrize("C,H,W,F,K,stride,par", [
    (3, 16, 16, 8, 3, 1, (4, 4, 4)),
    (4, 15, 15, 6, 3, 2, (4, 3, 5)),
    (1, 12, 12, 5, 1, 1, (2, 4, 4)),
    (8, 10, 10, 16, 5, 1, (16, 2, 3)),
    (2, 9, 9, 3, 3, 1, (2, 2, 2)),      # ragged everything
])
def test_conv_ce_vs_ref(C, H, W, F, K, stride, par):
    x = jax.random.normal(jax.random.key(0), (C, H, W), jnp.float32)
    w = jax.random.normal(jax.random.key(1), (F, C, K, K), jnp.float32)
    out = conv_ce(x, w, stride=stride, par_f=par[0], par_oh=par[1],
                  par_ow=par[2])
    ref = conv_ref(x, w, stride=stride)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_conv_ce_grid_is_eq1():
    """The kernel's grid × inner trip count IS Eq. 1 — same ceil-divs as
    blocks.layer_cycles."""
    from repro.core.blocks import CE, layer_cycles
    from repro.core.workload import ConvLayer
    F, C, K, OH, OW = 6, 4, 3, 7, 7
    par = (4, 2, 2)
    cyc = predicted_cycles(F, C, K, K, OH, OW, *par)
    l = ConvLayer(index=0, name="l", kind="conv", in_ch=C, out_ch=F,
                  kh=K, kw=K, stride=1, ih=OH, iw=OW, padding="same")
    ce = CE("ce", pes=int(np.prod(par)),
            par={"f": par[0], "oh": par[1], "ow": par[2]})
    assert cyc == layer_cycles(l, ce)
    assert grid_size(F, OH, OW, *par) == \
        -(-F // par[0]) * -(-OH // par[1]) * -(-OW // par[2])


# ------------------------------------------------------------- mccm_eval
@pytest.mark.parametrize("B,L,blk", [(7, 53, 8), (64, 155, 64), (130, 74, 32)])
def test_mccm_latency_vs_ref(B, L, blk):
    rng = np.random.default_rng(0)
    dims = jnp.asarray(rng.integers(1, 512, (L, 4)), jnp.float32)
    par = jnp.asarray(rng.choice([1, 2, 4, 8, 16, 32], (B, L, 3)),
                      jnp.float32)
    tot, cyc = mccm_latency(dims, par, design_blk=blk)
    rtot, rcyc = mccm_latency_ref(dims, par)
    np.testing.assert_allclose(np.asarray(tot), np.asarray(rtot), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(cyc), np.asarray(rcyc), rtol=1e-6)


# ---------------------------------------------- fused parallelism search
def _search_inputs(cnn, board="vcu110"):
    """Baseline arch templates -> raw inputs of the fused search."""
    from repro.cnn.registry import get_cnn
    from repro.core.batch_eval import (_ce_maps, _pair_layer_tables,
                                       encode_specs, make_device_tables,
                                       make_tables, pes_hint)
    from repro.fpga.archs import ARCH_NAMES, make_arch
    from repro.fpga.boards import get_board
    from repro.kernels.mccm_eval import pair_tables

    net, dev = get_cnn(cnn), get_board(board)
    specs = [make_arch(a, net, n) for a in ARCH_NAMES for n in (2, 5, 9, 11)]
    tables = make_tables(net)
    maps = _ce_maps(encode_specs(specs, len(net)), tables,
                    make_device_tables(dev))
    pairs = pair_tables(tables.candidates, pes_hint(dev.pes))
    fc_pair, coh_pair = _pair_layer_tables(tables, pairs)
    return net, dev, specs, tables, maps, pairs, fc_pair, coh_pair


@pytest.mark.parametrize("cnn", ["resnet50", "xception", "mobilenetv2",
                                 "densenet121", "resnet152"])
def test_parallelism_search_kernel_vs_ref_vs_scalar(cnn):
    """Pallas kernel (interpret) == pure-jnp ref bit for bit, and both
    reproduce the scalar Builder's per-CE ⟨pf, ph, pw⟩ choice exactly, on
    every baseline arch template."""
    from repro.core.evaluator import build_design
    from repro.kernels.mccm_eval import parallelism_search

    net, dev, specs, tables, maps, pairs, fc, coh = _search_inputs(cnn)
    args = (maps.pes_ce, maps.ce_of_layer, maps.ce_oh, fc, coh,
            tables.CEIL_OW, tables.OW[:, None], pairs)
    ref = parallelism_search(*args, backend="ref")
    ker = parallelism_search(*args, backend="pallas_interpret",
                             design_tile=8)
    for name, r, k in zip(("pf", "ph", "pw", "cost"), ref, ker):
        np.testing.assert_array_equal(np.asarray(r), np.asarray(k),
                                      err_msg=f"{cnn} {name}")

    pf, ph, pw, _ = (np.asarray(x) for x in ref)
    for b, spec in enumerate(specs):
        acc = build_design(spec, net, dev)
        ce_id = 0
        for seg, cseg in zip(spec.segments, acc.segments):
            n_layers_seg = seg.layer_hi - seg.layer_lo + 1
            for slot, ce in enumerate(cseg.ces):
                if slot < n_layers_seg:          # live CE (has layers)
                    got = (pf[b, ce_id], ph[b, ce_id], pw[b, ce_id])
                    want = (ce.par_of("f"), ce.par_of("oh"), ce.par_of("ow"))
                    assert got == want, \
                        f"{cnn} {spec.name} CE{ce_id}: {got} != {want}"
                ce_id += 1


def test_parallelism_search_infeasible_ce_degrades_to_unit():
    """A CE with 0 PEs (no layers) selects ⟨1, 1, 1⟩ in both backends."""
    from repro.core.batch_eval import make_tables, pes_hint
    from repro.cnn.registry import get_cnn
    from repro.kernels.mccm_eval import pair_tables, parallelism_search
    from repro.core.batch_eval import _pair_layer_tables

    tables = make_tables(get_cnn("mobilenetv2"))
    pairs = pair_tables(tables.candidates, pes_hint(900))
    fc, coh = _pair_layer_tables(tables, pairs)
    L = tables.max_L
    pes = jnp.zeros((2, 16), jnp.float32)
    cel = jnp.zeros((2, L), jnp.int32)
    ceoh = jnp.zeros((2, L, 16), jnp.float32)
    for backend in ("ref", "pallas_interpret"):
        pf, ph, pw, cost = parallelism_search(
            pes, cel, ceoh, fc, coh, tables.CEIL_OW, tables.OW[:, None],
            pairs, backend=backend)
        assert (np.asarray(pf) == 1).all() and (np.asarray(ph) == 1).all()
        assert (np.asarray(pw) == 1).all()
        assert np.isinf(np.asarray(cost)).all()
