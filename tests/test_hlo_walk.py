"""Trip-count-aware HLO walker: exact on a hand-countable scan program."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.tpu.hlo_walk import parse_hlo, walk


@pytest.fixture(scope="module")
def scan_hlo():
    def f(w, x):
        def body(x, wi):
            return jnp.tanh(x @ wi), None
        x, _ = jax.lax.scan(body, x, w)
        return (x.astype(jnp.float32) ** 2).sum()

    w = jnp.ones((6, 64, 64), jnp.bfloat16)
    x = jnp.ones((8, 64), jnp.bfloat16)
    return jax.jit(jax.grad(f)).lower(w, x).compile().as_text()


def test_flops_multiplied_by_trip_count(scan_hlo):
    costs = walk(scan_hlo)
    one_dot = 2 * 8 * 64 * 64
    # fwd dot + 2 bwd dots per layer, 6 layers
    assert costs.flops == pytest.approx(one_dot * 3 * 6, rel=0.01)


def test_entry_found_and_while_edges(scan_hlo):
    comps = parse_hlo(scan_hlo)
    assert "__entry__" in comps
    trips = [m for c in comps.values() for (_, m) in c.edges if m > 1]
    assert 6.0 in trips


def test_collectives_counted_with_trips():
    import os
    def f(w, x):
        def body(x, wi):
            y = x @ wi
            return jax.lax.with_sharding_constraint(
                jnp.tanh(y), jax.sharding.NamedSharding(mesh, P("data"))), None
        x, _ = jax.lax.scan(body, x, w)
        return x.sum()

    from jax.sharding import PartitionSpec as P
    if len(jax.devices()) < 2:
        pytest.skip("needs >1 device for real collectives")
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    w = jnp.ones((4, 64, 64), jnp.bfloat16)
    x = jnp.ones((8, 64), jnp.bfloat16)
    with mesh:
        txt = jax.jit(f).lower(w, x).compile().as_text()
    walk(txt)  # must not crash; counts validated in the dryrun artifacts


def test_bytes_use_slice_sizes_not_buffers(scan_hlo):
    costs = walk(scan_hlo)
    # stacked weights are (6, 64, 64) bf16 = 49KB; per-iteration the walker
    # must charge the (1, 64, 64) slice, so total dynamic-slice traffic is
    # O(6 * 8KB * 2), not O(6 * 49KB * 2)
    assert costs.bytes_accessed < 2e6
