"""The Session front door: shared table/compile caches across scalar,
batch, DSE and multinet calls; deprecated shims stay bit-identical."""
from __future__ import annotations

import numpy as np
import pytest

from repro.api import EvalConfig, EvalError, Session
from repro.cnn.registry import get_cnn
from repro.core.dse import sample_mixed
from repro.core.dse.search import SearchConfig
from repro.core.multinet import MultinetSearchConfig
from repro.fpga.archs import ARCH_NAMES, make_arch
from repro.fpga.boards import get_board

NET = "mobilenetv2"
BOARD = "zc706"


def _specs(net, n_ces=4):
    return [make_arch(a, net, n_ces) for a in ARCH_NAMES]


# --------------------------------------------------------------------------
# the flagship: evaluate -> explore -> deploy share compiled programs
# --------------------------------------------------------------------------
def test_session_shares_compiles_across_all_entry_points():
    """After one warmup round, a second evaluate -> explore (random +
    search) -> deploy round on the same net/board adds ZERO compiles and
    ZERO table builds — the one-compile-serves-all property, automatic."""
    net, net2 = get_cnn(NET), get_cnn("resnet50")
    dev = get_board(BOARD)
    ses = Session(dev)

    def round_trip(seed):
        ses.evaluate("{L1-Last:CE1-CE4}", net)            # scalar
        ses.evaluate(_specs(net), net)                    # batched specs
        ses.explore(net, n=256, chunk=256, seed=seed)     # random sweep
        ses.explore(net, n=256, strategy="search", seed=seed,
                    config=SearchConfig(pop_size=128, seed=seed))
        ses.deploy([net, net2], n=64, seed=seed,
                   config=MultinetSearchConfig(pop_size=32, seed=seed))

    round_trip(0)                                         # warmup
    compiles = ses.compile_stats()
    builds = (ses.stats.net_table_builds, ses.stats.device_table_builds,
              ses.stats.multi_table_builds)
    round_trip(1)                                         # warm round
    assert ses.compile_stats() == compiles, \
        "warm Session calls must not mint new compiled programs"
    assert (ses.stats.net_table_builds, ses.stats.device_table_builds,
            ses.stats.multi_table_builds) == builds, \
        "warm Session calls must not rebuild tables"
    assert ses.stats.net_table_hits > 0
    assert ses.stats.multi_table_hits > 0


def test_session_tables_memoized_by_bucket():
    net = get_cnn(NET)
    ses = Session(get_board(BOARD))
    t1 = ses.tables(net)
    t2 = ses.tables(net)
    assert t1 is t2
    assert ses.stats.net_table_builds == 1
    assert ses.stats.net_table_hits == 1
    # a different explicit bucket is a different (memoized) entry
    t3 = ses.tables(net, max_L=192)
    assert t3 is not t1 and t3.max_L == 192
    assert ses.tables(net, max_L=192) is t3


# --------------------------------------------------------------------------
# deprecated shims: warn once, return bit-identical results
# --------------------------------------------------------------------------
def test_evaluate_design_shim_warns_and_matches():
    from repro.core.evaluator import evaluate_design

    net, dev = get_cnn(NET), get_board(BOARD)
    ses = Session(dev)
    spec = "{L1-L20:CE1, L21-Last:CE2}"
    with pytest.warns(DeprecationWarning, match="Session.evaluate"):
        legacy = evaluate_design(spec, net, dev)
    m = ses.evaluate(spec, net)
    assert (m.latency_s, m.throughput_ips, m.buffer_bytes,
            m.access_bytes) == (legacy.latency_s, legacy.throughput_ips,
                                legacy.buffer_bytes, legacy.access_bytes)


def test_evaluate_specs_shims_warn_and_match_bitwise():
    from repro.core.batch_eval import evaluate_specs, evaluate_specs_multi

    net, dev = get_cnn(NET), get_board(BOARD)
    ses = Session(dev)
    specs = _specs(net)
    with pytest.warns(DeprecationWarning, match="Session.evaluate"):
        legacy = evaluate_specs(specs, net, dev)
    got = ses.evaluate(specs, net)
    for k in legacy:
        np.testing.assert_array_equal(got[k], legacy[k], err_msg=k)

    jobs = [(specs, net, dev), (_specs(net, 6), net, dev)]
    with pytest.warns(DeprecationWarning, match="Session.submit"):
        legacy_multi = evaluate_specs_multi(jobs)
    futs = [ses.submit(s, n, d) for s, n, d in jobs]
    for fut, want in zip(futs, legacy_multi):
        out = fut.result(timeout=300)
        for k in want:
            np.testing.assert_array_equal(out[k], want[k], err_msg=k)
    ses.close()


def test_explore_shim_warns_and_matches_bitwise():
    from repro.core.dse import explore

    net, dev = get_cnn(NET), get_board(BOARD)
    ses = Session(dev)
    with pytest.warns(DeprecationWarning, match="Session.explore"):
        legacy = explore(net, dev, n=128, chunk=128, seed=5)
    got = ses.explore(net, n=128, chunk=128, seed=5)
    for k in legacy.metrics:
        np.testing.assert_array_equal(got.metrics[k], legacy.metrics[k],
                                      err_msg=k)
    np.testing.assert_array_equal(got.front, legacy.front)


def test_joint_explore_shim_warns_and_matches_bitwise():
    from repro.core.multinet import joint_explore

    nets = [get_cnn(NET), get_cnn("resnet50")]
    dev = get_board(BOARD)
    ses = Session(dev)
    with pytest.warns(DeprecationWarning, match="Session.deploy"):
        legacy = joint_explore(nets, dev, 32, strategy="random", seed=2,
                               chunk=32)
    got = ses.deploy(nets, 32, strategy="random", seed=2, chunk=32)
    for k in legacy.metrics:
        np.testing.assert_array_equal(got.metrics[k], legacy.metrics[k],
                                      err_msg=k)


# --------------------------------------------------------------------------
# satellite regressions
# --------------------------------------------------------------------------
def test_build_design_forwards_inter_segment_pipelining():
    """A built accelerator must agree with the evaluated metrics for the
    same arguments (build_design used to drop the flag on parse)."""
    from repro.core.accelerator import evaluate
    from repro.core.evaluator import _evaluate_design, build_design

    net, dev = get_cnn(NET), get_board(BOARD)
    design = "{L1-L20:CE1, L21-Last:CE2}"
    for isp in (True, False):
        acc = build_design(design, net, dev,
                           inter_segment_pipelining=isp)
        assert acc.spec.inter_segment_pipelining is isp
        want = _evaluate_design(design, net, dev,
                                inter_segment_pipelining=isp)
        assert evaluate(acc).throughput_ips == want.throughput_ips
    # the flag is load-bearing for this 2-segment design
    on = _evaluate_design(design, net, dev, inter_segment_pipelining=True)
    off = _evaluate_design(design, net, dev, inter_segment_pipelining=False)
    assert on.throughput_ips != off.throughput_ips


def test_explore_random_respects_caller_tables(monkeypatch):
    """explore(strategy='random') must use a caller-provided tables=
    verbatim instead of calling make_tables again."""
    import repro.core.batch_eval as be
    from repro.core.dse.driver import _explore

    net, dev = get_cnn(NET), get_board(BOARD)
    tables = be.make_tables(net)
    calls = {"n": 0}
    real = be.make_tables

    def counting(*a, **k):
        calls["n"] += 1
        return real(*a, **k)

    monkeypatch.setattr(be, "make_tables", counting)
    res = _explore(net, dev, 64, chunk=64, tables=tables)
    assert res.n_evals == 64
    assert calls["n"] == 0, "explore rebuilt tables despite tables="


def test_joint_explore_random_respects_caller_mtables(monkeypatch):
    """joint_explore's random arm (the audit target) must honor mtables=."""
    import repro.core.multinet.driver as md
    from repro.core.multinet.driver import _joint_explore
    from repro.core.multinet.joint_eval import make_multi_tables

    nets = [get_cnn(NET), get_cnn("resnet50")]
    dev = get_board(BOARD)
    mt = make_multi_tables(nets)
    calls = {"n": 0}

    def counting(*a, **k):
        calls["n"] += 1
        return make_multi_tables(*a, **k)

    monkeypatch.setattr(md, "make_multi_tables", counting)
    res = _joint_explore(nets, dev, 32, strategy="random", chunk=32,
                         mtables=mt)
    assert res.n_evals == 32
    assert calls["n"] == 0, "joint_explore rebuilt tables despite mtables="


# --------------------------------------------------------------------------
# config + submit machinery
# --------------------------------------------------------------------------
def test_eval_config_resolved_once(monkeypatch):
    monkeypatch.setenv("REPRO_MCCM_BACKEND", "pallas_interpret")
    ses = Session(get_board(BOARD))
    assert ses.config.backend == "pallas_interpret"
    # explicit config wins over the env var
    assert Session(get_board(BOARD),
                   backend="ref").config.backend == "ref"
    monkeypatch.delenv("REPRO_MCCM_BACKEND")
    assert Session(get_board(BOARD)).config.backend in ("ref", "pallas")
    with pytest.raises(ValueError):
        EvalConfig(backend="nope").resolved()


def test_session_requires_a_device():
    ses = Session()
    with pytest.raises(ValueError, match="no device"):
        ses.evaluate("{L1-Last:CE1-CE4}", get_cnn(NET))
    # per-call dev works without a default
    m = ses.evaluate("{L1-Last:CE1-CE4}", get_cnn(NET), get_board(BOARD))
    assert m.latency_s > 0


def test_empty_design_lists_rejected_cleanly():
    net, dev = get_cnn(NET), get_board(BOARD)
    ses = Session(dev)
    with pytest.raises(EvalError, match="empty") as ei:
        ses.evaluate([], net)
    assert ei.value.code == EvalError.INVALID_INPUT
    with pytest.raises(EvalError, match="empty") as ei:
        ses.submit([], net)
    assert ei.value.code == EvalError.INVALID_INPUT


def test_config_knobs_consistent_across_batch_paths():
    """fm_tile_rows is honored by BOTH batch entry forms — the spec-list
    path and the DesignBatch path return the same metrics for the same
    design under a non-default config."""
    net, dev = get_cnn(NET), get_board(BOARD)
    ses = Session(dev, fm_tile_rows=4)
    specs = _specs(net)
    from repro.core.batch_eval import encode_specs

    via_list = ses.evaluate(specs, net)
    via_db = ses.evaluate(encode_specs(specs, len(net)), net)
    for k in via_list:
        np.testing.assert_array_equal(np.asarray(via_list[k]),
                                      np.asarray(via_db[k]), err_msg=k)


def test_submit_isolates_failing_jobs():
    """One malformed request must fail ITS future only — co-queued valid
    requests still resolve (the megabatch falls back to per-job eval)."""
    from repro.core.notation import parse

    net, dev = get_cnn(NET), get_board(BOARD)
    ses = Session(dev, linger_s=0.2)      # wide window: both jobs batch
    # 13 segments exceeds NS=12 — passes submit, fails at encode time
    bad = parse("{" + ", ".join(f"L{i + 1}:CE{i + 1}" for i in range(13))
                + f", L14-Last:CE14}}", len(net))
    good = _specs(net)
    f_bad = ses.submit([bad], net)
    f_good = ses.submit(good, net)
    out = f_good.result(timeout=300)
    want = ses.evaluate(good, net)
    for k in want:
        np.testing.assert_array_equal(out[k], want[k], err_msg=k)
    with pytest.raises(EvalError, match="segments") as ei:
        f_bad.result(timeout=300)
    assert ei.value.code == EvalError.INVALID_INPUT
    ses.close()


def test_submit_isolates_bad_net_table_build():
    """A request whose NET is broken (table build raises, BEFORE any
    per-request chunking) fails its own future only; the co-queued valid
    request still megabatches — no per-job fallback needed."""

    class _BadNet:
        # parses fine (len is all submit needs) but any table build dies
        name = "corrupt"

        def __len__(self):
            return 20

        def __iter__(self):
            raise ValueError("corrupt layer data")

        @property
        def total_macs(self):
            return 0

    net, dev = get_cnn(NET), get_board(BOARD)
    ses = Session(dev, linger_s=0.2)      # wide window: both jobs batch
    good = _specs(net)
    f_bad = ses.submit(["{L1-Last:CE1-CE4}"], _BadNet())
    f_good = ses.submit(good, net)
    out = f_good.result(timeout=300)
    want = ses.evaluate(good, net)
    for k in want:
        np.testing.assert_array_equal(out[k], want[k], err_msg=k)
    with pytest.raises(EvalError, match="corrupt") as ei:
        f_bad.result(timeout=300)
    assert ei.value.code == EvalError.INVALID_INPUT
    # the good request went through the megabatch path, not a fallback
    assert ses.stats.megabatches >= 1
    ses.close()


def test_deploy_honors_config_max_m():
    """config.max_m reaches the session's MultiNetTables (5 models need
    max_m=5; the session default of 4 must not override it)."""
    nets = [get_cnn(n) for n in ("mobilenetv2", "resnet50", "densenet121",
                                 "xception", "vgg16")]
    ses = Session(get_board("vcu110"))
    with pytest.raises(ValueError, match="max_m"):
        ses.deploy(nets, 8, strategy="random", seed=0, chunk=8)
    cfg = MultinetSearchConfig(pop_size=8, seed=0, max_m=5)
    res = ses.deploy(nets, 8, strategy="random", seed=0, chunk=8,
                     config=cfg)
    assert res.n_evals == 8 and res.n_models == 5
    assert np.isfinite(res.metrics["worst_latency_s"]).all()


def test_submit_megabatches_and_scalar_result():
    net, dev = get_cnn(NET), get_board(BOARD)
    ses = Session(dev)
    specs = _specs(net)
    want = ses.evaluate(specs, net)
    futs = [ses.submit(specs, net) for _ in range(3)]
    futs.append(ses.submit("{L1-Last:CE1-CE4}", net))
    outs = [f.result(timeout=300) for f in futs]
    for out in outs[:3]:
        for k in want:
            np.testing.assert_array_equal(out[k], want[k], err_msg=k)
    scalar = outs[-1]
    assert isinstance(scalar["latency_s"], float)
    ref = ses.evaluate(["{L1-Last:CE1-CE4}"], net)
    assert scalar["latency_s"] == float(ref["latency_s"][0])
    assert ses.stats.megabatch_requests == 4
    ses.close()
    with pytest.raises(RuntimeError, match="session closed"):
        ses.submit(specs, net)
    ses.close()   # idempotent: a second close is a no-op
    with pytest.raises(RuntimeError, match="session closed"):
        ses.submit(specs, net)
    # synchronous evaluation still works on the closed session's caches
    again = ses.evaluate(specs, net)
    for k in want:
        np.testing.assert_array_equal(again[k], want[k], err_msg=k)


def test_session_designbatch_path_matches_evaluate_batch():
    from repro.core.batch_eval import evaluate_batch, make_tables

    net, dev = get_cnn(NET), get_board(BOARD)
    ses = Session(dev)
    rng = np.random.default_rng(9)
    db = sample_mixed(rng, len(net), 48)
    want = evaluate_batch(db, make_tables(net), dev)
    got = ses.evaluate(db, net)
    for k in want:
        np.testing.assert_array_equal(np.asarray(got[k]),
                                      np.asarray(want[k]), err_msg=k)


def test_submit_hammer_counters_consistent():
    """SessionStats counters are mutated from submitter threads AND the
    drain thread; unsynchronized ``+=`` would lose updates under this
    hammer.  Every bump goes through the stats lock, so the totals must
    come out exact."""
    import threading

    net, dev = get_cnn(NET), get_board(BOARD)
    ses = Session(dev)
    ses.evaluate("{L1-Last:CE1-CE4}", net)        # warm the compile
    n_threads, per_thread = 8, 25
    futs, errs = [], []
    lock = threading.Lock()

    def hammer():
        mine = []
        try:
            for _ in range(per_thread):
                mine.append(ses.submit("{L1-Last:CE1-CE4}", net))
        except Exception as e:  # noqa: BLE001 — report, don't deadlock
            errs.append(e)
        with lock:
            futs.extend(mine)

    threads = [threading.Thread(target=hammer) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs, errs
    for f in futs:
        f.result(timeout=300)
    total = n_threads * per_thread
    assert ses.stats.submits == total
    assert ses.stats.megabatch_requests == total
    assert ses.stats.rejected == 0
    # scalar_evals counts the warmup only — submits take the batched path
    assert ses.stats.scalar_evals == 1
    ses.close()
