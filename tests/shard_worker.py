"""Multi-device worker driven by tests/test_shard.py in a subprocess.

The parent sets ``REPRO_MESH_DEVICES`` (NOT ``XLA_FLAGS``) so this also
exercises the supported env-var path: importing ``repro.core.shard``
before first jax use must force-split the host platform by itself.

Usage: python tests/shard_worker.py <job> — jobs: parity | islands | cache.
Prints ``WORKER_OK <job>`` on success; any assertion failure exits nonzero.
"""
from __future__ import annotations

import os
import sys

import numpy as np

# import order is the point: shard first (reads REPRO_MESH_DEVICES and
# sets the XLA flag), jax after
from repro.core import shard  # noqa: F401
import jax

from repro.cnn.registry import CNN_NAMES, get_cnn
from repro.core import batch_eval as be
from repro.core.session import EvalConfig, Session
from repro.core.shard import EvalMesh, mesh_compile_counts
from repro.fpga.archs import ARCH_NAMES, make_arch
from repro.fpga.boards import get_board

WANT = int(os.environ["REPRO_MESH_DEVICES"])
TILE = 8   # small tile so the ndevices x tile padding unit stays testable


def _eq(a, b, msg):
    a, b = np.asarray(a), np.asarray(b)
    assert a.shape == b.shape and np.array_equal(a, b, equal_nan=True), msg


def job_parity():
    """Sharded vs single-device bit-parity on every baseline arch x CNN."""
    assert len(jax.devices()) == WANT, \
        f"env bootstrap failed: {len(jax.devices())} devices, want {WANT}"
    mesh = EvalMesh()
    assert mesh.is_sharded and mesh.ndevices == WANT
    dev = get_board("vcu108")
    for cnn in CNN_NAMES:
        net = get_cnn(cnn)
        tables = be.make_tables(net)
        specs = [make_arch(a, net, n)
                 for a in ARCH_NAMES for n in (2, 5, 9, 11)]
        batch = be.encode_specs(specs, len(net))
        single = be.evaluate_batch(batch, tables, dev, tile=TILE)
        sharded = be.evaluate_batch(batch, tables, dev, tile=TILE,
                                    mesh=mesh)
        for k in single:
            _eq(single[k], sharded[k], f"{cnn} {k} diverges sharded")
    # one compiled program served all CNNs on each path
    counts = mesh_compile_counts()
    assert counts == {"evaluate_batch": 1}, counts
    assert be._evaluate_jit._cache_size() == 1
    print(f"WORKER_OK parity ({len(CNN_NAMES)} CNNs x {len(ARCH_NAMES)} "
          f"archs, {WANT} devices)")


def job_islands():
    """Sharded island search: deterministic, equal to the unsharded
    island model, and its merged front dominates every island front."""
    from repro.core.dse.search import SearchConfig, search

    net = get_cnn("mobilenetv2")
    dev = get_board()                      # the default board (vcu110)
    mesh = EvalMesh()
    # pop 32 x 8 islands = 256 evals/gen -> 5 generations on this budget,
    # so interval-2 migration fires twice before the final generation
    cfg = SearchConfig(pop_size=32, budget=1300, seed=3,
                       migration_interval=2, migration_elites=4)
    r1 = search(net, dev, cfg, mesh=mesh)  # islands = mesh devices
    r2 = search(net, dev, cfg, mesh=mesh)
    _eq(r1.front_idx, r2.front_idx, "sharded island search nondeterministic")
    _eq(r1.points, r2.points, "sharded island points nondeterministic")
    assert r1.n_evals == cfg.budget
    assert len(r1.island_fronts) == mesh.ndevices
    assert any(h.get("migrants", 0) > 0 for h in r1.history), \
        "migration never transferred elites"
    merged = r1.points[r1.front_idx]
    for i, fi in enumerate(r1.island_fronts):
        for p in r1.points[fi]:
            assert (merged <= p).all(1).any(), \
                f"island {i} point {p} not covered by the merged front"
    # the sharded step computes exactly what the serial island loop does
    r3 = search(net, dev,
                SearchConfig(**{**cfg.__dict__,
                                "n_islands": mesh.ndevices}))
    _eq(r1.front_idx, r3.front_idx, "sharded != serial island front")
    _eq(r1.points, r3.points, "sharded != serial island points")
    print(f"WORKER_OK islands ({mesh.ndevices} islands, "
          f"front {len(r1.front_idx)})")


def job_cache():
    """B not divisible by the device count never reshards/recompiles."""
    net = get_cnn("mobilenetv2")
    dev = get_board("zc706")
    ses = Session(dev, config=EvalConfig(tile=TILE))
    assert ses.mesh.is_sharded and ses.mesh.ndevices == WANT
    spec = "{L1-L20:CE1, L21-Last:CE2}"
    ses.evaluate([spec] * 100, net)        # 100 % WANT != 0
    warm = ses.compile_stats()
    assert warm[f"mesh_evaluate_batch"] == 1, warm
    for b in (97, 128, 65, 100):           # same pad bucket, awkward tails
        ses.evaluate([spec] * b, net)
    assert ses.compile_stats() == warm, \
        (warm, ses.compile_stats())
    # sharded joint evaluation shares the property
    res = ses.deploy([net, get_cnn("resnet50")], n=48, strategy="search",
                     seed=0)
    assert res.n_evals == 48
    joint_warm = ses.compile_stats()
    ses.deploy([net, get_cnn("resnet50")], n=48, strategy="search", seed=0)
    assert ses.compile_stats() == joint_warm
    print(f"WORKER_OK cache (stats {warm})")


if __name__ == "__main__":
    {"parity": job_parity, "islands": job_islands,
     "cache": job_cache}[sys.argv[1]]()
