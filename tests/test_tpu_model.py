"""MCCM-TPU cost model + autoplan sanity (analytical layer — no devices)."""
from __future__ import annotations

import pytest

from repro.configs import SHAPES, get_config
from repro.launch.plans import default_plan
from repro.tpu.autoplan import candidate_plans, rank
from repro.tpu.chip import V5E
from repro.tpu.cost_model import estimate


class MeshView:
    def __init__(self, shape):
        self.shape = shape


SINGLE = MeshView({"data": 16, "model": 16})
MULTI = MeshView({"pod": 2, "data": 16, "model": 16})


def test_terms_positive_and_fit_flags():
    cfg = get_config("llama3.2-1b")
    shape = SHAPES["train_4k"]
    est = estimate(cfg, shape, default_plan(cfg, shape, SINGLE), SINGLE)
    assert est.flops > 0 and est.hbm_bytes > 0 and est.wire_bytes > 0
    assert est.compute_s > 0 and est.fits
    assert 0 < est.mxu_utilization <= 1.0


def test_multi_pod_halves_per_device_work():
    cfg = get_config("qwen2.5-32b")
    shape = SHAPES["train_4k"]
    e1 = estimate(cfg, shape, default_plan(cfg, shape, SINGLE), SINGLE)
    e2 = estimate(cfg, shape, default_plan(cfg, shape, MULTI), MULTI)
    assert e2.flops == pytest.approx(e1.flops / 2, rel=0.05)


def test_kimi_memory_structure():
    """The 1T cell (EXPERIMENTS.md §Dry-run): with every memory trick
    (factored second moment, no momentum, bf16 state, ZeRO-3, seq-sharded
    residuals) it fits the 512-chip multi-pod mesh; on the 256-chip single
    pod params+grads alone are 16.3 GB of the 16 GiB HBM — the baseline
    does NOT fit (the §Perf optimizer-in-backward hillclimb target), and a
    naive fp32-Adam plan is far worse."""
    import dataclasses
    cfg = get_config("kimi-k2-1t-a32b")
    shape = SHAPES["train_4k"]
    good_multi = default_plan(cfg, shape, MULTI)
    assert estimate(cfg, shape, good_multi, MULTI).fits
    good_single = default_plan(cfg, shape, SINGLE)
    e = estimate(cfg, shape, good_single, SINGLE)
    assert not e.fits
    assert e.hbm_capacity_bytes < 24 * 2**30     # close, not hopeless
    naive = dataclasses.replace(good_single, opt_factored=False,
                                opt_momentum=True,
                                opt_state_dtype="float32", fsdp_axes=())
    e_naive = estimate(cfg, shape, naive, SINGLE)
    assert e_naive.hbm_capacity_bytes > 2 * e.hbm_capacity_bytes


def test_decode_is_memory_bound_dense():
    cfg = get_config("qwen2.5-32b")
    shape = SHAPES["decode_32k"]
    est = estimate(cfg, shape, default_plan(cfg, shape, SINGLE), SINGLE)
    assert est.dominant() == "memory"          # weights+KV reads per token


def test_swa_and_ssm_cheap_at_long_context():
    """long_500k: SSM state is O(1); the KV cache term must not explode."""
    for arch in ("mamba2-370m", "zamba2-1.2b", "h2o-danube-1.8b"):
        cfg = get_config(arch)
        shape = SHAPES["long_500k"]
        est = estimate(cfg, shape, default_plan(cfg, shape, SINGLE), SINGLE)
        assert est.fits, arch


def test_autoplan_prefers_feasible_and_orders_by_step():
    cfg = get_config("llama3.2-1b")
    shape = SHAPES["train_4k"]
    ranked = rank(cfg, shape, SINGLE)
    assert len(ranked) == len(candidate_plans(cfg, shape, SINGLE))
    fits = [r.est.fits for r in ranked]
    # all feasible plans come before infeasible ones
    assert fits == sorted(fits, reverse=True)
    feas = [r for r in ranked if r.est.fits]
    steps = [r.step_s for r in feas]
    assert steps == sorted(steps)


def test_mxu_padding_penalizes_odd_dims():
    """Eq. 1 analog: a head_dim of 80 (danube) wastes MXU lanes vs 128."""
    cfg80 = get_config("h2o-danube-1.8b")       # hd = 80
    cfg128 = get_config("qwen2.5-32b")          # hd = 128
    s = SHAPES["train_4k"]
    e80 = estimate(cfg80, s, default_plan(cfg80, s, SINGLE), SINGLE)
    e128 = estimate(cfg128, s, default_plan(cfg128, s, SINGLE), SINGLE)
    assert e80.mxu_utilization < e128.mxu_utilization
