"""The serving front over a real loopback socket (docs/serving.md):
round-trips, concurrent mixed traffic, the EvalError taxonomy on the
wire, deadline / queue-full codes end-to-end, DSE ops at tiny budgets,
interactive-lane latency under a running batch job, and graceful
shutdown that drains in-flight work.
"""
from __future__ import annotations

import json
import socket
import threading
import time

import numpy as np
import pytest

from repro.api import EvalError, Session
from repro.cnn.registry import get_cnn
from repro.fpga.boards import get_board
from repro.serve import EvalServer, ServeClient

NET = "mobilenetv2"
BOARD = "zc706"
SPEC = "{L1-Last:CE1-CE4}"


@pytest.fixture(scope="module")
def served():
    """One warmed session + server shared by the whole module (sockets
    are cheap; compiles are not)."""
    ses = Session(get_board(BOARD), linger_s=0.005)
    ses.evaluate([SPEC], get_cnn(NET))       # warm tables + ladder
    with EvalServer(ses) as srv:
        yield srv
    ses.close()


def _client(srv) -> ServeClient:
    return ServeClient(*srv.address)


# --------------------------------------------------------------------------
# round-trips
# --------------------------------------------------------------------------
def test_ping_and_scalar_roundtrip(served):
    with _client(served) as cli:
        assert cli.ping() == {"pong": True}
        m = cli.evaluate(SPEC, NET)
        want = served.session.evaluate(SPEC, get_cnn(NET))
        assert m["latency_s"] == pytest.approx(want.latency_s)


def test_list_roundtrip_bit_identical(served):
    specs = [SPEC, "{L1-Last:CE1-CE2}", "{L1-L4:CE1, L5-Last:CE2}"]
    with _client(served) as cli:
        out = cli.evaluate(specs, NET, board=BOARD)
    want = served.session.evaluate(specs, get_cnn(NET))
    for k, v in want.items():
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(v))


def test_observability_over_wire(served):
    with _client(served) as cli:
        obs = cli.observability()
    assert {"compile", "stats", "caches", "breaker"} <= obs.keys()
    assert obs["caches"]["net_tables"]["size"] >= 1


def test_pipelined_out_of_order_completion(served):
    """Many async requests on one connection resolve to the right
    futures regardless of server completion order."""
    with _client(served) as cli:
        futs = {i: cli.evaluate_async([f"{{L1-Last:CE1-CE{1 + i % 6}}}"],
                                      NET)
                for i in range(12)}
        for i, f in futs.items():
            want = served.session.evaluate(
                [f"{{L1-Last:CE1-CE{1 + i % 6}}}"], get_cnn(NET))
            got = f.result(timeout=300)
            np.testing.assert_array_equal(np.asarray(got["latency_s"]),
                                          np.asarray(want["latency_s"]))


def test_concurrent_mixed_traffic_hammer(served):
    """Several client connections at once, mixed scalar/list and
    interactive/batch — every reply correct, none dropped."""
    errors: list = []

    def worker(seed: int) -> None:
        try:
            with _client(served) as cli:
                for j in range(4):
                    k = 1 + (seed + j) % 6
                    spec = f"{{L1-Last:CE1-CE{k}}}"
                    out = cli.evaluate(
                        [spec], NET,
                        priority="batch" if j % 2 else "interactive")
                    want = served.session.evaluate([spec], get_cnn(NET))
                    np.testing.assert_array_equal(
                        np.asarray(out["latency_s"]),
                        np.asarray(want["latency_s"]))
        except Exception as e:  # noqa: BLE001 — collected for the assert
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=300)
    assert errors == []


# --------------------------------------------------------------------------
# the taxonomy on the wire
# --------------------------------------------------------------------------
def test_malformed_line_fails_only_that_line(served):
    """Raw socket: garbage JSON gets an INVALID_INPUT error envelope and
    the connection stays usable for the next request."""
    host, port = served.address
    with socket.create_connection((host, port)) as s:
        f = s.makefile("rw", encoding="utf-8")
        f.write("this is not json\n")
        f.flush()
        err = json.loads(f.readline())
        assert err["ok"] is False
        assert err["error"]["code"] == EvalError.INVALID_INPUT
        f.write(json.dumps({"id": 1, "op": "ping"}) + "\n")
        f.flush()
        ok = json.loads(f.readline())
        assert ok == {"id": 1, "ok": True, "result": {"pong": True}}


@pytest.mark.parametrize("msg", [
    {"op": "warp_drive"},                       # unknown op
    {"op": "evaluate", "designs": [SPEC], "net": "nope"},
    {"op": "evaluate", "designs": [], "net": NET},
    {"op": "evaluate", "designs": ["{not notation"], "net": NET},
    {"op": "evaluate", "designs": [SPEC], "net": NET, "board": "nope"},
    {"op": "deploy", "nets": [NET], "n": 8},    # needs >= 2 nets
    {"op": "evaluate", "designs": [SPEC], "net": NET,
     "priority": "vip"},
])
def test_invalid_requests_return_invalid_input(served, msg):
    with _client(served) as cli:
        with pytest.raises(EvalError) as ei:
            cli.request(msg.pop("op"), **msg)
        assert ei.value.code == EvalError.INVALID_INPUT


def test_deadline_exceeded_over_wire():
    """A deadline shorter than the linger window comes back as a wire
    DEADLINE_EXCEEDED, reconstructed as EvalError client-side."""
    ses = Session(get_board(BOARD), linger_s=0.5)
    with EvalServer(ses) as srv, _client(srv) as cli:
        with pytest.raises(EvalError) as ei:
            cli.evaluate(SPEC, NET, deadline_s=0.01)
        assert ei.value.code == EvalError.DEADLINE_EXCEEDED
    ses.close()


def test_client_side_timeout_raises_deadline_exceeded():
    """A client-side timeout (server still lingering, no reply yet)
    surfaces as the SAME taxonomy code as a server-expired deadline —
    EvalError(DEADLINE_EXCEEDED) — and abandons the request id, so the
    late server reply is dropped instead of leaking a pending future."""
    ses = Session(get_board(BOARD), linger_s=0.5)
    with EvalServer(ses) as srv, _client(srv) as cli:
        with pytest.raises(EvalError) as ei:
            cli.evaluate(SPEC, NET, timeout_s=0.01)
        assert ei.value.code == EvalError.DEADLINE_EXCEEDED
        with cli._plock:
            assert not cli._pending          # id abandoned, not leaked
        # the connection stays usable: the next (patient) request lands
        m = cli.evaluate(SPEC, NET, timeout_s=300.0)
        assert np.isfinite(m["latency_s"])
    ses.close()


def test_queue_full_over_wire():
    """Admission control crosses the wire: with max_queue=1 and a long
    linger, the second concurrent request is refused as QUEUE_FULL."""
    ses = Session(get_board(BOARD), linger_s=1.0, max_queue=1)
    with EvalServer(ses) as srv, _client(srv) as cli:
        first = cli.evaluate_async(SPEC, NET)     # parks in the queue
        time.sleep(0.1)
        with pytest.raises(EvalError) as ei:
            cli.evaluate(SPEC, NET)
        assert ei.value.code == EvalError.QUEUE_FULL
        first.result(timeout=300)                 # still delivered
    ses.close()


# --------------------------------------------------------------------------
# DSE over the wire, and lane isolation
# --------------------------------------------------------------------------
def test_explore_over_wire_matches_local(served):
    with _client(served) as cli:
        r = cli.explore(NET, n=128, strategy="random", seed=5)
    local = served.session.explore(get_cnn(NET), 128, strategy="random",
                                   seed=5)
    assert r["n_evals"] == local.n_evals == 128
    assert r["front"] == local.front.tolist()
    np.testing.assert_allclose(np.asarray(r["front_points"]),
                               local.front_points())


def test_deploy_over_wire(served):
    with _client(served) as cli:
        r = cli.deploy([NET, "resnet50"], n=48, seed=2)
    assert r["n_evals"] > 0
    assert r["front_size"] >= 1
    assert set(r["front_metrics"]) >= {"makespan_s"} \
        or len(r["front_metrics"]) > 0


def test_interactive_not_starved_by_batch_job(served):
    """An interactive probe lands within its deadline while an explore
    job holds the batch lane."""
    with _client(served) as cli:
        job = cli.request_async("explore", net=NET, n=2048,
                                strategy="random", seed=0)
        t0 = time.monotonic()
        cli.evaluate(SPEC, NET, deadline_s=30.0, priority="interactive")
        assert time.monotonic() - t0 < 30.0
        assert job.result(timeout=600)["n_evals"] == 2048


def test_server_bounded_under_key_churn():
    """The whole zoo (> 2x the table bound in distinct nets) through the
    wire: live tables never exceed the bound, evictions surface in the
    wire observability, answers stay correct."""
    from repro.cnn.registry import CNN_NAMES

    ses = Session(get_board(BOARD), linger_s=0.005, max_cached_tables=2)
    with EvalServer(ses) as srv, _client(srv) as cli:
        for name in CNN_NAMES:
            out = cli.evaluate([SPEC], name)
            want = ses.evaluate([SPEC], get_cnn(name))
            np.testing.assert_array_equal(np.asarray(out["latency_s"]),
                                          np.asarray(want["latency_s"]))
        caches = cli.observability()["caches"]
    assert caches["net_tables"]["size"] <= 2
    assert caches["net_tables"]["evictions"] >= len(CNN_NAMES) - 2
    ses.close()


# --------------------------------------------------------------------------
# lifecycle
# --------------------------------------------------------------------------
def test_graceful_shutdown_drains_inflight():
    """stop(drain=True) (the shutdown op) delivers every accepted
    response before closing the sockets."""
    ses = Session(get_board(BOARD), linger_s=0.3)
    ses.evaluate([SPEC], get_cnn(NET))
    srv = EvalServer(ses).start()
    addr = srv.address
    with _client(srv) as cli:
        fut = cli.evaluate_async(SPEC, NET)    # parked in the linger
        time.sleep(0.05)
        cli.shutdown(drain=True)
        out = fut.result(timeout=300)          # delivered, not dropped
        assert np.isfinite(out["latency_s"])
    # the listener is gone
    time.sleep(0.3)                            # shutdown thread finishes
    with pytest.raises(OSError):
        socket.create_connection(addr, timeout=0.5)
    srv.stop()                                 # idempotent
    ses.close()


def test_stop_is_idempotent_and_session_survives():
    ses = Session(get_board(BOARD), linger_s=0.005)
    srv = EvalServer(ses).start()
    srv.stop()
    srv.stop()
    # the server never owns the session
    m = ses.evaluate(SPEC, get_cnn(NET))
    assert np.isfinite(m.latency_s)
    ses.close()
