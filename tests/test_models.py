"""Per-arch smoke tests (deliverable f): reduced configs, one forward /
train / prefill / decode step on CPU; shapes + finiteness asserted."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.configs.base import smoke_shape
from repro.models import layers as L
from repro.models.registry import get_model, input_specs


def _mk_batch(cfg, shape, key=1):
    specs = input_specs(cfg, shape)
    out = {}
    for k, v in specs.items():
        if jnp.issubdtype(v.dtype, jnp.integer):
            out[k] = jnp.ones(v.shape, v.dtype)
        else:
            out[k] = jax.random.normal(jax.random.key(key), v.shape, v.dtype)
    return out


@pytest.fixture(scope="module")
def rt(local_rt):
    return local_rt


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_step_smoke(arch, rt):
    cfg = get_config(arch).reduced()
    api = get_model(cfg)
    params = api.init(jax.random.key(0))
    batch = _mk_batch(cfg, smoke_shape("train"))
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: api.loss(p, batch, rt), has_aux=True)(params)
    assert jnp.isfinite(loss)
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_decode_smoke(arch, rt):
    cfg = get_config(arch).reduced()
    api = get_model(cfg)
    params = api.init(jax.random.key(0))
    batch = _mk_batch(cfg, smoke_shape("prefill"))
    logits, cache = api.prefill(params, batch, rt, max_len=48)
    assert logits.shape[:2] == (2, 1)
    assert bool(jnp.isfinite(logits).all())
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    for _ in range(3):
        logits, cache = api.decode_step(params, cache, tok, rt)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "llama3.2-1b",
                                  "mamba2-370m", "zamba2-1.2b"])
def test_decode_consistent_with_forward(arch, rt):
    """Greedy decode after prefill must agree with teacher-forced forward."""
    cfg = get_config(arch).reduced()
    api = get_model(cfg)
    params = api.init(jax.random.key(0))
    B, S = 2, 24
    toks = jax.random.randint(jax.random.key(3), (B, S), 1, cfg.vocab_size)

    # teacher-forced logits at the last position
    full_logits, _ = api.forward(params, toks, rt)
    # prefill on the first S-1 tokens, then one decode step with token S-1
    logits_p, cache = api.prefill(params, {"tokens": toks[:, :-1]}, rt,
                                  max_len=S + 4)
    logits_d, _ = api.decode_step(params, cache, toks[:, -1:], rt)
    np.testing.assert_allclose(
        np.asarray(logits_d[:, 0]), np.asarray(full_logits[:, -1]),
        rtol=2e-2, atol=2e-2)


def test_flash_matches_dense_attention():
    B, S, H, Hkv, D = 2, 200, 4, 2, 32
    q = jax.random.normal(jax.random.key(0), (B, S, H, D))
    k = jax.random.normal(jax.random.key(1), (B, S, Hkv, D))
    v = jax.random.normal(jax.random.key(2), (B, S, Hkv, D))
    for window in (None, 48):
        a = L.chunked_attention(q, k, v, causal=True, window=window,
                                q_blk=64, kv_blk=64)
        b = L.dense_attention(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


def test_flash_grads_match_dense():
    B, S, H, D = 1, 130, 2, 16
    q = jax.random.normal(jax.random.key(0), (B, S, H, D))

    def f(fn):
        def loss(q):
            o = fn(q, q, q, causal=True, window=None)
            return (o.astype(jnp.float32) ** 2).sum()
        return jax.grad(loss)(q)

    import functools
    ga = f(functools.partial(L.chunked_attention, q_blk=64, kv_blk=32))
    gb = f(L.dense_attention)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(gb),
                               rtol=2e-4, atol=2e-4)


def test_moe_local_vs_ep_consistency(host_mesh):
    """local and shard_map EP dispatch compute the same function (on a
    1-device mesh EP reduces to local semantics)."""
    from repro.models.moe import init_moe, moe_ep, moe_local
    from repro.models.runtime import Runtime
    cfg = get_config("granite-moe-1b-a400m").reduced()
    p = init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model),
                          cfg.np_dtype)
    y1, aux1 = moe_local(p, x, cfg)
    y2, aux2 = moe_ep(p, x, cfg, host_mesh, ep_axis="model",
                      dp_axes=("data",))
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_param_count_matches_materialized():
    for arch in ("qwen1.5-0.5b", "llama3.2-1b", "mamba2-370m"):
        cfg = get_config(arch)
        declared = cfg.param_count()
        sds = jax.eval_shape(lambda c=cfg: get_model(c).init(
            jax.random.key(0)))
        actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(sds))
        # padded vocab inflates the materialized count slightly
        pad = (cfg.padded_vocab - cfg.vocab_size) * cfg.d_model
        pad *= 1 if cfg.tie_embeddings else 2
        assert abs(actual - pad - declared) / declared < 0.01, arch
