"""Telemetry acceptance smoke: one traced session round, schema-checked.

Sets ``REPRO_TELEMETRY_DIR`` **before** any repro import (the env-gated
activation path CI exercises), then runs a warm
evaluate -> explore -> deploy -> submit round plus a short
fault-injection burst, and asserts:

* the JSONL trace file exists and is non-empty;
* every line is schema-valid (``telemetry.read_trace`` raises otherwise);
* spans from all four session entry points are present;
* at least one ``resilience.*`` event landed under fault injection;
* ``Session.observability()`` agrees with the trace-side counters.

Usage (also run by the ``telemetry-smoke`` CI job):
    python tests/telemetry_smoke.py [trace_dir]
Exit code 0 = all assertions hold.
"""
from __future__ import annotations

import os
import sys
import tempfile

HERE = os.path.dirname(os.path.abspath(__file__))
SRC = os.path.join(os.path.dirname(HERE), "src")
sys.path.insert(0, SRC)
sys.path.insert(0, HERE)


def main(trace_dir: str) -> int:
    os.environ["REPRO_TELEMETRY_DIR"] = trace_dir

    # imports AFTER the env var: this is the env-gated activation path
    from faults import CountingHook, inject_fault
    from repro import telemetry
    from repro.api import Session
    from repro.cnn.registry import get_cnn
    from repro.core.dse.search import SearchConfig
    from repro.core.multinet import MultinetSearchConfig
    from repro.fpga.boards import get_board

    assert telemetry.enabled(), \
        "REPRO_TELEMETRY_DIR in the environment must enable telemetry"

    net, net2 = get_cnn("mobilenetv2"), get_cnn("resnet50")
    dev = get_board("zc706")
    ses = Session(dev)

    # warmup, then the traced warm round across all four entry points
    ses.evaluate("{L1-Last:CE1-CE4}", net)
    ses.evaluate("{L1-Last:CE1-CE4}", net)
    ses.explore(net, n=64, strategy="search", seed=0,
                config=SearchConfig(pop_size=32, seed=0))
    ses.deploy([net, net2], n=32, seed=0,
               config=MultinetSearchConfig(pop_size=16, seed=0))
    ses.submit(["{L1-Last:CE1-CE4}"], net).result(timeout=300)
    rep = ses.explain("{L1-Last:CE1-CE4}", net)
    assert rep["bottleneck"]["segment"] is not None

    # fault burst: trip the breaker so resilience events hit the trace
    fses = Session(dev, backend="pallas_interpret", design_tile=9,
                   fallback_backend="ref", max_retries=0)
    with inject_fault(CountingHook(backend="pallas_interpret")):
        for _ in range(fses.breaker.fail_threshold):
            # batched path: scalar evaluation is analytic and never
            # touches the kernel backend the hook faults
            fses.evaluate(["{L1-Last:CE1-CE4}"], net)
    assert fses.breaker.is_open, "fault burst never tripped the breaker"

    path = telemetry.trace_path()
    assert path and os.path.exists(path), "no trace file was written"
    lines = telemetry.read_trace(path)       # raises on any schema problem
    assert lines, "trace file is empty"

    names = {ln["name"] for ln in lines}
    for want in ("session.evaluate", "session.explore", "session.deploy",
                 "session.submit", "session.megabatch", "session.explain",
                 "dse.generation", "multinet.generation"):
        assert want in names, f"span/event {want!r} missing from trace"
    resilience_events = [ln for ln in lines if ln["type"] == "event"
                         and ln["name"].startswith("resilience.")]
    assert resilience_events, "no resilience.* event under fault injection"

    obs = ses.observability()
    counters = obs["telemetry"]["counters"]
    assert counters["session.scalar_evals"] >= 2
    assert obs["stats"]["submits"] == 1
    n_spans = sum(1 for ln in lines if ln["type"] == "span")
    print(f"telemetry smoke OK: {len(lines)} trace lines "
          f"({n_spans} spans, {len(resilience_events)} resilience "
          f"event(s)) in {path}")
    print("  span/event names:", ", ".join(sorted(names)))
    return 0


if __name__ == "__main__":
    if len(sys.argv) > 1:
        raise SystemExit(main(sys.argv[1]))
    with tempfile.TemporaryDirectory(prefix="repro-telemetry-") as d:
        raise SystemExit(main(d))
