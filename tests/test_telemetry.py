"""The observability layer: spans, the metrics registry, trace export,
and the bottleneck-attribution report.

Contracts pinned here (docs/observability.md):

* disabled telemetry is a true no-op — ``span()`` returns the shared
  singleton (identity, not equality), the registry does not grow, and no
  trace file appears;
* span nesting wires parent/trace ids through the thread-local stack and
  durations are monotonic (child <= parent, both >= the slept time);
* fixed-bucket histograms give EXACT percentiles when samples sit on
  bucket bounds (upper-bound quantile semantics);
* the JSONL trace round-trips through :func:`telemetry.read_trace`
  schema-valid;
* ``Session.explain`` reproduces ``benchmarks/fig6_fig7_breakdown.py``'s
  formulas bit-for-bit (same Metrics in, same numbers out);
* injected backend faults surface as resilience events in the trace.
"""
from __future__ import annotations

import math
import time

import pytest

from faults import CountingHook, inject_fault
from repro import telemetry
from repro.api import Session
from repro.cnn.registry import get_cnn
from repro.core.telemetry import _NOOP, _REGISTRY, Histogram
from repro.fpga.archs import make_arch
from repro.fpga.boards import get_board

NET = "mobilenetv2"
BOARD = "zc706"


@pytest.fixture()
def clean_telemetry(tmp_path):
    """Telemetry enabled with a fresh registry and a tmp trace dir;
    restores the disabled default afterwards so no other test sees it."""
    telemetry.disable()
    telemetry.reset()
    telemetry.enable(str(tmp_path))
    try:
        yield tmp_path
    finally:
        telemetry.disable()
        telemetry.reset()


@pytest.fixture()
def disabled_telemetry():
    telemetry.disable()
    telemetry.reset()
    yield
    telemetry.disable()
    telemetry.reset()


# --------------------------------------------------------------------------
# disabled mode: a true no-op
# --------------------------------------------------------------------------
def test_disabled_span_is_the_shared_singleton(disabled_telemetry):
    s1 = telemetry.span("a", {"k": 1})
    s2 = telemetry.span("b")
    assert s1 is _NOOP and s2 is _NOOP, \
        "disabled span() must return THE no-op singleton (no allocation)"
    assert telemetry.current_span() is _NOOP
    with s1 as s:
        s.set_attr("x", 1)
        s.add_event("e")


def test_disabled_mode_no_registry_growth_no_trace(disabled_telemetry,
                                                   tmp_path):
    size0 = _REGISTRY.size()
    telemetry.count("c")
    telemetry.gauge("g", 1.0)
    telemetry.observe("h", 0.5)
    telemetry.event("e", {"k": "v"})
    with telemetry.span("s", {"a": 1}):
        pass
    assert _REGISTRY.size() == size0 == 0
    assert telemetry.trace_path() is None
    assert list(tmp_path.iterdir()) == []
    snap = telemetry.snapshot()
    assert snap["enabled"] is False
    assert snap["counters"] == snap["gauges"] == snap["histograms"] == {}


# --------------------------------------------------------------------------
# spans: nesting + timing monotonicity
# --------------------------------------------------------------------------
def test_span_nesting_and_timing_monotonic(clean_telemetry):
    with telemetry.span("outer", {"who": "test"}) as outer:
        time.sleep(0.01)
        with telemetry.span("inner") as inner:
            assert inner.parent_id == outer.span_id
            assert inner.trace_id == outer.trace_id == outer.span_id
            assert telemetry.current_span() is inner
            time.sleep(0.01)
        assert telemetry.current_span() is outer
    assert inner.dur_s >= 0.01
    assert outer.dur_s >= inner.dur_s, \
        "a parent span can never be shorter than a child it encloses"
    # the span histogram recorded both
    snap = telemetry.snapshot()
    assert snap["histograms"]["span.outer.s"]["count"] == 1
    assert snap["histograms"]["span.inner.s"]["count"] == 1


def test_event_attaches_to_current_span_and_counts(clean_telemetry):
    with telemetry.span("work") as sp:
        telemetry.event("tick", {"n": 1})
    assert [e["name"] for e in sp.events] == ["tick"]
    assert telemetry.snapshot()["counters"]["event.tick"] == 1


# --------------------------------------------------------------------------
# histogram bucket math: exact percentiles on synthetic data
# --------------------------------------------------------------------------
def test_histogram_exact_percentiles_on_bucket_bounds():
    # 100 samples sitting exactly on the bounds 1..100: the q-quantile
    # observation IS bound ceil(100q) — upper-bound semantics make the
    # percentile exact, no interpolation error
    bounds = tuple(float(i) for i in range(1, 101))
    h = Histogram(bounds)
    for v in bounds:
        h.observe(v)
    assert h.percentile(0.50) == 50.0
    assert h.percentile(0.99) == 99.0
    assert h.percentile(0.999) == 100.0
    assert h.percentile(1.0) == 100.0
    assert h.total == 100 and h.sum == sum(bounds)
    d = h.as_dict()
    assert (d["p50"], d["p99"], d["p999"]) == (50.0, 99.0, 100.0)
    assert d["mean"] == pytest.approx(50.5)


def test_histogram_overflow_empty_and_validation():
    h = Histogram((1.0, 2.0))
    assert math.isnan(h.percentile(0.5))
    h.observe(5.0)                       # beyond the last bound
    assert h.percentile(0.5) == float("inf")
    h.observe(0.5)
    assert h.percentile(0.5) == 1.0      # first bucket's upper bound
    with pytest.raises(ValueError):
        Histogram((2.0, 1.0))
    with pytest.raises(ValueError):
        h.percentile(0.0)


def test_registry_observe_and_prometheus_export(clean_telemetry):
    for v in (0.001, 0.002, 0.004):
        telemetry.observe("lat", v, bounds=(0.001, 0.002, 0.004))
    telemetry.count("calls", 2)
    telemetry.gauge("depth", 7)
    text = telemetry.prometheus_text()
    assert "# TYPE repro_calls counter" in text
    assert "repro_calls 2" in text
    assert "repro_depth 7" in text
    assert 'repro_lat_bucket{le="0.002"} 2' in text
    assert 'repro_lat_bucket{le="+Inf"} 3' in text
    assert "repro_lat_count 3" in text


# --------------------------------------------------------------------------
# JSONL export round-trip
# --------------------------------------------------------------------------
def test_trace_jsonl_round_trip(clean_telemetry):
    with telemetry.span("outer", {"k": "v"}):
        telemetry.event("ping", {"i": 3})
        with telemetry.span("inner"):
            pass
    path = telemetry.trace_path()
    assert path is not None
    lines = telemetry.read_trace(path)          # raises on schema problems
    kinds = [(l["type"], l["name"]) for l in lines]
    # spans export on exit: inner closes before outer
    assert kinds == [("event", "ping"), ("span", "inner"),
                     ("span", "outer")]
    outer = lines[-1]
    inner = lines[-2]
    assert inner["parent"] == outer["span"]
    assert inner["trace"] == outer["trace"] == outer["span"]
    assert outer["attrs"] == {"k": "v"}
    assert [e["name"] for e in outer["events"]] == ["ping"]
    assert all(telemetry.validate_trace_line(l) == [] for l in lines)


def test_validate_trace_line_rejects_malformed():
    assert telemetry.validate_trace_line([]) != []
    assert telemetry.validate_trace_line({"type": "nope"}) != []
    missing = {"type": "span", "name": "x"}
    assert any("missing" in p for p in telemetry.validate_trace_line(missing))
    bad = {"type": "span", "name": "x", "trace": 1, "span": 1,
           "t_wall": 0.0, "dur_s": -1.0, "attrs": {}, "events": []}
    assert any("negative" in p for p in telemetry.validate_trace_line(bad))


# --------------------------------------------------------------------------
# the Session wiring: spans from every entry point + observability()
# --------------------------------------------------------------------------
def test_session_entry_points_emit_spans(clean_telemetry):
    net, dev = get_cnn(NET), get_board(BOARD)
    ses = Session(dev)
    ses.evaluate("{L1-Last:CE1-CE4}", net)
    ses.explore(net, n=32, chunk=32, seed=0)
    fut = ses.submit(["{L1-Last:CE1-CE4}"], net)
    fut.result(timeout=60)
    # the future resolves INSIDE the drain's megabatch span — give the
    # drain thread a beat to exit the span and flush its trace line
    want_names = {"session.evaluate", "session.explore", "session.submit",
                  "session.megabatch"}
    deadline = time.monotonic() + 5.0
    while True:
        names = {l["name"]
                 for l in telemetry.read_trace(telemetry.trace_path())}
        if want_names <= names or time.monotonic() > deadline:
            break
        time.sleep(0.01)
    for want in want_names:
        assert want in names, f"no {want} span exported"
    snap = telemetry.snapshot()
    assert snap["counters"]["session.scalar_evals"] >= 1
    assert snap["histograms"]["session.request_latency_s"]["count"] == 1
    obs = ses.observability()
    assert set(obs) == {"compile", "stats", "caches", "breaker",
                        "telemetry"}
    assert obs["stats"]["submits"] == 1
    assert obs["telemetry"]["enabled"] is True


def test_fault_injection_emits_resilience_events(clean_telemetry):
    net, dev = get_cnn(NET), get_board(BOARD)
    # design_tile=11 is unique to this test so the primary really traces
    # (and faults) instead of reusing a cached compile
    ses = Session(dev, backend="pallas_interpret", design_tile=11,
                  fallback_backend="ref", max_retries=0)
    specs = [make_arch("segmented", net, 4)]
    with inject_fault(CountingHook(backend="pallas_interpret")):
        for _ in range(ses.breaker.fail_threshold):
            ses.evaluate(specs, net)
    assert ses.breaker.is_open
    events = [l for l in telemetry.read_trace(telemetry.trace_path())
              if l["type"] == "event"]
    names = {e["name"] for e in events}
    assert "resilience.degrade" in names
    assert "resilience.breaker_open" in names
    assert telemetry.snapshot()["counters"]["session.degraded"] \
        == ses.stats.degraded


# --------------------------------------------------------------------------
# Session.explain: bit-for-bit parity with the fig6/fig7 formulas
# --------------------------------------------------------------------------
def test_explain_matches_fig6_fig7_formulas():
    """The report must BE the benchmark's analysis: every number derives
    from the same ``Metrics`` by the same formula, compared exactly."""
    net, dev = get_cnn("resnet50"), get_board(BOARD)
    ses = Session(dev)
    spec = make_arch("segmented_rr", net, 6)
    m = ses.evaluate(spec, net)
    rep = ses.explain(spec, net)

    # Fig. 6 layer granularity (fig6_fig7_breakdown.py lines)
    want_mem_bound = [r.layer.index for b in m.blocks for r in b.per_layer
                      if r.mem_cycles > r.compute_cycles]
    want_idle = (sum(max(r.mem_cycles - r.compute_cycles, 0.0)
                     for b in m.blocks for r in b.per_layer)
                 / sum(max(r.mem_cycles, r.compute_cycles)
                       for b in m.blocks for r in b.per_layer))
    assert rep["mem_bound_layers"] == want_mem_bound
    assert rep["idle_fraction"] == want_idle          # bit-for-bit
    assert len(want_mem_bound) > 0, \
        "SegmentedRR on ResNet50/ZC706 must show memory-bound layers"

    # Fig. 7 access split — exact Metrics fields, no re-derivation drift
    assert rep["access"]["weights_bytes"] == float(m.weight_access_bytes)
    assert rep["access"]["fm_bytes"] == float(m.fm_access_bytes)
    assert rep["access"]["total_bytes"] == float(m.access_bytes)
    assert rep["access"]["dominant"] == (
        "weights" if m.weight_access_bytes > m.fm_access_bytes else "fms")

    # segment ranking: occupancy-descending, shares sum to 1
    occs = [d["occupancy_s"] for d in rep["segments"]]
    assert occs == sorted(occs, reverse=True)
    assert sum(d["share"] for d in rep["segments"]) == pytest.approx(1.0)
    total = sum(max(s.compute_s, s.mem_s) for s in m.per_segment)
    for d in rep["segments"]:
        s = m.per_segment[d["index"]]
        assert d["occupancy_s"] == max(s.compute_s, s.mem_s)
        assert d["share"] == max(s.compute_s, s.mem_s) / total
        assert d["bound"] == ("memory" if s.mem_s > s.compute_s
                              else "compute")

    # CE ranking mirrors Metrics.ce_busy_s; the top CE bounds throughput
    assert {c["ce"]: c["busy_s"] for c in rep["ces"]} == m.ce_busy_s
    busiest = max(m.ce_busy_s.values())
    assert rep["bottleneck"]["ce_busy_s"] == busiest

    # summary is the Metrics headline, verbatim
    assert rep["summary"]["latency_s"] == m.latency_s
    assert rep["summary"]["throughput_ips"] == m.throughput_ips

    # the renderer covers every section without crashing
    text = telemetry.format_report(rep)
    assert "bottleneck: segment" in text and "idle fraction" in text


def test_explain_rejects_batches():
    from repro.api import EvalError

    net, dev = get_cnn(NET), get_board(BOARD)
    ses = Session(dev)
    with pytest.raises(EvalError):
        ses.explain(["{L1-Last:CE1-CE4}"], net)


# --------------------------------------------------------------------------
# search telemetry: per-generation counters/gauges
# --------------------------------------------------------------------------
def test_dse_search_emits_generation_telemetry(clean_telemetry):
    from repro.core.dse.search import SearchConfig

    net, dev = get_cnn(NET), get_board(BOARD)
    ses = Session(dev)
    ses.explore(net, n=128, strategy="search", seed=0,
                config=SearchConfig(pop_size=64, seed=0))
    snap = telemetry.snapshot()
    gens = [l for l in telemetry.read_trace(telemetry.trace_path())
            if l["name"] == "dse.generation"]
    assert len(gens) >= 1
    assert snap["counters"]["dse.generations"] == len(gens)
    assert "dse.front_size" in snap["gauges"]
    assert gens[-1]["attrs"]["front"] == snap["gauges"]["dse.front_size"]
