"""Bounded-cache guarantees (docs/serving.md): a long-lived session under
more distinct (net, board) keys than its bound stays memory-bounded, an
evicted entry rebuilds bit-identically on next use, eviction counters
surface in ``observability()``, and the mesh's sharded-jit LRU keeps
``mesh_compile_counts`` monotone across turnover.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.api import EvalConfig, Session
from repro.cnn.registry import get_cnn
from repro.core.cache import BoundedLRU, env_bound
from repro.core.device import DeviceSpec, mib
from repro.core.workload import make_network
from repro.fpga.boards import get_board

SPEC = "{L1-Last:CE1-CE2}"


def _tiny_net(i: int):
    """A distinct 3-layer synthetic net per ``i`` (distinct content →
    distinct NetTables cache key)."""
    c = 4 + i
    return make_network(f"tiny{i}", [
        dict(name="c0", kind="conv", in_ch=3, out_ch=c, kh=3, kw=3,
             stride=1, ih=16, iw=16),
        dict(name="c1", kind="conv", in_ch=c, out_ch=c, kh=3, kw=3,
             stride=2, ih=16, iw=16),
        dict(name="c2", kind="conv", in_ch=c, out_ch=2 * c, kh=1, kw=1,
             stride=1, ih=8, iw=8),
    ])


# --------------------------------------------------------------------------
# BoundedLRU unit behaviour
# --------------------------------------------------------------------------
def test_bounded_lru_evicts_least_recent():
    gone = []
    lru = BoundedLRU(2, on_evict=lambda k, v: gone.append(k))
    lru.put("a", 1)
    lru.put("b", 2)
    assert lru.get("a") == 1          # refresh: "b" is now the LRU entry
    lru.put("c", 3)
    assert gone == ["b"]
    assert lru.get("b") is None
    assert lru.get("a") == 1 and lru.get("c") == 3
    assert lru.stats() == {"size": 2, "maxsize": 2, "evictions": 1}


def test_bounded_lru_zero_bound_is_unbounded():
    lru = BoundedLRU(0)
    for i in range(500):
        lru.put(i, i)
    assert len(lru) == 500 and lru.evictions == 0


def test_env_bound_parses_unset_and_disable(monkeypatch):
    monkeypatch.delenv("REPRO_CACHE_TABLES", raising=False)
    assert env_bound("REPRO_CACHE_TABLES", 256) == 256
    monkeypatch.setenv("REPRO_CACHE_TABLES", "7")
    assert env_bound("REPRO_CACHE_TABLES", 256) == 7
    monkeypatch.setenv("REPRO_CACHE_TABLES", "0")
    assert env_bound("REPRO_CACHE_TABLES", 256) == 0


def test_config_resolves_table_bound_from_env(monkeypatch):
    monkeypatch.setenv("REPRO_CACHE_TABLES", "5")
    assert EvalConfig().resolved().max_cached_tables == 5
    # an explicit bound wins over the env
    assert EvalConfig(max_cached_tables=9).resolved() \
        .max_cached_tables == 9


# --------------------------------------------------------------------------
# session table caches
# --------------------------------------------------------------------------
def test_net_table_cache_stays_bounded_under_key_churn():
    """>2x the bound in distinct nets: live tables never exceed the
    bound, the overflow shows up as evictions, and observability()
    reports both."""
    ses = Session(get_board("zc706"), max_cached_tables=4)
    for i in range(10):
        ses.evaluate([SPEC], _tiny_net(i))
    caches = ses.observability()["caches"]
    assert caches["net_tables"]["size"] <= 4
    assert caches["net_tables"]["maxsize"] == 4
    assert caches["net_tables"]["evictions"] >= 6
    assert ses.stats.net_table_evictions == \
        caches["net_tables"]["evictions"]
    ses.close()


def test_evicted_net_table_rebuilds_bit_identically():
    ses = Session(get_board("zc706"), max_cached_tables=2)
    net0 = _tiny_net(0)
    first = ses.evaluate([SPEC], net0)
    for i in range(1, 5):                    # churn net0 out of the cache
        ses.evaluate([SPEC], _tiny_net(i))
    assert ses.stats.net_table_evictions >= 1
    builds_before = ses.stats.net_table_builds
    again = ses.evaluate([SPEC], net0)
    assert ses.stats.net_table_builds == builds_before + 1  # rebuilt
    for k in first:
        np.testing.assert_array_equal(np.asarray(first[k]),
                                      np.asarray(again[k]))
    ses.close()


def test_device_table_cache_bounded_under_board_churn():
    """More distinct boards than the bound — same guarantee on the
    device-table memo."""
    ses = Session(max_cached_tables=2)
    net = _tiny_net(0)
    boards = [DeviceSpec(f"b{i}", pes=256 + 64 * i,
                         on_chip_bytes=mib(1 + i), off_chip_gbps=4.0)
              for i in range(5)]
    for b in boards:
        ses.evaluate([SPEC], net, b)
    caches = ses.cache_stats()
    assert caches["device_tables"]["size"] <= 2
    assert caches["device_tables"]["evictions"] >= 3
    assert ses.stats.device_table_evictions >= 3
    ses.close()


def test_default_bounds_never_evict_in_normal_use():
    """The default bounds (256 tables) are far above any test or
    benchmark working set — a plain session never evicts."""
    ses = Session(get_board("zc706"))
    ses.evaluate([SPEC], get_cnn("mobilenetv2"))
    caches = ses.cache_stats()
    assert caches["net_tables"]["maxsize"] == 256
    for c in caches.values():
        assert c["evictions"] == 0
    ses.close()


def test_schedule_memo_bounded_under_design_churn():
    """More distinct designs than the bound through Session.schedule():
    the artifact memo stays at its bound, overflow surfaces as evictions
    in observability(), and a churned-out design rebuilds to an EQUAL
    artifact (bit-exact: the search is deterministic and the artifact is
    plain floats)."""
    ses = Session(get_board("zc706"), max_cached_tables=3)
    net = _tiny_net(0)
    specs = [f"{{L1-Last:CE1-CE{k}}}" for k in range(1, 9)]
    first = ses.schedule(specs[0], net)
    for s in specs[1:]:                       # churn the first one out
        ses.schedule(s, net)
    caches = ses.observability()["caches"]
    assert caches["schedule_artifacts"]["size"] <= 3
    assert caches["schedule_artifacts"]["maxsize"] == 3
    assert caches["schedule_artifacts"]["evictions"] >= len(specs) - 3
    assert ses.stats.schedule_evictions == \
        caches["schedule_artifacts"]["evictions"]
    builds_before = ses.stats.schedule_builds
    again = ses.schedule(specs[0], net)
    assert ses.stats.schedule_builds == builds_before + 1   # rebuilt
    assert again == first                     # dataclass equality: exact
    ses.close()


def test_schedule_memo_hit_returns_same_object():
    ses = Session(get_board("zc706"))
    net = _tiny_net(1)
    a = ses.schedule(SPEC, net)
    b = ses.schedule(SPEC, net)
    assert b is a
    assert ses.stats.schedule_hits == 1
    assert ses.stats.schedule_builds == 1
    assert ses.stats.schedule_calls == 2
    ses.close()


# --------------------------------------------------------------------------
# mesh sharded-jit LRU
# --------------------------------------------------------------------------
def test_mesh_jit_lru_bounded_and_counts_monotone():
    from repro.core.shard import EvalMesh, mesh_compile_counts

    mesh = EvalMesh(ndevices=1, max_jits=2)

    def f(x):
        return x * 2.0

    def counts_total():
        return sum(mesh_compile_counts().values())

    before = counts_total()
    for i in range(4):                       # distinct names → 4 entries
        fn = mesh.shard_jit(f"cache_probe_{i}", f)
        fn(np.ones(4, np.float32))
    assert len(mesh._jits) <= 2
    assert mesh.jit_evictions >= 2
    after = counts_total()
    assert after >= before               # eviction never loses history
    # re-requesting an evicted key rebuilds; the count only grows
    mesh.shard_jit("cache_probe_0", f)(np.ones(4, np.float32))
    assert counts_total() >= after


def test_mesh_jit_eviction_disabled_with_zero_bound():
    from repro.core.shard import EvalMesh

    mesh = EvalMesh(ndevices=1, max_jits=0)

    def g(x):
        return x + 1.0

    for i in range(6):
        mesh.shard_jit(f"unbounded_probe_{i}", g)
    assert mesh.jit_evictions == 0
    assert len(mesh._jits) >= 6


def test_session_reeval_after_jit_churn_no_new_compiles():
    """The headline reuse property survives the bounded registry at its
    default size: warm re-evaluation adds zero compile misses."""
    ses = Session(get_board("zc706"))
    net = get_cnn("mobilenetv2")
    ses.evaluate([SPEC], net)
    before = ses.compile_stats()["total"]
    ses.evaluate([SPEC], net)
    assert ses.compile_stats()["total"] == before
    ses.close()


def test_invalid_linger_max_rejected():
    with pytest.raises(ValueError, match="linger_max_s"):
        EvalConfig(linger_max_s=-0.1).resolved()
