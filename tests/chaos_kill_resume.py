"""SIGKILL-and-resume smoke: the checkpoint contract across real crashes.

The in-process chaos tests simulate crashes with a ``BaseException``; this
driver does it for real — a worker process runs the guided search with
checkpointing, ``SIGKILL``s itself right after its 2nd snapshot lands on
disk, and a fresh process resumes from the file.  The resumed front must
be bit-identical to an uninterrupted run, on BOTH search loops:

* ``serial``  — the single-population loop;
* ``island``  — the island model under ``REPRO_MESH_DEVICES=4`` (the env
  var is read before first jax use, so it only exists across a process
  boundary — the reason this file is a subprocess driver, not a test
  function).

Usage:
    python tests/chaos_kill_resume.py                 # driver: both modes
    python tests/chaos_kill_resume.py serial|island   # driver: one mode
    python tests/chaos_kill_resume.py worker <mode> <ckpt|-> <out.npz>

Workers honour ``REPRO_CHAOS_KILL_AFTER=N`` (die after the N-th snapshot)
and share one ``REPRO_JAX_CACHE_DIR`` so the three runs per mode compile
once.  Run by the ``chaos-smoke`` CI job and
``tests/test_chaos.py::test_sigkill_and_resume_subprocess``.
"""
from __future__ import annotations

import os
import signal
import subprocess
import sys
import tempfile

HERE = os.path.dirname(os.path.abspath(__file__))
SRC = os.path.join(os.path.dirname(HERE), "src")

#: per-mode search sizing: >= 5 generations each, so interval-2
#: checkpointing writes twice (gens 2 and 4) before the kill
SIZING = {
    "serial": dict(pop_size=32, budget=192, seed=3),
    "island": dict(pop_size=16, budget=320, seed=5,
                   migration_interval=2, migration_elites=4),
}
KILL_AFTER = 2
ISLAND_DEVICES = 4


# --------------------------------------------------------------------------
# worker: one search run (fresh or resumed), results to an .npz
# --------------------------------------------------------------------------
def worker(mode: str, ckpt: str, out_path: str) -> None:
    import numpy as np

    # import order is the point: shard first (reads REPRO_MESH_DEVICES and
    # force-splits the host platform), jax after — same bootstrap as
    # tests/shard_worker.py
    from repro.core import shard  # noqa: F401
    from repro.core import resilience
    from repro.core.dse.search import SearchConfig, search
    from repro.cnn.registry import get_cnn
    from repro.fpga.boards import get_board

    kill_after = int(os.environ.get("REPRO_CHAOS_KILL_AFTER", "0"))
    if kill_after:
        orig = resilience.save_checkpoint
        state = {"n": 0}

        def writer(path, kind, snap, meta=None):
            orig(path, kind, snap, meta=meta)
            state["n"] += 1
            if state["n"] >= kill_after:
                os.kill(os.getpid(), signal.SIGKILL)   # no cleanup, no exit
        resilience.save_checkpoint = writer

    mesh = None
    if mode == "island":
        from repro.core.shard import EvalMesh
        mesh = EvalMesh()
        assert mesh.is_sharded and mesh.ndevices == ISLAND_DEVICES, \
            f"mesh bootstrap failed: {mesh.ndevices} devices"
    cfg = SearchConfig(**SIZING[mode],
                       **({} if ckpt == "-" else
                          dict(checkpoint_path=ckpt, checkpoint_interval=2,
                               resume=True)))
    res = search(get_cnn("mobilenetv2"), get_board("zc706"), cfg, mesh=mesh)
    np.savez(out_path, front_idx=res.front_idx, points=res.points,
             latency=res.metrics["latency_s"],
             n_islands=len(res.island_fronts))
    print(f"WORKER_OK {mode} front={len(res.front_idx)}")


# --------------------------------------------------------------------------
# driver: reference run, killed run, resumed run; compare bit-exactly
# --------------------------------------------------------------------------
def _spawn(mode: str, ckpt: str, out: str, *, kill_after: int = 0,
           cache_dir: str) -> subprocess.CompletedProcess:
    env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["REPRO_MCCM_BACKEND"] = "ref"
    env["REPRO_JAX_CACHE_DIR"] = cache_dir
    env["REPRO_MESH_DEVICES"] = \
        str(ISLAND_DEVICES) if mode == "island" else "1"
    env.pop("REPRO_CHAOS_KILL_AFTER", None)
    if kill_after:
        env["REPRO_CHAOS_KILL_AFTER"] = str(kill_after)
    return subprocess.run(
        [sys.executable, os.path.abspath(__file__), "worker", mode, ckpt,
         out], env=env, capture_output=True, text=True, timeout=900)


def drive(mode: str) -> None:
    import numpy as np

    with tempfile.TemporaryDirectory(prefix=f"chaos-{mode}-") as tmp:
        cache = os.path.join(tmp, "jit-cache")
        ckpt = os.path.join(tmp, "search.ckpt")
        ref_npz = os.path.join(tmp, "ref.npz")
        res_npz = os.path.join(tmp, "resumed.npz")

        ref = _spawn(mode, "-", ref_npz, cache_dir=cache)
        assert ref.returncode == 0, \
            f"reference worker failed:\n{ref.stdout}\n{ref.stderr}"

        killed = _spawn(mode, ckpt, os.path.join(tmp, "never.npz"),
                        kill_after=KILL_AFTER, cache_dir=cache)
        assert killed.returncode == -signal.SIGKILL, \
            f"worker survived its own SIGKILL (rc={killed.returncode}):" \
            f"\n{killed.stdout}\n{killed.stderr}"
        assert os.path.exists(ckpt), "no checkpoint survived the kill"

        resumed = _spawn(mode, ckpt, res_npz, cache_dir=cache)
        assert resumed.returncode == 0, \
            f"resume worker failed:\n{resumed.stdout}\n{resumed.stderr}"

        a, b = np.load(ref_npz), np.load(res_npz)
        for key in ("front_idx", "points", "latency", "n_islands"):
            np.testing.assert_array_equal(
                a[key], b[key],
                err_msg=f"{mode}: resumed {key} != uninterrupted")
        print(f"CHAOS_OK {mode} (front {len(a['front_idx'])}, "
              f"islands {int(a['n_islands'])})")


def main(argv: list[str]) -> None:
    if argv and argv[0] == "worker":
        worker(argv[1], argv[2], argv[3])
        return
    for mode in argv or ("serial", "island"):
        if mode not in SIZING:
            raise SystemExit(f"unknown mode {mode!r}; known: serial, island")
        drive(mode)


if __name__ == "__main__":
    main(sys.argv[1:])
