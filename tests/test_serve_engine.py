"""ServeEngine behaviour: batching, stop tokens, greedy determinism."""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.runtime import Runtime
from repro.serve.engine import ServeEngine


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("qwen1.5-0.5b").reduced()
    eng = ServeEngine(cfg, rt=Runtime(), temperature=0.0)
    params = eng.api.init(jax.random.key(0))
    return eng, params


def test_greedy_deterministic(engine):
    eng, params = engine
    prompts = [[5, 6, 7, 8], [9, 10, 11]]
    a = eng.generate(params, prompts, max_new_tokens=8)
    b = eng.generate(params, prompts, max_new_tokens=8)
    assert a.tokens == b.tokens
    assert all(len(t) == 8 for t in a.tokens)


def test_batch_consistency(engine):
    """A request generates the same continuation alone or in a batch
    (static batching with right-aligned prompts of equal length)."""
    eng, params = engine
    p = [3, 4, 5, 6, 7, 8]
    solo = eng.generate(params, [p], max_new_tokens=6).tokens[0]
    batch = eng.generate(params, [p, p], max_new_tokens=6).tokens
    assert batch[0] == solo and batch[1] == solo


def test_stop_token(engine):
    eng, params = engine
    res = eng.generate(params, [[5, 6, 7]], max_new_tokens=12)
    stop = res.tokens[0][2]
    res2 = eng.generate(params, [[5, 6, 7]], max_new_tokens=12,
                        stop_token=stop)
    assert res2.tokens[0][-1] == stop
    assert len(res2.tokens[0]) <= 3


def test_tokens_in_vocab(engine):
    eng, params = engine
    res = eng.generate(params, [[1, 2, 3]], max_new_tokens=10)
    assert all(0 <= t < eng.cfg.vocab_size for t in res.tokens[0])
