"""Unit tests for MCCM building blocks (paper Eqs. 1-7)."""
from __future__ import annotations

import math

import pytest
from hypo_fallback import given, settings, st

from repro.core.blocks import (CE, eval_pipelined, eval_single_ce,
                               layer_cycles, layer_utilization,
                               pipeline_stage_sum, pipelined_min_buffer,
                               single_ce_min_buffer)
from repro.core.device import DeviceSpec, mib
from repro.core.workload import ConvLayer

DEV = DeviceSpec("test", pes=256, on_chip_bytes=mib(2), off_chip_gbps=8.0)


def _layer(i=0, f=64, c=32, k=3, s=1, hw=16, kind="conv", residual=False):
    return ConvLayer(index=i, name=f"l{i}", kind=kind, in_ch=c, out_ch=f,
                     kh=k, kw=k, stride=s, ih=hw, iw=hw, residual=residual)


# ---------------------------------------------------------------- Eq. 1
def test_layer_cycles_exact():
    l = _layer(f=6, c=4, k=1, hw=4)  # dims f=6 c=4 oh=4 ow=4
    ce = CE("ce", pes=16, par={"f": 4, "oh": 2, "ow": 2})
    # ceil(6/4)*4*1*1*ceil(4/2)*ceil(4/2) = 2*4*2*2
    assert layer_cycles(l, ce) == 2 * 4 * 2 * 2


def test_paper_underutilization_example():
    """§IV-A1: a 4x2x2 CE processing a 6-filter layer is half-utilized on
    the filter remainder."""
    l = _layer(f=6, c=1, k=1, hw=2)
    ce = CE("ce", pes=16, par={"f": 4, "oh": 2, "ow": 2})
    u = layer_utilization(l, ce)
    assert u == pytest.approx(6 / 8)  # 2 rounds of 4, only 6 useful


@given(f=st.integers(1, 300), oh=st.integers(1, 64), ow=st.integers(1, 64),
       pf=st.sampled_from([1, 2, 4, 8, 16]),
       ph=st.sampled_from([1, 2, 4]), pw=st.sampled_from([1, 2, 4]))
@settings(max_examples=60, deadline=None)
def test_utilization_bounds(f, oh, ow, pf, ph, pw):
    l = ConvLayer(index=0, name="l", kind="conv", in_ch=3, out_ch=f,
                  kh=3, kw=3, stride=1, ih=oh, iw=ow, padding="same")
    ce = CE("ce", pes=pf * ph * pw, par={"f": pf, "oh": ph, "ow": pw})
    u = layer_utilization(l, ce)
    assert 0.0 < u <= 1.0 + 1e-9
    # cycles * par >= macs (Eq. 1 never undercounts work)
    assert layer_cycles(l, ce) * pf * ph * pw >= l.macs


# ---------------------------------------------------------------- Eq. 2
def brute_stage_sum(lats, n_tiles):
    total = 0.0
    n = len(lats)
    for s in range(n_tiles + n - 1):
        lo, hi = max(0, s - n_tiles + 1), min(n - 1, s)
        total += max(lats[lo:hi + 1])
    return total


@given(st.lists(st.floats(0.1, 100.0), min_size=1, max_size=8),
       st.integers(1, 40))
@settings(max_examples=80, deadline=None)
def test_pipeline_stage_sum_matches_bruteforce(lats, n_tiles):
    assert pipeline_stage_sum(lats, n_tiles) == pytest.approx(
        brute_stage_sum(lats, n_tiles))


def test_pipeline_latency_vs_throughput_tradeoff():
    """Paper §IV-A1: pipelining raises throughput but (single-input)
    latency exceeds the busy time of the slowest CE."""
    layers = [_layer(i=i, f=32, c=16, hw=8) for i in range(3)]
    for i, l in enumerate(layers):
        layers[i] = l.replace(index=i)
    ces = [CE(f"ce{i}", pes=64, par={"f": 8, "oh": 2, "ow": 4})
           for i in range(3)]
    res = eval_pipelined(layers, ces, DEV, weights_resident=True)
    assert res.latency_cycles >= res.busy_cycles  # bubbles cost latency
    single = eval_single_ce(layers, ces[0].__class__(
        "big", pes=192, par={"f": 8, "oh": 4, "ow": 6}, buffer_bytes=mib(1)),
        DEV)
    assert single.latency_cycles == single.busy_cycles


# ---------------------------------------------------------------- Eq. 4/5
def test_min_buffers():
    layers = [_layer(i=0, f=16, c=8, hw=8), _layer(i=1, f=32, c=16, hw=8)]
    eq4 = single_ce_min_buffer(layers, ce_par_f=4, wordbytes=1)
    # max FMs + max weight tile
    fms = max(l.fms_size for l in layers)
    wtile = max(min(4, l.out_ch) * l.in_ch * 9 for l in layers)
    assert eq4 == fms + wtile
    eq5 = pipelined_min_buffer(layers, DEV)
    assert eq5 == sum(l.weights_size + 2 * l.out_ch * l.ow * 2
                      for l in layers)


def test_residual_fms_copy():
    plain = _layer(residual=False)
    res = _layer(residual=True)
    assert res.fms_size == plain.fms_size + plain.ofm_size


# ---------------------------------------------------------------- Eq. 6/7
def test_single_ce_ideal_min_access():
    """With a huge buffer, accesses = weights once (+ first IFM load)."""
    layers = [_layer(i=0, f=8, c=4, hw=8)]
    ce = CE("ce", pes=64, par={"f": 8, "oh": 2, "ow": 4},
            buffer_bytes=mib(64))
    res = eval_single_ce(layers, ce, DEV)
    assert res.access_bytes == pytest.approx(
        layers[0].weights_size + layers[0].ifm_size)


def test_single_ce_access_monotone_in_buffer():
    layers = [_layer(i=i, f=128, c=64, hw=32) for i in range(2)]
    layers = [l.replace(index=i) for i, l in enumerate(layers)]
    prev = None
    for buf in (mib(0.05), mib(0.2), mib(1), mib(8)):
        ce = CE("ce", pes=64, par={"f": 8, "oh": 2, "ow": 4},
                buffer_bytes=int(buf))
        acc = eval_single_ce(layers, ce, DEV).access_bytes
        if prev is not None:
            assert acc <= prev + 1e-6
        prev = acc


def test_pipelined_weight_streaming_penalty():
    """Eq. 7: weights not resident are re-streamed; resident cost ~0."""
    layers = [_layer(i=i) for i in range(2)]
    layers = [l.replace(index=i) for i, l in enumerate(layers)]
    ces = [CE(f"c{i}", pes=64, par={"f": 8, "oh": 2, "ow": 4},
              buffer_bytes=0) for i in range(2)]
    resident = eval_pipelined(layers, ces, DEV, weights_resident=True)
    streamed = eval_pipelined(layers, ces, DEV, weights_resident=False)
    assert resident.access_bytes == 0.0
    assert streamed.access_bytes > 0.0
