"""Input fuzzing: arbitrary notation strings and (corrupted) DesignBatch
rows either evaluate to finite metrics or fail as ``EvalError`` with the
``INVALID_INPUT`` code — never an uncaught parser/indexing exception, and
never silently non-finite numbers (docs/robustness.md taxonomy contract).

Runs under real ``hypothesis`` when installed, else the deterministic
``hypo_fallback`` shim — strings are built from token lists (the shim has
no ``st.text``), which also keeps the corpus centred on near-miss inputs
instead of pure noise.
"""
from __future__ import annotations

import numpy as np

from hypo_fallback import given, settings, st
from repro.api import EvalError, Session
from repro.cnn.registry import get_cnn
from repro.core.dse.encoding import NC, NS, DesignBatch
from repro.core.dse.samplers import sample_mixed
from repro.fpga.boards import get_board

NET = get_cnn("vgg16")
SES = Session(get_board("zc706"))


def _finite_or_invalid(call):
    """The fuzz contract: a finite result, or EvalError(INVALID_INPUT)."""
    try:
        out = call()
    except EvalError as e:
        assert e.code == EvalError.INVALID_INPUT, \
            f"fuzzed input mapped to {e.code}, want INVALID_INPUT: {e}"
        return None
    return out


# --------------------------------------------------------------------------
# notation strings: near-miss entries assembled from grammar tokens
# --------------------------------------------------------------------------
@st.composite
def notation_strings(draw):
    entries = []
    for _ in range(draw(st.integers(min_value=1, max_value=4))):
        lo = draw(st.integers(min_value=0, max_value=40))
        hi = draw(st.sampled_from(
            ["", "-Last", "-last"] + [f"-L{h}" for h in (0, 1, 5, 13, 40)]
            + [f"-{h}" for h in (3, 13)]))
        clo = draw(st.integers(min_value=0, max_value=NC + 3))
        chi = draw(st.sampled_from(
            [""] + [f"-CE{c}" for c in (0, 1, 2, 4, NC, NC + 3)]))
        sep = draw(st.sampled_from([":", "", ";"]))
        prefix = draw(st.sampled_from(["L", "", "X"]))
        entries.append(f"{prefix}{lo}{hi}{sep}CE{clo}{chi}")
    body = ", ".join(entries)
    wrap = draw(st.sampled_from(["{%s}", "%s", "{%s", "%s}"]))
    return wrap % body


@settings(max_examples=40, deadline=None)
@given(text=notation_strings())
def test_fuzzed_notation_never_escapes_the_taxonomy(text):
    m = _finite_or_invalid(lambda: SES.evaluate(text, NET))
    if m is not None:   # parsed + evaluated: the metrics must be finite
        assert np.isfinite([m.latency_s, m.throughput_ips]).all()


@settings(max_examples=40, deadline=None)
@given(text=notation_strings())
def test_fuzzed_submit_rejects_synchronously(text):
    """submit() applies the same parse guard before queueing: a bad spec
    raises HERE (INVALID_INPUT), a good one resolves to finite floats."""
    fut = _finite_or_invalid(lambda: SES.submit(text, NET))
    if fut is not None:
        out = fut.result(timeout=300)
        assert np.isfinite(out["latency_s"])


# --------------------------------------------------------------------------
# DesignBatch rows: valid samples, then targeted corruption
# --------------------------------------------------------------------------
_B = 4   # fixed fuzz batch: every example pads to one compiled shape

_CORRUPTIONS = ("none", "neg_end", "end_over", "unsorted", "nce_zero",
                "nce_over", "pad_dirty")


@st.composite
def design_batches(draw):
    rng = np.random.default_rng(draw(st.integers(min_value=0,
                                                 max_value=100_000)))
    db = sample_mixed(rng, len(NET), _B, min_ces=1, max_ces=8)
    se, sp, sn, ip = (np.array(a) for a in db.to_numpy())
    row = draw(st.integers(min_value=0, max_value=_B - 1))
    col = draw(st.integers(min_value=0, max_value=NS - 1))
    kind = draw(st.sampled_from(_CORRUPTIONS))
    if kind == "neg_end":
        se[row, col] = -draw(st.integers(min_value=1, max_value=5))
    elif kind == "end_over":
        se[row, col] = len(NET) + draw(st.integers(min_value=1,
                                                   max_value=9))
    elif kind == "unsorted":
        se[row, 0], se[row, -1] = se[row, -1].copy(), se[row, 0].copy()
    elif kind == "nce_zero":
        sn[row, col] = 0
    elif kind == "nce_over":
        sn[row, col] = NC + draw(st.integers(min_value=1, max_value=7))
    elif kind == "pad_dirty":
        # padding columns must stay canonical; scribble on the last one
        sn[row, NS - 1] = 3
        se[row, NS - 1] = se[row, NS - 2]
    return DesignBatch.from_numpy(se, sp, sn, ip), kind


@settings(max_examples=40, deadline=None)
@given(dbk=design_batches())
def test_fuzzed_design_batches_never_escape_the_taxonomy(dbk):
    db, kind = dbk
    out = _finite_or_invalid(lambda: SES.evaluate(db, NET))
    if kind == "none":
        assert out is not None, "a valid sampled batch was rejected"
    if out is not None:
        assert np.isfinite(np.asarray(out["latency_s"])).all()
        assert np.isfinite(np.asarray(out["throughput_ips"])).all()
