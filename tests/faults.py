"""Deterministic fault-injection harness for the chaos suite.

Three injection points, all count-based (no wall clock, no randomness) so
every chaos scenario replays exactly:

* :class:`CountingHook` + :func:`inject_fault` — raise out of the kernel
  dispatch (``mccm_eval.ops.parallelism_search``) at TRACE time, which is
  what a broken Pallas lowering looks like to the session.  Failed jit
  compiles are not cached, so every call through the faulty backend keeps
  faulting — the repeated-failure signature the circuit breaker consumes.
  The hook filters on the backend name, so a session's ``ref`` fallback
  traces straight through the same injection point unharmed.
* :func:`poison_megabatch` — wrap ``session._evaluate_specs_multi`` so
  one job's metrics come back NaN: the silent-corruption case the finite
  guards must isolate to that request's future.
* :func:`kill_after_checkpoints` — let the first N checkpoint writes land
  on disk, then raise :class:`Killed` (a ``BaseException``, like a real
  SIGKILL neither the search loop nor pytest machinery will swallow) out
  of the search loop: the crash-mid-search case checkpoint/resume must
  recover bit-identically.  ``tests/chaos_kill_resume.py`` runs the same
  scenario with an actual ``SIGKILL`` across processes.

Used by ``tests/test_chaos.py``; semantics in ``docs/robustness.md``.
"""
from __future__ import annotations

import contextlib

import numpy as np

from repro.kernels.mccm_eval import ops as _ops


class FaultInjected(RuntimeError):
    """The synthetic backend fault the harness raises at trace time."""


class CountingHook:
    """A fault hook that raises :class:`FaultInjected` on the first
    ``fail_first_n`` traces through the kernel dispatch (``None`` = every
    trace), counting every matching trace either way.

    ``backend`` restricts the faults (and the count) to one backend name,
    so a degraded session's fallback traces are left alone.
    """

    def __init__(self, fail_first_n: int | None = None,
                 backend: str | None = None):
        self.fail_first_n = fail_first_n
        self.backend = backend
        self.calls = 0

    def __call__(self, site: str, backend: str) -> None:
        if self.backend is not None and backend != self.backend:
            return
        self.calls += 1
        if self.fail_first_n is None or self.calls <= self.fail_first_n:
            raise FaultInjected(
                f"injected fault at {site} (backend={backend}, "
                f"trace #{self.calls})")


@contextlib.contextmanager
def inject_fault(hook):
    """Install ``hook`` as the kernel fault hook for the block, restoring
    whatever was installed before (exception-safe, so one failing chaos
    test can't poison the rest of the suite)."""
    prev = _ops.set_fault_hook(hook)
    try:
        yield hook
    finally:
        _ops.set_fault_hook(prev)


@contextlib.contextmanager
def poison_megabatch(job_index: int, key: str = "latency_s"):
    """Corrupt one job of every megabatch dispatch for the block: job
    ``job_index``'s ``key`` metric comes back all-NaN, everything else is
    delivered verbatim — silent data corruption, not an exception."""
    from repro.core import session as _session

    orig = _session._evaluate_specs_multi

    def poisoned(jobs, *args, **kwargs):
        results = list(orig(jobs, *args, **kwargs))
        if job_index < len(results):
            out = dict(results[job_index])
            arr = np.array(out[key], dtype=np.float64, copy=True)
            arr[...] = np.nan
            out[key] = arr
            results[job_index] = out
        return results

    _session._evaluate_specs_multi = poisoned
    try:
        yield
    finally:
        _session._evaluate_specs_multi = orig


class Killed(BaseException):
    """Simulated hard crash (BaseException so nothing downstream of the
    checkpoint writer can catch-and-continue past it, like SIGKILL)."""


@contextlib.contextmanager
def kill_after_checkpoints(n: int):
    """Let the first ``n`` checkpoint writes complete, then raise
    :class:`Killed` out of the writer — i.e. the process dies right after
    its n-th snapshot lands on disk.  Yields a dict whose ``"writes"``
    entry counts the completed writes."""
    from repro.core import resilience as res

    orig = res.save_checkpoint
    state = {"writes": 0}

    def writer(path, kind, snap, meta=None):
        orig(path, kind, snap, meta=meta)
        state["writes"] += 1
        if state["writes"] >= n:
            raise Killed(f"simulated crash after checkpoint write #{n}")

    res.save_checkpoint = writer
    try:
        yield state
    finally:
        res.save_checkpoint = orig
